#!/usr/bin/env python3
"""Scrape a running server's `stats` wire verb and fail on impossible
values.

Opens one TCP connection to the server, sends the line-delimited
`{"verb":"stats"}` request, reads back the single JSON snapshot line,
and cross-checks the counters the way `serve::metrics::Snapshot::check`
does server-side — plus a few reader-side checks (histogram percentile
ordering, per-shard sums against the aggregates). CI runs it after the
TCP loadgen cell, so a snapshot that claims more completions than
admissions (or shards that do not sum to their aggregate) turns the
build red instead of shipping a lying dashboard.

Unlike bench_guard.py this script *gates*: metric arithmetic is exact,
so a violation is a bug, never noise.

Usage: check_stats.py HOST:PORT [--expect-min-ok N] [--timeout SEC]
"""

import argparse
import json
import socket
import sys

# Execution-side counters that exist both per shard and as aggregates;
# mirrors serve::metrics::SHARD_FIELDS minus the `shard` index itself.
SHARD_SUMMED = (
    "batches",
    "cache_hits",
    "cache_misses",
    "errors",
    "hot_hits",
    "ok",
    "steals",
)

HISTS = (
    "batch_size",
    "queue_wait_us",
    "span_admit_ns",
    "span_assemble_ns",
    "span_forward_ns",
    "span_serialize_ns",
)


def fetch(addr, timeout):
    host, _, port = addr.rpartition(":")
    with socket.create_connection((host, int(port)), timeout=timeout) as s:
        s.sendall(b'{"verb":"stats"}\n')
        buf = b""
        while not buf.endswith(b"\n"):
            chunk = s.recv(65536)
            if not chunk:
                raise RuntimeError("server closed before sending a snapshot line")
            buf += chunk
    return json.loads(buf)


def check(snap, expect_min_ok):
    errors = []

    def ensure(cond, msg):
        if not cond:
            errors.append(msg)

    def num(key):
        v = snap.get(key)
        ensure(isinstance(v, (int, float)), f"missing numeric counter {key!r}")
        return v if isinstance(v, (int, float)) else 0

    admitted = num("admitted")
    ok = num("ok")
    errs = num("errors")
    expired = num("expired")
    ensure(
        ok + errs + expired <= admitted,
        f"ok {ok} + errors {errs} + expired {expired} > admitted {admitted}",
    )
    ensure(
        num("cache_misses") <= num("prepared_builds"),
        "more cache misses than prepared-state builds",
    )
    ensure(
        num("steals") + num("hot_hits") <= num("batches"),
        "more stolen/hot batches than batches",
    )
    # failure-domain counters, mirroring Snapshot::check server-side:
    # a quarantine is one admitted request and one recovered panic, and
    # drain flushes only happen to admitted work after a drain began
    quarantined = num("requests_quarantined")
    ensure(quarantined <= admitted, "more quarantined requests than admitted")
    ensure(
        quarantined <= num("panics_recovered"),
        "more quarantined requests than recovered panics",
    )
    flushed = num("drain_flushed")
    ensure(flushed <= admitted, "more drain-flushed requests than admitted")
    ensure(
        flushed == 0 or num("drain_begun") > 0,
        "drain_flushed nonzero but no drain ever began",
    )
    num("conns_reaped")  # presence check: the reaper counter is on the wire
    ensure(ok >= expect_min_ok, f"ok {ok} < expected minimum {expect_min_ok}")

    shards = snap.get("shards", [])
    ensure(isinstance(shards, list), "shards is not an array")
    for field in SHARD_SUMMED:
        total = sum(s.get(field, 0) for s in shards if isinstance(s, dict))
        ensure(
            total == num(field),
            f"per-shard {field} sums to {total}, aggregate says {num(field)}",
        )

    for name in HISTS:
        h = snap.get(name)
        if not isinstance(h, dict):
            errors.append(f"missing histogram {name!r}")
            continue
        count, mx = h.get("count", 0), h.get("max", 0)
        p50, p95, p99 = h.get("p50", 0), h.get("p95", 0), h.get("p99", 0)
        ensure(0 <= p50 <= p95 <= p99, f"{name}: percentiles out of order")
        ensure(p99 <= mx, f"{name}: p99 {p99} above max {mx}")
        if count == 0:
            ensure(mx == 0, f"{name}: empty histogram with max {mx}")
    # every request dispatched got a queue-wait sample
    qw = snap.get("queue_wait_us", {})
    if isinstance(qw, dict):
        ensure(
            qw.get("count", 0) >= ok,
            f"queue_wait_us count {qw.get('count')} below ok {ok}",
        )
    return errors


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("addr", help="HOST:PORT of a running `repro serve --listen`")
    ap.add_argument("--expect-min-ok", type=int, default=1)
    ap.add_argument("--timeout", type=float, default=30.0)
    args = ap.parse_args()

    snap = fetch(args.addr, args.timeout)
    errors = check(snap, args.expect_min_ok)
    for e in errors:
        print(f"::error title=impossible server stats::{e}")
    if errors:
        return 1
    print(
        "stats ok: admitted {admitted} ok {ok} errors {errors} expired {expired} "
        "batches {batches} across {n} shard(s)".format(
            n=len(snap.get("shards", [])), **{k: snap.get(k) for k in
            ("admitted", "ok", "errors", "expired", "batches")}
        )
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
