#!/usr/bin/env python3
"""Bench regression guard: diff two BENCH_runtime.json files.

Compares `toks_per_s` per (model, quant, backend) cell between a
previous CI artifact and the fresh one, and emits non-blocking GitHub
`::warning::` annotations for cells that regressed by more than the
threshold (default 10%). Always exits 0 — the guard annotates, it does
not gate (CI runners are shared and noisy; a red X on noise would train
people to ignore it).

Usage: bench_guard.py PREV.json CURRENT.json [--threshold 0.10]
"""

import argparse
import json
import sys


def load_cells(path):
    with open(path) as f:
        doc = json.load(f)
    cells = {}
    for row in doc.get("eval_throughput", []):
        key = (row.get("model"), row.get("quant"), row.get("backend"))
        tps = row.get("toks_per_s")
        if all(key) and isinstance(tps, (int, float)) and tps > 0:
            cells[key] = tps
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("previous")
    ap.add_argument("current")
    ap.add_argument("--threshold", type=float, default=0.10)
    args = ap.parse_args()

    try:
        prev = load_cells(args.previous)
        cur = load_cells(args.current)
    except (OSError, json.JSONDecodeError) as e:
        print(f"::notice::bench guard: could not parse inputs ({e}); skipping")
        return 0

    if not prev or not cur:
        print("::notice::bench guard: no comparable eval_throughput cells; skipping")
        return 0

    regressions = []
    improvements = 0
    for key, old_tps in sorted(prev.items()):
        new_tps = cur.get(key)
        if new_tps is None:
            continue
        ratio = new_tps / old_tps
        model, quant, backend = key
        if ratio < 1.0 - args.threshold:
            regressions.append((model, quant, backend, old_tps, new_tps, ratio))
        elif ratio > 1.0 + args.threshold:
            improvements += 1

    for model, quant, backend, old_tps, new_tps, ratio in regressions:
        print(
            f"::warning title=bench regression::{model}/{quant} @ {backend}: "
            f"{old_tps:.0f} -> {new_tps:.0f} tok/s ({(1 - ratio) * 100:.1f}% slower "
            f"than the previous BENCH_runtime artifact)"
        )

    common = len(set(prev) & set(cur))
    print(
        f"bench guard: {common} comparable cells, "
        f"{len(regressions)} regressed > {args.threshold:.0%}, "
        f"{improvements} improved > {args.threshold:.0%}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
