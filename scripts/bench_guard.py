#!/usr/bin/env python3
"""Bench regression guard: diff a fresh BENCH json against a rolling
baseline of previous CI artifacts.

Compares `toks_per_s` per (section, model, quant, backend) cell between
the fresh artifact and the **median** of the last N main-branch
artifacts, and emits non-blocking GitHub `::warning::` annotations for
cells that regressed by more than the threshold (default 10%). The
median baseline absorbs single noisy runs on shared CI runners — one
unlucky previous artifact no longer poisons (or masks) the comparison
the way a single-file diff did. Always exits 0 — the guard annotates,
it does not gate (a red X on noise would train people to ignore it).

Both `eval_throughput` (BENCH_runtime.json) and `serve_throughput`
(BENCH_serve.json) sections are understood; cells are keyed per section
so the same (model, quant, backend) triple never collides across files.
The `int_gemm` section of BENCH_tensor.json is tracked too: its
per-backend `int_speedup_vs_fused` (the true i8 GEMM's advantage over
the fused QDQ path) is a higher-is-better ratio, so the same median
comparison applies with the speedup standing in for toks_per_s. The
`metrics_overhead` cell of BENCH_serve.json follows the same shape:
its `throughput_ratio` (hot-path speed without recording over with,
higher is better, ~1.0 when recording is cheap) is watched so a future
change cannot quietly make the always-on metrics layer expensive.

Usage: bench_guard.py CURRENT.json PREV.json [PREV.json ...]
                      [--threshold 0.10]
"""

import argparse
import json
import statistics
import sys

SECTIONS = ("eval_throughput", "serve_throughput")


def load_cells(path):
    with open(path) as f:
        doc = json.load(f)
    cells = {}
    for section in SECTIONS:
        for row in doc.get(section, []):
            key = (section, row.get("model"), row.get("quant"), row.get("backend"))
            tps = row.get("toks_per_s")
            if all(key) and isinstance(tps, (int, float)) and tps > 0:
                cells[key] = tps
    # int_gemm (BENCH_tensor.json): per-backend int-vs-fused speedup
    ig = doc.get("int_gemm")
    if isinstance(ig, dict):
        quant = ig.get("quant") or "w8a8"
        for row in ig.get("results", []):
            key = ("int_gemm", "tensor", quant, row.get("backend"))
            sp = row.get("int_speedup_vs_fused")
            if all(key) and isinstance(sp, (int, float)) and sp > 0:
                cells[key] = sp
    # metrics_overhead (BENCH_serve.json): recording-off over recording-on
    # hot-path throughput — only tracked for metrics-enabled builds, so a
    # `no-metrics` artifact cannot skew the baseline toward ratio 1.0
    mo = doc.get("metrics_overhead")
    if isinstance(mo, dict) and mo.get("metrics_enabled") is True:
        ratio = mo.get("throughput_ratio")
        if isinstance(ratio, (int, float)) and ratio > 0:
            cells[("metrics_overhead", "serve", "hot_path", "wire")] = ratio
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("current")
    ap.add_argument("previous", nargs="+")
    ap.add_argument("--threshold", type=float, default=0.10)
    args = ap.parse_args()

    try:
        cur = load_cells(args.current)
    except (OSError, json.JSONDecodeError) as e:
        print(f"::notice::bench guard: could not parse current artifact ({e}); skipping")
        return 0

    # Per-cell history across however many previous artifacts parsed;
    # unreadable baselines are dropped individually, not fatally.
    history = {}
    usable_prev = 0
    for path in args.previous:
        try:
            prev = load_cells(path)
        except (OSError, json.JSONDecodeError) as e:
            print(f"::notice::bench guard: skipping unreadable baseline {path} ({e})")
            continue
        if not prev:
            continue
        usable_prev += 1
        for key, tps in prev.items():
            history.setdefault(key, []).append(tps)

    if not history or not cur:
        print("::notice::bench guard: no comparable throughput cells; skipping")
        return 0

    regressions = []
    improvements = 0
    for key, samples in sorted(history.items()):
        new_tps = cur.get(key)
        if new_tps is None:
            continue
        baseline = statistics.median(samples)
        ratio = new_tps / baseline
        if ratio < 1.0 - args.threshold:
            regressions.append((key, baseline, new_tps, ratio, len(samples)))
        elif ratio > 1.0 + args.threshold:
            improvements += 1

    for (section, model, quant, backend), baseline, new_tps, ratio, n in regressions:
        if section == "int_gemm":
            shown = f"median {baseline:.2f}x -> {new_tps:.2f}x int-vs-fused speedup"
        elif section == "metrics_overhead":
            shown = (
                f"median {baseline:.3f} -> {new_tps:.3f} without/with hot-path "
                f"ratio (metrics recording got more expensive)"
            )
        else:
            shown = f"median {baseline:.0f} -> {new_tps:.0f} tok/s"
        print(
            f"::warning title=bench regression::{section}: {model}/{quant} @ {backend}: "
            f"{shown} ({(1 - ratio) * 100:.1f}% slower than the median of {n} "
            f"previous main-branch artifact{'s' if n != 1 else ''})"
        )

    common = len(set(history) & set(cur))
    print(
        f"bench guard: {common} comparable cells over {usable_prev} baseline "
        f"artifact(s), {len(regressions)} regressed > {args.threshold:.0%}, "
        f"{improvements} improved > {args.threshold:.0%}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
