//! Format sweep (Tables II & VI): one model, every payload format the
//! simulator supports, at both ABFP vector lengths.
//!
//!   cargo run --release --example format_sweep [-- sim-opt-350m]

use anyhow::Result;
use intfpqsim::quantsim::{QuantConfig, Simulator};

fn main() -> Result<()> {
    let model = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "sim-opt-125m".to_string());
    let sim = Simulator::new("artifacts", "checkpoints")?;

    let fp32 = sim.evaluate(&model, &QuantConfig::fp32())?;
    println!("\n{}  (FP32 PPL = {:.2})", model, fp32.value);
    println!("{:<22} {:>10} {:>12}", "config", "PPL", "vs FP32");

    let configs = [
        "abfp_w4a4_n64",
        "abfp_w4a4_n128",
        "abfp_e2m1_n64",
        "abfp_e1m2_n64",
        "abfp_e1m2_n128",
        "abfp_w4a8_n64",
        "abfp_w4a8_n128",
        "abfp_w4ae4m3_n64",
        "mse_w4a4",
        "mse_w4a8",
    ];
    for c in configs {
        let m = sim.evaluate(&model, &QuantConfig::abfp(c))?;
        println!(
            "{:<22} {:>10.2} {:>11.1}%",
            c,
            m.value,
            100.0 * fp32.value / m.value
        );
    }
    Ok(())
}
