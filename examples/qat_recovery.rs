//! Accuracy recovery (Tables III & VII): plain ABFP vs ABFP-QAT vs
//! ABFP-SQ vs GPTQ on one model, at W4A4 and W4A8.
//!
//!   cargo run --release --example qat_recovery [-- sim-opt-350m]

use anyhow::Result;
use intfpqsim::quantsim::{Method, QuantConfig, Simulator};

fn main() -> Result<()> {
    let model = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "sim-opt-125m".to_string());
    let sim = Simulator::new("artifacts", "checkpoints")?;

    let fp32 = sim.evaluate(&model, &QuantConfig::fp32())?;
    println!("\n{}  (FP32 PPL = {:.2})", model, fp32.value);
    println!(
        "{:<14} {:>12} {:>12} {:>12} {:>12}",
        "acts", "ABFP", "ABFP-QAT", "ABFP-SQ", "GPTQ W4A16"
    );

    for acts in ["w4a4", "w4a8"] {
        let base = format!("abfp_{}_n64", acts);
        let plain = sim.evaluate(&model, &QuantConfig::abfp(&base))?;
        let qat = sim.evaluate(&model, &QuantConfig::with(&base, Method::Qat))?;
        let sq = sim.evaluate(&model, &QuantConfig::with(&base, Method::SmoothQuant))?;
        let gptq = sim.evaluate(&model, &QuantConfig::with("fp32", Method::Gptq))?;
        println!(
            "{:<14} {:>12.2} {:>12.2} {:>12.2} {:>12.2}",
            acts, plain.value, qat.value, sq.value, gptq.value
        );
    }
    println!("\nLower is better; QAT/SQ should close most of the gap to FP32.");
    Ok(())
}
