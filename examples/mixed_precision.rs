//! Per-layer mixed precision — the feature the paper's §VI defers
//! ("INT-FP-QSim currently does not support specification of different
//! quantizers for different layers"), implemented here as first-class
//! quant configs with layer overrides.
//!
//! Sweeps uniform W4A4 / W4A8 against boundary-block mixed configs and
//! prints the accuracy-vs-footprint trade-off, including the two-level
//! (VS-Quant) scale-storage variant.
//!
//!   cargo run --release --example mixed_precision [-- sim-opt-1.3b]

use anyhow::Result;
use intfpqsim::formats::scale_overhead_bits;
use intfpqsim::quantsim::{QuantConfig, Simulator};

/// Mean payload bits/element across a model's quantized sites for a
/// (weight_bits, act_bits) config — weights dominate storage, acts
/// dominate bandwidth; we report the weight side (what "W4" compresses).
fn weight_bits(uniform: f64, boundary: Option<f64>, layers: usize) -> f64 {
    match boundary {
        None => uniform,
        // first + last block at `b`, interior at `uniform`
        Some(b) => {
            let nb = 2.0_f64.min(layers as f64);
            (b * nb + uniform * (layers as f64 - nb)) / layers as f64
        }
    }
}

fn main() -> Result<()> {
    let model = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "sim-opt-125m".to_string());
    let sim = Simulator::new("artifacts", "checkpoints")?;
    let cfg = sim.rt.manifest.model(&model)?.clone();
    let fp32 = sim.evaluate(&model, &QuantConfig::fp32())?;

    println!(
        "\n{} (L={}, FP32 PPL = {:.2}): accuracy vs weight footprint",
        model, cfg.layers, fp32.value
    );
    println!(
        "{:<24} {:>8} {:>10} {:>12}",
        "config", "PPL", "w-bits/elt", "scale-bits"
    );

    // (label, quant config, uniform weight bits, boundary weight bits,
    //  two-level scales?)
    let rows: [(&str, &str, f64, Option<f64>, bool); 6] = [
        ("uniform W4A4", "abfp_w4a4_n64", 4.0, None, false),
        ("uniform W4A8", "abfp_w4a8_n64", 4.0, None, false),
        ("boundary A8", "mixed_a8_boundary_n64", 4.0, None, false),
        ("boundary W8A8", "mixed_w8a8_boundary_n64", 4.0, Some(8.0), false),
        ("two-level W4A4", "abfp2_w4a4_n64", 4.0, None, true),
        ("two-level W4A8", "abfp2_w4a8_n64", 4.0, None, true),
    ];
    for (label, quant, wu, wb, two_level) in rows {
        let m = sim.evaluate(&model, &QuantConfig::abfp(quant))?;
        let wbits = weight_bits(wu, wb, cfg.layers);
        let k = 4 * cfg.d; // widest reduction axis (fc2)
        let sbits =
            scale_overhead_bits(k, 64, if two_level { Some(8) } else { None });
        println!(
            "{:<24} {:>8.2} {:>10.2} {:>12.3}",
            label, m.value, wbits, sbits
        );
    }
    println!(
        "\nReading: boundary-8-bit buys back most of the W4A4 gap for a\n\
         fraction of uniform-W4A8's activation traffic; two-level scales\n\
         halve ABFP's scale storage at (near) zero PPL cost."
    );
    Ok(())
}
