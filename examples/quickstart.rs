//! Quickstart: evaluate one model at FP32 and at 4-bit weights / 8-bit
//! activations with ABFP — the simulator'score loop in ~20 lines.
//!
//!   make artifacts && cargo run --release --example quickstart
//!
//! (First run pretrains the FP32 checkpoint, ~20s on one core.)

use anyhow::Result;
use intfpqsim::quantsim::{QuantConfig, Simulator};

fn main() -> Result<()> {
    let sim = Simulator::new("artifacts", "checkpoints")?;
    let model = "sim-opt-125m";

    let fp32 = sim.evaluate(model, &QuantConfig::fp32())?;
    let w4a8 = sim.evaluate(model, &QuantConfig::abfp("abfp_w4a8_n64"))?;
    let w4a4 = sim.evaluate(model, &QuantConfig::abfp("abfp_w4a4_n64"))?;

    println!("\n{} on the synthetic Wikitext2 stand-in:", model);
    println!("  FP32                 PPL = {:.2}", fp32.value);
    println!("  ABFP W4A8 (n=64)     PPL = {:.2}", w4a8.value);
    println!("  ABFP W4A4 (n=64)     PPL = {:.2}", w4a4.value);
    println!(
        "\nW4A8 keeps {:.1}% of FP32 quality; W4A4 keeps {:.1}% (Fig. 1).",
        100.0 * fp32.value / w4a8.value,
        100.0 * fp32.value / w4a4.value
    );
    Ok(())
}
