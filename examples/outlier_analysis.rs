//! Outlier analysis: why per-tensor static quantization loses to ABFP.
//!
//! Captures the input activations of every quantized site (the same
//! capture artifact the MSE calibrator uses), then prints per-site range
//! statistics: absmax, the MSE-optimal clip range at 4 and 8 bits, and
//! the channel-range spread (max/median of per-channel absmax) — the
//! quantity SmoothQuant migrates and RPTQ clusters.  This is the
//! diagnostic view behind the paper's §IV-A discussion ("the MSE values
//! would have to clip most outliers to be effective").
//!
//!   cargo run --release --example outlier_analysis [-- sim-opt-350m]

use anyhow::Result;
use intfpqsim::calib;
use intfpqsim::quantsim::Simulator;

fn main() -> Result<()> {
    let model = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "sim-opt-125m".to_string());
    let sim = Simulator::new("artifacts", "checkpoints")?;
    let stats = sim.calibration(&model)?;

    println!("\n{}: activation-range anatomy per quantized site", model);
    println!(
        "{:<16} {:>10} {:>10} {:>10} {:>12} {:>10}",
        "site", "absmax", "mse_a4", "mse_a8", "clip@4bit", "ch-spread"
    );
    for (site, t) in &stats.acts {
        let absmax = t.absmax();
        let a4 = calib::mse_alpha(&t.data, 4);
        let a8 = calib::mse_alpha(&t.data, 8);

        // Per-channel absmax over the last axis: spread = max / median.
        let k = *t.shape.last().unwrap();
        let mut ch = vec![0.0f32; k];
        for row in t.data.chunks(k) {
            for (c, &v) in ch.iter_mut().zip(row) {
                *c = c.max(v.abs());
            }
        }
        let mut sorted = ch.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[k / 2].max(1e-12);
        let spread = sorted[k - 1] / median;

        println!(
            "{:<16} {:>10.3} {:>10.3} {:>10.3} {:>11.1}% {:>9.1}x",
            site,
            absmax,
            a4,
            a8,
            100.0 * a4 / absmax, // how much of the range MSE@4bit keeps
            spread
        );
    }
    println!(
        "\nReading: clip@4bit far below 100% means the MSE calibrator is\n\
         sacrificing outliers (the Table I failure mode); ch-spread >> 1\n\
         is the per-channel range variation SmoothQuant (alpha=0.5)\n\
         migrates into the weights and RPTQ absorbs with cluster scales.\n\
         ABFP sidesteps both: every 64-element vector gets its own scale."
    );
    Ok(())
}
