//! End-to-end driver (DESIGN.md "end-to-end validation"): exercises the
//! FULL stack on a real workload — Pallas-kernel artifacts, the PJRT
//! runtime, the Rust training driver, calibration, every PTQ method and
//! QAT — on one language model trained from scratch:
//!
//!   1. pretrain an OPT-style LM on the synthetic corpus, logging the
//!      loss curve (written to checkpoints/<model>.e2e.losses.json);
//!   2. evaluate FP32 / ABFP W4A4 / ABFP W4A8 perplexity;
//!   3. recover with SmoothQuant, GPTQ and QAT;
//!   4. print the loss curve + paper-shaped summary.
//!
//!   cargo run --release --example e2e_train [-- sim-opt-350m [steps]]

use anyhow::Result;
use intfpqsim::model;
use intfpqsim::quantsim::{Method, QuantConfig, Simulator};
use intfpqsim::train::{run_training, TrainOpts};

fn sparkline(losses: &[f32], buckets: usize) -> String {
    let glyphs = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let chunk = (losses.len() / buckets).max(1);
    let means: Vec<f32> = losses
        .chunks(chunk)
        .map(|c| c.iter().sum::<f32>() / c.len() as f32)
        .collect();
    let (lo, hi) = means
        .iter()
        .fold((f32::MAX, f32::MIN), |(l, h), &v| (l.min(v), h.max(v)));
    means
        .iter()
        .map(|&v| {
            let t = if hi > lo { (v - lo) / (hi - lo) } else { 0.0 };
            glyphs[((1.0 - t) * 7.0) as usize]
        })
        .collect()
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model_name = args.first().cloned().unwrap_or_else(|| "sim-opt-350m".into());
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(400);

    let sim = Simulator::new("artifacts", "checkpoints")?;
    let cfg = sim.rt.manifest.model(&model_name)?.clone();
    println!(
        "== e2e: {} ({} params, d={}, L={}) ==",
        model_name,
        cfg.param_count(),
        cfg.d,
        cfg.layers
    );

    // --- 1. pretrain from scratch (force a fresh run for the demo) ----
    let opts = TrainOpts { steps, ..Default::default() };
    let t0 = std::time::Instant::now();
    let init = model::init_params(&cfg, opts.seed);
    let result = run_training(
        &sim.rt,
        &format!("{}/train_fp32", model_name),
        init,
        &opts,
    )?;
    let train_secs = t0.elapsed().as_secs_f64();
    sim.ck.save(&model_name, "fp32", &result.params)?;
    let losses = &result.losses;
    println!(
        "\nloss curve ({} steps, {:.0}s, {:.1} steps/s):",
        steps,
        train_secs,
        steps as f64 / train_secs
    );
    println!("  {}", sparkline(losses, 60));
    println!(
        "  first {:.3}  min {:.3}  last {:.3}",
        losses[0],
        losses.iter().cloned().fold(f32::MAX, f32::min),
        losses[losses.len() - 1]
    );
    // persist the curve for EXPERIMENTS.md
    let json = intfpqsim::util::json::Json::Arr(
        losses.iter().map(|&l| intfpqsim::util::json::Json::Num(l as f64)).collect(),
    );
    std::fs::write(
        format!("checkpoints/{}.e2e.losses.json", model_name),
        json.dump(),
    )?;

    // --- 2-3. quantize + recover -------------------------------------
    println!("\n{:<26} {:>10}", "config", "PPL");
    let fp32 = sim.evaluate(&model_name, &QuantConfig::fp32())?;
    println!("{:<26} {:>10.2}", "fp32", fp32.value);
    for (label, qc) in [
        ("abfp w4a4 n64", QuantConfig::abfp("abfp_w4a4_n64")),
        ("abfp w4a8 n64", QuantConfig::abfp("abfp_w4a8_n64")),
        ("abfp w4a4 + SmoothQuant", QuantConfig::with("abfp_w4a4_n64", Method::SmoothQuant)),
        ("gptq w4a16", QuantConfig::with("fp32", Method::Gptq)),
        ("abfp w4a4 + QAT", QuantConfig::with("abfp_w4a4_n64", Method::Qat)),
    ] {
        let m = sim.evaluate(&model_name, &qc)?;
        println!("{:<26} {:>10.2}", label, m.value);
    }
    println!(
        "\nAll layers composed: Pallas kernels -> HLO artifacts -> PJRT runtime -> Rust coordinator."
    );
    Ok(())
}
