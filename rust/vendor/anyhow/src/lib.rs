//! Vendored, dependency-free stand-in for the `anyhow` crate.
//!
//! The offline build environment has no crates.io registry, so the crate
//! is provided as a path dependency implementing exactly the API surface
//! this repository uses: [`Error`], [`Result`], the [`Context`] extension
//! trait for `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!`
//! macros. Formatting matches upstream `anyhow` where the repo depends on
//! it: `{}` prints the outermost message, `{:#}` prints the full
//! `outer: inner: root` chain, and `{:?}` prints a `Caused by:` list.

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error: an outermost message plus the chain of
/// underlying causes, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message (what `anyhow!` produces).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an additional layer of context (outermost).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    fn from_std<E: std::error::Error>(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {}", cause)?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::from_std(e)
    }
}

/// Conversion into [`Error`] for both std errors and `Error` itself
/// (mirrors `anyhow`'s `ext::StdError` trick so `Context` has one
/// blanket impl over `Result`).
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
    fn into_error(self) -> Error {
        Error::from_std(self)
    }
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

/// `anyhow::Context`: attach context to failures of `Result` / `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: IntoError> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a formatted message, or any
/// displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an [`Error`] when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::Error::msg(format!(
                "condition failed: `{}`",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/real/path/xyz")
            .context("read config")?;
        Ok(s)
    }

    #[test]
    fn context_chain_formats() {
        let err = io_fail().unwrap_err();
        let plain = format!("{}", err);
        let full = format!("{:#}", err);
        assert_eq!(plain, "read config");
        assert!(full.starts_with("read config: "), "{}", full);
        assert!(full.len() > plain.len());
        let dbg = format!("{:?}", err);
        assert!(dbg.contains("Caused by:"), "{}", dbg);
    }

    #[test]
    fn option_context_and_macros() {
        let v: Option<u32> = None;
        let err = v.context("missing value").unwrap_err();
        assert_eq!(format!("{:#}", err), "missing value");

        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {}", x);
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(format!("{}", f(12).unwrap_err()).contains("12"));
        assert!(format!("{}", f(3).unwrap_err()).contains("three"));
        let from_string = anyhow!(String::from("plain message"));
        assert_eq!(format!("{}", from_string), "plain message");
    }

    #[test]
    fn with_context_on_result_and_error_passthrough() {
        let base: Result<()> = Err(anyhow!("root"));
        let err = base.with_context(|| format!("layer {}", 1)).unwrap_err();
        assert_eq!(format!("{:#}", err), "layer 1: root");
    }
}
