//! Vendored stub of the `xla` (PJRT) bindings used by the runtime layer.
//!
//! The offline build environment has neither the XLA C++ toolchain nor a
//! crates.io registry, so this crate provides the exact API surface
//! `runtime/mod.rs` consumes — client construction, HLO-text loading,
//! compilation, buffer upload and execution — with every operation that
//! would require a real PJRT runtime returning a descriptive error.
//!
//! Client construction succeeds (so `Runtime::new` still fails on the
//! *manifest*, with its actionable "run `make artifacts`" message, rather
//! than here); everything downstream of artifact loading reports that
//! PJRT is unavailable. All integration tests and benches already gate on
//! `artifacts/manifest.json` existing, so they skip cleanly under the
//! stub. Swapping in real bindings is a one-line change in
//! `rust/Cargo.toml` — no simulator code references the stub directly.

use std::fmt;

const UNAVAILABLE: &str =
    "PJRT unavailable: the `xla` crate is stubbed in this build (see rust/vendor/xla)";

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types uploadable to device buffers.
pub trait NativeType: Copy {}

impl NativeType for f32 {}
impl NativeType for i32 {}

/// Host handle to a PJRT device plugin.
pub struct PjRtClient(());

impl PjRtClient {
    /// The CPU plugin. Succeeds under the stub so callers fail later with
    /// per-operation errors instead of at startup.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient(()))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(UNAVAILABLE.to_string()))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error(UNAVAILABLE.to_string()))
    }
}

/// Parsed HLO module (stub: parsing always reports PJRT unavailable).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error(UNAVAILABLE.to_string()))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A compiled, device-loaded executable.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with borrowed argument buffers; returns per-device,
    /// per-output buffers.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(UNAVAILABLE.to_string()))
    }
}

/// A device-resident buffer.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error(UNAVAILABLE.to_string()))
    }
}

/// A host-side literal value.
pub struct Literal(());

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error(UNAVAILABLE.to_string()))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error(UNAVAILABLE.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_but_operations_report_unavailable() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client
            .buffer_from_host_buffer::<f32>(&[1.0], &[1], None)
            .is_err());
        let err = HloModuleProto::from_text_file("x.hlo.txt").unwrap_err();
        assert!(err.to_string().contains("PJRT unavailable"));
    }
}
