//! Table/figure regeneration benches: one timed reduced-fidelity run per
//! paper table & figure (the full-fidelity versions live behind
//! `repro experiment --all`). Prints the same rows the paper reports and
//! the wall time each regeneration takes.
//!
//!   cargo bench --bench bench_tables            # all
//!   cargo bench --bench bench_tables -- table2  # one

use intfpqsim::coordinator;
use intfpqsim::quantsim::Simulator;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("artifacts not built; run `make artifacts` first");
        return;
    }
    let filter: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let mut sim = Simulator::new("artifacts", "checkpoints").unwrap();
    // reduced fidelity: enough to show each table's shape quickly
    sim.opts.eval_batches = 4;
    sim.opts.pass1_programs = 16;
    sim.opts.qat_opts.steps = 8;

    for exp in coordinator::registry() {
        if !filter.is_empty() && !filter.iter().any(|f| exp.id.contains(f.as_str())) {
            continue;
        }
        let t0 = std::time::Instant::now();
        match (exp.run)(&sim) {
            Ok(mut rep) => {
                rep.meta.insert("id".into(), exp.id.into());
                rep.meta.insert("title".into(), exp.title.into());
                rep.meta.insert("paper_ref".into(), exp.paper_ref.into());
                println!("{}", rep.render());
                println!(
                    "[bench_tables] {} regenerated in {:.1}s (reduced fidelity)\n",
                    exp.id,
                    t0.elapsed().as_secs_f64()
                );
            }
            Err(e) => println!("[bench_tables] {} FAILED: {:#}", exp.id, e),
        }
    }
}
