//! Ablation micro-benchmarks for the extension features (DESIGN.md
//! §Extensions): two-level scale quantization cost vs plain ABFP, the
//! scale-storage accounting, and the output-quantizer (f_q^y) overhead
//! on a full fake-quantized matmul layer mirror.
//!
//!   cargo bench --bench bench_ablation

use intfpqsim::formats::{self, scale_overhead_bits, Format};
use intfpqsim::util::rng::Pcg64;
use intfpqsim::util::timer::bench;

fn heavy(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.gaussian() * rng.lognormal(1.0)).collect()
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let mut rng = Pcg64::new(7);
    let (rows, k) = if fast { (128usize, 1024usize) } else { (512usize, 2048usize) };
    let x = heavy(&mut rng, rows * k);
    let elems = (rows * k) as f64;

    println!("== one-level vs two-level ABFP ({}x{} f32) ==", rows, k);
    for (name, two) in [("abfp  int4 n64", false), ("abfp2 int4 n64", true)] {
        let mut buf = x.clone();
        let s = bench(if fast { 0 } else { 3 }, if fast { 2 } else { 20 }, || {
            buf.copy_from_slice(&x);
            if two {
                formats::abfp2_qdq(&mut buf, k, Format::Int(formats::INT4), 64, 8);
            } else {
                formats::abfp_qdq(&mut buf, k, Format::Int(formats::INT4), 64);
            }
            std::hint::black_box(&buf);
        });
        println!("{}", s.report(name, Some((elems / 1e6, "Melem"))));
    }

    println!("\n== scale-code bit-width sweep (abfp2 int4 n64) ==");
    for sb in [2u32, 4, 8, 12] {
        let mut buf = x.clone();
        let s = bench(if fast { 0 } else { 2 }, if fast { 1 } else { 10 }, || {
            buf.copy_from_slice(&x);
            formats::abfp2_qdq(&mut buf, k, Format::Int(formats::INT4), 64, sb);
            std::hint::black_box(&buf);
        });
        // Also report the reconstruction error the bit-width buys.
        let mut probe = x.clone();
        formats::abfp2_qdq(&mut probe, k, Format::Int(formats::INT4), 64, sb);
        let mse: f64 = probe
            .iter()
            .zip(&x)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / elems;
        println!(
            "{}  mse={:.3e} scale-bits/elt={:.4}",
            s.report(&format!("scale_bits={:>2}", sb), Some((elems / 1e6, "Melem"))),
            mse,
            scale_overhead_bits(k, 64, Some(sb)),
        );
    }

    println!("\n== output-quantizer overhead on a layer mirror ==");
    // y = QDQ_w(W) @ QDQ_a(X)^T is the runtime's fake-quant layer; f_q^y
    // adds one more ABFP pass over the (rows, dout) output.
    let dout = if fast { 128usize } else { 512usize };
    let w = heavy(&mut rng, dout * k);
    let mut y = vec![0.0f32; rows * dout];
    let matmul = |xq: &[f32], wq: &[f32], y: &mut [f32]| {
        // blocked ikj matmul, enough to dominate like the real HLO does
        y.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..rows {
            for l in 0..k {
                let xv = xq[i * k + l];
                if xv == 0.0 {
                    continue;
                }
                let wrow = &wq[l..]; // column l of W^T view
                for j in 0..dout {
                    y[i * dout + j] += xv * wrow[j * k];
                }
            }
        }
    };
    for (name, with_oq) in [("W4A4, y fp32", false), ("W4A4, y int8", true)] {
        let mut xq = x.clone();
        let mut wq = w.clone();
        formats::abfp_qdq(&mut xq, k, Format::Int(formats::INT4), 64);
        formats::abfp_qdq(&mut wq, k, Format::Int(formats::INT4), 64);
        let s = bench(0, 2, || {
            matmul(&xq, &wq, &mut y);
            if with_oq {
                formats::abfp_qdq(&mut y, dout, Format::Int(formats::INT8), 64);
            }
            std::hint::black_box(&y);
        });
        println!("{}", s.report(name, None));
    }
}
