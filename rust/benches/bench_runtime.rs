//! L3 runtime benchmarks: end-to-end artifact evaluation throughput on
//! the native executor, compared across every tensor backend.
//!
//! The native path needs no on-disk artifacts (the manifest is
//! synthesized), so unlike the PJRT era this bench always runs — in CI
//! it writes `BENCH_runtime.json` (tokens/sec per model × quant ×
//! backend) which the workflow uploads as an artifact, seeding the
//! repo's end-to-end perf trajectory. Every cell is also measured with
//! the fused qdq_matmul_t path disabled (`net::set_qdq_fusion`), so the
//! JSON carries a fused-vs-unfused A/B per backend × quant — tokens/sec
//! both ways plus the activation-temporary bytes one forward requests
//! on each path (`net::qdq_temp`).
//!
//!   cargo bench --bench bench_runtime [-- --fast]

use intfpqsim::corpus::TextCorpus;
use intfpqsim::model;
use intfpqsim::model::net;
use intfpqsim::runtime::{Runtime, Val};
use intfpqsim::tensor::backend;
use intfpqsim::util::json::Json;
use intfpqsim::util::timer::bench;

struct Row {
    model: String,
    quant: String,
    backend: String,
    mean_ms: f64,
    toks_per_s: f64,
    toks_per_s_unfused: f64,
    fused_speedup: f64,
    temp_bytes_fused: u64,
    temp_bytes_unfused: u64,
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let rt = Runtime::new("artifacts").unwrap();
    let corpus = TextCorpus::new(intfpqsim::corpus::TEXT_SEED);
    let threads = backend::env_threads();
    let (warmup, iters) = if fast { (1, 3) } else { (3, 12) };

    let models: &[&str] = if fast {
        &["sim-opt-125m"]
    } else {
        &["sim-opt-125m", "sim-opt-2.7b"]
    };
    let quants = ["fp32", "abfp_w4a4_n64", "abfp_w4a8_n64"];

    let mut rows: Vec<Row> = Vec::new();
    for model_name in models {
        let cfg = rt.manifest.model(model_name).unwrap().clone();
        let params = model::init_params(&cfg, 1);
        let sticky = model::param_vals(&cfg, &params).unwrap();
        let toks_per_batch = (cfg.batch * cfg.seq) as f64;
        let tb = corpus.eval_batch(0, cfg.batch, cfg.seq);
        let tv = Val::I32(tb.tokens.clone(), vec![cfg.batch, cfg.seq]);

        println!("\n== {} (batch {} x seq {}) ==", model_name, cfg.batch, cfg.seq);
        for &be_name in backend::all_names() {
            backend::configure(be_name, threads).unwrap();
            let be_desc = backend::active().describe();
            for quant in quants {
                let id = format!("{}/eval_{}", model_name, quant);
                let mut st = sticky.clone();
                if quant != "fp32" {
                    for s in &cfg.sites {
                        st.insert(
                            format!("smooth.{}", s.name),
                            Val::F32(vec![1.0; s.dim], vec![s.dim]),
                        );
                    }
                }
                // session open includes the one-time weight QDQ prep
                let sess = rt.session(&id, &st).unwrap();
                // default (fused) leg — field names stay the baseline's
                net::set_qdq_fusion(true);
                let s = bench(warmup, iters, || {
                    std::hint::black_box(sess.run(std::slice::from_ref(&tv)).unwrap());
                });
                net::qdq_temp::reset();
                let _ = sess.run(std::slice::from_ref(&tv)).unwrap();
                let temp_fused = net::qdq_temp::bytes();
                // unfused A/B leg (same bytes, different allocation)
                net::set_qdq_fusion(false);
                let s_unf = bench(warmup, iters, || {
                    std::hint::black_box(sess.run(std::slice::from_ref(&tv)).unwrap());
                });
                net::qdq_temp::reset();
                let _ = sess.run(std::slice::from_ref(&tv)).unwrap();
                let temp_unfused = net::qdq_temp::bytes();
                net::set_qdq_fusion(true);
                let tps = toks_per_batch / (s.mean_ns / 1e9);
                let tps_unf = toks_per_batch / (s_unf.mean_ns / 1e9);
                let label = format!("{} @ {}", quant, be_desc);
                println!("{}", s.report(&label, Some((toks_per_batch, "tok"))));
                println!(
                    "  fused {:.0} tok/s vs unfused {:.0} tok/s ({:.2}x); temps {} -> {} B/fwd",
                    tps,
                    tps_unf,
                    tps / tps_unf.max(1e-9),
                    temp_unfused,
                    temp_fused
                );
                rows.push(Row {
                    model: model_name.to_string(),
                    quant: quant.to_string(),
                    backend: be_desc.clone(),
                    mean_ms: s.mean_ms(),
                    toks_per_s: tps,
                    toks_per_s_unfused: tps_unf,
                    fused_speedup: tps / tps_unf.max(1e-9),
                    temp_bytes_fused: temp_fused,
                    temp_bytes_unfused: temp_unfused,
                });
            }
        }
        backend::configure("auto", threads).unwrap();

        // coordinator overhead: data generation only (no execute)
        let s = bench(1, 20, || {
            let tb = corpus.eval_batch(1, cfg.batch, cfg.seq);
            std::hint::black_box(Val::I32(tb.tokens, vec![cfg.batch, cfg.seq]));
        });
        println!("{}", s.report("coordinator-side batch prep", Some((toks_per_batch, "tok"))));

        // session-open cost (weight conversion + QDQ prep) — amortized
        // once per config
        let s = bench(1, 5, || {
            let id = format!("{}/eval_fp32", model_name);
            std::hint::black_box(rt.session(&id, &sticky).unwrap());
        });
        println!("{}", s.report("session open (weight prep)", None));
    }

    let json = Json::obj(vec![
        ("bench", Json::Str("runtime_native".into())),
        ("fast", Json::Bool(fast)),
        ("executor", Json::Str(rt.executor_name().into())),
        ("threads", Json::Num(threads as f64)),
        (
            "eval_throughput",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("model", Json::Str(r.model.clone())),
                            ("quant", Json::Str(r.quant.clone())),
                            ("backend", Json::Str(r.backend.clone())),
                            ("mean_ms", Json::Num(r.mean_ms)),
                            ("toks_per_s", Json::Num(r.toks_per_s)),
                            ("toks_per_s_unfused", Json::Num(r.toks_per_s_unfused)),
                            ("fused_speedup", Json::Num(r.fused_speedup)),
                            ("temp_bytes_fused", Json::Num(r.temp_bytes_fused as f64)),
                            (
                                "temp_bytes_unfused",
                                Json::Num(r.temp_bytes_unfused as f64),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    match std::fs::write("BENCH_runtime.json", json.pretty()) {
        Ok(()) => println!("\nwrote BENCH_runtime.json"),
        Err(e) => eprintln!("could not write BENCH_runtime.json: {}", e),
    }
}
