//! L3 runtime benchmarks: end-to-end artifact evaluation throughput on
//! the native executor, compared across every tensor backend.
//!
//! The native path needs no on-disk artifacts (the manifest is
//! synthesized), so unlike the PJRT era this bench always runs — in CI
//! it writes `BENCH_runtime.json` (tokens/sec per model × quant ×
//! backend) which the workflow uploads as an artifact, seeding the
//! repo's end-to-end perf trajectory.
//!
//!   cargo bench --bench bench_runtime [-- --fast]

use intfpqsim::corpus::TextCorpus;
use intfpqsim::model;
use intfpqsim::runtime::{Runtime, Val};
use intfpqsim::tensor::backend;
use intfpqsim::util::json::Json;
use intfpqsim::util::timer::bench;

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let rt = Runtime::new("artifacts").unwrap();
    let corpus = TextCorpus::new(intfpqsim::corpus::TEXT_SEED);
    let threads = backend::env_threads();
    let (warmup, iters) = if fast { (1, 3) } else { (3, 12) };

    let models: &[&str] = if fast {
        &["sim-opt-125m"]
    } else {
        &["sim-opt-125m", "sim-opt-2.7b"]
    };
    let quants = ["fp32", "abfp_w4a4_n64", "abfp_w4a8_n64"];

    let mut rows: Vec<(String, String, String, f64, f64)> = Vec::new();
    for model_name in models {
        let cfg = rt.manifest.model(model_name).unwrap().clone();
        let params = model::init_params(&cfg, 1);
        let sticky = model::param_vals(&cfg, &params).unwrap();
        let toks_per_batch = (cfg.batch * cfg.seq) as f64;
        let tb = corpus.eval_batch(0, cfg.batch, cfg.seq);
        let tv = Val::I32(tb.tokens.clone(), vec![cfg.batch, cfg.seq]);

        println!("\n== {} (batch {} x seq {}) ==", model_name, cfg.batch, cfg.seq);
        for &be_name in backend::all_names() {
            backend::configure(be_name, threads).unwrap();
            let be_desc = backend::active().describe();
            for quant in quants {
                let id = format!("{}/eval_{}", model_name, quant);
                let mut st = sticky.clone();
                if quant != "fp32" {
                    for s in &cfg.sites {
                        st.insert(
                            format!("smooth.{}", s.name),
                            Val::F32(vec![1.0; s.dim], vec![s.dim]),
                        );
                    }
                }
                // session open includes the one-time weight QDQ prep
                let sess = rt.session(&id, &st).unwrap();
                let s = bench(warmup, iters, || {
                    std::hint::black_box(sess.run(std::slice::from_ref(&tv)).unwrap());
                });
                let label = format!("{} @ {}", quant, be_desc);
                println!("{}", s.report(&label, Some((toks_per_batch, "tok"))));
                rows.push((
                    model_name.to_string(),
                    quant.to_string(),
                    be_desc.clone(),
                    s.mean_ms(),
                    toks_per_batch / (s.mean_ns / 1e9),
                ));
            }
        }
        backend::configure("auto", threads).unwrap();

        // coordinator overhead: data generation only (no execute)
        let s = bench(1, 20, || {
            let tb = corpus.eval_batch(1, cfg.batch, cfg.seq);
            std::hint::black_box(Val::I32(tb.tokens, vec![cfg.batch, cfg.seq]));
        });
        println!("{}", s.report("coordinator-side batch prep", Some((toks_per_batch, "tok"))));

        // session-open cost (weight conversion + QDQ prep) — amortized
        // once per config
        let s = bench(1, 5, || {
            let id = format!("{}/eval_fp32", model_name);
            std::hint::black_box(rt.session(&id, &sticky).unwrap());
        });
        println!("{}", s.report("session open (weight prep)", None));
    }

    let json = Json::obj(vec![
        ("bench", Json::Str("runtime_native".into())),
        ("fast", Json::Bool(fast)),
        ("executor", Json::Str(rt.executor_name().into())),
        ("threads", Json::Num(threads as f64)),
        (
            "eval_throughput",
            Json::Arr(
                rows.iter()
                    .map(|(m, q, be, ms, tps)| {
                        Json::obj(vec![
                            ("model", Json::Str(m.clone())),
                            ("quant", Json::Str(q.clone())),
                            ("backend", Json::Str(be.clone())),
                            ("mean_ms", Json::Num(*ms)),
                            ("toks_per_s", Json::Num(*tps)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    match std::fs::write("BENCH_runtime.json", json.pretty()) {
        Ok(()) => println!("\nwrote BENCH_runtime.json"),
        Err(e) => eprintln!("could not write BENCH_runtime.json: {}", e),
    }
}
