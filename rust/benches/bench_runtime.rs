//! L3 runtime benchmarks: artifact execution throughput (the simulator's
//! request hot path) and the coordinator overhead budget. §Perf target:
//! PJRT execute should dominate; session/upload overhead < 10%.
//!
//!   cargo bench --bench bench_runtime

use intfpqsim::corpus::TextCorpus;
use intfpqsim::model;
use intfpqsim::runtime::{Runtime, Val};
use intfpqsim::util::timer::bench;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("artifacts not built; run `make artifacts` first");
        return;
    }
    let rt = Runtime::new("artifacts").unwrap();
    let corpus = TextCorpus::new(intfpqsim::corpus::TEXT_SEED);

    for model_name in ["sim-opt-125m", "sim-opt-2.7b"] {
        let cfg = rt.manifest.model(model_name).unwrap().clone();
        let params = model::init_params(&cfg, 1);
        let sticky = model::param_vals(&cfg, &params).unwrap();
        let toks_per_batch = (cfg.batch * cfg.seq) as f64;

        println!("\n== {} (batch {} x seq {}) ==", model_name, cfg.batch, cfg.seq);
        for quant in ["fp32", "abfp_w4a4_n64", "abfp_w4a8_n64", "abfp_w4a4_n128"] {
            let id = format!("{}/eval_{}", model_name, quant);
            let mut st = sticky.clone();
            if quant != "fp32" {
                for s in &cfg.sites {
                    st.insert(
                        format!("smooth.{}", s.name),
                        Val::F32(vec![1.0; s.dim], vec![s.dim]),
                    );
                }
            }
            let sess = rt.session(&id, &st).unwrap();
            let tb = corpus.eval_batch(0, cfg.batch, cfg.seq);
            let tv = Val::I32(tb.tokens.clone(), vec![cfg.batch, cfg.seq]);
            let s = bench(3, 15, || {
                std::hint::black_box(sess.run(std::slice::from_ref(&tv)).unwrap());
            });
            println!("{}", s.report(quant, Some((toks_per_batch, "tok"))));
        }

        // coordinator overhead: data-generation + upload only (no execute)
        let s = bench(3, 50, || {
            let tb = corpus.eval_batch(1, cfg.batch, cfg.seq);
            std::hint::black_box(Val::I32(tb.tokens, vec![cfg.batch, cfg.seq]));
        });
        println!("{}", s.report("coordinator-side batch prep", Some((toks_per_batch, "tok"))));

        // session-open cost (weight upload) — amortized once per config
        let s = bench(1, 5, || {
            let id = format!("{}/eval_fp32", model_name);
            std::hint::black_box(rt.session(&id, &sticky).unwrap());
        });
        println!("{}", s.report("session open (weight upload)", None));
    }
}
