//! L1-mirror micro-benchmarks: the host-side quantizer arithmetic that
//! the PTQ methods and the calibrator run in their inner loops, plus the
//! GPTQ per-site transform. Part of the §Perf pass (EXPERIMENTS.md).
//!
//!   cargo bench --bench bench_quant

use intfpqsim::formats::{self, Format};
use intfpqsim::methods::gptq;
use intfpqsim::tensor::Tensor;
use intfpqsim::util::rng::Pcg64;
use intfpqsim::util::timer::bench;

fn heavy(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.gaussian() * rng.lognormal(1.0)).collect()
}

fn main() {
    let mut rng = Pcg64::new(42);
    let (rows, k) = (512, 2048);
    let x = heavy(&mut rng, rows * k);
    let elems = (rows * k) as f64;

    println!("== quantizer mirrors ({}x{} f32) ==", rows, k);
    for (name, fmt) in [
        ("abfp int4 n64", Format::Int(formats::INT4)),
        ("abfp int8 n64", Format::Int(formats::INT8)),
        ("abfp e2m1 n64", Format::Fp(formats::E2M1)),
        ("abfp e4m3 n64", Format::Fp(formats::E4M3)),
    ] {
        let mut buf = x.clone();
        let s = bench(3, 20, || {
            buf.copy_from_slice(&x);
            formats::abfp_qdq(&mut buf, k, fmt, 64);
            std::hint::black_box(&buf);
        });
        println!("{}", s.report(name, Some((elems / 1e6, "Melem"))));
    }
    for n in [64usize, 128] {
        let mut buf = x.clone();
        let s = bench(3, 20, || {
            buf.copy_from_slice(&x);
            formats::abfp_qdq(&mut buf, k, Format::Int(formats::INT4), n);
            std::hint::black_box(&buf);
        });
        println!("{}", s.report(&format!("abfp int4 n={}", n), Some((elems / 1e6, "Melem"))));
    }
    {
        let mut buf = x.clone();
        let s = bench(3, 20, || {
            buf.copy_from_slice(&x);
            formats::static_int_qdq(&mut buf, &[2.5], 4);
            std::hint::black_box(&buf);
        });
        println!("{}", s.report("static int4 per-tensor", Some((elems / 1e6, "Melem"))));
    }
    {
        let probe = heavy(&mut rng, rows * k);
        let s = bench(3, 20, || {
            let acc: f64 = intfpqsim::formats::quant_mse(&probe[..32768], 2.5, 4);
            std::hint::black_box(acc);
        });
        println!("{}", s.report("quant_mse (32k sample)", Some((32768.0 / 1e6, "Melem"))));
    }

    println!("\n== MSE calibration search ==");
    {
        let probe = heavy(&mut rng, 131072);
        let s = bench(1, 5, || {
            std::hint::black_box(intfpqsim::calib::mse_alpha(&probe, 4));
        });
        println!("{}", s.report("mse_alpha (131k elems, 48 pts)", None));
    }

    println!("\n== GPTQ site transform ==");
    for (dout, din, rows2) in [(256usize, 256usize, 1024usize), (512, 2048, 2048)] {
        let xx = Tensor::new(vec![rows2, din], heavy(&mut rng, rows2 * din));
        let w0 = Tensor::new(vec![dout, din], heavy(&mut rng, dout * din));
        let s = bench(0, 3, || {
            let mut w = w0.clone();
            gptq::gptq_site(&mut w, &xx).unwrap();
            std::hint::black_box(&w);
        });
        println!("{}", s.report(&format!("gptq {}x{} ({} rows)", dout, din, rows2), None));
    }
}
