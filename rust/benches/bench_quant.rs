//! L1-mirror micro-benchmarks: the host-side quantizer arithmetic that
//! the PTQ methods and the calibrator run in their inner loops, the GPTQ
//! per-site transform, and the tensor execution backends (scalar vs
//! blocked vs simd vs threaded vs pool) on the matmul/gram/axpy hot
//! paths, plus the fused qdq_matmul_t vs unfused clone+QDQ+matmul A/B
//! (per backend, with temporary-byte accounting) and the
//! many-small-sites spawn-overhead microbench (threaded vs pool). Part
//! of the §Perf pass (EXPERIMENTS.md).
//!
//!   cargo bench --bench bench_quant             # full
//!   cargo bench --bench bench_quant -- --fast   # CI smoke (one pass)
//!
//! Always writes a `BENCH_tensor.json` artifact with the backend
//! comparison (per-op mean ms + speedup vs scalar) to the working
//! directory.

use std::sync::Arc;

use intfpqsim::formats::{self, Format};
use intfpqsim::methods::gptq;
use intfpqsim::tensor::backend::{
    self, Backend, Blocked, Pool, QuantPanel, Scalar, Simd, Threaded,
};
use intfpqsim::tensor::Tensor;
use intfpqsim::util::json::Json;
use intfpqsim::util::rng::Pcg64;
use intfpqsim::util::timer::bench;

fn heavy(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.gaussian() * rng.lognormal(1.0)).collect()
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let mut rng = Pcg64::new(42);
    let (rows, k) = (512, 2048);
    let x = heavy(&mut rng, rows * k);
    let elems = (rows * k) as f64;
    let (qwarm, qiters) = if fast { (1, 3) } else { (3, 20) };

    println!("== quantizer mirrors ({}x{} f32) ==", rows, k);
    for (name, fmt) in [
        ("abfp int4 n64", Format::Int(formats::INT4)),
        ("abfp int8 n64", Format::Int(formats::INT8)),
        ("abfp e2m1 n64", Format::Fp(formats::E2M1)),
        ("abfp e4m3 n64", Format::Fp(formats::E4M3)),
    ] {
        let mut buf = x.clone();
        let s = bench(qwarm, qiters, || {
            buf.copy_from_slice(&x);
            formats::abfp_qdq(&mut buf, k, fmt, 64);
            std::hint::black_box(&buf);
        });
        println!("{}", s.report(name, Some((elems / 1e6, "Melem"))));
    }
    for n in [64usize, 128] {
        let mut buf = x.clone();
        let s = bench(qwarm, qiters, || {
            buf.copy_from_slice(&x);
            formats::abfp_qdq(&mut buf, k, Format::Int(formats::INT4), n);
            std::hint::black_box(&buf);
        });
        println!("{}", s.report(&format!("abfp int4 n={}", n), Some((elems / 1e6, "Melem"))));
    }
    {
        let mut buf = x.clone();
        let s = bench(qwarm, qiters, || {
            buf.copy_from_slice(&x);
            formats::static_int_qdq(&mut buf, &[2.5], 4);
            std::hint::black_box(&buf);
        });
        println!("{}", s.report("static int4 per-tensor", Some((elems / 1e6, "Melem"))));
    }
    {
        let probe = heavy(&mut rng, rows * k);
        let s = bench(qwarm, qiters, || {
            let acc: f64 = intfpqsim::formats::quant_mse(&probe[..32768], 2.5, 4);
            std::hint::black_box(acc);
        });
        println!("{}", s.report("quant_mse (32k sample)", Some((32768.0 / 1e6, "Melem"))));
    }

    println!("\n== MSE calibration search ==");
    {
        let probe = heavy(&mut rng, 131072);
        let s = bench(if fast { 0 } else { 1 }, if fast { 2 } else { 5 }, || {
            std::hint::black_box(intfpqsim::calib::mse_alpha(&probe, 4));
        });
        println!("{}", s.report("mse_alpha (131k elems, 48 pts)", None));
    }

    println!("\n== GPTQ site transform ==");
    let gptq_shapes: &[(usize, usize, usize)] = if fast {
        &[(256, 256, 1024)]
    } else {
        &[(256, 256, 1024), (512, 2048, 2048)]
    };
    for &(dout, din, rows2) in gptq_shapes {
        let xx = Tensor::new(vec![rows2, din], heavy(&mut rng, rows2 * din));
        let w0 = Tensor::new(vec![dout, din], heavy(&mut rng, dout * din));
        let s = bench(0, if fast { 1 } else { 3 }, || {
            let mut w = w0.clone();
            gptq::gptq_site(&mut w, &xx).unwrap();
            std::hint::black_box(&w);
        });
        println!("{}", s.report(&format!("gptq {}x{} ({} rows)", dout, din, rows2), None));
    }

    // ---- tensor backend comparison (the subsystem this file gates) ----
    let size = if fast { 256 } else { 1024 };
    let threads = backend::env_threads();
    println!(
        "\n== tensor backends ({s}x{s} matmul / {s}x{s} gram, {t} threads) ==",
        s = size,
        t = threads
    );
    let a = Tensor::new(vec![size, size], heavy(&mut rng, size * size));
    let b = Tensor::new(vec![size, size], heavy(&mut rng, size * size));
    let backends: Vec<Arc<dyn Backend>> = vec![
        Arc::new(Scalar),
        Arc::new(Blocked),
        Arc::new(Simd),
        Arc::new(Threaded::new(threads)),
        Arc::new(Pool::new(threads)),
    ];
    let (bwarm, biters) = if fast { (0, 1) } else { (1, 3) };
    // (op, backend, mean_ms)
    let mut results: Vec<(&str, String, f64)> = Vec::new();
    for be in &backends {
        let s = bench(bwarm, biters, || {
            std::hint::black_box(be.matmul(&a, &b));
        });
        println!("{}", s.report(&format!("matmul {}", be.describe()), None));
        results.push(("matmul", be.describe(), s.mean_ms()));
    }
    for be in &backends {
        let s = bench(bwarm, biters, || {
            std::hint::black_box(be.gram(&a));
        });
        println!("{}", s.report(&format!("gram {}", be.describe()), None));
        results.push(("gram", be.describe(), s.mean_ms()));
    }
    let xv = heavy(&mut rng, size * size);
    for be in &backends {
        let mut yv = heavy(&mut rng, size * size);
        let s = bench(bwarm, biters.max(3), || {
            be.axpy(-0.5, &xv, &mut yv);
            std::hint::black_box(&yv);
        });
        println!("{}", s.report(&format!("axpy {}", be.describe()), None));
        results.push(("axpy", be.describe(), s.mean_ms()));
    }
    let mut speedups = Vec::new();
    for op in ["matmul", "gram", "axpy"] {
        let base = results.iter().find(|r| r.0 == op && r.1 == "scalar").unwrap().2;
        for r in results.iter().filter(|r| r.0 == op && r.1 != "scalar") {
            let sp = base / r.2.max(1e-9);
            println!("  {} {:<14} {:>6.2}x vs scalar", op, r.1, sp);
            speedups.push((op, r.1.clone(), sp));
        }
    }

    // ---- fused QDQ→matmul vs unfused (ISSUE 5 tentpole A/B) ----
    // The unfused leg reproduces the old qlinear hot path exactly:
    // clone the activations, smooth, bulk-QDQ, then matmul against a
    // pre-transposed weight. The fused leg is one qdq_matmul_t call —
    // same bytes (conformance-enforced), no (rows × k) temporary.
    let (qrows, qk, qdout) = if fast { (128, 256, 256) } else { (512, 1024, 1024) };
    println!(
        "\n== fused qdq_matmul_t vs unfused ({}x{} @ {}^T, abfp int4 n64 + smooth) ==",
        qrows, qk, qdout
    );
    let xa = Tensor::new(vec![qrows, qk], heavy(&mut rng, qrows * qk));
    let wnat = Tensor::new(vec![qdout, qk], heavy(&mut rng, qdout * qk));
    let smooth: Vec<f32> = (0..qk).map(|j| 0.5 + (j % 7) as f32 * 0.25).collect();
    let wt_pre = wnat.transpose(); // the old prepared-session layout
    let prep = |row: &mut [f32]| {
        for (v, &s) in row.iter_mut().zip(smooth.iter()) {
            *v *= s;
        }
        formats::abfp_qdq_with(row, qk, Format::Int(formats::INT4), 64, &Scalar);
    };
    // (backend, unfused_ms, fused_ms, unfused_temp_bytes, fused_temp_bytes)
    let mut fused_rows: Vec<(String, f64, f64, u64, u64)> = Vec::new();
    for be in &backends {
        let s_unfused = bench(bwarm, biters, || {
            let mut xq = xa.clone();
            xq.scale_cols(&smooth);
            formats::abfp_qdq_with(
                &mut xq.data,
                qk,
                Format::Int(formats::INT4),
                64,
                be.as_ref(),
            );
            std::hint::black_box(be.matmul(&xq, &wt_pre));
        });
        let s_fused = bench(bwarm, biters, || {
            std::hint::black_box(be.qdq_matmul_t(&xa, &prep, &wnat));
        });
        let unfused_temp = (qrows * qk * 4) as u64;
        let fused_temp = (be.qdq_panel_rows().min(qrows) * qk * 4) as u64;
        println!(
            "{:<14} unfused {:>8.3} ms | fused {:>8.3} ms | {:>5.2}x | temps {} -> {} B",
            be.describe(),
            s_unfused.mean_ms(),
            s_fused.mean_ms(),
            s_unfused.mean_ms() / s_fused.mean_ms().max(1e-9),
            unfused_temp,
            fused_temp
        );
        fused_rows.push((
            be.describe(),
            s_unfused.mean_ms(),
            s_fused.mean_ms(),
            unfused_temp,
            fused_temp,
        ));
    }

    // ---- true int8 GEMM vs fused QDQ vs fp32 (ISSUE 8 tentpole A/B) ----
    // Three executions of one static-int W8A8 site: plain fp32 matmul_t
    // (no quantization), the fused QDQ simulation (per-row
    // quantize-dequantize in f32, then f32 dots), and the true
    // low-precision path (i8 activation quantize + i8×i8→i32 GEMM over
    // the prepacked weight panel). Weight prep for the latter two runs
    // once, outside the timed loop — the per-session prepack the native
    // executor does; the activation quantize IS timed, because the int
    // path pays it per forward.
    println!(
        "\n== int8 GEMM vs fused QDQ vs fp32 ({}x{} @ {}^T, static W8A8) ==",
        qrows, qk, qdout
    );
    let alpha_clip = 2.5f32;
    let x_scale = 127.0 / alpha_clip;
    let w_scales: Vec<f32> = (0..qdout)
        .map(|j| {
            let row = &wnat.data[j * qk..(j + 1) * qk];
            let a = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            127.0 / if a > 0.0 { a } else { 1.0 }
        })
        .collect();
    let panel = QuantPanel::pack(&wnat, &w_scales, 127.0);
    let mut wq_f32 = wnat.clone();
    formats::pcmax_weight_qdq_with(&mut wq_f32.data, qk, 8, &Scalar);
    let int_prep = |row: &mut [f32]| {
        formats::static_int_qdq_with(row, &[alpha_clip], 8, &Scalar);
    };
    let x_scales_v = vec![x_scale; qrows];
    let mut codes = vec![0i8; qrows * qk];
    // (backend, fp32_ms, qdq_fused_ms, int_ms)
    let mut int_rows: Vec<(String, f64, f64, f64)> = Vec::new();
    for be in &backends {
        let s_fp32 = bench(bwarm, biters, || {
            std::hint::black_box(be.matmul_t(&xa, &wnat));
        });
        let s_fused = bench(bwarm, biters, || {
            std::hint::black_box(be.qdq_matmul_t(&xa, &int_prep, &wq_f32));
        });
        let s_int = bench(bwarm, biters, || {
            backend::quantize_rows_i8(&xa.data, x_scale, 127.0, &mut codes);
            std::hint::black_box(be.int_matmul_t(&codes, &x_scales_v, &panel, &w_scales));
        });
        println!(
            "{:<14} fp32 {:>8.3} ms | fused {:>8.3} ms | int {:>8.3} ms | int {:>5.2}x vs fused",
            be.describe(),
            s_fp32.mean_ms(),
            s_fused.mean_ms(),
            s_int.mean_ms(),
            s_fused.mean_ms() / s_int.mean_ms().max(1e-9)
        );
        int_rows.push((be.describe(), s_fp32.mean_ms(), s_fused.mean_ms(), s_int.mean_ms()));
    }

    // ---- spawn overhead: many small calibration-style sites ----
    // `threaded` pays a scoped-thread spawn + join per call; `pool`
    // reuses persistent workers across calls. 64 sites x tiny per-site
    // work approximates the `mse_site_alphas` fan-out that ROADMAP
    // flagged. At least 2 workers so the parallel path is exercised even
    // on a single-core runner.
    let wt = threads.max(2);
    println!(
        "\n== spawn overhead (64-site fan-out x 512-elem site, {} workers) ==",
        wt
    );
    let site = heavy(&mut rng, 512);
    let threaded_be = Threaded::new(wt);
    let pool_be = Pool::new(wt);
    let contenders: [(&str, &dyn Backend); 2] =
        [("threaded", &threaded_be), ("pool", &pool_be)];
    let (swarm, siters) = if fast { (1, 5) } else { (2, 20) };
    let mut spawn_ms: Vec<(&str, f64)> = Vec::new();
    for (name, be) in contenders {
        let s = bench(swarm, siters, || {
            let v = be.par_map_f64(64, &|_| Scalar.sum_sq(&site));
            std::hint::black_box(v);
        });
        println!("{}", s.report(&format!("small sites {}", be.describe()), None));
        spawn_ms.push((name, s.mean_ms()));
    }
    let spawn_speedup = spawn_ms[0].1 / spawn_ms[1].1.max(1e-9);
    println!("  pool {:>6.2}x vs threaded on the small-site fan-out", spawn_speedup);

    let json = Json::obj(vec![
        ("bench", Json::Str("tensor_backends".to_string())),
        ("size", Json::Num(size as f64)),
        ("threads", Json::Num(threads as f64)),
        ("fast", Json::Bool(fast)),
        (
            "results",
            Json::Arr(
                results
                    .iter()
                    .map(|(op, be, ms)| {
                        Json::obj(vec![
                            ("op", Json::Str((*op).to_string())),
                            ("backend", Json::Str(be.clone())),
                            ("mean_ms", Json::Num(*ms)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "speedup_vs_scalar",
            Json::Arr(
                speedups
                    .iter()
                    .map(|(op, be, sp)| {
                        Json::obj(vec![
                            ("op", Json::Str((*op).to_string())),
                            ("backend", Json::Str(be.clone())),
                            ("speedup", Json::Num(*sp)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "fused_qdq",
            Json::obj(vec![
                ("rows", Json::Num(qrows as f64)),
                ("k", Json::Num(qk as f64)),
                ("dout", Json::Num(qdout as f64)),
                ("quant", Json::Str("abfp_int4_n64+smooth".to_string())),
                (
                    "results",
                    Json::Arr(
                        fused_rows
                            .iter()
                            .map(|(be, unf, fus, ut, ft)| {
                                Json::obj(vec![
                                    ("backend", Json::Str(be.clone())),
                                    ("unfused_ms", Json::Num(*unf)),
                                    ("fused_ms", Json::Num(*fus)),
                                    (
                                        "fused_speedup",
                                        Json::Num(unf / fus.max(1e-9)),
                                    ),
                                    ("unfused_temp_bytes", Json::Num(*ut as f64)),
                                    ("fused_temp_bytes", Json::Num(*ft as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ),
        (
            "int_gemm",
            Json::obj(vec![
                ("rows", Json::Num(qrows as f64)),
                ("k", Json::Num(qk as f64)),
                ("dout", Json::Num(qdout as f64)),
                ("quant", Json::Str("w8a8_static_pcmax".to_string())),
                (
                    "results",
                    Json::Arr(
                        int_rows
                            .iter()
                            .map(|(be, fp, fus, int)| {
                                Json::obj(vec![
                                    ("backend", Json::Str(be.clone())),
                                    ("fp32_ms", Json::Num(*fp)),
                                    ("qdq_fused_ms", Json::Num(*fus)),
                                    ("int_ms", Json::Num(*int)),
                                    (
                                        "int_speedup_vs_fused",
                                        Json::Num(fus / int.max(1e-9)),
                                    ),
                                    (
                                        "int_speedup_vs_fp32",
                                        Json::Num(fp / int.max(1e-9)),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ),
        (
            "spawn_overhead",
            Json::obj(vec![
                ("sites", Json::Num(64.0)),
                ("site_elems", Json::Num(512.0)),
                ("workers", Json::Num(wt as f64)),
                ("threaded_ms", Json::Num(spawn_ms[0].1)),
                ("pool_ms", Json::Num(spawn_ms[1].1)),
                ("pool_speedup_vs_threaded", Json::Num(spawn_speedup)),
            ]),
        ),
    ]);
    match std::fs::write("BENCH_tensor.json", json.pretty()) {
        Ok(()) => println!("\nwrote BENCH_tensor.json"),
        Err(e) => eprintln!("could not write BENCH_tensor.json: {}", e),
    }
}
