//! Serving benchmarks: sustained tokens/sec, batch occupancy and
//! p50/p95/p99 latency of the micro-batching server, per tensor backend
//! × quant config (plus one mixed-config cell per backend).
//!
//! Each cell drives the in-process server with the closed-loop loadgen
//! (4 clients, prewarmed sessions, 2 ms batching window), so the numbers
//! measure steady-state serving — the trajectory future perf PRs
//! optimize against. CI runs `-- --fast` and uploads `BENCH_serve.json`
//! next to `BENCH_tensor.json`/`BENCH_runtime.json`.
//!
//!   cargo bench --bench bench_serve [-- --fast]

use std::time::Duration;

use intfpqsim::quantsim::Simulator;
use intfpqsim::serve::loadgen::{run_loadgen, LoadgenCfg, LoadgenReport};
use intfpqsim::serve::ServeCfg;
use intfpqsim::tensor::backend;
use intfpqsim::train::TrainOpts;
use intfpqsim::util::json::Json;

const MODEL: &str = "sim-opt-125m";

fn cell(sim: &Simulator, mix: Vec<(String, String)>, requests: usize) -> LoadgenReport {
    let cfg = LoadgenCfg {
        clients: 4,
        requests_per_client: requests,
        mix,
        deadline_ms: None,
        seed: 17,
        prewarm: true,
        serve: ServeCfg {
            queue_cap: 64,
            batch_window: Duration::from_millis(2),
            max_batch: 8,
        },
    };
    run_loadgen(sim, &cfg).expect("loadgen cell")
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let threads = backend::env_threads();
    let mut sim = Simulator::new("artifacts", "checkpoints").unwrap();
    // brief pretrain: the bench measures serving, not training fidelity
    sim.opts.pretrain_opts = TrainOpts { steps: if fast { 40 } else { 120 }, ..Default::default() };
    let requests = if fast { 6 } else { 24 };
    let quants: &[&str] = if fast {
        &["fp32", "abfp_w4a4_n64"]
    } else {
        &["fp32", "abfp_w4a4_n64", "abfp_w4a8_n64"]
    };

    let mut rows: Vec<(String, String, LoadgenReport)> = Vec::new();
    for &be_name in backend::all_names() {
        backend::configure(be_name, threads).unwrap();
        let be_desc = backend::active().describe();
        println!("\n== backend {} ==", be_desc);
        for &quant in quants {
            let rep = cell(
                &sim,
                vec![(MODEL.to_string(), quant.to_string())],
                requests,
            );
            println!("{:<28} {}", quant, rep.render());
            rows.push((quant.to_string(), be_desc.clone(), rep));
        }
        // mixed-config traffic: two quant keys interleaved, exercising
        // per-key coalescing + session-cache sharing under contention
        let mixed_label = "mixed(fp32+abfp_w4a4_n64)";
        let rep = cell(
            &sim,
            vec![
                (MODEL.to_string(), "fp32".to_string()),
                (MODEL.to_string(), "abfp_w4a4_n64".to_string()),
            ],
            requests,
        );
        println!("{:<28} {}", mixed_label, rep.render());
        rows.push((mixed_label.to_string(), be_desc.clone(), rep));
    }
    backend::configure("auto", threads).unwrap();

    let json = Json::obj(vec![
        ("bench", Json::Str("serve".into())),
        ("fast", Json::Bool(fast)),
        ("model", Json::Str(MODEL.into())),
        ("threads", Json::Num(threads as f64)),
        ("clients", Json::Num(4.0)),
        (
            "serve_throughput",
            Json::Arr(
                rows.iter()
                    .map(|(quant, be, rep)| {
                        Json::obj(vec![
                            ("model", Json::Str(MODEL.into())),
                            ("quant", Json::Str(quant.clone())),
                            ("backend", Json::Str(be.clone())),
                            ("ok", Json::Num(rep.ok as f64)),
                            ("errors", Json::Num(rep.errors as f64)),
                            ("toks_per_s", Json::Num(rep.toks_per_s)),
                            ("mean_occupancy", Json::Num(rep.mean_occupancy)),
                            ("max_occupancy", Json::Num(rep.max_occupancy as f64)),
                            ("p50_ms", Json::Num(rep.p50_ms)),
                            ("p95_ms", Json::Num(rep.p95_ms)),
                            ("p99_ms", Json::Num(rep.p99_ms)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    match std::fs::write("BENCH_serve.json", json.pretty()) {
        Ok(()) => println!("\nwrote BENCH_serve.json"),
        Err(e) => eprintln!("could not write BENCH_serve.json: {}", e),
    }
}
