//! Serving benchmarks: sustained tokens/sec, batch occupancy and
//! p50/p95/p99 latency of the micro-batching server, per tensor backend
//! × quant config (plus one mixed-config cell per backend), a
//! shard-scaling sweep over worker counts, a real-socket TCP cell, and
//! a `proto_hot_path` microbench of the wire parse/serialize path
//! (ns/request and — via a counting global allocator — heap
//! allocations/request, which must be 0 in steady state), and a
//! `metrics_overhead` cell pricing the always-on observability layer
//! (hot-path loop with vs without the per-request recording footprint).
//!
//! Each serving cell drives the server with the closed-loop loadgen
//! (prewarmed sessions, 2 ms batching window), so the numbers measure
//! steady-state serving — the trajectory future perf PRs optimize
//! against. CI runs `-- --fast` and uploads `BENCH_serve.json` next to
//! `BENCH_tensor.json`/`BENCH_runtime.json`; see the README field guide
//! for the `shard_scaling`/`tcp`/`proto_hot_path` fields.
//!
//!   cargo bench --bench bench_serve [-- --fast]

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use intfpqsim::quantsim::Simulator;
use intfpqsim::serve::loadgen::{
    run_loadgen, run_loadgen_sharded, run_loadgen_tcp, LoadgenCfg, LoadgenReport,
};
use intfpqsim::serve::metrics::{self, SpanSlot};
use intfpqsim::serve::protocol::{
    parse_request, parse_request_streaming, OutputSummary, Request, Response, MAX_DEPTH,
    MAX_LINE_BYTES,
};
use intfpqsim::serve::shard::{ShardCfg, SimSpec};
use intfpqsim::serve::transport::TcpServer;
use intfpqsim::serve::ServeCfg;
use intfpqsim::tensor::backend;
use intfpqsim::train::TrainOpts;
use intfpqsim::util::json::Json;

const MODEL: &str = "sim-opt-125m";

/// Counts heap acquisitions so `proto_hot_path` can report
/// allocations/request; delegates everything to the system allocator.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Microbench of the wire hot path: streaming parse + reused-buffer
/// response serialize, vs the tree parser + allocating serializer as
/// the reference. Single-threaded, so the allocation counter attributes
/// cleanly.
fn proto_hot_path_cell(fast: bool) -> Json {
    let iters: u64 = if fast { 50_000 } else { 500_000 };
    let req = Request {
        id: 12345,
        model: MODEL.to_string(),
        quant: "abfp_w4a4_n64".to_string(),
        batch_index: 3,
        deadline_ms: Some(250),
        tokens: Some((0..64).collect()),
    };
    let mut line = Vec::new();
    req.write_line(&mut line);
    let resp = Response::ok(
        12345,
        vec![OutputSummary { shape: vec![2, 3], sum: 21.75, first: vec![1.0, 2.5, 3.0, 4.25] }],
        4,
        0.3125,
        1.0625,
    );

    let mut scratch = Request::default();
    let mut rbuf: Vec<u8> = Vec::new();
    for _ in 0..64 {
        parse_request_streaming(&line, &mut scratch).expect("warm-up parse");
        resp.write_line(&mut rbuf);
    }
    assert_eq!(scratch, req, "streaming parse must reproduce the request");

    let a0 = ALLOCS.load(Ordering::Relaxed);
    let t0 = Instant::now();
    for _ in 0..iters {
        parse_request_streaming(std::hint::black_box(&line[..]), &mut scratch)
            .expect("hot-path parse");
        resp.write_line(&mut rbuf);
        std::hint::black_box((&scratch, &rbuf));
    }
    let ns_per_req = t0.elapsed().as_nanos() as f64 / iters as f64;
    let allocs_per_req = (ALLOCS.load(Ordering::Relaxed) - a0) as f64 / iters as f64;

    // tree-parser reference (allocating path), fewer iters — it is the
    // baseline being replaced, not the thing under optimization
    let text = std::str::from_utf8(&line).expect("request line is utf-8");
    let tree_iters = (iters / 10).max(1);
    let b0 = ALLOCS.load(Ordering::Relaxed);
    let t1 = Instant::now();
    for _ in 0..tree_iters {
        let r = parse_request(std::hint::black_box(text)).expect("tree parse");
        std::hint::black_box(resp.line());
        std::hint::black_box(r);
    }
    let tree_ns_per_req = t1.elapsed().as_nanos() as f64 / tree_iters as f64;
    let tree_allocs_per_req = (ALLOCS.load(Ordering::Relaxed) - b0) as f64 / tree_iters as f64;

    println!(
        "{:<28} {:.0} ns/req, {:.2} allocs/req (tree: {:.0} ns/req, {:.2} allocs/req)",
        "proto_hot_path", ns_per_req, allocs_per_req, tree_ns_per_req, tree_allocs_per_req
    );

    Json::obj(vec![
        ("iters", Json::Num(iters as f64)),
        ("allocs_per_request", Json::Num(allocs_per_req)),
        ("parse_serialize_ns_per_request", Json::Num(ns_per_req)),
        ("tree_iters", Json::Num(tree_iters as f64)),
        ("tree_allocs_per_request", Json::Num(tree_allocs_per_req)),
        ("tree_parse_serialize_ns_per_request", Json::Num(tree_ns_per_req)),
        ("max_line_bytes", Json::Num(MAX_LINE_BYTES as f64)),
        ("max_depth", Json::Num(MAX_DEPTH as f64)),
    ])
}

/// Overhead of always-on metrics recording: the wire hot-path loop with
/// the full per-request metrics footprint added, vs the same loop bare.
/// `throughput_ratio` (without/with, higher is better) is the headline
/// `bench_guard.py` watches; building with `--features no-metrics`
/// compiles the recording away and drives the ratio to ~1.0, isolating
/// the cost of the relaxed-atomic counters and histograms themselves.
fn metrics_overhead_cell(fast: bool) -> Json {
    let iters: u64 = if fast { 50_000 } else { 500_000 };
    let req = Request {
        id: 12345,
        model: MODEL.to_string(),
        quant: "abfp_w4a4_n64".to_string(),
        batch_index: 3,
        deadline_ms: Some(250),
        tokens: Some((0..64).collect()),
    };
    let mut line = Vec::new();
    req.write_line(&mut line);
    let resp = Response::ok(
        12345,
        vec![OutputSummary { shape: vec![2, 3], sum: 21.75, first: vec![1.0, 2.5, 3.0, 4.25] }],
        4,
        0.3125,
        1.0625,
    );

    metrics::reset();
    let mut scratch = Request::default();
    let mut rbuf: Vec<u8> = Vec::new();
    for i in 0..64u64 {
        parse_request_streaming(&line, &mut scratch).expect("warm-up parse");
        resp.write_line(&mut rbuf);
        metrics::admitted();
        metrics::queue_wait(i);
        metrics::record_span(SpanSlot::Admit, i);
    }

    // bare wire ops: the "without recording" baseline
    let t0 = Instant::now();
    for _ in 0..iters {
        parse_request_streaming(std::hint::black_box(&line[..]), &mut scratch)
            .expect("hot-path parse");
        resp.write_line(&mut rbuf);
        std::hint::black_box((&scratch, &rbuf));
    }
    let without_ns = t0.elapsed().as_nanos() as f64 / iters as f64;

    // the same loop plus the per-request metrics footprint the server
    // records (counters, shard cells, queue-wait + span histograms)
    let t1 = Instant::now();
    for i in 0..iters {
        parse_request_streaming(std::hint::black_box(&line[..]), &mut scratch)
            .expect("hot-path parse");
        resp.write_line(&mut rbuf);
        metrics::admitted();
        metrics::batch_dispatched((i % 4) as usize, 4);
        metrics::request_ok((i % 4) as usize);
        metrics::cache_hit((i % 4) as usize);
        metrics::queue_wait(i);
        metrics::record_span(SpanSlot::Admit, i);
        metrics::record_span(SpanSlot::Assemble, i * 2);
        metrics::record_span(SpanSlot::Serialize, i * 3);
        std::hint::black_box((&scratch, &rbuf));
    }
    let with_ns = t1.elapsed().as_nanos() as f64 / iters as f64;

    let enabled = cfg!(not(feature = "no-metrics"));
    let ratio = without_ns / with_ns.max(1e-9);
    println!(
        "{:<28} {:.0} ns/req with recording, {:.0} ns/req without \
         (ratio {:.3}, metrics {})",
        "metrics_overhead",
        with_ns,
        without_ns,
        ratio,
        if enabled { "on" } else { "compiled out" }
    );

    Json::obj(vec![
        ("iters", Json::Num(iters as f64)),
        ("metrics_enabled", Json::Bool(enabled)),
        ("with_ns_per_request", Json::Num(with_ns)),
        ("without_ns_per_request", Json::Num(without_ns)),
        ("overhead_ns_per_request", Json::Num(with_ns - without_ns)),
        ("throughput_ratio", Json::Num(ratio)),
    ])
}

fn mixed_mix() -> Vec<(String, String)> {
    vec![
        (MODEL.to_string(), "fp32".to_string()),
        (MODEL.to_string(), "abfp_w4a4_n64".to_string()),
    ]
}

fn base_cfg(mix: Vec<(String, String)>, clients: usize, requests: usize) -> LoadgenCfg {
    LoadgenCfg {
        clients,
        requests_per_client: requests,
        mix,
        deadline_ms: None,
        seed: 17,
        prewarm: true,
        serve: ServeCfg {
            queue_cap: 64,
            batch_window: Duration::from_millis(2),
            max_batch: 8,
            ..ServeCfg::default()
        },
        ..Default::default()
    }
}

fn cell(sim: &Simulator, mix: Vec<(String, String)>, requests: usize) -> LoadgenReport {
    run_loadgen(sim, &base_cfg(mix, 4, requests)).expect("loadgen cell")
}

fn percentile_fields(rep: &LoadgenReport) -> Vec<(&'static str, Json)> {
    vec![
        ("ok", Json::Num(rep.ok as f64)),
        ("errors", Json::Num(rep.errors as f64)),
        ("toks_per_s", Json::Num(rep.toks_per_s)),
        ("mean_occupancy", Json::Num(rep.mean_occupancy)),
        ("max_occupancy", Json::Num(rep.max_occupancy as f64)),
        ("p50_ms", Json::Num(rep.p50_ms)),
        ("p95_ms", Json::Num(rep.p95_ms)),
        ("p99_ms", Json::Num(rep.p99_ms)),
    ]
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    println!("== protocol hot path ==");
    let proto_cell = proto_hot_path_cell(fast);
    println!("\n== metrics overhead ==");
    let metrics_cell = metrics_overhead_cell(fast);
    let threads = backend::env_threads();
    let pretrain = TrainOpts { steps: if fast { 40 } else { 120 }, ..Default::default() };
    let mut sim = Simulator::new("artifacts", "checkpoints").unwrap();
    // brief pretrain: the bench measures serving, not training fidelity
    sim.opts.pretrain_opts = pretrain.clone();
    let requests = if fast { 6 } else { 24 };
    let quants: &[&str] = if fast {
        &["fp32", "abfp_w4a4_n64"]
    } else {
        &["fp32", "abfp_w4a4_n64", "abfp_w4a8_n64"]
    };

    let mut rows: Vec<(String, String, LoadgenReport)> = Vec::new();
    for &be_name in backend::all_names() {
        backend::configure(be_name, threads).unwrap();
        let be_desc = backend::active().describe();
        println!("\n== backend {} ==", be_desc);
        for &quant in quants {
            let rep = cell(
                &sim,
                vec![(MODEL.to_string(), quant.to_string())],
                requests,
            );
            println!("{:<28} {}", quant, rep.render());
            rows.push((quant.to_string(), be_desc.clone(), rep));
        }
        // mixed-config traffic: two quant keys interleaved, exercising
        // per-key coalescing + session-cache sharing under contention
        let mixed_label = "mixed(fp32+abfp_w4a4_n64)";
        let rep = cell(&sim, mixed_mix(), requests);
        println!("{:<28} {}", mixed_label, rep.render());
        rows.push((mixed_label.to_string(), be_desc.clone(), rep));
    }

    // Shard-scaling sweep: the same mixed traffic against the worker
    // pool at 1/2/4 workers (one backend — the interesting axis here is
    // worker count). Aggregate tokens/sec at N workers over the
    // 1-worker cell is the scaling headline; bit-exactness across the
    // sweep is asserted by the serve_shard tests, not re-checked here.
    backend::configure("simd", threads).unwrap();
    let shard_backend = backend::active().describe();
    println!("\n== shard scaling ({}) ==", shard_backend);
    let mut spec = SimSpec::new("artifacts", "checkpoints");
    spec.opts.pretrain_opts = pretrain;
    let shard_clients = if fast { 8 } else { 16 };
    let mut scaling: Vec<(usize, LoadgenReport)> = Vec::new();
    for workers in [1usize, 2, 4] {
        let mut cfg = base_cfg(mixed_mix(), shard_clients, requests);
        cfg.shard = ShardCfg { workers, replicate_hot: true, hot_min: 4 };
        let rep = run_loadgen_sharded(&spec, &cfg).expect("shard scaling cell");
        println!("workers={:<21} {}", workers, rep.render());
        scaling.push((workers, rep));
    }
    let base_tps = scaling[0].1.toks_per_s.max(1e-9);

    // TCP cell: the same traffic over real sockets (2 workers), so the
    // transport overhead is on the record next to the in-process cells.
    println!("\n== tcp transport ({}) ==", shard_backend);
    let srv = TcpServer::start(
        spec.clone(),
        "127.0.0.1:0",
        base_cfg(mixed_mix(), shard_clients, requests).serve,
        ShardCfg { workers: 2, replicate_hot: true, hot_min: 4 },
        mixed_mix(),
    )
    .expect("tcp server");
    let addr = srv.local_addr().to_string();
    let tcp_rep = run_loadgen_tcp(
        &sim,
        &addr,
        &base_cfg(mixed_mix(), shard_clients, requests),
    )
    .expect("tcp cell");
    println!("{:<28} {}", "tcp(workers=2)", tcp_rep.render());
    srv.shutdown().expect("tcp shutdown");
    backend::configure("auto", threads).unwrap();

    let json = Json::obj(vec![
        ("bench", Json::Str("serve".into())),
        ("fast", Json::Bool(fast)),
        ("model", Json::Str(MODEL.into())),
        ("threads", Json::Num(threads as f64)),
        ("clients", Json::Num(4.0)),
        (
            "serve_throughput",
            Json::Arr(
                rows.iter()
                    .map(|(quant, be, rep)| {
                        let mut fields = vec![
                            ("model", Json::Str(MODEL.into())),
                            ("quant", Json::Str(quant.clone())),
                            ("backend", Json::Str(be.clone())),
                        ];
                        fields.extend(percentile_fields(rep));
                        Json::obj(fields)
                    })
                    .collect(),
            ),
        ),
        (
            "shard_scaling",
            Json::Arr(
                scaling
                    .iter()
                    .map(|(workers, rep)| {
                        let mut fields = vec![
                            ("backend", Json::Str(shard_backend.clone())),
                            ("workers", Json::Num(*workers as f64)),
                            ("clients", Json::Num(shard_clients as f64)),
                            ("replicate_hot", Json::Bool(true)),
                            ("speedup_vs_1", Json::Num(rep.toks_per_s / base_tps)),
                            ("stolen_batches", Json::Num(rep.stolen_batches() as f64)),
                            ("hot_batches", Json::Num(rep.hot_batches() as f64)),
                        ];
                        fields.extend(percentile_fields(rep));
                        // per-worker occupancy/attribution rides along
                        // inside the full report payload
                        fields.push(("report", rep.to_json()));
                        Json::obj(fields)
                    })
                    .collect(),
            ),
        ),
        (
            "tcp",
            Json::obj({
                let mut fields = vec![
                    ("backend", Json::Str(shard_backend.clone())),
                    ("workers", Json::Num(2.0)),
                    ("clients", Json::Num(shard_clients as f64)),
                ];
                fields.extend(percentile_fields(&tcp_rep));
                fields
            }),
        ),
        ("proto_hot_path", proto_cell),
        ("metrics_overhead", metrics_cell),
    ]);
    match std::fs::write("BENCH_serve.json", json.pretty()) {
        Ok(()) => println!("\nwrote BENCH_serve.json"),
        Err(e) => eprintln!("could not write BENCH_serve.json: {}", e),
    }
}
