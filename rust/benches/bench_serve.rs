//! Serving benchmarks: sustained tokens/sec, batch occupancy and
//! p50/p95/p99 latency of the micro-batching server, per tensor backend
//! × quant config (plus one mixed-config cell per backend), a
//! shard-scaling sweep over worker counts, and a real-socket TCP cell.
//!
//! Each cell drives the server with the closed-loop loadgen (prewarmed
//! sessions, 2 ms batching window), so the numbers measure steady-state
//! serving — the trajectory future perf PRs optimize against. CI runs
//! `-- --fast` and uploads `BENCH_serve.json` next to
//! `BENCH_tensor.json`/`BENCH_runtime.json`; see the README field guide
//! for the `shard_scaling`/`tcp` fields.
//!
//!   cargo bench --bench bench_serve [-- --fast]

use std::time::Duration;

use intfpqsim::quantsim::Simulator;
use intfpqsim::serve::loadgen::{
    run_loadgen, run_loadgen_sharded, run_loadgen_tcp, LoadgenCfg, LoadgenReport,
};
use intfpqsim::serve::shard::{ShardCfg, SimSpec};
use intfpqsim::serve::transport::TcpServer;
use intfpqsim::serve::ServeCfg;
use intfpqsim::tensor::backend;
use intfpqsim::train::TrainOpts;
use intfpqsim::util::json::Json;

const MODEL: &str = "sim-opt-125m";

fn mixed_mix() -> Vec<(String, String)> {
    vec![
        (MODEL.to_string(), "fp32".to_string()),
        (MODEL.to_string(), "abfp_w4a4_n64".to_string()),
    ]
}

fn base_cfg(mix: Vec<(String, String)>, clients: usize, requests: usize) -> LoadgenCfg {
    LoadgenCfg {
        clients,
        requests_per_client: requests,
        mix,
        deadline_ms: None,
        seed: 17,
        prewarm: true,
        serve: ServeCfg {
            queue_cap: 64,
            batch_window: Duration::from_millis(2),
            max_batch: 8,
        },
        ..Default::default()
    }
}

fn cell(sim: &Simulator, mix: Vec<(String, String)>, requests: usize) -> LoadgenReport {
    run_loadgen(sim, &base_cfg(mix, 4, requests)).expect("loadgen cell")
}

fn percentile_fields(rep: &LoadgenReport) -> Vec<(&'static str, Json)> {
    vec![
        ("ok", Json::Num(rep.ok as f64)),
        ("errors", Json::Num(rep.errors as f64)),
        ("toks_per_s", Json::Num(rep.toks_per_s)),
        ("mean_occupancy", Json::Num(rep.mean_occupancy)),
        ("max_occupancy", Json::Num(rep.max_occupancy as f64)),
        ("p50_ms", Json::Num(rep.p50_ms)),
        ("p95_ms", Json::Num(rep.p95_ms)),
        ("p99_ms", Json::Num(rep.p99_ms)),
    ]
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let threads = backend::env_threads();
    let pretrain = TrainOpts { steps: if fast { 40 } else { 120 }, ..Default::default() };
    let mut sim = Simulator::new("artifacts", "checkpoints").unwrap();
    // brief pretrain: the bench measures serving, not training fidelity
    sim.opts.pretrain_opts = pretrain.clone();
    let requests = if fast { 6 } else { 24 };
    let quants: &[&str] = if fast {
        &["fp32", "abfp_w4a4_n64"]
    } else {
        &["fp32", "abfp_w4a4_n64", "abfp_w4a8_n64"]
    };

    let mut rows: Vec<(String, String, LoadgenReport)> = Vec::new();
    for &be_name in backend::all_names() {
        backend::configure(be_name, threads).unwrap();
        let be_desc = backend::active().describe();
        println!("\n== backend {} ==", be_desc);
        for &quant in quants {
            let rep = cell(
                &sim,
                vec![(MODEL.to_string(), quant.to_string())],
                requests,
            );
            println!("{:<28} {}", quant, rep.render());
            rows.push((quant.to_string(), be_desc.clone(), rep));
        }
        // mixed-config traffic: two quant keys interleaved, exercising
        // per-key coalescing + session-cache sharing under contention
        let mixed_label = "mixed(fp32+abfp_w4a4_n64)";
        let rep = cell(&sim, mixed_mix(), requests);
        println!("{:<28} {}", mixed_label, rep.render());
        rows.push((mixed_label.to_string(), be_desc.clone(), rep));
    }

    // Shard-scaling sweep: the same mixed traffic against the worker
    // pool at 1/2/4 workers (one backend — the interesting axis here is
    // worker count). Aggregate tokens/sec at N workers over the
    // 1-worker cell is the scaling headline; bit-exactness across the
    // sweep is asserted by the serve_shard tests, not re-checked here.
    backend::configure("simd", threads).unwrap();
    let shard_backend = backend::active().describe();
    println!("\n== shard scaling ({}) ==", shard_backend);
    let mut spec = SimSpec::new("artifacts", "checkpoints");
    spec.opts.pretrain_opts = pretrain;
    let shard_clients = if fast { 8 } else { 16 };
    let mut scaling: Vec<(usize, LoadgenReport)> = Vec::new();
    for workers in [1usize, 2, 4] {
        let mut cfg = base_cfg(mixed_mix(), shard_clients, requests);
        cfg.shard = ShardCfg { workers, replicate_hot: true, hot_min: 4 };
        let rep = run_loadgen_sharded(&spec, &cfg).expect("shard scaling cell");
        println!("workers={:<21} {}", workers, rep.render());
        scaling.push((workers, rep));
    }
    let base_tps = scaling[0].1.toks_per_s.max(1e-9);

    // TCP cell: the same traffic over real sockets (2 workers), so the
    // transport overhead is on the record next to the in-process cells.
    println!("\n== tcp transport ({}) ==", shard_backend);
    let srv = TcpServer::start(
        spec.clone(),
        "127.0.0.1:0",
        base_cfg(mixed_mix(), shard_clients, requests).serve,
        ShardCfg { workers: 2, replicate_hot: true, hot_min: 4 },
        mixed_mix(),
    )
    .expect("tcp server");
    let addr = srv.local_addr().to_string();
    let tcp_rep = run_loadgen_tcp(
        &sim,
        &addr,
        &base_cfg(mixed_mix(), shard_clients, requests),
    )
    .expect("tcp cell");
    println!("{:<28} {}", "tcp(workers=2)", tcp_rep.render());
    srv.shutdown().expect("tcp shutdown");
    backend::configure("auto", threads).unwrap();

    let json = Json::obj(vec![
        ("bench", Json::Str("serve".into())),
        ("fast", Json::Bool(fast)),
        ("model", Json::Str(MODEL.into())),
        ("threads", Json::Num(threads as f64)),
        ("clients", Json::Num(4.0)),
        (
            "serve_throughput",
            Json::Arr(
                rows.iter()
                    .map(|(quant, be, rep)| {
                        let mut fields = vec![
                            ("model", Json::Str(MODEL.into())),
                            ("quant", Json::Str(quant.clone())),
                            ("backend", Json::Str(be.clone())),
                        ];
                        fields.extend(percentile_fields(rep));
                        Json::obj(fields)
                    })
                    .collect(),
            ),
        ),
        (
            "shard_scaling",
            Json::Arr(
                scaling
                    .iter()
                    .map(|(workers, rep)| {
                        let mut fields = vec![
                            ("backend", Json::Str(shard_backend.clone())),
                            ("workers", Json::Num(*workers as f64)),
                            ("clients", Json::Num(shard_clients as f64)),
                            ("replicate_hot", Json::Bool(true)),
                            ("speedup_vs_1", Json::Num(rep.toks_per_s / base_tps)),
                            ("stolen_batches", Json::Num(rep.stolen_batches() as f64)),
                            ("hot_batches", Json::Num(rep.hot_batches() as f64)),
                        ];
                        fields.extend(percentile_fields(rep));
                        // per-worker occupancy/attribution rides along
                        // inside the full report payload
                        fields.push(("report", rep.to_json()));
                        Json::obj(fields)
                    })
                    .collect(),
            ),
        ),
        (
            "tcp",
            Json::obj({
                let mut fields = vec![
                    ("backend", Json::Str(shard_backend.clone())),
                    ("workers", Json::Num(2.0)),
                    ("clients", Json::Num(shard_clients as f64)),
                ];
                fields.extend(percentile_fields(&tcp_rep));
                fields
            }),
        ),
    ]);
    match std::fs::write("BENCH_serve.json", json.pretty()) {
        Ok(()) => println!("\nwrote BENCH_serve.json"),
        Err(e) => eprintln!("could not write BENCH_serve.json: {}", e),
    }
}
