//! Differential protocol-parser suite: the streaming wire parser and
//! the tree parser must agree — on accept/reject for every document in
//! the adversarial corpus, and on every parsed field for request lines.
//! Plus the TCP line-length cap: an oversized line is answered with
//! `bad_request` and the connection stays usable.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write as IoWrite};
use std::net::TcpStream;
use std::time::Duration;

use intfpqsim::serve::protocol::{
    self, codes, parse_request, parse_request_streaming, Request, ERR_ID, MAX_DEPTH,
    MAX_LINE_BYTES,
};
use intfpqsim::serve::shard::{ShardCfg, SimSpec};
use intfpqsim::serve::transport::TcpServer;
use intfpqsim::serve::ServeCfg;
use intfpqsim::train::TrainOpts;
use intfpqsim::util::json::Json;
use intfpqsim::util::json_stream::{validate, StreamParser, Token};

/// Build a `Json` tree from the streaming parser's events, with an
/// explicit stack (the point of the exercise: no recursion anywhere).
fn tree_via_stream(s: &str) -> Result<Json, String> {
    enum Frame {
        Arr(Vec<Json>),
        Obj(BTreeMap<String, Json>, Option<String>),
    }
    fn place(stack: &mut Vec<Frame>, root: &mut Option<Json>, v: Json) {
        match stack.last_mut() {
            None => *root = Some(v),
            Some(Frame::Arr(a)) => a.push(v),
            Some(Frame::Obj(m, key)) => {
                let k = key.take().expect("value without a pending key");
                m.insert(k, v);
            }
        }
    }
    let mut p = StreamParser::new(s.as_bytes());
    let mut stack: Vec<Frame> = Vec::new();
    let mut root: Option<Json> = None;
    loop {
        let tok = match p.next_token() {
            Ok(Some(t)) => t,
            Ok(None) => break,
            Err(e) => return Err(e.to_string()),
        };
        match tok {
            Token::Null => place(&mut stack, &mut root, Json::Null),
            Token::Bool(b) => place(&mut stack, &mut root, Json::Bool(b)),
            Token::Num(n) => place(&mut stack, &mut root, Json::Num(n)),
            Token::Str(s) => {
                let mut d = String::new();
                s.append_to(&mut d);
                place(&mut stack, &mut root, Json::Str(d));
            }
            Token::Key(k) => {
                let mut d = String::new();
                k.append_to(&mut d);
                match stack.last_mut() {
                    Some(Frame::Obj(_, key)) => *key = Some(d),
                    _ => return Err("key outside an object".to_string()),
                }
            }
            Token::ObjStart => stack.push(Frame::Obj(BTreeMap::new(), None)),
            Token::ArrStart => stack.push(Frame::Arr(Vec::new())),
            Token::ObjEnd => match stack.pop() {
                Some(Frame::Obj(m, _)) => place(&mut stack, &mut root, Json::Obj(m)),
                _ => return Err("mismatched ObjEnd".to_string()),
            },
            Token::ArrEnd => match stack.pop() {
                Some(Frame::Arr(a)) => place(&mut stack, &mut root, Json::Arr(a)),
                _ => return Err("mismatched ArrEnd".to_string()),
            },
        }
    }
    root.ok_or_else(|| "no value".to_string())
}

/// The two parsers must agree on accept/reject; on accept they must
/// produce the same tree.
fn assert_doc_parity(s: &str) {
    let tree = Json::parse(s);
    let stream = tree_via_stream(s);
    match (&tree, &stream) {
        (Ok(a), Ok(b)) => assert_eq!(a, b, "trees differ for {:?}", s),
        (Err(_), Err(_)) => {}
        (a, b) => panic!(
            "parity break on {:?}: tree={:?} stream={:?}",
            s,
            a.as_ref().map(|_| "accept").map_err(|e| e.to_string()),
            b.as_ref().map(|_| "accept").map_err(|e| e.clone()),
        ),
    }
}

/// Request-level parity: same accept/reject, and on accept every field
/// of the parsed `Request` equal.
fn assert_request_parity(line: &str) {
    let tree = parse_request(line);
    let mut scratch = Request::default();
    let stream = parse_request_streaming(line.as_bytes(), &mut scratch);
    match (&tree, &stream) {
        (Ok(t), Ok(())) => assert_eq!(&scratch, t, "fields differ for {:?}", line),
        (Err(_), Err(_)) => {}
        _ => panic!(
            "request parity break on {:?}: tree accept={} stream accept={}",
            line,
            tree.is_ok(),
            stream.is_ok()
        ),
    }
}

#[test]
fn valid_documents_parse_identically() {
    for s in [
        "null",
        "true",
        "false",
        "0",
        "-0",
        "42",
        "-3.5e2",
        "1e999", // saturates to inf in both
        r#""""#,
        r#""plain""#,
        r#""a\nb\t\\\"/""#,
        r#""Aé""#,
        r#""𐀀""#,
        r#""􏿿""#,
        "\"héllo — ok 😀\"",
        "[]",
        "{}",
        "[1,2,3]",
        r#"{"a":[1,2,{"b":false}],"c":"x"}"#,
        r#"{"a": {"b": {"c": [null, true, 1.5]}}}"#,
        "  [ 1 , [ 2 ] , { } ]  ",
        r#"{"dup":1,"dup":2}"#, // last wins in both
    ] {
        assert_doc_parity(s);
    }
}

#[test]
fn malformed_numbers_are_rejected_by_both() {
    for s in [
        "01", "-01", "00", ".5", "1.", "-", "+1", "1e", "1e+", "1.e3", "0x10", "NaN",
        "Infinity", "- 1", "1..2", "1e1.5",
    ] {
        assert_doc_parity(s);
        assert!(Json::parse(s).is_err(), "{:?} must be rejected", s);
    }
    for s in ["0", "-0", "0.5", "1E+10", "123.456e-7", "9007199254740993"] {
        assert_doc_parity(s);
        assert!(Json::parse(s).is_ok(), "{:?} must parse", s);
    }
    // in request context
    assert_request_parity(r#"{"id": 01, "model": "m"}"#);
    assert_request_parity(r#"{"id": 1, "model": "m", "batch": .5}"#);
}

#[test]
fn bad_surrogates_and_truncated_escapes_are_rejected_by_both() {
    for s in [
        r#""\ud800A""#,
        r#""\ud800""#,
        r#""\udc00""#,
        r#""\ud800\ud800""#,
        r#""\ud800A""#,
        r#""\u+123""#,
        r#""abc"#,
        r#""\"#,
        r#""\u00""#,
        r#""\q""#,
        "\"a\tb\"",
        "\"a\nb\"",
    ] {
        assert_doc_parity(s);
        assert!(Json::parse(s).is_err(), "{:?} must be rejected", s);
    }
}

#[test]
fn invalid_utf8_is_rejected_by_the_streaming_parser() {
    // the tree API takes &str so these can only reach the wire parser
    for bytes in [
        b"\"\xff\"".as_slice(),
        b"\"\xc0\xaf\"".as_slice(),    // overlong encoding
        b"\"\xe2\x82\"".as_slice(),    // truncated 3-byte sequence
        b"\"\xed\xa0\x80\"".as_slice(), // UTF-8-encoded surrogate
        b"\xff{}".as_slice(),
    ] {
        assert!(validate(bytes).is_err(), "{:?} must be rejected", bytes);
        let mut scratch = Request::default();
        assert!(parse_request_streaming(bytes, &mut scratch).is_err());
    }
}

#[test]
fn deep_nesting_is_a_clean_error_in_both_parsers() {
    // depth exactly MAX_DEPTH parses in both
    let ok = format!("{}1{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
    assert_doc_parity(&ok);
    assert!(Json::parse(&ok).is_ok());
    // one deeper is rejected by both
    let bad = format!("{}1{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
    assert_doc_parity(&bad);
    assert!(Json::parse(&bad).is_err());
    // a million-deep bomb previously overflowed the recursive parser's
    // call stack; now both parsers return a depth error
    let bomb = "[".repeat(1_000_000);
    assert!(Json::parse(&bomb).is_err());
    assert!(validate(bomb.as_bytes()).is_err());
    let mixed = "[{\"a\":".repeat(500_000);
    assert!(Json::parse(&mixed).is_err());
    assert!(validate(mixed.as_bytes()).is_err());
}

#[test]
fn request_field_matrix_parses_identically() {
    for line in [
        r#"{"id": 0, "model": "m"}"#,
        r#"{"id": 9007199254740991, "model": "m"}"#,
        r#"{"id": 7, "model": "sim-opt-125m", "quant": "abfp_w4a4_n64", "batch": 3, "deadline_ms": 500}"#,
        r#"{"id": 2, "model": "m", "tokens": []}"#,
        r#"{"id": 2, "model": "m", "tokens": [0, -1, 2147483647, -2147483648]}"#,
        r#"{"id": 3, "model": "mo\"del\n😀", "quant": "q\\x"}"#,
        r#"{"deadline_ms": 1, "batch": 2, "quant": "q", "model": "m", "id": 9}"#,
        "  {\"id\": 1, \"model\": \"m\"}  ",
        // rejects
        "not json",
        "",
        "   ",
        r#"{"model": "m"}"#,
        r#"{"id": 3}"#,
        r#"{"id": "x", "model": "m"}"#,
        r#"{"id": -1, "model": "m"}"#,
        r#"{"id": 1.5, "model": "m"}"#,
        r#"{"id": 1, "model": 5}"#,
        r#"{"id": 1, "model": "m", "quant": 4}"#,
        r#"{"id": 1, "model": "m", "tokens": [1, "x"]}"#,
        r#"{"id": 1, "model": "m", "tokens": [1.5]}"#,
        r#"{"id": 1, "model": "m", "tokens": [2147483648]}"#,
        r#"{"id": 1, "model": "m", "tokens": 3}"#,
        r#"{"id": 1, "model": "m", "deadline_ms": -5}"#,
        r#"{"id": 1, "model": "m", "bogus": 1}"#,
        r#"{"id": 1, "model": "m"} trailing"#,
        r#"[{"id": 1}]"#,
        "17",
    ] {
        assert_request_parity(line);
    }
}

fn tmp_spec(tag: &str) -> SimSpec {
    let dir = std::env::temp_dir().join(format!("intfpqsim_protostream_{}", tag));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let mut spec = SimSpec::new("artifacts", dir.to_str().unwrap());
    spec.opts.eval_batches = 2;
    spec.opts.pretrain_opts = TrainOpts { steps: 25, log_every: 1000, ..Default::default() };
    spec
}

/// One client sends an oversized line, a recovery probe, a second
/// oversized line, garbage and raw invalid UTF-8 — every one must be
/// answered, in bounded memory, on the SAME connection.
#[test]
fn tcp_line_cap_answers_bad_request_and_connection_recovers() {
    let srv = TcpServer::start(
        tmp_spec("cap"),
        "127.0.0.1:0",
        ServeCfg::default(),
        ShardCfg { workers: 1, replicate_hot: false, hot_min: 16 },
        Vec::new(),
    )
    .unwrap();

    let mut stream = TcpStream::connect(srv.local_addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let mut r = BufReader::new(stream.try_clone().unwrap());

    // 1) a line one chunk past the cap
    let oversized = vec![b'a'; MAX_LINE_BYTES + 16];
    stream.write_all(&oversized).unwrap();
    stream.write_all(b"\n").unwrap();
    // 2) recovery probe: a well-formed request (unknown model — the
    //    worker answers without opening a session)
    stream
        .write_all(b"{\"id\": 5, \"model\": \"definitely-not-a-model\"}\n")
        .unwrap();
    // 3) a second oversized line, 4) garbage, 5) invalid UTF-8
    stream.write_all(&oversized).unwrap();
    stream.write_all(b"\n").unwrap();
    stream.write_all(b"this is not json\n").unwrap();
    stream.write_all(b"\xff\xfe{\"id\": 6}\n").unwrap();
    stream.flush().unwrap();

    let mut responses = Vec::new();
    while responses.len() < 5 {
        let mut line = String::new();
        let n = r.read_line(&mut line).expect("read response");
        assert!(n > 0, "server hung up after {} of 5 responses", responses.len());
        responses.push(protocol::parse_response(line.trim()).unwrap());
    }

    let errs: Vec<_> = responses.iter().filter(|resp| resp.id == ERR_ID).collect();
    assert_eq!(errs.len(), 4, "both oversized lines + garbage + bad utf8");
    for resp in &errs {
        assert_eq!(resp.code.as_deref(), Some(codes::BAD_REQUEST));
    }
    let oversize_answers = errs
        .iter()
        .filter(|resp| {
            resp.error
                .as_deref()
                .unwrap_or("")
                .contains("exceeds max_line_bytes")
        })
        .count();
    assert_eq!(oversize_answers, 2, "each oversized line is answered");

    let probe = responses
        .iter()
        .find(|resp| resp.id == 5)
        .expect("the connection must survive the oversized line");
    assert_eq!(probe.code.as_deref(), Some(codes::UNKNOWN_MODEL));

    srv.shutdown().unwrap();
}
