//! Sharded-serving integration tests: the EDF queue property, the
//! determinism matrix over worker counts × batching × replication, and
//! a real-socket round trip through the TCP transport.
//!
//! Like the other integration suites these run with no artifacts and no
//! PJRT — the native executor synthesizes the manifest, and weights are
//! pretrained briefly into throwaway checkpoint directories.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use intfpqsim::prop_assert;
use intfpqsim::serve::batcher::Batcher;
use intfpqsim::serve::loadgen::{
    fetch_server_stats, run_loadgen, run_loadgen_sharded, run_loadgen_tcp, LoadgenCfg,
};
use intfpqsim::serve::metrics;
use intfpqsim::serve::protocol::{codes, Request};
use intfpqsim::serve::queue::{AdmissionQueue, Job};
use intfpqsim::serve::shard::{ShardCfg, SimSpec};
use intfpqsim::serve::transport::TcpServer;
use intfpqsim::serve::ServeCfg;
use intfpqsim::train::TrainOpts;
use intfpqsim::util::prop;

static SERIAL: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|p| p.into_inner())
}

fn tmp_spec(tag: &str) -> SimSpec {
    let dir = std::env::temp_dir().join(format!("intfpqsim_shard_{}", tag));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let mut spec = SimSpec::new("artifacts", dir.to_str().unwrap());
    spec.opts.eval_batches = 2;
    spec.opts.pretrain_opts = TrainOpts { steps: 25, log_every: 1000, ..Default::default() };
    spec
}

/// Property: across random keys, deadlines and batch caps, the
/// deadline-aware queue (a) never dispatches a job whose deadline
/// lapsed in the queue — it is answered with `deadline_expired_in_queue`
/// instead — (b) never mixes keys within a batch, and (c) dispatches
/// each key's jobs in EDF order, which for same-key no-deadline traffic
/// is exactly arrival order (the determinism the serve tests lean on).
#[test]
fn prop_edf_never_dispatches_expired_and_keeps_same_key_order() {
    let _g = lock();
    prop::check("edf_queue", 24, |rng| {
        let q = AdmissionQueue::new(256);
        let nkeys = 1 + rng.below(3);
        let njobs = 5 + rng.below(16);
        // (quant, deadline_ms): Some(1) will expire, Some(60_000) won't
        let mut meta: Vec<(String, Option<u64>)> = Vec::new();
        let mut rxs = Vec::new();
        for id in 0..njobs {
            let quant = format!("k{}", rng.below(nkeys));
            let dl = match rng.below(3) {
                0 => None,
                1 => Some(1),
                _ => Some(60_000),
            };
            let mut req = Request::new(id as u64, "m", &quant, 0);
            req.deadline_ms = dl;
            let (tx, rx) = mpsc::channel();
            q.try_push(Job::new(req, tx)).map_err(|_| "queue rejected a push".to_string())?;
            meta.push((quant, dl));
            rxs.push(rx);
        }
        // let the 1ms deadlines lapse while everything sits queued
        std::thread::sleep(Duration::from_millis(5));
        q.close();

        let max_batch = 1 + rng.below(4);
        let b = Batcher::new(Arc::clone(&q), Duration::from_millis(1), max_batch);
        let mut dispatched: Vec<u64> = Vec::new();
        while let Some(mb) = b.next_batch() {
            prop_assert!(
                mb.jobs.len() <= max_batch,
                "batch of {} exceeds max_batch {}",
                mb.jobs.len(),
                max_batch
            );
            for j in &mb.jobs {
                prop_assert!(
                    j.req.quant == mb.key.quant,
                    "job {} (key {}) rode a {} batch",
                    j.req.id,
                    j.req.quant,
                    mb.key.quant
                );
                dispatched.push(j.req.id);
            }
        }

        for (id, (_, dl)) in meta.iter().enumerate() {
            let ran = dispatched.contains(&(id as u64));
            if *dl == Some(1) {
                prop_assert!(!ran, "expired job {} was dispatched", id);
                let resp = rxs[id]
                    .try_recv()
                    .map_err(|_| format!("expired job {} got no response", id))?;
                prop_assert!(
                    resp.code.as_deref() == Some(codes::DEADLINE_QUEUE),
                    "expired job {} got code {:?}",
                    id,
                    resp.code
                );
            } else {
                prop_assert!(ran, "live job {} was never dispatched", id);
            }
        }

        // per key: EDF = live deadlined jobs (arrival order — their
        // absolute deadlines are arrival-ordered) before no-deadline
        // jobs (arrival order)
        for k in 0..nkeys {
            let quant = format!("k{}", k);
            let got: Vec<u64> = dispatched
                .iter()
                .copied()
                .filter(|&id| meta[id as usize].0 == quant)
                .collect();
            let mut want: Vec<u64> = (0..njobs as u64)
                .filter(|&id| {
                    meta[id as usize].0 == quant && meta[id as usize].1 == Some(60_000)
                })
                .collect();
            want.extend((0..njobs as u64).filter(|&id| {
                meta[id as usize].0 == quant && meta[id as usize].1.is_none()
            }));
            prop_assert!(
                got == want,
                "key {}: dispatch order {:?} != EDF order {:?}",
                quant,
                got,
                want
            );
        }
        Ok(())
    });
}

/// The sharded determinism matrix: per-request outputs are bit-identical
/// across worker counts, batching windows and hot-key replication — the
/// single-worker unbatched run is the reference.
#[test]
fn sharded_outputs_bit_identical_across_workers_and_batching() {
    let _g = lock();
    let spec = tmp_spec("determinism");
    let sim = spec.build().unwrap();
    let mix = vec![
        ("sim-opt-125m".to_string(), "fp32".to_string()),
        ("sim-opt-125m".to_string(), "abfp_w4a4_n64".to_string()),
    ];
    let base = LoadgenCfg {
        clients: 3,
        requests_per_client: 3,
        mix,
        deadline_ms: None,
        seed: 7,
        prewarm: true,
        ..Default::default()
    };
    let reference = run_loadgen(
        &sim,
        &LoadgenCfg {
            serve: ServeCfg {
                queue_cap: 64,
                batch_window: Duration::from_millis(1),
                max_batch: 1,
                ..ServeCfg::default()
            },
            ..base.clone()
        },
    )
    .unwrap();
    assert_eq!(reference.errors, 0);
    assert_eq!(reference.responses.len(), 9);

    let aggressive = ServeCfg {
        queue_cap: 64,
        batch_window: Duration::from_millis(30),
        max_batch: 8,
        ..ServeCfg::default()
    };
    let unbatched = ServeCfg {
        queue_cap: 64,
        batch_window: Duration::from_millis(1),
        max_batch: 1,
        ..ServeCfg::default()
    };
    let cells = [
        (1usize, false, aggressive.clone()),
        (3, false, unbatched),
        (3, true, aggressive),
    ];
    for (workers, replicate_hot, serve) in cells {
        let cfg = LoadgenCfg {
            serve,
            shard: ShardCfg { workers, replicate_hot, hot_min: 2 },
            ..base.clone()
        };
        let run = run_loadgen_sharded(&spec, &cfg).unwrap();
        assert_eq!(run.errors, 0, "workers={}", workers);
        assert_eq!(run.workers, workers);
        assert_eq!(run.per_worker.len(), workers);
        assert_eq!(run.responses.len(), reference.responses.len());
        for (ra, rb) in reference.responses.iter().zip(run.responses.iter()) {
            assert_eq!(ra.id, rb.id);
            assert!(rb.ok, "request {} failed under workers={}", rb.id, workers);
            assert_eq!(
                ra.outputs, rb.outputs,
                "request {}: output drift (workers={}, replicate_hot={})",
                ra.id, workers, replicate_hot
            );
        }
        let batches: usize = run.per_worker.iter().map(|w| w.serve.batches).sum();
        assert!(batches > 0, "per-worker stats must attribute the batches");

        // the registry saw exactly this run, attributed to real shards,
        // with per-shard cells summing to the aggregates
        let server = run.server.as_ref().expect("sharded loadgen attaches server stats");
        assert_eq!(server.admitted, 9, "workers={}", workers);
        assert_eq!(server.ok, 9, "workers={}", workers);
        assert_eq!(server.errors, 0);
        let snap = metrics::snapshot();
        snap.check().unwrap();
        assert_eq!(snap.ok, server.ok, "registry unchanged since the run");
        assert!(
            snap.shards.iter().all(|s| s.shard < workers),
            "activity attributed to a nonexistent shard (workers={}): {:?}",
            workers,
            snap.shards
        );
        let shard_ok: u64 = snap.shards.iter().map(|s| s.ok).sum();
        assert_eq!(shard_ok, snap.ok, "per-shard ok must sum to the aggregate");
        let shard_batches: u64 = snap.shards.iter().map(|s| s.batches).sum();
        assert_eq!(shard_batches, snap.batches, "per-shard batches must sum");
        let worker_ok: usize = run.per_worker.iter().map(|w| w.serve.ok).sum();
        assert_eq!(worker_ok as u64, snap.ok, "registry agrees with per-worker stats");
    }
}

/// Real-socket round trip: a 2-worker TCP server serves the closed-loop
/// TCP loadgen clients, then shuts down cleanly with per-worker stats
/// accounting for every request.
#[test]
fn tcp_server_round_trips_the_loadgen_over_real_sockets() {
    let _g = lock();
    let spec = tmp_spec("tcp");
    // the probe validates the mix locally and does the token accounting
    let probe = spec.build().unwrap();
    let srv = TcpServer::start(
        spec,
        "127.0.0.1:0",
        ServeCfg::default(),
        ShardCfg { workers: 2, replicate_hot: false, hot_min: 16 },
        Vec::new(),
    )
    .unwrap();
    let addr = srv.local_addr().to_string();

    let cfg = LoadgenCfg {
        clients: 2,
        requests_per_client: 2,
        mix: vec![("sim-opt-125m".to_string(), "fp32".to_string())],
        prewarm: false,
        ..Default::default()
    };
    let report = run_loadgen_tcp(&probe, &addr, &cfg).unwrap();
    assert_eq!(report.errors, 0);
    assert_eq!(report.ok, 4);
    assert_eq!(report.workers, 0, "remote server: shape unknown to the client");
    assert!(report.toks_per_s > 0.0);

    // the loadgen scraped the stats verb before and after: the delta is
    // exactly this run's traffic as the server counted it
    let server = report.server.as_ref().expect("TCP loadgen scrapes the stats verb");
    assert_eq!(server.admitted, 4);
    assert_eq!(server.ok, 4);
    assert_eq!(server.errors, 0);
    assert_eq!(server.expired, 0);
    assert!(
        server.cache_misses >= 1,
        "no prewarm: at least one session prepared on the clock"
    );
    // a raw stats-verb round trip over a fresh socket still answers and
    // stays internally consistent (cumulative since process start)
    let raw = fetch_server_stats(&addr).unwrap();
    raw.check().unwrap();
    assert!(raw.admitted >= server.admitted);

    let stats = srv.shutdown().unwrap();
    assert_eq!(stats.len(), 2);
    let served: usize = stats.iter().map(|s| s.serve.ok).sum();
    assert_eq!(served, 4, "per-worker stats must account for every request");
}
