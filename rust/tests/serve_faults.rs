//! Chaos suite: the serving plane under deterministic fault injection.
//!
//! Every test arms a seeded [`FaultPlan`] and drives real traffic
//! through the real serve stack, asserting the failure-domain
//! invariant the tentpole promises: **every admitted request yields
//! exactly one response — a success or a documented error code — under
//! every fault schedule**, no worker thread dies permanently, and the
//! metrics registry stays internally consistent
//! (`Snapshot::check`). Each fault site runs on at least two seeds so
//! the phase shift itself is under test, and the non-faulted requests
//! of a poisoned batch are compared byte-for-byte against a fault-free
//! run (timings zeroed) — supervision must not perturb innocent
//! batch-mates.
//!
//! The fault plan is process-global, so every test takes the
//! file-local mutex and clears the plan through a drop guard (a
//! panicking assertion must not leak an armed plan into the next
//! test).

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::sync::{mpsc, Mutex};
use std::time::Duration;

use intfpqsim::quantsim::Simulator;
use intfpqsim::serve::cache::SessionCache;
use intfpqsim::serve::faults::{self, FaultPlan};
use intfpqsim::serve::metrics;
use intfpqsim::serve::protocol::{self, codes, Request, Response, ERR_ID, SHUTDOWN_LINE};
use intfpqsim::serve::queue::{AdmissionQueue, Job};
use intfpqsim::serve::shard::{run_sharded, ShardCfg, SimSpec};
use intfpqsim::serve::transport::TcpServer;
use intfpqsim::serve::{serve_loop, ServeCfg};
use intfpqsim::train::TrainOpts;

static SERIAL: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|p| p.into_inner())
}

/// Clears the process-global fault plan when dropped, so a failing
/// assertion cannot leave a later test running under this test's
/// faults.
struct FaultGuard;

impl Drop for FaultGuard {
    fn drop(&mut self) {
        faults::clear();
    }
}

fn arm(spec: &str) -> FaultGuard {
    faults::install(FaultPlan::parse(spec).unwrap());
    FaultGuard
}

fn tmp_sim(tag: &str) -> Simulator {
    let dir = std::env::temp_dir().join(format!("intfpqsim_faults_{}", tag));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let mut sim = Simulator::new("artifacts", dir.to_str().unwrap()).unwrap();
    sim.opts.eval_batches = 2;
    sim.opts.pretrain_opts = TrainOpts { steps: 25, log_every: 1000, ..Default::default() };
    sim
}

fn tmp_spec(tag: &str) -> SimSpec {
    let dir = std::env::temp_dir().join(format!("intfpqsim_faults_{}", tag));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let mut spec = SimSpec::new("artifacts", dir.to_str().unwrap());
    spec.opts.eval_batches = 2;
    spec.opts.pretrain_opts = TrainOpts { steps: 25, log_every: 1000, ..Default::default() };
    spec
}

fn push_req(queue: &AdmissionQueue, req: Request) -> mpsc::Receiver<Response> {
    let (tx, rx) = mpsc::channel();
    queue.try_push(Job::new(req, tx)).map_err(|r| r.job.req.id).unwrap();
    rx
}

/// The payload bytes of a response with the run-dependent timing and
/// occupancy fields zeroed — what "byte-identical across fault
/// schedules" means for requests whose *content* must not change.
fn payload_bytes(mut resp: Response) -> Vec<u8> {
    resp.queue_ms = 0.0;
    resp.run_ms = 0.0;
    resp.batched = 0;
    let mut buf = Vec::new();
    resp.write_line(&mut buf);
    buf
}

/// `worker_panic` on the single-worker in-process server, two seeds:
/// the poison request is quarantined with `internal_error`, its
/// batch-mates answer byte-identically to a fault-free run, and the
/// worker keeps serving follow-up batches through its evicted cache.
#[test]
fn poison_request_is_quarantined_and_batchmates_answer_clean() {
    let _g = lock();
    let sim = tmp_sim("poison");

    for seed in [1u64, 3] {
        // ids seed, seed+1, seed+2 under `panic=10`: only id == seed
        // satisfies id % 10 == seed % 10 — one poison, two innocents
        let ids = [seed, seed + 1, seed + 2];

        // fault-free baseline: what every request's payload must be
        faults::clear();
        let queue = AdmissionQueue::new(8);
        let rxs: Vec<_> = ids
            .iter()
            .enumerate()
            .map(|(i, &id)| {
                push_req(&queue, Request::new(id, "sim-opt-125m", "fp32", i as u64))
            })
            .collect();
        queue.close();
        let cfg = ServeCfg {
            queue_cap: 8,
            batch_window: Duration::from_millis(1),
            max_batch: 2,
            ..ServeCfg::default()
        };
        let mut cache = SessionCache::new();
        let stats = serve_loop(&sim, &queue, &cfg, &mut cache);
        assert_eq!(stats.ok, 3, "baseline must be fault-free");
        let baseline: Vec<Vec<u8>> =
            rxs.into_iter().map(|rx| payload_bytes(rx.try_recv().unwrap())).collect();

        // same traffic under the fault plan: batch {seed, seed+1}
        // panics, blame isolation re-runs it singly, batch {seed+2}
        // rides the post-recovery (evicted, reopened) cache
        metrics::reset();
        let _guard = arm(&format!("seed={},panic=10", seed));
        let queue = AdmissionQueue::new(8);
        let rxs: Vec<_> = ids
            .iter()
            .enumerate()
            .map(|(i, &id)| {
                push_req(&queue, Request::new(id, "sim-opt-125m", "fp32", i as u64))
            })
            .collect();
        queue.close();
        let mut cache = SessionCache::new();
        let stats = serve_loop(&sim, &queue, &cfg, &mut cache);
        assert_eq!(stats.ok, 2, "seed {}: innocents must serve", seed);
        assert_eq!(stats.errors, 1, "seed {}: exactly the poison errors", seed);

        let responses: Vec<Response> =
            rxs.into_iter().map(|rx| rx.try_recv().unwrap()).collect();
        let poison = &responses[0];
        assert!(!poison.ok, "seed {}: poison request must not succeed", seed);
        assert_eq!(poison.code.as_deref(), Some(codes::INTERNAL_ERROR));
        assert!(poison.error.as_deref().unwrap().contains("quarantined"));
        assert!(poison.outputs.is_empty(), "no output from a panicked run");
        for i in [1, 2] {
            assert!(responses[i].ok, "seed {}: innocent id {} errored", seed, ids[i]);
            assert_eq!(
                payload_bytes(responses[i].clone()),
                baseline[i],
                "seed {}: innocent id {} diverged from the fault-free run",
                seed,
                ids[i]
            );
        }

        // the registry saw the whole story and stayed consistent: one
        // batch panic plus one single-rerun panic, one quarantine
        let snap = metrics::snapshot();
        snap.check().unwrap();
        assert_eq!(snap.admitted, 3);
        assert_eq!(snap.panics_recovered, 2, "batch panic + single-rerun panic");
        assert_eq!(snap.requests_quarantined, 1);
        assert_eq!(snap.ok, 2);
        assert_eq!(snap.errors, 1);
    }
}

/// `worker_panic` through the shard pool: the panicked worker rebuilds
/// its simulator from the [`SimSpec`] and the pool drains to a clean
/// `Ok` — no worker thread dies permanently.
#[test]
fn sharded_worker_rebuilds_simulator_and_keeps_serving() {
    let _g = lock();
    let spec = tmp_spec("rebuild");
    metrics::reset();
    // seed=2, panic=10: id 2 is the only poison among 2..=5
    let _guard = arm("seed=2,panic=10");

    let queue = AdmissionQueue::new(8);
    let rxs: Vec<_> = (2u64..=5)
        .map(|id| push_req(&queue, Request::new(id, "sim-opt-125m", "fp32", id - 2)))
        .collect();
    queue.close();
    let cfg = ServeCfg {
        queue_cap: 8,
        batch_window: Duration::from_millis(1),
        max_batch: 2,
        ..ServeCfg::default()
    };
    let shard_cfg = ShardCfg { workers: 2, replicate_hot: false, hot_min: 16 };
    let stats = run_sharded(&spec, &queue, &cfg, &shard_cfg, &[]).unwrap();
    assert_eq!(stats.len(), 2, "every worker must exit cleanly, panic or not");
    let ok: usize = stats.iter().map(|s| s.serve.ok).sum();
    let errors: usize = stats.iter().map(|s| s.serve.errors).sum();
    assert_eq!(ok, 3, "the three innocents all serve — after the rebuild too");
    assert_eq!(errors, 1, "exactly the poison request errors");

    let responses: Vec<Response> = rxs.into_iter().map(|rx| rx.try_recv().unwrap()).collect();
    assert_eq!(responses[0].code.as_deref(), Some(codes::INTERNAL_ERROR));
    for resp in &responses[1..] {
        assert!(resp.ok, "id {}: {:?}", resp.id, resp.error);
    }

    let snap = metrics::snapshot();
    snap.check().unwrap();
    assert_eq!(snap.admitted, 4);
    assert_eq!(snap.requests_quarantined, 1);
    assert!(snap.panics_recovered >= 2);
}

/// `forward_delay` with a seed-shifted schedule: the same traffic run
/// under seeds 1 and 2 of `delay=2:1200` delays a *different* forward
/// each time — under seed 2 the injected stall lands on the deadlined
/// request and expires it in-run; under seed 1 it lands on the
/// no-deadline request and both succeed. The outcome flip is exactly
/// the determinism the seeded plan promises.
#[test]
fn forward_delay_schedule_is_seed_shifted_and_expires_deadlines() {
    let _g = lock();
    let sim = tmp_sim("delay");
    let cfg = ServeCfg {
        queue_cap: 8,
        batch_window: Duration::from_millis(1),
        max_batch: 1,
        ..ServeCfg::default()
    };
    // warm the session cache off the clock so the deadlined request
    // pays neither pretraining nor session prepare against its budget
    let mut cache = SessionCache::new();
    let queue = AdmissionQueue::new(8);
    let rx = push_req(&queue, Request::new(100, "sim-opt-125m", "fp32", 0));
    queue.close();
    serve_loop(&sim, &queue, &cfg, &mut cache);
    assert!(rx.try_recv().unwrap().ok, "warm-up request must serve");

    for (seed, expect_expiry) in [(1u64, false), (2, true)] {
        metrics::reset();
        let _guard = arm(&format!("seed={},delay=2:1200", seed));
        let queue = AdmissionQueue::new(8);
        // EDF dispatches the deadlined job first: its forward is k=0,
        // the no-deadline job's is k=1; (k + seed) % 2 == 0 fires
        let mut deadlined = Request::new(0, "sim-opt-125m", "fp32", 0);
        deadlined.deadline_ms = Some(500);
        let rx_deadlined = push_req(&queue, deadlined);
        let rx_patient = push_req(&queue, Request::new(1, "sim-opt-125m", "fp32", 1));
        queue.close();
        let stats = serve_loop(&sim, &queue, &cfg, &mut cache);

        let r0 = rx_deadlined.try_recv().unwrap();
        let r1 = rx_patient.try_recv().unwrap();
        assert!(r1.ok, "seed {}: the no-deadline request always serves", seed);
        if expect_expiry {
            assert_eq!(
                r0.code.as_deref(),
                Some(codes::DEADLINE_RUN),
                "seed {}: the stall lands on the deadlined forward",
                seed
            );
            assert!(r0.outputs.is_empty(), "expired: no stale output");
            assert_eq!(stats.errors, 1);
            assert_eq!(stats.ok, 1);
        } else {
            assert!(r0.ok, "seed {}: the stall misses the deadlined forward", seed);
            assert_eq!(stats.errors, 0);
            assert_eq!(stats.ok, 2);
        }
        let snap = metrics::snapshot();
        snap.check().unwrap();
        assert_eq!(snap.admitted, 2);
        assert_eq!(snap.errors, if expect_expiry { 1 } else { 0 });
    }
}

fn connect(addr: &str) -> (BufWriter<TcpStream>, BufReader<TcpStream>) {
    let s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    (BufWriter::new(s.try_clone().unwrap()), BufReader::new(s))
}

/// `conn_drop` over real sockets, two seeds against one server: every
/// request line the schedule spares gets exactly one `ok` response;
/// every dropped line closes the connection instead of hanging it, the
/// client reconnects, and the server's books balance afterwards.
#[test]
fn conn_drop_schedule_kills_connections_but_books_balance() {
    let _g = lock();
    metrics::reset();
    let spec = tmp_spec("drop");
    let srv = TcpServer::start(
        spec,
        "127.0.0.1:0",
        ServeCfg {
            queue_cap: 16,
            batch_window: Duration::from_millis(1),
            max_batch: 8,
            ..ServeCfg::default()
        },
        ShardCfg { workers: 1, replicate_hot: false, hot_min: 16 },
        Vec::new(),
    )
    .unwrap();
    let addr = srv.local_addr().to_string();

    let mut served = 0u64;
    for seed in [1u64, 2] {
        // installing the plan resets the line counter: line k of this
        // phase is dropped iff (k + seed) % 3 == 0, independent of the
        // other phase — seed 1 kills k ∈ {2, 5}, seed 2 kills k ∈ {1, 4}
        let _guard = arm(&format!("seed={},drop=3", seed));
        let mut conn: Option<(BufWriter<TcpStream>, BufReader<TcpStream>)> = None;
        for k in 0u64..6 {
            if conn.is_none() {
                conn = Some(connect(&addr));
            }
            let id = seed * 100 + k;
            {
                let w = &mut conn.as_mut().unwrap().0;
                writeln!(
                    w,
                    r#"{{"id": {}, "model": "sim-opt-125m", "quant": "fp32", "batch": {}}}"#,
                    id, k
                )
                .unwrap();
                w.flush().unwrap();
            }
            let dropped = (k + seed) % 3 == 0;
            let mut line = String::new();
            match conn.as_mut().unwrap().1.read_line(&mut line) {
                Ok(0) | Err(_) => {
                    // the server killed the connection before answering
                    assert!(
                        dropped,
                        "seed {}: line {} closed the connection off-schedule",
                        seed, k
                    );
                    conn = None;
                }
                Ok(_) => {
                    assert!(!dropped, "seed {}: line {} answered despite the drop", seed, k);
                    let resp = protocol::parse_response(line.trim()).unwrap();
                    assert_eq!(resp.id, id);
                    assert!(resp.ok, "id {}: {:?}", id, resp.error);
                    served += 1;
                }
            }
        }
    }
    assert_eq!(served, 8, "4 of 6 lines survive each seed's schedule");

    // a dropped line dies before admission, so the books balance:
    // everything admitted was answered, nothing leaked
    let snap = metrics::snapshot();
    snap.check().unwrap();
    assert_eq!(snap.admitted, 8);
    assert_eq!(snap.ok, 8);
    assert_eq!(snap.errors, 0);

    let stats = srv.shutdown().unwrap();
    let ok: usize = stats.iter().map(|s| s.serve.ok).sum();
    assert_eq!(ok, 8, "per-worker stats must account for every served request");
}

/// An idle TCP connection past `--idle-timeout` is reaped (counted in
/// `conns_reaped`) without disturbing the server: a fresh connection
/// still serves afterwards.
#[test]
fn idle_connections_are_reaped_and_server_keeps_serving() {
    let _g = lock();
    metrics::reset();
    let spec = tmp_spec("idle");
    let srv = TcpServer::start(
        spec,
        "127.0.0.1:0",
        ServeCfg {
            queue_cap: 16,
            batch_window: Duration::from_millis(1),
            max_batch: 8,
            idle_timeout: Some(Duration::from_millis(50)),
            ..ServeCfg::default()
        },
        ShardCfg { workers: 1, replicate_hot: false, hot_min: 16 },
        Vec::new(),
    )
    .unwrap();
    let addr = srv.local_addr().to_string();

    // connect, say nothing: the read timeout reaps us
    let (_w_idle, mut r_idle) = connect(&addr);
    let mut line = String::new();
    let reaped = matches!(r_idle.read_line(&mut line), Ok(0) | Err(_));
    assert!(reaped, "an idle connection past the timeout must be closed");

    // the server is unharmed: a new connection round-trips a request
    let (mut w, mut r) = connect(&addr);
    writeln!(w, r#"{{"id": 1, "model": "sim-opt-125m", "quant": "fp32", "batch": 0}}"#)
        .unwrap();
    w.flush().unwrap();
    let mut line = String::new();
    assert!(r.read_line(&mut line).unwrap() > 0, "server must keep serving");
    let resp = protocol::parse_response(line.trim()).unwrap();
    assert!(resp.ok, "{:?}", resp.error);

    let snap = metrics::snapshot();
    snap.check().unwrap();
    assert!(snap.conns_reaped >= 1, "the reap must be counted");

    srv.shutdown().unwrap();
}

/// The drain timeout flushes what cannot finish: with every forward
/// stalled by fault injection and a 100ms `--drain-timeout`, a
/// `shutdown` verb acks immediately, the jobs the worker cannot reach
/// in time are answered `shutting_down` (never silently dropped), and
/// the verb-initiated drain runs the whole server to a clean
/// [`TcpServer::wait`] exit.
#[test]
fn drain_timeout_flushes_unfinished_jobs_with_shutting_down() {
    let _g = lock();
    metrics::reset();
    // every batched forward sleeps 800ms — admitted work cannot finish
    // inside the 100ms drain budget
    let _guard = arm("seed=1,delay=1:800");
    let spec = tmp_spec("flush");
    let srv = TcpServer::start(
        spec,
        "127.0.0.1:0",
        ServeCfg {
            queue_cap: 16,
            batch_window: Duration::from_millis(1),
            max_batch: 1,
            drain_timeout: Duration::from_millis(100),
            ..ServeCfg::default()
        },
        ShardCfg { workers: 1, replicate_hot: false, hot_min: 16 },
        Vec::new(),
    )
    .unwrap();
    let addr = srv.local_addr().to_string();

    let (mut w, mut r) = connect(&addr);
    for id in 1u64..=3 {
        writeln!(
            w,
            r#"{{"id": {}, "model": "sim-opt-125m", "quant": "fp32", "batch": {}}}"#,
            id,
            id - 1
        )
        .unwrap();
    }
    w.flush().unwrap();
    writeln!(w, "{}", SHUTDOWN_LINE).unwrap();
    w.flush().unwrap();

    // ack first (admission flips synchronously), then one response per
    // admitted request — flushed ones early, any in-flight one after
    // its stalled forward finishes
    let mut acked = false;
    let mut responses: Vec<Response> = Vec::new();
    while responses.len() < 3 {
        let mut line = String::new();
        let n = r.read_line(&mut line).expect("server hung up before answering");
        assert!(n > 0, "connection closed with {} of 3 responses", responses.len());
        let resp = protocol::parse_response(line.trim()).unwrap();
        if resp.id == ERR_ID {
            assert_eq!(resp.code.as_deref(), Some(codes::SHUTTING_DOWN), "drain ack");
            acked = true;
            continue;
        }
        responses.push(resp);
    }
    assert!(acked, "the shutdown verb must be acked");

    // the single worker holds at most one job and each forward stalls
    // for 800ms, so at least the other two jobs must have been flushed;
    // whatever was in flight finishes normally — exactly one response
    // per admitted request either way
    let mut ids: Vec<u64> = responses.iter().map(|resp| resp.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![1, 2, 3], "exactly one response per admitted request");
    let flushed = responses
        .iter()
        .filter(|resp| resp.code.as_deref() == Some(codes::SHUTTING_DOWN))
        .count();
    for resp in &responses {
        assert!(
            resp.ok || resp.code.as_deref() == Some(codes::SHUTTING_DOWN),
            "id {}: undocumented drain outcome {:?}",
            resp.id,
            resp.code
        );
    }
    assert!(flushed >= 2, "the stalled worker cannot beat the drain timeout");

    let snap = metrics::snapshot();
    snap.check().unwrap();
    assert_eq!(snap.admitted, 3);
    assert_eq!(snap.drain_begun, 1);
    assert_eq!(snap.drain_flushed as usize, flushed);

    // the verb-driven drain stops the accept loop on its own: wait()
    // returns without an abortive shutdown
    srv.wait().unwrap();
}
