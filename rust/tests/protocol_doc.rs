//! Protocol-conformance suite: `docs/serving.md` is the operator-facing
//! spec, and these tests keep it honest.
//!
//! * the anchored tables in the doc (request fields, response fields,
//!   error codes) must match the server's own manifests exactly;
//! * the "Failure modes" table must carry one row per error code, each
//!   with a non-empty trigger and client-action cell — an operator
//!   reading the doc learns what to DO about every code the wire can
//!   emit;
//! * a live TCP server is then exercised through every documented
//!   request field and every client-triggerable error code, over a real
//!   socket, asserting the documented `code` comes back — including the
//!   graceful-drain handshake (`shutdown` verb ack, then
//!   `shutting_down` rejections for new work);
//! * the codes a well-formed client cannot trigger (`run_failed`,
//!   `internal_error`) are pinned to the server source instead.

use std::collections::BTreeSet;
use std::io::{BufRead, BufReader, BufWriter, Write as IoWrite};
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::Duration;

use intfpqsim::serve::metrics;
use intfpqsim::serve::protocol::{
    self, codes, Response, ERR_ID, REQUEST_FIELDS, RESPONSE_FIELDS,
};
use intfpqsim::serve::shard::{ShardCfg, SimSpec};
use intfpqsim::serve::transport::TcpServer;
use intfpqsim::serve::ServeCfg;
use intfpqsim::train::TrainOpts;
use intfpqsim::util::json::Json;

static SERIAL: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|p| p.into_inner())
}

const DOC: &str = include_str!("../../docs/serving.md");

/// First backticked token of every table row inside the named
/// `<!-- wire:NAME --> ... <!-- /wire -->` block of the doc.
fn anchored_fields(anchor: &str) -> BTreeSet<String> {
    let open = format!("<!-- wire:{} -->", anchor);
    let start = DOC
        .find(&open)
        .unwrap_or_else(|| panic!("docs/serving.md lost its {} anchor", open));
    let rest = &DOC[start..];
    let end = rest.find("<!-- /wire -->").expect("unclosed wire anchor");
    rest[..end]
        .lines()
        .filter(|l| l.trim_start().starts_with('|'))
        .filter_map(|l| l.split('`').nth(1).map(str::to_string))
        .collect()
}

fn manifest(fields: &[&str]) -> BTreeSet<String> {
    fields.iter().map(|s| s.to_string()).collect()
}

#[test]
fn doc_tables_match_the_wire_manifests_exactly() {
    assert_eq!(
        anchored_fields("request-fields"),
        manifest(REQUEST_FIELDS),
        "docs/serving.md request table drifted from protocol::REQUEST_FIELDS"
    );
    assert_eq!(
        anchored_fields("response-fields"),
        manifest(RESPONSE_FIELDS),
        "docs/serving.md response table drifted from protocol::RESPONSE_FIELDS"
    );
    assert_eq!(
        anchored_fields("error-codes"),
        manifest(codes::ALL),
        "docs/serving.md error-code table drifted from protocol::codes::ALL"
    );
}

#[test]
fn doc_verb_and_metric_tables_match_the_compiled_manifests() {
    assert_eq!(
        anchored_fields("verbs"),
        manifest(protocol::VERBS),
        "docs/serving.md verb table drifted from protocol::VERBS"
    );
    assert_eq!(
        anchored_fields("metrics"),
        manifest(metrics::NAMES),
        "docs/serving.md metric-name table drifted from metrics::NAMES"
    );
}

/// The documented wire limits must be the compiled-in constants: row
/// name is the first backticked token, the value is the third `|` cell.
#[test]
fn doc_limits_match_the_wire_constants() {
    let open = "<!-- wire:limits -->";
    let start = DOC
        .find(open)
        .expect("docs/serving.md lost its <!-- wire:limits --> anchor");
    let rest = &DOC[start..];
    let end = rest.find("<!-- /wire -->").expect("unclosed wire anchor");
    let mut documented = std::collections::BTreeMap::new();
    for l in rest[..end].lines() {
        let l = l.trim_start();
        if !l.starts_with('|') {
            continue;
        }
        let Some(name) = l.split('`').nth(1) else { continue };
        let value = l
            .split('|')
            .nth(2)
            .and_then(|cell| cell.trim().parse::<usize>().ok())
            .unwrap_or_else(|| panic!("limit row {:?} has no numeric value cell", name));
        documented.insert(name.to_string(), value);
    }
    assert_eq!(
        documented.remove("max_line_bytes"),
        Some(protocol::MAX_LINE_BYTES),
        "docs/serving.md max_line_bytes drifted from protocol::MAX_LINE_BYTES"
    );
    assert_eq!(
        documented.remove("max_depth"),
        Some(protocol::MAX_DEPTH),
        "docs/serving.md max_depth drifted from protocol::MAX_DEPTH"
    );
    assert!(
        documented.is_empty(),
        "undocumented-in-code limit rows: {:?}",
        documented.keys().collect::<Vec<_>>()
    );
}

/// Every documented error code gets a row in the "Failure modes" table
/// — code, what triggers it, and what the client should do — and no
/// row documents a code the wire cannot emit.
#[test]
fn failure_modes_table_covers_every_error_code_with_a_client_action() {
    assert_eq!(
        anchored_fields("failure-modes"),
        manifest(codes::ALL),
        "docs/serving.md failure-modes table drifted from protocol::codes::ALL"
    );
    let open = "<!-- wire:failure-modes -->";
    let start = DOC.find(open).expect("anchor vanished mid-test");
    let rest = &DOC[start..];
    let end = rest.find("<!-- /wire -->").expect("unclosed wire anchor");
    for l in rest[..end].lines() {
        let l = l.trim();
        if !l.starts_with('|') || l.starts_with("|-") || l.starts_with("| -") {
            continue;
        }
        let Some(code) = l.split('`').nth(1) else { continue };
        let cells: Vec<&str> = l.trim_matches('|').split('|').map(str::trim).collect();
        assert!(
            cells.len() >= 3 && cells.iter().all(|c| !c.is_empty()),
            "failure-mode row for {:?} must carry code | trigger | client action, got {:?}",
            code,
            cells
        );
    }
}

#[test]
fn run_failed_is_emitted_by_the_server_even_if_not_client_triggerable() {
    // `run_failed` needs an internal failure and `internal_error` a
    // worker panic to fire, so the live test below cannot exercise
    // them; pin them to the emission sites instead.
    let dispatch_src = include_str!("../src/serve/mod.rs");
    let shard_src = include_str!("../src/serve/shard.rs");
    assert!(dispatch_src.contains("codes::RUN_FAILED"), "dispatch lost run_failed");
    assert!(shard_src.contains("codes::RUN_FAILED"), "worker-failure drain lost run_failed");
    assert!(
        dispatch_src.contains("codes::INTERNAL_ERROR"),
        "the quarantine path lost internal_error"
    );
}

fn tmp_spec(tag: &str) -> SimSpec {
    let dir = std::env::temp_dir().join(format!("intfpqsim_protodoc_{}", tag));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let mut spec = SimSpec::new("artifacts", dir.to_str().unwrap());
    spec.opts.eval_batches = 2;
    spec.opts.pretrain_opts = TrainOpts { steps: 25, log_every: 1000, ..Default::default() };
    spec
}

/// Drive a live TCP server through every documented request field and
/// every client-triggerable error code, on one connection.
///
/// The choreography leans on the batching window for determinism: the
/// first request anchors a long (700ms) fp32 window, follow-ups are
/// staggered into or behind it, and a small queue cap (4) plus a burst
/// of same-key traffic forces real `queue_full` rejections while the
/// worker is pinned inside the window.
#[test]
fn live_server_honors_every_documented_field_and_code() {
    let _g = lock();
    let spec = tmp_spec("live");
    // B·S for the inline-tokens requests, from the same manifest the
    // server uses
    let probe = spec.build().unwrap();
    let mcfg = probe.rt.manifest.model("sim-opt-125m").unwrap().clone();
    let n_tokens = mcfg.batch * mcfg.seq;
    drop(probe);

    let srv = TcpServer::start(
        spec,
        "127.0.0.1:0",
        ServeCfg {
            queue_cap: 4,
            batch_window: Duration::from_millis(700),
            max_batch: 8,
            ..ServeCfg::default()
        },
        ShardCfg { workers: 1, replicate_hot: false, hot_min: 16 },
        Vec::new(),
    )
    .unwrap();

    let stream = TcpStream::connect(srv.local_addr()).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(120)))
        .unwrap();
    let mut w = BufWriter::new(stream.try_clone().unwrap());
    let mut r = BufReader::new(stream);
    let mut send = |line: &str| {
        writeln!(w, "{}", line).unwrap();
        w.flush().unwrap();
    };
    let pause = || std::thread::sleep(Duration::from_millis(25));

    // give the worker time to build its simulator and park on the queue
    std::thread::sleep(Duration::from_millis(300));

    // id 1 anchors the fp32 window: exercises id/model/quant/batch/
    // deadline_ms on a success path
    send(
        r#"{"id": 1, "model": "sim-opt-125m", "quant": "fp32", "batch": 0, "deadline_ms": 60000}"#,
    );
    std::thread::sleep(Duration::from_millis(100));

    // id 2: valid inline tokens (the `tokens` field, success path);
    // id 3: wrong token count -> bad_input at dispatch;
    // id 4: a 100ms deadline that survives admission but lapses before
    //       the 700ms window closes -> deadline_expired_in_run
    let zeros = vec!["0"; n_tokens].join(",");
    send(&format!(
        r#"{{"id": 2, "model": "sim-opt-125m", "quant": "fp32", "tokens": [{}]}}"#,
        zeros
    ));
    pause();
    send(r#"{"id": 3, "model": "sim-opt-125m", "quant": "fp32", "tokens": [1, 2, 3]}"#);
    pause();
    send(r#"{"id": 4, "model": "sim-opt-125m", "quant": "fp32", "deadline_ms": 100}"#);
    pause();

    // foreign keys queue up behind the open fp32 window:
    // id 5 -> unknown_model, id 6 -> open_session_failed,
    // id 7 (1ms deadline) -> deadline_expired_in_queue
    send(r#"{"id": 5, "model": "sim-opt-125b", "quant": "fp32"}"#);
    pause();
    send(r#"{"id": 6, "model": "sim-opt-125m", "quant": "bogus"}"#);
    pause();
    send(r#"{"id": 7, "model": "sim-opt-125m", "quant": "abfp_w4a4_n64", "deadline_ms": 1}"#);
    pause();
    // the queue now holds ids 5, 6, 7 (cap 4): id 8 fills the last
    // slot, ids 9 and 10 are rejected with queue_full
    send(r#"{"id": 8, "model": "sim-opt-125m", "quant": "abfp_w4a4_n64"}"#);
    send(r#"{"id": 9, "model": "sim-opt-125m", "quant": "abfp_w4a4_n64"}"#);
    send(r#"{"id": 10, "model": "sim-opt-125m", "quant": "abfp_w4a4_n64"}"#);
    // unparseable line and unknown field -> bad_request with the
    // reserved id
    send("this is not json");
    send(r#"{"id": 11, "model": "sim-opt-125m", "deadline_mss": 5}"#);

    let mut responses: Vec<Response> = Vec::new();
    while responses.len() < 12 {
        let mut line = String::new();
        let n = r.read_line(&mut line).expect("server hung up early");
        assert!(n > 0, "server closed with {} of 12 responses", responses.len());
        responses.push(protocol::parse_response(line.trim()).unwrap());
    }

    let by_id = |id: u64| -> &Response {
        responses
            .iter()
            .find(|resp| resp.id == id)
            .unwrap_or_else(|| panic!("no response for id {}", id))
    };
    let code_of = |id: u64| -> &str { by_id(id).code.as_deref().unwrap_or("") };

    // success path: every documented response field is on the wire
    let ok = by_id(1);
    assert!(ok.ok);
    assert!(!ok.outputs.is_empty());
    let raw = Json::parse(&ok.line()).unwrap();
    for field in ["id", "ok", "batched", "queue_ms", "run_ms", "outputs"] {
        assert!(raw.get(field).is_some(), "success response lost {:?}", field);
    }
    assert!(by_id(2).ok, "valid inline tokens must serve");
    assert_eq!(
        by_id(1).batched,
        by_id(2).batched,
        "ids 1 and 2 rode the same fp32 window"
    );

    assert_eq!(code_of(3), codes::BAD_INPUT);
    assert_eq!(code_of(4), codes::DEADLINE_RUN);
    assert_eq!(code_of(5), codes::UNKNOWN_MODEL);
    assert_eq!(code_of(6), codes::OPEN_FAILED);
    assert_eq!(code_of(7), codes::DEADLINE_QUEUE);
    assert!(by_id(8).ok, "the last admitted request still serves");
    assert_eq!(code_of(9), codes::QUEUE_FULL);
    assert_eq!(code_of(10), codes::QUEUE_FULL);

    let bad: Vec<&Response> = responses.iter().filter(|resp| resp.id == ERR_ID).collect();
    assert_eq!(bad.len(), 2, "unparseable line + unknown field");
    for resp in bad {
        assert_eq!(resp.code.as_deref(), Some(codes::BAD_REQUEST));
        assert!(resp.error.as_deref().unwrap_or("").contains("bad request"));
    }

    // every error response carries both error and code; every failure
    // code observed is documented
    let documented = anchored_fields("error-codes");
    for resp in &responses {
        if !resp.ok {
            assert!(resp.error.is_some() && resp.code.is_some(), "id {}", resp.id);
            assert!(
                documented.contains(resp.code.as_deref().unwrap()),
                "undocumented code {:?}",
                resp.code
            );
        }
    }

    // the `stats` verb answers on the same connection with one snapshot
    // line whose top-level keys are exactly the documented metric names
    send(protocol::STATS_LINE);
    let mut line = String::new();
    r.read_line(&mut line).expect("read stats snapshot");
    let snap = Json::parse(line.trim()).expect("stats snapshot parses");
    let keys: Vec<&str> = snap
        .as_obj()
        .expect("stats snapshot is an object")
        .keys()
        .map(|k| k.as_str())
        .collect();
    assert_eq!(keys, metrics::NAMES, "stats keys drifted from metrics::NAMES");

    // the graceful-drain handshake, as documented: the `shutdown` verb
    // is acked with a shutting_down line (reserved id), and every
    // subsequent request on any connection is rejected with
    // `shutting_down` — admission flips synchronously, so the very next
    // request deterministically sees it
    send(protocol::SHUTDOWN_LINE);
    let mut line = String::new();
    r.read_line(&mut line).expect("read drain ack");
    let ack = protocol::parse_response(line.trim()).unwrap();
    assert_eq!(ack.id, ERR_ID, "drain ack rides the reserved id");
    assert_eq!(ack.code.as_deref(), Some(codes::SHUTTING_DOWN));
    send(r#"{"id": 12, "model": "sim-opt-125m", "quant": "fp32"}"#);
    let mut line = String::new();
    r.read_line(&mut line).expect("read post-drain rejection");
    let rej = protocol::parse_response(line.trim()).unwrap();
    assert_eq!(rej.id, 12);
    assert_eq!(
        rej.code.as_deref(),
        Some(codes::SHUTTING_DOWN),
        "new work after the shutdown verb must be rejected, not queued"
    );

    srv.shutdown().unwrap();
}
