//! Cross-backend conformance harness.
//!
//! The paper's core promise — trustworthy simulation of many numeric
//! formats — only holds if every execution path produces identical
//! quantizer math. This suite enumerates **every registered backend**
//! (`backend::all_names()`) at several thread counts and asserts
//! bit-equality against the `scalar` reference across:
//!
//! * a shape grid: empty / 1x1 / non-square / prime-sized / tall-thin /
//!   wide-flat / multi-worker sizes;
//! * adversarial values: subnormals, signed zeros, infinities, NaN
//!   propagation, and catastrophic-cancellation sums (which fail under
//!   *any* reordering of a reduction — the sharpest probe of the fixed
//!   reduction-order contract).
//!
//! The same matrix holds the transpose-free `matmul_t` to the
//! `matmul(a, b.transpose())` reference and the fused `qdq_matmul_t` to
//! the unfused clone-prep-matmul reference (synthetic non-idempotent
//! preps plus the real quantizer row kernels), and pins a native eval
//! session's fused output to the unfused path end to end.
//!
//! A backend added later only needs a line in `all_names()`/`select()`
//! to inherit the whole matrix. Ops with a documented tolerance
//! (`sum_sq` above the parallel threshold) are checked at 1e-5 relative
//! on finite data instead; serial configurations of every backend must
//! still match bit-for-bit.
//!
//! Run against one backend end-to-end (through the `Tensor` API) with
//! e.g. `INTFPQSIM_BACKEND=pool INTFPQSIM_THREADS=4 cargo test`.

use std::sync::Arc;

use intfpqsim::tensor::backend::{self, Backend, Pool, Scalar};
use intfpqsim::tensor::Tensor;
use intfpqsim::util::prop;
use intfpqsim::util::rng::Pcg64;

/// Adversarial f32 values: signed zeros, infinities, NaN, subnormals,
/// extremes, and magnitudes that force catastrophic cancellation.
const ADVERSARIAL: [f32; 16] = [
    0.0,
    -0.0,
    1.0,
    -1.0,
    f32::INFINITY,
    f32::NEG_INFINITY,
    f32::NAN,
    f32::MIN_POSITIVE, // smallest normal
    1.0e-42,           // subnormal
    -1.0e-42,
    f32::MAX,
    -f32::MAX,
    1.0e8,
    -1.0e8,
    1.0e-8,
    16_777_216.0, // 2^24: integer-precision edge of f32
];

/// (m, k, n) matmul shapes; gram uses the (m, k) prefix.
const SHAPES: [(usize, usize, usize); 10] = [
    (0, 0, 0),
    (0, 4, 3),
    (4, 0, 3),
    (4, 3, 0),
    (1, 1, 1),
    (3, 5, 2),    // non-square, rows < threads (forces fallback path)
    (7, 11, 13),  // prime-sized
    (64, 3, 5),   // tall/thin
    (3, 48, 37),  // wide/flat
    (33, 17, 29), // enough rows/cols for a real 8-way partition
];

/// How a test tensor is filled.
#[derive(Clone, Copy)]
enum Fill {
    /// Pure adversarial cycle (every element from `ADVERSARIAL`).
    Adversarial,
    /// Heavy-tailed random with adversarial values sprinkled in.
    Mixed,
    /// Alternating huge/small magnitudes: any reduction reordering
    /// changes the result, so bit-equality proves the order is fixed.
    Cancellation,
}

impl Fill {
    fn name(self) -> &'static str {
        match self {
            Fill::Adversarial => "adversarial",
            Fill::Mixed => "mixed",
            Fill::Cancellation => "cancellation",
        }
    }

    fn vec(self, rng: &mut Pcg64, len: usize, salt: usize) -> Vec<f32> {
        match self {
            Fill::Adversarial => (0..len)
                .map(|i| ADVERSARIAL[(i * 7 + salt) % ADVERSARIAL.len()])
                .collect(),
            Fill::Mixed => {
                let mut v = prop::heavy_vec(rng, len, 1.0);
                for (i, slot) in v.iter_mut().enumerate() {
                    if i % 7 == salt % 7 {
                        *slot = ADVERSARIAL[(i / 7 + salt) % ADVERSARIAL.len()];
                    }
                }
                v
            }
            Fill::Cancellation => (0..len)
                .map(|i| match (i + salt) % 4 {
                    0 => 1.0e8,
                    1 => 1.0 + (i % 13) as f32,
                    2 => -1.0e8,
                    _ => -(2.0 + (i % 11) as f32),
                })
                .collect(),
        }
    }
}

/// All (label, backend) pairs under test: every registered name, and for
/// the parallel backends several worker counts.
fn backends_under_test() -> Vec<(String, Arc<dyn Backend>)> {
    let mut out = Vec::new();
    for &name in backend::all_names() {
        // Build the 3-worker instance first; if the backend reports a
        // single worker anyway it is serial and the thread count is
        // irrelevant, so that one instance covers the whole name (no
        // throwaway probe constructions).
        let be3 = backend::select(name, 3).unwrap();
        if be3.threads() == 1 {
            out.push((format!("{}[serial]", be3.describe()), be3));
            continue;
        }
        out.push((format!("{}[t=3]", be3.describe()), be3));
        for threads in [1usize, 8] {
            let be = backend::select(name, threads).unwrap();
            out.push((format!("{}[t={}]", be.describe(), threads), be));
        }
    }
    out
}

/// Bit-equality with a NaN escape hatch: any NaN payload is accepted as
/// long as both sides are NaN (payload bits are not part of the
/// contract; *where* NaNs appear is).
fn assert_bits_f32(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{}: length", ctx);
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        let same = g.to_bits() == w.to_bits() || (g.is_nan() && w.is_nan());
        assert!(
            same,
            "{}: idx {}: got {:e} ({:#010x}) want {:e} ({:#010x})",
            ctx,
            i,
            g,
            g.to_bits(),
            w,
            w.to_bits()
        );
    }
}

fn assert_bits_f64(got: f64, want: f64, ctx: &str) {
    let same = got.to_bits() == want.to_bits() || (got.is_nan() && want.is_nan());
    assert!(
        same,
        "{}: got {:e} ({:#018x}) want {:e} ({:#018x})",
        ctx,
        got,
        got.to_bits(),
        want,
        want.to_bits()
    );
}

#[test]
fn matmul_bit_identical_across_backends_shapes_and_values() {
    let mut rng = Pcg64::new(0xC04F);
    let under_test = backends_under_test();
    for fill in [Fill::Adversarial, Fill::Mixed, Fill::Cancellation] {
        for &(m, k, n) in &SHAPES {
            let a = Tensor::new(vec![m, k], fill.vec(&mut rng, m * k, 1));
            let b = Tensor::new(vec![k, n], fill.vec(&mut rng, k * n, 5));
            let want = Scalar.matmul(&a, &b);
            for (label, be) in &under_test {
                let got = be.matmul(&a, &b);
                assert_eq!(got.shape, want.shape);
                let ctx = format!("matmul {} {}x{}x{} {}", label, m, k, n, fill.name());
                assert_bits_f32(&got.data, &want.data, &ctx);
            }
        }
    }
}

#[test]
fn matmul_t_bit_identical_to_transposed_reference() {
    // Satellite (ISSUE 5): a @ b^T off row-major b must reproduce the
    // unfused `matmul(a, b.transpose())` scalar reference bit for bit —
    // every backend, every shape, every adversarial fill. Registered
    // backends inherit this suite automatically.
    let mut rng = Pcg64::new(0x3A71);
    let under_test = backends_under_test();
    for fill in [Fill::Adversarial, Fill::Mixed, Fill::Cancellation] {
        for &(m, k, n) in &SHAPES {
            let a = Tensor::new(vec![m, k], fill.vec(&mut rng, m * k, 2));
            let b = Tensor::new(vec![n, k], fill.vec(&mut rng, n * k, 8));
            let want = Scalar.matmul(&a, &b.transpose());
            for (label, be) in &under_test {
                let got = be.matmul_t(&a, &b);
                assert_eq!(got.shape, want.shape);
                let ctx = format!("matmul_t {} {}x{}x{} {}", label, m, k, n, fill.name());
                assert_bits_f32(&got.data, &want.data, &ctx);
            }
        }
    }
}

#[test]
fn qdq_matmul_t_bit_identical_to_unfused_reference() {
    // The fused A-panel prep must equal "clone x; prep every row;
    // matmul(xq, w^T)" exactly. The synthetic preps are deliberately
    // non-idempotent (an affine map, not a fixed point), so a backend
    // that applies prep to a row buffer twice fails loudly; the
    // smoothing prep covers the per-column multiply the real sites use.
    let mut rng = Pcg64::new(0x9D07);
    let under_test = backends_under_test();
    type Prep<'a> = Box<dyn Fn(&mut [f32]) + Sync + 'a>;
    for fill in [Fill::Adversarial, Fill::Mixed, Fill::Cancellation] {
        for &(m, k, n) in &SHAPES {
            let x = Tensor::new(vec![m, k], fill.vec(&mut rng, m * k, 4));
            let w = Tensor::new(vec![n, k], fill.vec(&mut rng, n * k, 7));
            let smooth: Vec<f32> = (0..k).map(|j| 0.25 + (j % 5) as f32 * 0.5).collect();
            let preps: Vec<(&str, Prep<'_>)> = vec![
                ("identity", Box::new(|_row: &mut [f32]| {})),
                (
                    "affine",
                    Box::new(|row: &mut [f32]| {
                        for (j, v) in row.iter_mut().enumerate() {
                            *v = *v * 0.5 + (j % 3) as f32;
                        }
                    }),
                ),
                (
                    "smooth",
                    Box::new(|row: &mut [f32]| {
                        for (v, &s) in row.iter_mut().zip(smooth.iter()) {
                            *v *= s;
                        }
                    }),
                ),
            ];
            for (pname, prep) in &preps {
                let mut xq = x.clone();
                for r in 0..m {
                    prep(xq.row_mut(r));
                }
                let want = Scalar.matmul(&xq, &w.transpose());
                for (label, be) in &under_test {
                    let got = be.qdq_matmul_t(&x, prep.as_ref(), &w);
                    assert_eq!(got.shape, want.shape);
                    let ctx = format!(
                        "qdq_matmul_t {} {}x{}x{} {} prep={}",
                        label,
                        m,
                        k,
                        n,
                        fill.name(),
                        pname
                    );
                    assert_bits_f32(&got.data, &want.data, &ctx);
                }
            }
        }
    }
}

#[test]
fn qdq_matmul_t_with_real_quantizer_kernels_matches_bulk_path() {
    // The exact prep the native executor fuses: smoothing multiply +
    // RowQdq (ABFP int4/e4m3, two-level ABFP, static per-tensor and
    // per-channel int) vs the unfused bulk QuantSpec::apply_with path.
    use intfpqsim::formats::{Format, E4M3, INT4, INT8};
    use intfpqsim::runtime::registry::{QuantKind, QuantSpec};
    let mut rng = Pcg64::new(0xF0CA);
    let under_test = backends_under_test();
    let q = |kind: QuantKind, fmt: Format, n: usize| QuantSpec { kind, fmt: Some(fmt), n };
    for (rows, k, dout) in [(33usize, 128usize, 29usize), (5, 64, 9)] {
        let x = Tensor::new(vec![rows, k], prop::heavy_vec(&mut rng, rows * k, 2.0));
        let w = Tensor::new(vec![dout, k], prop::heavy_vec(&mut rng, dout * k, 1.0));
        let smooth: Vec<f32> = (0..k).map(|j| 0.5 + (j % 7) as f32 * 0.25).collect();
        let alpha_pc: Vec<f32> = (0..k).map(|j| 0.25 + (j % 9) as f32 * 0.5).collect();
        let cases: Vec<(&str, QuantSpec, Option<Vec<f32>>)> = vec![
            ("abfp_int4", q(QuantKind::Abfp, Format::Int(INT4), 64), None),
            ("abfp_e4m3", q(QuantKind::Abfp, Format::Fp(E4M3), 64), None),
            ("abfp2_int4", q(QuantKind::Abfp2, Format::Int(INT4), 64), None),
            ("static_int8", q(QuantKind::StaticInt, Format::Int(INT8), 64), Some(vec![2.5])),
            (
                "static_int4_pc",
                q(QuantKind::StaticIntPc, Format::Int(INT4), 64),
                Some(alpha_pc.clone()),
            ),
        ];
        for (cname, spec, alpha) in &cases {
            // unfused reference: full materialized copy through the bulk path
            let mut xq = x.clone();
            xq.scale_cols(&smooth);
            spec.apply_with(&mut xq.data, k, alpha.as_deref(), &Scalar).unwrap();
            let want = Scalar.matmul(&xq, &w.transpose());
            // fused: the site prep closure qlinear builds
            let kern = spec.row_kernel(k, alpha.as_deref()).unwrap();
            let prep = |row: &mut [f32]| {
                for (v, &s) in row.iter_mut().zip(smooth.iter()) {
                    *v *= s;
                }
                kern.apply(row);
            };
            for (label, be) in &under_test {
                let got = be.qdq_matmul_t(&x, &prep, &w);
                let ctx = format!("fused {} {} {}x{}x{}", cname, label, rows, k, dout);
                assert_bits_f32(&got.data, &want.data, &ctx);
            }
        }
    }
}

#[test]
fn fused_eval_session_bit_identical_to_unfused_across_backends() {
    // End-to-end tentpole check: a native eval session run with the
    // fused qdq_matmul_t path must produce byte-identical outputs to
    // the unfused reference path, for a quantized-with-smoothing LM
    // wiring and a static-clip wiring, on every registered backend.
    // (The toggle swaps equal-bit kernels, so concurrent tests sampling
    // it mid-flip cannot observe different results.)
    use intfpqsim::corpus::TextCorpus;
    use intfpqsim::model;
    use intfpqsim::model::net;
    use intfpqsim::runtime::{Runtime, Val};

    struct Restore(bool);
    impl Drop for Restore {
        fn drop(&mut self) {
            net::set_qdq_fusion(self.0);
            let name =
                std::env::var("INTFPQSIM_BACKEND").unwrap_or_else(|_| "auto".to_string());
            let threads = backend::env_threads();
            if backend::configure(&name, threads).is_err() {
                backend::configure("auto", threads).unwrap();
            }
        }
    }
    let _restore = Restore(net::set_qdq_fusion(true));

    let rt = Runtime::new("artifacts").unwrap();
    let model_name = "sim-opt-125m";
    let cfg = rt.manifest.model(model_name).unwrap().clone();
    let params = model::init_params(&cfg, 23);
    let tb = TextCorpus::new(intfpqsim::corpus::TEXT_SEED).eval_batch(5, cfg.batch, cfg.seq);
    let tv = vec![Val::I32(tb.tokens, vec![cfg.batch, cfg.seq])];
    for art in ["eval_abfp_w4a8_n64", "eval_mse_w4a8"] {
        let mut sticky = model::param_vals(&cfg, &params).unwrap();
        if art.contains("abfp") {
            for s in &cfg.sites {
                let sm: Vec<f32> = (0..s.dim).map(|j| 0.5 + 0.25 * (j % 3) as f32).collect();
                sticky.insert(format!("smooth.{}", s.name), Val::F32(sm, vec![s.dim]));
            }
        } else {
            for s in &cfg.sites {
                sticky.insert(format!("alpha.{}", s.name), Val::F32(vec![1.75], vec![]));
            }
        }
        let id = format!("{}/{}", model_name, art);
        for &be_name in backend::all_names() {
            backend::set_active(backend::select(be_name, 3).unwrap());
            let sess = rt.session(&id, &sticky).unwrap();
            net::set_qdq_fusion(true);
            let fused = sess.run(&tv.iter().collect::<Vec<_>>()).unwrap();
            net::set_qdq_fusion(false);
            let unfused = sess.run(&tv.iter().collect::<Vec<_>>()).unwrap();
            net::set_qdq_fusion(true);
            assert_eq!(fused.len(), unfused.len(), "{} @ {}", id, be_name);
            for (o, (f, u)) in fused.iter().zip(unfused.iter()).enumerate() {
                assert_eq!(f.shape, u.shape, "{} @ {} out {}", id, be_name, o);
                let ctx = format!("fused session {} @ {} out {}", id, be_name, o);
                assert_bits_f32(&f.data, &u.data, &ctx);
            }
        }
    }
}

#[test]
fn gram_bit_identical_across_backends_shapes_and_values() {
    let mut rng = Pcg64::new(0x6A40);
    let under_test = backends_under_test();
    for fill in [Fill::Adversarial, Fill::Mixed, Fill::Cancellation] {
        for &(m, k, _) in &SHAPES {
            let x = Tensor::new(vec![m, k], fill.vec(&mut rng, m * k, 3));
            let want = Scalar.gram(&x);
            for (label, be) in &under_test {
                let got = be.gram(&x);
                assert_eq!(got.shape, want.shape);
                let ctx = format!("gram {} {}x{} {}", label, m, k, fill.name());
                assert_bits_f32(&got.data, &want.data, &ctx);
            }
        }
    }
}

#[test]
fn axpy_bit_identical_for_every_length_and_value() {
    // axpy is element-wise: chunked parallelism cannot change per-element
    // math, so bit-equality must hold at EVERY length, including above
    // the parallel threshold, for every backend.
    let mut rng = Pcg64::new(0xA417);
    let under_test = backends_under_test();
    for fill in [Fill::Adversarial, Fill::Mixed, Fill::Cancellation] {
        for len in [0usize, 1, 3, 4, 5, 257, (1 << 15) + 7] {
            let x = fill.vec(&mut rng, len, 2);
            let y0 = fill.vec(&mut rng, len, 9);
            let mut want = y0.clone();
            Scalar.axpy(-1.25, &x, &mut want);
            for (label, be) in &under_test {
                let mut got = y0.clone();
                be.axpy(-1.25, &x, &mut got);
                let ctx = format!("axpy {} len {} {}", label, len, fill.name());
                assert_bits_f32(&got, &want, &ctx);
            }
        }
    }
}

#[test]
fn sum_sq_bit_identical_serial_tolerant_parallel() {
    let mut rng = Pcg64::new(0x5059);
    let under_test = backends_under_test();
    // Below the parallel threshold every backend takes an order-preserving
    // path: bit-equality even on NaN/inf/subnormal/cancellation data.
    for fill in [Fill::Adversarial, Fill::Mixed, Fill::Cancellation] {
        for len in [0usize, 1, 3, 4, 5, 257, 4099] {
            let x = fill.vec(&mut rng, len, 4);
            let want = Scalar.sum_sq(&x);
            for (label, be) in &under_test {
                let ctx = format!("sum_sq {} len {} {}", label, len, fill.name());
                assert_bits_f64(be.sum_sq(&x), want, &ctx);
            }
        }
    }
    // Above the threshold: serial configurations (threads() == 1, which
    // includes simd — its unroll keeps the scalar fold order) stay
    // bit-identical; parallel ones are held to the documented 1e-5.
    let big = prop::heavy_vec(&mut rng, (1 << 15) + 777, 1.0);
    let want = Scalar.sum_sq(&big);
    for (label, be) in &under_test {
        let got = be.sum_sq(&big);
        if be.threads() == 1 {
            assert_bits_f64(got, want, &format!("sum_sq {} big serial", label));
        } else {
            let rel = (got - want).abs() / want.abs().max(1e-12);
            assert!(rel <= 1e-5, "sum_sq {}: rel err {}", label, rel);
        }
    }
}

#[test]
fn par_map_preserves_index_order_everywhere() {
    for (label, be) in backends_under_test() {
        for n in [0usize, 1, 7, 23, 64] {
            let got = be.par_map_f64(n, &|i| (i * i + 1) as f64);
            let want: Vec<f64> = (0..n).map(|i| (i * i + 1) as f64).collect();
            assert_eq!(got, want, "{} n={}", label, n);
        }
    }
}

#[test]
fn par_map_tensor_preserves_index_order_and_bits() {
    // The tensor-valued fan-out behind the batched per-(b, h) attention
    // wave: results must come back in index order with exactly the
    // serial loop's bytes, for every backend and worker count.
    let mut rng = Pcg64::new(0x7E27);
    let src: Vec<Tensor> = (0..23)
        .map(|_| Tensor::new(vec![3, 4], prop::heavy_vec(&mut rng, 12, 1.0)))
        .collect();
    let job = |i: usize| -> Tensor {
        // same per-element math every time: a scale plus an index tag
        let mut t = src[i].clone();
        for (j, v) in t.data.iter_mut().enumerate() {
            *v = *v * 0.5 + (i * 31 + j) as f32;
        }
        t
    };
    for n in [0usize, 1, 7, 23] {
        let want: Vec<Tensor> = (0..n).map(&job).collect();
        for (label, be) in backends_under_test() {
            let got = be.par_map_tensor(n, &job);
            assert_eq!(got.len(), n, "{} n={}", label, n);
            for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
                assert_eq!(g.shape, w.shape, "{} n={} idx {}", label, n, i);
                let ctx = format!("par_map_tensor {} n={} idx {}", label, n, i);
                assert_bits_f32(&g.data, &w.data, &ctx);
            }
        }
    }
}

#[test]
fn run_batch_bit_identical_to_sequential_across_backends_and_tasks() {
    // Satellite (ISSUE 4): for every registered artifact task — LM
    // (scalar NLL head), span-QA (start/end logit heads), classification
    // (class logits) — a coalesced `Session::run_batch([r1..rB])` must be
    // bit-identical per request to B sequential `run` calls. The native
    // batched path concatenates requests into one [B·T, d] forward, so
    // this also pins the batched embedding/linear/attention math to the
    // sequential reference. Checked on every registered backend (the
    // session hoists the process-wide handle at open, so each backend is
    // installed in turn and restored from the environment afterwards);
    // the CI backend matrix re-runs the whole file per env-pinned cell
    // on top.
    use intfpqsim::corpus::{ImageCorpus, QaCorpus, TextCorpus};
    use intfpqsim::model;
    use intfpqsim::runtime::{Runtime, Val};

    // Restore the env-pinned selection even if an assertion below
    // panics, so tests running after this one see the cell's backend.
    // (Concurrent tests in this binary may sample the temporary backend
    // mid-test; every assertion they make holds under ANY registered
    // backend — the whole point of the parity matrix — so that overlap
    // is benign.)
    struct RestoreEnvBackend;
    impl Drop for RestoreEnvBackend {
        fn drop(&mut self) {
            let name =
                std::env::var("INTFPQSIM_BACKEND").unwrap_or_else(|_| "auto".to_string());
            let threads = backend::env_threads();
            if backend::configure(&name, threads).is_err() {
                backend::configure("auto", threads).unwrap();
            }
        }
    }
    let _restore = RestoreEnvBackend;

    let rt = Runtime::new("artifacts").unwrap();
    let nb = 3usize;
    // (model, artifact suffix): fp32 per task + one quantized LM wiring
    // so the batch-wide QDQ fan-out is covered.
    let cases = [
        ("sim-opt-125m", "eval_fp32"),
        ("sim-opt-125m", "eval_abfp_w4a4_n64"),
        ("sim-bert-base", "eval_fp32"),
        ("sim-vit-32", "eval_fp32"),
    ];
    for (model_name, art) in cases {
        let cfg = rt.manifest.model(model_name).unwrap().clone();
        let params = model::init_params(&cfg, 11);
        let mut sticky = model::param_vals(&cfg, &params).unwrap();
        if art.contains("abfp") {
            for s in &cfg.sites {
                sticky.insert(
                    format!("smooth.{}", s.name),
                    Val::F32(vec![1.0; s.dim], vec![s.dim]),
                );
            }
        }
        let frees: Vec<Vec<Val>> = (0..nb)
            .map(|i| {
                let v = match cfg.task.as_str() {
                    "span_qa" => Val::I32(
                        QaCorpus::new(intfpqsim::corpus::QA_SEED)
                            .eval_batch(i as u64, cfg.batch, cfg.seq)
                            .tokens
                            .tokens,
                        vec![cfg.batch, cfg.seq],
                    ),
                    "image_cls" => {
                        let ib = ImageCorpus::new(intfpqsim::corpus::IMG_SEED)
                            .eval_batch(i as u64, cfg.batch);
                        Val::F32(
                            ib.pixels,
                            vec![cfg.batch, cfg.image, cfg.image, cfg.channels],
                        )
                    }
                    _ => Val::I32(
                        TextCorpus::new(intfpqsim::corpus::TEXT_SEED)
                            .eval_batch(i as u64, cfg.batch, cfg.seq)
                            .tokens,
                        vec![cfg.batch, cfg.seq],
                    ),
                };
                vec![v]
            })
            .collect();
        let id = format!("{}/{}", model_name, art);
        for &be_name in backend::all_names() {
            backend::set_active(backend::select(be_name, 3).unwrap());
            let sess = rt.session(&id, &sticky).unwrap();
            let batched = sess.run_batch(&frees).unwrap();
            assert_eq!(batched.len(), nb, "{} @ {}", id, be_name);
            for (i, free) in frees.iter().enumerate() {
                let seq = sess.run(free).unwrap();
                assert_eq!(seq.len(), batched[i].len(), "{} @ {} req {}", id, be_name, i);
                for (o, (bt, st)) in batched[i].iter().zip(seq.iter()).enumerate() {
                    assert_eq!(bt.shape, st.shape, "{} @ {} req {} out {}", id, be_name, i, o);
                    let ctx = format!(
                        "run_batch {} @ {} req {} out {}",
                        id, be_name, i, o
                    );
                    assert_bits_f32(&bt.data, &st.data, &ctx);
                }
            }
        }
    }
    // _restore's Drop reinstalls the env-pinned backend here (and on
    // any panic above).
}

#[test]
fn nan_propagates_identically() {
    // NaN must appear exactly where the scalar kernel puts one: a NaN in
    // A poisons its whole output row; a NaN in B poisons a column —
    // except where the kernel's documented a==0 skip masks it.
    let mut a = Tensor::zeros(vec![3, 3]);
    for v in a.data.iter_mut() {
        *v = 1.0;
    }
    a.set2(1, 1, f32::NAN);
    let mut b = Tensor::zeros(vec![3, 3]);
    for v in b.data.iter_mut() {
        *v = 2.0;
    }
    let want = Scalar.matmul(&a, &b);
    for r in 0..3 {
        for c in 0..3 {
            assert_eq!(want.at2(r, c).is_nan(), r == 1, "scalar NaN row placement");
        }
    }
    for (label, be) in backends_under_test() {
        let got = be.matmul(&a, &b);
        assert_bits_f32(&got.data, &want.data, &format!("nan prop {}", label));
    }
}

#[test]
fn active_backend_matches_scalar_through_tensor_api() {
    // The env-selected backend (CI runs this file once per
    // INTFPQSIM_BACKEND x INTFPQSIM_THREADS cell) must agree with scalar
    // when driven through the public Tensor entry points.
    let mut rng = Pcg64::new(0xAC71);
    let a = Tensor::new(vec![24, 17], prop::heavy_vec(&mut rng, 24 * 17, 1.0));
    let b = Tensor::new(vec![17, 19], prop::heavy_vec(&mut rng, 17 * 19, 1.0));
    let desc = backend::active().describe();
    assert_bits_f32(
        &a.matmul(&b).data,
        &Scalar.matmul(&a, &b).data,
        &format!("Tensor::matmul via {}", desc),
    );
    assert_bits_f32(
        &a.gram().data,
        &Scalar.gram(&a).data,
        &format!("Tensor::gram via {}", desc),
    );
}

#[test]
fn pool_survives_reuse_across_many_small_calls() {
    // The persistent pool must give identical answers on the 500th call
    // as on the first (no worker death, no queue corruption) — the
    // many-small-sites calibration pattern it exists to accelerate.
    let mut rng = Pcg64::new(0x9001);
    let pool = Pool::new(4);
    let a = Tensor::new(vec![12, 9], prop::heavy_vec(&mut rng, 12 * 9, 1.0));
    let b = Tensor::new(vec![9, 7], prop::heavy_vec(&mut rng, 9 * 7, 1.0));
    let want = Scalar.matmul(&a, &b);
    for call in 0..500 {
        let got = pool.matmul(&a, &b);
        assert_bits_f32(&got.data, &want.data, &format!("pool call {}", call));
    }
}

#[test]
fn pool_nested_fan_out_does_not_deadlock() {
    // calibration -> par_map over sites -> gram per site is a nested
    // fan-out on ONE pool; the help-while-waiting design must complete it
    // even when every worker is blocked inside an inner batch.
    let mut rng = Pcg64::new(0x9002);
    let pool = Pool::new(2);
    let x = Tensor::new(vec![16, 8], prop::heavy_vec(&mut rng, 16 * 8, 1.0));
    let want = Scalar.gram(&x).data[0] as f64;
    let got = pool.par_map_f64(8, &|_| pool.gram(&x).data[0] as f64);
    assert_eq!(got, vec![want; 8]);
}

#[test]
fn pool_propagates_task_panics_and_keeps_working() {
    let pool = Pool::new(2);
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        pool.par_map_f64(8, &|i| {
            assert!(i != 5, "deliberate test panic");
            i as f64
        })
    }));
    assert!(r.is_err(), "panic in a pool task must propagate to the caller");
    // the pool (and its workers) must remain fully usable afterwards
    let got = pool.par_map_f64(6, &|i| i as f64 * 3.0);
    assert_eq!(got, vec![0.0, 3.0, 6.0, 9.0, 12.0, 15.0]);
}

#[test]
fn par_chunks_f32_bit_identical_for_any_chunking() {
    // The chunked-dispatch primitive behind the bulk QDQ loops: disjoint
    // pieces + identical per-element math ⇒ bit-equality with the serial
    // loop for every backend, chunk size and length (incl. ragged tails).
    let mut rng = Pcg64::new(0xC806);
    let under_test = backends_under_test();
    for fill in [Fill::Adversarial, Fill::Mixed, Fill::Cancellation] {
        for len in [0usize, 1, 5, 64, 257, (1 << 15) + 13] {
            let base = fill.vec(&mut rng, len, 6);
            let mut want = base.clone();
            for (start, v) in want.iter_mut().enumerate() {
                *v = *v * 0.5 + start as f32;
            }
            for (label, be) in &under_test {
                for chunk in [1usize, 7, 64, len.max(1)] {
                    let mut got = base.clone();
                    be.par_chunks_f32(&mut got, chunk, &|start, piece| {
                        for (j, v) in piece.iter_mut().enumerate() {
                            *v = *v * 0.5 + (start + j) as f32;
                        }
                    });
                    let ctx =
                        format!("par_chunks {} len {} chunk {} {}", label, len, chunk, fill.name());
                    assert_bits_f32(&got, &want, &ctx);
                }
            }
        }
    }
}

#[test]
fn int_matmul_t_bit_identical_across_backends_shapes_and_scales() {
    // Tentpole (ISSUE 8): the true i8×i8→i32 GEMM accumulates exactly
    // (integer sums are order-independent) and every backend stores the
    // identical rescale expression `(acc as f32) / (sx * sw)`, so —
    // unlike the f32 kernels, which need a fixed fold order — the int
    // kernel is **unconditionally** bit-identical to the scalar
    // reference for ANY codes and ANY scales, on every backend × thread
    // count × shape (including empty dims and the 8-way-partition
    // sizes). Awkward non-power-of-two scales are the point here: they
    // make the rescale division inexact, so a backend that reassociated
    // it (e.g. multiplied by a precomputed reciprocal) fails loudly.
    use intfpqsim::tensor::backend::QuantPanel;
    let mut rng = Pcg64::new(0x18B1);
    let under_test = backends_under_test();
    for &(m, k, n) in &SHAPES {
        let xq: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let wq = QuantPanel {
            q: (0..n * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect(),
            n,
            k,
        };
        let x_scales: Vec<f32> = (0..m).map(|_| 0.05 + rng.below(700) as f32 * 0.01).collect();
        let w_scales: Vec<f32> = (0..n).map(|_| 0.05 + rng.below(900) as f32 * 0.007).collect();
        let want = Scalar.int_matmul_t(&xq, &x_scales, &wq, &w_scales);
        assert_eq!(want.shape, vec![m, n]);
        for (label, be) in &under_test {
            let got = be.int_matmul_t(&xq, &x_scales, &wq, &w_scales);
            assert_eq!(got.shape, want.shape);
            let ctx = format!("int_matmul_t {} {}x{}x{}", label, m, k, n);
            assert_bits_f32(&got.data, &want.data, &ctx);
        }
    }
}

#[test]
fn int_matmul_t_bit_exact_vs_qdq_reference_on_exact_cells() {
    // Tentpole (ISSUE 8): where every f32 rounding in the QDQ
    // simulation is exact, the int kernel must agree with it bit for
    // bit — that is what makes the compute-mode switch observable only
    // through speed on such cells. Exactness holds when (a) all scales
    // are powers of two (quantize multiply, dequantize divide, and the
    // rescale product are then lossless) and (b) every partial integer
    // sum stays within f32's 24 significand bits. Cells where scales
    // are arbitrary reals agree only to a documented few-ULP tolerance
    // (`docs/architecture.md`) and are deliberately NOT asserted
    // bit-equal here.
    use intfpqsim::tensor::backend::{quantize_rows_i8, QuantPanel};
    let mut rng = Pcg64::new(0x1E8A);
    let under_test = backends_under_test();
    // (m, k, n) small enough that |partial sum| <= k * 20 * 127 < 2^24
    for &(m, k, n) in &[(5usize, 8usize, 4usize), (7, 64, 13), (33, 48, 29)] {
        for &(sx, sw_base) in &[(1.0f32, 1.0f32), (2.0, 0.5), (0.25, 4.0)] {
            // integer-valued activations and weights whose codes fit i8
            // after the power-of-two scaling
            let x: Vec<f32> = (0..m * k)
                .map(|_| (rng.below(41) as f32 - 20.0) / sx)
                .collect();
            let w_scales: Vec<f32> =
                (0..n).map(|j| sw_base * [0.5f32, 1.0, 2.0][j % 3]).collect();
            let mut w = Tensor::zeros(vec![n, k]);
            for j in 0..n {
                for v in w.row_mut(j) {
                    *v = (rng.below(255) as f32 - 127.0) / w_scales[j];
                }
            }
            // int path: quantize activations, pack weights, integer GEMM
            let mut xq = vec![0i8; m * k];
            quantize_rows_i8(&x, sx, 127.0, &mut xq);
            let panel = QuantPanel::pack(&w, &w_scales, 127.0);
            let x_scales = vec![sx; m];
            // QDQ reference: the simulated path's dequantized f32
            // operands through the ordinary matmul_t
            let xf = Tensor::new(vec![m, k], x.clone());
            let want = Scalar.matmul_t(&xf, &w);
            for (label, be) in &under_test {
                let got = be.int_matmul_t(&xq, &x_scales, &panel, &w_scales);
                let ctx = format!(
                    "int vs qdq {} {}x{}x{} sx={}",
                    label, m, k, n, sx
                );
                assert_bits_f32(&got.data, &want.data, &ctx);
            }
        }
    }
}

#[test]
fn bulk_qdq_bit_identical_to_scalar_backend() {
    // Satellite regression: the three bulk QDQ loops route through
    // Backend::par_chunks_f32 above the parallel threshold; every
    // backend must reproduce the scalar backend's bytes exactly.
    use intfpqsim::formats::{
        abfp_qdq_with, pcmax_weight_qdq_with, static_int_qdq_with, Format, E4M3, INT4,
    };
    let mut rng = Pcg64::new(0xBD0);
    let under_test = backends_under_test();
    // (rows, k): big enough to cross PAR_MIN_LEN (1<<15) plus a small one
    for (rows, k) in [(520usize, 128usize), (7, 64)] {
        let base = prop::heavy_vec(&mut rng, rows * k, 2.5);
        let alpha_pc: Vec<f32> = (0..k).map(|j| 0.25 + (j % 9) as f32 * 0.5).collect();

        let mut want_abfp = base.clone();
        abfp_qdq_with(&mut want_abfp, k, Format::Int(INT4), 64, &Scalar);
        let mut want_abfp_fp = base.clone();
        abfp_qdq_with(&mut want_abfp_fp, k, Format::Fp(E4M3), 64, &Scalar);
        let mut want_static = base.clone();
        static_int_qdq_with(&mut want_static, &[2.5], 8, &Scalar);
        let mut want_static_pc = base.clone();
        static_int_qdq_with(&mut want_static_pc, &alpha_pc, 4, &Scalar);
        let mut want_pcmax = base.clone();
        pcmax_weight_qdq_with(&mut want_pcmax, k, 4, &Scalar);

        for (label, be) in &under_test {
            let ctx = |what: &str| format!("{} {} {}x{}", what, label, rows, k);
            let mut got = base.clone();
            abfp_qdq_with(&mut got, k, Format::Int(INT4), 64, be.as_ref());
            assert_bits_f32(&got, &want_abfp, &ctx("abfp_int4"));
            let mut got = base.clone();
            abfp_qdq_with(&mut got, k, Format::Fp(E4M3), 64, be.as_ref());
            assert_bits_f32(&got, &want_abfp_fp, &ctx("abfp_e4m3"));
            let mut got = base.clone();
            static_int_qdq_with(&mut got, &[2.5], 8, be.as_ref());
            assert_bits_f32(&got, &want_static, &ctx("static_int8"));
            let mut got = base.clone();
            static_int_qdq_with(&mut got, &alpha_pc, 4, be.as_ref());
            assert_bits_f32(&got, &want_static_pc, &ctx("static_int4_pc"));
            let mut got = base.clone();
            pcmax_weight_qdq_with(&mut got, k, 4, be.as_ref());
            assert_bits_f32(&got, &want_pcmax, &ctx("pcmax_int4"));
        }
    }
}
