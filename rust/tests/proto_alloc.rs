//! Steady-state allocation audit for the wire hot path.
//!
//! A counting `#[global_allocator]` wraps the system allocator; after a
//! short warm-up (buffers grow to their high-water mark) the test runs
//! ten thousand full round trips — request parse, request serialize,
//! and the worker-side response build (output tensors summarized into a
//! pool-recycled `Response::outputs` vector) plus its serialize — and
//! asserts the allocation counter does not move AT ALL: 0 allocations
//! per request. Every serve-metrics recording call rides inside the
//! audited loop too: the observability layer is always-on, so its
//! counters and histograms must be just as allocation-free as the wire
//! path they instrument. The failure-domain paths ride along as well:
//! the `internal_error` (quarantine) and `shutting_down` (drain)
//! responses are rebuilt in place via `Response::err_into` — String
//! and Vec capacity reuse — and serialized each iteration, so a server
//! under fault injection stays just as allocation-free as a healthy
//! one.
//!
//! This lives in its own test binary on purpose — the libtest harness
//! runs tests in parallel threads, and any neighbour test's allocations
//! would pollute the counter. One binary, one test, one thread.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use intfpqsim::serve::metrics::{self, SpanSlot};
use intfpqsim::serve::protocol::{
    codes, outputs_pool, parse_request_streaming, summarize, summarize_into, Request,
    Response,
};
use intfpqsim::tensor::Tensor;

/// Counts every heap acquisition (alloc, alloc_zeroed, realloc) and
/// delegates to the system allocator.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn hot_path_makes_zero_steady_state_allocations() {
    // a request exercising every field, including a 64-token prompt
    let req = Request {
        id: 12345,
        model: "sim-opt-125m".to_string(),
        quant: "abfp_w4a4_n64".to_string(),
        batch_index: 3,
        deadline_ms: Some(250),
        tokens: Some((0..64).collect()),
    };
    let mut line = Vec::new();
    req.write_line(&mut line);
    let text = line.clone();

    // the session outputs a worker summarizes per request: a 2x3
    // tensor with non-integer values (the float Display path must not
    // heap), summarized into a pool-recycled Response::outputs vector
    // exactly the way `serve::dispatch` does it
    let outs = [Tensor::new(vec![2, 3], vec![1.0, 2.5, 3.0, 4.25, 5.0, 6.0])];
    let reference = Response::ok(12345, summarize(&outs), 4, 0.3125, 1.0625);

    let mut scratch = Request::default();
    let mut wbuf: Vec<u8> = Vec::new();
    let mut rbuf: Vec<u8> = Vec::new();

    // the failure-domain error responses, rebuilt in place each round
    // the way a fault-injected server would emit them
    let quarantine_msg = "worker panicked executing this request; request quarantined";
    let drain_msg = "server draining: no new work accepted";
    let mut err_resp = Response::err(0, codes::INTERNAL_ERROR, quarantine_msg);
    let mut ebuf: Vec<u8> = Vec::new();

    // warm-up: scratch strings/token vec, both buffers and the pooled
    // summary vector reach their high-water capacity (and we prove
    // correctness while we're here)
    for _ in 0..32 {
        parse_request_streaming(&text, &mut scratch).unwrap();
        assert_eq!(scratch, req);
        req.write_line(&mut wbuf);
        assert_eq!(wbuf, text);
        let mut sums = outputs_pool::take();
        summarize_into(&outs, &mut sums);
        assert_eq!(sums, reference.outputs, "summarize_into must match summarize");
        let mut resp = Response::ok(scratch.id, sums, 4, 0.3125, 1.0625);
        resp.write_line(&mut rbuf);
        outputs_pool::put(std::mem::take(&mut resp.outputs));
        // warm (and verify) the in-place error-response refill for both
        // failure-domain codes
        err_resp.err_into(scratch.id, codes::INTERNAL_ERROR, quarantine_msg);
        assert_eq!(
            err_resp.line(),
            Response::err(scratch.id, codes::INTERNAL_ERROR, quarantine_msg).line(),
            "err_into must be byte-equivalent to a fresh Response::err"
        );
        err_resp.write_line(&mut ebuf);
        err_resp.err_into(scratch.id, codes::SHUTTING_DOWN, drain_msg);
        assert_eq!(
            err_resp.line(),
            Response::err(scratch.id, codes::SHUTTING_DOWN, drain_msg).line(),
        );
        err_resp.write_line(&mut ebuf);
        // warm the metrics path too (thread-local trace slot included)
        metrics::admitted();
        metrics::queue_wait(1);
        let _trace = metrics::trace(SpanSlot::Forward);
        drop(intfpqsim::util::timer::Scope::new("proto_alloc.forward"));
    }
    assert_eq!(
        rbuf,
        reference.line().as_bytes(),
        "reused-buffer serializer must match dump"
    );

    let before = ALLOCS.load(Ordering::Relaxed);
    for i in 0..10_000u64 {
        parse_request_streaming(std::hint::black_box(&text), &mut scratch).unwrap();
        if scratch.id != req.id {
            panic!("parse corrupted at iteration {}", i);
        }
        req.write_line(&mut wbuf);
        let mut sums = outputs_pool::take();
        summarize_into(std::hint::black_box(&outs), &mut sums);
        let mut resp = Response::ok(scratch.id, sums, 4, 0.3125, 1.0625);
        resp.write_line(&mut rbuf);
        outputs_pool::put(std::mem::take(&mut resp.outputs));
        // the failure-domain responses: quarantine + drain rejection
        // rebuilt in place, serialized into the reused buffer
        err_resp.err_into(scratch.id, codes::INTERNAL_ERROR, quarantine_msg);
        err_resp.write_line(&mut ebuf);
        err_resp.err_into(scratch.id, codes::SHUTTING_DOWN, drain_msg);
        err_resp.write_line(&mut ebuf);
        // the full per-request metrics footprint, exactly as the serve
        // path records it — must be allocation-free with metrics on
        metrics::admitted();
        metrics::batch_dispatched((i % 4) as usize, 4);
        metrics::request_ok((i % 4) as usize);
        metrics::cache_hit((i % 4) as usize);
        metrics::queue_wait(i);
        metrics::record_span(SpanSlot::Admit, i);
        metrics::record_span(SpanSlot::Assemble, i * 2);
        metrics::record_span(SpanSlot::Serialize, i * 3);
        // the supervision/lifecycle counters are plain atomics and must
        // stay allocation-free too
        metrics::panic_recovered();
        metrics::quarantined();
        metrics::conn_reaped();
        {
            let _trace = metrics::trace(SpanSlot::Forward);
            let _scope = intfpqsim::util::timer::Scope::new("proto_alloc.forward");
        }
        std::hint::black_box((&scratch, &wbuf, &rbuf, &ebuf));
    }
    let delta = ALLOCS.load(Ordering::Relaxed) - before;
    assert_eq!(
        delta, 0,
        "wire hot path allocated {} times across 10000 requests; \
         the steady state must be allocation-free",
        delta
    );
}
