//! Integration: load a real AOT artifact, bind weights, execute, check
//! the numbers make sense (random-init LM => NLL/token ~ ln(vocab)).

use std::collections::BTreeMap;

use intfpqsim::corpus::TextCorpus;
use intfpqsim::model;
use intfpqsim::runtime::{Runtime, Val};

fn artifacts_dir() -> Option<String> {
    let p = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(p).join("manifest.json").exists() {
        Some(p.to_string())
    } else {
        eprintln!("artifacts not built; skipping");
        None
    }
}

#[test]
fn eval_fp32_runs_and_matches_uniform_nll() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let cfg = rt.manifest.model("sim-opt-125m").unwrap().clone();
    let params = model::init_params(&cfg, 1);
    let sticky = model::param_vals(&cfg, &params).unwrap();
    let sess = rt.session("sim-opt-125m/eval_fp32", &sticky).unwrap();
    assert_eq!(sess.free_inputs(), vec!["tokens"]);

    let corpus = TextCorpus::new(99);
    let batch = corpus.eval_batch(0, cfg.batch, cfg.seq);
    let out = sess
        .run(&[Val::I32(batch.tokens.clone(), vec![cfg.batch, cfg.seq])])
        .unwrap();
    assert_eq!(out.len(), 1);
    let nll = out[0].data[0] as f64;
    let per_tok = nll / (cfg.batch * (cfg.seq - 1)) as f64;
    let uniform = (cfg.vocab as f64).ln();
    assert!(
        (per_tok - uniform).abs() < 0.7,
        "per-token NLL {} vs uniform {}",
        per_tok,
        uniform
    );
}

#[test]
fn quantized_artifact_close_to_fp32_with_int8() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let cfg = rt.manifest.model("sim-opt-125m").unwrap().clone();
    let params = model::init_params(&cfg, 2);
    let mut sticky = model::param_vals(&cfg, &params).unwrap();
    // smoothing = identity
    for s in &cfg.sites {
        sticky.insert(
            format!("smooth.{}", s.name),
            Val::F32(vec![1.0; s.dim], vec![s.dim]),
        );
    }
    let corpus = TextCorpus::new(99);
    let batch = corpus.eval_batch(1, cfg.batch, cfg.seq);
    let toks = Val::I32(batch.tokens.clone(), vec![cfg.batch, cfg.seq]);

    let base_sticky: BTreeMap<String, Val> = model::param_vals(&cfg, &params).unwrap();
    let fp = rt
        .session("sim-opt-125m/eval_fp32", &base_sticky)
        .unwrap()
        .run(&[toks.clone()])
        .unwrap()[0]
        .data[0];
    let q = rt
        .session("sim-opt-125m/eval_abfp_w4a8_n64", &sticky)
        .unwrap()
        .run(&[toks])
        .unwrap()[0]
        .data[0];
    let rel = ((q - fp) / fp).abs();
    assert!(rel < 0.3, "w4a8 nll {} vs fp32 {} (rel {})", q, fp, rel);
    assert!(q != fp, "quantized artifact must differ from fp32");
}

#[test]
fn session_rejects_wrong_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let cfg = rt.manifest.model("sim-opt-125m").unwrap().clone();
    let params = model::init_params(&cfg, 3);
    let sticky = model::param_vals(&cfg, &params).unwrap();
    let sess = rt.session("sim-opt-125m/eval_fp32", &sticky).unwrap();
    // wrong token shape
    assert!(sess.run(&[Val::I32(vec![0; 8], vec![2, 4])]).is_err());
    // wrong dtype
    assert!(sess
        .run(&[Val::F32(vec![0.0; cfg.batch * cfg.seq], vec![cfg.batch, cfg.seq])])
        .is_err());
}
