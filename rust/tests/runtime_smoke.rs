//! Integration: open real artifact sessions on the **native executor**
//! and check the numbers make sense. These tests need no on-disk
//! artifacts (the manifest is synthesized from the registry mirror) and
//! therefore ALWAYS run — a skip here would hide a broken simulator, so
//! there is deliberately no artifacts-gating. The one PJRT-only test at
//! the bottom is `#[ignore]`d until real `xla` bindings are vendored.

use std::collections::BTreeMap;

use intfpqsim::corpus::TextCorpus;
use intfpqsim::model;
use intfpqsim::runtime::{executor, Runtime, Val};

/// The repo-relative artifacts dir; absent in CI, so `Runtime::new`
/// synthesizes the manifest for the (default) native executor.
const ARTIFACTS: &str = "artifacts";

#[test]
fn native_is_the_default_executor() {
    let rt = Runtime::new(ARTIFACTS).unwrap();
    // INTFPQSIM_EXECUTOR is unset in CI; `auto` must mean native, and
    // the synthesized manifest must cover the full model matrix.
    if std::env::var("INTFPQSIM_EXECUTOR").is_err() {
        assert_eq!(rt.executor_name(), "native");
    }
    assert_eq!(rt.manifest.models.len(), 10);
    assert!(rt.manifest.artifacts.contains_key("sim-opt-125m/eval_fp32"));
}

#[test]
fn eval_fp32_runs_and_matches_uniform_nll() {
    let rt = Runtime::new(ARTIFACTS).unwrap();
    let cfg = rt.manifest.model("sim-opt-125m").unwrap().clone();
    let params = model::init_params(&cfg, 1);
    let sticky = model::param_vals(&cfg, &params).unwrap();
    let sess = rt.session("sim-opt-125m/eval_fp32", &sticky).unwrap();
    assert_eq!(sess.free_inputs(), vec!["tokens"]);

    let corpus = TextCorpus::new(99);
    let batch = corpus.eval_batch(0, cfg.batch, cfg.seq);
    let out = sess
        .run(&[Val::I32(batch.tokens.clone(), vec![cfg.batch, cfg.seq])])
        .unwrap();
    assert_eq!(out.len(), 1);
    let nll = out[0].data[0] as f64;
    let per_tok = nll / (cfg.batch * (cfg.seq - 1)) as f64;
    let uniform = (cfg.vocab as f64).ln();
    assert!(
        (per_tok - uniform).abs() < 0.7,
        "per-token NLL {} vs uniform {}",
        per_tok,
        uniform
    );
}

#[test]
fn quantized_artifact_close_to_fp32_with_int8() {
    let rt = Runtime::new(ARTIFACTS).unwrap();
    let cfg = rt.manifest.model("sim-opt-125m").unwrap().clone();
    let params = model::init_params(&cfg, 2);
    let mut sticky = model::param_vals(&cfg, &params).unwrap();
    // smoothing = identity
    for s in &cfg.sites {
        sticky.insert(
            format!("smooth.{}", s.name),
            Val::F32(vec![1.0; s.dim], vec![s.dim]),
        );
    }
    let corpus = TextCorpus::new(99);
    let batch = corpus.eval_batch(1, cfg.batch, cfg.seq);
    let toks = Val::I32(batch.tokens.clone(), vec![cfg.batch, cfg.seq]);

    let base_sticky: BTreeMap<String, Val> = model::param_vals(&cfg, &params).unwrap();
    let fp = rt
        .session("sim-opt-125m/eval_fp32", &base_sticky)
        .unwrap()
        .run(&[toks.clone()])
        .unwrap()[0]
        .data[0];
    let q = rt
        .session("sim-opt-125m/eval_abfp_w4a8_n64", &sticky)
        .unwrap()
        .run(&[toks])
        .unwrap()[0]
        .data[0];
    let rel = ((q - fp) / fp).abs();
    assert!(rel < 0.3, "w4a8 nll {} vs fp32 {} (rel {})", q, fp, rel);
    assert!(q != fp, "quantized artifact must differ from fp32");
}

#[test]
fn session_rejects_wrong_shapes() {
    let rt = Runtime::new(ARTIFACTS).unwrap();
    let cfg = rt.manifest.model("sim-opt-125m").unwrap().clone();
    let params = model::init_params(&cfg, 3);
    let sticky = model::param_vals(&cfg, &params).unwrap();
    let sess = rt.session("sim-opt-125m/eval_fp32", &sticky).unwrap();
    // wrong token shape
    assert!(sess.run(&[Val::I32(vec![0; 8], vec![2, 4])]).is_err());
    // wrong dtype
    assert!(sess
        .run(&[Val::F32(vec![0.0; cfg.batch * cfg.seq], vec![cfg.batch, cfg.seq])])
        .is_err());
}

#[test]
fn repeated_runs_reuse_prepared_weights_and_are_deterministic() {
    // The native session converts/QDQs sticky weights once; repeated
    // runs must be bit-identical and rebinding must invalidate.
    let rt = Runtime::new(ARTIFACTS).unwrap();
    let cfg = rt.manifest.model("sim-opt-125m").unwrap().clone();
    let params = model::init_params(&cfg, 4);
    let sticky = model::param_vals(&cfg, &params).unwrap();
    let mut sess = rt.session("sim-opt-125m/eval_fp32", &sticky).unwrap();
    let corpus = TextCorpus::new(7);
    let batch = corpus.eval_batch(2, cfg.batch, cfg.seq);
    let toks = Val::I32(batch.tokens.clone(), vec![cfg.batch, cfg.seq]);
    let a = sess.run(std::slice::from_ref(&toks)).unwrap()[0].data[0];
    let b = sess.run(std::slice::from_ref(&toks)).unwrap()[0].data[0];
    assert_eq!(a.to_bits(), b.to_bits(), "prepared eval must be deterministic");

    // rebind different weights -> different NLL
    let params2 = model::init_params(&cfg, 5);
    sess.rebind("tok_emb", &Val::from_tensor(params2.get("tok_emb").unwrap()))
        .unwrap();
    let c = sess.run(std::slice::from_ref(&toks)).unwrap()[0].data[0];
    assert_ne!(a.to_bits(), c.to_bits(), "rebind must take effect");
    // free inputs cannot be rebound
    assert!(sess.rebind("tokens", &toks).is_err());
}

#[test]
fn capture_artifact_emits_every_site() {
    let rt = Runtime::new(ARTIFACTS).unwrap();
    let cfg = rt.manifest.model("sim-opt-125m").unwrap().clone();
    let params = model::init_params(&cfg, 6);
    let sticky = model::param_vals(&cfg, &params).unwrap();
    let sess = rt.session("sim-opt-125m/capture_fp32", &sticky).unwrap();
    let corpus = TextCorpus::new(3);
    let batch = corpus.eval_batch(0, cfg.batch, cfg.seq);
    let out = sess
        .run(&[Val::I32(batch.tokens, vec![cfg.batch, cfg.seq])])
        .unwrap();
    assert_eq!(out.len(), cfg.sites.len() + 1, "sites + _anchor");
    for (t, site) in out.iter().zip(cfg.sites.iter()) {
        assert_eq!(t.shape, vec![cfg.batch * cfg.seq, site.dim], "{}", site.name);
        assert!(t.absmax() > 0.0, "{} captured all zeros", site.name);
    }
}

#[test]
fn run_batch_splits_logits_artifacts_per_request() {
    // The coalesced eval path on an `eval_logits` artifact (codegen):
    // per-request logit tensors must match sequential runs exactly, and
    // the manifest output shape must hold per request.
    let rt = Runtime::new(ARTIFACTS).unwrap();
    let cfg = rt.manifest.model("sim-codegen-2b").unwrap().clone();
    let params = model::init_params(&cfg, 7);
    let sticky = model::param_vals(&cfg, &params).unwrap();
    let sess = rt.session("sim-codegen-2b/eval_logits_fp32", &sticky).unwrap();
    let corpus = intfpqsim::corpus::CodeCorpus::new(intfpqsim::corpus::CODE_SEED);
    let frees: Vec<Vec<Val>> = (0..2)
        .map(|i| {
            vec![Val::I32(
                corpus.train_batch(i, cfg.batch, cfg.seq).tokens,
                vec![cfg.batch, cfg.seq],
            )]
        })
        .collect();
    let batched = sess.run_batch(&frees).unwrap();
    assert_eq!(batched.len(), 2);
    for (i, free) in frees.iter().enumerate() {
        let seq = sess.run(free).unwrap();
        assert_eq!(batched[i].len(), 1);
        assert_eq!(batched[i][0].shape, vec![cfg.batch, cfg.seq, cfg.vocab]);
        assert_eq!(
            batched[i][0].data, seq[0].data,
            "request {} batched vs sequential",
            i
        );
    }
    // an empty batch is a no-op, not an error
    assert!(sess.run_batch(&[]).unwrap().is_empty());
}

#[test]
fn int_compute_mode_matches_qdq_bits_on_exact_w8a8_cell() {
    // Tentpole (ISSUE 8): on a static-int W8A8 cell engineered so every
    // f32 rounding in the QDQ simulation is exact — per-tensor
    // activation clip alpha = 127 makes the activation scale exactly
    // 127/127 = 1.0, and each weight row is normalized to absmax
    // exactly 127.0 so every per-channel-max scale is exactly 1.0 —
    // the true i8×i8→i32 compute path must reproduce the QDQ path's
    // NLL bit for bit through a full native eval forward (with d = 128
    // the worst-case partial integer sum, 4d·127², stays inside f32's
    // 24 significand bits, so the f32 dot fold is itself exact).
    //
    // The compute mode is a process global; flipping it here is safe
    // because every other session this binary opens is fp32 or ABFP —
    // wirings the int path is ineligible for, which take the QDQ branch
    // under either mode. The guard restores the entry mode on any exit.
    use intfpqsim::model::net::{self, ComputeMode};

    struct Restore(ComputeMode);
    impl Drop for Restore {
        fn drop(&mut self) {
            net::set_compute_mode(self.0);
        }
    }

    let rt = Runtime::new(ARTIFACTS).unwrap();
    let cfg = rt.manifest.model("sim-opt-125m").unwrap().clone();
    let mut params = model::init_params(&cfg, 8);
    for site in &cfg.sites {
        let wname = intfpqsim::methods::site_weight_param(&site.name).unwrap();
        let mut w = params.get(&wname).unwrap().clone();
        let (rows, k) = (w.shape[0], w.shape[1]);
        for r in 0..rows {
            let row = &mut w.data[r * k..(r + 1) * k];
            let a = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            if a > 0.0 {
                // v/a has max element exactly ±1.0; ×127.0 is exact on
                // ±1.0, so the row absmax lands on exactly 127.0
                for v in row.iter_mut() {
                    *v = (*v / a) * 127.0;
                }
            }
        }
        params.insert(&wname, w);
    }
    let mut sticky = model::param_vals(&cfg, &params).unwrap();
    for s in &cfg.sites {
        sticky.insert(format!("alpha.{}", s.name), Val::F32(vec![127.0], vec![]));
    }
    let sess = rt.session("sim-opt-125m/eval_mse_w8a8", &sticky).unwrap();
    let corpus = TextCorpus::new(99);
    let batch = corpus.eval_batch(3, cfg.batch, cfg.seq);
    let toks = Val::I32(batch.tokens, vec![cfg.batch, cfg.seq]);

    let _restore = Restore(net::set_compute_mode(ComputeMode::Qdq));
    let qdq = sess.run(std::slice::from_ref(&toks)).unwrap()[0].data[0];
    net::set_compute_mode(ComputeMode::IntKernel);
    let int = sess.run(std::slice::from_ref(&toks)).unwrap()[0].data[0];
    net::set_compute_mode(ComputeMode::Qdq);
    let back = sess.run(std::slice::from_ref(&toks)).unwrap()[0].data[0];
    assert!(qdq.is_finite(), "qdq NLL must be finite, got {}", qdq);
    assert_eq!(
        qdq.to_bits(),
        int.to_bits(),
        "int compute path NLL {} must bit-match the qdq path's {} on the exact cell",
        int,
        qdq
    );
    assert_eq!(
        qdq.to_bits(),
        back.to_bits(),
        "flipping the mode back must restore the qdq path exactly"
    );
}

#[test]
#[ignore] // PJRT-only: needs real `xla` bindings + `make artifacts`.
fn pjrt_executor_compiles_and_runs_artifacts() {
    // Drive the pjrt executor directly (no process-global configure, so
    // concurrently running native tests are unaffected). Under the
    // vendored stub the compile step reports "PJRT unavailable".
    use intfpqsim::runtime::executor::{ExecSession, Executor};
    use intfpqsim::runtime::manifest::Manifest;
    use std::path::Path;

    let pjrt = executor::select("pjrt").unwrap();
    assert_eq!(pjrt.name(), "pjrt");
    let manifest = Manifest::load(Path::new(ARTIFACTS)).unwrap();
    let cfg = manifest.model("sim-opt-125m").unwrap().clone();
    let params = model::init_params(&cfg, 1);
    let sticky = model::param_vals(&cfg, &params).unwrap();
    let spec = manifest.artifact("sim-opt-125m/eval_fp32").unwrap();
    let sess = pjrt.open(Path::new(ARTIFACTS), &manifest, spec, &sticky).unwrap();
    let corpus = TextCorpus::new(99);
    let batch = corpus.eval_batch(0, cfg.batch, cfg.seq);
    let toks = Val::I32(batch.tokens, vec![cfg.batch, cfg.seq]);
    let out = sess.run(&[&toks]).unwrap();
    assert_eq!(out.len(), 1);
}
