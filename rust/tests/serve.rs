//! Serving-subsystem integration tests: session-cache reuse (no re-QDQ),
//! queue backpressure, deadline expiry, and multi-client determinism
//! under different batching configurations.
//!
//! Like the other integration suites these run with no artifacts and no
//! PJRT — the native executor synthesizes the manifest, and weights are
//! pretrained briefly into throwaway checkpoint directories.
//!
//! The tests serialize on a file-local mutex: they observe the
//! process-global prepared-builds counter and drive multi-threaded
//! servers, so interleaving them would blur exactly the invariants under
//! test.

use std::sync::mpsc;
use std::sync::Mutex;
use std::time::Duration;

use intfpqsim::quantsim::Simulator;
use intfpqsim::runtime::native;
use intfpqsim::serve::cache::SessionCache;
use intfpqsim::serve::loadgen::{run_loadgen, LoadgenCfg};
use intfpqsim::serve::metrics;
use intfpqsim::serve::protocol::{Request, Response};
use intfpqsim::serve::queue::{AdmissionQueue, Job};
use intfpqsim::serve::{serve_loop, ServeCfg};
use intfpqsim::train::TrainOpts;

static SERIAL: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|p| p.into_inner())
}

fn tmp_sim(tag: &str) -> Simulator {
    let dir = std::env::temp_dir().join(format!("intfpqsim_serve_{}", tag));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let mut sim = Simulator::new("artifacts", dir.to_str().unwrap()).unwrap();
    sim.opts.eval_batches = 2;
    sim.opts.pretrain_opts = TrainOpts { steps: 25, log_every: 1000, ..Default::default() };
    sim
}

fn push_req(
    queue: &AdmissionQueue,
    req: Request,
) -> mpsc::Receiver<Response> {
    let (tx, rx) = mpsc::channel();
    queue.try_push(Job::new(req, tx)).map_err(|r| r.job.req.id).unwrap();
    rx
}

#[test]
fn session_cache_reuse_second_request_performs_no_requantize() {
    let _g = lock();
    let sim = tmp_sim("reuse");
    metrics::reset();
    let queue = AdmissionQueue::new(8);
    // two requests for the SAME (model, quant) key, forced into separate
    // micro-batches (max_batch 1) so the second goes through the cache
    let rx1 = push_req(&queue, Request::new(1, "sim-opt-125m", "fp32", 0));
    let rx2 = push_req(&queue, Request::new(2, "sim-opt-125m", "fp32", 1));
    queue.close();

    let cfg = ServeCfg {
        queue_cap: 8,
        batch_window: Duration::from_millis(1),
        max_batch: 1,
        ..ServeCfg::default()
    };
    let mut cache = SessionCache::new();
    let before = native::prepared_builds();
    let stats = serve_loop(&sim, &queue, &cfg, &mut cache);
    let built = native::prepared_builds() - before;

    assert_eq!(stats.batches, 2);
    assert_eq!(stats.ok, 2);
    assert_eq!(stats.errors, 0);
    let r1 = rx1.try_recv().unwrap();
    let r2 = rx2.try_recv().unwrap();
    assert!(r1.ok && r2.ok);
    // one session opened, one prepared-state build: the second request
    // re-used the QDQ-prepared weights instead of re-transforming them
    assert_eq!(cache.stats(), (1, 1), "(hits, misses)");
    assert_eq!(cache.len(), 1);
    assert_eq!(built, 1, "second request must not re-QDQ the weights");
    // different stream indices -> different NLL outputs
    assert_ne!(r1.outputs, r2.outputs);

    // the metrics registry saw exactly this traffic, nothing else
    let snap = metrics::snapshot();
    snap.check().unwrap();
    assert_eq!(snap.admitted, 2);
    assert_eq!(snap.rejected, 0);
    assert_eq!(snap.ok, 2);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.batches, 2);
    assert_eq!(snap.cache_hits, 1, "hits == requests − distinct keys");
    assert_eq!(snap.cache_misses, 1);
    assert_eq!(snap.prepared_builds, 1);
    assert_eq!(snap.queue_wait_us.count, 2, "one queue-wait sample per job");
    assert_eq!(snap.span_admit_ns.count, 2);
    assert_eq!(snap.span_assemble_ns.count, 2);
    assert_eq!(snap.span_forward_ns.count, 2, "one timed forward per batch");
    assert_eq!(snap.batch_size.count, 2);
    // single-worker serving lands everything in shard 0's cells
    let shard0 = snap.shards.iter().find(|s| s.shard == 0).unwrap();
    assert_eq!(shard0.ok, 2);
    assert_eq!(shard0.batches, 2);
}

#[test]
fn queue_backpressure_rejects_overflow_and_server_recovers() {
    let _g = lock();
    let sim = tmp_sim("backpressure");
    metrics::reset();
    let queue = AdmissionQueue::new(2);
    let rx1 = push_req(&queue, Request::new(1, "sim-opt-125m", "fp32", 0));
    let rx2 = push_req(&queue, Request::new(2, "sim-opt-125m", "fp32", 1));
    // the queue is full: admission must hand the job back (backpressure),
    // and the would-be submitter answers the client itself
    let (tx3, rx3) = mpsc::channel();
    let rejected = queue
        .try_push(Job::new(Request::new(3, "sim-opt-125m", "fp32", 2), tx3))
        .unwrap_err();
    assert_eq!(
        rejected.reason.code(),
        intfpqsim::serve::protocol::codes::QUEUE_FULL,
        "a full (not draining) queue rejects with the backpressure code"
    );
    rejected.job.reply(Response::err(
        rejected.job.req.id,
        rejected.reason.code(),
        rejected.reason.message(),
    ));
    queue.close();

    let cfg = ServeCfg::default();
    let mut cache = SessionCache::new();
    let stats = serve_loop(&sim, &queue, &cfg, &mut cache);
    assert_eq!(stats.ok, 2, "admitted requests still serve after overflow");
    assert!(rx1.try_recv().unwrap().ok);
    assert!(rx2.try_recv().unwrap().ok);
    let r3 = rx3.try_recv().unwrap();
    assert!(!r3.ok);
    assert!(r3.error.unwrap().contains("queue full"));

    let snap = metrics::snapshot();
    snap.check().unwrap();
    assert_eq!(snap.admitted, 2);
    assert_eq!(snap.rejected, 1, "the overflow rejection must be counted");
    assert_eq!(snap.ok, 2);
}

#[test]
fn deadline_expiry_yields_error_not_stale_output() {
    let _g = lock();
    let sim = tmp_sim("deadline");
    metrics::reset();
    let queue = AdmissionQueue::new(8);
    let mut expired = Request::new(1, "sim-opt-125m", "fp32", 0);
    expired.deadline_ms = Some(1);
    let rx_expired = push_req(&queue, expired);
    let mut live = Request::new(2, "sim-opt-125m", "fp32", 0);
    live.deadline_ms = Some(60_000);
    let rx_live = push_req(&queue, live);
    queue.close();
    // let the first deadline lapse while the jobs sit in the queue
    std::thread::sleep(Duration::from_millis(5));

    let cfg = ServeCfg::default();
    let mut cache = SessionCache::new();
    let stats = serve_loop(&sim, &queue, &cfg, &mut cache);
    let r1 = rx_expired.try_recv().unwrap();
    assert!(!r1.ok, "expired request must error");
    assert!(r1.error.unwrap().contains("deadline"));
    assert!(r1.outputs.is_empty(), "no stale output");
    let r2 = rx_live.try_recv().unwrap();
    assert!(r2.ok, "generous deadline is honored");
    assert_eq!(stats.ok, 1);
    assert_eq!(stats.expired, 1, "pre-dispatch expiry must be counted");

    let snap = metrics::snapshot();
    snap.check().unwrap();
    assert_eq!(snap.admitted, 2);
    assert_eq!(snap.expired, 1, "the queue-lapsed deadline lands in the registry");
    assert_eq!(snap.ok, 1);
}

#[test]
fn serve_errors_cleanly_on_unknown_model_and_quant() {
    let _g = lock();
    let sim = tmp_sim("unknown");
    let queue = AdmissionQueue::new(8);
    let rx_model = push_req(&queue, Request::new(1, "sim-opt-125b", "fp32", 0));
    let rx_quant = push_req(&queue, Request::new(2, "sim-opt-125m", "w2a2", 0));
    queue.close();
    let mut cache = SessionCache::new();
    let stats = serve_loop(&sim, &queue, &ServeCfg::default(), &mut cache);
    assert_eq!(stats.errors, 2);
    assert!(!rx_model.try_recv().unwrap().ok);
    assert!(!rx_quant.try_recv().unwrap().ok);
    assert!(cache.is_empty(), "failed opens are not cached");
}

#[test]
fn concurrent_clients_fixed_seeds_identical_outputs_regardless_of_batching() {
    let _g = lock();
    let sim = tmp_sim("determinism");
    let mix = vec![
        ("sim-opt-125m".to_string(), "fp32".to_string()),
        ("sim-opt-125m".to_string(), "abfp_w4a4_n64".to_string()),
    ];
    // A: batching effectively disabled; B: aggressive coalescing. The
    // request streams are identical (fixed seed), so every per-request
    // output must match bit-for-bit even though B's requests ride in
    // shared batched forwards in arbitrary groupings.
    let base = LoadgenCfg {
        clients: 3,
        requests_per_client: 3,
        mix,
        deadline_ms: None,
        seed: 7,
        prewarm: true,
        ..Default::default()
    };
    let run_a = run_loadgen(
        &sim,
        &LoadgenCfg {
            serve: ServeCfg {
                queue_cap: 64,
                batch_window: Duration::from_millis(1),
                max_batch: 1,
                ..ServeCfg::default()
            },
            ..base.clone()
        },
    )
    .unwrap();
    let run_b = run_loadgen(
        &sim,
        &LoadgenCfg {
            serve: ServeCfg {
                queue_cap: 64,
                batch_window: Duration::from_millis(30),
                max_batch: 8,
                ..ServeCfg::default()
            },
            ..base.clone()
        },
    )
    .unwrap();

    assert_eq!(run_a.errors, 0);
    assert_eq!(run_b.errors, 0);
    assert_eq!(run_a.responses.len(), 9);
    assert_eq!(run_b.responses.len(), 9);
    for (ra, rb) in run_a.responses.iter().zip(run_b.responses.iter()) {
        assert_eq!(ra.id, rb.id);
        assert!(ra.ok && rb.ok);
        assert_eq!(
            ra.outputs, rb.outputs,
            "request {}: batched output differs from unbatched",
            ra.id
        );
    }
}

#[test]
fn int_compute_mode_serves_identical_bytes_regardless_of_batching() {
    // Tentpole (ISSUE 8): with the true i8×i8→i32 compute path active,
    // serving a static-int W8A8 key must stay fully deterministic — the
    // same request stream produces byte-identical wire lines whether
    // requests ride alone or coalesced into shared batched forwards.
    // (Int-vs-QDQ *equality* is not asserted here: on MSE-calibrated
    // real weights the scales are arbitrary reals, the documented
    // few-ULP-tolerance regime. The engineered-exact cell lives in
    // runtime_smoke.rs.) The SERIAL mutex plus the restore guard keep
    // the process-global mode flip invisible to the other tests.
    let _g = lock();
    use intfpqsim::model::net::{self, ComputeMode};
    struct Restore(ComputeMode);
    impl Drop for Restore {
        fn drop(&mut self) {
            net::set_compute_mode(self.0);
        }
    }
    let _restore = Restore(net::set_compute_mode(ComputeMode::IntKernel));

    let sim = tmp_sim("intmode");
    let serve_bytes = |max_batch: usize, window_ms: u64| -> Vec<Vec<u8>> {
        let queue = AdmissionQueue::new(8);
        let rxs: Vec<_> = (0..3u64)
            .map(|i| push_req(&queue, Request::new(i, "sim-opt-125m", "mse_w8a8", i)))
            .collect();
        queue.close();
        let cfg = ServeCfg {
            queue_cap: 8,
            batch_window: Duration::from_millis(window_ms),
            max_batch,
            ..ServeCfg::default()
        };
        let mut cache = SessionCache::new();
        let stats = serve_loop(&sim, &queue, &cfg, &mut cache);
        assert_eq!(stats.ok, 3, "all int-mode requests must serve");
        rxs.into_iter()
            .map(|rx| {
                let mut resp = rx.try_recv().unwrap();
                assert!(resp.ok, "{:?}", resp.error);
                // wall-clock timings and batch occupancy legitimately
                // vary across batching configs; zero them so the byte
                // comparison pins exactly the payload (id, ok, outputs)
                resp.queue_ms = 0.0;
                resp.run_ms = 0.0;
                resp.batched = 0;
                let mut buf = Vec::new();
                resp.write_line(&mut buf);
                buf
            })
            .collect()
    };
    let solo = serve_bytes(1, 1);
    let coalesced = serve_bytes(8, 30);
    assert_eq!(
        solo, coalesced,
        "int-mode serve bytes must be batching-invariant"
    );
}

#[test]
fn loadgen_single_key_traffic_coalesces_above_occupancy_one() {
    let _g = lock();
    let sim = tmp_sim("occupancy");
    let cfg = LoadgenCfg {
        clients: 4,
        requests_per_client: 4,
        mix: vec![("sim-opt-125m".to_string(), "fp32".to_string())],
        deadline_ms: None,
        seed: 3,
        prewarm: true,
        serve: ServeCfg {
            queue_cap: 64,
            batch_window: Duration::from_millis(30),
            max_batch: 8,
            ..ServeCfg::default()
        },
        ..Default::default()
    };
    let report = run_loadgen(&sim, &cfg).unwrap();
    assert_eq!(report.errors, 0);
    assert_eq!(report.ok, 16);
    assert!(
        report.max_occupancy >= 2,
        "4 concurrent same-key clients must share at least one batch \
         (max occupancy {})",
        report.max_occupancy
    );
    assert!(report.toks_per_s > 0.0);
    assert!(report.p50_ms <= report.p95_ms && report.p95_ms <= report.p99_ms);

    // server-side truth rides on the report and matches the client view
    let server = report.server.as_ref().expect("in-process loadgen attaches server stats");
    assert_eq!(server.admitted, 16);
    assert_eq!(server.ok, 16);
    assert_eq!(server.errors, 0);
    assert_eq!(server.expired, 0);
    assert_eq!(
        server.cache_misses, 0,
        "the key was prewarmed off the clock: no session prepared mid-run"
    );
    assert_eq!(
        server.cache_hits, server.batches,
        "every dispatched batch hit the prewarmed session"
    );
    assert!(server.batches >= 1 && server.batches <= 16);
}
