//! Full-pipeline integration tests on the native executor: pretrain
//! (briefly) -> calibrate -> transform (SQ/GPTQ/RPTQ) -> evaluate, on
//! the smallest models, against a throwaway checkpoint directory.
//!
//! These tests run with NO on-disk artifacts and no PJRT — the native
//! executor synthesizes the manifest and evaluates host-side — so they
//! always execute (no silent skips; see runtime_smoke.rs).

use intfpqsim::calib;
use intfpqsim::methods::{gptq, rptq, smoothquant};
use intfpqsim::model;
use intfpqsim::quantsim::{Method, MetricKind, QuantConfig, Simulator};
use intfpqsim::train::{self, TrainOpts};

fn tmp_sim(tag: &str) -> Simulator {
    let dir = std::env::temp_dir().join(format!("intfpqsim_pipe_{}", tag));
    // fresh checkpoint dir: stale checkpoints from older code versions
    // must not leak into the assertions below
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let mut sim = Simulator::new("artifacts", dir.to_str().unwrap()).unwrap();
    sim.opts.eval_batches = 2;
    sim.opts.pass1_programs = 8;
    sim.opts.qat_opts = TrainOpts { steps: 3, peak_lr: 1e-4, warmup: 1, ..Default::default() };
    sim.opts.pretrain_opts =
        TrainOpts { steps: 25, log_every: 1000, ..Default::default() };
    sim
}

#[test]
fn training_reduces_loss_and_eval_runs() {
    let sim = tmp_sim("train");
    let cfg = sim.rt.manifest.model("sim-opt-125m").unwrap().clone();
    let init = model::init_params(&cfg, 5);
    let opts =
        TrainOpts { steps: 40, peak_lr: 3e-3, warmup: 5, log_every: 1000, ..Default::default() };
    let res = train::run_training(&sim.rt, "sim-opt-125m/train_fp32", init, &opts).unwrap();
    // smoothed loss must drop substantially from the uniform start
    let head: f32 = res.losses[..5].iter().sum::<f32>() / 5.0;
    let tail: f32 = res.losses[35..].iter().sum::<f32>() / 5.0;
    assert!(
        tail < head - 0.5,
        "loss did not improve: head {} tail {}",
        head,
        tail
    );
    sim.ck.save("sim-opt-125m", "fp32", &res.params).unwrap();
    let m = sim.evaluate("sim-opt-125m", &QuantConfig::fp32()).unwrap();
    assert_eq!(m.kind, MetricKind::Ppl);
    assert!(m.value > 1.0 && m.value < 520.0, "ppl {}", m.value);
}

#[test]
fn simulator_end_to_end_native_fp32_and_quantized() {
    // The acceptance path: Simulator::new(..).evaluate(..) with no
    // artifacts and no PJRT — pretraining, calibration and evaluation
    // all run on the native executor.
    let sim = tmp_sim("e2e");
    assert_eq!(sim.rt.executor_name(), "native");
    let fp = sim.evaluate("sim-opt-125m", &QuantConfig::fp32()).unwrap();
    assert_eq!(fp.kind, MetricKind::Ppl);
    assert!(
        fp.value.is_finite() && fp.value > 1.0 && fp.value < 520.0,
        "fp32 ppl {}",
        fp.value
    );
    // dynamic ABFP W4A4
    let q = sim
        .evaluate("sim-opt-125m", &QuantConfig::abfp("abfp_w4a4_n64"))
        .unwrap();
    assert!(q.value.is_finite() && q.value > 1.0, "w4a4 ppl {}", q.value);
    // static MSE-calibrated W4A8 (runs the capture + calibration path)
    let q8 = sim
        .evaluate("sim-opt-125m", &QuantConfig::abfp("mse_w4a8"))
        .unwrap();
    assert!(q8.value.is_finite() && q8.value > 1.0, "mse_w4a8 ppl {}", q8.value);
    // W4A8 with calibrated clips stays within 2x of FP32 perplexity on
    // the trained stand-in (the paper's qualitative Table-I shape).
    assert!(
        q8.value < 2.0 * fp.value,
        "mse_w4a8 ppl {} vs fp32 {}",
        q8.value,
        fp.value
    );
}

#[test]
fn calibrate_transform_evaluate_all_methods() {
    let sim = tmp_sim("methods");
    let cfg = sim.rt.manifest.model("sim-opt-125m").unwrap().clone();
    // brief pretrain so the activations have structure
    let init = model::init_params(&cfg, 6);
    let opts = TrainOpts { steps: 15, log_every: 1000, ..Default::default() };
    let res = train::run_training(&sim.rt, "sim-opt-125m/train_fp32", init, &opts).unwrap();
    sim.ck.save("sim-opt-125m", "fp32", &res.params).unwrap();

    // capture -> stats cover every site with the right dims
    let stats = sim.calibration("sim-opt-125m").unwrap();
    assert_eq!(stats.acts.len(), cfg.sites.len());
    for site in &cfg.sites {
        let t = &stats.acts[&site.name];
        assert_eq!(t.shape[1], site.dim);
        assert!(t.shape[0] >= 2048);
        assert!(t.absmax() > 0.0);
    }

    // MSE alphas are positive and below absmax
    let alphas = calib::mse_site_alphas(&stats, 4);
    for (site, a) in &alphas {
        assert!(*a > 0.0 && *a <= stats.absmax(site).unwrap() * 1.001, "{}", site);
    }

    // SmoothQuant transform keeps shapes and produces finite weights
    let base = sim.weights("sim-opt-125m").unwrap();
    let sm = smoothquant::apply(&cfg, &base, &stats).unwrap();
    for p in &cfg.params {
        let t = sm.params.get(&p.name).unwrap();
        assert_eq!(t.shape, p.shape);
        assert!(t.data.iter().all(|v| v.is_finite()), "{}", p.name);
    }

    // RPTQ per-site alpha vectors cover channel ranges
    let rv = rptq::site_alpha_vals(&cfg, &stats).unwrap();
    assert_eq!(rv.len(), cfg.sites.len());

    // GPTQ on one site reduces layer MSE vs nearest rounding
    let wname = "l0.wqkv";
    let w = base.get(wname).unwrap().clone();
    let x = stats.acts["l0.qkv"].clone();
    let mut w_rtn = w.clone();
    gptq::nearest_site(&mut w_rtn);
    let mut w_g = w.clone();
    gptq::gptq_site(&mut w_g, &x).unwrap();
    let mse_rtn = gptq::layer_mse(&x, &w, &w_rtn);
    let mse_g = gptq::layer_mse(&x, &w, &w_g);
    assert!(mse_g <= mse_rtn * 1.01, "gptq {} vs rtn {}", mse_g, mse_rtn);

    // every method end-to-end produces a finite PPL
    for qc in [
        QuantConfig::abfp("abfp_w4a4_n64"),
        QuantConfig::abfp("mse_w4a4"),
        QuantConfig::with("abfp_w4a4_n64", Method::SmoothQuant),
        QuantConfig::with("fp32", Method::Gptq),
        QuantConfig::with("rptq_w4a4", Method::Rptq),
        QuantConfig::with("abfp_w4a4_n64", Method::Qat),
    ] {
        let m = sim.evaluate("sim-opt-125m", &qc).unwrap();
        assert!(m.value.is_finite() && m.value > 1.0, "{:?} -> {}", qc, m.value);
    }
}

#[test]
fn non_lm_tasks_produce_metrics() {
    let sim = tmp_sim("tasks");
    for (model_name, lo, hi) in [
        ("sim-vit-16", 0.0, 100.0),
        ("sim-bert-base", 0.0, 100.0),
        ("sim-codegen-2b", 0.0, 100.0),
    ] {
        let cfg = sim.rt.manifest.model(model_name).unwrap().clone();
        let init = model::init_params(&cfg, 7);
        let opts = TrainOpts { steps: 8, log_every: 1000, ..Default::default() };
        let res = train::run_training(
            &sim.rt,
            &format!("{}/train_fp32", model_name),
            init,
            &opts,
        )
        .unwrap();
        sim.ck.save(model_name, "fp32", &res.params).unwrap();
        for q in ["fp32", "abfp_w4a8_n64"] {
            let m = sim.evaluate(model_name, &QuantConfig::abfp(q)).unwrap();
            assert!(
                (lo..=hi).contains(&m.value),
                "{} {} metric {}",
                model_name,
                q,
                m.value
            );
        }
    }
}
