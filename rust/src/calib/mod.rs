//! Calibration engine (paper §II-B-1 and §III).
//!
//! Drives the `capture_fp32` artifact to collect every quantized site's
//! raw input activations over a calibration stream, then derives:
//!   * static **MSE** clip ranges — the scale α minimizing the MSE
//!     between QDQ(x) and x (grid search over clip fractions, the
//!     TensorRT/[7] approach);
//!   * static **max** ranges (the simulator's static-max mode);
//!   * per-channel absmax ranges (SmoothQuant's difficulty migration and
//!     RPTQ's channel clustering both start from these).

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::formats::quant_mse;
use crate::model;
use crate::runtime::{Runtime, Val};
use crate::tensor::io::TensorStore;
use crate::tensor::Tensor;
use crate::train;

/// Calibration batches (train-split indices far from the training prefix
/// so QAT and calibration never share exact batches).
pub const CALIB_BATCHES: u64 = 4;
const CALIB_OFFSET: u64 = 1 << 20;

/// Per-site activation statistics from a capture run.
#[derive(Debug)]
pub struct CalibStats {
    /// site name -> concatenated raw activations (rows, din)
    pub acts: BTreeMap<String, Tensor>,
}

impl CalibStats {
    /// Per-channel absmax of a site's activations.
    pub fn channel_absmax(&self, site: &str) -> Result<Vec<f32>> {
        Ok(self.acts.get(site).context("site missing")?.col_absmax())
    }

    /// Whole-tensor absmax of a site's activations.
    pub fn absmax(&self, site: &str) -> Result<f32> {
        Ok(self.acts.get(site).context("site missing")?.absmax())
    }
}

/// Run the capture artifact over the calibration stream.
pub fn capture(rt: &Runtime, model_name: &str, params: &TensorStore) -> Result<CalibStats> {
    let cfg = rt.manifest.model(model_name)?.clone();
    let artifact = format!("{}/capture_fp32", model_name);
    let sticky = model::param_vals(&cfg, params)?;
    let sess = rt.session(&artifact, &sticky)?;
    let supplier = train::data_fn(&cfg, 0x0CA1_1B);

    let mut acts: BTreeMap<String, Vec<Tensor>> = BTreeMap::new();
    for i in 0..CALIB_BATCHES {
        let data = supplier(CALIB_OFFSET + i);
        let outs = sess.run(&data)?;
        for (out, ospec) in outs.into_iter().zip(sess.spec.outputs.iter()) {
            if ospec.name.starts_with('_') {
                continue; // _anchor: graph-liveness scalar, not a site
            }
            acts.entry(ospec.name.clone()).or_default().push(out);
        }
    }
    let mut merged = BTreeMap::new();
    for (site, parts) in acts {
        let cols = parts[0].shape[1];
        let rows: usize = parts.iter().map(|t| t.shape[0]).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for p in &parts {
            data.extend_from_slice(&p.data);
        }
        merged.insert(site, Tensor::new(vec![rows, cols], data));
    }
    Ok(CalibStats { acts: merged })
}

/// MSE-optimal clip range for integer quantization of `x` at `bits`.
///
/// Searches clip fractions α = f·absmax over a log-spaced grid (the MSE
/// objective is smooth and unimodal in practice; 48 candidates matches
/// the resolution TensorRT uses). Subsamples large tensors for speed.
pub fn mse_alpha(x: &[f32], bits: u32) -> f32 {
    let absmax = x.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
    if absmax == 0.0 {
        return 1.0;
    }
    // deterministic stride subsample to <= 32768 elements
    let stride = (x.len() / 32768).max(1);
    let sample: Vec<f32> = x.iter().step_by(stride).cloned().collect();
    let mut best = (f64::INFINITY, absmax);
    for i in 0..48 {
        // fractions from ~1.5% to 100% of absmax, log-spaced
        let f = (-4.2f32 + 4.2 * (i as f32 + 1.0) / 48.0).exp();
        let alpha = f * absmax;
        let mse = quant_mse(&sample, alpha, bits);
        if mse < best.0 {
            best = (mse, alpha);
        }
    }
    best.1
}

/// Static per-site MSE clip ranges for every quantized site.
///
/// Sites are independent, so the per-site grid searches fan out across
/// the active tensor backend's workers; results are keyed by site name
/// and each search is single-threaded internally, so the output is
/// identical for every backend. Under the `pool` backend the fan-out
/// reuses the persistent worker pool — no per-call thread spawn, which
/// is the win on this many-small-sites pattern (see the spawn-overhead
/// microbench in `bench_quant`).
pub fn mse_site_alphas(stats: &CalibStats, bits: u32) -> BTreeMap<String, f32> {
    let sites: Vec<(&String, &Tensor)> = stats.acts.iter().collect();
    let alphas = crate::tensor::backend::active()
        .par_map_f64(sites.len(), &|i| mse_alpha(&sites[i].1.data, bits) as f64);
    sites
        .iter()
        .zip(alphas)
        .map(|((site, _), a)| ((*site).clone(), a as f32))
        .collect()
}

/// Static per-site max clip ranges (the simulator's static-max mode).
pub fn max_site_alphas(stats: &CalibStats) -> BTreeMap<String, f32> {
    stats
        .acts
        .iter()
        .map(|(site, t)| {
            let a = t.absmax();
            (site.clone(), if a > 0.0 { a } else { 1.0 })
        })
        .collect()
}

/// Build the `alpha.<site>` sticky inputs for a static (MSE) artifact.
pub fn alpha_vals(alphas: &BTreeMap<String, f32>) -> BTreeMap<String, Val> {
    alphas
        .iter()
        .map(|(site, &a)| (format!("alpha.{}", site), Val::scalar(a)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg64;

    #[test]
    fn mse_alpha_clips_heavy_tails() {
        // Heavy-tailed activations: at 4 bits the MSE-optimal clip lands
        // strictly below the absmax (trading tail error for resolution on
        // the bulk — exactly why MSE calibration clips outliers, §IV-A-1);
        // at 8 bits the extra resolution lets the clip relax upward.
        let mut rng = Pcg64::new(1);
        let x: Vec<f32> = (0..8192)
            .map(|_| rng.gaussian() * rng.lognormal(1.5))
            .collect();
        let absmax = x.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        let a4 = mse_alpha(&x, 4);
        assert!(a4 < 0.8 * absmax, "a4 {} should clip below absmax {}", a4, absmax);
        let a8 = mse_alpha(&x, 8);
        assert!(a8 > a4, "a8 {} should exceed a4 {}", a8, a4);
    }

    #[test]
    fn mse_alpha_beats_max_on_mse() {
        prop::check("mse_beats_max", 10, |rng| {
            let x = prop::heavy_vec(rng, 2048, 1.0);
            let absmax = x.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            let a = mse_alpha(&x, 4);
            let mse_opt = quant_mse(&x, a, 4);
            let mse_max = quant_mse(&x, absmax, 4);
            crate::prop_assert!(
                mse_opt <= mse_max * 1.0001,
                "mse at alpha* {} > mse at absmax {}",
                mse_opt,
                mse_max
            );
            Ok(())
        });
    }

    #[test]
    fn mse_alpha_handles_degenerate() {
        assert_eq!(mse_alpha(&[0.0; 16], 4), 1.0);
        let a = mse_alpha(&[2.0; 16], 4);
        assert!(a > 0.5 && a <= 2.01);
    }
}
