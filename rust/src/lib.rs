//! INT-FP-QSim — a mixed-precision & mixed-format quantization simulator
//! for transformer models, reproduced as a three-layer Rust + JAX +
//! Pallas system (AOT via HLO text → PJRT).
//!
//! Layers:
//! * L1 (build-time Python): Pallas fake-quant kernels (`python/compile/kernels/`);
//! * L2 (build-time Python): JAX model families with quantizer-wrapped
//!   layers, lowered to `artifacts/*.hlo.txt`;
//! * L3 (this crate): the simulator product — runtime, calibration, PTQ
//!   methods (SmoothQuant/GPTQ/RPTQ), training drivers, experiment
//!   coordinator reproducing every table/figure of the paper.

pub mod util;
pub mod tensor;
pub mod formats;
pub mod corpus;
pub mod runtime;
pub mod model;
pub mod train;
pub mod eval;
pub mod calib;
pub mod methods;
pub mod quantsim;
pub mod coordinator;
