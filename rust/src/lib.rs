//! INT-FP-QSim — a mixed-precision & mixed-format quantization simulator
//! for transformer models, reproduced as a three-layer Rust + JAX +
//! Pallas system (AOT via HLO text → PJRT).
//!
//! Layers:
//! * L1 (build-time Python): Pallas fake-quant kernels (`python/compile/kernels/`);
//! * L2 (build-time Python): JAX model families with quantizer-wrapped
//!   layers, lowered to `artifacts/*.hlo.txt`;
//! * L3 (this crate): the simulator product — runtime (a native host
//!   executor plus the PJRT path behind one [`runtime::executor`] seam;
//!   `auto` = native, fully offline), calibration, PTQ methods
//!   (SmoothQuant/GPTQ/RPTQ), training drivers, experiment coordinator
//!   reproducing every table/figure of the paper, and a dynamic
//!   micro-batching inference server ([`serve`]: `repro serve` /
//!   `repro loadgen`) over prepared quantized sessions.
//!
//! Host-side tensor math (Hessian builds, weight transforms, metrics)
//! executes on a pluggable backend — scalar / cache-blocked / 4-lane
//! SIMD-unrolled / scoped-thread / persistent worker pool, see
//! [`tensor::backend`] — selected at runtime via `--backend`/`--threads`
//! or `INTFPQSIM_BACKEND`/`INTFPQSIM_THREADS`; every backend is held to
//! bit-equality with the scalar reference by the conformance harness in
//! `rust/tests/backend_conformance.rs`, and the same seam is where a
//! future PJRT-offload backend plugs in.

// The codebase predates clippy's impl-header lifetime elision lint;
// keeping explicit `impl<'a> T<'a>` headers is a deliberate style.
#![allow(clippy::needless_lifetimes)]

pub mod util;
pub mod tensor;
pub mod formats;
pub mod corpus;
pub mod runtime;
pub mod model;
pub mod train;
pub mod eval;
pub mod calib;
pub mod methods;
pub mod quantsim;
pub mod serve;
pub mod coordinator;
