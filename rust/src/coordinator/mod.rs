//! Experiment coordinator: one registered experiment per table/figure of
//! the paper, with dependency-aware caching (pretrain → calibrate →
//! transform → evaluate) and markdown/JSON report rendering.

pub mod experiments;
pub mod report;

use anyhow::Result;

use crate::quantsim::Simulator;
use report::Report;

pub struct Experiment {
    pub id: &'static str,
    pub paper_ref: &'static str,
    pub title: &'static str,
    /// The paper's qualitative claim this experiment checks (DESIGN.md §3).
    pub expected_shape: &'static str,
    pub run: fn(&Simulator) -> Result<Report>,
}

pub fn registry() -> Vec<Experiment> {
    experiments::all()
}

pub fn find(id: &str) -> Option<Experiment> {
    registry().into_iter().find(|e| e.id == id)
}

/// Run one experiment, save its report under `results/`, return it.
pub fn run_experiment(sim: &Simulator, id: &str) -> Result<Report> {
    let exp = find(id).ok_or_else(|| anyhow::anyhow!("unknown experiment {}", id))?;
    crate::info!("=== {} ({}) — {} ===", exp.id, exp.paper_ref, exp.title);
    let t0 = std::time::Instant::now();
    let mut rep = (exp.run)(sim)?;
    rep.meta.insert("id".into(), exp.id.into());
    rep.meta.insert("paper_ref".into(), exp.paper_ref.into());
    rep.meta.insert("title".into(), exp.title.into());
    rep.meta.insert("expected_shape".into(), exp.expected_shape.into());
    rep.meta
        .insert("wall_seconds".into(), format!("{:.1}", t0.elapsed().as_secs_f64()));
    rep.save("results")?;
    println!("{}", rep.render());
    Ok(rep)
}
