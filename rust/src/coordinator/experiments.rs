//! One registered experiment per table/figure of the paper (§IV).
//!
//! Absolute numbers cannot match the paper (scaled-down stand-in models,
//! synthetic corpora — DESIGN.md §1); each experiment's `expected_shape`
//! states the qualitative claim being reproduced, and EXPERIMENTS.md
//! records paper-vs-measured side by side.

use anyhow::Result;

use crate::quantsim::{Method, QuantConfig, Simulator};

use super::report::Report;
use super::Experiment;

const OPTS: [&str; 4] =
    ["sim-opt-125m", "sim-opt-350m", "sim-opt-1.3b", "sim-opt-2.7b"];

const ALL_MODELS: [&str; 10] = [
    "sim-opt-125m",
    "sim-opt-350m",
    "sim-opt-1.3b",
    "sim-opt-2.7b",
    "sim-codegen-2b",
    "sim-codegen-6b",
    "sim-bert-base",
    "sim-bert-large",
    "sim-vit-16",
    "sim-vit-32",
];

fn ev(sim: &Simulator, model: &str, qc: &QuantConfig) -> Result<f64> {
    Ok(sim.evaluate(model, qc)?.value)
}

/// Grid helper: one row per model, one metric column per config.
fn grid(
    sim: &Simulator,
    models: &[&str],
    configs: &[(&str, QuantConfig)],
) -> Result<Report> {
    let mut header = vec!["Model".to_string()];
    header.extend(configs.iter().map(|(n, _)| n.to_string()));
    let mut rep = Report::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    for m in models {
        let mut row = vec![m.to_string()];
        for (_, qc) in configs {
            row.push(Report::cell(Some(ev(sim, m, qc)?)));
        }
        rep.row(row);
    }
    Ok(rep)
}

fn q(name: &str) -> QuantConfig {
    QuantConfig::abfp(name)
}

fn qm(name: &str, m: Method) -> QuantConfig {
    QuantConfig::with(name, m)
}

// --- experiments -----------------------------------------------------------

fn fig1(sim: &Simulator) -> Result<Report> {
    // Relative performance vs FP32 at W4A4 ABFP n=64 across all models.
    let mut rep = Report::new(&["Model", "Task metric", "FP32", "W4A4 (ABFP)", "Relative"]);
    for m in ALL_MODELS {
        let fp = sim.evaluate(m, &QuantConfig::fp32())?;
        let qq = sim.evaluate(m, &q("abfp_w4a4_n64"))?;
        let rel = crate::quantsim::relative_to_fp32(qq, fp);
        rep.row(vec![
            m.into(),
            fp.kind.name().into(),
            Report::cell(Some(fp.value)),
            Report::cell(Some(qq.value)),
            format!("{:.3}", rel),
        ]);
    }
    Ok(rep)
}

fn table1(sim: &Simulator) -> Result<Report> {
    grid(
        sim,
        &OPTS[..2],
        &[
            ("FP32", QuantConfig::fp32()),
            ("MSE (W4A4)", q("mse_w4a4")),
            ("ABFP (W4A4 n=64)", q("abfp_w4a4_n64")),
        ],
    )
}

fn table2(sim: &Simulator) -> Result<Report> {
    grid(
        sim,
        &OPTS,
        &[
            ("FP32", QuantConfig::fp32()),
            ("W4A4 (INT)", q("abfp_w4a4_n64")),
            ("E2M1", q("abfp_e2m1_n64")),
            ("E1M2", q("abfp_e1m2_n64")),
        ],
    )
}

fn fig3(sim: &Simulator) -> Result<Report> {
    grid(
        sim,
        &OPTS,
        &[
            ("FP32", QuantConfig::fp32()),
            ("E1M2 n=64", q("abfp_e1m2_n64")),
            ("E1M2 n=128", q("abfp_e1m2_n128")),
        ],
    )
}

fn table3(sim: &Simulator) -> Result<Report> {
    grid(
        sim,
        &OPTS,
        &[
            ("FP32", QuantConfig::fp32()),
            ("ABFP", q("abfp_w4a4_n64")),
            ("ABFP-QAT", qm("abfp_w4a4_n64", Method::Qat)),
            ("ABFP-SQ", qm("abfp_w4a4_n64", Method::SmoothQuant)),
        ],
    )
}

fn fig4(sim: &Simulator) -> Result<Report> {
    grid(
        sim,
        &OPTS,
        &[
            ("FP32", QuantConfig::fp32()),
            ("ABFP n=64", q("abfp_w4a4_n64")),
            ("ABFP n=128", q("abfp_w4a4_n128")),
            ("QAT n=64", qm("abfp_w4a4_n64", Method::Qat)),
            ("QAT n=128", qm("abfp_w4a4_n128", Method::Qat)),
        ],
    )
}

fn table4(sim: &Simulator) -> Result<Report> {
    grid(
        sim,
        &OPTS,
        &[
            ("FP32", QuantConfig::fp32()),
            ("MSE (W4A8)", q("mse_w4a8")),
            ("ABFP (W4A8 n=64)", q("abfp_w4a8_n64")),
        ],
    )
}

fn table5(sim: &Simulator) -> Result<Report> {
    grid(
        sim,
        &OPTS,
        &[
            ("FP32", QuantConfig::fp32()),
            ("W4-AE4M3 ABFP", q("abfp_w4ae4m3_n64")),
            ("W4-AE4M3 ABFP-SQ", qm("abfp_w4ae4m3_n64", Method::SmoothQuant)),
            ("GPTQ (W4A16)", qm("fp32", Method::Gptq)),
        ],
    )
}

fn table6(sim: &Simulator) -> Result<Report> {
    grid(
        sim,
        &OPTS,
        &[
            ("AE4M3 ABFP", q("abfp_w4ae4m3_n64")),
            ("AE4M3 ABFP-SQ", qm("abfp_w4ae4m3_n64", Method::SmoothQuant)),
            ("A8 ABFP", q("abfp_w4a8_n64")),
            ("A8 ABFP-SQ", qm("abfp_w4a8_n64", Method::SmoothQuant)),
        ],
    )
}

fn table7(sim: &Simulator) -> Result<Report> {
    grid(
        sim,
        &OPTS,
        &[
            ("ABFP (W4A8)", q("abfp_w4a8_n64")),
            ("ABFP-QAT", qm("abfp_w4a8_n64", Method::Qat)),
            ("ABFP-SQ", qm("abfp_w4a8_n64", Method::SmoothQuant)),
            ("GPTQ (W4A16)", qm("fp32", Method::Gptq)),
        ],
    )
}

fn fig5(sim: &Simulator) -> Result<Report> {
    grid(
        sim,
        &OPTS,
        &[
            ("FP32", QuantConfig::fp32()),
            ("ABFP n=64", q("abfp_w4a8_n64")),
            ("ABFP n=128", q("abfp_w4a8_n128")),
            ("QAT n=64", qm("abfp_w4a8_n64", Method::Qat)),
            ("QAT n=128", qm("abfp_w4a8_n128", Method::Qat)),
        ],
    )
}

fn table8(sim: &Simulator) -> Result<Report> {
    // The paper's RPTQ repo lacks OPT 350M/2.7B support; our RPTQ covers
    // all sizes, so the table is complete rather than dashed.
    grid(
        sim,
        &OPTS,
        &[
            ("FP32", QuantConfig::fp32()),
            ("RPTQ W4A4", qm("rptq_w4a4", Method::Rptq)),
            ("ABFP W4A4", q("abfp_w4a4_n64")),
            ("RPTQ W4A8", qm("rptq_w4a8", Method::Rptq)),
            ("ABFP W4A8", q("abfp_w4a8_n64")),
        ],
    )
}

fn table10(sim: &Simulator) -> Result<Report> {
    let mut rep = Report::new(&["Model", "Metric", "FP32", "ABFP W4A4", "ABFP W4A8"]);
    for m in ALL_MODELS {
        let fp = sim.evaluate(m, &QuantConfig::fp32())?;
        let a4 = sim.evaluate(m, &q("abfp_w4a4_n64"))?;
        let a8 = sim.evaluate(m, &q("abfp_w4a8_n64"))?;
        rep.row(vec![
            m.into(),
            fp.kind.name().into(),
            Report::cell(Some(fp.value)),
            Report::cell(Some(a4.value)),
            Report::cell(Some(a8.value)),
        ]);
    }
    Ok(rep)
}

fn table9(sim: &Simulator) -> Result<Report> {
    // The model/task/dataset catalog (informational).
    let mut rep =
        Report::new(&["Model", "Stands for", "Task", "Dataset (stand-in)", "Metric"]);
    for m in ALL_MODELS {
        let cfg = sim.rt.manifest.model(m)?;
        let (dataset, metric) = match cfg.task.as_str() {
            "lm" => ("Zipf-Markov text (Wikitext2)", "PPL"),
            "codegen" => ("expr grammar (HumanEval)", "Pass@1"),
            "span_qa" => ("marker-span QA (SQuAD v1.1)", "F1"),
            _ => ("Gaussian blobs (ImageNet)", "Accuracy"),
        };
        rep.row(vec![
            m.into(),
            cfg.stands_for.clone(),
            cfg.task.clone(),
            dataset.into(),
            metric.into(),
        ]);
    }
    Ok(rep)
}

// --- extension ablations (DESIGN.md §Extensions; not paper tables) ---------

/// Models the extension artifacts are lowered for (registry
/// ABLATION_MODELS): one small + one large OPT stand-in.
const ABL_MODELS: [&str; 2] = ["sim-opt-125m", "sim-opt-1.3b"];

fn abl_scales(sim: &Simulator) -> Result<Report> {
    // Two-level scale quantization (VS-Quant): same payload formats as
    // ABFP, scales stored as 8-bit codes + per-row BF16. The paper defers
    // this (§II-B-2, §IV-C "storage overhead of the scales ... mitigated
    // through a second-order quantization"); we measure the PPL cost.
    let mut rep = grid(
        sim,
        &ABL_MODELS,
        &[
            ("FP32", QuantConfig::fp32()),
            ("ABFP W4A4", q("abfp_w4a4_n64")),
            ("ABFP2 W4A4", q("abfp2_w4a4_n64")),
            ("ABFP W4A8", q("abfp_w4a8_n64")),
            ("ABFP2 W4A8", q("abfp2_w4a8_n64")),
        ],
    )?;
    // Scale storage per payload element (d_ff rows are the widest case).
    for m in ABL_MODELS {
        let k = 4 * sim.rt.manifest.model(m)?.d;
        rep.meta.insert(
            format!("scale_bits_per_elt.{}", m),
            format!(
                "abfp={:.4} abfp2={:.4}",
                crate::formats::scale_overhead_bits(k, 64, None),
                crate::formats::scale_overhead_bits(k, 64, Some(8)),
            ),
        );
    }
    Ok(rep)
}

fn abl_outq(sim: &Simulator) -> Result<Report> {
    // Output quantization f_q^y (Eqn 9) — the photonics-hardware case the
    // simulator supports but every paper experiment disables.
    grid(
        sim,
        &ABL_MODELS,
        &[
            ("FP32", QuantConfig::fp32()),
            ("W4A4 (y fp32)", q("abfp_w4a4_n64")),
            ("W4A4 yINT8", q("abfp_w4a4_o8_n64")),
            ("W4A4 yE4M3", q("abfp_w4a4_oe4m3_n64")),
            ("W4A8 (y fp32)", q("abfp_w4a8_n64")),
            ("W4A8 yINT8", q("abfp_w4a8_o8_n64")),
        ],
    )
}

fn abl_mixed(sim: &Simulator) -> Result<Report> {
    // Per-layer mixed precision (§VI future work): boundary blocks at
    // higher precision, interior at W4A4.
    grid(
        sim,
        &ABL_MODELS,
        &[
            ("FP32", QuantConfig::fp32()),
            ("uniform W4A4", q("abfp_w4a4_n64")),
            ("boundary A8", q("mixed_a8_boundary_n64")),
            ("boundary W8A8", q("mixed_w8a8_boundary_n64")),
            ("uniform W4A8", q("abfp_w4a8_n64")),
        ],
    )
}

pub fn all() -> Vec<Experiment> {
    vec![
        Experiment {
            id: "fig1",
            paper_ref: "Figure 1",
            title: "Relative performance vs FP32, W4A4 ABFP, all models",
            expected_shape: "W4A4 stays within ~0.7-1.0 of FP32; vision models degrade less than LMs",
            run: fig1,
        },
        Experiment {
            id: "table1",
            paper_ref: "Table I",
            title: "Static MSE calibration vs ABFP, W4A4",
            expected_shape: "MSE calibration collapses (PPL orders of magnitude worse); ABFP stays usable",
            run: table1,
        },
        Experiment {
            id: "table2",
            paper_ref: "Table II",
            title: "4-bit integer vs floating point formats (ABFP n=64)",
            expected_shape: "E1M2 ≈ INT4 on most models; E2M1 inconsistent/worse",
            run: table2,
        },
        Experiment {
            id: "fig3",
            paper_ref: "Figure 3",
            title: "E1M2 vector lengths n=64 vs n=128",
            expected_shape: "n=128 worse than n=64; the gap shrinks with model size",
            run: fig3,
        },
        Experiment {
            id: "table3",
            paper_ref: "Table III",
            title: "Accuracy recovery on W4A4: ABFP vs ABFP-QAT vs ABFP-SQ",
            expected_shape: "QAT recovers most (closest to FP32); SQ helps, more for larger models",
            run: table3,
        },
        Experiment {
            id: "fig4",
            paper_ref: "Figure 4",
            title: "ABFP+QAT vector lengths (W4A4)",
            expected_shape: "QAT improves both n; QAT n=128 closes most of the gap to n=64",
            run: fig4,
        },
        Experiment {
            id: "table4",
            paper_ref: "Table IV",
            title: "Static MSE calibration vs ABFP, W4A8",
            expected_shape: "MSE becomes usable at 8-bit acts but still loses to ABFP everywhere",
            run: table4,
        },
        Experiment {
            id: "table5",
            paper_ref: "Table V",
            title: "E4M3 activations + INT4 weights vs GPTQ (W4A16)",
            expected_shape: "ABFP(-SQ) with E4M3 acts beats GPTQ on the larger models",
            run: table5,
        },
        Experiment {
            id: "table6",
            paper_ref: "Table VI",
            title: "E4M3 vs INT8 activations (±SQ)",
            expected_shape: "E4M3 ≈ INT8 — no significant advantage either way",
            run: table6,
        },
        Experiment {
            id: "table7",
            paper_ref: "Table VII",
            title: "Accuracy recovery on W4A8 vs GPTQ",
            expected_shape: "QAT best; SQ close behind; both beat GPTQ on larger models",
            run: table7,
        },
        Experiment {
            id: "fig5",
            paper_ref: "Figure 5",
            title: "ABFP+QAT vector lengths (W4A8)",
            expected_shape: "QAT n=128 ≈ QAT n=64, both near FP32 for the larger models",
            run: fig5,
        },
        Experiment {
            id: "table8",
            paper_ref: "Table VIII",
            title: "RPTQ vs ABFP (W4A4, W4A8)",
            expected_shape: "ABFP better at W4A4; mixed at W4A8",
            run: table8,
        },
        Experiment {
            id: "table9",
            paper_ref: "Table IX",
            title: "Model/task/dataset catalog",
            expected_shape: "(informational)",
            run: table9,
        },
        Experiment {
            id: "table10",
            paper_ref: "Table X",
            title: "ABFP W4A4/W4A8 across all model families",
            expected_shape: "W4A8 ≈ FP32 everywhere; W4A4 degrades LMs more than vision models",
            run: table10,
        },
        Experiment {
            id: "abl_scales",
            paper_ref: "Ext §II-B-2",
            title: "Two-level scale quantization (VS-Quant) vs plain ABFP",
            expected_shape: "ABFP2 within noise of ABFP at ~0.5x the scale storage",
            run: abl_scales,
        },
        Experiment {
            id: "abl_outq",
            paper_ref: "Ext Eqn 9",
            title: "Output quantization f_q^y (photonics case)",
            expected_shape: "yINT8/yE4M3 cost little on top of W4A4/W4A8 (outputs are post-accumulation)",
            run: abl_outq,
        },
        Experiment {
            id: "abl_mixed",
            paper_ref: "Ext §VI",
            title: "Per-layer mixed precision: boundary blocks at 8-bit",
            expected_shape: "boundary-8-bit lands between uniform W4A4 and uniform W4A8",
            run: abl_mixed,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_ids_unique_and_well_formed() {
        let exps = all();
        let mut seen = std::collections::BTreeSet::new();
        for e in &exps {
            assert!(seen.insert(e.id), "duplicate id {}", e.id);
            assert!(
                e.id.starts_with("table")
                    || e.id.starts_with("fig")
                    || e.id.starts_with("abl_"),
                "{}",
                e.id
            );
            assert!(!e.title.is_empty() && !e.expected_shape.is_empty(), "{}", e.id);
            assert!(!e.paper_ref.is_empty(), "{}", e.id);
        }
    }

    #[test]
    fn registry_covers_every_paper_table_and_figure() {
        let ids: Vec<&str> = all().iter().map(|e| e.id).collect();
        // Tables I-X of the paper (XI is checkpoint provenance, see
        // EXPERIMENTS.md) and Figures 1, 3, 4, 5 (2 is the block diagram).
        for want in [
            "table1", "table2", "table3", "table4", "table5", "table6",
            "table7", "table8", "table9", "table10", "fig1", "fig3", "fig4",
            "fig5",
        ] {
            assert!(ids.contains(&want), "missing {}", want);
        }
        // the three extension ablations
        for want in ["abl_scales", "abl_outq", "abl_mixed"] {
            assert!(ids.contains(&want), "missing {}", want);
        }
    }

    #[test]
    fn find_resolves_ids() {
        assert!(super::super::find("table1").is_some());
        assert!(super::super::find("abl_outq").is_some());
        assert!(super::super::find("table99").is_none());
    }
}
