//! Table-shaped experiment reports: markdown rendering + JSON persistence.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::Result;

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Report {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub meta: BTreeMap<String, String>,
}

impl Report {
    pub fn new(header: &[&str]) -> Report {
        Report {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            meta: BTreeMap::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Format a metric cell; None renders as "-" (paper's missing cells).
    pub fn cell(v: Option<f64>) -> String {
        match v {
            Some(x) if x.abs() >= 100.0 => format!("{:.1}", x),
            Some(x) => format!("{:.2}", x),
            None => "-".to_string(),
        }
    }

    /// GitHub-flavored markdown table with the title line.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths.iter()) {
                s.push_str(&format!(" {:<w$} |", c, w = w));
            }
            s
        };
        let mut out = String::new();
        if let (Some(id), Some(title)) = (self.meta.get("id"), self.meta.get("title")) {
            let pref = self.meta.get("paper_ref").cloned().unwrap_or_default();
            out.push_str(&format!("\n## {} — {} ({})\n\n", id, title, pref));
        }
        out.push_str(&line(&self.header));
        out.push('\n');
        out.push_str(&format!(
            "|{}|\n",
            widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
        ));
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        if let Some(shape) = self.meta.get("expected_shape") {
            out.push_str(&format!("\nPaper shape to reproduce: {}\n", shape));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "meta",
                Json::Obj(
                    self.meta
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                        .collect(),
                ),
            ),
            (
                "header",
                Json::Arr(self.header.iter().map(|h| Json::Str(h.clone())).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect())
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn save(&self, dir: &str) -> Result<()> {
        let id = self.meta.get("id").cloned().unwrap_or_else(|| "report".into());
        std::fs::create_dir_all(dir)?;
        std::fs::write(Path::new(dir).join(format!("{}.md", id)), self.render())?;
        std::fs::write(
            Path::new(dir).join(format!("{}.json", id)),
            self.to_json().pretty(),
        )?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut r = Report::new(&["Model", "FP32", "W4A4"]);
        r.row(vec!["sim-opt-125m".into(), "25.94".into(), "33.14".into()]);
        r.row(vec!["x".into(), Report::cell(None), Report::cell(Some(3.14159))]);
        let md = r.render();
        assert!(md.contains("| Model"));
        assert!(md.contains("| 3.14"));
        assert!(md.contains("| -"));
        let lines: Vec<&str> = md.trim().lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    fn json_roundtrip() {
        let mut r = Report::new(&["a"]);
        r.row(vec!["1".into()]);
        r.meta.insert("id".into(), "t".into());
        let j = r.to_json();
        let parsed = Json::parse(&j.dump()).unwrap();
        assert_eq!(
            parsed.get("rows").unwrap().as_arr().unwrap()[0].as_arr().unwrap()[0]
                .as_str(),
            Some("1")
        );
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut r = Report::new(&["a", "b"]);
        r.row(vec!["1".into()]);
    }
}
