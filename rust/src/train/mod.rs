//! Training drivers: FP32 pretraining and ABFP quantization-aware
//! fine-tuning (paper §II-C), both executing `train_*` artifacts (Adam
//! step compiled into the graph, PWL estimator for QAT).
//!
//! The driver owns the optimizer state host-side and threads it through
//! the artifact each step; the learning-rate schedule is computed here
//! (runtime scalar input), so schedules never require re-lowering.

use anyhow::{bail, Context, Result};

use crate::corpus::{CodeCorpus, ImageCorpus, QaCorpus, TextCorpus};
use crate::info;
use crate::model;
use crate::runtime::manifest::{InputKind, ModelCfg};
use crate::runtime::{Runtime, Val};
use crate::tensor::io::TensorStore;
use crate::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct TrainOpts {
    pub steps: usize,
    pub peak_lr: f32,
    pub warmup: usize,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts { steps: 300, peak_lr: 3e-3, warmup: 30, seed: 7, log_every: 20 }
    }
}

/// Adam hyperparameters, mirroring `python/compile/train.py`.
pub const ADAM_B1: f32 = 0.9;
pub const ADAM_B2: f32 = 0.999;
pub const ADAM_EPS: f32 = 1e-8;

/// Parameters excluded from optimization: the log-normal outlier gains
/// model an *end state* of full pretraining, not something to learn
/// away (`train.py FROZEN_SUFFIXES`; DESIGN.md §1 substitution table).
pub const FROZEN_SUFFIXES: [&str; 3] = ["emb_gain", "ln1_g", "ln2_g"];

pub fn is_frozen(name: &str) -> bool {
    FROZEN_SUFFIXES.iter().any(|s| name.ends_with(s))
}

/// One Adam update over a flat parameter tensor (`train.py adam_update`):
/// bias-corrected first/second moments, `step` is the 1-based f32 step
/// counter the train artifacts take as a runtime scalar.
pub fn adam_step(p: &mut [f32], m: &mut [f32], v: &mut [f32], g: &[f32], step: f32, lr: f32) {
    debug_assert!(p.len() == m.len() && m.len() == v.len() && v.len() == g.len());
    let bc1 = 1.0 - ADAM_B1.powf(step);
    let bc2 = 1.0 - ADAM_B2.powf(step);
    for i in 0..p.len() {
        let gi = g[i];
        let m2 = ADAM_B1 * m[i] + (1.0 - ADAM_B1) * gi;
        let v2 = ADAM_B2 * v[i] + (1.0 - ADAM_B2) * gi * gi;
        m[i] = m2;
        v[i] = v2;
        let mhat = m2 / bc1;
        let vhat = v2 / bc2;
        p[i] -= lr * mhat / (vhat.sqrt() + ADAM_EPS);
    }
}

/// Warmup + cosine decay to 10% of peak.
pub fn lr_at(opts: &TrainOpts, step: usize) -> f32 {
    let s = step as f32;
    if step < opts.warmup {
        return opts.peak_lr * (s + 1.0) / opts.warmup as f32;
    }
    let progress = (s - opts.warmup as f32)
        / (opts.steps.max(opts.warmup + 1) - opts.warmup) as f32;
    let cos = 0.5 * (1.0 + (std::f32::consts::PI * progress.min(1.0)).cos());
    opts.peak_lr * (0.1 + 0.9 * cos)
}

/// Per-step data supplier: step index -> data `Val`s in manifest order.
pub type DataFn<'a> = Box<dyn Fn(u64) -> Vec<Val> + 'a>;

/// Build the training data supplier for a model family. The corpus seed
/// is the *family constant* (corpus::TEXT_SEED etc.) so training,
/// calibration and evaluation share one generative process.
pub fn data_fn<'a>(cfg: &'a ModelCfg, _seed: u64) -> DataFn<'a> {
    let (b, s) = (cfg.batch, cfg.seq);
    match cfg.task.as_str() {
        "lm" => {
            let corpus = TextCorpus::new(crate::corpus::TEXT_SEED);
            Box::new(move |i| {
                let tb = corpus.train_batch(i, b, s);
                vec![Val::I32(tb.tokens, vec![b, s])]
            })
        }
        "codegen" => {
            let corpus = CodeCorpus::new(crate::corpus::CODE_SEED);
            Box::new(move |i| {
                let tb = corpus.train_batch(i, b, s);
                vec![Val::I32(tb.tokens, vec![b, s])]
            })
        }
        "span_qa" => {
            let corpus = QaCorpus::new(crate::corpus::QA_SEED);
            Box::new(move |i| {
                let qb = corpus.train_batch(i, b, s);
                vec![
                    Val::I32(qb.tokens.tokens, vec![b, s]),
                    Val::I32(qb.starts, vec![b]),
                    Val::I32(qb.ends, vec![b]),
                ]
            })
        }
        "image_cls" => {
            let corpus = ImageCorpus::new(crate::corpus::IMG_SEED);
            let (img, ch) = (cfg.image, cfg.channels);
            Box::new(move |i| {
                let ib = corpus.train_batch(i, b);
                vec![
                    Val::F32(ib.pixels, vec![b, img, img, ch]),
                    Val::I32(ib.labels, vec![b]),
                ]
            })
        }
        other => panic!("unknown task {}", other),
    }
}

/// Result of a training run: final params + the loss curve.
pub struct TrainResult {
    pub params: TensorStore,
    pub losses: Vec<f32>,
}

/// Run `steps` of the given train artifact starting from `params`.
pub fn run_training(
    rt: &Runtime,
    artifact_id: &str,
    params: TensorStore,
    opts: &TrainOpts,
) -> Result<TrainResult> {
    let spec = rt.manifest.artifact(artifact_id)?.clone();
    if spec.purpose != "train" {
        bail!("{} is not a train artifact", artifact_id);
    }
    let cfg = rt.manifest.model(&spec.model)?.clone();
    model::check_params(&cfg, &params)?;
    // Sanity-check the manifest input layout we rely on below.
    let p = cfg.params.len();
    for (i, inp) in spec.inputs.iter().enumerate() {
        let want = match i {
            i if i < p => InputKind::Param,
            i if i < 2 * p => InputKind::AdamM,
            i if i < 3 * p => InputKind::AdamV,
            i if i < 3 * p + 2 => InputKind::Scalar,
            _ => InputKind::Data,
        };
        if inp.kind != want {
            bail!("unexpected input layout at {} of {}", i, artifact_id);
        }
    }

    let sess = rt.session(artifact_id, &Default::default())?;
    let supplier = data_fn(&cfg, opts.seed ^ 0xDA7A);

    let mut pvals: Vec<Tensor> =
        cfg.params.iter().map(|ps| params.get(&ps.name).unwrap().clone()).collect();
    let mut mvals: Vec<Tensor> =
        cfg.params.iter().map(|ps| Tensor::zeros(ps.shape.clone())).collect();
    let mut vvals: Vec<Tensor> =
        cfg.params.iter().map(|ps| Tensor::zeros(ps.shape.clone())).collect();

    let mut losses = Vec::with_capacity(opts.steps);
    let t0 = std::time::Instant::now();
    for step in 0..opts.steps {
        let mut args: Vec<Val> = Vec::with_capacity(3 * p + 2 + 2);
        for t in pvals.iter().chain(mvals.iter()).chain(vvals.iter()) {
            args.push(Val::from_tensor(t));
        }
        args.push(Val::scalar((step + 1) as f32)); // 1-based for bias correction
        args.push(Val::scalar(lr_at(opts, step)));
        args.extend(supplier(step as u64));

        let out = sess.run(&args).with_context(|| format!("train step {}", step))?;
        debug_assert_eq!(out.len(), 3 * p + 1);
        let loss = out[3 * p].data[0];
        if !loss.is_finite() {
            bail!("non-finite loss {} at step {} of {}", loss, step, artifact_id);
        }
        losses.push(loss);
        let mut it = out.into_iter();
        for t in pvals.iter_mut() {
            *t = it.next().unwrap();
        }
        for t in mvals.iter_mut() {
            *t = it.next().unwrap();
        }
        for t in vvals.iter_mut() {
            *t = it.next().unwrap();
        }
        if step % opts.log_every == 0 || step + 1 == opts.steps {
            info!(
                "{}: step {:>4}/{} loss {:.4} lr {:.2e} ({:.2}s)",
                artifact_id,
                step,
                opts.steps,
                loss,
                lr_at(opts, step),
                t0.elapsed().as_secs_f64()
            );
        }
    }

    let mut out_store = TensorStore::default();
    for (ps, t) in cfg.params.iter().zip(pvals.into_iter()) {
        out_store.insert(&ps.name, t);
    }
    Ok(TrainResult { params: out_store, losses })
}

/// Serialize checkpoint-producing sections across threads: sharded
/// serve workers build their simulators concurrently, and two threads
/// pretraining the same model would race on the checkpoint file (one
/// could load a half-written store). Two separate locks because
/// `qat_cached` calls `pretrain_cached` — one lock would self-deadlock.
static PRETRAIN_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
static QAT_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Pretrain (or fetch cached) FP32 weights for a model.
pub fn pretrain_cached(
    rt: &Runtime,
    model_name: &str,
    ck: &model::CkptDir,
    opts: &TrainOpts,
) -> Result<TensorStore> {
    let _g = PRETRAIN_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let cfg = rt.manifest.model(model_name)?.clone();
    if ck.exists(model_name, "fp32") {
        let s = ck.load(model_name, "fp32")?;
        model::check_params(&cfg, &s)?;
        return Ok(s);
    }
    info!("pretraining {} ({} params)", model_name, cfg.param_count());
    let init = model::init_params(&cfg, opts.seed);
    let result = run_training(rt, &format!("{}/train_fp32", model_name), init, opts)?;
    ck.save(model_name, "fp32", &result.params)?;
    save_losses(ck, model_name, "fp32", &result.losses)?;
    Ok(result.params)
}

/// QAT fine-tune from the FP32 checkpoint (or fetch cached).
pub fn qat_cached(
    rt: &Runtime,
    model_name: &str,
    qat_config: &str, // e.g. "qat_w4a4_n64"
    ck: &model::CkptDir,
    opts: &TrainOpts,
) -> Result<TensorStore> {
    let _g = QAT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    if ck.exists(model_name, qat_config) {
        return ck.load(model_name, qat_config);
    }
    let base = pretrain_cached(rt, model_name, ck, &TrainOpts::default())?;
    info!("QAT fine-tuning {} with {}", model_name, qat_config);
    let result =
        run_training(rt, &format!("{}/train_{}", model_name, qat_config), base, opts)?;
    ck.save(model_name, qat_config, &result.params)?;
    save_losses(ck, model_name, qat_config, &result.losses)?;
    Ok(result.params)
}

fn save_losses(
    ck: &model::CkptDir,
    model_name: &str,
    tag: &str,
    losses: &[f32],
) -> Result<()> {
    use crate::util::json::Json;
    let arr = Json::Arr(losses.iter().map(|&l| Json::Num(l as f64)).collect());
    let path = ck.dir.join(format!("{}.{}.losses.json", model_name, tag));
    std::fs::write(path, arr.dump())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frozen_suffixes_match_python() {
        assert!(is_frozen("emb_gain"));
        assert!(is_frozen("l0.ln1_g"));
        assert!(is_frozen("l7.ln2_g"));
        assert!(!is_frozen("lnf_g"), "final LN gain is trainable");
        assert!(!is_frozen("l0.ln1_b"));
        assert!(!is_frozen("tok_emb"));
    }

    #[test]
    fn adam_step_descends_and_corrects_bias() {
        // First step: mhat == g exactly (bias correction), so the update
        // is -lr * g / (|g| + eps) up to the vhat sqrt.
        let mut p = vec![1.0f32];
        let mut m = vec![0.0f32];
        let mut v = vec![0.0f32];
        adam_step(&mut p, &mut m, &mut v, &[0.5], 1.0, 0.1);
        assert!((p[0] - (1.0 - 0.1)).abs() < 1e-4, "p {}", p[0]);
        assert!((m[0] - 0.05).abs() < 1e-7);
        assert!((v[0] - 0.00025).abs() < 1e-9);
        // Repeated identical gradients keep descending
        let before = p[0];
        for step in 2..6 {
            adam_step(&mut p, &mut m, &mut v, &[0.5], step as f32, 0.1);
        }
        assert!(p[0] < before);
    }

    #[test]
    fn lr_schedule_shape() {
        let opts = TrainOpts { steps: 100, peak_lr: 1.0, warmup: 10, ..Default::default() };
        assert!(lr_at(&opts, 0) < 0.2);
        assert!((lr_at(&opts, 9) - 1.0).abs() < 0.01);
        assert!(lr_at(&opts, 50) < 1.0);
        assert!(lr_at(&opts, 99) >= 0.1 * 1.0 - 1e-3);
        // monotone decay after warmup
        assert!(lr_at(&opts, 30) > lr_at(&opts, 60));
    }

    #[test]
    fn data_fn_shapes() {
        use crate::runtime::manifest::{ModelCfg, ParamSpec};
        let mk = |task: &str, image: usize| ModelCfg {
            seq: if task == "span_qa" { 64 } else { 16 },
            name: "t".into(),
            arch: "opt".into(),
            task: task.into(),
            stands_for: String::new(),
            vocab: 64,
            d: 8,
            layers: 1,
            heads: 1,
            d_ff: 32,
            batch: 2,
            image,
            patch: 4,
            channels: 3,
            classes: 16,
            params: Vec::<ParamSpec>::new(),
            sites: vec![],
        };
        assert_eq!(data_fn(&mk("lm", 0), 1)(0).len(), 1);
        assert_eq!(data_fn(&mk("codegen", 0), 1)(0).len(), 1);
        assert_eq!(data_fn(&mk("span_qa", 0), 1)(0).len(), 3);
        let v = data_fn(&mk("image_cls", 32), 1)(0);
        assert_eq!(v.len(), 2);
        assert_eq!(v[0].shape(), &[2, 32, 32, 3]);
    }
}
