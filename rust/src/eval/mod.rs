//! Metric evaluators: perplexity (OPT/Wikitext2 stand-in), Pass@1
//! (Codegen/HumanEval stand-in), span F1 (BERT/SQuAD stand-in) and
//! classification accuracy (ViT/ImageNet stand-in) — the four metrics of
//! the paper's Table IX.

use anyhow::{bail, Result};

use crate::corpus::{
    span_f1 as span_f1_tokens, CodeCorpus, ImageCorpus, Program, QaCorpus, TextCorpus,
};
use crate::runtime::manifest::ModelCfg;
use crate::runtime::{Session, Val};

/// Number of eval batches per metric point (fixed so every cell of every
/// table sees the same eval stream).
pub const EVAL_BATCHES: u64 = 24;

/// Corpus-level perplexity through an `eval_*` artifact (output: nll_sum).
pub fn perplexity(
    sess: &Session,
    cfg: &ModelCfg,
    corpus: &TextCorpus,
    batches: u64,
) -> Result<f64> {
    let (b, s) = (cfg.batch, cfg.seq);
    let mut total_nll = 0.0f64;
    let mut total_tok = 0usize;
    for i in 0..batches {
        let tb = corpus.eval_batch(i, b, s);
        let out = sess.run(&[Val::I32(tb.tokens, vec![b, s])])?;
        total_nll += out[0].data[0] as f64;
        total_tok += b * (s - 1);
    }
    let ppl = (total_nll / total_tok as f64).exp();
    if !ppl.is_finite() {
        bail!("non-finite perplexity");
    }
    Ok(ppl)
}

/// Greedy-decoding Pass@1 over held-out programs (logits artifact).
///
/// Rows are padded with token 0 beyond the cursor; causal masking makes
/// the padding irrelevant to the decoded position.
pub fn pass_at_1(
    sess: &Session,
    cfg: &ModelCfg,
    corpus: &CodeCorpus,
    n_programs: usize,
) -> Result<f64> {
    let (b, s) = (cfg.batch, cfg.seq);
    let programs = corpus.eval_programs(n_programs);
    let mut passed = 0usize;
    for chunk in programs.chunks(b) {
        // rows: prompt + decoded-so-far; cursor per row
        let mut rows = vec![vec![0i32; s]; b];
        let mut cursors = vec![0usize; b];
        for (r, prog) in chunk.iter().enumerate() {
            let p = prog.prompt();
            rows[r][..p.len()].copy_from_slice(&p);
            cursors[r] = p.len();
        }
        let max_new = 5; // up to 3 digits + ';' + slack
        let mut generated: Vec<Vec<i32>> = vec![Vec::new(); b];
        for _ in 0..max_new {
            let mut flat = Vec::with_capacity(b * s);
            for row in &rows {
                flat.extend_from_slice(row);
            }
            let out = sess.run(&[Val::I32(flat, vec![b, s])])?;
            let logits = &out[0]; // (b, s, vocab)
            let vocab = cfg.vocab;
            for r in 0..chunk.len() {
                let cur = cursors[r];
                if cur >= s || generated[r].last() == Some(&crate::corpus::code_semi()) {
                    continue;
                }
                let base = (r * s + (cur - 1)) * vocab;
                let row_logits = &logits.data[base..base + vocab];
                let mut best = 0usize;
                for (j, &v) in row_logits.iter().enumerate() {
                    if v > row_logits[best] {
                        best = j;
                    }
                }
                rows[r][cur] = best as i32;
                generated[r].push(best as i32);
                cursors[r] = cur + 1;
            }
        }
        for (r, prog) in chunk.iter().enumerate() {
            if check_completion(prog, &generated[r]) {
                passed += 1;
            }
        }
    }
    Ok(passed as f64 / programs.len() as f64)
}

/// "Run the program": the generated digits (up to `;`) must evaluate to
/// the interpreter's exact value.
pub fn check_completion(prog: &Program, generated: &[i32]) -> bool {
    let want = prog.completion();
    let upto_semi: Vec<i32> = generated
        .iter()
        .cloned()
        .take_while(|&t| t != crate::corpus::code_semi())
        .collect();
    let want_digits = &want[..want.len() - 1];
    upto_semi == want_digits
        && generated.len() > upto_semi.len() // the ';' was emitted
}

/// Span-F1 for the QA encoder (start/end logits outputs).
pub fn qa_f1(
    sess: &Session,
    cfg: &ModelCfg,
    corpus: &QaCorpus,
    batches: u64,
) -> Result<f64> {
    let (b, s) = (cfg.batch, cfg.seq);
    let mut f1_sum = 0.0f64;
    let mut n = 0usize;
    for i in 0..batches {
        let qb = corpus.eval_batch(i, b, s);
        let out = sess.run(&[Val::I32(qb.tokens.tokens, vec![b, s])])?;
        let (sl, el) = (&out[0], &out[1]); // each (b, s)
        for r in 0..b {
            let argmax = |t: &crate::tensor::Tensor| -> i32 {
                let row = &t.data[r * s..(r + 1) * s];
                let mut best = 0usize;
                for (j, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = j;
                    }
                }
                best as i32
            };
            let pred = (argmax(sl), argmax(el));
            f1_sum += span_f1_tokens(pred, (qb.starts[r], qb.ends[r]));
            n += 1;
        }
    }
    Ok(100.0 * f1_sum / n as f64)
}

/// Top-1 classification accuracy for the ViT models (logits output).
pub fn image_accuracy(
    sess: &Session,
    cfg: &ModelCfg,
    corpus: &ImageCorpus,
    batches: u64,
) -> Result<f64> {
    let b = cfg.batch;
    let (img, ch, classes) = (cfg.image, cfg.channels, cfg.classes);
    let mut correct = 0usize;
    let mut n = 0usize;
    for i in 0..batches {
        let ib = corpus.eval_batch(i, b);
        let out = sess.run(&[Val::F32(ib.pixels, vec![b, img, img, ch])])?;
        let logits = &out[0]; // (b, classes)
        for r in 0..b {
            let row = &logits.data[r * classes..(r + 1) * classes];
            let mut best = 0usize;
            for (j, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = j;
                }
            }
            if best as i32 == ib.labels[r] {
                correct += 1;
            }
            n += 1;
        }
    }
    Ok(100.0 * correct as f64 / n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{CodeCorpus, Program};

    #[test]
    fn completion_checker() {
        let corpus = CodeCorpus::new(1);
        for prog in corpus.eval_programs(20) {
            let mut good = prog.completion();
            assert!(check_completion(&prog, &good), "{:?}", prog);
            // wrong digit fails
            good[0] = (good[0] + 1) % 10;
            assert!(!check_completion(&prog, &good));
            // missing ';' fails
            let trunc: Vec<i32> = prog
                .completion()
                .into_iter()
                .filter(|&t| t != crate::corpus::code_semi())
                .collect();
            assert!(!check_completion(&prog, &trunc));
        }
        let _ = Program::sample(&mut crate::util::rng::Pcg64::new(0));
    }
}
