//! Cross-layer golden tests: the Rust quantizer mirrors must be
//! bit-exact against tables emitted by the Pallas/jnp reference
//! (`python -m compile.aot` → artifacts/goldens/quant_goldens.json).
//!
//! Skipped (with a note) when artifacts have not been built yet.

#![cfg(test)]

use std::path::PathBuf;

use super::*;
use crate::util::json::Json;

fn goldens() -> Option<Json> {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/goldens/quant_goldens.json");
    let text = std::fs::read_to_string(path).ok()?;
    Some(Json::parse(&text).expect("parse goldens"))
}

fn probe(g: &Json) -> Vec<f32> {
    g.get("probe").unwrap().as_f32_vec().unwrap()
}

macro_rules! need_goldens {
    () => {
        match goldens() {
            Some(g) => g,
            None => {
                eprintln!("goldens not built; skipping (run `make artifacts`)");
                return;
            }
        }
    };
}

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{} length", what);
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        // compare as bits, treating ±0 as equal only when bit-identical;
        // the goldens round-trip through JSON decimal so compare exactly
        // on value with NaN-safety.
        assert!(
            g == w || (g.is_nan() && w.is_nan()),
            "{}: idx {}: got {} want {}",
            what,
            i,
            g,
            w
        );
    }
}

#[test]
fn grids_match_python() {
    let g = need_goldens!();
    for (fmt, key) in [
        (E2M1, "grid_e2m1"),
        (E1M2, "grid_e1m2"),
        (E4M3, "grid_e4m3"),
    ] {
        let want = g.get(key).unwrap().as_f32_vec().unwrap();
        assert_bits_eq(&fmt.grid(), &want, key);
    }
}

#[test]
fn fp_round_matches_python() {
    let g = need_goldens!();
    let p = probe(&g);
    for (fmt, key) in [
        (E2M1, "fp_round_e2m1"),
        (E1M2, "fp_round_e1m2"),
        (E4M3, "fp_round_e4m3"),
    ] {
        let want = g.get(key).unwrap().as_f32_vec().unwrap();
        let got: Vec<f32> = p.iter().map(|&v| fp_round(v, fmt)).collect();
        assert_bits_eq(&got, &want, key);
    }
}

#[test]
fn abfp_matches_python() {
    let g = need_goldens!();
    let p = probe(&g);
    let formats: [(Format, &str); 5] = [
        (Format::Int(INT4), "int4"),
        (Format::Int(INT8), "int8"),
        (Format::Fp(E2M1), "e2m1"),
        (Format::Fp(E1M2), "e1m2"),
        (Format::Fp(E4M3), "e4m3"),
    ];
    for (fmt, name) in formats {
        for n in [64usize, 128] {
            let key = format!("abfp_{}_n{}", name, n);
            let want = g.get(&key).unwrap().as_f32_vec().unwrap();
            let mut got = p.clone();
            abfp_qdq(&mut got, 128, fmt, n);
            assert_bits_eq(&got, &want, &key);
        }
    }
}

#[test]
fn abfp2_matches_python() {
    let g = need_goldens!();
    let p = probe(&g);
    let formats: [(Format, &str); 3] = [
        (Format::Int(INT4), "int4"),
        (Format::Int(INT8), "int8"),
        (Format::Fp(E4M3), "e4m3"),
    ];
    for (fmt, name) in formats {
        for n in [64usize, 128] {
            let key = format!("abfp2_{}_n{}", name, n);
            let want = g.get(&key).unwrap().as_f32_vec().unwrap();
            let mut got = p.clone();
            abfp2_qdq(&mut got, 128, fmt, n, 8);
            assert_bits_eq(&got, &want, &key);
        }
    }
}

#[test]
fn static_int_matches_python() {
    let g = need_goldens!();
    let p = probe(&g);
    for bits in [4u32, 8] {
        let key = format!("static_int{}_a2.5", bits);
        let want = g.get(&key).unwrap().as_f32_vec().unwrap();
        let mut got = p.clone();
        static_int_qdq(&mut got, &[2.5], bits);
        assert_bits_eq(&got, &want, &key);

        // per-channel variant: alpha = per-column absmax of the 8x128 probe
        let mut alpha = vec![0.0f32; 128];
        for row in p.chunks(128) {
            for (a, &v) in alpha.iter_mut().zip(row) {
                *a = a.max(v.abs());
            }
        }
        let key = format!("static_int{}_pc", bits);
        let want = g.get(&key).unwrap().as_f32_vec().unwrap();
        let mut got = p.clone();
        static_int_qdq(&mut got, &alpha, bits);
        assert_bits_eq(&got, &want, &key);

        let key = format!("pcmax_w_int{}", bits);
        let want = g.get(&key).unwrap().as_f32_vec().unwrap();
        let mut got = p.clone();
        pcmax_weight_qdq(&mut got, 128, bits);
        assert_bits_eq(&got, &want, &key);
    }
}
