//! Cross-layer golden tests: the Rust quantizer mirrors must be
//! bit-exact against tables emitted by the Pallas/jnp reference
//! (`python -m compile.aot` → artifacts/goldens/quant_goldens.json).
//!
//! Skipped (with a note) when artifacts have not been built yet.

#![cfg(test)]

use std::path::PathBuf;

use super::*;
use crate::util::json::Json;

fn goldens() -> Option<Json> {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/goldens/quant_goldens.json");
    let text = std::fs::read_to_string(path).ok()?;
    Some(Json::parse(&text).expect("parse goldens"))
}

fn probe(g: &Json) -> Vec<f32> {
    g.get("probe").unwrap().as_f32_vec().unwrap()
}

macro_rules! need_goldens {
    () => {
        match goldens() {
            Some(g) => g,
            None => {
                eprintln!("goldens not built; skipping (run `make artifacts`)");
                return;
            }
        }
    };
}

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{} length", what);
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        // compare as bits, treating ±0 as equal only when bit-identical;
        // the goldens round-trip through JSON decimal so compare exactly
        // on value with NaN-safety.
        assert!(
            g == w || (g.is_nan() && w.is_nan()),
            "{}: idx {}: got {} want {}",
            what,
            i,
            g,
            w
        );
    }
}

#[test]
fn grids_match_python() {
    let g = need_goldens!();
    for (fmt, key) in [
        (E2M1, "grid_e2m1"),
        (E1M2, "grid_e1m2"),
        (E4M3, "grid_e4m3"),
    ] {
        let want = g.get(key).unwrap().as_f32_vec().unwrap();
        assert_bits_eq(&fmt.grid(), &want, key);
    }
}

#[test]
fn fp_round_matches_python() {
    let g = need_goldens!();
    let p = probe(&g);
    for (fmt, key) in [
        (E2M1, "fp_round_e2m1"),
        (E1M2, "fp_round_e1m2"),
        (E4M3, "fp_round_e4m3"),
    ] {
        let want = g.get(key).unwrap().as_f32_vec().unwrap();
        let got: Vec<f32> = p.iter().map(|&v| fp_round(v, fmt)).collect();
        assert_bits_eq(&got, &want, key);
    }
}

#[test]
fn abfp_matches_python() {
    let g = need_goldens!();
    let p = probe(&g);
    let formats: [(Format, &str); 5] = [
        (Format::Int(INT4), "int4"),
        (Format::Int(INT8), "int8"),
        (Format::Fp(E2M1), "e2m1"),
        (Format::Fp(E1M2), "e1m2"),
        (Format::Fp(E4M3), "e4m3"),
    ];
    for (fmt, name) in formats {
        for n in [64usize, 128] {
            let key = format!("abfp_{}_n{}", name, n);
            let want = g.get(&key).unwrap().as_f32_vec().unwrap();
            let mut got = p.clone();
            abfp_qdq(&mut got, 128, fmt, n);
            assert_bits_eq(&got, &want, &key);
        }
    }
}

#[test]
fn abfp2_matches_python() {
    let g = need_goldens!();
    let p = probe(&g);
    let formats: [(Format, &str); 3] = [
        (Format::Int(INT4), "int4"),
        (Format::Int(INT8), "int8"),
        (Format::Fp(E4M3), "e4m3"),
    ];
    for (fmt, name) in formats {
        for n in [64usize, 128] {
            let key = format!("abfp2_{}_n{}", name, n);
            let want = g.get(&key).unwrap().as_f32_vec().unwrap();
            let mut got = p.clone();
            abfp2_qdq(&mut got, 128, fmt, n, 8);
            assert_bits_eq(&got, &want, &key);
        }
    }
}

// ---- FP8 boundary goldens (E4M3 / E5M2) ----
//
// Unlike the table-driven tests above, these encode the *known-answer*
// edge values of the FP8 formats directly, so they run without built
// artifacts: fmax, the smallest subnormal, the E4M3 NaN-code
// reservation, and round-to-nearest-even tie behaviour.

#[test]
fn e4m3_boundary_goldens() {
    // fmax: the all-ones code is NaN, so the top value drops one
    // mantissa step: 2^8 * (2 - 2/8) = 448, not 480.
    assert_eq!(E4M3.fmax(), 448.0);
    let grid = E4M3.grid();
    assert_eq!(grid.last().copied(), Some(448.0));
    assert_eq!(grid[grid.len() - 2], 416.0);
    assert!(!grid.contains(&480.0), "NaN code must not be a value");
    // 1 (zero) + 7 subnormals + 15 binades x 8 codes - 1 NaN = 127
    assert_eq!(grid.len(), 127);

    // smallest subnormal: 2^emin * 2^-m = 2^-6 * 2^-3 = 2^-9
    let tiny = 2.0f32.powi(-9);
    assert_eq!(grid[1], tiny);
    assert_eq!(fp_round(0.6 * tiny, E4M3), tiny);
    // exactly half the smallest subnormal ties to even (zero)
    assert_eq!(fp_round(0.5 * tiny, E4M3), 0.0);
    // tie between subnormal codes 1 and 2 goes to the even code (2)
    assert_eq!(fp_round(1.5 * tiny, E4M3), 2.0 * tiny);

    // RNE ties in the [16, 32) binade (ulp = 2): halfway values go to
    // the even mantissa code on both sides.
    assert_eq!(fp_round(17.0, E4M3), 16.0);
    assert_eq!(fp_round(19.0, E4M3), 20.0);

    // values that would round onto the reserved NaN code saturate
    assert_eq!(fp_round(470.0, E4M3), 448.0);
    assert_eq!(fp_round(476.0, E4M3), 448.0);
    assert_eq!(fp_round(f32::MAX, E4M3), 448.0);
    assert_eq!(fp_round(-1.0e9, E4M3), -448.0);
}

#[test]
fn e5m2_boundary_goldens() {
    // Repo convention (python/compile/formats.py): finite-only, the full
    // top binade holds values, so fmax = 2^16 * 1.75 = 114688 — NOT the
    // OCP/IEEE 57344, which reserves the top exponent for inf/NaN.
    assert_eq!(E5M2.fmax(), 114688.0);
    let grid = E5M2.grid();
    assert_eq!(grid.last().copied(), Some(114688.0));
    assert_eq!(grid[grid.len() - 2], 98304.0);
    assert_eq!(grid.len(), 128); // zero + 3 subnormals + 31 x 4 codes

    // smallest subnormal: 2^emin * 2^-m = 2^-14 * 2^-2 = 2^-16
    let tiny = 2.0f32.powi(-16);
    assert_eq!(grid[1], tiny);
    assert_eq!(fp_round(0.6 * tiny, E5M2), tiny);
    assert_eq!(fp_round(0.5 * tiny, E5M2), 0.0); // tie to even (zero)
    assert_eq!(fp_round(1.5 * tiny, E5M2), 2.0 * tiny);

    // RNE ties in the [16, 32) binade (ulp = 4)
    assert_eq!(fp_round(18.0, E5M2), 16.0);
    assert_eq!(fp_round(22.0, E5M2), 24.0);

    // top binade (ulp = 16384) and saturation
    assert_eq!(fp_round(100_000.0, E5M2), 98304.0);
    assert_eq!(fp_round(107_000.0, E5M2), 114688.0);
    assert_eq!(fp_round(1.0e9, E5M2), 114688.0);
    assert_eq!(fp_round(-f32::MAX, E5M2), -114688.0);
}

#[test]
fn static_int_matches_python() {
    let g = need_goldens!();
    let p = probe(&g);
    for bits in [4u32, 8] {
        let key = format!("static_int{}_a2.5", bits);
        let want = g.get(&key).unwrap().as_f32_vec().unwrap();
        let mut got = p.clone();
        static_int_qdq(&mut got, &[2.5], bits);
        assert_bits_eq(&got, &want, &key);

        // per-channel variant: alpha = per-column absmax of the 8x128 probe
        let mut alpha = vec![0.0f32; 128];
        for row in p.chunks(128) {
            for (a, &v) in alpha.iter_mut().zip(row) {
                *a = a.max(v.abs());
            }
        }
        let key = format!("static_int{}_pc", bits);
        let want = g.get(&key).unwrap().as_f32_vec().unwrap();
        let mut got = p.clone();
        static_int_qdq(&mut got, &alpha, bits);
        assert_bits_eq(&got, &want, &key);

        let key = format!("pcmax_w_int{}", bits);
        let want = g.get(&key).unwrap().as_f32_vec().unwrap();
        let mut got = p.clone();
        pcmax_weight_qdq(&mut got, 128, bits);
        assert_bits_eq(&got, &want, &key);
    }
}
