//! Bit-exact Rust mirrors of the quantizer arithmetic (L1 kernels).
//!
//! The coordinator needs the same fake-quant math as the compiled HLO —
//! GPTQ quantizes weight columns host-side, SmoothQuant/RPTQ reason about
//! quantization error, and the calibrator searches MSE-optimal clip
//! ranges.  Every function here matches `python/compile/kernels/ref.py`
//! *exactly* (same rounding, same op order in f32); the golden tests in
//! `goldens.rs` enforce bit equality against tables emitted by aot.py.

mod goldens;

/// Symmetric signed integer format (qmax = 2^(bits-1) - 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IntFmt {
    pub bits: u32,
}

impl IntFmt {
    pub const fn new(bits: u32) -> IntFmt {
        IntFmt { bits }
    }

    pub fn qmax(&self) -> f32 {
        ((1u32 << (self.bits - 1)) - 1) as f32
    }
}

/// Miniature float: 1 sign, e exponent, m mantissa bits; no inf,
/// optional NaN reservation (E4M3 convention, fmax 448).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FpFmt {
    pub e: u32,
    pub m: u32,
    pub nan_reserved: bool,
}

impl FpFmt {
    pub const fn new(e: u32, m: u32, nan_reserved: bool) -> FpFmt {
        FpFmt { e, m, nan_reserved }
    }

    pub fn bias(&self) -> i32 {
        (1 << (self.e - 1)) - 1
    }

    pub fn emin(&self) -> i32 {
        1 - self.bias()
    }

    pub fn emax(&self) -> i32 {
        ((1 << self.e) - 1) - self.bias()
    }

    pub fn fmax(&self) -> f32 {
        let mut top = 2.0 - 0.5f64.powi(self.m as i32);
        if self.nan_reserved {
            top -= 0.5f64.powi(self.m as i32);
        }
        (2.0f64.powi(self.emax()) * top) as f32
    }

    /// Every non-negative representable value, ascending (tests/goldens).
    pub fn grid(&self) -> Vec<f32> {
        let mut vals = vec![0.0f32];
        let scale = 0.5f64.powi(self.m as i32);
        for k in 1..(1u32 << self.m) {
            vals.push((2.0f64.powi(self.emin()) * k as f64 * scale) as f32);
        }
        for efield in 1..(1u32 << self.e) {
            let ee = efield as i32 - self.bias();
            for k in 0..(1u32 << self.m) {
                if self.nan_reserved
                    && efield == (1 << self.e) - 1
                    && k == (1 << self.m) - 1
                {
                    continue;
                }
                vals.push((2.0f64.powi(ee) * (1.0 + k as f64 * scale)) as f32);
            }
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        vals.dedup();
        vals
    }
}

pub const INT4: IntFmt = IntFmt::new(4);
pub const INT8: IntFmt = IntFmt::new(8);
pub const E2M1: FpFmt = FpFmt::new(2, 1, false);
pub const E1M2: FpFmt = FpFmt::new(1, 2, false);
pub const E4M3: FpFmt = FpFmt::new(4, 3, true);
/// FP8 E5M2 under this repo's finite-only convention (`formats.py`:
/// no inf encoding, the full top binade holds values), so fmax is
/// 2^16 * 1.75 = 114688 — NOT the OCP/IEEE-style 57344, which reserves
/// the top exponent for inf/NaN. Matches `python/compile/formats.py`
/// `parse("e5m2")` bit-for-bit (asserted in `python/tests/test_formats.py`).
pub const E5M2: FpFmt = FpFmt::new(5, 2, false);

/// Either payload format, as named in the manifest (`int4`, `e4m3`, ...).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Format {
    Int(IntFmt),
    Fp(FpFmt),
}

impl Format {
    pub fn parse(name: &str) -> Option<Format> {
        match name {
            "int4" => Some(Format::Int(INT4)),
            "int8" => Some(Format::Int(INT8)),
            "e2m1" => Some(Format::Fp(E2M1)),
            "e1m2" => Some(Format::Fp(E1M2)),
            "e4m3" => Some(Format::Fp(E4M3)),
            "e5m2" => Some(Format::Fp(E5M2)),
            _ => {
                // generic intN, bounded like eXmY below: bits outside
                // [2, 32] would make qmax() shift-overflow (int1's qmax
                // of 0 divides to NaN scales) rather than quantize
                if let Some(b) = name.strip_prefix("int") {
                    return b
                        .parse::<u32>()
                        .ok()
                        .filter(|bits| (2..=32).contains(bits) && b == bits.to_string())
                        .map(|bits| Format::Int(IntFmt::new(bits)));
                }
                // generic eXmY (mirrors formats.py parse: nan_reserved
                // off), bounded to sane low-precision widths — wider e/m
                // would overflow fmax()/explode grid() rather than
                // describe a simulable format. e is capped at 7: e = 8
                // already gives emax = 128, whose fmax casts to f32 inf.
                if let Some(rest) = name.strip_prefix('e') {
                    if let Some((e, m)) = rest.split_once('m') {
                        if let (Ok(e), Ok(m)) = (e.parse::<u32>(), m.parse::<u32>()) {
                            // round-trip guard: reject non-canonical
                            // spellings ("e04m3", "e+4m3") rather than
                            // silently constructing a format that shadows
                            // a named constant with different semantics
                            if (1..=7).contains(&e)
                                && (1..=10).contains(&m)
                                && format!("e{}m{}", e, m) == name
                            {
                                return Some(Format::Fp(FpFmt::new(e, m, false)));
                            }
                        }
                    }
                }
                None
            }
        }
    }
}

/// Round-to-nearest-even to integer, matching jnp.round.
#[inline]
pub fn rne(x: f32) -> f32 {
    x.round_ties_even()
}

/// f32 -> bf16 -> f32 (RNE), matching jnp astype(bfloat16) round-trip.
#[inline]
pub fn bf16_round(x: f32) -> f32 {
    let bits = x.to_bits();
    let rounded = bits.wrapping_add(0x7FFF + ((bits >> 16) & 1));
    f32::from_bits(rounded & 0xFFFF_0000)
}

/// Symmetric integer QDQ with explicit scale (Eqns 1-3): s = qmax/alpha.
#[inline]
pub fn int_qdq(x: f32, scale: f32, qmax: f32) -> f32 {
    let q = rne(x * scale).clamp(-qmax, qmax);
    q / scale
}

/// RNE onto the EeMm grid, saturating at fmax (ref.fp_round).
///
/// The binade exponent comes from the f32 bit pattern, which equals
/// floor(log2|x|) exactly; at values straddling a binade boundary both
/// exponents produce the same grid value (see ref.py), so this matches
/// the jnp float-log2 implementation bit-for-bit.
pub fn fp_round(x: f32, fmt: FpFmt) -> f32 {
    if x == 0.0 {
        return x; // preserves signed zero like jnp.sign(x) * 0
    }
    let ax = x.abs();
    let bits = ax.to_bits();
    let mut e = ((bits >> 23) & 0xFF) as i32 - 127;
    if (bits >> 23) & 0xFF == 0 {
        // f32 subnormal: far below any target emin; clamp below handles it
        e = -127;
    }
    let e = e.max(fmt.emin());
    let ulp = exp2i(e - fmt.m as i32);
    let q = (rne(ax / ulp) * ulp).min(fmt.fmax());
    if x < 0.0 {
        -q
    } else {
        q
    }
}

#[inline]
fn exp2i(e: i32) -> f32 {
    // exact powers of two; range is tiny (|e| < 160)
    (2.0f64).powi(e) as f32
}

/// Scaled float QDQ: scale = fmax/alpha (ref.fp_qdq).
#[inline]
pub fn fp_qdq(x: f32, scale: f32, fmt: FpFmt) -> f32 {
    fp_round(x * scale, fmt) / scale
}

/// Dispatch a row-local QDQ kernel over (rows, `row`) data: serial below
/// the parallel threshold, otherwise split across the active backend's
/// workers with row-aligned chunk boundaries. The kernel runs the same
/// per-element math on disjoint pieces either way, so results are
/// bit-identical to the serial loop (regression-tested against every
/// backend in `tests/backend_conformance.rs`).
fn bulk_rows(
    x: &mut [f32],
    row: usize,
    be: &dyn crate::tensor::backend::Backend,
    kernel: &(dyn Fn(&mut [f32]) + Sync),
) {
    let t = be.threads();
    if row == 0 || t <= 1 || x.len() < crate::tensor::backend::PAR_MIN_LEN {
        kernel(x);
        return;
    }
    let rows = x.len() / row;
    let per = rows.div_ceil(t).max(1) * row;
    be.par_chunks_f32(x, per, &|_, piece| kernel(piece));
}

/// Static integer QDQ from a clip range alpha (per-tensor broadcast),
/// on the active backend for large tensors.
pub fn static_int_qdq(x: &mut [f32], alpha: &[f32], bits: u32) {
    static_int_qdq_with(x, alpha, bits, crate::tensor::backend::active().as_ref());
}

/// [`static_int_qdq`] on an explicit backend handle.
pub fn static_int_qdq_with(
    x: &mut [f32],
    alpha: &[f32],
    bits: u32,
    be: &dyn crate::tensor::backend::Backend,
) {
    let qmax = IntFmt::new(bits).qmax();
    if alpha.len() == 1 {
        let a = if alpha[0] > 0.0 { alpha[0] } else { 1.0 };
        let s = qmax / a;
        bulk_rows(x, 1, be, &|piece: &mut [f32]| {
            for v in piece.iter_mut() {
                *v = int_qdq(*v, s, qmax);
            }
        });
    } else {
        // per-channel over the last axis; x is (rows, alpha.len())
        let k = alpha.len();
        assert_eq!(x.len() % k, 0);
        let scales: Vec<f32> = alpha
            .iter()
            .map(|&a| qmax / if a > 0.0 { a } else { 1.0 })
            .collect();
        bulk_rows(x, k, be, &|piece: &mut [f32]| {
            for row in piece.chunks_mut(k) {
                for (v, &s) in row.iter_mut().zip(scales.iter()) {
                    *v = int_qdq(*v, s, qmax);
                }
            }
        });
    }
}

/// Per-output-channel max weight QDQ: w is (dout, din) row-major, on
/// the active backend for large tensors.
pub fn pcmax_weight_qdq(w: &mut [f32], din: usize, bits: u32) {
    pcmax_weight_qdq_with(w, din, bits, crate::tensor::backend::active().as_ref());
}

/// [`pcmax_weight_qdq`] on an explicit backend handle.
pub fn pcmax_weight_qdq_with(
    w: &mut [f32],
    din: usize,
    bits: u32,
    be: &dyn crate::tensor::backend::Backend,
) {
    let qmax = IntFmt::new(bits).qmax();
    bulk_rows(w, din, be, &|piece: &mut [f32]| {
        for row in piece.chunks_mut(din) {
            let a = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let a = if a > 0.0 { a } else { 1.0 };
            let s = qmax / a;
            for v in row.iter_mut() {
                *v = int_qdq(*v, s, qmax);
            }
        }
    });
}

/// ABFP QDQ along the last axis: x is (rows, k) row-major, k % n == 0.
/// Mirrors ref.abfp_qdq exactly (BF16 scales, zero-vector -> 1); bulk
/// tensors fan out across the active backend.
pub fn abfp_qdq(x: &mut [f32], k: usize, fmt: Format, n: usize) {
    abfp_qdq_with(x, k, fmt, n, crate::tensor::backend::active().as_ref());
}

/// [`abfp_qdq`] on an explicit backend handle.
pub fn abfp_qdq_with(
    x: &mut [f32],
    k: usize,
    fmt: Format,
    n: usize,
    be: &dyn crate::tensor::backend::Backend,
) {
    assert_eq!(k % n, 0, "ABFP needs k % n == 0 (k={}, n={})", k, n);
    assert_eq!(x.len() % k, 0);
    bulk_rows(x, k, be, &|piece: &mut [f32]| abfp_rows(piece, k, fmt, n));
}

/// The serial per-row ABFP kernel (row-local, chunking-invariant).
/// `pub(crate)` so the fused QDQ→matmul A-panel prep
/// (`runtime::registry::RowQdq`) can run it on a single row without
/// per-row re-validation — same bytes as the bulk entry points above.
pub(crate) fn abfp_rows(x: &mut [f32], k: usize, fmt: Format, n: usize) {
    for row in x.chunks_mut(k) {
        for chunk in row.chunks_mut(n) {
            let alpha = chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let alpha = bf16_round(alpha);
            let alpha = if alpha > 0.0 { alpha } else { 1.0 };
            match fmt {
                Format::Int(ifmt) => {
                    let qmax = ifmt.qmax();
                    let s = qmax / alpha;
                    for v in chunk.iter_mut() {
                        *v = int_qdq(*v, s, qmax);
                    }
                }
                Format::Fp(ffmt) => {
                    let s = ffmt.fmax() / alpha;
                    for v in chunk.iter_mut() {
                        *v = fp_qdq(*v, s, ffmt);
                    }
                }
            }
        }
    }
}

/// Two-level ABFP QDQ (VS-Quant; paper §II-B-2 second-level scale
/// quantization): per-vector absmax scales stored as unsigned
/// ``scale_bits`` codes against a per-row BF16 second-level scale.
/// Codes ceil (never undershoot the absmax → never clips); the
/// reconstructed scale is BF16 like every ABFP scale.  Mirrors
/// ref.abfp2_qdq exactly.
pub fn abfp2_qdq(x: &mut [f32], k: usize, fmt: Format, n: usize, scale_bits: u32) {
    assert_eq!(k % n, 0, "ABFP needs k % n == 0 (k={}, n={})", k, n);
    assert_eq!(x.len() % k, 0);
    abfp2_rows(x, k, fmt, n, scale_bits);
}

/// The serial per-row two-level ABFP kernel (row-local, chunking-
/// invariant), shared by [`abfp2_qdq`] and the fused A-panel prep
/// (`runtime::registry::RowQdq`).
pub(crate) fn abfp2_rows(x: &mut [f32], k: usize, fmt: Format, n: usize, scale_bits: u32) {
    let smax = ((1u32 << scale_bits) - 1) as f32;
    let chunks = k / n;
    let mut alpha = vec![0.0f32; chunks];
    for row in x.chunks_mut(k) {
        for (j, chunk) in row.chunks(n).enumerate() {
            alpha[j] = chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        }
        let gamma = bf16_round(alpha.iter().fold(0.0f32, |m, &a| m.max(a)));
        let gamma = if gamma > 0.0 { gamma } else { 1.0 };
        for (j, chunk) in row.chunks_mut(n).enumerate() {
            let code = (alpha[j] / gamma * smax).ceil().clamp(1.0, smax);
            let ah = bf16_round(code / smax * gamma);
            let a = if alpha[j] > 0.0 { ah } else { 1.0 };
            match fmt {
                Format::Int(ifmt) => {
                    let qmax = ifmt.qmax();
                    let s = qmax / a;
                    for v in chunk.iter_mut() {
                        *v = int_qdq(*v, s, qmax);
                    }
                }
                Format::Fp(ffmt) => {
                    let s = ffmt.fmax() / a;
                    for v in chunk.iter_mut() {
                        *v = fp_qdq(*v, s, ffmt);
                    }
                }
            }
        }
    }
}

/// Scale-storage overhead of a quantizer family, in bits per payload
/// element (the Table VIII trade-off note): ABFP stores one BF16 scale
/// per n elements; two-level ABFP stores one ``scale_bits`` code per n
/// elements plus one BF16 second-level scale per k-element row.
pub fn scale_overhead_bits(k: usize, n: usize, two_level: Option<u32>) -> f64 {
    match two_level {
        None => 16.0 / n as f64,
        Some(sb) => sb as f64 / n as f64 + 16.0 / k as f64,
    }
}

/// Quantization MSE of a tensor under a given static clip range — the
/// objective the MSE calibrator minimizes (paper §II-B-1).
pub fn quant_mse(x: &[f32], alpha: f32, bits: u32) -> f64 {
    let qmax = IntFmt::new(bits).qmax();
    let a = if alpha > 0.0 { alpha } else { 1.0 };
    let s = qmax / a;
    let mut acc = 0.0f64;
    for &v in x {
        let d = (int_qdq(v, s, qmax) - v) as f64;
        acc += d * d;
    }
    acc / x.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    #[test]
    fn grids_match_paper_formats() {
        assert_eq!(
            E2M1.grid(),
            vec![0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]
        );
        assert_eq!(
            E1M2.grid(),
            vec![0.0, 0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 3.5]
        );
        assert_eq!(E4M3.fmax(), 448.0);
        assert_eq!(INT4.qmax(), 7.0);
    }

    #[test]
    fn rne_ties_to_even() {
        assert_eq!(rne(0.5), 0.0);
        assert_eq!(rne(1.5), 2.0);
        assert_eq!(rne(2.5), 2.0);
        assert_eq!(rne(-0.5), -0.0);
        assert_eq!(rne(-1.5), -2.0);
    }

    #[test]
    fn bf16_round_known_values() {
        assert_eq!(bf16_round(1.0), 1.0);
        // 1.0039062 (1 + 2^-8) is exactly halfway between bf16 codes
        // 1.0 and 1.0078125; RNE ties to the even mantissa (1.0).
        assert_eq!(bf16_round(1.0 + 0.00390625), 1.0);
        // 1.01171875 = 1 + 1.5*2^-7 ties between mantissa codes 1 (odd)
        // and 2 (even): RNE picks the even one, 1.015625 (matches jnp).
        assert_eq!(bf16_round(1.01171875), 1.015625);
    }

    #[test]
    fn fp_round_on_grid_fixed_points() {
        for fmt in [E2M1, E1M2, E4M3] {
            for v in fmt.grid() {
                assert_eq!(fp_round(v, fmt), v, "{:?} {}", fmt, v);
                assert_eq!(fp_round(-v, fmt), -v);
            }
        }
    }

    #[test]
    fn fp_round_is_nearest_property() {
        prop::check("fp_round_nearest", 30, |rng| {
            for fmt in [E2M1, E1M2, E4M3] {
                let grid = fmt.grid();
                let x = (rng.gaussian()) * fmt.fmax() / 2.0;
                let y = fp_round(x, fmt);
                let best = grid
                    .iter()
                    .flat_map(|&g| [g, -g])
                    .map(|g| (g - x).abs())
                    .fold(f32::INFINITY, f32::min);
                prop_assert!(
                    (y - x).abs() <= best + 1e-6 * x.abs().max(1.0),
                    "{:?}: fp_round({}) = {} not nearest (best {})",
                    fmt,
                    x,
                    y,
                    best
                );
            }
            Ok(())
        });
    }

    #[test]
    fn fp_round_saturates() {
        assert_eq!(fp_round(1e30, E4M3), 448.0);
        assert_eq!(fp_round(-1e30, E2M1), -6.0);
    }

    #[test]
    fn int_qdq_clips() {
        assert_eq!(int_qdq(100.0, 1.0, 7.0), 7.0);
        assert_eq!(int_qdq(-100.0, 1.0, 7.0), -7.0);
        assert_eq!(int_qdq(0.4, 1.0, 7.0), 0.0);
    }

    #[test]
    fn abfp_never_clips_property() {
        prop::check("abfp_never_clips", 20, |rng| {
            let k = 128;
            let mut x = prop::heavy_vec(rng, 4 * k, 3.0);
            let orig = x.clone();
            abfp_qdq(&mut x, k, Format::Int(INT4), 64);
            // the absmax element of each vector survives within rounding
            for (rc, (row, orow)) in
                x.chunks(64).zip(orig.chunks(64)).enumerate()
            {
                let (mi, &mv) = orow
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
                    .unwrap();
                if mv.abs() > 1e-6 {
                    let rel = (row[mi] - mv).abs() / mv.abs();
                    prop_assert!(rel < 0.01, "chunk {} max lost: {}", rc, rel);
                }
            }
            Ok(())
        });
    }

    #[test]
    fn abfp_zero_rows_stay_zero() {
        let mut x = vec![0.0f32; 256];
        abfp_qdq(&mut x, 128, Format::Fp(E4M3), 64);
        assert!(x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn abfp2_never_clips_property() {
        prop::check("abfp2_never_clips", 20, |rng| {
            let k = 128;
            let mut x = prop::heavy_vec(rng, 4 * k, 3.0);
            let orig = x.clone();
            abfp2_qdq(&mut x, k, Format::Int(INT4), 64, 8);
            for (rc, (row, orow)) in x.chunks(64).zip(orig.chunks(64)).enumerate() {
                let (mi, &mv) = orow
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
                    .unwrap();
                if mv.abs() > 1e-6 {
                    let rel = (row[mi] - mv).abs() / mv.abs();
                    prop_assert!(rel < 0.02, "chunk {} max lost: {}", rc, rel);
                }
            }
            Ok(())
        });
    }

    #[test]
    fn abfp2_error_close_to_abfp_property() {
        prop::check("abfp2_error_vs_abfp", 15, |rng| {
            let k = 256;
            let x = prop::heavy_vec(rng, 8 * k, 2.0);
            let (mut a, mut b) = (x.clone(), x.clone());
            abfp_qdq(&mut a, k, Format::Int(INT4), 64);
            abfp2_qdq(&mut b, k, Format::Int(INT4), 64, 8);
            let mse = |y: &[f32]| -> f64 {
                y.iter()
                    .zip(&x)
                    .map(|(u, v)| ((u - v) as f64).powi(2))
                    .sum::<f64>()
                    / x.len() as f64
            };
            let (e1, e2) = (mse(&a), mse(&b));
            prop_assert!(e2 <= 2.5 * e1 + 1e-12, "abfp {} vs abfp2 {}", e1, e2);
            Ok(())
        });
    }

    #[test]
    fn abfp2_zero_rows_stay_zero() {
        let mut x = vec![0.0f32; 256];
        abfp2_qdq(&mut x, 128, Format::Fp(E4M3), 64, 8);
        assert!(x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn abfp2_high_scale_bits_converges_to_abfp() {
        // With many scale-code bits the reconstructed scale approaches the
        // bf16 absmax, so abfp2 error approaches plain-ABFP error.
        let mut rng = crate::util::rng::Pcg64::new(7);
        let k = 128;
        let x = prop::heavy_vec(&mut rng, 16 * k, 2.0);
        let mse = |y: &[f32]| -> f64 {
            y.iter()
                .zip(&x)
                .map(|(u, v)| ((u - v) as f64).powi(2))
                .sum::<f64>()
                / x.len() as f64
        };
        let mut a = x.clone();
        abfp_qdq(&mut a, k, Format::Int(INT4), 64);
        let mut prev = f64::INFINITY;
        for sb in [2u32, 4, 8] {
            let mut b = x.clone();
            abfp2_qdq(&mut b, k, Format::Int(INT4), 64, sb);
            let e = mse(&b);
            assert!(e <= prev * 1.001, "sb={} err {} prev {}", sb, e, prev);
            prev = e;
        }
        assert!((prev - mse(&a)).abs() / mse(&a) < 0.10);
    }

    #[test]
    fn scale_overhead_accounting() {
        // ABFP n=64: one bf16 per 64 payload elements = 0.25 bits/elt.
        assert_eq!(scale_overhead_bits(2048, 64, None), 0.25);
        // two-level n=64, 8-bit codes, k=2048 row: 8/64 + 16/2048.
        let got = scale_overhead_bits(2048, 64, Some(8));
        assert!((got - (0.125 + 0.0078125)).abs() < 1e-12);
        // Two-level wins once rows are wide enough to amortize the per-row
        // bf16 (k > 2n at 8-bit codes); at k == 2n it breaks even.
        for k in [512usize, 2048] {
            for n in [64usize, 128] {
                assert!(
                    scale_overhead_bits(k, n, Some(8))
                        < scale_overhead_bits(k, n, None),
                    "k={} n={}",
                    k,
                    n
                );
            }
        }
        assert_eq!(
            scale_overhead_bits(128, 64, Some(8)),
            scale_overhead_bits(128, 64, None)
        );
    }

    #[test]
    fn quant_mse_zero_when_representable() {
        // alpha=7 with int4 => scale 1, integers -7..7 are exact
        let x: Vec<f32> = (-7..=7).map(|v| v as f32).collect();
        assert_eq!(quant_mse(&x, 7.0, 4), 0.0);
        assert!(quant_mse(&x, 1.0, 4) > 0.0);
    }

    #[test]
    fn format_parse() {
        assert_eq!(Format::parse("int4"), Some(Format::Int(INT4)));
        assert_eq!(Format::parse("e4m3"), Some(Format::Fp(E4M3)));
        assert_eq!(Format::parse("e5m2"), Some(Format::Fp(E5M2)));
        // generic eXmY names mirror formats.py (nan_reserved off)
        assert_eq!(
            Format::parse("e3m4"),
            Some(Format::Fp(FpFmt::new(3, 4, false)))
        );
        assert!(Format::parse("nope").is_none());
        assert!(Format::parse("emx").is_none());
        // out-of-bounds widths are rejected, not constructed broken
        // (e8m2 and wider would overflow fmax() to f32 inf; e4m99 would
        // explode grid()); e7 is the widest exponent whose fmax is finite
        assert!(Format::parse("e8m2").is_none());
        assert!(Format::parse("e31m2").is_none());
        assert!(Format::parse("e0m2").is_none());
        assert!(Format::parse("e4m99").is_none());
        // non-canonical spellings must not shadow named constants with
        // different semantics (e04m3 would lose E4M3's NaN reservation)
        assert!(Format::parse("e04m3").is_none());
        assert!(Format::parse("e+4m3").is_none());
        // intN widths that cannot quantize are rejected, not constructed
        assert_eq!(Format::parse("int6"), Some(Format::Int(IntFmt::new(6))));
        assert!(Format::parse("int0").is_none());
        assert!(Format::parse("int1").is_none());
        assert!(Format::parse("int40").is_none());
        assert!(Format::parse("int04").is_none());
        match Format::parse("e7m3") {
            Some(Format::Fp(f)) => assert!(f.fmax().is_finite()),
            other => panic!("e7m3 should parse, got {:?}", other),
        }
    }

    // ---- quantizer property suite (bits/e/m sweeps) ----

    /// FpFmt sweep used by the property tests: the paper's formats plus
    /// off-grid e/m combinations and both NaN-reservation settings.
    fn fp_sweep() -> Vec<FpFmt> {
        vec![
            E2M1,
            E1M2,
            E4M3,
            E5M2,
            FpFmt::new(3, 2, false),
            FpFmt::new(2, 3, true),
            FpFmt::new(5, 2, true),
            FpFmt::new(3, 4, false),
        ]
    }

    fn bits_sweep() -> Vec<u32> {
        vec![2, 3, 4, 6, 8]
    }

    #[test]
    fn qdq_idempotent_property() {
        // quantize -> dequantize -> quantize must be a fixed point, bit
        // for bit: the second pass re-quantizes exactly onto the same
        // code (the defining property of fake-quant simulation).
        prop::check("qdq_idempotent", 25, |rng| {
            let alpha = 0.25 + 7.75 * rng.f32();
            for bits in bits_sweep() {
                let qmax = IntFmt::new(bits).qmax();
                let s = qmax / alpha;
                for _ in 0..16 {
                    let x = rng.gaussian() * rng.lognormal(1.0);
                    let once = int_qdq(x, s, qmax);
                    let twice = int_qdq(once, s, qmax);
                    prop_assert!(
                        once.to_bits() == twice.to_bits(),
                        "int{} s={}: {} -> {} -> {}",
                        bits,
                        s,
                        x,
                        once,
                        twice
                    );
                }
            }
            for fmt in fp_sweep() {
                let s = fmt.fmax() / alpha;
                for _ in 0..16 {
                    let x = rng.gaussian() * rng.lognormal(1.0);
                    let ronce = fp_round(x, fmt);
                    prop_assert!(
                        ronce.to_bits() == fp_round(ronce, fmt).to_bits(),
                        "{:?}: fp_round not idempotent at {}",
                        fmt,
                        x
                    );
                    let once = fp_qdq(x, s, fmt);
                    let twice = fp_qdq(once, s, fmt);
                    prop_assert!(
                        once.to_bits() == twice.to_bits(),
                        "{:?} s={}: {} -> {} -> {}",
                        fmt,
                        s,
                        x,
                        once,
                        twice
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn fp_round_output_in_grid_property() {
        // every fp_round output must be a representable value of the
        // format: a member of grid() (up to sign), never something
        // in-between and never beyond fmax.
        prop::check("fp_round_in_grid", 25, |rng| {
            for fmt in fp_sweep() {
                let grid = fmt.grid();
                for _ in 0..24 {
                    // span subnormals through saturation
                    let x = rng.gaussian() * fmt.fmax() * rng.lognormal(2.0) / 4.0;
                    let y = fp_round(x, fmt);
                    prop_assert!(
                        grid.iter().any(|&g| g.to_bits() == y.abs().to_bits()),
                        "{:?}: fp_round({}) = {} not on the grid",
                        fmt,
                        x,
                        y
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn qdq_monotone_property() {
        // x1 <= x2 implies q(x1) <= q(x2): RNE, clamping and positive
        // scaling are all monotone, and any violation would reorder
        // values across the quantization boundary.
        prop::check("qdq_monotone", 25, |rng| {
            let alpha = 0.25 + 7.75 * rng.f32();
            for _ in 0..24 {
                let a = rng.gaussian() * rng.lognormal(1.0);
                let b = rng.gaussian() * rng.lognormal(1.0);
                let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
                for bits in bits_sweep() {
                    let qmax = IntFmt::new(bits).qmax();
                    let s = qmax / alpha;
                    prop_assert!(
                        int_qdq(lo, s, qmax) <= int_qdq(hi, s, qmax),
                        "int{}: qdq({}) > qdq({})",
                        bits,
                        lo,
                        hi
                    );
                }
                for fmt in fp_sweep() {
                    prop_assert!(
                        fp_round(lo, fmt) <= fp_round(hi, fmt),
                        "{:?}: fp_round({}) > fp_round({})",
                        fmt,
                        lo,
                        hi
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn qdq_respects_clip_bounds_property() {
        // outputs never escape the clip range: |int_qdq| <= qmax/s and
        // |fp_qdq| <= fmax/s (saturation, paper Eqns 1-3).
        prop::check("qdq_clip_bounds", 25, |rng| {
            let alpha = 0.25 + 7.75 * rng.f32();
            for _ in 0..24 {
                // include magnitudes far beyond the clip range
                let x = rng.gaussian() * rng.lognormal(2.0) * 100.0;
                for bits in bits_sweep() {
                    let qmax = IntFmt::new(bits).qmax();
                    let s = qmax / alpha;
                    let y = int_qdq(x, s, qmax);
                    prop_assert!(
                        y.abs() <= qmax / s,
                        "int{}: |{}| > {}",
                        bits,
                        y,
                        qmax / s
                    );
                }
                for fmt in fp_sweep() {
                    let s = fmt.fmax() / alpha;
                    let y = fp_qdq(x, s, fmt);
                    prop_assert!(
                        y.abs() <= fmt.fmax() / s,
                        "{:?}: |{}| > {}",
                        fmt,
                        y,
                        fmt.fmax() / s
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn e5m2_follows_repo_convention() {
        // finite-only convention (formats.py): full top binade usable
        assert_eq!(E5M2.fmax(), 114688.0);
        assert_eq!(E5M2.bias(), 15);
        assert_eq!(E5M2.emin(), -14);
        // 3 subnormals + 31 binades x 4 mantissa codes + zero
        assert_eq!(E5M2.grid().len(), 128);
        assert_eq!(E5M2.grid()[1], 2.0f32.powi(-16)); // smallest subnormal
    }
}
