//! Post-training-quantization accuracy-recovery methods (paper §II-B):
//! SmoothQuant (difficulty migration), GPTQ (second-order weight
//! compression) and RPTQ (channel-cluster activation scales).
//!
//! All three are *host-side transforms*: they rewrite the weights and/or
//! the per-site runtime inputs (smoothing vectors, clip-range vectors)
//! that the eval artifacts consume — no re-lowering required.

pub mod gptq;
pub mod rptq;
pub mod smoothquant;

use anyhow::{bail, Result};

/// The weight parameter feeding each quantized site `l{i}.{site}`.
pub fn site_weight_param(site: &str) -> Result<String> {
    let (layer, kind) = site
        .split_once('.')
        .ok_or_else(|| anyhow::anyhow!("bad site name {}", site))?;
    let w = match kind {
        "qkv" => "wqkv",
        "attn_out" => "wo",
        "fc1" => "wfc1",
        "fc2" => "wfc2",
        other => bail!("unknown site kind {}", other),
    };
    Ok(format!("{}.{}", layer, w))
}

/// The bias parameter of each quantized site (the native executor binds
/// both halves of every site linear).
pub fn site_bias_param(site: &str) -> Result<String> {
    let (layer, kind) = site
        .split_once('.')
        .ok_or_else(|| anyhow::anyhow!("bad site name {}", site))?;
    let b = match kind {
        "qkv" => "bqkv",
        "attn_out" => "bo",
        "fc1" => "bfc1",
        "fc2" => "bfc2",
        other => bail!("unknown site kind {}", other),
    };
    Ok(format!("{}.{}", layer, b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn site_weight_mapping() {
        assert_eq!(site_weight_param("l0.qkv").unwrap(), "l0.wqkv");
        assert_eq!(site_weight_param("l3.fc2").unwrap(), "l3.wfc2");
        assert!(site_weight_param("nonsense").is_err());
        assert_eq!(site_bias_param("l0.qkv").unwrap(), "l0.bqkv");
        assert_eq!(site_bias_param("l2.attn_out").unwrap(), "l2.bo");
        assert!(site_bias_param("l0.what").is_err());
    }
}
