//! GPTQ (paper §II-B-4, [3]): post-training weight quantization using
//! approximate second-order (Hessian) information.
//!
//! For each weight-bearing site with weights W (dout, din) and calibration
//! activations X (N, din):
//!   H = 2 X^T X + λI                 (λ: 1% of mean diagonal, as in [3])
//!   C = chol(H^{-1})  (upper)        — the error-propagation operator
//!   for each column j in order:
//!     q_j   = quant_int4(W[:, j])    (per-output-row scale from original W)
//!     err_j = (W[:, j] - q_j) / C[j,j]
//!     W[:, j+k] -= err_j · C[j, j+k]   for all remaining columns k>0
//! The result is fully-quantized (then de-quantized) f32 weights that the
//! unmodified `eval_fp32` artifact consumes — GPTQ's W4A16 configuration.

use anyhow::{Context, Result};

use crate::calib::CalibStats;
use crate::formats::{int_qdq, INT4};
use crate::runtime::manifest::ModelCfg;
use crate::tensor::io::TensorStore;
use crate::tensor::{spd_inverse, Tensor};

use super::site_weight_param;

/// Quantize all site weights in-place with GPTQ; returns the transformed
/// checkpoint (other params untouched).
pub fn apply(cfg: &ModelCfg, params: &TensorStore, stats: &CalibStats) -> Result<TensorStore> {
    let mut out = params.clone();
    // One backend handle for the whole checkpoint: with the `pool`
    // backend this reuses a single persistent worker pool across every
    // site's Gram build and tail updates (no per-site teardown).
    let be = crate::tensor::backend::active();
    for site in &cfg.sites {
        let wname = site_weight_param(&site.name)?;
        let w = out
            .get_mut(&wname)
            .with_context(|| format!("weight {} missing", wname))?;
        let x = stats
            .acts
            .get(&site.name)
            .with_context(|| format!("no calibration acts for {}", site.name))?;
        // Hessian estimation needs only O(din) rows; stride-subsample the
        // calibration stream so the X^T X accumulation stays O(din^3)-ish
        // for the widest sites (matches GPTQ's ~128-sample practice).
        let max_rows = 2048;
        let (rows, din) = x.dims2();
        if rows > max_rows {
            let stride = rows.div_ceil(max_rows);
            let mut data = Vec::with_capacity((rows / stride + 1) * din);
            for r in (0..rows).step_by(stride) {
                data.extend_from_slice(x.row(r));
            }
            let sub = Tensor::new(vec![data.len() / din, din], data);
            gptq_site_with(w, &sub, be.as_ref())?;
        } else {
            gptq_site_with(w, x, be.as_ref())?;
        }
    }
    Ok(out)
}

/// Cholesky (upper) of the inverse Hessian, with escalating damping.
fn chol_inv_upper(h: &Tensor) -> Result<Tensor> {
    let (n, _) = h.dims2();
    let mean_diag: f64 =
        (0..n).map(|i| h.at2(i, i) as f64).sum::<f64>() / n as f64;
    let mut damp = 0.01 * mean_diag.max(1e-8);
    for _ in 0..8 {
        let mut hd = h.clone();
        for i in 0..n {
            hd.data[i * n + i] += damp as f32;
        }
        if let Some(hinv) = spd_inverse(&hd) {
            if let Some(l) = crate::tensor::cholesky(&hinv) {
                return Ok(l.transpose()); // upper
            }
        }
        damp *= 10.0;
    }
    anyhow::bail!("Hessian not invertible even with damping");
}

/// One site: W (dout, din) quantized column-by-column with error
/// compensation into the not-yet-quantized columns, on the active
/// backend.
pub fn gptq_site(w: &mut Tensor, x: &Tensor) -> Result<()> {
    let be = crate::tensor::backend::active();
    gptq_site_with(w, x, be.as_ref())
}

/// [`gptq_site`] on an explicit backend handle — `apply` hoists one
/// handle across the per-site loop so a worker-pool backend is reused
/// rather than re-resolved per site. The Gram/Hessian build and the
/// rank-B tail updates below are the transform's hot paths.
pub fn gptq_site_with(
    w: &mut Tensor,
    x: &Tensor,
    be: &dyn crate::tensor::backend::Backend,
) -> Result<()> {
    let (dout, din) = w.dims2();
    anyhow::ensure!(x.shape[1] == din, "X cols {} != W din {}", x.shape[1], din);
    let mut h = be.gram(x); // X^T X
    for v in h.data.iter_mut() {
        *v *= 2.0;
    }
    let u = chol_inv_upper(&h)?; // (din, din) upper

    // Per-output-row INT4 scales frozen from the ORIGINAL weights
    // (GPTQ keeps the quantization grid fixed while compensating).
    let qmax = INT4.qmax();
    let scales: Vec<f32> = (0..dout)
        .map(|r| {
            let a = w.row(r).iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            qmax / if a > 0.0 { a } else { 1.0 }
        })
        .collect();

    // §Perf L3 iteration 2 (EXPERIMENTS.md): lazy batch updates (the GPTQ
    // paper's own optimization).  Quantize columns in blocks of B; inside
    // a block propagate errors only within the block, then apply the
    // accumulated rank-B update to the tail columns row by row.  Per
    // (r, k) element the subtractions still happen in ascending-j order,
    // so the result is bit-identical to the column-at-a-time loop — the
    // win is pure locality: each W row tail stays in cache for B error
    // vectors instead of being evicted between columns.
    const BLOCK: usize = 64;
    let mut eblk = vec![0.0f32; dout * BLOCK];
    for j0 in (0..din).step_by(BLOCK) {
        let jend = (j0 + BLOCK).min(din);
        let bw = jend - j0;
        for j in j0..jend {
            let ujj = u.at2(j, j);
            anyhow::ensure!(ujj.abs() > 1e-20, "degenerate pivot at {}", j);
            let urow = u.row(j);
            for r in 0..dout {
                let wj = w.at2(r, j);
                let q = int_qdq(wj, scales[r], qmax);
                let e = (wj - q) / ujj;
                eblk[r * BLOCK + (j - j0)] = e;
                let wrow = w.row_mut(r);
                wrow[j] = q;
                if e != 0.0 {
                    // propagate within the block only
                    for (wv, uv) in
                        wrow[j + 1..jend].iter_mut().zip(&urow[j + 1..jend])
                    {
                        *wv -= e * uv;
                    }
                }
            }
        }
        // rank-bw tail update: W[r, jend..] -= Σ_j eblk[r, j] · U[j, jend..],
        // tiled over tail columns so the (bw × KTILE) U tile stays L2-hot
        // across all dout rows while each W row tile streams through once.
        const KTILE: usize = 512;
        let mut k0 = jend;
        while k0 < din {
            let kend = (k0 + KTILE).min(din);
            for r in 0..dout {
                let erow = &eblk[r * BLOCK..r * BLOCK + bw];
                let wrow = w.row_mut(r);
                for (bj, &e) in erow.iter().enumerate() {
                    if e == 0.0 {
                        continue;
                    }
                    // w[r, k0..kend] -= e * U[j, k0..kend]: IEEE-identical
                    // to the fused loop (x - e*u == x + (-e)*u exactly).
                    let urow = u.row(j0 + bj);
                    be.axpy(-e, &urow[k0..kend], &mut wrow[k0..kend]);
                }
            }
            k0 = kend;
        }
    }
    Ok(())
}

/// Nearest-rounding baseline (per-output-row max scales, no error
/// compensation) — the ablation GPTQ is measured against.
pub fn nearest_site(w: &mut Tensor) {
    let (dout, din) = w.dims2();
    let qmax = INT4.qmax();
    let _ = din;
    for r in 0..dout {
        let a = w.row(r).iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let s = qmax / if a > 0.0 { a } else { 1.0 };
        for v in w.row_mut(r) {
            *v = int_qdq(*v, s, qmax);
        }
    }
}

/// Layer-output MSE proxy: ||X W^T - X Ŵ^T||² / numel — the objective
/// GPTQ minimizes; used by tests and the ablation bench.
pub fn layer_mse(x: &Tensor, w_orig: &Tensor, w_quant: &Tensor) -> f64 {
    let y1 = x.matmul_t(w_orig);
    let y2 = x.matmul_t(w_quant);
    y1.mse(&y2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn gptq_beats_nearest_rounding() {
        // The defining property of GPTQ: on correlated inputs, error
        // compensation yields strictly lower layer-output MSE than
        // nearest rounding.
        prop::check("gptq_beats_rtn", 8, |rng| {
            let (n, din, dout) = (64, 16, 12);
            // correlated activations: x = z A with random mixing A
            let z = Tensor::new(vec![n, din], prop::heavy_vec(rng, n * din, 1.0));
            let a = Tensor::new(vec![din, din], prop::heavy_vec(rng, din * din, 0.5));
            let x = z.matmul(&a);
            let w = Tensor::new(vec![dout, din], prop::heavy_vec(rng, dout * din, 0.3));

            let mut w_rtn = w.clone();
            nearest_site(&mut w_rtn);
            let mut w_gptq = w.clone();
            gptq_site(&mut w_gptq, &x).unwrap();

            let mse_rtn = layer_mse(&x, &w, &w_rtn);
            let mse_gptq = layer_mse(&x, &w, &w_gptq);
            crate::prop_assert!(
                mse_gptq <= mse_rtn * 1.05,
                "gptq {} worse than rtn {}",
                mse_gptq,
                mse_rtn
            );
            Ok(())
        });
    }

    #[test]
    fn gptq_output_on_int4_grid() {
        // every output value must live on its row's INT4 grid
        let mut rng = crate::util::rng::Pcg64::new(3);
        let x = Tensor::new(vec![32, 8], prop::heavy_vec(&mut rng, 32 * 8, 1.0));
        let w = Tensor::new(vec![4, 8], prop::heavy_vec(&mut rng, 32, 0.5));
        let orig = w.clone();
        let mut wq = w;
        gptq_site(&mut wq, &x).unwrap();
        let qmax = INT4.qmax();
        for r in 0..4 {
            let a = orig.row(r).iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let s = qmax / a;
            for &v in wq.row(r) {
                let q = v * s;
                assert!(
                    (q - q.round()).abs() < 1e-3 && q.abs() <= qmax + 1e-3,
                    "row {} value {} not on grid (q={})",
                    r,
                    v,
                    q
                );
            }
        }
    }

    #[test]
    fn uncorrelated_inputs_reduce_to_rtn() {
        // with H ≈ diagonal the compensation term is ~0, so GPTQ ≈ RTN.
        let n = 4096;
        let mut rng = crate::util::rng::Pcg64::new(9);
        let din = 6;
        let mut xd = vec![0.0f32; n * din];
        for (i, v) in xd.iter_mut().enumerate() {
            // one-hot-ish rows: only a single active channel per row
            if i % din == (i / din) % din {
                *v = rng.gaussian();
            }
        }
        let x = Tensor::new(vec![n, din], xd);
        let w = Tensor::new(vec![3, din], prop::heavy_vec(&mut rng, 3 * din, 0.4));
        let mut w_rtn = w.clone();
        nearest_site(&mut w_rtn);
        let mut w_gptq = w.clone();
        gptq_site(&mut w_gptq, &x).unwrap();
        for (a, b) in w_gptq.data.iter().zip(w_rtn.data.iter()) {
            assert!((a - b).abs() < 0.05, "{} vs {}", a, b);
        }
    }
}

#[cfg(test)]
mod perf_probe {
    use super::*;
    use crate::util::prop;

    #[test]
    #[ignore] // run explicitly: cargo test --release -- --ignored perf_probe
    fn gptq_breakdown() {
        let mut rng = crate::util::rng::Pcg64::new(1);
        let (rows, din, dout) = (2048usize, 2048usize, 512usize);
        let x = Tensor::new(vec![rows, din], prop::heavy_vec(&mut rng, rows * din, 1.0));
        let w = Tensor::new(vec![dout, din], prop::heavy_vec(&mut rng, dout * din, 0.3));
        let t0 = std::time::Instant::now();
        let mut h = x.gram();
        eprintln!("gram:      {:.2}s", t0.elapsed().as_secs_f64());
        for v in h.data.iter_mut() { *v *= 2.0; }
        // damp like chol_inv_upper does, so plain cholesky succeeds
        let n = h.shape[0];
        let md: f64 = (0..n).map(|i| h.at2(i, i) as f64).sum::<f64>() / n as f64;
        for i in 0..n { h.data[i * n + i] += (0.01 * md) as f32; }
        let t1 = std::time::Instant::now();
        let l = crate::tensor::cholesky(&h).unwrap();
        eprintln!("cholesky:  {:.2}s", t1.elapsed().as_secs_f64());
        let t2 = std::time::Instant::now();
        let hinv = crate::tensor::spd_inverse(&h).unwrap();
        eprintln!("spd_inv:   {:.2}s", t2.elapsed().as_secs_f64());
        let t3 = std::time::Instant::now();
        let _u = crate::tensor::cholesky(&hinv).unwrap().transpose();
        eprintln!("chol(inv): {:.2}s", t3.elapsed().as_secs_f64());
        let t4 = std::time::Instant::now();
        let mut wq = w.clone();
        gptq_site(&mut wq, &x).unwrap();
        eprintln!("full site: {:.2}s", t4.elapsed().as_secs_f64());
        let _ = l;
    }
}
