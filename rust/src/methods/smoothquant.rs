//! SmoothQuant (paper §II-B-3, [1]): migrate quantization difficulty
//! from activations to weights.
//!
//! Per input channel j of each quantized linear:
//!     s_j = max|X_j|^α / max|W_j|^(1-α),      α = 0.5 (paper setting)
//! then X' = X / s  and  W' = W · diag(s), which leaves X·W^T exactly
//! unchanged in full precision but evens out channel magnitudes so both
//! tensors quantize better.
//!
//! The runtime wiring: eval artifacts multiply activations by a per-site
//! `smooth.<site>` vector before the quantizer, so we hand them 1/s and
//! upload the scaled weights.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::calib::CalibStats;
use crate::runtime::manifest::ModelCfg;
use crate::runtime::Val;
use crate::tensor::io::TensorStore;

use super::site_weight_param;

pub const ALPHA: f64 = 0.5;

/// Result: transformed weights + the per-site activation multipliers.
pub struct Smoothed {
    pub params: TensorStore,
    /// site -> the 1/s vector the artifact multiplies activations by
    pub smooth: BTreeMap<String, Vec<f32>>,
}

pub fn apply(
    cfg: &ModelCfg,
    params: &TensorStore,
    stats: &CalibStats,
) -> Result<Smoothed> {
    let mut out = params.clone();
    let mut smooth = BTreeMap::new();
    for site in &cfg.sites {
        let wname = site_weight_param(&site.name)?;
        let w = out
            .get_mut(&wname)
            .with_context(|| format!("weight {} missing", wname))?;
        let (_, din) = w.dims2();
        let act_max = stats.channel_absmax(&site.name)?;
        anyhow::ensure!(act_max.len() == din, "channel count mismatch at {}", site.name);
        // per input channel absmax of W: column absmax of (dout, din)
        let w_max = w.col_absmax();
        let mut s = vec![1.0f32; din];
        let mut inv = vec![1.0f32; din];
        for j in 0..din {
            let a = act_max[j].max(1e-8) as f64;
            let ww = w_max[j].max(1e-8) as f64;
            let sj = (a.powf(ALPHA) / ww.powf(1.0 - ALPHA)).max(1e-4) as f32;
            s[j] = sj;
            inv[j] = 1.0 / sj;
        }
        w.scale_cols(&s);
        smooth.insert(site.name.clone(), inv);
    }
    Ok(Smoothed { params: out, smooth })
}

/// Identity smoothing vectors (for plain-ABFP artifacts).
pub fn identity_smooth(cfg: &ModelCfg) -> BTreeMap<String, Vec<f32>> {
    cfg.sites
        .iter()
        .map(|s| (s.name.clone(), vec![1.0f32; s.dim]))
        .collect()
}

/// Build `smooth.<site>` sticky inputs from smoothing vectors.
pub fn smooth_vals(smooth: &BTreeMap<String, Vec<f32>>) -> BTreeMap<String, Val> {
    smooth
        .iter()
        .map(|(site, v)| {
            (format!("smooth.{}", site), Val::F32(v.clone(), vec![v.len()]))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{ParamSpec, SiteSpec};
    use crate::tensor::Tensor;
    use crate::util::prop;

    fn cfg_1site(din: usize, dout: usize) -> ModelCfg {
        ModelCfg {
            name: "t".into(),
            arch: "opt".into(),
            task: "lm".into(),
            stands_for: String::new(),
            vocab: 8,
            d: din,
            layers: 1,
            heads: 1,
            d_ff: 4 * din,
            seq: 4,
            batch: 1,
            image: 0,
            patch: 0,
            channels: 0,
            classes: 0,
            params: vec![ParamSpec {
                name: "l0.wqkv".into(),
                shape: vec![dout, din],
                init: "normal".into(),
            }],
            sites: vec![SiteSpec { name: "l0.qkv".into(), dim: din }],
        }
    }

    #[test]
    fn smoothing_preserves_product_exactly_in_f64() {
        prop::check("sq_preserves_product", 10, |rng| {
            let (din, dout, rows) = (8, 6, 5);
            let cfg = cfg_1site(din, dout);
            let mut params = TensorStore::default();
            let w = Tensor::new(vec![dout, din], prop::heavy_vec(rng, dout * din, 1.0));
            params.insert("l0.wqkv", w.clone());
            let x = Tensor::new(vec![rows, din], prop::heavy_vec(rng, rows * din, 4.0));
            let stats = CalibStats {
                acts: [("l0.qkv".to_string(), x.clone())].into_iter().collect(),
            };
            let sm = apply(&cfg, &params, &stats).unwrap();
            // (x * inv_s) @ (W diag(s))^T == x @ W^T up to f32 rounding
            let mut xs = x.clone();
            xs.scale_cols(&sm.smooth["l0.qkv"]);
            let w2 = sm.params.get("l0.wqkv").unwrap();
            let y1 = x.matmul_t(&w);
            let y2 = xs.matmul_t(w2);
            for (a, b) in y1.data.iter().zip(y2.data.iter()) {
                crate::prop_assert!(
                    (a - b).abs() <= 2e-3 * (1.0 + a.abs()),
                    "product changed: {} vs {}",
                    a,
                    b
                );
            }
            Ok(())
        });
    }

    #[test]
    fn smoothing_evens_channel_ranges() {
        // a channel with huge activations gets its weight scaled up and
        // its activation multiplier scaled down.
        let cfg = cfg_1site(4, 3);
        let mut params = TensorStore::default();
        params.insert("l0.wqkv", Tensor::full(vec![3, 4], 1.0));
        let mut acts = Tensor::full(vec![10, 4], 1.0);
        for r in 0..10 {
            acts.set2(r, 2, 100.0); // outlier channel 2
        }
        let stats = CalibStats {
            acts: [("l0.qkv".to_string(), acts)].into_iter().collect(),
        };
        let sm = apply(&cfg, &params, &stats).unwrap();
        let inv = &sm.smooth["l0.qkv"];
        assert!(inv[2] < inv[0], "outlier channel must shrink: {:?}", inv);
        let w2 = sm.params.get("l0.wqkv").unwrap();
        assert!(w2.at2(0, 2) > w2.at2(0, 0));
    }
}
