//! RPTQ (paper §II-B-5, [4]): reorder-based post-training quantization.
//!
//! Observation: activation channels have wildly different ranges, so one
//! per-tensor scale wastes most of the integer grid on most channels.
//! RPTQ clusters channels by range and quantizes each cluster with its
//! own scale (the *reordering* groups cluster members contiguously in
//! memory — a locality optimization that is numerically equivalent to
//! per-channel scales shared within each cluster, which is how we express
//! it: the `rptq_*` artifacts take a per-channel `alpha.<site>` vector).
//!
//! Clustering: 1-D k-means on log-range, K = 8 (RPTQ's R3 setting scale).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::calib::CalibStats;
use crate::runtime::manifest::ModelCfg;
use crate::runtime::Val;

pub const K_CLUSTERS: usize = 8;
const KMEANS_ITERS: usize = 25;

/// 1-D k-means over values; returns cluster assignment per element.
pub fn kmeans_1d(values: &[f64], k: usize) -> Vec<usize> {
    let n = values.len();
    let k = k.min(n.max(1));
    let mut sorted: Vec<f64> = values.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // init centroids at quantiles
    let mut centroids: Vec<f64> = (0..k)
        .map(|i| sorted[(i * (n - 1)) / k.max(1)])
        .collect();
    let mut assign = vec![0usize; n];
    for _ in 0..KMEANS_ITERS {
        // assign
        for (i, &v) in values.iter().enumerate() {
            let mut best = 0;
            let mut bd = f64::INFINITY;
            for (c, &ct) in centroids.iter().enumerate() {
                let d = (v - ct).abs();
                if d < bd {
                    bd = d;
                    best = c;
                }
            }
            assign[i] = best;
        }
        // update
        let mut sums = vec![0.0f64; k];
        let mut counts = vec![0usize; k];
        for (i, &v) in values.iter().enumerate() {
            sums[assign[i]] += v;
            counts[assign[i]] += 1;
        }
        for c in 0..k {
            if counts[c] > 0 {
                centroids[c] = sums[c] / counts[c] as f64;
            }
        }
    }
    assign
}

/// Per-channel clip-range vector for one site: channels share their
/// cluster's max range.
pub fn cluster_alphas(channel_absmax: &[f32], k: usize) -> Vec<f32> {
    let logs: Vec<f64> = channel_absmax
        .iter()
        .map(|&a| (a.max(1e-8) as f64).ln())
        .collect();
    let assign = kmeans_1d(&logs, k);
    let nclusters = assign.iter().copied().max().unwrap_or(0) + 1;
    let mut cluster_max = vec![0.0f32; nclusters];
    for (j, &c) in assign.iter().enumerate() {
        cluster_max[c] = cluster_max[c].max(channel_absmax[j]);
    }
    assign
        .iter()
        .map(|&c| if cluster_max[c] > 0.0 { cluster_max[c] } else { 1.0 })
        .collect()
}

/// Build per-site `alpha.<site>` vectors for an `rptq_*` artifact.
pub fn site_alpha_vals(
    cfg: &ModelCfg,
    stats: &CalibStats,
) -> Result<BTreeMap<String, Val>> {
    let mut out = BTreeMap::new();
    for site in &cfg.sites {
        let ranges = stats.channel_absmax(&site.name)?;
        let alphas = cluster_alphas(&ranges, K_CLUSTERS);
        out.insert(
            format!("alpha.{}", site.name),
            Val::F32(alphas, vec![site.dim]),
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::quant_mse;
    use crate::util::prop;

    #[test]
    fn kmeans_separates_obvious_clusters() {
        let vals: Vec<f64> =
            vec![0.1, 0.11, 0.12, 5.0, 5.1, 5.2, 100.0, 101.0, 99.5];
        let a = kmeans_1d(&vals, 3);
        assert_eq!(a[0], a[1]);
        assert_eq!(a[1], a[2]);
        assert_eq!(a[3], a[4]);
        assert_ne!(a[0], a[3]);
        assert_ne!(a[3], a[6]);
    }

    #[test]
    fn cluster_alphas_cover_every_channel() {
        prop::check("rptq_alphas_cover", 10, |rng| {
            let ranges: Vec<f32> =
                (0..64).map(|_| rng.lognormal(2.0) + 1e-3).collect();
            let alphas = cluster_alphas(&ranges, 8);
            for (j, (&a, &r)) in alphas.iter().zip(ranges.iter()).enumerate() {
                crate::prop_assert!(
                    a >= r * 0.999,
                    "channel {} alpha {} below its range {}",
                    j,
                    a,
                    r
                );
            }
            // at most 8 distinct scale values
            let mut distinct: Vec<f32> = alphas.clone();
            distinct.sort_by(|a, b| a.partial_cmp(b).unwrap());
            distinct.dedup();
            crate::prop_assert!(distinct.len() <= 8, "too many scales");
            Ok(())
        });
    }

    #[test]
    fn clustered_scales_beat_per_tensor_on_spread_channels() {
        // RPTQ's motivating case: channels with very different ranges.
        let mut rng = crate::util::rng::Pcg64::new(5);
        let (rows, cols) = (64, 32);
        let mut x = vec![0.0f32; rows * cols];
        let chan_scale: Vec<f32> =
            (0..cols).map(|j| 10.0f32.powi((j % 4) as i32 - 2)).collect();
        for r in 0..rows {
            for (c, cs) in chan_scale.iter().enumerate() {
                x[r * cols + c] = rng.gaussian() * cs;
            }
        }
        // per-tensor MSE with alpha = absmax
        let absmax = x.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        let mse_pt = quant_mse(&x, absmax, 4);
        // clustered per-channel: quantize each channel with its alpha
        let mut ranges = vec![0.0f32; cols];
        for r in 0..rows {
            for c in 0..cols {
                ranges[c] = ranges[c].max(x[r * cols + c].abs());
            }
        }
        let alphas = cluster_alphas(&ranges, 8);
        // Compare *channel-normalized* error (error relative to each
        // channel's signal power): absolute MSE is dominated by the
        // largest channels either way, but RPTQ's win is that small
        // channels stop being flattened to zero.
        let mut rel_cl = 0.0f64;
        let mut rel_pt = 0.0f64;
        for c in 0..cols {
            let col: Vec<f32> = (0..rows).map(|r| x[r * cols + c]).collect();
            let power: f64 =
                col.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
                    / rows as f64;
            rel_cl += quant_mse(&col, alphas[c], 4) / power;
            rel_pt += quant_mse(&col, absmax, 4) / power;
        }
        let _ = mse_pt;
        assert!(
            rel_cl < rel_pt * 0.1,
            "clustered rel-err {} not ≪ per-tensor {}",
            rel_cl,
            rel_pt
        );
    }
}
