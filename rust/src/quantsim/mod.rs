//! The simulator facade — INT-FP-QSim's public API (paper §III).
//!
//! A [`QuantConfig`] picks the numeric configuration (which lowered
//! artifact simulates it) plus an optional accuracy-recovery method; the
//! [`Simulator`] assembles weights, smoothing vectors and calibrated clip
//! ranges, opens a runtime session (the Rust analog of "replace the
//! layers with quantizer-wrapped versions") and evaluates the model's
//! task metric.
//!
//! ```text
//! Simulator::new("artifacts", "checkpoints")?
//!     .evaluate("sim-opt-125m", &QuantConfig::abfp("abfp_w4a4_n64"))?
//! ```

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::rc::Rc;

use anyhow::{bail, Result};

use crate::calib::{self, CalibStats};
use crate::corpus::{CodeCorpus, ImageCorpus, QaCorpus, TextCorpus};
use crate::eval;
use crate::info;
use crate::methods::{gptq, rptq, smoothquant};
use crate::model::{self, CkptDir};
use crate::runtime::{Runtime, Session};
use crate::tensor::io::TensorStore;
use crate::train::{self, TrainOpts};

/// Accuracy-recovery method applied on top of the numeric config.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Method {
    /// Plain PTQ: dynamic ABFP or static calibration, no transform.
    None,
    /// SmoothQuant α=0.5 difficulty migration (weights + smooth inputs).
    SmoothQuant,
    /// GPTQ second-order weight compression (W4, high-precision acts).
    Gptq,
    /// RPTQ channel-cluster activation scales.
    Rptq,
    /// QAT: evaluate the checkpoint fine-tuned with this quant config.
    Qat,
}

#[derive(Debug, Clone)]
pub struct QuantConfig {
    /// Quantizer configuration name from the artifact matrix
    /// (`fp32`, `abfp_w4a4_n64`, `mse_w4a8`, `rptq_w4a4`, ...).
    pub quant: String,
    pub method: Method,
}

impl QuantConfig {
    pub fn fp32() -> QuantConfig {
        QuantConfig { quant: "fp32".into(), method: Method::None }
    }

    pub fn abfp(quant: &str) -> QuantConfig {
        QuantConfig { quant: quant.into(), method: Method::None }
    }

    pub fn with(quant: &str, method: Method) -> QuantConfig {
        QuantConfig { quant: quant.into(), method }
    }

    /// Label used in reports, mirroring the paper's column names.
    pub fn label(&self) -> String {
        match self.method {
            Method::None => self.quant.clone(),
            Method::SmoothQuant => format!("{}+SQ", self.quant),
            Method::Gptq => "gptq_w4a16".to_string(),
            Method::Rptq => self.quant.clone(),
            Method::Qat => format!("{}+QAT", self.quant),
        }
    }
}

/// A metric value tagged with its kind (lower-is-better PPL vs
/// higher-is-better percentages).
#[derive(Debug, Clone, Copy)]
pub struct Metric {
    pub value: f64,
    pub kind: MetricKind,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    Ppl,
    PassAt1,
    F1,
    Accuracy,
}

impl MetricKind {
    pub fn name(&self) -> &'static str {
        match self {
            MetricKind::Ppl => "PPL",
            MetricKind::PassAt1 => "Pass@1",
            MetricKind::F1 => "F1",
            MetricKind::Accuracy => "Acc",
        }
    }

    pub fn lower_is_better(&self) -> bool {
        matches!(self, MetricKind::Ppl)
    }
}

/// Relative performance vs an FP32 baseline (Fig. 1's y-axis): 1.0 means
/// "matches FP32"; for PPL the ratio inverts so higher is always better.
pub fn relative_to_fp32(q: Metric, fp32: Metric) -> f64 {
    match q.kind {
        MetricKind::Ppl => fp32.value / q.value,
        _ => q.value / fp32.value.max(1e-9),
    }
}

#[derive(Debug, Clone)]
pub struct EvalOpts {
    pub eval_batches: u64,
    pub pass1_programs: usize,
    pub qat_opts: TrainOpts,
    /// FP32 pretraining options for `Simulator::weights` (the native
    /// executor actually runs these steps host-side; tests and `--fast`
    /// sweeps dial them down).
    pub pretrain_opts: TrainOpts,
    pub seed: u64,
}

impl Default for EvalOpts {
    fn default() -> Self {
        EvalOpts {
            eval_batches: eval::EVAL_BATCHES,
            pass1_programs: 64,
            qat_opts: TrainOpts { steps: 60, peak_lr: 3e-4, warmup: 6, ..Default::default() },
            pretrain_opts: TrainOpts::default(),
            seed: 1234,
        }
    }
}

pub struct Simulator {
    pub rt: Runtime,
    pub ck: CkptDir,
    pub opts: EvalOpts,
    calib_cache: RefCell<HashMap<String, Rc<CalibStats>>>,
    gptq_cache: RefCell<HashMap<String, Rc<TensorStore>>>,
}

impl Simulator {
    pub fn new(artifacts: &str, checkpoints: &str) -> Result<Simulator> {
        // Every host-side transform below (Hessian builds, SmoothQuant
        // products, calibration searches) runs on this backend; selection
        // comes from `--backend`/`--threads` or INTFPQSIM_BACKEND.
        crate::debug!(
            "tensor backend: {}",
            crate::tensor::backend::active().describe()
        );
        Ok(Simulator {
            rt: Runtime::new(artifacts)?,
            ck: CkptDir::new(checkpoints),
            opts: EvalOpts::default(),
            calib_cache: RefCell::new(HashMap::new()),
            gptq_cache: RefCell::new(HashMap::new()),
        })
    }

    /// FP32 weights for a model, pretraining (and caching) if needed.
    pub fn weights(&self, model_name: &str) -> Result<TensorStore> {
        train::pretrain_cached(&self.rt, model_name, &self.ck, &self.opts.pretrain_opts)
    }

    /// Calibration stats for (model, fp32 weights), cached in-process.
    pub fn calibration(&self, model_name: &str) -> Result<Rc<CalibStats>> {
        if let Some(c) = self.calib_cache.borrow().get(model_name) {
            return Ok(c.clone());
        }
        let params = self.weights(model_name)?;
        info!("calibrating {} ({} batches)", model_name, calib::CALIB_BATCHES);
        let stats = Rc::new(calib::capture(&self.rt, model_name, &params)?);
        self.calib_cache
            .borrow_mut()
            .insert(model_name.to_string(), stats.clone());
        Ok(stats)
    }

    fn gptq_weights(&self, model_name: &str) -> Result<Rc<TensorStore>> {
        if let Some(w) = self.gptq_cache.borrow().get(model_name) {
            return Ok(w.clone());
        }
        let tag = "gptq_w4";
        let cfg = self.rt.manifest.model(model_name)?.clone();
        let store = if self.ck.exists(model_name, tag) {
            self.ck.load(model_name, tag)?
        } else {
            let params = self.weights(model_name)?;
            let stats = self.calibration(model_name)?;
            info!("running GPTQ on {}", model_name);
            let t0 = std::time::Instant::now();
            let transformed = gptq::apply(&cfg, &params, &stats)?;
            info!("GPTQ {} done in {:.1}s", model_name, t0.elapsed().as_secs_f64());
            self.ck.save(model_name, tag, &transformed)?;
            transformed
        };
        let rc = Rc::new(store);
        self.gptq_cache
            .borrow_mut()
            .insert(model_name.to_string(), rc.clone());
        Ok(rc)
    }

    /// Manifest id of the eval artifact for (model, quant) — validated
    /// against the manifest. Public so the serving layer can pre-check a
    /// traffic mix before spawning clients.
    pub fn eval_artifact_id(&self, model_name: &str, quant: &str) -> Result<String> {
        let cfg = self.rt.manifest.model(model_name)?;
        let purpose = if cfg.task == "codegen" { "eval_logits" } else { "eval" };
        let id = format!("{}/{}_{}", model_name, purpose, quant);
        self.rt.manifest.artifact(&id)?; // validate
        Ok(id)
    }

    /// Assemble everything an evaluation needs — method-transformed
    /// weights, smoothing vectors, calibrated clip ranges — and open a
    /// prepared runtime session with them bound sticky (weights
    /// converted/QDQ-prepared once). [`Simulator::evaluate`] and the
    /// serving layer (`serve::`) both go through here, so a cached serve
    /// session is exactly the session `evaluate` would run.
    pub fn open_eval_session(&self, model_name: &str, qc: &QuantConfig) -> Result<Session> {
        let cfg = self.rt.manifest.model(model_name)?.clone();

        // 1. weights (possibly method-transformed or QAT-fine-tuned)
        let (params, smooth): (TensorStore, BTreeMap<String, Vec<f32>>) =
            match qc.method {
                Method::None | Method::Rptq => {
                    (self.weights(model_name)?, smoothquant::identity_smooth(&cfg))
                }
                Method::SmoothQuant => {
                    let stats = self.calibration(model_name)?;
                    let base = self.weights(model_name)?;
                    let sm = smoothquant::apply(&cfg, &base, &stats)?;
                    (sm.params, sm.smooth)
                }
                Method::Gptq => (
                    (*self.gptq_weights(model_name)?).clone(),
                    smoothquant::identity_smooth(&cfg),
                ),
                Method::Qat => {
                    let tag = format!("qat_{}", qc.quant.trim_start_matches("abfp_"));
                    let w = train::qat_cached(
                        &self.rt,
                        model_name,
                        &tag,
                        &self.ck,
                        &self.opts.qat_opts,
                    )?;
                    (w, smoothquant::identity_smooth(&cfg))
                }
            };

        // 2. pick the artifact: GPTQ runs W4A16 == transformed weights
        //    through the fp32 graph (activations stay high-precision).
        let quant_for_artifact = match qc.method {
            Method::Gptq => "fp32",
            _ => qc.quant.as_str(),
        };
        let id = self.eval_artifact_id(model_name, quant_for_artifact)?;
        let spec = self.rt.manifest.artifact(&id)?.clone();

        // 3. sticky inputs: params + smooth + calibrated alphas
        let mut sticky = model::param_vals(&cfg, &params)?;
        let needs_smooth = spec.inputs.iter().any(|i| i.name.starts_with("smooth."));
        if needs_smooth {
            sticky.extend(smoothquant::smooth_vals(&smooth));
        }
        let needs_alpha = spec.inputs.iter().any(|i| i.name.starts_with("alpha."));
        if needs_alpha {
            let stats = self.calibration(model_name)?;
            if qc.quant.starts_with("rptq") {
                sticky.extend(rptq::site_alpha_vals(&cfg, &stats)?);
            } else if qc.quant.starts_with("mse") {
                let bits = if qc.quant.ends_with("a8") { 8 } else { 4 };
                let alphas = calib::mse_site_alphas(&stats, bits);
                sticky.extend(calib::alpha_vals(&alphas));
            } else {
                bail!("artifact {} needs alphas but quant {} unknown", id, qc.quant);
            }
        }

        // 4. open the prepared session
        self.rt.session(&id, &sticky)
    }

    /// Evaluate a model under a quantization configuration; returns the
    /// task metric (PPL / Pass@1 / F1 / Accuracy).
    pub fn evaluate(&self, model_name: &str, qc: &QuantConfig) -> Result<Metric> {
        let cfg = self.rt.manifest.model(model_name)?.clone();
        let sess = self.open_eval_session(model_name, qc)?;
        let m = match cfg.task.as_str() {
            "lm" => Metric {
                value: eval::perplexity(
                    &sess,
                    &cfg,
                    &TextCorpus::new(crate::corpus::TEXT_SEED),
                    self.opts.eval_batches,
                )?,
                kind: MetricKind::Ppl,
            },
            "codegen" => Metric {
                value: 100.0
                    * eval::pass_at_1(
                        &sess,
                        &cfg,
                        &CodeCorpus::new(crate::corpus::CODE_SEED),
                        self.opts.pass1_programs,
                    )?,
                kind: MetricKind::PassAt1,
            },
            "span_qa" => Metric {
                value: eval::qa_f1(
                    &sess,
                    &cfg,
                    &QaCorpus::new(crate::corpus::QA_SEED),
                    self.opts.eval_batches,
                )?,
                kind: MetricKind::F1,
            },
            "image_cls" => Metric {
                value: eval::image_accuracy(
                    &sess,
                    &cfg,
                    &ImageCorpus::new(crate::corpus::IMG_SEED),
                    self.opts.eval_batches,
                )?,
                kind: MetricKind::Accuracy,
            },
            other => bail!("unknown task {}", other),
        };
        info!(
            "{} [{}] -> {} {:.2}",
            model_name,
            qc.label(),
            m.kind.name(),
            m.value
        );
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_and_relative_metric() {
        assert_eq!(QuantConfig::fp32().label(), "fp32");
        assert_eq!(
            QuantConfig::with("abfp_w4a4_n64", Method::SmoothQuant).label(),
            "abfp_w4a4_n64+SQ"
        );
        let fp = Metric { value: 20.0, kind: MetricKind::Ppl };
        let q = Metric { value: 25.0, kind: MetricKind::Ppl };
        assert!((relative_to_fp32(q, fp) - 0.8).abs() < 1e-9);
        let fa = Metric { value: 80.0, kind: MetricKind::Accuracy };
        let qa = Metric { value: 60.0, kind: MetricKind::Accuracy };
        assert!((relative_to_fp32(qa, fa) - 0.75).abs() < 1e-9);
    }
}
