//! Line-delimited JSON request/response protocol of `repro serve`.
//!
//! One request per line on stdin, one response per line on stdout;
//! responses carry the request id and may arrive out of submission
//! order (micro-batching reorders completion across keys).
//!
//! Request:
//!
//! ```json
//! {"id": 7, "model": "sim-opt-125m", "quant": "abfp_w4a4_n64",
//!  "batch": 3, "deadline_ms": 500}
//! ```
//!
//! * `id` (required) — echoed back on the response; any non-negative
//!   integer below [`ERR_ID`] (`u64::MAX`, reserved for responses to
//!   lines that could not be parsed at all);
//! * `model` (required) — a manifest model name;
//! * `quant` (default `"fp32"`) — an eval quant-config name;
//! * `batch` (default 0) — index into the model family's deterministic
//!   eval stream (the server generates the input, so a fixed index
//!   always means the same payload — the property the determinism tests
//!   lean on);
//! * `tokens` (optional) — inline token payload for token models,
//!   overriding `batch`; must be exactly `B·S` ids in vocab range;
//! * `deadline_ms` (optional) — relative deadline; a request that
//!   expires before dispatch (or whose batch finishes past it) gets an
//!   error response, never a stale output.
//!
//! Response:
//!
//! ```json
//! {"id": 7, "ok": true, "batched": 4, "queue_ms": 0.4, "run_ms": 12.1,
//!  "outputs": [{"shape": [], "sum": 1834.2, "first": [1834.2]}]}
//! ```
//!
//! `outputs` summarizes each output tensor (shape, f64 sum in fixed
//! iteration order, first values) — compact enough for a wire line yet
//! exact enough that two responses are equal iff the tensors are.

use anyhow::{Context, Result};

use crate::tensor::Tensor;
use crate::util::json::Json;

/// Response id used for lines that failed to parse (no request id to
/// echo). Reserved: requests may use any id below it.
pub const ERR_ID: u64 = u64::MAX;

/// A JSON number that must be a non-negative integer — fractions and
/// negatives are protocol errors, never silently truncated (`1.5` as a
/// token id or `-5` as a deadline would otherwise evaluate as a
/// plausible-but-wrong request).
fn as_uint(j: &Json, what: &str) -> Result<u64> {
    let n = j.as_f64().with_context(|| format!("{} must be a number", what))?;
    anyhow::ensure!(
        n >= 0.0 && n.fract() == 0.0 && n < u64::MAX as f64,
        "{} must be a non-negative integer, got {}",
        what,
        n
    );
    Ok(n as u64)
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub model: String,
    pub quant: String,
    /// Index into the model family's deterministic eval stream.
    pub batch_index: u64,
    /// Inline token payload overriding `batch_index` (token models).
    pub tokens: Option<Vec<i32>>,
    pub deadline_ms: Option<u64>,
}

impl Request {
    /// A minimal well-formed request (tests and loadgen fill the rest).
    pub fn new(id: u64, model: &str, quant: &str, batch_index: u64) -> Request {
        Request {
            id,
            model: model.to_string(),
            quant: quant.to_string(),
            batch_index,
            tokens: None,
            deadline_ms: None,
        }
    }
}

/// Parse one protocol line into a [`Request`].
pub fn parse_request(line: &str) -> Result<Request> {
    let j = Json::parse(line).map_err(|e| anyhow::anyhow!("bad request json: {}", e))?;
    let id = as_uint(j.get("id").context("request needs a numeric \"id\"")?, "\"id\"")?;
    let model = j
        .get("model")
        .and_then(Json::as_str)
        .context("request needs a \"model\" string")?
        .to_string();
    let quant = j
        .get("quant")
        .and_then(Json::as_str)
        .unwrap_or("fp32")
        .to_string();
    let batch_index = match j.get("batch") {
        None => 0,
        Some(b) => as_uint(b, "\"batch\"")?,
    };
    // Strict: every inline token must be an integer in i32 range — a
    // dropped or truncated entry could leave a shifted-but-right-length
    // stream that evaluates as if it were valid.
    let tokens = match j.get("tokens") {
        None => None,
        Some(t) => {
            let arr = t.as_arr().context("\"tokens\" must be an array")?;
            let mut toks = Vec::with_capacity(arr.len());
            for (i, v) in arr.iter().enumerate() {
                let n = v
                    .as_f64()
                    .with_context(|| format!("\"tokens\"[{}] is not a number", i))?;
                anyhow::ensure!(
                    n.fract() == 0.0 && (i32::MIN as f64..=i32::MAX as f64).contains(&n),
                    "\"tokens\"[{}] must be an integer token id, got {}",
                    i,
                    n
                );
                toks.push(n as i32);
            }
            Some(toks)
        }
    };
    let deadline_ms = match j.get("deadline_ms") {
        None => None,
        Some(d) => Some(as_uint(d, "\"deadline_ms\"")?),
    };
    Ok(Request { id, model, quant, batch_index, tokens, deadline_ms })
}

/// Exact-but-compact digest of one output tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct OutputSummary {
    pub shape: Vec<usize>,
    /// f64 sum over elements in storage order (deterministic).
    pub sum: f64,
    /// The first (up to) 4 elements verbatim.
    pub first: Vec<f32>,
}

/// Summarize a session's outputs for the wire.
pub fn summarize(outputs: &[Tensor]) -> Vec<OutputSummary> {
    outputs
        .iter()
        .map(|t| OutputSummary {
            shape: t.shape.clone(),
            sum: t.data.iter().map(|&v| v as f64).sum(),
            first: t.data.iter().take(4).copied().collect(),
        })
        .collect()
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub ok: bool,
    pub error: Option<String>,
    pub outputs: Vec<OutputSummary>,
    /// Occupancy of the micro-batch this request rode in.
    pub batched: usize,
    /// Admission-to-dispatch wait.
    pub queue_ms: f64,
    /// Wall time of the (shared) batched forward.
    pub run_ms: f64,
}

impl Response {
    pub fn ok(
        id: u64,
        outputs: Vec<OutputSummary>,
        batched: usize,
        queue_ms: f64,
        run_ms: f64,
    ) -> Response {
        Response { id, ok: true, error: None, outputs, batched, queue_ms, run_ms }
    }

    pub fn err(id: u64, msg: &str) -> Response {
        Response {
            id,
            ok: false,
            error: Some(msg.to_string()),
            outputs: Vec::new(),
            batched: 0,
            queue_ms: 0.0,
            run_ms: 0.0,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("id", Json::Num(self.id as f64)),
            ("ok", Json::Bool(self.ok)),
            ("batched", Json::Num(self.batched as f64)),
            ("queue_ms", Json::Num(self.queue_ms)),
            ("run_ms", Json::Num(self.run_ms)),
            (
                "outputs",
                Json::Arr(
                    self.outputs
                        .iter()
                        .map(|o| {
                            Json::obj(vec![
                                (
                                    "shape",
                                    Json::Arr(
                                        o.shape
                                            .iter()
                                            .map(|&v| Json::Num(v as f64))
                                            .collect(),
                                    ),
                                ),
                                ("sum", Json::Num(o.sum)),
                                (
                                    "first",
                                    Json::Arr(
                                        o.first
                                            .iter()
                                            .map(|&v| Json::Num(v as f64))
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        if let Some(e) = &self.error {
            pairs.push(("error", Json::Str(e.clone())));
        }
        Json::obj(pairs)
    }

    /// One compact protocol line.
    pub fn line(&self) -> String {
        self.to_json().dump()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_and_defaulted_requests() {
        let r = parse_request(
            r#"{"id": 7, "model": "sim-opt-125m", "quant": "abfp_w4a4_n64",
                "batch": 3, "deadline_ms": 500}"#,
        )
        .unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.model, "sim-opt-125m");
        assert_eq!(r.quant, "abfp_w4a4_n64");
        assert_eq!(r.batch_index, 3);
        assert_eq!(r.deadline_ms, Some(500));
        assert!(r.tokens.is_none());

        let d = parse_request(r#"{"id": 1, "model": "m"}"#).unwrap();
        assert_eq!(d.quant, "fp32");
        assert_eq!(d.batch_index, 0);
        assert!(d.deadline_ms.is_none());

        let t = parse_request(r#"{"id": 2, "model": "m", "tokens": [1, 2, 3]}"#).unwrap();
        assert_eq!(t.tokens, Some(vec![1, 2, 3]));
    }

    #[test]
    fn parse_rejects_malformed_requests() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"model": "m"}"#).is_err(), "missing id");
        assert!(parse_request(r#"{"id": 3}"#).is_err(), "missing model");
        assert!(parse_request(r#"{"id": "x", "model": "m"}"#).is_err(), "non-numeric id");
        // inline tokens must be all-numeric integers — no silent
        // filtering, no silent truncation
        assert!(
            parse_request(r#"{"id": 4, "model": "m", "tokens": [1, "x", 3]}"#).is_err(),
            "junk token entry"
        );
        assert!(
            parse_request(r#"{"id": 4, "model": "m", "tokens": [1.5, 2]}"#).is_err(),
            "fractional token id"
        );
        assert!(
            parse_request(r#"{"id": 5, "model": "m", "tokens": 3}"#).is_err(),
            "tokens must be an array"
        );
        // numeric fields must be non-negative integers, never truncated
        assert!(parse_request(r#"{"id": 1.5, "model": "m"}"#).is_err(), "fractional id");
        assert!(
            parse_request(r#"{"id": 1, "model": "m", "deadline_ms": -5}"#).is_err(),
            "negative deadline"
        );
        assert!(
            parse_request(r#"{"id": 1, "model": "m", "batch": 2.5}"#).is_err(),
            "fractional batch index"
        );
    }

    #[test]
    fn response_lines_are_valid_json_and_summaries_exact() {
        let t = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let s = summarize(&[t]);
        assert_eq!(s[0].shape, vec![2, 3]);
        assert_eq!(s[0].sum, 21.0);
        assert_eq!(s[0].first, vec![1.0, 2.0, 3.0, 4.0]);

        let ok = Response::ok(9, s, 4, 0.5, 12.0);
        let j = Json::parse(&ok.line()).unwrap();
        assert_eq!(j.get("id").unwrap().as_f64(), Some(9.0));
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("batched").unwrap().as_f64(), Some(4.0));

        let err = Response::err(3, "queue full");
        let j = Json::parse(&err.line()).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(j.get("error").unwrap().as_str(), Some("queue full"));
    }
}
