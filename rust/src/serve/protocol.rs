//! Line-delimited JSON request/response protocol of `repro serve`.
//!
//! One request per line (stdin or a TCP connection), one response per
//! line back; responses carry the request id and may arrive out of
//! submission order (micro-batching and sharding reorder completion
//! across keys). The full operator-facing specification lives in
//! `docs/serving.md`; [`REQUEST_FIELDS`], [`RESPONSE_FIELDS`] and
//! [`codes::ALL`] are the machine-readable manifests a test compares
//! against that document so the two cannot drift apart.
//!
//! Request:
//!
//! ```json
//! {"id": 7, "model": "sim-opt-125m", "quant": "abfp_w4a4_n64",
//!  "batch": 3, "deadline_ms": 500}
//! ```
//!
//! * `id` (required) — echoed back on the response; any non-negative
//!   integer below [`ERR_ID`] (`u64::MAX`, reserved for responses to
//!   lines that could not be parsed at all);
//! * `model` (required) — a manifest model name;
//! * `quant` (default `"fp32"`) — an eval quant-config name;
//! * `batch` (default 0) — index into the model family's deterministic
//!   eval stream (the server generates the input, so a fixed index
//!   always means the same payload — the property the determinism tests
//!   lean on);
//! * `tokens` (optional) — inline token payload for token models,
//!   overriding `batch`; must be exactly `B·S` ids in vocab range;
//! * `deadline_ms` (optional) — relative deadline; a request that
//!   expires before dispatch (or whose batch finishes past it) gets an
//!   error response, never a stale output.
//!
//! Unknown request fields are rejected (`bad_request`), so a typo like
//! `"deadline_mss"` fails loudly instead of silently dropping the
//! deadline.
//!
//! Response:
//!
//! ```json
//! {"id": 7, "ok": true, "batched": 4, "queue_ms": 0.4, "run_ms": 12.1,
//!  "outputs": [{"shape": [], "sum": 1834.2, "first": [1834.2]}]}
//! ```
//!
//! `outputs` summarizes each output tensor (shape, f64 sum in fixed
//! iteration order, first values) — compact enough for a wire line yet
//! exact enough that two responses are equal iff the tensors are. Error
//! responses set `ok: false` and carry a human-readable `error` message
//! plus a stable machine-readable `code` (see [`codes`]).

use std::io::Write as IoWrite;

use anyhow::{Context, Result};

use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::json_stream::{self, StreamParser, Token};

pub use crate::util::json_stream::MAX_DEPTH;

/// Response id used for lines that failed to parse (no request id to
/// echo). Reserved: requests may use any id below it.
pub const ERR_ID: u64 = u64::MAX;

/// Maximum accepted request-line length in bytes (newline excluded).
/// The transport reads lines through a capped reader, so a client
/// streaming an endless line costs bounded memory: the oversized line
/// is discarded as it arrives, answered with `bad_request`, and the
/// connection stays usable. Documented in `docs/serving.md` (the
/// `wire:limits` table is machine-checked against this constant).
pub const MAX_LINE_BYTES: usize = 1 << 20;

/// Every field a request line may carry, as documented in
/// `docs/serving.md`. Unknown fields are rejected at parse time.
pub const REQUEST_FIELDS: &[&str] = &["id", "model", "quant", "batch", "tokens", "deadline_ms"];

/// Every verb a client line can speak, as documented in
/// `docs/serving.md`. A line without a `"verb"` field is a `run`
/// request (the original — and still default — protocol); `stats`
/// fetches a metrics snapshot; `shutdown` begins a graceful drain.
pub const VERBS: &[&str] = &["run", "shutdown", "stats"];

/// The canonical `stats` request line (what `repro loadgen` sends).
pub const STATS_LINE: &str = "{\"verb\":\"stats\"}";

/// The canonical `shutdown` request line: begins a graceful drain. The
/// server acks with a `shutting_down` line ([`ERR_ID`]), finishes what
/// was already admitted (bounded by `--drain-timeout`), then closes.
pub const SHUTDOWN_LINE: &str = "{\"verb\":\"shutdown\"}";

/// Is this trimmed line a request for `verb`? The canonical line is a
/// plain byte compare (hot-path cheap); as a courtesy, any short object
/// whose only content is `"verb": "<verb>"` (key order / whitespace
/// free) is also accepted — the tree parse only runs for lines that
/// contain `"verb"`, which normal requests reject as an unknown field
/// anyway.
fn is_verb_request(line: &[u8], canonical: &str, verb: &str) -> bool {
    if line == canonical.as_bytes() {
        return true;
    }
    if line.len() > 64 || !line.windows(6).any(|w| w == b"\"verb\"") {
        return false;
    }
    let Ok(s) = std::str::from_utf8(line) else {
        return false;
    };
    match Json::parse(s) {
        Ok(j) => {
            j.get("verb").and_then(Json::as_str) == Some(verb)
                && j.as_obj().map(|o| o.len() == 1).unwrap_or(false)
        }
        Err(_) => false,
    }
}

/// Is this trimmed line a `stats` request (see [`STATS_LINE`])?
pub fn is_stats_request(line: &[u8]) -> bool {
    is_verb_request(line, STATS_LINE, "stats")
}

/// Is this trimmed line a `shutdown` request (see [`SHUTDOWN_LINE`])?
pub fn is_shutdown_request(line: &[u8]) -> bool {
    is_verb_request(line, SHUTDOWN_LINE, "shutdown")
}

/// Internal `code` value marking the in-process sentinel a reader
/// thread sends its writer when a `stats` line arrives (never
/// serialized to the wire — the writer swaps it for a snapshot line).
const STATS_MARKER_CODE: &str = "__stats__";

/// The sentinel [`Response`] routed from reader to writer for a `stats`
/// request. Rides the existing per-connection response channel, so the
/// snapshot is serialized by the same thread that owns the socket.
/// Unambiguous: real [`ERR_ID`] responses only ever carry
/// [`codes::BAD_REQUEST`] or [`codes::SHUTTING_DOWN`], never a private
/// `__`-prefixed marker code.
pub fn stats_marker() -> Response {
    Response::err(ERR_ID, STATS_MARKER_CODE, "stats")
}

/// Is this response the [`stats_marker`] sentinel?
pub fn is_stats_marker(resp: &Response) -> bool {
    resp.id == ERR_ID && resp.code.as_deref() == Some(STATS_MARKER_CODE)
}

/// Internal `code` value of the drain sentinel a front end sends its
/// writer thread once the worker loop has finished (never serialized
/// to the wire — the writer exits on it).
const DRAIN_MARKER_CODE: &str = "__drain__";

/// The sentinel [`Response`] that tells a writer thread to exit. Sent
/// *after* the worker loop returns, so mpsc FIFO ordering guarantees
/// every real response is serialized first — the graceful-drain
/// handshake both the stdio and TCP fronts rely on.
pub fn drain_marker() -> Response {
    Response::err(ERR_ID, DRAIN_MARKER_CODE, "drain")
}

/// Is this response the [`drain_marker`] sentinel?
pub fn is_drain_marker(resp: &Response) -> bool {
    resp.id == ERR_ID && resp.code.as_deref() == Some(DRAIN_MARKER_CODE)
}

/// Every field a response line may carry, as documented in
/// `docs/serving.md` (`error` and `code` only appear on failures).
pub const RESPONSE_FIELDS: &[&str] =
    &["id", "ok", "batched", "queue_ms", "run_ms", "outputs", "error", "code"];

/// Stable machine-readable error codes carried in the `code` field of
/// failure responses. Clients branch on these (`queue_full` means
/// retry-later; `bad_request` means fix the line); the human-readable
/// `error` message is free to change, the codes are not.
pub mod codes {
    /// The line was not a well-formed request (bad JSON, missing or
    /// malformed field, unknown field). Sent with [`super::ERR_ID`]
    /// when no request id could be recovered.
    pub const BAD_REQUEST: &str = "bad_request";
    /// Admission rejected: the bounded queue is at capacity, or the
    /// server hit its `--max-conns` connection cap. Backpressure —
    /// retry after a pause (a draining server answers
    /// [`SHUTTING_DOWN`] instead, which means switch servers).
    pub const QUEUE_FULL: &str = "queue_full";
    /// The deadline lapsed while the request waited in the admission
    /// queue; it was shed before dispatch and never ran.
    pub const DEADLINE_QUEUE: &str = "deadline_expired_in_queue";
    /// The request ran, but its batch finished past the deadline; the
    /// (stale) output is withheld.
    pub const DEADLINE_RUN: &str = "deadline_expired_in_run";
    /// `model` is not a manifest model name.
    pub const UNKNOWN_MODEL: &str = "unknown_model";
    /// Opening the (model × quant) session failed — most commonly an
    /// unknown quant-config name.
    pub const OPEN_FAILED: &str = "open_session_failed";
    /// The request's input was invalid for the model (wrong inline
    /// token count, out-of-vocab ids, tokens for an image model, ...).
    pub const BAD_INPUT: &str = "bad_input";
    /// The batched forward itself failed, or a server worker died.
    pub const RUN_FAILED: &str = "run_failed";
    /// A worker panicked while executing this request and the panic
    /// was recovered by supervision. The request is quarantined: it
    /// will not be retried server-side, and resubmitting the same line
    /// is expected to fail the same way — do not retry blindly.
    pub const INTERNAL_ERROR: &str = "internal_error";
    /// The server is draining for shutdown and admits no new work.
    /// Already-admitted requests still complete (within
    /// `--drain-timeout`); send new work elsewhere.
    pub const SHUTTING_DOWN: &str = "shutting_down";
    /// Every code the server can emit, for the doc-drift test.
    pub const ALL: &[&str] = &[
        BAD_REQUEST,
        QUEUE_FULL,
        DEADLINE_QUEUE,
        DEADLINE_RUN,
        UNKNOWN_MODEL,
        OPEN_FAILED,
        BAD_INPUT,
        RUN_FAILED,
        INTERNAL_ERROR,
        SHUTTING_DOWN,
    ];
}

/// A JSON number that must be a non-negative integer — fractions and
/// negatives are protocol errors, never silently truncated (`1.5` as a
/// token id or `-5` as a deadline would otherwise evaluate as a
/// plausible-but-wrong request).
fn as_uint(j: &Json, what: &str) -> Result<u64> {
    let n = j.as_f64().with_context(|| format!("{} must be a number", what))?;
    anyhow::ensure!(
        n >= 0.0 && n.fract() == 0.0 && n < u64::MAX as f64,
        "{} must be a non-negative integer, got {}",
        what,
        n
    );
    Ok(n as u64)
}

/// One parsed request line (see the module docs for field semantics).
/// `Default` is the empty scratch value [`parse_request_streaming`]
/// fills — reusing one `Request` across lines keeps its string/vec
/// capacity, which is what makes the transport parse path
/// allocation-free in steady state.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Request {
    /// Client-chosen id echoed on the response; must be below [`ERR_ID`].
    pub id: u64,
    /// Manifest model name.
    pub model: String,
    /// Eval quant-config name (wire default `"fp32"`).
    pub quant: String,
    /// Index into the model family's deterministic eval stream.
    pub batch_index: u64,
    /// Inline token payload overriding `batch_index` (token models).
    pub tokens: Option<Vec<i32>>,
    /// Relative deadline in milliseconds from admission.
    pub deadline_ms: Option<u64>,
}

impl Request {
    /// A minimal well-formed request (tests and loadgen fill the rest).
    pub fn new(id: u64, model: &str, quant: &str, batch_index: u64) -> Request {
        Request {
            id,
            model: model.to_string(),
            quant: quant.to_string(),
            batch_index,
            tokens: None,
            deadline_ms: None,
        }
    }

    /// Wire form of the request — the inverse of [`parse_request`]
    /// (used by the TCP loadgen clients and the protocol examples).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("id", Json::Num(self.id as f64)),
            ("model", Json::Str(self.model.clone())),
            ("quant", Json::Str(self.quant.clone())),
            ("batch", Json::Num(self.batch_index as f64)),
        ];
        if let Some(toks) = &self.tokens {
            pairs.push((
                "tokens",
                Json::Arr(toks.iter().map(|&t| Json::Num(t as f64)).collect()),
            ));
        }
        if let Some(d) = self.deadline_ms {
            pairs.push(("deadline_ms", Json::Num(d as f64)));
        }
        Json::obj(pairs)
    }

    /// One compact protocol line.
    pub fn line(&self) -> String {
        self.to_json().dump()
    }

    /// Serialize the request into a reused buffer, byte-identical to
    /// [`Request::line`] (same sorted key order, same number
    /// formatting) but with zero allocation once `out` has warmed up.
    /// No trailing newline — callers frame with `out.push(b'\n')`.
    pub fn write_line(&self, out: &mut Vec<u8>) {
        out.clear();
        out.extend_from_slice(b"{\"batch\":");
        write_num(out, self.batch_index as f64);
        if let Some(d) = self.deadline_ms {
            out.extend_from_slice(b",\"deadline_ms\":");
            write_num(out, d as f64);
        }
        out.extend_from_slice(b",\"id\":");
        write_num(out, self.id as f64);
        out.extend_from_slice(b",\"model\":");
        write_escaped_bytes(out, &self.model);
        out.extend_from_slice(b",\"quant\":");
        write_escaped_bytes(out, &self.quant);
        if let Some(toks) = &self.tokens {
            out.extend_from_slice(b",\"tokens\":[");
            for (i, &t) in toks.iter().enumerate() {
                if i > 0 {
                    out.push(b',');
                }
                write_num(out, t as f64);
            }
            out.push(b']');
        }
        out.push(b'}');
    }
}

/// Parse one protocol line into a [`Request`].
pub fn parse_request(line: &str) -> Result<Request> {
    let j = Json::parse(line).map_err(|e| anyhow::anyhow!("bad request json: {}", e))?;
    let obj = j.as_obj().context("request must be a JSON object")?;
    for k in obj.keys() {
        anyhow::ensure!(
            REQUEST_FIELDS.contains(&k.as_str()),
            "unknown request field {:?} (known: {})",
            k,
            REQUEST_FIELDS.join(", ")
        );
    }
    let id = as_uint(j.get("id").context("request needs a numeric \"id\"")?, "\"id\"")?;
    let model = j
        .get("model")
        .and_then(Json::as_str)
        .context("request needs a \"model\" string")?
        .to_string();
    // strict: a present-but-non-string quant is an error, never a
    // silent fallback to fp32 (matching the streaming parser)
    let quant = match j.get("quant") {
        None => "fp32".to_string(),
        Some(q) => q
            .as_str()
            .context("\"quant\" must be a string")?
            .to_string(),
    };
    let batch_index = match j.get("batch") {
        None => 0,
        Some(b) => as_uint(b, "\"batch\"")?,
    };
    // Strict: every inline token must be an integer in i32 range — a
    // dropped or truncated entry could leave a shifted-but-right-length
    // stream that evaluates as if it were valid.
    let tokens = match j.get("tokens") {
        None => None,
        Some(t) => {
            let arr = t.as_arr().context("\"tokens\" must be an array")?;
            let mut toks = Vec::with_capacity(arr.len());
            for (i, v) in arr.iter().enumerate() {
                let n = v
                    .as_f64()
                    .with_context(|| format!("\"tokens\"[{}] is not a number", i))?;
                anyhow::ensure!(
                    n.fract() == 0.0 && (i32::MIN as f64..=i32::MAX as f64).contains(&n),
                    "\"tokens\"[{}] must be an integer token id, got {}",
                    i,
                    n
                );
                toks.push(n as i32);
            }
            Some(toks)
        }
    };
    let deadline_ms = match j.get("deadline_ms") {
        None => None,
        Some(d) => Some(as_uint(d, "\"deadline_ms\"")?),
    };
    Ok(Request { id, model, quant, batch_index, tokens, deadline_ms })
}

fn wire_err(e: json_stream::StreamError) -> anyhow::Error {
    anyhow::anyhow!("bad request json: {}", e)
}

/// The streaming twin of [`as_uint`]: the next token must be a
/// non-negative integer number.
fn stream_uint(p: &mut StreamParser<'_>, what: &str) -> Result<u64> {
    match p.next_token().map_err(wire_err)? {
        Some(Token::Num(n)) => {
            anyhow::ensure!(
                n >= 0.0 && n.fract() == 0.0 && n < u64::MAX as f64,
                "{} must be a non-negative integer, got {}",
                what,
                n
            );
            Ok(n as u64)
        }
        _ => anyhow::bail!("{} must be a number", what),
    }
}

/// The next token must be a string; decode it into the reused `out`.
fn stream_string(p: &mut StreamParser<'_>, out: &mut String, what: &str) -> Result<()> {
    match p.next_token().map_err(wire_err)? {
        Some(Token::Str(s)) => {
            out.clear();
            s.append_to(out);
            Ok(())
        }
        _ => anyhow::bail!("{} must be a string", what),
    }
}

/// Parse one wire line into a reused [`Request`] — the transport hot
/// path. Built on the non-recursive [`StreamParser`]: no `Json` tree,
/// no per-field `String`; field values land in `out`'s existing
/// string/vec capacity, so a warmed scratch request parses with zero
/// allocations. Accept/reject decisions and every parsed field agree
/// with [`parse_request`] (held by the differential corpus in
/// `tests/protocol_stream.rs`).
pub fn parse_request_streaming(line: &[u8], out: &mut Request) -> Result<()> {
    let mut p = StreamParser::new(line);
    match p.next_token().map_err(wire_err)? {
        Some(Token::ObjStart) => {}
        _ => anyhow::bail!("request must be a JSON object"),
    }
    out.id = 0;
    out.model.clear();
    out.quant.clear();
    out.batch_index = 0;
    out.deadline_ms = None;
    // keep the tokens capacity across lines that carry tokens
    let mut tokens = out.tokens.take().unwrap_or_default();
    tokens.clear();
    let (mut saw_id, mut saw_model, mut saw_quant, mut saw_tokens) =
        (false, false, false, false);
    loop {
        let key = match p.next_token().map_err(wire_err)? {
            Some(Token::Key(k)) => k,
            Some(Token::ObjEnd) => break,
            _ => anyhow::bail!("request must be a JSON object"),
        };
        if key.eq_str("id") {
            out.id = stream_uint(&mut p, "\"id\"")?;
            saw_id = true;
        } else if key.eq_str("model") {
            stream_string(&mut p, &mut out.model, "\"model\"")?;
            saw_model = true;
        } else if key.eq_str("quant") {
            stream_string(&mut p, &mut out.quant, "\"quant\"")?;
            saw_quant = true;
        } else if key.eq_str("batch") {
            out.batch_index = stream_uint(&mut p, "\"batch\"")?;
        } else if key.eq_str("deadline_ms") {
            out.deadline_ms = Some(stream_uint(&mut p, "\"deadline_ms\"")?);
        } else if key.eq_str("tokens") {
            match p.next_token().map_err(wire_err)? {
                Some(Token::ArrStart) => {}
                _ => anyhow::bail!("\"tokens\" must be an array"),
            }
            tokens.clear();
            let mut i = 0usize;
            loop {
                match p.next_token().map_err(wire_err)? {
                    Some(Token::ArrEnd) => break,
                    Some(Token::Num(n)) => {
                        anyhow::ensure!(
                            n.fract() == 0.0
                                && (i32::MIN as f64..=i32::MAX as f64).contains(&n),
                            "\"tokens\"[{}] must be an integer token id, got {}",
                            i,
                            n
                        );
                        tokens.push(n as i32);
                        i += 1;
                    }
                    _ => anyhow::bail!("\"tokens\"[{}] is not a number", i),
                }
            }
            saw_tokens = true;
        } else {
            // error path: decoding the unknown key may allocate, which
            // is fine — rejects are off the hot path
            let mut name = String::new();
            key.append_to(&mut name);
            anyhow::bail!(
                "unknown request field {:?} (known: {})",
                name,
                REQUEST_FIELDS.join(", ")
            );
        }
    }
    match p.next_token().map_err(wire_err)? {
        None => {}
        Some(_) => anyhow::bail!("trailing data after request object"),
    }
    anyhow::ensure!(saw_id, "request needs a numeric \"id\"");
    anyhow::ensure!(saw_model, "request needs a \"model\" string");
    if !saw_quant {
        out.quant.push_str("fp32");
    }
    out.tokens = if saw_tokens { Some(tokens) } else { None };
    Ok(())
}

/// `Json::dump`'s exact number formatting, into a byte buffer: `null`
/// for non-finite, integer form for integral values below 1e15, `{}`
/// of f64 otherwise. Formatting goes through stack buffers — no heap.
fn write_num(out: &mut Vec<u8>, n: f64) {
    if !n.is_finite() {
        out.extend_from_slice(b"null");
    } else if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{}", n);
    }
}

/// `Json::dump`'s exact string escaping, into a byte buffer.
fn write_escaped_bytes(out: &mut Vec<u8>, s: &str) {
    out.push(b'"');
    for c in s.chars() {
        match c {
            '"' => out.extend_from_slice(b"\\\""),
            '\\' => out.extend_from_slice(b"\\\\"),
            '\n' => out.extend_from_slice(b"\\n"),
            '\r' => out.extend_from_slice(b"\\r"),
            '\t' => out.extend_from_slice(b"\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => {
                let mut buf = [0u8; 4];
                out.extend_from_slice(c.encode_utf8(&mut buf).as_bytes());
            }
        }
    }
    out.push(b'"');
}

/// Exact-but-compact digest of one output tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct OutputSummary {
    /// The tensor's shape.
    pub shape: Vec<usize>,
    /// f64 sum over elements in storage order (deterministic).
    pub sum: f64,
    /// The first (up to) 4 elements verbatim.
    pub first: Vec<f32>,
}

/// Summarize a session's outputs for the wire.
pub fn summarize(outputs: &[Tensor]) -> Vec<OutputSummary> {
    let mut out = Vec::with_capacity(outputs.len());
    summarize_into(outputs, &mut out);
    out
}

/// Summarize into a reused buffer — the worker-side hot path.
/// Existing [`OutputSummary`] slots (and their inner shape/first
/// vectors) are overwritten in place and only missing slots are pushed,
/// so a warmed vector taken from [`outputs_pool`] summarizes with zero
/// allocations in steady state. Produces exactly [`summarize`]'s value.
pub fn summarize_into(outputs: &[Tensor], out: &mut Vec<OutputSummary>) {
    out.truncate(outputs.len());
    for (i, t) in outputs.iter().enumerate() {
        let sum = t.data.iter().map(|&v| v as f64).sum();
        match out.get_mut(i) {
            Some(slot) => {
                slot.shape.clear();
                slot.shape.extend_from_slice(&t.shape);
                slot.sum = sum;
                slot.first.clear();
                slot.first.extend(t.data.iter().take(4).copied());
            }
            None => out.push(OutputSummary {
                shape: t.shape.clone(),
                sum,
                first: t.data.iter().take(4).copied().collect(),
            }),
        }
    }
}

/// Recycling pool for [`Response::outputs`] vectors, closing the last
/// per-request allocation on the serve path: a worker [`take`]s a
/// warmed vector, fills it with [`summarize_into`], and moves it into
/// the [`Response`]; the transport writer [`put`]s it back after the
/// line is serialized. Pooled vectors keep their elements (and so
/// every inner vector's capacity) — [`summarize_into`] overwrites
/// slots in place. Bounded, shared-nothing-on-failure: a lost vector
/// (client gone, poisoned lock) just means the next `take` allocates
/// fresh, exactly the pre-pool behavior.
///
/// [`take`]: outputs_pool::take
/// [`put`]: outputs_pool::put
pub mod outputs_pool {
    use std::sync::Mutex;

    use super::OutputSummary;

    /// Upper bound on pooled vectors; returns beyond it are dropped.
    /// Sized for the deepest concurrency the server runs (worker count
    /// × in-flight batches), not request volume.
    const POOL_CAP: usize = 64;

    static POOL: Mutex<Vec<Vec<OutputSummary>>> = Mutex::new(Vec::new());

    /// Pop a warmed outputs vector, or a fresh empty one if the pool
    /// is empty. Any leftover elements are live capacity for
    /// [`super::summarize_into`], never stale wire data — it truncates
    /// and overwrites.
    pub fn take() -> Vec<OutputSummary> {
        POOL.lock().ok().and_then(|mut p| p.pop()).unwrap_or_default()
    }

    /// Return a response's outputs vector once its wire line is
    /// written. Capacity-less vectors (the error-response common case)
    /// carry nothing worth pooling and are dropped.
    pub fn put(v: Vec<OutputSummary>) {
        if v.capacity() == 0 {
            return;
        }
        if let Ok(mut p) = POOL.lock() {
            if p.len() < POOL_CAP {
                p.push(v);
            }
        }
    }
}

/// One response line (see the module docs for field semantics).
#[derive(Debug, Clone)]
pub struct Response {
    /// The request's id ([`ERR_ID`] when no id could be parsed).
    pub id: u64,
    /// Success flag; `false` responses carry `error` + `code`.
    pub ok: bool,
    /// Human-readable failure message (absent on success).
    pub error: Option<String>,
    /// Machine-readable failure code from [`codes`] (absent on success).
    pub code: Option<String>,
    /// Output tensor digests (empty on failure).
    pub outputs: Vec<OutputSummary>,
    /// Occupancy of the micro-batch this request rode in.
    pub batched: usize,
    /// Admission-to-dispatch wait.
    pub queue_ms: f64,
    /// Wall time of the (shared) batched forward.
    pub run_ms: f64,
}

impl Response {
    /// A success response.
    pub fn ok(
        id: u64,
        outputs: Vec<OutputSummary>,
        batched: usize,
        queue_ms: f64,
        run_ms: f64,
    ) -> Response {
        Response {
            id,
            ok: true,
            error: None,
            code: None,
            outputs,
            batched,
            queue_ms,
            run_ms,
        }
    }

    /// A failure response carrying a [`codes`] code and a message.
    pub fn err(id: u64, code: &str, msg: &str) -> Response {
        Response {
            id,
            ok: false,
            error: Some(msg.to_string()),
            code: Some(code.to_string()),
            outputs: Vec::new(),
            batched: 0,
            queue_ms: 0.0,
            run_ms: 0.0,
        }
    }

    /// Refill `self` as a failure response in place — the
    /// reuse-friendly twin of [`Response::err`]. A warmed scratch
    /// `Response` keeps its string and vector capacity across calls,
    /// so rebuilding an `internal_error` / `shutting_down` / any other
    /// rejection line is allocation-free in steady state
    /// (`tests/proto_alloc.rs` audits exactly this path).
    pub fn err_into(&mut self, id: u64, code: &str, msg: &str) {
        self.id = id;
        self.ok = false;
        match &mut self.code {
            Some(c) => {
                c.clear();
                c.push_str(code);
            }
            None => self.code = Some(code.to_string()),
        }
        match &mut self.error {
            Some(e) => {
                e.clear();
                e.push_str(msg);
            }
            None => self.error = Some(msg.to_string()),
        }
        self.outputs.clear();
        self.batched = 0;
        self.queue_ms = 0.0;
        self.run_ms = 0.0;
    }

    /// Wire form of the response — the inverse of [`parse_response`].
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("id", Json::Num(self.id as f64)),
            ("ok", Json::Bool(self.ok)),
            ("batched", Json::Num(self.batched as f64)),
            ("queue_ms", Json::Num(self.queue_ms)),
            ("run_ms", Json::Num(self.run_ms)),
            (
                "outputs",
                Json::Arr(
                    self.outputs
                        .iter()
                        .map(|o| {
                            Json::obj(vec![
                                (
                                    "shape",
                                    Json::Arr(
                                        o.shape
                                            .iter()
                                            .map(|&v| Json::Num(v as f64))
                                            .collect(),
                                    ),
                                ),
                                ("sum", Json::Num(o.sum)),
                                (
                                    "first",
                                    Json::Arr(
                                        o.first
                                            .iter()
                                            .map(|&v| Json::Num(v as f64))
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        if let Some(e) = &self.error {
            pairs.push(("error", Json::Str(e.clone())));
        }
        if let Some(c) = &self.code {
            pairs.push(("code", Json::Str(c.clone())));
        }
        Json::obj(pairs)
    }

    /// One compact protocol line.
    pub fn line(&self) -> String {
        self.to_json().dump()
    }

    /// Serialize the response into a reused buffer, byte-identical to
    /// [`Response::line`] (same sorted key order — `to_json` goes
    /// through a `BTreeMap` — same number formatting) with zero
    /// allocation once `out` has warmed up. No trailing newline —
    /// callers frame with `out.push(b'\n')`.
    pub fn write_line(&self, out: &mut Vec<u8>) {
        out.clear();
        out.extend_from_slice(b"{\"batched\":");
        write_num(out, self.batched as f64);
        if let Some(c) = &self.code {
            out.extend_from_slice(b",\"code\":");
            write_escaped_bytes(out, c);
        }
        if let Some(e) = &self.error {
            out.extend_from_slice(b",\"error\":");
            write_escaped_bytes(out, e);
        }
        out.extend_from_slice(b",\"id\":");
        write_num(out, self.id as f64);
        out.extend_from_slice(b",\"ok\":");
        out.extend_from_slice(if self.ok { b"true" } else { b"false" });
        out.extend_from_slice(b",\"outputs\":[");
        for (i, o) in self.outputs.iter().enumerate() {
            if i > 0 {
                out.push(b',');
            }
            out.extend_from_slice(b"{\"first\":[");
            for (j, &v) in o.first.iter().enumerate() {
                if j > 0 {
                    out.push(b',');
                }
                write_num(out, v as f64);
            }
            out.extend_from_slice(b"],\"shape\":[");
            for (j, &v) in o.shape.iter().enumerate() {
                if j > 0 {
                    out.push(b',');
                }
                write_num(out, v as f64);
            }
            out.extend_from_slice(b"],\"sum\":");
            write_num(out, o.sum);
            out.push(b'}');
        }
        out.extend_from_slice(b"],\"queue_ms\":");
        write_num(out, self.queue_ms);
        out.extend_from_slice(b",\"run_ms\":");
        write_num(out, self.run_ms);
        out.push(b'}');
    }
}

/// Parse one response line back into a [`Response`] — the client half
/// of the wire (used by the TCP loadgen and the protocol-conformance
/// tests).
pub fn parse_response(line: &str) -> Result<Response> {
    let j = Json::parse(line).map_err(|e| anyhow::anyhow!("bad response json: {}", e))?;
    // Unlike request ids, the response id may be ERR_ID (u64::MAX,
    // which rounds to 2^64 as f64) — accept it via a saturating cast.
    let id_f = j
        .get("id")
        .and_then(Json::as_f64)
        .context("response needs a numeric \"id\"")?;
    anyhow::ensure!(
        id_f >= 0.0 && id_f.fract() == 0.0,
        "response \"id\" must be a non-negative integer, got {}",
        id_f
    );
    let id = id_f as u64;
    let ok = j
        .get("ok")
        .and_then(Json::as_bool)
        .context("response needs a boolean \"ok\"")?;
    let batched = j.get("batched").and_then(Json::as_usize).unwrap_or(0);
    let queue_ms = j.get("queue_ms").and_then(Json::as_f64).unwrap_or(0.0);
    let run_ms = j.get("run_ms").and_then(Json::as_f64).unwrap_or(0.0);
    let error = j.get("error").and_then(Json::as_str).map(str::to_string);
    let code = j.get("code").and_then(Json::as_str).map(str::to_string);
    let mut outputs = Vec::new();
    if let Some(arr) = j.get("outputs").and_then(Json::as_arr) {
        for o in arr {
            let shape = o
                .get("shape")
                .and_then(Json::as_arr)
                .context("output needs a \"shape\" array")?
                .iter()
                .map(|v| v.as_usize().context("non-integer shape entry"))
                .collect::<Result<Vec<usize>>>()?;
            // a non-finite sum serializes as null (no JSON literal for
            // NaN/inf); map it back to NaN rather than rejecting the
            // response
            let sum = match o.get("sum") {
                Some(Json::Null) => f64::NAN,
                Some(v) => v.as_f64().context("output needs a numeric \"sum\"")?,
                None => anyhow::bail!("output needs a numeric \"sum\""),
            };
            let first = o
                .get("first")
                .and_then(Json::as_f32_vec)
                .context("output needs a \"first\" array")?;
            outputs.push(OutputSummary { shape, sum, first });
        }
    }
    Ok(Response { id, ok, error, code, outputs, batched, queue_ms, run_ms })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_and_defaulted_requests() {
        let r = parse_request(
            r#"{"id": 7, "model": "sim-opt-125m", "quant": "abfp_w4a4_n64",
                "batch": 3, "deadline_ms": 500}"#,
        )
        .unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.model, "sim-opt-125m");
        assert_eq!(r.quant, "abfp_w4a4_n64");
        assert_eq!(r.batch_index, 3);
        assert_eq!(r.deadline_ms, Some(500));
        assert!(r.tokens.is_none());

        let d = parse_request(r#"{"id": 1, "model": "m"}"#).unwrap();
        assert_eq!(d.quant, "fp32");
        assert_eq!(d.batch_index, 0);
        assert!(d.deadline_ms.is_none());

        let t = parse_request(r#"{"id": 2, "model": "m", "tokens": [1, 2, 3]}"#).unwrap();
        assert_eq!(t.tokens, Some(vec![1, 2, 3]));
    }

    #[test]
    fn parse_rejects_malformed_requests() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"model": "m"}"#).is_err(), "missing id");
        assert!(parse_request(r#"{"id": 3}"#).is_err(), "missing model");
        assert!(parse_request(r#"{"id": "x", "model": "m"}"#).is_err(), "non-numeric id");
        // inline tokens must be all-numeric integers — no silent
        // filtering, no silent truncation
        assert!(
            parse_request(r#"{"id": 4, "model": "m", "tokens": [1, "x", 3]}"#).is_err(),
            "junk token entry"
        );
        assert!(
            parse_request(r#"{"id": 4, "model": "m", "tokens": [1.5, 2]}"#).is_err(),
            "fractional token id"
        );
        assert!(
            parse_request(r#"{"id": 5, "model": "m", "tokens": 3}"#).is_err(),
            "tokens must be an array"
        );
        // numeric fields must be non-negative integers, never truncated
        assert!(parse_request(r#"{"id": 1.5, "model": "m"}"#).is_err(), "fractional id");
        assert!(
            parse_request(r#"{"id": 1, "model": "m", "deadline_ms": -5}"#).is_err(),
            "negative deadline"
        );
        assert!(
            parse_request(r#"{"id": 1, "model": "m", "batch": 2.5}"#).is_err(),
            "fractional batch index"
        );
        // unknown fields are rejected, not silently ignored — a typo'd
        // knob must not quietly deactivate itself
        let e = parse_request(r#"{"id": 1, "model": "m", "deadline_mss": 5}"#)
            .unwrap_err()
            .to_string();
        assert!(e.contains("deadline_mss"), "{}", e);
    }

    #[test]
    fn response_lines_are_valid_json_and_summaries_exact() {
        let t = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let s = summarize(&[t]);
        assert_eq!(s[0].shape, vec![2, 3]);
        assert_eq!(s[0].sum, 21.0);
        assert_eq!(s[0].first, vec![1.0, 2.0, 3.0, 4.0]);

        let ok = Response::ok(9, s, 4, 0.5, 12.0);
        let j = Json::parse(&ok.line()).unwrap();
        assert_eq!(j.get("id").unwrap().as_f64(), Some(9.0));
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("batched").unwrap().as_f64(), Some(4.0));

        let err = Response::err(3, codes::QUEUE_FULL, "queue full");
        let j = Json::parse(&err.line()).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(j.get("error").unwrap().as_str(), Some("queue full"));
        assert_eq!(j.get("code").unwrap().as_str(), Some(codes::QUEUE_FULL));
    }

    #[test]
    fn requests_and_responses_round_trip_the_wire() {
        let mut req = Request::new(41, "sim-opt-125m", "abfp_w4a4_n64", 3);
        req.deadline_ms = Some(250);
        req.tokens = Some(vec![1, 2, 3]);
        let back = parse_request(&req.line()).unwrap();
        assert_eq!(back.id, req.id);
        assert_eq!(back.model, req.model);
        assert_eq!(back.quant, req.quant);
        assert_eq!(back.batch_index, req.batch_index);
        assert_eq!(back.tokens, req.tokens);
        assert_eq!(back.deadline_ms, req.deadline_ms);

        let t = Tensor::new(vec![2], vec![1.5, -2.5]);
        let resp = Response::ok(41, summarize(&[t]), 2, 0.25, 3.5);
        let back = parse_response(&resp.line()).unwrap();
        assert!(back.ok);
        assert_eq!(back.id, 41);
        assert_eq!(back.batched, 2);
        assert_eq!(back.outputs, resp.outputs);
        assert!(back.code.is_none());

        let err = Response::err(ERR_ID, codes::BAD_REQUEST, "bad request: nope");
        let back = parse_response(&err.line()).unwrap();
        assert!(!back.ok);
        assert_eq!(back.id, ERR_ID);
        assert_eq!(back.code.as_deref(), Some(codes::BAD_REQUEST));
        assert!(back.outputs.is_empty());
    }

    #[test]
    fn field_and_code_manifests_cover_the_wire_structs() {
        // every field to_json can emit is in the manifest, and vice versa
        let mut req = Request::new(1, "m", "q", 0);
        req.tokens = Some(vec![1]);
        req.deadline_ms = Some(5);
        let j = req.to_json();
        let keys: Vec<&str> =
            j.as_obj().unwrap().keys().map(String::as_str).collect();
        for k in &keys {
            assert!(REQUEST_FIELDS.contains(k), "undocumented request field {}", k);
        }
        assert_eq!(keys.len(), REQUEST_FIELDS.len());

        let mut resp = Response::err(1, codes::RUN_FAILED, "x");
        resp.outputs = Vec::new();
        let j = resp.to_json();
        for k in j.as_obj().unwrap().keys() {
            assert!(
                RESPONSE_FIELDS.contains(&k.as_str()),
                "undocumented response field {}",
                k
            );
        }
        assert_eq!(codes::ALL.len(), 10);
    }

    #[test]
    fn write_line_is_byte_identical_to_line() {
        // every shape of request: minimal, full, with tokens
        let mut reqs = vec![Request::new(0, "m", "fp32", 0)];
        let mut full = Request::new(41, "sim-opt-125m", "abfp_w4a4_n64", 3);
        full.deadline_ms = Some(250);
        full.tokens = Some(vec![-1, 0, 7, i32::MAX]);
        reqs.push(full);
        let mut esc = Request::new(ERR_ID - 1, "mo\"del\n", "fp\\32", u64::MAX / 2);
        esc.tokens = Some(vec![]);
        reqs.push(esc);
        let mut buf = Vec::new();
        for req in &reqs {
            req.write_line(&mut buf);
            assert_eq!(buf, req.line().as_bytes(), "request {:?}", req);
        }

        // every shape of response: success with outputs, error, ERR_ID,
        // non-finite sum
        let t = Tensor::new(vec![2, 3], vec![1.0, 2.5, -3.0, 4.0, 5.0, 6.0]);
        let mut resps = vec![
            Response::ok(9, summarize(&[t]), 4, 0.5, 12.25),
            Response::err(3, codes::QUEUE_FULL, "queue full"),
            Response::err(ERR_ID, codes::BAD_REQUEST, "bad request: \"x\"\n"),
        ];
        let mut nf = Response::ok(1, vec![], 1, 0.0, 1.0);
        nf.outputs.push(OutputSummary {
            shape: vec![2],
            sum: f64::INFINITY,
            first: vec![f32::NAN],
        });
        resps.push(nf);
        for resp in &resps {
            resp.write_line(&mut buf);
            assert_eq!(buf, resp.line().as_bytes(), "response {:?}", resp);
        }
    }

    #[test]
    fn streaming_parser_accepts_what_the_tree_parser_does() {
        let mut scratch = Request::default();
        for line in [
            r#"{"id": 7, "model": "sim-opt-125m", "quant": "abfp_w4a4_n64",
                "batch": 3, "deadline_ms": 500}"#,
            r#"{"id": 1, "model": "m"}"#,
            r#"{"id": 2, "model": "m", "tokens": [1, 2, 3]}"#,
            r#"{"id": 2, "model": "m", "tokens": []}"#,
            r#"{"id": 9007199254740991, "model": "é\n\"x\""}"#,
        ] {
            let tree = parse_request(line).unwrap();
            parse_request_streaming(line.as_bytes(), &mut scratch).unwrap();
            assert_eq!(scratch, tree, "line {:?}", line);
        }
    }

    #[test]
    fn streaming_parser_rejects_what_the_tree_parser_does() {
        let mut scratch = Request::default();
        for line in [
            "not json",
            r#"{"model": "m"}"#,
            r#"{"id": 3}"#,
            r#"{"id": "x", "model": "m"}"#,
            r#"{"id": 1.5, "model": "m"}"#,
            r#"{"id": 01, "model": "m"}"#,
            r#"{"id": 4, "model": "m", "tokens": [1, "x", 3]}"#,
            r#"{"id": 4, "model": "m", "tokens": [1.5, 2]}"#,
            r#"{"id": 5, "model": "m", "tokens": 3}"#,
            r#"{"id": 1, "model": "m", "deadline_ms": -5}"#,
            r#"{"id": 1, "model": "m", "deadline_mss": 5}"#,
            r#"{"id": 1, "model": "m"} extra"#,
            r#"[1, 2]"#,
        ] {
            assert!(parse_request(line).is_err(), "tree must reject {:?}", line);
            assert!(
                parse_request_streaming(line.as_bytes(), &mut scratch).is_err(),
                "streaming must reject {:?}",
                line
            );
        }
    }

    #[test]
    fn non_string_quant_is_rejected_not_defaulted() {
        // regression: quant used to fall back to fp32 when present but
        // not a string — a typo'd config silently served fp32
        let line = r#"{"id": 1, "model": "m", "quant": 4}"#;
        let mut scratch = Request::default();
        assert!(parse_request(line).is_err());
        assert!(parse_request_streaming(line.as_bytes(), &mut scratch).is_err());
    }

    #[test]
    fn streaming_scratch_reuse_is_clean_across_lines() {
        // a field set by one line must not leak into the next
        let mut scratch = Request::default();
        let full =
            br#"{"id":1, "model":"m", "quant":"q", "batch":5, "tokens":[1,2], "deadline_ms":9}"#;
        parse_request_streaming(full, &mut scratch).unwrap();
        parse_request_streaming(br#"{"id": 2, "model": "n"}"#, &mut scratch).unwrap();
        assert_eq!(scratch, parse_request(r#"{"id": 2, "model": "n"}"#).unwrap());
        // and a failed parse leaves the scratch safe to reuse
        assert!(parse_request_streaming(b"{", &mut scratch).is_err());
        parse_request_streaming(br#"{"id": 3, "model": "o"}"#, &mut scratch).unwrap();
        assert_eq!(scratch.id, 3);
        assert_eq!(scratch.model, "o");
    }

    #[test]
    fn stats_lines_and_markers_are_recognized() {
        assert!(is_stats_request(STATS_LINE.as_bytes()));
        // whitespace / formatting-lenient
        assert!(is_stats_request(b"{ \"verb\" : \"stats\" }"));
        // not stats: other verbs, extra fields, ordinary requests
        assert!(!is_stats_request(b"{\"verb\":\"run\"}"));
        assert!(!is_stats_request(b"{\"verb\":\"stats\",\"id\":1}"));
        assert!(!is_stats_request(br#"{"id":1,"model":"m"}"#));
        assert!(!is_stats_request(b""));
        // shutdown lines: same canonical/lenient recognition
        assert!(is_shutdown_request(SHUTDOWN_LINE.as_bytes()));
        assert!(is_shutdown_request(b"{ \"verb\" : \"shutdown\" }"));
        assert!(!is_shutdown_request(STATS_LINE.as_bytes()));
        assert!(!is_shutdown_request(b"{\"verb\":\"shutdown\",\"id\":1}"));
        assert!(!is_stats_request(SHUTDOWN_LINE.as_bytes()));
        // the sentinels never collide with a real error response
        let m = stats_marker();
        assert!(is_stats_marker(&m));
        let d = drain_marker();
        assert!(is_drain_marker(&d));
        assert!(!is_stats_marker(&d));
        assert!(!is_drain_marker(&m));
        let real = Response::err(ERR_ID, codes::BAD_REQUEST, "bad request: x");
        assert!(!is_stats_marker(&real));
        assert!(!is_drain_marker(&real));
        let ack = Response::err(ERR_ID, codes::SHUTTING_DOWN, "draining");
        assert!(!is_stats_marker(&ack));
        assert!(!is_drain_marker(&ack));
        assert_eq!(VERBS, &["run", "shutdown", "stats"]);
    }

    #[test]
    fn err_into_is_equivalent_to_err_for_any_scratch_state() {
        // from a success response carrying outputs...
        let mut scratch = Response::ok(
            9,
            vec![OutputSummary { shape: vec![2], sum: 1.0, first: vec![1.0f32] }],
            4,
            1.25,
            2.5,
        );
        scratch.err_into(7, codes::INTERNAL_ERROR, "worker panicked");
        assert_eq!(
            scratch.line(),
            Response::err(7, codes::INTERNAL_ERROR, "worker panicked").line()
        );
        // ...and from a previous (longer) error, shrinking in place
        scratch.err_into(8, codes::SHUTTING_DOWN, "bye");
        assert_eq!(scratch.line(), Response::err(8, codes::SHUTTING_DOWN, "bye").line());
    }

    #[test]
    fn responses_with_null_sum_parse_back_as_nan() {
        let mut resp = Response::ok(1, vec![], 1, 0.0, 1.0);
        resp.outputs.push(OutputSummary {
            shape: vec![2],
            sum: f64::NAN,
            first: vec![1.0],
        });
        let back = parse_response(&resp.line()).unwrap();
        assert!(back.outputs[0].sum.is_nan());
    }
}
