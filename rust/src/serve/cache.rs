//! Prepared-session cache: open each (model × quant-config × executor ×
//! backend) session once, reuse it for every subsequent request.
//!
//! Opening an eval session is the expensive part of serving — weights
//! are converted to host tensors and QDQ-transformed (the host analog of
//! a device upload, see `runtime::native`). The cache makes that a
//! once-per-key cost: a hit hands back the same `Rc<Session>`, whose
//! prepared state persists across `run_batch` calls, so the second
//! request for a config performs **no re-QDQ** (asserted by the serving
//! tests via `runtime::native::prepared_builds`).
//!
//! The executor and backend names are part of the key because the
//! prepared state is specific to both (a session hoists one backend
//! handle at open); reconfiguring the backend mid-serve simply faults in
//! a fresh entry rather than silently running on a stale handle.

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::Result;

use crate::runtime::Session;

use super::metrics;

/// Full identity of a prepared session.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SessionKey {
    pub model: String,
    pub quant: String,
    pub executor: String,
    pub backend: String,
}

#[derive(Default)]
pub struct SessionCache {
    entries: HashMap<SessionKey, Rc<Session>>,
    hits: usize,
    misses: usize,
    /// Which shard's metrics cell this cache's traffic lands in.
    shard: usize,
}

impl SessionCache {
    pub fn new() -> SessionCache {
        SessionCache::default()
    }

    /// A cache whose hit/miss traffic is attributed to `shard` in the
    /// metrics registry (each shard worker owns one).
    pub fn for_shard(shard: usize) -> SessionCache {
        SessionCache { shard, ..SessionCache::default() }
    }

    /// The cached session for `key`, opening (and retaining) it on miss.
    /// An open failure is returned to the caller and cached as nothing —
    /// a later retry re-attempts the open (and counts as another miss
    /// only once it succeeds).
    pub fn get_or_open(
        &mut self,
        key: &SessionKey,
        open: impl FnOnce() -> Result<Session>,
    ) -> Result<Rc<Session>> {
        if let Some(sess) = self.entries.get(key) {
            self.hits += 1;
            metrics::cache_hit(self.shard);
            return Ok(Rc::clone(sess));
        }
        let sess = Rc::new(open()?);
        self.misses += 1;
        metrics::cache_miss(self.shard);
        self.entries.insert(key.clone(), Rc::clone(&sess));
        Ok(sess)
    }

    /// Drop every cached session, keeping the hit/miss counters. The
    /// panic-recovery path: after a caught unwind the prepared state of
    /// any session the worker touched is suspect, so the supervisor
    /// evicts them all and lets the next batch fault in fresh ones
    /// (each re-open counts as a miss, visible in the stats).
    pub fn evict_all(&mut self) {
        self.entries.clear();
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// (hits, misses) since construction.
    pub fn stats(&self) -> (usize, usize) {
        (self.hits, self.misses)
    }
}
