//! Bounded admission queue: the concurrency boundary of the server.
//!
//! Clients (the stdin reader, TCP connection readers, loadgen threads)
//! push [`Job`]s from any thread; one or more worker threads pop them
//! through the micro-batcher. The queue is **bounded with
//! reject-on-full backpressure**: a full queue hands the job straight
//! back instead of buffering unboundedly or blocking the submitter —
//! the client decides whether to retry (the closed-loop loadgen does)
//! or surface the error (the stdio/TCP front ends answer `queue_full`).
//!
//! Internally jobs live in per-[`BatchKey`] buckets ordered
//! **earliest-deadline-first** (EDF): within a key, the job whose
//! deadline lands soonest dispatches first; jobs without a deadline
//! sort after every deadlined job, among themselves in arrival order.
//! Because batches never mix keys and per-request outputs are
//! independent of batch composition, EDF reordering can change *when* a
//! request runs but never *what* it returns — the determinism contract
//! survives scheduling.
//!
//! For sharded serving, [`AdmissionQueue::take_anchor`] adds key-level
//! coordination: while one worker holds a key (a [`KeyHold`]), other
//! workers skip it — unless hot-key replication is enabled and the
//! bucket is long enough to be worth serving from two shards at once.
//!
//! Every job carries its own response channel and an optional absolute
//! deadline; expiry is enforced by the batcher (pre-dispatch) and the
//! dispatcher (post-run), never here — admission stays O(1) in the
//! number of keys plus the bucket insertion scan.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::metrics;
use super::protocol::{Request, Response};

/// Compatibility key of a micro-batch: requests for the same prepared
/// session (model × quant config) can share one batched forward.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BatchKey {
    /// Manifest model name.
    pub model: String,
    /// Eval quant-config name.
    pub quant: String,
}

/// Stable home shard of a key (FNV-1a over model and quant, mod
/// `nshards`). Sticky assignment keeps a key's prepared session warm on
/// one worker; stealing and hot-key replication relax it under skew.
pub fn home_shard(key: &BatchKey, nshards: usize) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key.model.bytes().chain([0u8]).chain(key.quant.bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_01b3);
    }
    (h % nshards.max(1) as u64) as usize
}

/// One admitted request: the parsed protocol request plus its response
/// route and timing/deadline bookkeeping.
pub struct Job {
    /// The parsed protocol request.
    pub req: Request,
    /// Admission time; `queue_ms` on the response measures from here.
    pub enqueued: Instant,
    /// Absolute deadline derived from `req.deadline_ms` at admission.
    pub deadline: Option<Instant>,
    /// Where the response goes (per client / per connection).
    pub respond: Sender<Response>,
    /// Admission sequence number (set by the queue): the EDF tiebreak
    /// and the FIFO order for jobs without deadlines.
    pub(crate) seq: u64,
    /// Trace-span stamp: ns from `enqueued` to queue admission (set by
    /// [`AdmissionQueue::try_push`]; feeds `span_admit_ns`).
    pub(crate) admit_ns: u64,
    /// Trace-span stamp: ns from `enqueued` to micro-batch assembly
    /// (set by the batcher; feeds `span_assemble_ns`).
    pub(crate) assemble_ns: u64,
}

impl std::fmt::Debug for Job {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Job")
            .field("id", &self.req.id)
            .field("model", &self.req.model)
            .field("quant", &self.req.quant)
            .field("deadline", &self.deadline)
            .field("seq", &self.seq)
            .finish()
    }
}

impl Job {
    /// Wrap an admitted request; the deadline clock starts now.
    pub fn new(req: Request, respond: Sender<Response>) -> Job {
        let enqueued = Instant::now();
        let deadline = req
            .deadline_ms
            .map(|ms| enqueued + Duration::from_millis(ms));
        Job { req, enqueued, deadline, respond, seq: 0, admit_ns: 0, assemble_ns: 0 }
    }

    /// The micro-batch compatibility key of this request.
    pub fn key(&self) -> BatchKey {
        BatchKey { model: self.req.model.clone(), quant: self.req.quant.clone() }
    }

    /// Whether the job's deadline has lapsed as of `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }

    /// Send `resp` to the requester; a hung-up client is not an error.
    pub fn reply(&self, resp: Response) {
        let _ = self.respond.send(resp);
    }
}

/// Why admission handed a job back. Each reason maps to exactly one
/// documented wire code, so the stdio/TCP front ends can answer the
/// client without guessing at queue state that may have changed since.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// The bounded queue is at capacity. Backpressure: retry later.
    Full,
    /// The queue is draining (or closed) for shutdown; no new work is
    /// admitted and a retry will not help — switch servers.
    Draining,
}

impl RejectReason {
    /// The stable wire code a front end answers for this rejection.
    pub fn code(self) -> &'static str {
        match self {
            RejectReason::Full => super::protocol::codes::QUEUE_FULL,
            RejectReason::Draining => super::protocol::codes::SHUTTING_DOWN,
        }
    }

    /// The human-readable message paired with [`RejectReason::code`].
    pub fn message(self) -> &'static str {
        match self {
            RejectReason::Full => "queue full (backpressure): retry later",
            RejectReason::Draining => "server draining: no new work accepted",
        }
    }
}

/// A rejected admission: the job handed back, plus why.
#[derive(Debug)]
pub struct Rejected {
    /// The job, returned to the caller untouched.
    pub job: Job,
    /// Why admission refused it.
    pub reason: RejectReason,
}

/// EDF ordering: sooner deadline first; a deadline beats no deadline;
/// ties (and the no-deadline tail) fall back to arrival order.
fn edf_before(a: &Job, b: &Job) -> bool {
    match (a.deadline, b.deadline) {
        (Some(x), Some(y)) => (x, a.seq) < (y, b.seq),
        (Some(_), None) => true,
        (None, Some(_)) => false,
        (None, None) => a.seq < b.seq,
    }
}

struct State {
    /// Per-key EDF-ordered buckets. Invariant: no empty buckets.
    buckets: HashMap<BatchKey, VecDeque<Job>>,
    /// Total queued jobs across all buckets (the bound `cap` applies to).
    len: usize,
    /// Keys currently anchored by a worker (count of live [`KeyHold`]s).
    active: HashMap<BatchKey, usize>,
    closed: bool,
    /// Draining for shutdown: admission rejects with `shutting_down`
    /// while workers keep serving what is already queued.
    draining: bool,
    /// Monotone arrival counter — lets the batcher's window wait sleep
    /// on "a NEW job arrived" instead of busy-polling a non-empty queue
    /// of incompatible jobs.
    arrivals: u64,
    /// Monotone admission counter feeding [`Job::seq`].
    next_seq: u64,
}

/// The bounded, deadline-aware admission queue shared by every producer
/// and worker thread (see the module docs for the scheduling policy).
pub struct AdmissionQueue {
    state: Mutex<State>,
    arrived: Condvar,
    cap: usize,
}

/// How a worker came to anchor a batch key (reported per batch so the
/// loadgen/bench occupancy story can attribute cross-shard traffic).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AnchorKind {
    /// The key's stable [`home_shard`] is this worker.
    Home,
    /// A foreign idle worker stole the key (its home was busy or slow).
    Stolen,
    /// Hot-key replication: the bucket was long enough that a second
    /// worker serves the same key concurrently.
    Hot,
}

/// RAII hold on a batch key taken by [`AdmissionQueue::take_anchor`]:
/// while alive, other workers skip the key unless hot-key replication
/// applies. Dropping it (after dispatch) releases the key and wakes
/// waiting workers.
pub struct KeyHold {
    queue: Arc<AdmissionQueue>,
    key: BatchKey,
}

impl Drop for KeyHold {
    fn drop(&mut self) {
        let mut st = self.queue.state.lock().unwrap();
        if let Some(n) = st.active.get_mut(&self.key) {
            *n -= 1;
            if *n == 0 {
                st.active.remove(&self.key);
            }
        }
        drop(st);
        self.queue.arrived.notify_all();
    }
}

impl AdmissionQueue {
    /// A queue admitting at most `cap` (min 1) jobs at a time.
    pub fn new(cap: usize) -> Arc<AdmissionQueue> {
        Arc::new(AdmissionQueue {
            state: Mutex::new(State {
                buckets: HashMap::new(),
                len: 0,
                active: HashMap::new(),
                closed: false,
                draining: false,
                arrivals: 0,
                next_seq: 0,
            }),
            arrived: Condvar::new(),
            cap: cap.max(1),
        })
    }

    /// The admission bound this queue was built with.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Queued (not yet anchored/dispatched) jobs right now.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().len
    }

    /// Whether no jobs are queued right now.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Admission with backpressure: a full queue rejects with
    /// [`RejectReason::Full`], a draining or closed queue with
    /// [`RejectReason::Draining`] — either way the job is handed back
    /// to the caller instead of blocking. Admitted jobs are
    /// EDF-inserted into their key's bucket.
    pub fn try_push(&self, mut job: Job) -> Result<(), Rejected> {
        let mut st = self.state.lock().unwrap();
        if st.closed || st.draining {
            metrics::rejected();
            return Err(Rejected { job, reason: RejectReason::Draining });
        }
        if st.len >= self.cap {
            metrics::rejected();
            return Err(Rejected { job, reason: RejectReason::Full });
        }
        job.admit_ns = job.enqueued.elapsed().as_nanos() as u64;
        metrics::admitted();
        job.seq = st.next_seq;
        st.next_seq += 1;
        st.arrivals += 1;
        st.len += 1;
        let key = job.key();
        let bucket = st.buckets.entry(key).or_default();
        // Backward scan from the tail: no-deadline traffic (the common
        // case) appends in O(1) and stays FIFO.
        let mut i = bucket.len();
        while i > 0 && edf_before(&job, &bucket[i - 1]) {
            i -= 1;
        }
        bucket.insert(i, job);
        drop(st);
        self.arrived.notify_all();
        Ok(())
    }

    /// No more admissions; the workers drain what is queued and stop.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.arrived.notify_all();
    }

    /// Whether [`AdmissionQueue::close`] has been called.
    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }

    /// Flip the queue into its draining state: new admissions reject
    /// with [`RejectReason::Draining`] while already-admitted jobs keep
    /// dispatching. Idempotent; the first call records `drain_begun`.
    pub fn begin_drain(&self) {
        let mut st = self.state.lock().unwrap();
        if !st.draining {
            st.draining = true;
            metrics::drain_begun();
        }
        drop(st);
        self.arrived.notify_all();
    }

    /// Whether [`AdmissionQueue::begin_drain`] (or close) has been
    /// called — i.e. the server no longer admits new work.
    pub fn is_draining(&self) -> bool {
        let st = self.state.lock().unwrap();
        st.draining || st.closed
    }

    /// Block until every queued job has been taken by a worker and
    /// every [`KeyHold`] released (in-flight batches dispatched), or
    /// until `timeout`. Returns `true` when fully drained. Intended to
    /// follow [`AdmissionQueue::begin_drain`]; the caller decides what
    /// to do with leftovers on timeout (see
    /// [`AdmissionQueue::flush_all`]).
    pub fn wait_drained(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            if st.len == 0 && st.active.is_empty() {
                return true;
            }
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            // Short slices: pops do not signal the condvar (only
            // arrivals and hold releases do), so re-check periodically
            // rather than trusting a wakeup to arrive.
            let slice = (deadline - now).min(Duration::from_millis(5));
            let (guard, _) = self.arrived.wait_timeout(st, slice).unwrap();
            st = guard;
        }
    }

    /// Remove and return every queued job (drain-timeout expiry: the
    /// caller answers them with `shutting_down` so no admitted request
    /// goes unanswered). Records each as `drain_flushed`.
    pub fn flush_all(&self) -> Vec<Job> {
        let mut st = self.state.lock().unwrap();
        let mut out = Vec::new();
        let keys: Vec<BatchKey> = st.buckets.keys().cloned().collect();
        for key in keys {
            while st.buckets.contains_key(&key) {
                out.push(Self::pop_head(&mut st, &key));
            }
        }
        metrics::drain_flushed(out.len() as u64);
        drop(st);
        self.arrived.notify_all();
        out
    }

    /// Blocking pop of the globally EDF-first job (FIFO when nothing
    /// carries a deadline); `None` once closed *and* drained. The
    /// single-worker path — it ignores key holds.
    pub(crate) fn pop_front_blocking(&self) -> Option<Job> {
        let mut st = self.state.lock().unwrap();
        loop {
            let mut best: Option<BatchKey> = None;
            for (key, bucket) in &st.buckets {
                let head = bucket.front().expect("no empty buckets");
                let better = match &best {
                    None => true,
                    Some(bk) => edf_before(head, st.buckets[bk].front().unwrap()),
                };
                if better {
                    best = Some(key.clone());
                }
            }
            if let Some(key) = best {
                return Some(Self::pop_head(&mut st, &key));
            }
            if st.closed {
                return None;
            }
            st = self.arrived.wait(st).unwrap();
        }
    }

    fn pop_head(st: &mut State, key: &BatchKey) -> Job {
        let bucket = st.buckets.get_mut(key).expect("bucket exists");
        let job = bucket.pop_front().expect("bucket non-empty");
        if bucket.is_empty() {
            st.buckets.remove(key);
        }
        st.len -= 1;
        job
    }

    /// Blocking pop of a batch anchor for shard `shard` of `nshards`,
    /// plus a [`KeyHold`] granting the key to this worker. Eligible keys
    /// are those no other worker holds — or, when `replicate_hot`, keys
    /// whose bucket holds at least `hot_min` jobs (long enough to be
    /// worth a second prepared session). Home keys are preferred; an
    /// idle worker steals the EDF-first eligible foreign key rather than
    /// sit idle. `None` once closed *and* drained.
    pub(crate) fn take_anchor(
        self: &Arc<Self>,
        shard: usize,
        nshards: usize,
        replicate_hot: bool,
        hot_min: usize,
    ) -> Option<(Job, AnchorKind, KeyHold)> {
        let mut st = self.state.lock().unwrap();
        loop {
            let mut best: Option<(BatchKey, AnchorKind)> = None;
            for (key, bucket) in &st.buckets {
                let held = st.active.get(key).copied().unwrap_or(0) > 0;
                let hot = replicate_hot && bucket.len() >= hot_min.max(1);
                if held && !hot {
                    continue;
                }
                let kind = if held {
                    AnchorKind::Hot
                } else if home_shard(key, nshards) == shard {
                    AnchorKind::Home
                } else {
                    AnchorKind::Stolen
                };
                let better = match &best {
                    None => true,
                    Some((bk, bkind)) => {
                        let home = kind == AnchorKind::Home;
                        let best_home = *bkind == AnchorKind::Home;
                        // prefer home keys; within a class, EDF order
                        (home && !best_home)
                            || (home == best_home
                                && edf_before(
                                    bucket.front().unwrap(),
                                    st.buckets[bk].front().unwrap(),
                                ))
                    }
                };
                if better {
                    best = Some((key.clone(), kind));
                }
            }
            if let Some((key, kind)) = best {
                let job = Self::pop_head(&mut st, &key);
                *st.active.entry(key.clone()).or_insert(0) += 1;
                drop(st);
                return Some((job, kind, KeyHold { queue: Arc::clone(self), key }));
            }
            if st.closed && st.len == 0 {
                return None;
            }
            // Either empty, or every key is held by another worker:
            // sleep until an arrival, a close, or a hold release.
            st = self.arrived.wait(st).unwrap();
        }
    }

    /// Remove up to `max` queued jobs matching `key`, in EDF order
    /// (arrival order when no deadlines are in play — so an incompatible
    /// request is never starved by later-arriving traffic of another key
    /// jumping the whole queue, and same-key FIFO is preserved).
    pub(crate) fn drain_matching(&self, key: &BatchKey, max: usize) -> Vec<Job> {
        let mut st = self.state.lock().unwrap();
        let mut out = Vec::new();
        while out.len() < max {
            if !st.buckets.contains_key(key) {
                break;
            }
            out.push(Self::pop_head(&mut st, key));
        }
        out
    }

    pub(crate) fn arrivals(&self) -> u64 {
        self.state.lock().unwrap().arrivals
    }

    /// Block until an arrival newer than `seen` (or `timeout`, or close);
    /// returns the current arrival count. The batching-window sleep.
    pub(crate) fn wait_new_arrival(&self, seen: u64, timeout: Duration) -> u64 {
        let mut st = self.state.lock().unwrap();
        if st.arrivals == seen && !st.closed {
            let (guard, _) = self.arrived.wait_timeout(st, timeout).unwrap();
            st = guard;
        }
        st.arrivals
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn job(id: u64, model: &str, quant: &str) -> (Job, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        (Job::new(Request::new(id, model, quant, 0), tx), rx)
    }

    fn deadline_job(id: u64, quant: &str, ms: u64) -> (Job, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        let mut req = Request::new(id, "m", quant, 0);
        req.deadline_ms = Some(ms);
        (Job::new(req, tx), rx)
    }

    #[test]
    fn bounded_queue_rejects_when_full_and_after_close() {
        let q = AdmissionQueue::new(2);
        assert_eq!(q.capacity(), 2);
        let (j1, _r1) = job(1, "m", "fp32");
        let (j2, _r2) = job(2, "m", "fp32");
        let (j3, _r3) = job(3, "m", "fp32");
        assert!(q.try_push(j1).is_ok());
        assert!(q.try_push(j2).is_ok());
        let rejected = q.try_push(j3).unwrap_err();
        assert_eq!(rejected.job.req.id, 3, "full queue hands the job back");
        assert_eq!(rejected.reason, RejectReason::Full);
        assert_eq!(q.len(), 2);
        // draining one slot re-admits
        let popped = q.pop_front_blocking().unwrap();
        assert_eq!(popped.req.id, 1);
        assert!(q.try_push(rejected.job).is_ok());
        // a closed queue rejects regardless of occupancy — and the
        // reason is shutdown, not backpressure
        q.close();
        let (j4, _r4) = job(4, "m", "fp32");
        assert_eq!(q.try_push(j4).unwrap_err().reason, RejectReason::Draining);
    }

    #[test]
    fn drain_rejects_new_work_but_serves_queued_jobs() {
        let q = AdmissionQueue::new(8);
        let (j1, _r1) = job(1, "m", "fp32");
        q.try_push(j1).unwrap();
        assert!(!q.is_draining());
        q.begin_drain();
        q.begin_drain(); // idempotent
        assert!(q.is_draining());
        assert!(!q.is_closed(), "draining is not yet closed");
        let (j2, _r2) = job(2, "m", "fp32");
        let rej = q.try_push(j2).unwrap_err();
        assert_eq!(rej.reason, RejectReason::Draining);
        assert_eq!(rej.reason.code(), super::super::protocol::codes::SHUTTING_DOWN);
        // the already-admitted job is still served
        assert_eq!(q.pop_front_blocking().unwrap().req.id, 1);
        assert!(q.wait_drained(Duration::from_millis(50)), "empty queue drains");
    }

    #[test]
    fn wait_drained_times_out_and_flush_all_empties_the_queue() {
        let q = AdmissionQueue::new(8);
        let mut rxs = Vec::new();
        for (id, quant) in [(1, "a"), (2, "b"), (3, "a")] {
            let (j, r) = job(id, "m", quant);
            rxs.push(r);
            q.try_push(j).unwrap();
        }
        q.begin_drain();
        assert!(!q.wait_drained(Duration::from_millis(20)), "jobs still queued");
        let mut flushed: Vec<u64> = q.flush_all().iter().map(|j| j.req.id).collect();
        flushed.sort_unstable();
        assert_eq!(flushed, vec![1, 2, 3]);
        assert!(q.is_empty());
        assert!(q.wait_drained(Duration::from_millis(20)));
    }

    #[test]
    fn drain_matching_preserves_fifo_and_leaves_other_keys() {
        let q = AdmissionQueue::new(16);
        let mut rxs = Vec::new();
        for (id, quant) in [(1, "a"), (2, "b"), (3, "a"), (4, "a"), (5, "b")] {
            let (j, r) = job(id, "m", quant);
            rxs.push(r);
            q.try_push(j).unwrap();
        }
        let key = BatchKey { model: "m".into(), quant: "a".into() };
        let got = q.drain_matching(&key, 2);
        assert_eq!(got.iter().map(|j| j.req.id).collect::<Vec<_>>(), vec![1, 3]);
        // remaining: 2(b), 4(a), 5(b); no deadlines, so global pops stay
        // in arrival order
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop_front_blocking().unwrap().req.id, 2);
        assert_eq!(q.pop_front_blocking().unwrap().req.id, 4);
        assert_eq!(q.pop_front_blocking().unwrap().req.id, 5);
    }

    #[test]
    fn close_wakes_blocked_pop() {
        let q = AdmissionQueue::new(4);
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop_front_blocking().is_none());
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap(), "pop on a closed empty queue returns None");
    }

    #[test]
    fn expiry_is_relative_to_admission() {
        let (tx, _rx) = mpsc::channel();
        let mut req = Request::new(1, "m", "fp32", 0);
        req.deadline_ms = Some(5);
        let j = Job::new(req, tx);
        assert!(!j.expired(j.enqueued));
        assert!(j.expired(j.enqueued + Duration::from_millis(6)));
        let (tx2, _rx2) = mpsc::channel();
        let j2 = Job::new(Request::new(2, "m", "fp32", 0), tx2);
        assert!(!j2.expired(j2.enqueued + Duration::from_secs(3600)), "no deadline");
    }

    #[test]
    fn edf_orders_same_key_by_deadline_then_arrival() {
        let q = AdmissionQueue::new(16);
        let mut rxs = Vec::new();
        for (id, ms) in [(1, None), (2, Some(500)), (3, Some(100)), (4, None)] {
            let (j, r) = match ms {
                Some(ms) => deadline_job(id, "a", ms),
                None => job(id, "m", "a"),
            };
            rxs.push(r);
            q.try_push(j).unwrap();
        }
        let key = BatchKey { model: "m".into(), quant: "a".into() };
        let got = q.drain_matching(&key, 8);
        // soonest deadline first, then the later deadline, then the
        // no-deadline jobs in arrival order
        assert_eq!(got.iter().map(|j| j.req.id).collect::<Vec<_>>(), vec![3, 2, 1, 4]);
    }

    #[test]
    fn take_anchor_excludes_held_keys_until_release() {
        let q = AdmissionQueue::new(16);
        let (ja, _ra) = job(1, "m", "a");
        let (jb, _rb) = job(2, "m", "b");
        q.try_push(ja).unwrap();
        q.try_push(jb).unwrap();
        let (first, _kind, hold) = q.take_anchor(0, 1, false, 16).unwrap();
        // the other key is still available to a concurrent worker...
        let (second, _kind2, hold2) = q.take_anchor(0, 1, false, 16).unwrap();
        assert_ne!(first.req.key(), second.req.key());
        // ...but pushing more of a held key does not make it eligible:
        // a third worker blocks until the hold on "a" is released
        let (ja2, _ra2) = job(3, "m", "a");
        q.try_push(ja2).unwrap();
        q.close();
        let q2 = Arc::clone(&q);
        let waiter = std::thread::spawn(move || {
            let (j, _k, h) = q2.take_anchor(0, 1, false, 16).expect("job after release");
            drop(h);
            j.req.id
        });
        std::thread::sleep(Duration::from_millis(20));
        drop(hold2);
        drop(hold);
        assert_eq!(waiter.join().unwrap(), 3);
        assert!(q.take_anchor(0, 1, false, 16).is_none(), "closed + drained");
    }

    #[test]
    fn take_anchor_replicates_hot_keys() {
        let q = AdmissionQueue::new(16);
        let mut rxs = Vec::new();
        for id in 1..=4 {
            let (j, r) = job(id, "m", "a");
            rxs.push(r);
            q.try_push(j).unwrap();
        }
        let (_j1, k1, hold1) = q.take_anchor(0, 2, true, 3).unwrap();
        // 3 jobs remain >= hot_min: a second worker may serve the key
        let (_j2, k2, hold2) = q.take_anchor(1, 2, true, 3).unwrap();
        assert!(k1 == AnchorKind::Home || k1 == AnchorKind::Stolen);
        assert_eq!(k2, AnchorKind::Hot);
        // without replication the same situation blocks: nothing grants
        drop(hold1);
        drop(hold2);
        q.close();
        // drain the rest so the queue ends empty
        while q.take_anchor(0, 2, false, 3).is_some() {}
        assert!(q.is_empty());
    }

    #[test]
    fn home_shard_is_stable_and_in_range() {
        let a = BatchKey { model: "sim-opt-125m".into(), quant: "fp32".into() };
        for n in 1..8 {
            let h = home_shard(&a, n);
            assert!(h < n);
            assert_eq!(h, home_shard(&a, n), "deterministic");
        }
        assert_eq!(home_shard(&a, 1), 0);
    }
}
