//! Bounded admission queue: the concurrency boundary of the server.
//!
//! Clients (the stdin reader, loadgen threads) push [`Job`]s from any
//! thread; the single worker thread pops them through the micro-batcher.
//! The queue is **bounded with reject-on-full backpressure**: a full
//! queue hands the job straight back instead of buffering unboundedly or
//! blocking the submitter — the client decides whether to retry (the
//! closed-loop loadgen does) or surface the error (the stdio server
//! answers `queue full`).
//!
//! Every job carries its own response channel and an optional absolute
//! deadline; expiry is enforced by the batcher (pre-dispatch) and the
//! dispatcher (post-run), never here — admission stays O(1).

use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::protocol::{Request, Response};

/// Compatibility key of a micro-batch: requests for the same prepared
/// session (model × quant config) can share one batched forward.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BatchKey {
    pub model: String,
    pub quant: String,
}

/// One admitted request: the parsed protocol request plus its response
/// route and timing/deadline bookkeeping.
pub struct Job {
    pub req: Request,
    pub enqueued: Instant,
    pub deadline: Option<Instant>,
    pub respond: Sender<Response>,
}

impl Job {
    pub fn new(req: Request, respond: Sender<Response>) -> Job {
        let enqueued = Instant::now();
        let deadline = req
            .deadline_ms
            .map(|ms| enqueued + Duration::from_millis(ms));
        Job { req, enqueued, deadline, respond }
    }

    pub fn key(&self) -> BatchKey {
        BatchKey { model: self.req.model.clone(), quant: self.req.quant.clone() }
    }

    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }

    /// Send `resp` to the requester; a hung-up client is not an error.
    pub fn reply(&self, resp: Response) {
        let _ = self.respond.send(resp);
    }
}

struct State {
    jobs: VecDeque<Job>,
    closed: bool,
    /// Monotone arrival counter — lets the batcher's window wait sleep
    /// on "a NEW job arrived" instead of busy-polling a non-empty queue
    /// of incompatible jobs.
    arrivals: u64,
}

pub struct AdmissionQueue {
    state: Mutex<State>,
    arrived: Condvar,
    cap: usize,
}

impl AdmissionQueue {
    pub fn new(cap: usize) -> Arc<AdmissionQueue> {
        Arc::new(AdmissionQueue {
            state: Mutex::new(State {
                jobs: VecDeque::new(),
                closed: false,
                arrivals: 0,
            }),
            arrived: Condvar::new(),
            cap: cap.max(1),
        })
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Admission with backpressure: a full (or closed) queue rejects and
    /// hands the job back to the caller instead of blocking.
    pub fn try_push(&self, job: Job) -> Result<(), Job> {
        let mut st = self.state.lock().unwrap();
        if st.closed || st.jobs.len() >= self.cap {
            return Err(job);
        }
        st.jobs.push_back(job);
        st.arrivals += 1;
        drop(st);
        self.arrived.notify_all();
        Ok(())
    }

    /// No more admissions; the worker drains what is queued and stops.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.arrived.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }

    /// Blocking pop of the oldest job; `None` once closed *and* drained.
    pub(crate) fn pop_front_blocking(&self) -> Option<Job> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(j) = st.jobs.pop_front() {
                return Some(j);
            }
            if st.closed {
                return None;
            }
            st = self.arrived.wait(st).unwrap();
        }
    }

    /// Remove up to `max` queued jobs matching `key`. FIFO order is kept
    /// both for the drained jobs and for the ones left behind, so an
    /// incompatible request is never starved by later-arriving traffic
    /// of another key jumping the whole queue.
    pub(crate) fn drain_matching(&self, key: &BatchKey, max: usize) -> Vec<Job> {
        let mut st = self.state.lock().unwrap();
        let mut out = Vec::new();
        let mut rest = VecDeque::with_capacity(st.jobs.len());
        while let Some(j) = st.jobs.pop_front() {
            if out.len() < max && j.key() == *key {
                out.push(j);
            } else {
                rest.push_back(j);
            }
        }
        st.jobs = rest;
        out
    }

    pub(crate) fn arrivals(&self) -> u64 {
        self.state.lock().unwrap().arrivals
    }

    /// Block until an arrival newer than `seen` (or `timeout`, or close);
    /// returns the current arrival count. The batching-window sleep.
    pub(crate) fn wait_new_arrival(&self, seen: u64, timeout: Duration) -> u64 {
        let mut st = self.state.lock().unwrap();
        if st.arrivals == seen && !st.closed {
            let (guard, _) = self.arrived.wait_timeout(st, timeout).unwrap();
            st = guard;
        }
        st.arrivals
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn job(id: u64, model: &str, quant: &str) -> (Job, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        (Job::new(Request::new(id, model, quant, 0), tx), rx)
    }

    #[test]
    fn bounded_queue_rejects_when_full_and_after_close() {
        let q = AdmissionQueue::new(2);
        assert_eq!(q.capacity(), 2);
        let (j1, _r1) = job(1, "m", "fp32");
        let (j2, _r2) = job(2, "m", "fp32");
        let (j3, _r3) = job(3, "m", "fp32");
        assert!(q.try_push(j1).is_ok());
        assert!(q.try_push(j2).is_ok());
        let rejected = q.try_push(j3).unwrap_err();
        assert_eq!(rejected.req.id, 3, "full queue hands the job back");
        assert_eq!(q.len(), 2);
        // draining one slot re-admits
        let popped = q.pop_front_blocking().unwrap();
        assert_eq!(popped.req.id, 1);
        assert!(q.try_push(rejected).is_ok());
        // a closed queue rejects regardless of occupancy
        q.close();
        let (j4, _r4) = job(4, "m", "fp32");
        assert!(q.try_push(j4).is_err());
    }

    #[test]
    fn drain_matching_preserves_fifo_and_leaves_other_keys() {
        let q = AdmissionQueue::new(16);
        let mut rxs = Vec::new();
        for (id, quant) in [(1, "a"), (2, "b"), (3, "a"), (4, "a"), (5, "b")] {
            let (j, r) = job(id, "m", quant);
            rxs.push(r);
            q.try_push(j).unwrap();
        }
        let key = BatchKey { model: "m".into(), quant: "a".into() };
        let got = q.drain_matching(&key, 2);
        assert_eq!(got.iter().map(|j| j.req.id).collect::<Vec<_>>(), vec![1, 3]);
        // remaining: 2(b), 4(a), 5(b) in order
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop_front_blocking().unwrap().req.id, 2);
        assert_eq!(q.pop_front_blocking().unwrap().req.id, 4);
        assert_eq!(q.pop_front_blocking().unwrap().req.id, 5);
    }

    #[test]
    fn close_wakes_blocked_pop() {
        let q = AdmissionQueue::new(4);
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop_front_blocking().is_none());
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap(), "pop on a closed empty queue returns None");
    }

    #[test]
    fn expiry_is_relative_to_admission() {
        let (tx, _rx) = mpsc::channel();
        let mut req = Request::new(1, "m", "fp32", 0);
        req.deadline_ms = Some(5);
        let j = Job::new(req, tx);
        assert!(!j.expired(j.enqueued));
        assert!(j.expired(j.enqueued + Duration::from_millis(6)));
        let (tx2, _rx2) = mpsc::channel();
        let j2 = Job::new(Request::new(2, "m", "fp32", 0), tx2);
        assert!(!j2.expired(j2.enqueued + Duration::from_secs(3600)), "no deadline");
    }
}
