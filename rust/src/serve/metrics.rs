//! The serve-side observability plane: a static registry of lock-free
//! counters and latency histograms, per-shard and aggregated.
//!
//! Everything the serving stack measures lands here: queue admissions
//! and rejections, deadline sheds, dispatched batches and their
//! occupancy, work stealing and hot-key replication, session-cache
//! traffic, prepared-state builds (and what they cost), the int-vs-QDQ
//! per-site compute dispatch split from `model/net.rs`, and the
//! per-request trace spans (enqueue → admit → batch-assemble → forward
//! → serialize) stamped on each [`super::queue::Job`].
//!
//! **Recording contract:** every record function is relaxed-atomic only
//! and performs **zero allocations** — the request hot path keeps its
//! 0-steady-state-allocation guarantee with metrics always on
//! (`tests/proto_alloc.rs` audits the wire path with recording calls
//! included, and the `metrics_overhead` cell of `bench_serve` measures
//! the cost per request). There is no lock anywhere in the registry;
//! consistency across counters is best-effort by design, which is why
//! snapshots are for operators and tests quiesce traffic before
//! asserting exact values.
//!
//! **Reading:** [`snapshot`] materializes the registry into a
//! [`Snapshot`]; its JSON form (sorted keys, one line) is what the
//! `stats` wire verb returns and what `repro serve --stats-every N`
//! logs. The top-level key set is the compiled metric-name manifest
//! ([`NAMES`]) — `tests/protocol_doc.rs` machine-checks the table in
//! `docs/serving.md` against it, so the docs cannot drift.
//!
//! Aggregates of execution-side counters are *derived* from the
//! per-shard cells at snapshot time, so per-shard breakdowns sum to the
//! aggregate by construction. Queue-level counters (admitted, rejected,
//! expired) have no shard identity and are kept globally.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::model::net::site_dispatch;
use crate::runtime::native;
use crate::util::hist::{Hist, HistSnapshot};

/// Size of the static per-shard cell array; shard indices wrap modulo
/// this, so pools wider than 64 workers fold counters rather than lose
/// them (the aggregate stays exact either way).
pub const MAX_SHARDS: usize = 64;

/// Top-level keys of the snapshot JSON, in emission (= sorted) order —
/// the compiled metric-name manifest the docs table is checked against.
pub const NAMES: &[&str] = &[
    "admitted",
    "batch_size",
    "batches",
    "cache_hits",
    "cache_misses",
    "conns_reaped",
    "drain_begun",
    "drain_flushed",
    "errors",
    "expired",
    "hot_hits",
    "int_dispatch",
    "ok",
    "panics_recovered",
    "prepared_build_us",
    "prepared_builds",
    "qdq_dispatch",
    "queue_wait_us",
    "rejected",
    "requests_quarantined",
    "shards",
    "span_admit_ns",
    "span_assemble_ns",
    "span_forward_ns",
    "span_serialize_ns",
    "steals",
];

/// Keys of each element of the snapshot's `shards` array, in emission
/// (= sorted) order.
pub const SHARD_FIELDS: &[&str] = &[
    "batches",
    "cache_hits",
    "cache_misses",
    "errors",
    "hot_hits",
    "ok",
    "shard",
    "steals",
];

/// Keys of every histogram object in the snapshot, in emission order.
pub const HIST_FIELDS: &[&str] = &["count", "max", "p50", "p95", "p99", "sum"];

// ---- the registry ------------------------------------------------------

/// One shard's execution-side counters.
struct ShardCells {
    batches: AtomicU64,
    ok: AtomicU64,
    errors: AtomicU64,
    steals: AtomicU64,
    hot_hits: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
}

impl ShardCells {
    const fn new() -> ShardCells {
        ShardCells {
            batches: AtomicU64::new(0),
            ok: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            hot_hits: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
        }
    }

    fn reset(&self) {
        self.batches.store(0, Ordering::Relaxed);
        self.ok.store(0, Ordering::Relaxed);
        self.errors.store(0, Ordering::Relaxed);
        self.steals.store(0, Ordering::Relaxed);
        self.hot_hits.store(0, Ordering::Relaxed);
        self.cache_hits.store(0, Ordering::Relaxed);
        self.cache_misses.store(0, Ordering::Relaxed);
    }
}

#[allow(clippy::declare_interior_mutable_const)]
const SHARD_ZERO: ShardCells = ShardCells::new();
static SHARDS: [ShardCells; MAX_SHARDS] = [SHARD_ZERO; MAX_SHARDS];

// Queue-level counters (no shard identity at the admission boundary).
static ADMITTED: AtomicU64 = AtomicU64::new(0);
static REJECTED: AtomicU64 = AtomicU64::new(0);
static EXPIRED: AtomicU64 = AtomicU64::new(0);

// Failure-domain counters: supervision, quarantine, connection reaping
// and drain accounting. Global like the queue counters — a panic is
// attributed to the request, not pinned to a shard cell, because the
// recovering worker may not be the one that crashed.
static PANICS_RECOVERED: AtomicU64 = AtomicU64::new(0);
static QUARANTINED: AtomicU64 = AtomicU64::new(0);
static CONNS_REAPED: AtomicU64 = AtomicU64::new(0);
static DRAIN_BEGUN: AtomicU64 = AtomicU64::new(0);
static DRAIN_FLUSHED: AtomicU64 = AtomicU64::new(0);

// Baselines subtracted from process-global counters owned elsewhere, so
// [`reset`] can zero the registry's view without disturbing them.
static PREPARED_BASE: AtomicU64 = AtomicU64::new(0);
static PREPARED_NS_BASE: AtomicU64 = AtomicU64::new(0);
static INT_BASE: AtomicU64 = AtomicU64::new(0);
static QDQ_BASE: AtomicU64 = AtomicU64::new(0);

static QUEUE_WAIT_US: Hist = Hist::new();
static BATCH_SIZE: Hist = Hist::new();
static SPAN_ADMIT_NS: Hist = Hist::new();
static SPAN_ASSEMBLE_NS: Hist = Hist::new();
static SPAN_FORWARD_NS: Hist = Hist::new();
static SPAN_SERIALIZE_NS: Hist = Hist::new();

#[inline]
fn on() -> bool {
    // `--features no-metrics` compiles every record call to a no-op:
    // the baseline build of the bench_serve `metrics_overhead` cell.
    cfg!(not(feature = "no-metrics"))
}

#[inline]
fn cells(shard: usize) -> &'static ShardCells {
    &SHARDS[shard % MAX_SHARDS]
}

// ---- record functions (relaxed atomics, zero allocation) ---------------

/// A job was admitted into the queue.
#[inline]
pub fn admitted() {
    if on() {
        ADMITTED.fetch_add(1, Ordering::Relaxed);
    }
}

/// A job was rejected at admission (queue full or closed).
#[inline]
pub fn rejected() {
    if on() {
        REJECTED.fetch_add(1, Ordering::Relaxed);
    }
}

/// A job was shed with a deadline error before dispatch.
#[inline]
pub fn expired() {
    if on() {
        EXPIRED.fetch_add(1, Ordering::Relaxed);
    }
}

/// A micro-batch of `size` jobs was dispatched by `shard`.
#[inline]
pub fn batch_dispatched(shard: usize, size: usize) {
    if on() {
        cells(shard).batches.fetch_add(1, Ordering::Relaxed);
        BATCH_SIZE.record(size as u64);
    }
}

/// A job was answered ok by `shard`.
#[inline]
pub fn request_ok(shard: usize) {
    if on() {
        cells(shard).ok.fetch_add(1, Ordering::Relaxed);
    }
}

/// A job was answered with an error by `shard` (post-admission).
#[inline]
pub fn request_error(shard: usize) {
    if on() {
        cells(shard).errors.fetch_add(1, Ordering::Relaxed);
    }
}

/// `shard` served a batch anchored on a stolen (foreign-home) key.
#[inline]
pub fn stolen(shard: usize) {
    if on() {
        cells(shard).steals.fetch_add(1, Ordering::Relaxed);
    }
}

/// `shard` served a batch under hot-key replication.
#[inline]
pub fn hot_hit(shard: usize) {
    if on() {
        cells(shard).hot_hits.fetch_add(1, Ordering::Relaxed);
    }
}

/// `shard`'s session cache answered a lookup from a prepared session.
#[inline]
pub fn cache_hit(shard: usize) {
    if on() {
        cells(shard).cache_hits.fetch_add(1, Ordering::Relaxed);
    }
}

/// `shard`'s session cache had to open (prepare) a session.
#[inline]
pub fn cache_miss(shard: usize) {
    if on() {
        cells(shard).cache_misses.fetch_add(1, Ordering::Relaxed);
    }
}

/// Record a job's enqueue→assembly wait (the `queue_wait_us` histogram).
#[inline]
pub fn queue_wait(us: u64) {
    if on() {
        QUEUE_WAIT_US.record(us);
    }
}

/// A worker panic was caught by supervision and the worker recovered
/// (rebuilt its simulator and kept serving).
#[inline]
pub fn panic_recovered() {
    if on() {
        PANICS_RECOVERED.fetch_add(1, Ordering::Relaxed);
    }
}

/// A request was identified as the panic trigger and quarantined
/// (answered `internal_error`, never retried server-side).
#[inline]
pub fn quarantined() {
    if on() {
        QUARANTINED.fetch_add(1, Ordering::Relaxed);
    }
}

/// An idle TCP connection hit `--idle-timeout` and was reaped.
#[inline]
pub fn conn_reaped() {
    if on() {
        CONNS_REAPED.fetch_add(1, Ordering::Relaxed);
    }
}

/// The admission queue entered its draining state (once per drain).
#[inline]
pub fn drain_begun() {
    if on() {
        DRAIN_BEGUN.fetch_add(1, Ordering::Relaxed);
    }
}

/// `n` queued jobs were flushed unserved at drain-timeout expiry (each
/// answered `shutting_down`, so none goes unanswered).
#[inline]
pub fn drain_flushed(n: u64) {
    if on() {
        DRAIN_FLUSHED.fetch_add(n, Ordering::Relaxed);
    }
}

// ---- trace spans -------------------------------------------------------

/// The per-request span intervals (enqueue → admit → batch-assemble →
/// forward → serialize); each has its own latency histogram.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanSlot {
    /// enqueue → queue admission (parse + push overhead).
    Admit,
    /// admission → micro-batch assembly (time spent queued).
    Assemble,
    /// the batched forward itself.
    Forward,
    /// response serialization on the writer thread.
    Serialize,
}

/// Record `ns` into `slot`'s span histogram.
#[inline]
pub fn record_span(slot: SpanSlot, ns: u64) {
    if on() {
        match slot {
            SpanSlot::Admit => SPAN_ADMIT_NS.record(ns),
            SpanSlot::Assemble => SPAN_ASSEMBLE_NS.record(ns),
            SpanSlot::Forward => SPAN_FORWARD_NS.record(ns),
            SpanSlot::Serialize => SPAN_SERIALIZE_NS.record(ns),
        }
    }
}

thread_local! {
    static TRACE: Cell<Option<SpanSlot>> = const { Cell::new(None) };
}

/// The span slot an enclosing [`trace`] made active on this thread, if
/// any — `util::timer::Scope` consults this on drop to emit into the
/// span plumbing instead of the debug log.
pub fn active_trace() -> Option<SpanSlot> {
    TRACE.with(|t| t.get())
}

/// RAII guard of [`trace`]; restores the previous slot on drop.
pub struct TraceGuard {
    prev: Option<SpanSlot>,
}

impl Drop for TraceGuard {
    fn drop(&mut self) {
        TRACE.with(|t| t.set(self.prev));
    }
}

/// Make `slot` the active trace context on this thread until the guard
/// drops: timer scopes created inside record their elapsed time into
/// the slot's span histogram.
pub fn trace(slot: SpanSlot) -> TraceGuard {
    let prev = TRACE.with(|t| t.replace(Some(slot)));
    TraceGuard { prev }
}

// ---- reset / snapshot --------------------------------------------------

/// Zero the registry (tests and loadgen run boundaries). Process-global
/// counters owned elsewhere (prepared builds, site dispatch) are
/// re-baselined rather than reset, so other subsystems are undisturbed.
pub fn reset() {
    for cell in &SHARDS {
        cell.reset();
    }
    ADMITTED.store(0, Ordering::Relaxed);
    REJECTED.store(0, Ordering::Relaxed);
    EXPIRED.store(0, Ordering::Relaxed);
    PANICS_RECOVERED.store(0, Ordering::Relaxed);
    QUARANTINED.store(0, Ordering::Relaxed);
    CONNS_REAPED.store(0, Ordering::Relaxed);
    DRAIN_BEGUN.store(0, Ordering::Relaxed);
    DRAIN_FLUSHED.store(0, Ordering::Relaxed);
    QUEUE_WAIT_US.reset();
    BATCH_SIZE.reset();
    SPAN_ADMIT_NS.reset();
    SPAN_ASSEMBLE_NS.reset();
    SPAN_FORWARD_NS.reset();
    SPAN_SERIALIZE_NS.reset();
    PREPARED_BASE.store(native::prepared_builds() as u64, Ordering::Relaxed);
    PREPARED_NS_BASE.store(native::prepared_build_ns(), Ordering::Relaxed);
    let (int, qdq) = site_dispatch::counts();
    INT_BASE.store(int, Ordering::Relaxed);
    QDQ_BASE.store(qdq, Ordering::Relaxed);
}

/// One shard's counters at snapshot time.
#[derive(Debug, Clone, Default)]
pub struct ShardSnapshot {
    /// Shard index (cell index — indices wrap at [`MAX_SHARDS`]).
    pub shard: usize,
    /// Micro-batches this shard dispatched.
    pub batches: u64,
    /// Jobs this shard answered ok.
    pub ok: u64,
    /// Jobs this shard answered with an error.
    pub errors: u64,
    /// Batches served on stolen keys.
    pub steals: u64,
    /// Batches served under hot-key replication.
    pub hot_hits: u64,
    /// Session-cache hits.
    pub cache_hits: u64,
    /// Session-cache misses (sessions prepared).
    pub cache_misses: u64,
}

impl ShardSnapshot {
    fn any(&self) -> bool {
        self.batches
            + self.ok
            + self.errors
            + self.steals
            + self.hot_hits
            + self.cache_hits
            + self.cache_misses
            > 0
    }
}

/// A point-in-time copy of the whole registry (see [`snapshot`]).
#[derive(Debug, Clone)]
pub struct Snapshot {
    /// Jobs admitted into the queue.
    pub admitted: u64,
    /// Jobs rejected at admission (queue full/closed).
    pub rejected: u64,
    /// Jobs shed with a deadline error before dispatch.
    pub expired: u64,
    /// Worker panics caught and recovered by supervision.
    pub panics_recovered: u64,
    /// Requests quarantined as panic triggers (answered
    /// `internal_error`).
    pub requests_quarantined: u64,
    /// Idle TCP connections reaped by `--idle-timeout`.
    pub conns_reaped: u64,
    /// Times the queue entered its draining state.
    pub drain_begun: u64,
    /// Queued jobs flushed unserved at drain-timeout expiry.
    pub drain_flushed: u64,
    /// Jobs answered ok (sum over shards).
    pub ok: u64,
    /// Jobs answered with an error post-admission (sum over shards).
    pub errors: u64,
    /// Micro-batches dispatched (sum over shards).
    pub batches: u64,
    /// Batches served on stolen keys (sum over shards).
    pub steals: u64,
    /// Batches served under hot-key replication (sum over shards).
    pub hot_hits: u64,
    /// Session-cache hits (sum over shards).
    pub cache_hits: u64,
    /// Session-cache misses (sum over shards).
    pub cache_misses: u64,
    /// Prepared-state builds since the last [`reset`].
    pub prepared_builds: u64,
    /// Microseconds spent in prepared-state builds since last [`reset`].
    pub prepared_build_us: u64,
    /// qlinear sites dispatched to the true int8 GEMM.
    pub int_dispatch: u64,
    /// qlinear sites dispatched to the simulated QDQ path.
    pub qdq_dispatch: u64,
    /// Enqueue→assembly wait per job, microseconds.
    pub queue_wait_us: HistSnapshot,
    /// Dispatched micro-batch occupancy.
    pub batch_size: HistSnapshot,
    /// Enqueue→admission span per job, nanoseconds.
    pub span_admit_ns: HistSnapshot,
    /// Admission→assembly span per job, nanoseconds.
    pub span_assemble_ns: HistSnapshot,
    /// Batched-forward span per batch, nanoseconds.
    pub span_forward_ns: HistSnapshot,
    /// Serialization span per response, nanoseconds.
    pub span_serialize_ns: HistSnapshot,
    /// Per-shard breakdowns (active shards only; they sum to the
    /// aggregates above by construction).
    pub shards: Vec<ShardSnapshot>,
}

/// Materialize the registry. Aggregates of execution-side counters are
/// computed as the sum of the per-shard cells read here, so the
/// `shards` breakdown always sums to the aggregate.
pub fn snapshot() -> Snapshot {
    let mut shards = Vec::new();
    let (mut ok, mut errors, mut batches) = (0u64, 0u64, 0u64);
    let (mut steals, mut hot_hits) = (0u64, 0u64);
    let (mut cache_hits, mut cache_misses) = (0u64, 0u64);
    for (i, cell) in SHARDS.iter().enumerate() {
        let s = ShardSnapshot {
            shard: i,
            batches: cell.batches.load(Ordering::Relaxed),
            ok: cell.ok.load(Ordering::Relaxed),
            errors: cell.errors.load(Ordering::Relaxed),
            steals: cell.steals.load(Ordering::Relaxed),
            hot_hits: cell.hot_hits.load(Ordering::Relaxed),
            cache_hits: cell.cache_hits.load(Ordering::Relaxed),
            cache_misses: cell.cache_misses.load(Ordering::Relaxed),
        };
        ok += s.ok;
        errors += s.errors;
        batches += s.batches;
        steals += s.steals;
        hot_hits += s.hot_hits;
        cache_hits += s.cache_hits;
        cache_misses += s.cache_misses;
        if s.any() {
            shards.push(s);
        }
    }
    let (int, qdq) = site_dispatch::counts();
    Snapshot {
        admitted: ADMITTED.load(Ordering::Relaxed),
        rejected: REJECTED.load(Ordering::Relaxed),
        expired: EXPIRED.load(Ordering::Relaxed),
        panics_recovered: PANICS_RECOVERED.load(Ordering::Relaxed),
        requests_quarantined: QUARANTINED.load(Ordering::Relaxed),
        conns_reaped: CONNS_REAPED.load(Ordering::Relaxed),
        drain_begun: DRAIN_BEGUN.load(Ordering::Relaxed),
        drain_flushed: DRAIN_FLUSHED.load(Ordering::Relaxed),
        ok,
        errors,
        batches,
        steals,
        hot_hits,
        cache_hits,
        cache_misses,
        prepared_builds: (native::prepared_builds() as u64)
            .saturating_sub(PREPARED_BASE.load(Ordering::Relaxed)),
        prepared_build_us: native::prepared_build_ns()
            .saturating_sub(PREPARED_NS_BASE.load(Ordering::Relaxed))
            / 1_000,
        int_dispatch: int.saturating_sub(INT_BASE.load(Ordering::Relaxed)),
        qdq_dispatch: qdq.saturating_sub(QDQ_BASE.load(Ordering::Relaxed)),
        queue_wait_us: QUEUE_WAIT_US.snapshot(),
        batch_size: BATCH_SIZE.snapshot(),
        span_admit_ns: SPAN_ADMIT_NS.snapshot(),
        span_assemble_ns: SPAN_ASSEMBLE_NS.snapshot(),
        span_forward_ns: SPAN_FORWARD_NS.snapshot(),
        span_serialize_ns: SPAN_SERIALIZE_NS.snapshot(),
        shards,
    }
}

fn push_hist(out: &mut String, key: &str, h: &HistSnapshot) {
    out.push('"');
    out.push_str(key);
    out.push_str("\":{\"count\":");
    out.push_str(&h.count.to_string());
    out.push_str(",\"max\":");
    out.push_str(&h.max.to_string());
    out.push_str(",\"p50\":");
    out.push_str(&h.percentile(0.50).to_string());
    out.push_str(",\"p95\":");
    out.push_str(&h.percentile(0.95).to_string());
    out.push_str(",\"p99\":");
    out.push_str(&h.percentile(0.99).to_string());
    out.push_str(",\"sum\":");
    out.push_str(&h.sum.to_string());
    out.push('}');
}

fn push_kv(out: &mut String, key: &str, v: u64) {
    out.push('"');
    out.push_str(key);
    out.push_str("\":");
    out.push_str(&v.to_string());
}

impl Snapshot {
    /// The snapshot as one compact JSON object with keys in [`NAMES`]
    /// order (sorted — the same convention as the wire serializers).
    /// This is the exact line the `stats` verb returns.
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(1024);
        s.push('{');
        push_kv(&mut s, "admitted", self.admitted);
        s.push(',');
        push_hist(&mut s, "batch_size", &self.batch_size);
        s.push(',');
        push_kv(&mut s, "batches", self.batches);
        s.push(',');
        push_kv(&mut s, "cache_hits", self.cache_hits);
        s.push(',');
        push_kv(&mut s, "cache_misses", self.cache_misses);
        s.push(',');
        push_kv(&mut s, "conns_reaped", self.conns_reaped);
        s.push(',');
        push_kv(&mut s, "drain_begun", self.drain_begun);
        s.push(',');
        push_kv(&mut s, "drain_flushed", self.drain_flushed);
        s.push(',');
        push_kv(&mut s, "errors", self.errors);
        s.push(',');
        push_kv(&mut s, "expired", self.expired);
        s.push(',');
        push_kv(&mut s, "hot_hits", self.hot_hits);
        s.push(',');
        push_kv(&mut s, "int_dispatch", self.int_dispatch);
        s.push(',');
        push_kv(&mut s, "ok", self.ok);
        s.push(',');
        push_kv(&mut s, "panics_recovered", self.panics_recovered);
        s.push(',');
        push_kv(&mut s, "prepared_build_us", self.prepared_build_us);
        s.push(',');
        push_kv(&mut s, "prepared_builds", self.prepared_builds);
        s.push(',');
        push_kv(&mut s, "qdq_dispatch", self.qdq_dispatch);
        s.push(',');
        push_hist(&mut s, "queue_wait_us", &self.queue_wait_us);
        s.push(',');
        push_kv(&mut s, "rejected", self.rejected);
        s.push(',');
        push_kv(&mut s, "requests_quarantined", self.requests_quarantined);
        s.push_str(",\"shards\":[");
        for (i, sh) in self.shards.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push('{');
            push_kv(&mut s, "batches", sh.batches);
            s.push(',');
            push_kv(&mut s, "cache_hits", sh.cache_hits);
            s.push(',');
            push_kv(&mut s, "cache_misses", sh.cache_misses);
            s.push(',');
            push_kv(&mut s, "errors", sh.errors);
            s.push(',');
            push_kv(&mut s, "hot_hits", sh.hot_hits);
            s.push(',');
            push_kv(&mut s, "ok", sh.ok);
            s.push(',');
            push_kv(&mut s, "shard", sh.shard as u64);
            s.push(',');
            push_kv(&mut s, "steals", sh.steals);
            s.push('}');
        }
        s.push(']');
        s.push(',');
        push_hist(&mut s, "span_admit_ns", &self.span_admit_ns);
        s.push(',');
        push_hist(&mut s, "span_assemble_ns", &self.span_assemble_ns);
        s.push(',');
        push_hist(&mut s, "span_forward_ns", &self.span_forward_ns);
        s.push(',');
        push_hist(&mut s, "span_serialize_ns", &self.span_serialize_ns);
        s.push(',');
        push_kv(&mut s, "steals", self.steals);
        s.push('}');
        s
    }

    /// A one-line human rendering for `--stats-every` stderr snapshots.
    pub fn render_compact(&self) -> String {
        format!(
            "stats: admitted {} ok {} err {} shed {} rej {} | {} batches \
             (p50 size {}, queue p95 {}us, forward p95 {}us) | cache {}/{} \
             | int/qdq {}/{} | stolen {} hot {}",
            self.admitted,
            self.ok,
            self.errors,
            self.expired,
            self.rejected,
            self.batches,
            self.batch_size.percentile(0.50),
            self.queue_wait_us.percentile(0.95),
            self.span_forward_ns.percentile(0.95) / 1_000,
            self.cache_hits,
            self.cache_misses,
            self.int_dispatch,
            self.qdq_dispatch,
            self.steals,
            self.hot_hits
        )
    }

    /// Cross-counter sanity: invariants no healthy server can violate
    /// (the CI smoke cells fail on these). Quiesce traffic first — the
    /// registry is relaxed-atomic, so mid-flight reads can transiently
    /// disagree across counters.
    pub fn check(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.ok + self.errors + self.expired <= self.admitted,
            "impossible stats: ok {} + errors {} + expired {} > admitted {}",
            self.ok,
            self.errors,
            self.expired,
            self.admitted
        );
        anyhow::ensure!(
            self.cache_misses <= self.prepared_builds,
            "impossible stats: cache_misses {} > prepared_builds {}",
            self.cache_misses,
            self.prepared_builds
        );
        anyhow::ensure!(
            self.steals + self.hot_hits <= self.batches,
            "impossible stats: steals {} + hot_hits {} > batches {}",
            self.steals,
            self.hot_hits,
            self.batches
        );
        anyhow::ensure!(
            self.requests_quarantined <= self.admitted,
            "impossible stats: requests_quarantined {} > admitted {}",
            self.requests_quarantined,
            self.admitted
        );
        anyhow::ensure!(
            self.requests_quarantined <= self.panics_recovered,
            "impossible stats: requests_quarantined {} > panics_recovered {} \
             (every quarantine is a recovered panic)",
            self.requests_quarantined,
            self.panics_recovered
        );
        anyhow::ensure!(
            self.drain_flushed <= self.admitted,
            "impossible stats: drain_flushed {} > admitted {}",
            self.drain_flushed,
            self.admitted
        );
        anyhow::ensure!(
            self.drain_flushed == 0 || self.drain_begun > 0,
            "impossible stats: drain_flushed {} with drain_begun 0",
            self.drain_flushed
        );
        let sums: [u64; 7] = self.shards.iter().fold([0; 7], |mut acc, s| {
            for (a, v) in acc.iter_mut().zip([
                s.batches,
                s.ok,
                s.errors,
                s.steals,
                s.hot_hits,
                s.cache_hits,
                s.cache_misses,
            ]) {
                *a += v;
            }
            acc
        });
        let agg = [
            self.batches,
            self.ok,
            self.errors,
            self.steals,
            self.hot_hits,
            self.cache_hits,
            self.cache_misses,
        ];
        anyhow::ensure!(
            sums == agg,
            "impossible stats: per-shard sums {:?} != aggregates {:?}",
            sums,
            agg
        );
        Ok(())
    }
}

/// Serialize a fresh snapshot into `buf` (cleared first, no trailing
/// newline) — the writer-thread half of the `stats` wire verb.
pub fn write_snapshot(buf: &mut Vec<u8>) {
    buf.clear();
    buf.extend_from_slice(snapshot().to_json().as_bytes());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    // Lib tests run concurrently and several suites drive the queue or
    // qlinear (bumping global counters), so these tests only assert (a)
    // structural properties of the snapshot and (b) deltas on a shard
    // cell (63) no other test touches.
    const TEST_SHARD: usize = MAX_SHARDS - 1;

    fn shard_cell(snap: &Snapshot, shard: usize) -> ShardSnapshot {
        snap.shards
            .iter()
            .find(|s| s.shard == shard)
            .cloned()
            .unwrap_or(ShardSnapshot { shard, ..Default::default() })
    }

    #[test]
    fn snapshot_json_keys_match_the_compiled_manifest() {
        let snap = snapshot();
        let parsed = Json::parse(&snap.to_json()).expect("snapshot is valid JSON");
        let obj = parsed.as_obj().expect("snapshot is an object");
        let keys: Vec<&str> = obj.keys().map(|k| k.as_str()).collect();
        assert_eq!(keys, NAMES, "snapshot keys == NAMES (both sorted)");
        // histogram objects carry exactly HIST_FIELDS
        for key in ["batch_size", "queue_wait_us", "span_forward_ns"] {
            let h = obj[key].as_obj().expect("histogram object");
            let hkeys: Vec<&str> = h.keys().map(|k| k.as_str()).collect();
            assert_eq!(hkeys, HIST_FIELDS, "{} fields", key);
        }
    }

    #[test]
    fn shard_entries_carry_exactly_the_shard_fields() {
        request_ok(TEST_SHARD); // ensure at least one active shard
        let parsed = Json::parse(&snapshot().to_json()).unwrap();
        let shards = parsed.get("shards").and_then(|s| s.as_arr()).unwrap();
        assert!(!shards.is_empty());
        for sh in shards {
            let obj = sh.as_obj().expect("shard object");
            let keys: Vec<&str> = obj.keys().map(|k| k.as_str()).collect();
            assert_eq!(keys, SHARD_FIELDS);
        }
    }

    #[test]
    fn per_shard_cells_record_deltas_and_sum_into_aggregates() {
        let before = snapshot();
        let b = shard_cell(&before, TEST_SHARD);
        batch_dispatched(TEST_SHARD, 3);
        request_ok(TEST_SHARD);
        request_ok(TEST_SHARD);
        request_error(TEST_SHARD);
        stolen(TEST_SHARD);
        hot_hit(TEST_SHARD);
        cache_hit(TEST_SHARD);
        cache_miss(TEST_SHARD);
        let after = snapshot();
        let a = shard_cell(&after, TEST_SHARD);
        assert_eq!(a.batches - b.batches, 1);
        assert_eq!(a.ok - b.ok, 2);
        assert_eq!(a.errors - b.errors, 1);
        assert_eq!(a.steals - b.steals, 1);
        assert_eq!(a.hot_hits - b.hot_hits, 1);
        assert_eq!(a.cache_hits - b.cache_hits, 1);
        assert_eq!(a.cache_misses - b.cache_misses, 1);
        // aggregates are derived from the same cells, so they moved by
        // at least as much (concurrent suites may add more)
        assert!(after.ok >= before.ok + 2);
        assert!(after.batches >= before.batches + 1);
        // and the shard breakdown sums to the aggregate by construction
        let sum_ok: u64 = after.shards.iter().map(|s| s.ok).sum();
        assert_eq!(sum_ok, after.ok);
    }

    #[test]
    fn queue_counters_and_hists_move_forward() {
        let before = snapshot();
        admitted();
        rejected();
        expired();
        queue_wait(250);
        record_span(SpanSlot::Serialize, 1_500);
        let after = snapshot();
        assert!(after.admitted >= before.admitted + 1);
        assert!(after.rejected >= before.rejected + 1);
        assert!(after.expired >= before.expired + 1);
        assert!(after.queue_wait_us.count >= before.queue_wait_us.count + 1);
        assert!(after.span_serialize_ns.count >= before.span_serialize_ns.count + 1);
    }

    #[test]
    fn failure_domain_counters_move_forward() {
        let before = snapshot();
        panic_recovered();
        quarantined();
        conn_reaped();
        drain_begun();
        drain_flushed(3);
        let after = snapshot();
        assert!(after.panics_recovered >= before.panics_recovered + 1);
        assert!(after.requests_quarantined >= before.requests_quarantined + 1);
        assert!(after.conns_reaped >= before.conns_reaped + 1);
        assert!(after.drain_begun >= before.drain_begun + 1);
        assert!(after.drain_flushed >= before.drain_flushed + 3);
    }

    #[test]
    fn trace_context_nests_and_restores() {
        assert_eq!(active_trace(), None);
        {
            let _outer = trace(SpanSlot::Forward);
            assert_eq!(active_trace(), Some(SpanSlot::Forward));
            {
                let _inner = trace(SpanSlot::Serialize);
                assert_eq!(active_trace(), Some(SpanSlot::Serialize));
            }
            assert_eq!(active_trace(), Some(SpanSlot::Forward));
        }
        assert_eq!(active_trace(), None);
    }

    #[test]
    fn check_accepts_consistent_and_rejects_impossible_snapshots() {
        let mut snap = snapshot();
        // a quiesced snapshot built from the registry passes
        snap.shards.clear();
        snap.ok = 0;
        snap.errors = 0;
        snap.batches = 0;
        snap.steals = 0;
        snap.hot_hits = 0;
        snap.cache_hits = 0;
        snap.cache_misses = 0;
        snap.expired = 0;
        snap.admitted = 5;
        snap.prepared_builds = 0;
        snap.panics_recovered = 0;
        snap.requests_quarantined = 0;
        snap.conns_reaped = 0;
        snap.drain_begun = 0;
        snap.drain_flushed = 0;
        snap.check().expect("consistent snapshot passes");
        snap.requests_quarantined = 1;
        assert!(snap.check().is_err(), "quarantine without a recovered panic");
        snap.panics_recovered = 1;
        snap.check().expect("one quarantine per recovered panic is fine");
        snap.drain_flushed = 2;
        assert!(snap.check().is_err(), "flushed jobs without a drain");
        snap.drain_begun = 1;
        snap.check().expect("flush during a drain is fine");
        snap.drain_flushed = 0;
        snap.drain_begun = 0;
        snap.panics_recovered = 0;
        snap.requests_quarantined = 0;
        snap.ok = 9; // > admitted, and not matched by shard sums
        assert!(snap.check().is_err(), "completed > admitted is impossible");
    }
}
