//! TCP socket transport: the front end the line-delimited JSON protocol
//! was designed for (`repro serve --listen ADDR`).
//!
//! One listener thread accepts connections; each connection gets a
//! reader thread (parsing request lines into the shared admission
//! queue) and a writer thread (serializing that connection's responses
//! back). All connections multiplex into ONE admission queue served by
//! the shard pool — backpressure is global, so a single chatty client
//! cannot queue unboundedly ahead of others — and every job carries its
//! connection's response channel, so responses route back to whoever
//! asked, in completion order.
//!
//! Protocol framing and error codes are exactly those of
//! [`super::protocol`] (one JSON object per `\n`-terminated line in
//! each direction); `docs/serving.md` has the operator guide and a
//! worked `nc`/python client example.
//!
//! The wire path is hardened and allocation-free in steady state: each
//! connection reads through [`read_line_capped`] into a reused buffer
//! (a line longer than [`protocol::MAX_LINE_BYTES`] is discarded as it
//! streams in — bounded memory — answered with `bad_request`, and the
//! connection keeps working), parses with the non-recursive
//! [`protocol::parse_request_streaming`] into a reused scratch
//! `Request`, and serializes responses with
//! [`protocol::Response::write_line`] into a reused write buffer.
//!
//! The connection lifecycle is supervised (failure-domain isolation
//! for the serving plane):
//!
//! * **`--idle-timeout`** — a connection that sends no complete line
//!   within the window is reaped: counted by the `conns_reaped`
//!   metric, socket closed, every other connection unaffected.
//! * **`--max-conns`** — excess connections beyond the cap are
//!   answered with a single `queue_full` retry-later line and closed
//!   before they can occupy a pump thread.
//! * **graceful drain** — a `{"verb":"shutdown"}` line flips the
//!   admission queue to draining: new work (from every connection) is
//!   rejected with `shutting_down`, already-admitted jobs finish under
//!   `--drain-timeout` (leftovers are answered with `shutting_down`),
//!   then the listener stops and [`TcpServer::wait`] returns cleanly.
//! * **dead connections** — responses owed to a connection whose
//!   socket died are dropped without stalling the dispatcher (the
//!   writer exits, the response channel closes, and workers' sends
//!   into it are ignored).
//!
//! Shutdown ([`TcpServer::shutdown`]) is abortive for still-connected
//! clients: the listener stops, open sockets are shut down, admitted
//! jobs finish draining, and per-worker stats are returned. The CLI
//! path ([`run_tcp`]) instead serves until the process is killed or a
//! client initiates the drain handshake above.

use std::io::{self, BufRead, BufReader, BufWriter, Write as IoWrite};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::tensor::backend;

use super::faults;
use super::metrics;
use super::protocol::{self, codes, Request, Response};
use super::queue::{AdmissionQueue, Job};
use super::shard::{run_sharded, ShardCfg, ShardStats, SimSpec};
use super::ServeCfg;

/// A running TCP server: listener + per-connection pumps + shard pool.
pub struct TcpServer {
    local: SocketAddr,
    queue: Arc<AdmissionQueue>,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    conn_handles: Arc<Mutex<Vec<JoinHandle<()>>>>,
    accept: JoinHandle<()>,
    workers: JoinHandle<Result<Vec<ShardStats>>>,
}

impl TcpServer {
    /// Bind `addr` (e.g. `127.0.0.1:7411`, port 0 for ephemeral), spawn
    /// the accept loop and the shard pool, and return immediately.
    /// `prewarm` keys are opened by their home shards before traffic.
    pub fn start(
        spec: SimSpec,
        addr: &str,
        serve_cfg: ServeCfg,
        shard_cfg: ShardCfg,
        prewarm: Vec<(String, String)>,
    ) -> Result<TcpServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {}", addr))?;
        let local = listener.local_addr().context("local_addr")?;
        let queue = AdmissionQueue::new(serve_cfg.queue_cap);
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let conn_handles: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let live = Arc::new(AtomicUsize::new(0));
        let ctl = Arc::new(DrainCtl {
            queue: Arc::clone(&queue),
            timeout: serve_cfg.drain_timeout,
            stop: Arc::clone(&stop),
            local,
            started: AtomicBool::new(false),
        });
        let idle_timeout = serve_cfg.idle_timeout;
        let max_conns = serve_cfg.max_conns;

        let accept = {
            let queue = Arc::clone(&queue);
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            let conn_handles = Arc::clone(&conn_handles);
            let live = Arc::clone(&live);
            let ctl = Arc::clone(&ctl);
            std::thread::Builder::new()
                .name("tcp-accept".to_string())
                .spawn(move || {
                    for incoming in listener.incoming() {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        let stream = match incoming {
                            Ok(s) => s,
                            Err(_) => continue,
                        };
                        if let Some(cap) = max_conns {
                            if live.load(Ordering::SeqCst) >= cap {
                                refuse_conn(stream, cap);
                                continue;
                            }
                        }
                        if let Ok(clone) = stream.try_clone() {
                            conns.lock().unwrap().push(clone);
                        }
                        live.fetch_add(1, Ordering::SeqCst);
                        let h = handle_conn(
                            stream,
                            Arc::clone(&queue),
                            idle_timeout,
                            Arc::clone(&ctl),
                            Arc::clone(&live),
                        );
                        conn_handles.lock().unwrap().push(h);
                    }
                })
                .expect("spawn tcp accept thread")
        };

        let workers = {
            let queue = Arc::clone(&queue);
            std::thread::Builder::new()
                .name("shard-pool".to_string())
                .spawn(move || {
                    run_sharded(&spec, &queue, &serve_cfg, &shard_cfg, &prewarm)
                })
                .expect("spawn shard pool supervisor")
        };

        Ok(TcpServer { local, queue, stop, conns, conn_handles, accept, workers })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Stop accepting, shut open connections down, drain admitted jobs,
    /// and return per-worker stats.
    pub fn shutdown(self) -> Result<Vec<ShardStats>> {
        self.stop.store(true, Ordering::SeqCst);
        // unblock the accept loop: it re-checks `stop` per connection
        let _ = TcpStream::connect(self.local);
        let _ = self.accept.join();
        // connection readers exit on socket shutdown; their writers
        // drain whatever responses are already owed to that connection
        for s in self.conns.lock().unwrap().drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
        let handles: Vec<JoinHandle<()>> =
            self.conn_handles.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        self.queue.close();
        match self.workers.join() {
            Ok(stats) => stats,
            Err(_) => Err(anyhow::anyhow!("shard pool panicked")),
        }
    }

    /// Serve until the accept loop exits — for the CLI: until the
    /// process is killed, or until a client's `shutdown` verb completes
    /// the graceful drain (which stops the accept loop) — then close
    /// remaining connections and stop the workers.
    pub fn wait(self) -> Result<()> {
        let _ = self.accept.join();
        // mirror `shutdown`: close whatever connections remain so
        // their pump threads exit instead of leaking
        for s in self.conns.lock().unwrap().drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
        let handles: Vec<JoinHandle<()>> =
            self.conn_handles.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        self.queue.close();
        match self.workers.join() {
            Ok(stats) => {
                let _ = stats?;
                Ok(())
            }
            Err(_) => Err(anyhow::anyhow!("shard pool panicked")),
        }
    }
}

/// Coordinates a verb-initiated graceful drain for the TCP front: the
/// first `shutdown` verb (from any connection) flips the shared queue
/// to draining and spawns one watcher that — once the drain supervisor
/// finishes (drained, or timed out and flushed) — stops the accept
/// loop so [`TcpServer::wait`] can return cleanly. Later triggers are
/// no-ops beyond the (idempotent) `begin_drain`.
struct DrainCtl {
    queue: Arc<AdmissionQueue>,
    timeout: Duration,
    stop: Arc<AtomicBool>,
    local: SocketAddr,
    started: AtomicBool,
}

impl DrainCtl {
    fn trigger(&self) {
        self.queue.begin_drain();
        if self.started.swap(true, Ordering::SeqCst) {
            return;
        }
        let drain = super::spawn_drain(Arc::clone(&self.queue), self.timeout);
        let stop = Arc::clone(&self.stop);
        let local = self.local;
        std::thread::Builder::new()
            .name("tcp-drain".to_string())
            .spawn(move || {
                let _ = drain.join();
                stop.store(true, Ordering::SeqCst);
                // poke the accept loop so it observes `stop`
                let _ = TcpStream::connect(local);
            })
            .expect("spawn tcp drain watcher");
    }
}

/// Answer a connection refused by the `--max-conns` cap: one
/// `queue_full` retry-later line, then close. The refused client never
/// occupies a pump thread, so the cap bounds thread count as well as
/// socket count.
fn refuse_conn(stream: TcpStream, cap: usize) {
    let mut resp = Response::err(
        protocol::ERR_ID,
        codes::QUEUE_FULL,
        &format!("connection limit reached (--max-conns {}): retry later", cap),
    );
    let mut buf = Vec::with_capacity(160);
    resp.write_line(&mut buf);
    buf.push(b'\n');
    let _ = (&stream).write_all(&buf);
    let _ = stream.shutdown(Shutdown::Both);
}

/// Outcome of one [`read_line_capped`] call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum LineRead {
    /// A complete line (newline stripped) is in the buffer.
    Line,
    /// Clean end of stream with no pending bytes.
    Eof,
    /// The line exceeded the cap; its bytes were discarded as they
    /// streamed in, the stream is positioned after its newline (or at
    /// EOF), and the buffer is empty. The connection stays usable.
    TooLong,
}

/// Read one `\n`-terminated line into the reused `buf` (cleared first,
/// capacity kept), holding at most `max` line bytes in memory. A line
/// of exactly `max` bytes is accepted; anything longer flips into
/// discard mode — the remainder streams through the fixed `BufRead`
/// chunk buffer without accumulating — so an adversarial endless line
/// costs O(max) memory, not O(line).
pub(crate) fn read_line_capped<R: BufRead>(
    r: &mut R,
    buf: &mut Vec<u8>,
    max: usize,
) -> io::Result<LineRead> {
    buf.clear();
    let mut discarding = false;
    loop {
        let (used, found_nl) = {
            let chunk = match r.fill_buf() {
                Ok(c) => c,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            };
            if chunk.is_empty() {
                // EOF
                return Ok(if discarding {
                    LineRead::TooLong
                } else if buf.is_empty() {
                    LineRead::Eof
                } else {
                    // final unterminated line
                    LineRead::Line
                });
            }
            match chunk.iter().position(|&b| b == b'\n') {
                Some(nl) => {
                    if !discarding {
                        buf.extend_from_slice(&chunk[..nl]);
                    }
                    (nl + 1, true)
                }
                None => {
                    if !discarding {
                        buf.extend_from_slice(chunk);
                    }
                    (chunk.len(), false)
                }
            }
        };
        r.consume(used);
        if !discarding && buf.len() > max {
            buf.clear();
            discarding = true;
        }
        if found_nl {
            return Ok(if discarding { LineRead::TooLong } else { LineRead::Line });
        }
    }
}

/// ASCII-whitespace trim of a byte slice (the wire-path replacement for
/// `str::trim` — no UTF-8 requirement, no allocation).
pub(crate) fn trim_ws(mut b: &[u8]) -> &[u8] {
    while let [first, rest @ ..] = b {
        if matches!(first, b' ' | b'\t' | b'\r' | b'\n') {
            b = rest;
        } else {
            break;
        }
    }
    while let [rest @ .., last] = b {
        if matches!(last, b' ' | b'\t' | b'\r' | b'\n') {
            b = rest;
        } else {
            break;
        }
    }
    b
}

/// The `bad_request` answer for a line that blew the length cap.
pub(crate) fn oversized_response() -> Response {
    Response::err(
        protocol::ERR_ID,
        codes::BAD_REQUEST,
        &format!(
            "bad request: line exceeds max_line_bytes ({} bytes)",
            protocol::MAX_LINE_BYTES
        ),
    )
}

/// Per-connection pumps: a reader thread (this handle) parsing lines
/// into the queue, plus a writer thread it owns for the responses.
/// Both directions run on reused buffers (zero steady-state allocation
/// on the parse/serialize path — asserted by `tests/proto_alloc.rs`).
///
/// `idle_timeout` arms a read timeout: a connection that produces no
/// complete line within it is reaped (`conns_reaped` metric, socket
/// closed). `ctl` handles the `shutdown` verb, and `live` is the
/// server's live-connection count (decremented when the pumps exit, so
/// the `--max-conns` cap tracks reality).
fn handle_conn(
    stream: TcpStream,
    queue: Arc<AdmissionQueue>,
    idle_timeout: Option<Duration>,
    ctl: Arc<DrainCtl>,
    live: Arc<AtomicUsize>,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        if let Some(t) = idle_timeout {
            let _ = stream.set_read_timeout(Some(t));
        }
        let write_half = match stream.try_clone() {
            Ok(s) => s,
            Err(_) => {
                live.fetch_sub(1, Ordering::SeqCst);
                return;
            }
        };
        let (tx, rx) = mpsc::channel::<Response>();
        let writer = std::thread::spawn(move || {
            let mut out = BufWriter::new(write_half);
            let mut buf: Vec<u8> = Vec::with_capacity(256);
            for mut resp in rx {
                if protocol::is_stats_marker(&resp) {
                    // `stats` verb: answer with a registry snapshot line
                    metrics::write_snapshot(&mut buf);
                    buf.push(b'\n');
                    if out.write_all(&buf).is_err() {
                        break;
                    }
                    let _ = out.flush();
                    continue;
                }
                let t0 = std::time::Instant::now();
                resp.write_line(&mut buf);
                buf.push(b'\n');
                metrics::record_span(
                    metrics::SpanSlot::Serialize,
                    t0.elapsed().as_nanos() as u64,
                );
                if out.write_all(&buf).is_err() {
                    break;
                }
                let _ = out.flush();
                // recycle the summary vector dispatch() took from the
                // pool — the wire line is written, the payload is done
                protocol::outputs_pool::put(std::mem::take(&mut resp.outputs));
            }
        });
        let mut reader = BufReader::new(stream);
        let mut line: Vec<u8> = Vec::with_capacity(256);
        let mut scratch = Request::default();
        loop {
            match read_line_capped(&mut reader, &mut line, protocol::MAX_LINE_BYTES) {
                Ok(LineRead::Eof) => break,
                Err(e)
                    if matches!(
                        e.kind(),
                        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
                    ) =>
                {
                    // --idle-timeout: no complete line within the
                    // window. Reap this connection; everyone else is
                    // untouched.
                    metrics::conn_reaped();
                    let _ = reader.get_ref().shutdown(Shutdown::Both);
                    break;
                }
                Err(_) => break,
                Ok(LineRead::TooLong) => {
                    let _ = tx.send(oversized_response());
                    continue;
                }
                Ok(LineRead::Line) => {}
            }
            if faults::should_drop_conn() {
                // injected `conn_drop` fault: kill the socket before
                // any response for this line (or responses still owed
                // to it) can be written — the dead-connection routing
                // path under test
                let _ = reader.get_ref().shutdown(Shutdown::Both);
                break;
            }
            let bytes = trim_ws(&line);
            if bytes.is_empty() {
                continue;
            }
            if protocol::is_stats_request(bytes) {
                let _ = tx.send(protocol::stats_marker());
                continue;
            }
            if protocol::is_shutdown_request(bytes) {
                // graceful drain handshake: ack, then serve admitted
                // work to completion while rejecting everything new
                ctl.trigger();
                let _ = tx.send(Response::err(
                    protocol::ERR_ID,
                    codes::SHUTTING_DOWN,
                    "draining: serving admitted work, then closing",
                ));
                continue;
            }
            match protocol::parse_request_streaming(bytes, &mut scratch) {
                Ok(()) => {
                    let id = scratch.id;
                    // the clone hands an owned Request to the queue
                    // while the scratch keeps its warmed capacity
                    if let Err(rej) = queue.try_push(Job::new(scratch.clone(), tx.clone())) {
                        let _ = tx.send(Response::err(
                            id,
                            rej.reason.code(),
                            rej.reason.message(),
                        ));
                    }
                }
                Err(e) => {
                    let _ = tx.send(Response::err(
                        protocol::ERR_ID,
                        codes::BAD_REQUEST,
                        &format!("bad request: {:#}", e),
                    ));
                }
            }
        }
        // EOF/error on the read half: the writer finishes once every
        // response owed to this connection's admitted jobs has landed
        // (each queued Job holds a Sender clone; the last drop ends rx).
        // If the socket died instead, the writer's first failed write
        // breaks it out — those responses are dropped, not queued.
        drop(tx);
        let _ = writer.join();
        live.fetch_sub(1, Ordering::SeqCst);
    })
}

/// `repro serve --listen ADDR`: bind, print the bound address, and
/// serve until killed. The shard pool runs under the calling thread's
/// supervision; sessions fault in lazily (no prewarm — the first
/// request for a key pays its session prepare).
pub fn run_tcp(
    spec: SimSpec,
    addr: &str,
    serve_cfg: &ServeCfg,
    shard_cfg: &ShardCfg,
) -> Result<()> {
    let srv = TcpServer::start(
        spec,
        addr,
        serve_cfg.clone(),
        shard_cfg.clone(),
        Vec::new(),
    )?;
    // machine-readable first line so scripts can scrape the bound port
    println!("listening on {}", srv.local_addr());
    crate::info!(
        "serving on tcp://{}: workers={} replicate_hot={} queue_cap={} \
         batch_window={:?} max_batch={} backend={}",
        srv.local_addr(),
        shard_cfg.workers,
        shard_cfg.replicate_hot,
        serve_cfg.queue_cap,
        serve_cfg.batch_window,
        serve_cfg.max_batch,
        backend::active().describe()
    );
    srv.wait()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn read_all(input: &[u8], max: usize, chunk: usize) -> Vec<(LineRead, Vec<u8>)> {
        // a tiny BufReader capacity forces the multi-chunk path
        let mut r = BufReader::with_capacity(chunk, Cursor::new(input.to_vec()));
        let mut buf = Vec::new();
        let mut out = Vec::new();
        loop {
            let res = read_line_capped(&mut r, &mut buf, max).unwrap();
            if res == LineRead::Eof {
                return out;
            }
            out.push((res, buf.clone()));
        }
    }

    #[test]
    fn capped_reader_splits_lines_and_discards_oversized() {
        let lines = read_all(b"ab\ncdef\n\nghi", 100, 3);
        assert_eq!(
            lines,
            vec![
                (LineRead::Line, b"ab".to_vec()),
                (LineRead::Line, b"cdef".to_vec()),
                (LineRead::Line, b"".to_vec()),
                // final unterminated line still delivers
                (LineRead::Line, b"ghi".to_vec()),
            ]
        );

        // a line of exactly max bytes is accepted; max+1 is discarded
        // and the NEXT line still comes through intact
        let input = b"aaaa\nbbbbb\ncc\n";
        let lines = read_all(input, 4, 3);
        assert_eq!(lines[0], (LineRead::Line, b"aaaa".to_vec()));
        assert_eq!(lines[1], (LineRead::TooLong, Vec::new()));
        assert_eq!(lines[2], (LineRead::Line, b"cc".to_vec()));

        // an endless unterminated line ends as TooLong at EOF
        let lines = read_all(&vec![b'x'; 64], 8, 4);
        assert_eq!(lines, vec![(LineRead::TooLong, Vec::new())]);
    }

    #[test]
    fn capped_reader_memory_stays_bounded() {
        // the accumulation buffer never holds more than max + one
        // BufRead chunk, even while a 1 MiB line streams through
        let chunk = 16;
        let max = 32;
        let big: Vec<u8> = vec![b'y'; 1 << 20];
        let mut r = BufReader::with_capacity(chunk, Cursor::new(big));
        let mut buf = Vec::new();
        let res = read_line_capped(&mut r, &mut buf, max).unwrap();
        assert_eq!(res, LineRead::TooLong);
        // amortized growth may double past the high-water mark of
        // max + one chunk, but it must stay nowhere near the 1 MiB line
        assert!(buf.capacity() <= 2 * (max + chunk), "capacity {}", buf.capacity());
    }

    #[test]
    fn trim_ws_trims_ascii_whitespace_only() {
        assert_eq!(trim_ws(b"  {\"a\":1}\r\n"), b"{\"a\":1}");
        assert_eq!(trim_ws(b""), b"");
        assert_eq!(trim_ws(b" \t\r\n "), b"");
        assert_eq!(trim_ws(b"x"), b"x");
    }

    #[test]
    fn oversized_response_names_the_limit() {
        let resp = oversized_response();
        assert_eq!(resp.id, protocol::ERR_ID);
        assert_eq!(resp.code.as_deref(), Some(codes::BAD_REQUEST));
        let msg = resp.error.as_deref().unwrap();
        assert!(msg.contains("exceeds max_line_bytes"), "{}", msg);
        assert!(msg.contains("1048576"), "{}", msg);
    }
}
