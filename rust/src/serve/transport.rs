//! TCP socket transport: the front end the line-delimited JSON protocol
//! was designed for (`repro serve --listen ADDR`).
//!
//! One listener thread accepts connections; each connection gets a
//! reader thread (parsing request lines into the shared admission
//! queue) and a writer thread (serializing that connection's responses
//! back). All connections multiplex into ONE admission queue served by
//! the shard pool — backpressure is global, so a single chatty client
//! cannot queue unboundedly ahead of others — and every job carries its
//! connection's response channel, so responses route back to whoever
//! asked, in completion order.
//!
//! Protocol framing and error codes are exactly those of
//! [`super::protocol`] (one JSON object per `\n`-terminated line in
//! each direction); `docs/serving.md` has the operator guide and a
//! worked `nc`/python client example.
//!
//! Shutdown ([`TcpServer::shutdown`]) is abortive for still-connected
//! clients: the listener stops, open sockets are shut down, admitted
//! jobs finish draining, and per-worker stats are returned. The CLI
//! path ([`run_tcp`]) instead serves until the process is killed.

use std::io::{BufRead, BufReader, BufWriter, Write as IoWrite};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::tensor::backend;

use super::protocol::{self, codes, Response};
use super::queue::{AdmissionQueue, Job};
use super::shard::{run_sharded, ShardCfg, ShardStats, SimSpec};
use super::ServeCfg;

/// A running TCP server: listener + per-connection pumps + shard pool.
pub struct TcpServer {
    local: SocketAddr,
    queue: Arc<AdmissionQueue>,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    conn_handles: Arc<Mutex<Vec<JoinHandle<()>>>>,
    accept: JoinHandle<()>,
    workers: JoinHandle<Result<Vec<ShardStats>>>,
}

impl TcpServer {
    /// Bind `addr` (e.g. `127.0.0.1:7411`, port 0 for ephemeral), spawn
    /// the accept loop and the shard pool, and return immediately.
    /// `prewarm` keys are opened by their home shards before traffic.
    pub fn start(
        spec: SimSpec,
        addr: &str,
        serve_cfg: ServeCfg,
        shard_cfg: ShardCfg,
        prewarm: Vec<(String, String)>,
    ) -> Result<TcpServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {}", addr))?;
        let local = listener.local_addr().context("local_addr")?;
        let queue = AdmissionQueue::new(serve_cfg.queue_cap);
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let conn_handles: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));

        let accept = {
            let queue = Arc::clone(&queue);
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            let conn_handles = Arc::clone(&conn_handles);
            std::thread::Builder::new()
                .name("tcp-accept".to_string())
                .spawn(move || {
                    for incoming in listener.incoming() {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        let stream = match incoming {
                            Ok(s) => s,
                            Err(_) => continue,
                        };
                        if let Ok(clone) = stream.try_clone() {
                            conns.lock().unwrap().push(clone);
                        }
                        let h = handle_conn(stream, Arc::clone(&queue));
                        conn_handles.lock().unwrap().push(h);
                    }
                })
                .expect("spawn tcp accept thread")
        };

        let workers = {
            let queue = Arc::clone(&queue);
            std::thread::Builder::new()
                .name("shard-pool".to_string())
                .spawn(move || {
                    run_sharded(&spec, &queue, &serve_cfg, &shard_cfg, &prewarm)
                })
                .expect("spawn shard pool supervisor")
        };

        Ok(TcpServer { local, queue, stop, conns, conn_handles, accept, workers })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Stop accepting, shut open connections down, drain admitted jobs,
    /// and return per-worker stats.
    pub fn shutdown(self) -> Result<Vec<ShardStats>> {
        self.stop.store(true, Ordering::SeqCst);
        // unblock the accept loop: it re-checks `stop` per connection
        let _ = TcpStream::connect(self.local);
        let _ = self.accept.join();
        // connection readers exit on socket shutdown; their writers
        // drain whatever responses are already owed to that connection
        for s in self.conns.lock().unwrap().drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
        let handles: Vec<JoinHandle<()>> =
            self.conn_handles.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        self.queue.close();
        match self.workers.join() {
            Ok(stats) => stats,
            Err(_) => Err(anyhow::anyhow!("shard pool panicked")),
        }
    }

    /// Serve until the accept loop exits (for the CLI: effectively
    /// until the process is killed), then drain and stop the workers.
    pub fn wait(self) -> Result<()> {
        let _ = self.accept.join();
        self.queue.close();
        match self.workers.join() {
            Ok(stats) => {
                let _ = stats?;
                Ok(())
            }
            Err(_) => Err(anyhow::anyhow!("shard pool panicked")),
        }
    }
}

/// Per-connection pumps: a reader thread (this handle) parsing lines
/// into the queue, plus a writer thread it owns for the responses.
fn handle_conn(stream: TcpStream, queue: Arc<AdmissionQueue>) -> JoinHandle<()> {
    std::thread::spawn(move || {
        let write_half = match stream.try_clone() {
            Ok(s) => s,
            Err(_) => return,
        };
        let (tx, rx) = mpsc::channel::<Response>();
        let writer = std::thread::spawn(move || {
            let mut out = BufWriter::new(write_half);
            for resp in rx {
                if writeln!(out, "{}", resp.line()).is_err() {
                    break;
                }
                let _ = out.flush();
            }
        });
        let reader = BufReader::new(stream);
        for line in reader.lines() {
            let Ok(line) = line else { break };
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            match protocol::parse_request(line) {
                Ok(req) => {
                    let id = req.id;
                    if queue.try_push(Job::new(req, tx.clone())).is_err() {
                        let _ = tx.send(Response::err(
                            id,
                            codes::QUEUE_FULL,
                            "queue full (backpressure): retry later",
                        ));
                    }
                }
                Err(e) => {
                    let _ = tx.send(Response::err(
                        protocol::ERR_ID,
                        codes::BAD_REQUEST,
                        &format!("bad request: {:#}", e),
                    ));
                }
            }
        }
        // EOF/error on the read half: the writer finishes once every
        // response owed to this connection's admitted jobs has landed
        // (each queued Job holds a Sender clone; the last drop ends rx).
        drop(tx);
        let _ = writer.join();
    })
}

/// `repro serve --listen ADDR`: bind, print the bound address, and
/// serve until killed. The shard pool runs under the calling thread's
/// supervision; sessions fault in lazily (no prewarm — the first
/// request for a key pays its session prepare).
pub fn run_tcp(
    spec: SimSpec,
    addr: &str,
    serve_cfg: &ServeCfg,
    shard_cfg: &ShardCfg,
) -> Result<()> {
    let srv = TcpServer::start(
        spec,
        addr,
        serve_cfg.clone(),
        shard_cfg.clone(),
        Vec::new(),
    )?;
    // machine-readable first line so scripts can scrape the bound port
    println!("listening on {}", srv.local_addr());
    crate::info!(
        "serving on tcp://{}: workers={} replicate_hot={} queue_cap={} \
         batch_window={:?} max_batch={} backend={}",
        srv.local_addr(),
        shard_cfg.workers,
        shard_cfg.replicate_hot,
        serve_cfg.queue_cap,
        serve_cfg.batch_window,
        serve_cfg.max_batch,
        backend::active().describe()
    );
    srv.wait()
}
