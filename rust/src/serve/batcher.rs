//! Dynamic micro-batcher: coalesce compatible queued requests into one
//! batched forward.
//!
//! Policy: pop the EDF-first job (its key anchors the batch), then keep
//! draining same-key jobs for up to `window` — sleeping between
//! arrivals, not polling — until `max_batch` is reached or the window
//! closes. Incompatible jobs stay queued for the next round, so a
//! minority key is delayed by at most the batches ahead of it, never
//! starved.
//!
//! Two anchor paths share the window-fill loop: [`Batcher::next_batch`]
//! (the single-worker server) pops globally; [`Batcher::next_shard_batch`]
//! asks the queue for an anchor this shard may serve (home keys first,
//! stealing when idle, hot-key replication when enabled) and carries the
//! key hold through dispatch.
//!
//! Deadlines are enforced here on the way out: a job that expired while
//! queued is answered with an error (`deadline_expired_in_queue`) and
//! never dispatched.

use std::cell::Cell;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::metrics;
use super::protocol::{codes, Response};
use super::queue::{AdmissionQueue, AnchorKind, BatchKey, Job, KeyHold};

/// A dispatch-ready set of compatible jobs (same model × quant config).
pub struct MicroBatch {
    /// The shared (model × quant) key of every job in the batch.
    pub key: BatchKey,
    /// The jobs, in EDF order at formation time.
    pub jobs: Vec<Job>,
}

/// A micro-batch granted to one shard worker, with the key hold that
/// keeps other workers off the key until dispatch finishes.
pub struct ShardBatch {
    /// The dispatch-ready batch.
    pub mb: MicroBatch,
    /// How this worker came to serve the key.
    pub kind: AnchorKind,
    /// Held through dispatch; dropping it releases the key.
    pub hold: KeyHold,
}

/// Which shard a [`Batcher::next_shard_batch`] call is forming for, and
/// under which replication policy.
#[derive(Debug, Clone)]
pub struct ShardSel {
    /// This worker's shard index in `0..nshards`.
    pub shard: usize,
    /// Total worker count.
    pub nshards: usize,
    /// Allow several shards to serve one key when its backlog is long.
    pub replicate_hot: bool,
    /// Minimum queued jobs for a key to count as hot.
    pub hot_min: usize,
}

/// Forms micro-batches from an [`AdmissionQueue`] (see module docs).
pub struct Batcher {
    queue: Arc<AdmissionQueue>,
    window: Duration,
    max_batch: usize,
    /// Jobs answered with a deadline error before dispatch — surfaced
    /// via [`Batcher::expired_count`] so the server's totals reconcile
    /// with the responses actually sent.
    expired: Cell<usize>,
}

impl Batcher {
    /// A batcher over `queue` with the given window and occupancy cap.
    pub fn new(queue: Arc<AdmissionQueue>, window: Duration, max_batch: usize) -> Batcher {
        Batcher {
            queue,
            window,
            max_batch: max_batch.max(1),
            expired: Cell::new(0),
        }
    }

    /// Requests answered with a pre-dispatch deadline error so far.
    pub fn expired_count(&self) -> usize {
        self.expired.get()
    }

    /// If `job` expired while queued, answer it with an error and drop
    /// it. Returns whether it was expired.
    fn expire_if_due(&self, job: &Job) -> bool {
        if job.expired(Instant::now()) {
            job.reply(Response::err(
                job.req.id,
                codes::DEADLINE_QUEUE,
                "deadline expired before dispatch",
            ));
            metrics::expired();
            self.expired.set(self.expired.get() + 1);
            return true;
        }
        false
    }

    /// The shared window-fill loop: drain same-key jobs (shedding
    /// expired ones) until `max_batch` or the window closes. Every job
    /// in the formed batch (anchor included) gets its assembly span
    /// stamp here.
    fn fill(&self, key: &BatchKey, jobs: &mut Vec<Job>) {
        let start = Instant::now();
        let mut seen = self.queue.arrivals();
        while jobs.len() < self.max_batch {
            for job in self
                .queue
                .drain_matching(key, self.max_batch - jobs.len())
            {
                if !self.expire_if_due(&job) {
                    jobs.push(job);
                }
            }
            if jobs.len() >= self.max_batch {
                break;
            }
            // A closed queue admits nothing new: waiting out the
            // window would only spin, so dispatch what we have.
            if self.queue.is_closed() {
                break;
            }
            let left = self.window.saturating_sub(start.elapsed());
            if left.is_zero() {
                break;
            }
            seen = self.queue.wait_new_arrival(seen, left);
        }
        let assembled = Instant::now();
        for job in jobs.iter_mut() {
            job.assemble_ns = assembled.duration_since(job.enqueued).as_nanos() as u64;
        }
    }

    /// Block until a micro-batch is ready; `None` once the queue is
    /// closed and drained. The single-worker path.
    pub fn next_batch(&self) -> Option<MicroBatch> {
        loop {
            let first = self.queue.pop_front_blocking()?;
            if self.expire_if_due(&first) {
                continue;
            }
            let key = first.key();
            let mut jobs = vec![first];
            self.fill(&key, &mut jobs);
            return Some(MicroBatch { key, jobs });
        }
    }

    /// Block until a micro-batch this shard may serve is ready; `None`
    /// once the queue is closed and drained. The returned [`ShardBatch`]
    /// carries the key hold — keep it alive through dispatch.
    pub fn next_shard_batch(&self, sel: &ShardSel) -> Option<ShardBatch> {
        loop {
            let (first, kind, hold) = self.queue.take_anchor(
                sel.shard,
                sel.nshards,
                sel.replicate_hot,
                sel.hot_min,
            )?;
            if self.expire_if_due(&first) {
                drop(hold);
                continue;
            }
            let key = first.key();
            let mut jobs = vec![first];
            self.fill(&key, &mut jobs);
            return Some(ShardBatch { mb: MicroBatch { key, jobs }, kind, hold });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::protocol::Request;
    use std::sync::mpsc;

    fn push(q: &AdmissionQueue, id: u64, quant: &str) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        q.try_push(Job::new(Request::new(id, "m", quant, 0), tx)).map_err(|_| ()).unwrap();
        rx
    }

    #[test]
    fn coalesces_same_key_and_leaves_other_keys_queued() {
        let q = AdmissionQueue::new(16);
        let _rxs: Vec<_> = vec![
            push(&q, 1, "a"),
            push(&q, 2, "b"),
            push(&q, 3, "a"),
            push(&q, 4, "a"),
            push(&q, 5, "b"),
        ];
        let b = Batcher::new(Arc::clone(&q), Duration::from_millis(1), 8);
        let mb = b.next_batch().unwrap();
        assert_eq!(mb.key.quant, "a");
        assert_eq!(mb.jobs.iter().map(|j| j.req.id).collect::<Vec<_>>(), vec![1, 3, 4]);
        let mb = b.next_batch().unwrap();
        assert_eq!(mb.key.quant, "b");
        assert_eq!(mb.jobs.iter().map(|j| j.req.id).collect::<Vec<_>>(), vec![2, 5]);
        q.close();
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn max_batch_caps_occupancy() {
        let q = AdmissionQueue::new(16);
        let _rxs: Vec<_> = (1..=5).map(|i| push(&q, i, "a")).collect();
        let b = Batcher::new(Arc::clone(&q), Duration::from_millis(1), 2);
        let sizes: Vec<usize> = (0..3).map(|_| b.next_batch().unwrap().jobs.len()).collect();
        assert_eq!(sizes, vec![2, 2, 1]);
        q.close();
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn expired_jobs_get_errors_not_dispatch() {
        let q = AdmissionQueue::new(16);
        let (tx, rx) = mpsc::channel();
        let mut req = Request::new(9, "m", "a", 0);
        req.deadline_ms = Some(1);
        q.try_push(Job::new(req, tx)).map_err(|_| ()).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        // a live job behind the expired one still comes through
        let _rx2 = push(&q, 10, "a");
        let b = Batcher::new(Arc::clone(&q), Duration::from_millis(1), 8);
        let mb = b.next_batch().unwrap();
        assert_eq!(mb.jobs.iter().map(|j| j.req.id).collect::<Vec<_>>(), vec![10]);
        let resp = rx.try_recv().unwrap();
        assert!(!resp.ok);
        assert!(resp.error.unwrap().contains("deadline"), "id 9 expired in queue");
        assert_eq!(resp.code.as_deref(), Some(codes::DEADLINE_QUEUE));
        assert_eq!(b.expired_count(), 1);
    }

    #[test]
    fn shard_batches_hold_the_key_and_fill_like_the_single_path() {
        let q = AdmissionQueue::new(16);
        let _rxs: Vec<_> = vec![push(&q, 1, "a"), push(&q, 2, "a"), push(&q, 3, "b")];
        q.close();
        let b = Batcher::new(Arc::clone(&q), Duration::from_millis(1), 8);
        let sel = ShardSel { shard: 0, nshards: 1, replicate_hot: false, hot_min: 16 };
        let sb = b.next_shard_batch(&sel).unwrap();
        let ids: Vec<u64> = sb.mb.jobs.iter().map(|j| j.req.id).collect();
        assert!(ids == vec![1, 2] || ids == vec![3], "one key per batch: {:?}", ids);
        drop(sb);
        let sb2 = b.next_shard_batch(&sel).unwrap();
        assert_ne!(sb2.mb.key.quant, if ids == vec![3] { "b" } else { "a" });
        drop(sb2);
        assert!(b.next_shard_batch(&sel).is_none(), "closed + drained");
    }
}
