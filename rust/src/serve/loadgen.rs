//! Closed-loop multi-client load generator (`repro loadgen`).
//!
//! N client threads each submit `requests_per_client` requests against a
//! server, one at a time (closed loop: the next request goes out only
//! after the previous response lands — so a full queue is real
//! backpressure, not an unbounded backlog). The traffic mix cycles
//! deterministically over (model × quant config) pairs and the request
//! stream indices derive from a fixed seed, so two runs with the same
//! `LoadgenCfg` traffic issue byte-identical requests regardless of
//! batching configuration, worker count or thread interleaving — the
//! serving determinism tests compare exactly that.
//!
//! Three transports share the same clients and accounting:
//!
//! * [`run_loadgen`] — in-process, single worker (the calling thread
//!   serves);
//! * [`run_loadgen_sharded`] — in-process against an N-worker shard
//!   pool (`--workers`);
//! * [`run_loadgen_tcp`] — real sockets against a `--listen` server
//!   (`--connect ADDR`), one TCP connection per client.
//!
//! The report records sustained tokens/sec, batch occupancy and
//! p50/p95/p99 client-observed latency; `bench_serve` snapshots it into
//! `BENCH_serve.json` per backend × quant config × worker count.

use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Write as IoWrite};
use std::net::TcpStream;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::quantsim::{QuantConfig, Simulator};
use crate::util::json::Json;

use super::cache::SessionCache;
use super::metrics;
use super::protocol::{self, codes, Request, Response};
use super::queue::{AdmissionQueue, Job, RejectReason};
use super::shard::{run_sharded, ShardCfg, ShardStats, SimSpec};
use super::transport;
use super::{serve_loop, ServeCfg, ServeStats};

/// Load-generator knobs (`repro loadgen --clients N ...`).
#[derive(Debug, Clone)]
pub struct LoadgenCfg {
    /// Concurrent closed-loop client threads.
    pub clients: usize,
    /// Requests each client submits before exiting.
    pub requests_per_client: usize,
    /// The (model, quant config) pairs the clients cycle over.
    pub mix: Vec<(String, String)>,
    /// Per-request relative deadline; `None` = no deadline.
    pub deadline_ms: Option<u64>,
    /// Seeds the request stream indices (not the model weights).
    pub seed: u64,
    /// Open every mix session (pretraining weights as needed) before
    /// the clock starts, so the report measures steady-state serving.
    pub prewarm: bool,
    /// The server's tuning knobs (in-process transports only).
    pub serve: ServeCfg,
    /// The shard pool shape ([`run_loadgen_sharded`] only).
    pub shard: ShardCfg,
}

impl Default for LoadgenCfg {
    fn default() -> LoadgenCfg {
        LoadgenCfg {
            clients: 4,
            requests_per_client: 8,
            mix: vec![
                ("sim-opt-125m".to_string(), "fp32".to_string()),
                ("sim-opt-125m".to_string(), "abfp_w4a4_n64".to_string()),
            ],
            deadline_ms: None,
            seed: 1,
            prewarm: true,
            serve: ServeCfg::default(),
            shard: ShardCfg::default(),
        }
    }
}

/// Which mix entry client `c`'s request `i` targets — the ONE place the
/// formula lives, used both by the client threads (choosing what to
/// send) and the throughput accounting (reconstructing what a response
/// id targeted). Keep them in lock-step or tokens/sec misattributes.
fn mix_slot(nmix: usize, c: usize, i: usize) -> usize {
    (c + i) % nmix
}

/// Globally unique, reconstructible request id.
fn request_id(c: usize, i: usize) -> u64 {
    (c as u64) * 1_000_000 + i as u64
}

/// The request client `c` sends at step `i` — shared by the in-process
/// and TCP submit paths so the wire traffic is identical across
/// transports.
fn request_for(cfg: &LoadgenCfg, c: usize, i: usize) -> Request {
    let (model, quant) = &cfg.mix[mix_slot(cfg.mix.len(), c, i)];
    let mut req = Request::new(
        request_id(c, i),
        model,
        quant,
        cfg.seed.wrapping_add((c * 131 + i * 17) as u64) % 64,
    );
    req.deadline_ms = cfg.deadline_ms;
    req
}

/// What one load-generator run observed, aggregated over all clients.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    /// Every response, sorted by request id.
    pub responses: Vec<Response>,
    /// Successful responses.
    pub ok: usize,
    /// Error responses (any code).
    pub errors: usize,
    /// Wall-clock seconds from first submit to last response.
    pub wall_s: f64,
    /// Sustained tokens/sec over the whole run (ok responses only).
    pub toks_per_s: f64,
    /// Mean micro-batch occupancy over ok responses.
    pub mean_occupancy: f64,
    /// Largest micro-batch any response reported.
    pub max_occupancy: usize,
    /// Median client-observed latency (ms, includes queueing).
    pub p50_ms: f64,
    /// 95th-percentile client-observed latency (ms).
    pub p95_ms: f64,
    /// 99th-percentile client-observed latency (ms).
    pub p99_ms: f64,
    /// Server-side counters (zeroed for the TCP transport — the server
    /// is another process).
    pub stats: ServeStats,
    /// Worker count the server ran with (1 = classic single worker,
    /// 0 = remote server over TCP, shape unknown to the client).
    pub workers: usize,
    /// TCP connections re-established after a drop (capped exponential
    /// backoff; always 0 for the in-process transports).
    pub reconnects: usize,
    /// Per-worker counters (sharded in-process transport only).
    pub per_worker: Vec<ShardStats>,
    /// Server-side truth from the metrics registry — read directly for
    /// the in-process transports, scraped via the `stats` wire verb
    /// (before/after delta) over TCP. Always present in reports built
    /// by the `run_loadgen*` entry points.
    pub server: Option<ServerSide>,
}

/// The server's own headline counters for one loadgen run — what the
/// *registry* saw, printed next to the client-observed percentiles so
/// operators can spot client/server disagreement (e.g. responses the
/// client dropped, sheds the client never noticed).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServerSide {
    /// Jobs admitted into the queue.
    pub admitted: u64,
    /// Jobs rejected at admission (queue-full backpressure).
    pub rejected: u64,
    /// Jobs shed with a deadline error before dispatch.
    pub expired: u64,
    /// Jobs answered ok.
    pub ok: u64,
    /// Jobs answered with an error post-admission.
    pub errors: u64,
    /// Micro-batches dispatched.
    pub batches: u64,
    /// Batches anchored on stolen keys.
    pub steals: u64,
    /// Batches served under hot-key replication.
    pub hot_hits: u64,
    /// Session-cache hits.
    pub cache_hits: u64,
    /// Session-cache misses (sessions prepared).
    pub cache_misses: u64,
    /// Prepared-state builds.
    pub prepared_builds: u64,
    /// qlinear sites dispatched to the true int8 GEMM.
    pub int_dispatch: u64,
    /// qlinear sites dispatched to the simulated QDQ path.
    pub qdq_dispatch: u64,
}

impl ServerSide {
    fn from_snapshot(s: &metrics::Snapshot) -> ServerSide {
        ServerSide {
            admitted: s.admitted,
            rejected: s.rejected,
            expired: s.expired,
            ok: s.ok,
            errors: s.errors,
            batches: s.batches,
            steals: s.steals,
            hot_hits: s.hot_hits,
            cache_hits: s.cache_hits,
            cache_misses: s.cache_misses,
            prepared_builds: s.prepared_builds,
            int_dispatch: s.int_dispatch,
            qdq_dispatch: s.qdq_dispatch,
        }
    }

    /// Parse the counters out of one `stats` snapshot line.
    pub fn from_stats_json(j: &Json) -> Result<ServerSide> {
        let uint = |key: &str| -> Result<u64> {
            j.get(key)
                .and_then(Json::as_f64)
                .map(|n| n as u64)
                .with_context(|| format!("stats snapshot missing numeric {:?}", key))
        };
        Ok(ServerSide {
            admitted: uint("admitted")?,
            rejected: uint("rejected")?,
            expired: uint("expired")?,
            ok: uint("ok")?,
            errors: uint("errors")?,
            batches: uint("batches")?,
            steals: uint("steals")?,
            hot_hits: uint("hot_hits")?,
            cache_hits: uint("cache_hits")?,
            cache_misses: uint("cache_misses")?,
            prepared_builds: uint("prepared_builds")?,
            int_dispatch: uint("int_dispatch")?,
            qdq_dispatch: uint("qdq_dispatch")?,
        })
    }

    /// Counter-wise difference (`self` after − `before`), for TCP runs
    /// against a long-lived server whose registry is cumulative.
    pub fn delta_since(&self, before: &ServerSide) -> ServerSide {
        ServerSide {
            admitted: self.admitted.saturating_sub(before.admitted),
            rejected: self.rejected.saturating_sub(before.rejected),
            expired: self.expired.saturating_sub(before.expired),
            ok: self.ok.saturating_sub(before.ok),
            errors: self.errors.saturating_sub(before.errors),
            batches: self.batches.saturating_sub(before.batches),
            steals: self.steals.saturating_sub(before.steals),
            hot_hits: self.hot_hits.saturating_sub(before.hot_hits),
            cache_hits: self.cache_hits.saturating_sub(before.cache_hits),
            cache_misses: self.cache_misses.saturating_sub(before.cache_misses),
            prepared_builds: self.prepared_builds.saturating_sub(before.prepared_builds),
            int_dispatch: self.int_dispatch.saturating_sub(before.int_dispatch),
            qdq_dispatch: self.qdq_dispatch.saturating_sub(before.qdq_dispatch),
        }
    }

    /// Fraction of qlinear sites served by the true int8 GEMM (0 when
    /// nothing dispatched).
    pub fn int_share(&self) -> f64 {
        let total = self.int_dispatch + self.qdq_dispatch;
        if total == 0 {
            0.0
        } else {
            self.int_dispatch as f64 / total as f64
        }
    }

    /// Cross-counter sanity for a quiesced run; every CI loadgen cell
    /// fails on a violation (an impossible server is worse than a slow
    /// one).
    pub fn check(&self) -> Result<()> {
        anyhow::ensure!(
            self.ok + self.errors + self.expired <= self.admitted,
            "impossible server stats: ok {} + errors {} + expired {} > admitted {}",
            self.ok,
            self.errors,
            self.expired,
            self.admitted
        );
        anyhow::ensure!(
            self.cache_misses <= self.prepared_builds,
            "impossible server stats: cache_misses {} > prepared_builds {}",
            self.cache_misses,
            self.prepared_builds
        );
        anyhow::ensure!(
            self.steals + self.hot_hits <= self.batches,
            "impossible server stats: steals {} + hot_hits {} > batches {}",
            self.steals,
            self.hot_hits,
            self.batches
        );
        Ok(())
    }

    /// The counters as a JSON object (nested under `server` in the
    /// report payload).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("admitted", Json::Num(self.admitted as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("expired", Json::Num(self.expired as f64)),
            ("ok", Json::Num(self.ok as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("steals", Json::Num(self.steals as f64)),
            ("hot_hits", Json::Num(self.hot_hits as f64)),
            ("cache_hits", Json::Num(self.cache_hits as f64)),
            ("cache_misses", Json::Num(self.cache_misses as f64)),
            ("prepared_builds", Json::Num(self.prepared_builds as f64)),
            ("int_dispatch", Json::Num(self.int_dispatch as f64)),
            ("qdq_dispatch", Json::Num(self.qdq_dispatch as f64)),
        ])
    }
}

impl LoadgenReport {
    /// Batches this run anchored on stolen keys, summed over workers.
    pub fn stolen_batches(&self) -> usize {
        self.per_worker.iter().map(|w| w.stolen_batches).sum()
    }

    /// Batches this run anchored on hot-replicated keys.
    pub fn hot_batches(&self) -> usize {
        self.per_worker.iter().map(|w| w.hot_batches).sum()
    }

    /// One-line human summary.
    pub fn render(&self) -> String {
        let mut s = format!(
            "loadgen: {} ok / {} errors in {:.2}s  {:.1} tok/s  \
             occupancy mean {:.2} max {}  latency p50 {:.2}ms p95 {:.2}ms p99 {:.2}ms",
            self.ok,
            self.errors,
            self.wall_s,
            self.toks_per_s,
            self.mean_occupancy,
            self.max_occupancy,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms
        );
        if !self.per_worker.is_empty() {
            s.push_str(&format!(
                "  workers {} (stolen {}, hot {})",
                self.workers,
                self.stolen_batches(),
                self.hot_batches()
            ));
        }
        if self.reconnects > 0 {
            s.push_str(&format!("  reconnects {}", self.reconnects));
        }
        if let Some(sv) = &self.server {
            s.push_str(&format!(
                "\n  server: admitted {} ok {} err {} shed {} rej {} | {} batches \
                 (stolen {}, hot {}) | cache {}/{} | int dispatch {:.0}%",
                sv.admitted,
                sv.ok,
                sv.errors,
                sv.expired,
                sv.rejected,
                sv.batches,
                sv.steals,
                sv.hot_hits,
                sv.cache_hits,
                sv.cache_misses,
                100.0 * sv.int_share()
            ));
        }
        s
    }

    /// The report as JSON (the `BENCH_serve.json` cell payload).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("ok", Json::Num(self.ok as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("wall_s", Json::Num(self.wall_s)),
            ("toks_per_s", Json::Num(self.toks_per_s)),
            ("mean_occupancy", Json::Num(self.mean_occupancy)),
            ("max_occupancy", Json::Num(self.max_occupancy as f64)),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p95_ms", Json::Num(self.p95_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
            ("workers", Json::Num(self.workers as f64)),
            ("reconnects", Json::Num(self.reconnects as f64)),
        ];
        if !self.per_worker.is_empty() {
            fields.push(("stolen_batches", Json::Num(self.stolen_batches() as f64)));
            fields.push(("hot_batches", Json::Num(self.hot_batches() as f64)));
            let per = self
                .per_worker
                .iter()
                .map(|w| {
                    Json::obj(vec![
                        ("shard", Json::Num(w.shard as f64)),
                        ("requests", Json::Num(w.serve.requests as f64)),
                        ("batches", Json::Num(w.serve.batches as f64)),
                        ("ok", Json::Num(w.serve.ok as f64)),
                        ("errors", Json::Num(w.serve.errors as f64)),
                        ("expired", Json::Num(w.serve.expired as f64)),
                        ("max_occupancy", Json::Num(w.serve.max_occupancy as f64)),
                        ("stolen_batches", Json::Num(w.stolen_batches as f64)),
                        ("hot_batches", Json::Num(w.hot_batches as f64)),
                        ("cache_hits", Json::Num(w.cache_hits as f64)),
                        ("cache_misses", Json::Num(w.cache_misses as f64)),
                    ])
                })
                .collect();
            fields.push(("per_worker", Json::Arr(per)));
        }
        if let Some(sv) = &self.server {
            fields.push(("server", sv.to_json()));
        }
        Json::obj(fields)
    }
}

/// Validate every mix entry against the manifest and record each
/// model's tokens-per-request (what a `toks_per_s` unit means).
fn validate_mix(sim: &Simulator, cfg: &LoadgenCfg) -> Result<HashMap<String, f64>> {
    anyhow::ensure!(cfg.clients > 0, "loadgen needs at least one client");
    anyhow::ensure!(cfg.requests_per_client > 0, "loadgen needs at least one request");
    anyhow::ensure!(!cfg.mix.is_empty(), "loadgen needs a non-empty traffic mix");
    let mut toks_per_model: HashMap<String, f64> = HashMap::new();
    for (model, quant) in &cfg.mix {
        sim.eval_artifact_id(model, quant)
            .with_context(|| format!("mix entry {}:{}", model, quant))?;
        let mcfg = sim.rt.manifest.model(model)?;
        let toks = if mcfg.arch == "vit" {
            mcfg.batch as f64
        } else {
            (mcfg.batch * mcfg.seq) as f64
        };
        toks_per_model.insert(model.clone(), toks);
    }
    Ok(toks_per_model)
}

/// Spawn the in-process closed-loop clients pushing into `queue`. Each
/// client sends its records through the returned channel when done.
fn spawn_clients(
    cfg: &LoadgenCfg,
    queue: &Arc<AdmissionQueue>,
) -> (Vec<std::thread::JoinHandle<()>>, mpsc::Receiver<Vec<(Response, f64)>>) {
    let (done_tx, done_rx) = mpsc::channel::<Vec<(Response, f64)>>();
    let mut clients = Vec::with_capacity(cfg.clients);
    for c in 0..cfg.clients {
        let queue = Arc::clone(queue);
        let cfg = cfg.clone();
        let done = done_tx.clone();
        clients.push(std::thread::spawn(move || {
            let (tx, rx) = mpsc::channel::<Response>();
            let mut records = Vec::with_capacity(cfg.requests_per_client);
            'requests: for i in 0..cfg.requests_per_client {
                let req = request_for(&cfg, c, i);
                let started = Instant::now();
                let mut job = Job::new(req, tx.clone());
                // Closed-loop backpressure: a full queue means wait and
                // retry, never pile on.
                loop {
                    match queue.try_push(job) {
                        Ok(()) => break,
                        Err(rejected) => {
                            // Draining covers a closed queue too: the
                            // server will never take this job, stop.
                            if rejected.reason == RejectReason::Draining {
                                break 'requests;
                            }
                            job = rejected.job;
                            std::thread::sleep(Duration::from_micros(200));
                        }
                    }
                }
                match rx.recv() {
                    Ok(resp) => {
                        records.push((resp, started.elapsed().as_secs_f64() * 1e3));
                    }
                    Err(_) => break,
                }
            }
            let _ = done.send(records);
        }));
    }
    (clients, done_rx)
}

/// Fold every client's records into the final report (shared by all
/// three transports).
fn assemble_report(
    cfg: &LoadgenCfg,
    done_rx: mpsc::Receiver<Vec<(Response, f64)>>,
    wall_s: f64,
    toks_per_model: &HashMap<String, f64>,
    stats: ServeStats,
    workers: usize,
    per_worker: Vec<ShardStats>,
) -> LoadgenReport {
    let mut responses: Vec<Response> = Vec::new();
    let mut latencies: Vec<f64> = Vec::new();
    let (mut ok, mut errors, mut toks) = (0usize, 0usize, 0.0f64);
    let mut occ_sum = 0usize;
    let mut occ_max = stats.max_occupancy;
    for records in done_rx.iter() {
        for (resp, ms) in records {
            if resp.ok {
                ok += 1;
                occ_sum += resp.batched;
                occ_max = occ_max.max(resp.batched);
                let c = (resp.id / 1_000_000) as usize;
                let i = (resp.id % 1_000_000) as usize;
                let model = &cfg.mix[mix_slot(cfg.mix.len(), c, i)].0;
                toks += toks_per_model.get(model).copied().unwrap_or(0.0);
            } else {
                errors += 1;
            }
            latencies.push(ms);
            responses.push(resp);
        }
    }
    responses.sort_by_key(|r| r.id);
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| -> f64 {
        if latencies.is_empty() {
            0.0
        } else {
            latencies[((latencies.len() as f64 - 1.0) * p) as usize]
        }
    };

    LoadgenReport {
        ok,
        errors,
        wall_s,
        toks_per_s: if wall_s > 0.0 { toks / wall_s } else { 0.0 },
        mean_occupancy: if ok > 0 { occ_sum as f64 / ok as f64 } else { 0.0 },
        max_occupancy: occ_max,
        p50_ms: pct(0.5),
        p95_ms: pct(0.95),
        p99_ms: pct(0.99),
        responses,
        stats,
        workers,
        reconnects: 0,
        per_worker,
        server: None,
    }
}

/// Drive `cfg.clients` concurrent closed-loop clients against an
/// in-process server; the calling thread becomes the serving worker
/// (sessions are not `Send`). Returns the aggregated report.
pub fn run_loadgen(sim: &Simulator, cfg: &LoadgenCfg) -> Result<LoadgenReport> {
    let toks_per_model = validate_mix(sim, cfg)?;

    let mut cache = SessionCache::new();
    if cfg.prewarm {
        for (model, quant) in &cfg.mix {
            let key = super::session_key(sim, model, quant);
            cache.get_or_open(&key, || {
                sim.open_eval_session(model, &QuantConfig::abfp(quant))
            })?;
        }
    }

    // Measure this run only: prewarm opens stay out of the registry.
    metrics::reset();
    let queue = AdmissionQueue::new(cfg.serve.queue_cap);
    let t0 = Instant::now();
    let (clients, done_rx) = spawn_clients(cfg, &queue);

    // Close the queue once every client has finished — from a helper
    // thread, because this thread is about to become the server.
    let closer = {
        let queue = Arc::clone(&queue);
        std::thread::spawn(move || {
            for h in clients {
                let _ = h.join();
            }
            queue.close();
        })
    };

    let stats = serve_loop(sim, &queue, &cfg.serve, &mut cache);
    let wall_s = t0.elapsed().as_secs_f64();
    let _ = closer.join();

    let snap = metrics::snapshot();
    snap.check().context("server-side metrics failed the sanity check")?;
    let mut report =
        assemble_report(cfg, done_rx, wall_s, &toks_per_model, stats, 1, Vec::new());
    report.server = Some(ServerSide::from_snapshot(&snap));
    Ok(report)
}

/// Like [`run_loadgen`], but the serving side is an in-process
/// `cfg.shard.workers`-strong shard pool supervised by the calling
/// thread. Weights are pretrained (and sessions optionally prewarmed on
/// their home shards) before the clock starts.
pub fn run_loadgen_sharded(spec: &SimSpec, cfg: &LoadgenCfg) -> Result<LoadgenReport> {
    // A probe simulator validates the mix and — when prewarming — pays
    // every checkpoint pretrain ONCE before the pool spawns, so shard
    // workers only ever load cached weights.
    let probe = spec.build().context("loadgen: build probe simulator")?;
    let toks_per_model = validate_mix(&probe, cfg)?;
    let prewarm: Vec<(String, String)> = if cfg.prewarm { cfg.mix.clone() } else { Vec::new() };
    if cfg.prewarm {
        for (model, quant) in &cfg.mix {
            probe
                .open_eval_session(model, &QuantConfig::abfp(quant))
                .with_context(|| format!("prewarm {}:{}", model, quant))?;
        }
    }
    drop(probe);

    // Measure this run only. Worker prewarm happens *after* the pool
    // spawns (each worker opens its home keys itself), so unlike the
    // single-worker transport those opens are counted here — accounted
    // for in the serve_shard metric assertions.
    metrics::reset();
    let queue = AdmissionQueue::new(cfg.serve.queue_cap);
    let t0 = Instant::now();
    let (clients, done_rx) = spawn_clients(cfg, &queue);
    let closer = {
        let queue = Arc::clone(&queue);
        std::thread::spawn(move || {
            for h in clients {
                let _ = h.join();
            }
            queue.close();
        })
    };

    let per_worker = run_sharded(spec, &queue, &cfg.serve, &cfg.shard, &prewarm)?;
    let wall_s = t0.elapsed().as_secs_f64();
    let _ = closer.join();

    let mut stats = ServeStats::default();
    for w in &per_worker {
        stats.absorb(&w.serve);
    }
    let snap = metrics::snapshot();
    snap.check().context("server-side metrics failed the sanity check")?;
    let mut report = assemble_report(
        cfg,
        done_rx,
        wall_s,
        &toks_per_model,
        stats,
        cfg.shard.workers,
        per_worker,
    );
    report.server = Some(ServerSide::from_snapshot(&snap));
    Ok(report)
}

/// One loadgen client's connection halves (reader + writer over the
/// same socket).
struct ClientConn {
    writer: BufWriter<TcpStream>,
    reader: BufReader<TcpStream>,
}

/// Connect to `addr` with capped exponential backoff: up to `tries`
/// attempts, sleeping 1ms, 2ms, 4ms, … (capped at 100ms) between them.
/// Covers both slow server starts and the reconnect path after a
/// dropped connection.
fn connect_backoff(addr: &str, tries: usize) -> Result<ClientConn> {
    let mut delay = Duration::from_millis(1);
    let cap = Duration::from_millis(100);
    let mut attempt = 0usize;
    loop {
        match TcpStream::connect(addr) {
            Ok(stream) => {
                let writer = BufWriter::new(stream.try_clone().context("clone stream")?);
                let reader = BufReader::new(stream);
                return Ok(ClientConn { writer, reader });
            }
            Err(e) => {
                attempt += 1;
                if attempt >= tries {
                    return Err(e).with_context(|| {
                        format!("connect {} ({} attempts with backoff)", addr, attempt)
                    });
                }
                std::thread::sleep(delay);
                delay = (delay * 2).min(cap);
            }
        }
    }
}

/// Drive the closed-loop clients over real sockets against a running
/// `repro serve --listen` server at `addr` — one TCP connection per
/// client. `sim` is only a local probe (mix validation and token
/// accounting); all serving happens in the remote process, so
/// `report.stats` is zeroed and `report.workers` is 0.
///
/// Connections are established (and, after a drop, re-established)
/// with capped exponential backoff; a client whose connection dies
/// mid-request reconnects and resubmits the in-flight request
/// (at-least-once over the wire — the deterministic request ids make
/// the duplicate harmless to the accounting, which is keyed per
/// submission). The total across clients lands in
/// [`LoadgenReport::reconnects`].
pub fn run_loadgen_tcp(sim: &Simulator, addr: &str, cfg: &LoadgenCfg) -> Result<LoadgenReport> {
    let toks_per_model = validate_mix(sim, cfg)?;

    // The remote registry is cumulative across the server's lifetime;
    // scrape it before and after and report the delta as this run's
    // server-side truth.
    let before = fetch_server_stats(addr).context("scrape server stats (pre-run)")?;

    let (done_tx, done_rx) = mpsc::channel::<Vec<(Response, f64)>>();
    let reconnects = Arc::new(std::sync::atomic::AtomicUsize::new(0));
    let mut clients = Vec::with_capacity(cfg.clients);
    let t0 = Instant::now();
    for c in 0..cfg.clients {
        let cfg = cfg.clone();
        let addr = addr.to_string();
        let done = done_tx.clone();
        let reconnects = Arc::clone(&reconnects);
        clients.push(std::thread::spawn(move || -> Result<()> {
            let mut conn = connect_backoff(&addr, 8)?;
            let mut records = Vec::with_capacity(cfg.requests_per_client);
            // reused wire buffers: requests serialize via write_line,
            // replies land in a capped reused read buffer — the client
            // side of the zero-allocation hot path
            let mut wbuf: Vec<u8> = Vec::with_capacity(256);
            let mut rbuf: Vec<u8> = Vec::with_capacity(256);
            for i in 0..cfg.requests_per_client {
                let req = request_for(&cfg, c, i);
                req.write_line(&mut wbuf);
                wbuf.push(b'\n');
                let started = Instant::now();
                // Closed-loop backpressure over the wire: a queue_full
                // error means wait and resubmit the same request. A
                // dead connection (write failure or EOF/read error
                // while awaiting the response) means reconnect with
                // backoff and resubmit.
                let resp = loop {
                    let sent = conn
                        .writer
                        .write_all(&wbuf)
                        .and_then(|()| conn.writer.flush());
                    if sent.is_err() {
                        conn = connect_backoff(&addr, 8).context("reconnect after drop")?;
                        reconnects.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        continue;
                    }
                    match transport::read_line_capped(
                        &mut conn.reader,
                        &mut rbuf,
                        protocol::MAX_LINE_BYTES,
                    ) {
                        Ok(transport::LineRead::Line) => {}
                        Ok(transport::LineRead::Eof) | Err(_) => {
                            conn =
                                connect_backoff(&addr, 8).context("reconnect after drop")?;
                            reconnects.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            continue;
                        }
                        Ok(transport::LineRead::TooLong) => {
                            anyhow::bail!("response line exceeds max_line_bytes")
                        }
                    }
                    let reply = std::str::from_utf8(transport::trim_ws(&rbuf))
                        .context("response is not utf-8")?;
                    let resp = protocol::parse_response(reply)?;
                    if !resp.ok && resp.code.as_deref() == Some(codes::QUEUE_FULL) {
                        std::thread::sleep(Duration::from_micros(200));
                        continue;
                    }
                    break resp;
                };
                records.push((resp, started.elapsed().as_secs_f64() * 1e3));
            }
            let _ = done.send(records);
            Ok(())
        }));
    }
    drop(done_tx);
    let mut first_err: Option<anyhow::Error> = None;
    for h in clients {
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                first_err.get_or_insert(e);
            }
            Err(_) => {
                first_err.get_or_insert_with(|| anyhow::anyhow!("loadgen client panicked"));
            }
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    let wall_s = t0.elapsed().as_secs_f64();

    let after = fetch_server_stats(addr).context("scrape server stats (post-run)")?;
    let server = after.delta_since(&before);
    server.check().context("server-side metrics failed the sanity check")?;
    let mut report = assemble_report(
        cfg,
        done_rx,
        wall_s,
        &toks_per_model,
        ServeStats::default(),
        0,
        Vec::new(),
    );
    report.reconnects = reconnects.load(std::sync::atomic::Ordering::Relaxed);
    report.server = Some(server);
    Ok(report)
}

/// Scrape one metrics snapshot from a remote server: a fresh
/// connection, one `stats` verb line out, one JSON snapshot line back.
pub fn fetch_server_stats(addr: &str) -> Result<ServerSide> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connect {}", addr))?;
    let mut writer = BufWriter::new(stream.try_clone().context("clone stream")?);
    let mut reader = BufReader::new(stream);
    writer.write_all(protocol::STATS_LINE.as_bytes()).context("send stats verb")?;
    writer.write_all(b"\n").context("send stats verb")?;
    writer.flush().context("flush stats verb")?;
    let mut rbuf: Vec<u8> = Vec::with_capacity(1024);
    match transport::read_line_capped(&mut reader, &mut rbuf, protocol::MAX_LINE_BYTES)
        .context("read stats response")?
    {
        transport::LineRead::Line => {}
        transport::LineRead::Eof => anyhow::bail!("server closed the connection"),
        transport::LineRead::TooLong => {
            anyhow::bail!("stats line exceeds max_line_bytes")
        }
    }
    let text =
        std::str::from_utf8(transport::trim_ws(&rbuf)).context("stats line is not utf-8")?;
    let json = Json::parse(text).map_err(|e| anyhow::anyhow!("bad stats json: {}", e))?;
    ServerSide::from_stats_json(&json)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_side_round_trips_deltas_and_sanity_checks() {
        let sv = ServerSide {
            admitted: 10,
            rejected: 2,
            expired: 1,
            ok: 8,
            errors: 1,
            batches: 4,
            steals: 1,
            hot_hits: 1,
            cache_hits: 7,
            cache_misses: 2,
            prepared_builds: 2,
            int_dispatch: 3,
            qdq_dispatch: 1,
        };
        sv.check().unwrap();
        assert!((sv.int_share() - 0.75).abs() < 1e-12);

        let parsed =
            ServerSide::from_stats_json(&Json::parse(&sv.to_json().dump()).unwrap()).unwrap();
        assert_eq!(parsed, sv);

        let later = ServerSide { admitted: 25, ok: 20, ..sv.clone() };
        let d = later.delta_since(&sv);
        assert_eq!(d.admitted, 15);
        assert_eq!(d.ok, 12);
        assert_eq!(d.batches, 0);

        let bad = ServerSide { ok: 20, ..sv.clone() };
        assert!(bad.check().is_err(), "ok+errors+expired > admitted must be impossible");
    }
}
