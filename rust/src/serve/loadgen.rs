//! Closed-loop multi-client load generator (`repro loadgen`).
//!
//! N client threads each submit `requests_per_client` requests against
//! an in-process server, one at a time (closed loop: the next request
//! goes out only after the previous response lands — so a full queue is
//! real backpressure, not an unbounded backlog). The traffic mix cycles
//! deterministically over (model × quant config) pairs and the request
//! stream indices derive from a fixed seed, so two runs with the same
//! `LoadgenCfg` traffic issue byte-identical requests regardless of
//! batching configuration or thread interleaving — the serving
//! determinism tests compare exactly that.
//!
//! The report records sustained tokens/sec, batch occupancy and
//! p50/p95/p99 client-observed latency; `bench_serve` snapshots it into
//! `BENCH_serve.json` per backend × quant config.

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::quantsim::{QuantConfig, Simulator};
use crate::util::json::Json;

use super::cache::SessionCache;
use super::protocol::{Request, Response};
use super::queue::{AdmissionQueue, Job};
use super::{serve_loop, ServeCfg, ServeStats};

#[derive(Debug, Clone)]
pub struct LoadgenCfg {
    pub clients: usize,
    pub requests_per_client: usize,
    /// The (model, quant config) pairs the clients cycle over.
    pub mix: Vec<(String, String)>,
    /// Per-request relative deadline; `None` = no deadline.
    pub deadline_ms: Option<u64>,
    /// Seeds the request stream indices (not the model weights).
    pub seed: u64,
    /// Open every mix session (pretraining weights as needed) before
    /// the clock starts, so the report measures steady-state serving.
    pub prewarm: bool,
    pub serve: ServeCfg,
}

impl Default for LoadgenCfg {
    fn default() -> LoadgenCfg {
        LoadgenCfg {
            clients: 4,
            requests_per_client: 8,
            mix: vec![
                ("sim-opt-125m".to_string(), "fp32".to_string()),
                ("sim-opt-125m".to_string(), "abfp_w4a4_n64".to_string()),
            ],
            deadline_ms: None,
            seed: 1,
            prewarm: true,
            serve: ServeCfg::default(),
        }
    }
}

/// Which mix entry client `c`'s request `i` targets — the ONE place the
/// formula lives, used both by the client threads (choosing what to
/// send) and the throughput accounting (reconstructing what a response
/// id targeted). Keep them in lock-step or tokens/sec misattributes.
fn mix_slot(nmix: usize, c: usize, i: usize) -> usize {
    (c + i) % nmix
}

/// Globally unique, reconstructible request id.
fn request_id(c: usize, i: usize) -> u64 {
    (c as u64) * 1_000_000 + i as u64
}

#[derive(Debug, Clone)]
pub struct LoadgenReport {
    /// Every response, sorted by request id.
    pub responses: Vec<Response>,
    pub ok: usize,
    pub errors: usize,
    pub wall_s: f64,
    pub toks_per_s: f64,
    pub mean_occupancy: f64,
    pub max_occupancy: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub stats: ServeStats,
}

impl LoadgenReport {
    pub fn render(&self) -> String {
        format!(
            "loadgen: {} ok / {} errors in {:.2}s  {:.1} tok/s  \
             occupancy mean {:.2} max {}  latency p50 {:.2}ms p95 {:.2}ms p99 {:.2}ms",
            self.ok,
            self.errors,
            self.wall_s,
            self.toks_per_s,
            self.mean_occupancy,
            self.max_occupancy,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ok", Json::Num(self.ok as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("wall_s", Json::Num(self.wall_s)),
            ("toks_per_s", Json::Num(self.toks_per_s)),
            ("mean_occupancy", Json::Num(self.mean_occupancy)),
            ("max_occupancy", Json::Num(self.max_occupancy as f64)),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p95_ms", Json::Num(self.p95_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
        ])
    }
}

/// Drive `cfg.clients` concurrent closed-loop clients against an
/// in-process server; the calling thread becomes the serving worker
/// (sessions are not `Send`). Returns the aggregated report.
pub fn run_loadgen(sim: &Simulator, cfg: &LoadgenCfg) -> Result<LoadgenReport> {
    anyhow::ensure!(cfg.clients > 0, "loadgen needs at least one client");
    anyhow::ensure!(cfg.requests_per_client > 0, "loadgen needs at least one request");
    anyhow::ensure!(!cfg.mix.is_empty(), "loadgen needs a non-empty traffic mix");

    // Validate the mix up front and record tokens-per-request per model.
    let mut toks_per_model: HashMap<String, f64> = HashMap::new();
    for (model, quant) in &cfg.mix {
        sim.eval_artifact_id(model, quant)
            .with_context(|| format!("mix entry {}:{}", model, quant))?;
        let mcfg = sim.rt.manifest.model(model)?;
        let toks = if mcfg.arch == "vit" {
            mcfg.batch as f64
        } else {
            (mcfg.batch * mcfg.seq) as f64
        };
        toks_per_model.insert(model.clone(), toks);
    }

    let mut cache = SessionCache::new();
    if cfg.prewarm {
        for (model, quant) in &cfg.mix {
            let key = super::session_key(sim, model, quant);
            cache.get_or_open(&key, || {
                sim.open_eval_session(model, &QuantConfig::abfp(quant))
            })?;
        }
    }

    let queue = AdmissionQueue::new(cfg.serve.queue_cap);
    let (done_tx, done_rx) = mpsc::channel::<Vec<(Response, f64)>>();
    let mut clients = Vec::with_capacity(cfg.clients);
    let t0 = Instant::now();
    for c in 0..cfg.clients {
        let queue = Arc::clone(&queue);
        let mix = cfg.mix.clone();
        let n = cfg.requests_per_client;
        let deadline = cfg.deadline_ms;
        let seed = cfg.seed;
        let nmix = cfg.mix.len();
        let done = done_tx.clone();
        clients.push(std::thread::spawn(move || {
            let (tx, rx) = mpsc::channel::<Response>();
            let mut records = Vec::with_capacity(n);
            'requests: for i in 0..n {
                let (model, quant) = mix[mix_slot(nmix, c, i)].clone();
                let mut req = Request::new(
                    request_id(c, i),
                    &model,
                    &quant,
                    seed.wrapping_add((c * 131 + i * 17) as u64) % 64,
                );
                req.deadline_ms = deadline;
                let started = Instant::now();
                let mut job = Job::new(req, tx.clone());
                // Closed-loop backpressure: a full queue means wait and
                // retry, never pile on.
                loop {
                    match queue.try_push(job) {
                        Ok(()) => break,
                        Err(rejected) => {
                            if queue.is_closed() {
                                break 'requests;
                            }
                            job = rejected;
                            std::thread::sleep(Duration::from_micros(200));
                        }
                    }
                }
                match rx.recv() {
                    Ok(resp) => {
                        records.push((resp, started.elapsed().as_secs_f64() * 1e3));
                    }
                    Err(_) => break,
                }
            }
            let _ = done.send(records);
        }));
    }
    drop(done_tx);

    // Close the queue once every client has finished — from a helper
    // thread, because this thread is about to become the server.
    let closer = {
        let queue = Arc::clone(&queue);
        std::thread::spawn(move || {
            for h in clients {
                let _ = h.join();
            }
            queue.close();
        })
    };

    let stats = serve_loop(sim, &queue, &cfg.serve, &mut cache);
    let wall_s = t0.elapsed().as_secs_f64();
    let _ = closer.join();

    let mut responses: Vec<Response> = Vec::new();
    let mut latencies: Vec<f64> = Vec::new();
    let (mut ok, mut errors, mut toks) = (0usize, 0usize, 0.0f64);
    let mut occ_sum = 0usize;
    for records in done_rx.iter() {
        for (resp, ms) in records {
            if resp.ok {
                ok += 1;
                occ_sum += resp.batched;
                let c = (resp.id / 1_000_000) as usize;
                let i = (resp.id % 1_000_000) as usize;
                let model = &cfg.mix[mix_slot(cfg.mix.len(), c, i)].0;
                toks += toks_per_model.get(model).copied().unwrap_or(0.0);
            } else {
                errors += 1;
            }
            latencies.push(ms);
            responses.push(resp);
        }
    }
    responses.sort_by_key(|r| r.id);
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| -> f64 {
        if latencies.is_empty() {
            0.0
        } else {
            latencies[((latencies.len() as f64 - 1.0) * p) as usize]
        }
    };

    Ok(LoadgenReport {
        ok,
        errors,
        wall_s,
        toks_per_s: if wall_s > 0.0 { toks / wall_s } else { 0.0 },
        mean_occupancy: if ok > 0 { occ_sum as f64 / ok as f64 } else { 0.0 },
        max_occupancy: stats.max_occupancy,
        p50_ms: pct(0.5),
        p95_ms: pct(0.95),
        p99_ms: pct(0.99),
        responses,
        stats,
    })
}
