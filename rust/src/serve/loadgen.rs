//! Closed-loop multi-client load generator (`repro loadgen`).
//!
//! N client threads each submit `requests_per_client` requests against a
//! server, one at a time (closed loop: the next request goes out only
//! after the previous response lands — so a full queue is real
//! backpressure, not an unbounded backlog). The traffic mix cycles
//! deterministically over (model × quant config) pairs and the request
//! stream indices derive from a fixed seed, so two runs with the same
//! `LoadgenCfg` traffic issue byte-identical requests regardless of
//! batching configuration, worker count or thread interleaving — the
//! serving determinism tests compare exactly that.
//!
//! Three transports share the same clients and accounting:
//!
//! * [`run_loadgen`] — in-process, single worker (the calling thread
//!   serves);
//! * [`run_loadgen_sharded`] — in-process against an N-worker shard
//!   pool (`--workers`);
//! * [`run_loadgen_tcp`] — real sockets against a `--listen` server
//!   (`--connect ADDR`), one TCP connection per client.
//!
//! The report records sustained tokens/sec, batch occupancy and
//! p50/p95/p99 client-observed latency; `bench_serve` snapshots it into
//! `BENCH_serve.json` per backend × quant config × worker count.

use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Write as IoWrite};
use std::net::TcpStream;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::quantsim::{QuantConfig, Simulator};
use crate::util::json::Json;

use super::cache::SessionCache;
use super::protocol::{self, codes, Request, Response};
use super::queue::{AdmissionQueue, Job};
use super::shard::{run_sharded, ShardCfg, ShardStats, SimSpec};
use super::transport;
use super::{serve_loop, ServeCfg, ServeStats};

/// Load-generator knobs (`repro loadgen --clients N ...`).
#[derive(Debug, Clone)]
pub struct LoadgenCfg {
    /// Concurrent closed-loop client threads.
    pub clients: usize,
    /// Requests each client submits before exiting.
    pub requests_per_client: usize,
    /// The (model, quant config) pairs the clients cycle over.
    pub mix: Vec<(String, String)>,
    /// Per-request relative deadline; `None` = no deadline.
    pub deadline_ms: Option<u64>,
    /// Seeds the request stream indices (not the model weights).
    pub seed: u64,
    /// Open every mix session (pretraining weights as needed) before
    /// the clock starts, so the report measures steady-state serving.
    pub prewarm: bool,
    /// The server's tuning knobs (in-process transports only).
    pub serve: ServeCfg,
    /// The shard pool shape ([`run_loadgen_sharded`] only).
    pub shard: ShardCfg,
}

impl Default for LoadgenCfg {
    fn default() -> LoadgenCfg {
        LoadgenCfg {
            clients: 4,
            requests_per_client: 8,
            mix: vec![
                ("sim-opt-125m".to_string(), "fp32".to_string()),
                ("sim-opt-125m".to_string(), "abfp_w4a4_n64".to_string()),
            ],
            deadline_ms: None,
            seed: 1,
            prewarm: true,
            serve: ServeCfg::default(),
            shard: ShardCfg::default(),
        }
    }
}

/// Which mix entry client `c`'s request `i` targets — the ONE place the
/// formula lives, used both by the client threads (choosing what to
/// send) and the throughput accounting (reconstructing what a response
/// id targeted). Keep them in lock-step or tokens/sec misattributes.
fn mix_slot(nmix: usize, c: usize, i: usize) -> usize {
    (c + i) % nmix
}

/// Globally unique, reconstructible request id.
fn request_id(c: usize, i: usize) -> u64 {
    (c as u64) * 1_000_000 + i as u64
}

/// The request client `c` sends at step `i` — shared by the in-process
/// and TCP submit paths so the wire traffic is identical across
/// transports.
fn request_for(cfg: &LoadgenCfg, c: usize, i: usize) -> Request {
    let (model, quant) = &cfg.mix[mix_slot(cfg.mix.len(), c, i)];
    let mut req = Request::new(
        request_id(c, i),
        model,
        quant,
        cfg.seed.wrapping_add((c * 131 + i * 17) as u64) % 64,
    );
    req.deadline_ms = cfg.deadline_ms;
    req
}

/// What one load-generator run observed, aggregated over all clients.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    /// Every response, sorted by request id.
    pub responses: Vec<Response>,
    /// Successful responses.
    pub ok: usize,
    /// Error responses (any code).
    pub errors: usize,
    /// Wall-clock seconds from first submit to last response.
    pub wall_s: f64,
    /// Sustained tokens/sec over the whole run (ok responses only).
    pub toks_per_s: f64,
    /// Mean micro-batch occupancy over ok responses.
    pub mean_occupancy: f64,
    /// Largest micro-batch any response reported.
    pub max_occupancy: usize,
    /// Median client-observed latency (ms, includes queueing).
    pub p50_ms: f64,
    /// 95th-percentile client-observed latency (ms).
    pub p95_ms: f64,
    /// 99th-percentile client-observed latency (ms).
    pub p99_ms: f64,
    /// Server-side counters (zeroed for the TCP transport — the server
    /// is another process).
    pub stats: ServeStats,
    /// Worker count the server ran with (1 = classic single worker,
    /// 0 = remote server over TCP, shape unknown to the client).
    pub workers: usize,
    /// Per-worker counters (sharded in-process transport only).
    pub per_worker: Vec<ShardStats>,
}

impl LoadgenReport {
    /// Batches this run anchored on stolen keys, summed over workers.
    pub fn stolen_batches(&self) -> usize {
        self.per_worker.iter().map(|w| w.stolen_batches).sum()
    }

    /// Batches this run anchored on hot-replicated keys.
    pub fn hot_batches(&self) -> usize {
        self.per_worker.iter().map(|w| w.hot_batches).sum()
    }

    /// One-line human summary.
    pub fn render(&self) -> String {
        let mut s = format!(
            "loadgen: {} ok / {} errors in {:.2}s  {:.1} tok/s  \
             occupancy mean {:.2} max {}  latency p50 {:.2}ms p95 {:.2}ms p99 {:.2}ms",
            self.ok,
            self.errors,
            self.wall_s,
            self.toks_per_s,
            self.mean_occupancy,
            self.max_occupancy,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms
        );
        if !self.per_worker.is_empty() {
            s.push_str(&format!(
                "  workers {} (stolen {}, hot {})",
                self.workers,
                self.stolen_batches(),
                self.hot_batches()
            ));
        }
        s
    }

    /// The report as JSON (the `BENCH_serve.json` cell payload).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("ok", Json::Num(self.ok as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("wall_s", Json::Num(self.wall_s)),
            ("toks_per_s", Json::Num(self.toks_per_s)),
            ("mean_occupancy", Json::Num(self.mean_occupancy)),
            ("max_occupancy", Json::Num(self.max_occupancy as f64)),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p95_ms", Json::Num(self.p95_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
            ("workers", Json::Num(self.workers as f64)),
        ];
        if !self.per_worker.is_empty() {
            fields.push(("stolen_batches", Json::Num(self.stolen_batches() as f64)));
            fields.push(("hot_batches", Json::Num(self.hot_batches() as f64)));
            let per = self
                .per_worker
                .iter()
                .map(|w| {
                    Json::obj(vec![
                        ("shard", Json::Num(w.shard as f64)),
                        ("requests", Json::Num(w.serve.requests as f64)),
                        ("batches", Json::Num(w.serve.batches as f64)),
                        ("ok", Json::Num(w.serve.ok as f64)),
                        ("errors", Json::Num(w.serve.errors as f64)),
                        ("expired", Json::Num(w.serve.expired as f64)),
                        ("max_occupancy", Json::Num(w.serve.max_occupancy as f64)),
                        ("stolen_batches", Json::Num(w.stolen_batches as f64)),
                        ("hot_batches", Json::Num(w.hot_batches as f64)),
                        ("cache_hits", Json::Num(w.cache_hits as f64)),
                        ("cache_misses", Json::Num(w.cache_misses as f64)),
                    ])
                })
                .collect();
            fields.push(("per_worker", Json::Arr(per)));
        }
        Json::obj(fields)
    }
}

/// Validate every mix entry against the manifest and record each
/// model's tokens-per-request (what a `toks_per_s` unit means).
fn validate_mix(sim: &Simulator, cfg: &LoadgenCfg) -> Result<HashMap<String, f64>> {
    anyhow::ensure!(cfg.clients > 0, "loadgen needs at least one client");
    anyhow::ensure!(cfg.requests_per_client > 0, "loadgen needs at least one request");
    anyhow::ensure!(!cfg.mix.is_empty(), "loadgen needs a non-empty traffic mix");
    let mut toks_per_model: HashMap<String, f64> = HashMap::new();
    for (model, quant) in &cfg.mix {
        sim.eval_artifact_id(model, quant)
            .with_context(|| format!("mix entry {}:{}", model, quant))?;
        let mcfg = sim.rt.manifest.model(model)?;
        let toks = if mcfg.arch == "vit" {
            mcfg.batch as f64
        } else {
            (mcfg.batch * mcfg.seq) as f64
        };
        toks_per_model.insert(model.clone(), toks);
    }
    Ok(toks_per_model)
}

/// Spawn the in-process closed-loop clients pushing into `queue`. Each
/// client sends its records through the returned channel when done.
fn spawn_clients(
    cfg: &LoadgenCfg,
    queue: &Arc<AdmissionQueue>,
) -> (Vec<std::thread::JoinHandle<()>>, mpsc::Receiver<Vec<(Response, f64)>>) {
    let (done_tx, done_rx) = mpsc::channel::<Vec<(Response, f64)>>();
    let mut clients = Vec::with_capacity(cfg.clients);
    for c in 0..cfg.clients {
        let queue = Arc::clone(queue);
        let cfg = cfg.clone();
        let done = done_tx.clone();
        clients.push(std::thread::spawn(move || {
            let (tx, rx) = mpsc::channel::<Response>();
            let mut records = Vec::with_capacity(cfg.requests_per_client);
            'requests: for i in 0..cfg.requests_per_client {
                let req = request_for(&cfg, c, i);
                let started = Instant::now();
                let mut job = Job::new(req, tx.clone());
                // Closed-loop backpressure: a full queue means wait and
                // retry, never pile on.
                loop {
                    match queue.try_push(job) {
                        Ok(()) => break,
                        Err(rejected) => {
                            if queue.is_closed() {
                                break 'requests;
                            }
                            job = rejected;
                            std::thread::sleep(Duration::from_micros(200));
                        }
                    }
                }
                match rx.recv() {
                    Ok(resp) => {
                        records.push((resp, started.elapsed().as_secs_f64() * 1e3));
                    }
                    Err(_) => break,
                }
            }
            let _ = done.send(records);
        }));
    }
    (clients, done_rx)
}

/// Fold every client's records into the final report (shared by all
/// three transports).
fn assemble_report(
    cfg: &LoadgenCfg,
    done_rx: mpsc::Receiver<Vec<(Response, f64)>>,
    wall_s: f64,
    toks_per_model: &HashMap<String, f64>,
    stats: ServeStats,
    workers: usize,
    per_worker: Vec<ShardStats>,
) -> LoadgenReport {
    let mut responses: Vec<Response> = Vec::new();
    let mut latencies: Vec<f64> = Vec::new();
    let (mut ok, mut errors, mut toks) = (0usize, 0usize, 0.0f64);
    let mut occ_sum = 0usize;
    let mut occ_max = stats.max_occupancy;
    for records in done_rx.iter() {
        for (resp, ms) in records {
            if resp.ok {
                ok += 1;
                occ_sum += resp.batched;
                occ_max = occ_max.max(resp.batched);
                let c = (resp.id / 1_000_000) as usize;
                let i = (resp.id % 1_000_000) as usize;
                let model = &cfg.mix[mix_slot(cfg.mix.len(), c, i)].0;
                toks += toks_per_model.get(model).copied().unwrap_or(0.0);
            } else {
                errors += 1;
            }
            latencies.push(ms);
            responses.push(resp);
        }
    }
    responses.sort_by_key(|r| r.id);
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| -> f64 {
        if latencies.is_empty() {
            0.0
        } else {
            latencies[((latencies.len() as f64 - 1.0) * p) as usize]
        }
    };

    LoadgenReport {
        ok,
        errors,
        wall_s,
        toks_per_s: if wall_s > 0.0 { toks / wall_s } else { 0.0 },
        mean_occupancy: if ok > 0 { occ_sum as f64 / ok as f64 } else { 0.0 },
        max_occupancy: occ_max,
        p50_ms: pct(0.5),
        p95_ms: pct(0.95),
        p99_ms: pct(0.99),
        responses,
        stats,
        workers,
        per_worker,
    }
}

/// Drive `cfg.clients` concurrent closed-loop clients against an
/// in-process server; the calling thread becomes the serving worker
/// (sessions are not `Send`). Returns the aggregated report.
pub fn run_loadgen(sim: &Simulator, cfg: &LoadgenCfg) -> Result<LoadgenReport> {
    let toks_per_model = validate_mix(sim, cfg)?;

    let mut cache = SessionCache::new();
    if cfg.prewarm {
        for (model, quant) in &cfg.mix {
            let key = super::session_key(sim, model, quant);
            cache.get_or_open(&key, || {
                sim.open_eval_session(model, &QuantConfig::abfp(quant))
            })?;
        }
    }

    let queue = AdmissionQueue::new(cfg.serve.queue_cap);
    let t0 = Instant::now();
    let (clients, done_rx) = spawn_clients(cfg, &queue);

    // Close the queue once every client has finished — from a helper
    // thread, because this thread is about to become the server.
    let closer = {
        let queue = Arc::clone(&queue);
        std::thread::spawn(move || {
            for h in clients {
                let _ = h.join();
            }
            queue.close();
        })
    };

    let stats = serve_loop(sim, &queue, &cfg.serve, &mut cache);
    let wall_s = t0.elapsed().as_secs_f64();
    let _ = closer.join();

    Ok(assemble_report(cfg, done_rx, wall_s, &toks_per_model, stats, 1, Vec::new()))
}

/// Like [`run_loadgen`], but the serving side is an in-process
/// `cfg.shard.workers`-strong shard pool supervised by the calling
/// thread. Weights are pretrained (and sessions optionally prewarmed on
/// their home shards) before the clock starts.
pub fn run_loadgen_sharded(spec: &SimSpec, cfg: &LoadgenCfg) -> Result<LoadgenReport> {
    // A probe simulator validates the mix and — when prewarming — pays
    // every checkpoint pretrain ONCE before the pool spawns, so shard
    // workers only ever load cached weights.
    let probe = spec.build().context("loadgen: build probe simulator")?;
    let toks_per_model = validate_mix(&probe, cfg)?;
    let prewarm: Vec<(String, String)> = if cfg.prewarm { cfg.mix.clone() } else { Vec::new() };
    if cfg.prewarm {
        for (model, quant) in &cfg.mix {
            probe
                .open_eval_session(model, &QuantConfig::abfp(quant))
                .with_context(|| format!("prewarm {}:{}", model, quant))?;
        }
    }
    drop(probe);

    let queue = AdmissionQueue::new(cfg.serve.queue_cap);
    let t0 = Instant::now();
    let (clients, done_rx) = spawn_clients(cfg, &queue);
    let closer = {
        let queue = Arc::clone(&queue);
        std::thread::spawn(move || {
            for h in clients {
                let _ = h.join();
            }
            queue.close();
        })
    };

    let per_worker = run_sharded(spec, &queue, &cfg.serve, &cfg.shard, &prewarm)?;
    let wall_s = t0.elapsed().as_secs_f64();
    let _ = closer.join();

    let mut stats = ServeStats::default();
    for w in &per_worker {
        stats.absorb(&w.serve);
    }
    Ok(assemble_report(
        cfg,
        done_rx,
        wall_s,
        &toks_per_model,
        stats,
        cfg.shard.workers,
        per_worker,
    ))
}

/// Drive the closed-loop clients over real sockets against a running
/// `repro serve --listen` server at `addr` — one TCP connection per
/// client. `sim` is only a local probe (mix validation and token
/// accounting); all serving happens in the remote process, so
/// `report.stats` is zeroed and `report.workers` is 0.
pub fn run_loadgen_tcp(sim: &Simulator, addr: &str, cfg: &LoadgenCfg) -> Result<LoadgenReport> {
    let toks_per_model = validate_mix(sim, cfg)?;

    let (done_tx, done_rx) = mpsc::channel::<Vec<(Response, f64)>>();
    let mut clients = Vec::with_capacity(cfg.clients);
    let t0 = Instant::now();
    for c in 0..cfg.clients {
        let cfg = cfg.clone();
        let addr = addr.to_string();
        let done = done_tx.clone();
        clients.push(std::thread::spawn(move || -> Result<()> {
            let stream =
                TcpStream::connect(&addr).with_context(|| format!("connect {}", addr))?;
            let mut writer = BufWriter::new(stream.try_clone().context("clone stream")?);
            let mut reader = BufReader::new(stream);
            let mut records = Vec::with_capacity(cfg.requests_per_client);
            // reused wire buffers: requests serialize via write_line,
            // replies land in a capped reused read buffer — the client
            // side of the zero-allocation hot path
            let mut wbuf: Vec<u8> = Vec::with_capacity(256);
            let mut rbuf: Vec<u8> = Vec::with_capacity(256);
            for i in 0..cfg.requests_per_client {
                let req = request_for(&cfg, c, i);
                req.write_line(&mut wbuf);
                wbuf.push(b'\n');
                let started = Instant::now();
                // Closed-loop backpressure over the wire: a queue_full
                // error means wait and resubmit the same request.
                let resp = loop {
                    writer.write_all(&wbuf).context("send request")?;
                    writer.flush().context("flush request")?;
                    match transport::read_line_capped(
                        &mut reader,
                        &mut rbuf,
                        protocol::MAX_LINE_BYTES,
                    )
                    .context("read response")?
                    {
                        transport::LineRead::Line => {}
                        transport::LineRead::Eof => {
                            anyhow::bail!("server closed the connection")
                        }
                        transport::LineRead::TooLong => {
                            anyhow::bail!("response line exceeds max_line_bytes")
                        }
                    }
                    let reply = std::str::from_utf8(transport::trim_ws(&rbuf))
                        .context("response is not utf-8")?;
                    let resp = protocol::parse_response(reply)?;
                    if !resp.ok && resp.code.as_deref() == Some(codes::QUEUE_FULL) {
                        std::thread::sleep(Duration::from_micros(200));
                        continue;
                    }
                    break resp;
                };
                records.push((resp, started.elapsed().as_secs_f64() * 1e3));
            }
            let _ = done.send(records);
            Ok(())
        }));
    }
    drop(done_tx);
    let mut first_err: Option<anyhow::Error> = None;
    for h in clients {
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                first_err.get_or_insert(e);
            }
            Err(_) => {
                first_err.get_or_insert_with(|| anyhow::anyhow!("loadgen client panicked"));
            }
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    let wall_s = t0.elapsed().as_secs_f64();

    Ok(assemble_report(
        cfg,
        done_rx,
        wall_s,
        &toks_per_model,
        ServeStats::default(),
        0,
        Vec::new(),
    ))
}
