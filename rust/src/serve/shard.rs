//! Sharded multi-worker serving: N threads, each owning its own
//! simulator and prepared-session cache, coordinated only through the
//! shared admission queue.
//!
//! Sessions are not `Send`, so the pool never moves one across threads.
//! Instead each worker *builds* everything it needs from a cloneable
//! [`SimSpec`] recipe: its own [`Simulator`], its own [`SessionCache`].
//! The prepared-session cache is thereby partitioned by (model × quant)
//! key — a key's sessions live on whichever shards have served it:
//!
//! * **home assignment** — every key has a stable home shard
//!   ([`crate::serve::queue::home_shard`], FNV-1a mod N), preferred
//!   when forming batches, so a key's prepared state stays warm on one
//!   worker instead of faulting in everywhere;
//! * **stealing** — an idle worker takes the EDF-first foreign key no
//!   one is serving rather than sit idle while its own keys are quiet;
//! * **hot-key replication** (`--replicate-hot`) — a key whose backlog
//!   reaches `hot_min` may be served by several shards concurrently;
//!   each prepares its own session replica (an independent, determinis-
//!   tic QDQ of the same checkpoint — replicas cannot diverge).
//!
//! Each worker is additionally its own **failure domain**: a panic in
//! batch execution is caught by the supervised dispatcher (see
//! `serve::dispatch`), the offending request is quarantined, and the
//! worker rebuilds its simulator and session cache from the same
//! [`SimSpec`] recipe before taking the next batch — one poison request
//! cannot take a shard (let alone the pool) down.
//!
//! Scheduling never changes results: `run_batch` outputs are
//! bit-identical per request regardless of batch composition, and a
//! shard only decides where/when a batch runs. The `serve_shard`
//! integration tests assert byte-identical responses across worker
//! counts, batching windows and replication settings.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::quantsim::{EvalOpts, QuantConfig, Simulator};

use super::batcher::{Batcher, ShardSel};
use super::cache::SessionCache;
use super::protocol::{codes, Response};
use super::queue::{home_shard, AdmissionQueue, AnchorKind, BatchKey};
use super::{Corpora, ServeCfg, ServeStats};

/// Cloneable recipe for building one [`Simulator`] per shard worker —
/// the shard pool's answer to sessions (and simulators) not being
/// `Send`: ship the *recipe* across threads, build locally.
#[derive(Clone)]
pub struct SimSpec {
    /// Artifacts directory (as passed to `Simulator::new`).
    pub artifacts: String,
    /// Checkpoints directory — shared by all shards, so pretrained
    /// weights are written once and replicas load the same bytes.
    pub checkpoints: String,
    /// Evaluation options every built simulator starts from.
    pub opts: EvalOpts,
}

impl SimSpec {
    /// A spec with default [`EvalOpts`].
    pub fn new(artifacts: &str, checkpoints: &str) -> SimSpec {
        SimSpec {
            artifacts: artifacts.to_string(),
            checkpoints: checkpoints.to_string(),
            opts: EvalOpts::default(),
        }
    }

    /// Build a fresh [`Simulator`] from this recipe (one per worker).
    pub fn build(&self) -> Result<Simulator> {
        let mut sim = Simulator::new(&self.artifacts, &self.checkpoints)?;
        sim.opts = self.opts.clone();
        Ok(sim)
    }
}

/// Shard-pool tuning knobs (`--workers`, `--replicate-hot`, `--hot-min`).
#[derive(Debug, Clone)]
pub struct ShardCfg {
    /// Worker thread count (1 = the classic single-worker server).
    pub workers: usize,
    /// Let several shards serve one key when its backlog is long.
    pub replicate_hot: bool,
    /// Minimum queued jobs for a key to count as hot.
    pub hot_min: usize,
}

impl Default for ShardCfg {
    fn default() -> ShardCfg {
        ShardCfg { workers: 1, replicate_hot: false, hot_min: 16 }
    }
}

/// One worker's counters after the pool drains.
#[derive(Debug, Clone, Default)]
pub struct ShardStats {
    /// This worker's shard index.
    pub shard: usize,
    /// The worker's serve-loop counters.
    pub serve: ServeStats,
    /// Batches anchored on a foreign key (work stealing).
    pub stolen_batches: usize,
    /// Batches anchored on a key another shard also held (replication).
    pub hot_batches: usize,
    /// Session-cache hits on this worker.
    pub cache_hits: usize,
    /// Session-cache misses (sessions prepared) on this worker.
    pub cache_misses: usize,
}

/// Run the shard pool to completion: spawn `shard.workers` workers,
/// each serving eligible batches from `queue` until it is closed and
/// drained, then return per-worker stats (sorted by shard index).
///
/// `prewarm` lists (model, quant) keys each worker opens up front *if
/// it is their home shard* — steady-state measurement without paying
/// first-request session prepares on the clock.
///
/// If any worker fails (e.g. its simulator cannot be built), the queue
/// is closed, the remaining queued jobs are answered with `run_failed`
/// errors, and the first error is returned.
pub fn run_sharded(
    spec: &SimSpec,
    queue: &Arc<AdmissionQueue>,
    serve_cfg: &ServeCfg,
    shard_cfg: &ShardCfg,
    prewarm: &[(String, String)],
) -> Result<Vec<ShardStats>> {
    anyhow::ensure!(shard_cfg.workers >= 1, "shard pool needs at least one worker");
    let mut handles = Vec::with_capacity(shard_cfg.workers);
    for w in 0..shard_cfg.workers {
        let spec = spec.clone();
        let queue = Arc::clone(queue);
        let serve_cfg = serve_cfg.clone();
        let shard_cfg = shard_cfg.clone();
        let prewarm: Vec<(String, String)> = prewarm.to_vec();
        let handle = std::thread::Builder::new()
            .name(format!("shard-{}", w))
            .spawn(move || worker_loop(w, &spec, &queue, &serve_cfg, &shard_cfg, &prewarm))
            .expect("spawn shard worker");
        handles.push(handle);
    }

    let mut stats = Vec::with_capacity(shard_cfg.workers);
    let mut first_err: Option<anyhow::Error> = None;
    for h in handles {
        match h.join() {
            Ok(Ok(s)) => stats.push(s),
            Ok(Err(e)) => {
                queue.close();
                first_err.get_or_insert(e);
            }
            Err(_) => {
                queue.close();
                first_err.get_or_insert_with(|| anyhow::anyhow!("shard worker panicked"));
            }
        }
    }
    match first_err {
        Some(e) => {
            // Surviving workers have exited; answer whatever is still
            // queued so no client hangs on a response that never comes.
            while let Some(job) = queue.pop_front_blocking() {
                job.reply(Response::err(
                    job.req.id,
                    codes::RUN_FAILED,
                    "server worker failed",
                ));
            }
            Err(e)
        }
        None => {
            stats.sort_by_key(|s| s.shard);
            Ok(stats)
        }
    }
}

fn worker_loop(
    w: usize,
    spec: &SimSpec,
    queue: &Arc<AdmissionQueue>,
    serve_cfg: &ServeCfg,
    shard_cfg: &ShardCfg,
    prewarm: &[(String, String)],
) -> Result<ShardStats> {
    let mut sim = spec.build().with_context(|| format!("shard {}: build simulator", w))?;
    let mut cache = SessionCache::for_shard(w);
    for (model, quant) in prewarm {
        let bkey = BatchKey { model: model.clone(), quant: quant.clone() };
        if home_shard(&bkey, shard_cfg.workers) != w {
            continue;
        }
        let skey = super::session_key(&sim, model, quant);
        cache
            .get_or_open(&skey, || sim.open_eval_session(model, &QuantConfig::abfp(quant)))
            .with_context(|| format!("shard {}: prewarm {}:{}", w, model, quant))?;
    }

    let batcher = Batcher::new(Arc::clone(queue), serve_cfg.batch_window, serve_cfg.max_batch);
    let corpora = Corpora::new();
    let sel = ShardSel {
        shard: w,
        nshards: shard_cfg.workers,
        replicate_hot: shard_cfg.replicate_hot,
        hot_min: shard_cfg.hot_min,
    };
    let mut st = ShardStats { shard: w, ..Default::default() };
    while let Some(sb) = batcher.next_shard_batch(&sel) {
        match sb.kind {
            AnchorKind::Stolen => {
                st.stolen_batches += 1;
                super::metrics::stolen(w);
            }
            AnchorKind::Hot => {
                st.hot_batches += 1;
                super::metrics::hot_hit(w);
            }
            AnchorKind::Home => {}
        }
        if super::dispatch(&sim, &mut cache, &corpora, sb.mb, &mut st.serve, w) {
            // A panic unwound through this worker's simulator and its
            // prepared sessions; both are suspect. Rebuild the shard's
            // whole failure domain from the cloneable recipe — fresh
            // simulator, evicted session cache (hit/miss totals kept) —
            // and keep serving. Only if even the rebuild fails does the
            // worker exit (surfaced by `run_sharded` as a worker error).
            sim = spec
                .build()
                .with_context(|| format!("shard {}: rebuild simulator after panic", w))?;
            cache.evict_all();
        }
        drop(sb.hold);
    }
    st.serve.expired = batcher.expired_count();
    let (hits, misses) = cache.stats();
    st.cache_hits = hits;
    st.cache_misses = misses;
    crate::debug!(
        "shard {}: {} batches ({} stolen, {} hot), {} ok, {} errors, {} sessions",
        w,
        st.serve.batches,
        st.stolen_batches,
        st.hot_batches,
        st.serve.ok,
        st.serve.errors,
        misses
    );
    Ok(st)
}
