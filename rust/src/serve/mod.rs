//! Dynamic micro-batching inference server over the native executor.
//!
//! The subsystem turns prepared quantized sessions into a shared,
//! batched, concurrently-driven service:
//!
//! * [`queue`] — bounded admission queue with reject-on-full
//!   backpressure, per-request deadlines, and earliest-deadline-first
//!   scheduling within each (model × quant) key;
//! * [`batcher`] — dynamic micro-batcher coalescing compatible requests
//!   (same model × quant config) into one batched forward within a
//!   configurable window / max batch;
//! * [`cache`] — prepared-session cache keyed by (model, quant config,
//!   executor, backend): weights converted/QDQ-prepared once per key;
//! * [`protocol`] — the line-delimited JSON request/response format of
//!   `repro serve` (specified operator-facing in `docs/serving.md`);
//! * [`metrics`] — the lock-free observability registry: counters,
//!   latency histograms and per-request trace spans, readable via the
//!   `stats` wire verb or `--stats-every` periodic snapshots;
//! * [`shard`] — the multi-worker pool: N threads, each owning its own
//!   simulator and session cache, coordinating through key holds with
//!   cross-shard stealing and optional hot-key replication;
//! * [`transport`] — the TCP socket front end (`repro serve --listen`):
//!   connection multiplexing into the shared admission queue, responses
//!   routed back per connection;
//! * [`loadgen`] — closed-loop multi-client load generator
//!   (`repro loadgen`) measuring tokens/sec, batch occupancy and
//!   latency percentiles, in-process or over TCP;
//! * [`faults`] — deterministic fault injection (seeded plans arming
//!   worker panics, forward delays and connection drops at named
//!   sites) driving the chaos suite in `tests/serve_faults.rs`.
//!
//! Failure domains: a panicking request is caught by worker
//! supervision ([`dispatch`] wraps the forward in `catch_unwind`),
//! blamed by re-running the batch singly, and quarantined with an
//! `internal_error` response; the worker rebuilds its simulator from
//! the cloneable [`shard::SimSpec`] and keeps serving. Graceful drain
//! (the `shutdown` wire verb, or stdin EOF) flips the admission queue
//! to a draining state that rejects new work with `shutting_down`,
//! serves what was admitted under `--drain-timeout`, and joins every
//! worker cleanly.
//!
//! Threading model: runtime sessions are deliberately **not** `Send`
//! (they hold `Rc` sticky inputs and a hoisted backend handle), so each
//! worker thread owns its [`Simulator`], its session cache and every
//! dispatch it performs; producers on other threads only touch the
//! admission queue and per-request response channels. Sharding scales
//! that model out instead of breaking it: replication of a hot key
//! means each shard independently prepares its own session for the key,
//! never that two threads share one. Within a worker, parallelism comes
//! from *inside* each batched forward — the coalesced `[B·T, d]`
//! matmuls and the per-(b, h) attention wave fan out across the pool
//! tensor backend.
//!
//! Determinism contract: per-request outputs are bit-identical across
//! batching configuration, worker count, shard assignment, stealing and
//! replication — `run_batch` already guarantees outputs independent of
//! batch composition, shards only move *where/when* a batch runs, and
//! replicated sessions are prepared by the same deterministic transform
//! from the same checkpoint. The serving tests assert exactly this.

#![warn(missing_docs)]

pub mod batcher;
pub mod cache;
pub mod faults;
pub mod loadgen;
pub mod metrics;
pub mod protocol;
pub mod queue;
pub mod shard;
pub mod transport;

use std::io::Write as IoWrite;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::corpus::{
    CodeCorpus, ImageCorpus, QaCorpus, TextCorpus, CODE_SEED, IMG_SEED, QA_SEED, TEXT_SEED,
};
use crate::quantsim::{QuantConfig, Simulator};
use crate::runtime::manifest::ModelCfg;
use crate::runtime::Val;
use crate::tensor::backend;

use batcher::{Batcher, MicroBatch};
use cache::{SessionCache, SessionKey};
use protocol::{codes, outputs_pool, summarize_into, Request, Response};
use queue::{AdmissionQueue, Job};
use shard::{ShardCfg, SimSpec};

/// Server tuning knobs (`--queue-cap`, `--batch-window`, `--max-batch`,
/// `--drain-timeout`, `--idle-timeout`, `--max-conns`).
#[derive(Debug, Clone)]
pub struct ServeCfg {
    /// Admission queue bound (reject-on-full backpressure).
    pub queue_cap: usize,
    /// How long a batch anchor waits for same-key company.
    pub batch_window: Duration,
    /// Micro-batch occupancy cap.
    pub max_batch: usize,
    /// How long a graceful drain waits for admitted jobs before
    /// flushing the leftovers with `shutting_down` (`--drain-timeout`).
    pub drain_timeout: Duration,
    /// TCP read timeout: a connection idle past it is reaped
    /// (`--idle-timeout`; `None` keeps idle connections forever).
    pub idle_timeout: Option<Duration>,
    /// Concurrent TCP connection cap; excess connections are answered
    /// with a retry-later `queue_full` line and closed (`--max-conns`;
    /// `None` is unlimited).
    pub max_conns: Option<usize>,
}

impl Default for ServeCfg {
    fn default() -> ServeCfg {
        ServeCfg {
            queue_cap: 64,
            batch_window: Duration::from_millis(5),
            max_batch: 8,
            drain_timeout: Duration::from_secs(5),
            idle_timeout: None,
            max_conns: None,
        }
    }
}

/// Aggregate counters of one worker's serve loop. `requests` counts
/// dispatched jobs; `expired` counts jobs answered with a deadline
/// error *before* dispatch (they never reach a batch), so the total
/// responses sent is `ok + errors + expired`.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Jobs dispatched into batches.
    pub requests: usize,
    /// Successful responses.
    pub ok: usize,
    /// Error responses (excluding pre-dispatch expiry).
    pub errors: usize,
    /// Jobs shed with a deadline error before dispatch.
    pub expired: usize,
    /// Micro-batches dispatched.
    pub batches: usize,
    /// Largest micro-batch occupancy seen.
    pub max_occupancy: usize,
}

impl ServeStats {
    /// Mean occupancy of the *dispatched* batches (expired-in-queue
    /// jobs never occupy a batch and are excluded).
    pub fn mean_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    /// Fold another worker's counters into this one (multi-shard
    /// aggregation; `max_occupancy` takes the max, the rest sum).
    pub fn absorb(&mut self, other: &ServeStats) {
        self.requests += other.requests;
        self.ok += other.ok;
        self.errors += other.errors;
        self.expired += other.expired;
        self.batches += other.batches;
        self.max_occupancy = self.max_occupancy.max(other.max_occupancy);
    }
}

/// The shared, deterministic request streams — one corpus per model
/// family, seeded exactly like evaluation, so request `batch` index `i`
/// always denotes the same payload.
pub(crate) struct Corpora {
    text: TextCorpus,
    code: CodeCorpus,
    qa: QaCorpus,
    image: ImageCorpus,
}

impl Corpora {
    pub(crate) fn new() -> Corpora {
        Corpora {
            text: TextCorpus::new(TEXT_SEED),
            code: CodeCorpus::new(CODE_SEED),
            qa: QaCorpus::new(QA_SEED),
            image: ImageCorpus::new(IMG_SEED),
        }
    }

    /// Build one request's data tensor: inline tokens if supplied,
    /// otherwise batch `index` of the family's deterministic stream.
    fn input_for(&self, cfg: &ModelCfg, req: &Request) -> Result<Val> {
        let (b, s) = (cfg.batch, cfg.seq);
        if let Some(toks) = &req.tokens {
            anyhow::ensure!(
                cfg.arch != "vit",
                "model {} takes images; inline tokens are not supported",
                cfg.name
            );
            anyhow::ensure!(
                toks.len() == b * s,
                "inline tokens: expected {}x{} = {} ids, got {}",
                b,
                s,
                b * s,
                toks.len()
            );
            anyhow::ensure!(
                toks.iter().all(|&t| (0..cfg.vocab as i32).contains(&t)),
                "inline tokens out of vocab range [0, {})",
                cfg.vocab
            );
            return Ok(Val::I32(toks.clone(), vec![b, s]));
        }
        let i = req.batch_index;
        Ok(match cfg.task.as_str() {
            "lm" => Val::I32(self.text.eval_batch(i, b, s).tokens, vec![b, s]),
            "codegen" => Val::I32(self.code.train_batch(i, b, s).tokens, vec![b, s]),
            "span_qa" => Val::I32(self.qa.eval_batch(i, b, s).tokens.tokens, vec![b, s]),
            "image_cls" => {
                let ib = self.image.eval_batch(i, b);
                Val::F32(ib.pixels, vec![b, cfg.image, cfg.image, cfg.channels])
            }
            other => anyhow::bail!("model {}: unknown task {}", cfg.name, other),
        })
    }
}

/// The cache identity of a prepared session under the process's CURRENT
/// executor + backend selection. Single constructor shared by dispatch
/// and the loadgen prewarm, so the two can never key differently (a
/// divergence would silently turn every prewarm into a cache miss).
pub(crate) fn session_key(sim: &Simulator, model: &str, quant: &str) -> SessionKey {
    SessionKey {
        model: model.to_string(),
        quant: quant.to_string(),
        executor: sim.rt.executor_name().to_string(),
        backend: backend::active().describe(),
    }
}

/// Answer `job` with `internal_error` and record it as quarantined: it
/// was identified as the trigger of a worker panic and must not be
/// retried (resubmitting the same line is expected to fail the same
/// way).
fn quarantine(job: &Job, stats: &mut ServeStats, shard: usize) {
    job.reply(Response::err(
        job.req.id,
        codes::INTERNAL_ERROR,
        "worker panicked executing this request; request quarantined",
    ));
    metrics::quarantined();
    metrics::request_error(shard);
    stats.errors += 1;
}

/// Run one micro-batch to completion: resolve the cached session, build
/// every request's input, drive `Session::run_batch`, and answer each
/// job (post-run deadline expiry becomes an error — never stale output).
/// `shard` attributes the batch in the metrics registry (0 for the
/// single-worker server).
///
/// **Supervision:** the batched forward runs under `catch_unwind`. If
/// it panics, the batch's requests are re-run singly on the same
/// session to isolate blame — only the request that still panics alone
/// is quarantined (`internal_error`); innocent batch-mates get their
/// normal responses. Returns `true` when a panic was recovered, which
/// tells the caller to rebuild its execution state (sessions — and in
/// the sharded server the whole simulator — may be tainted by the
/// unwind).
pub(crate) fn dispatch(
    sim: &Simulator,
    cache: &mut SessionCache,
    corpora: &Corpora,
    mb: MicroBatch,
    stats: &mut ServeStats,
    shard: usize,
) -> bool {
    stats.batches += 1;
    stats.requests += mb.jobs.len();
    stats.max_occupancy = stats.max_occupancy.max(mb.jobs.len());
    metrics::batch_dispatched(shard, mb.jobs.len());
    let popped = Instant::now();
    for job in &mb.jobs {
        // span stamps: enqueue→admit from the queue, admit→assemble
        // from the batcher (fall back to "now" for jobs that skipped
        // the batcher, e.g. hand-built test batches)
        let waited = popped.duration_since(job.enqueued).as_nanos() as u64;
        let assembled = if job.assemble_ns > 0 { job.assemble_ns } else { waited };
        metrics::record_span(metrics::SpanSlot::Admit, job.admit_ns);
        metrics::record_span(
            metrics::SpanSlot::Assemble,
            assembled.saturating_sub(job.admit_ns),
        );
        metrics::queue_wait(waited / 1_000);
    }

    let cfg = match sim.rt.manifest.model(&mb.key.model) {
        Ok(cfg) => cfg.clone(),
        Err(e) => {
            for job in &mb.jobs {
                job.reply(Response::err(
                    job.req.id,
                    codes::UNKNOWN_MODEL,
                    &format!("{:#}", e),
                ));
                metrics::request_error(shard);
            }
            stats.errors += mb.jobs.len();
            return false;
        }
    };

    let key = session_key(sim, &mb.key.model, &mb.key.quant);
    let sess = match cache.get_or_open(&key, || {
        sim.open_eval_session(&mb.key.model, &QuantConfig::abfp(&mb.key.quant))
    }) {
        Ok(sess) => sess,
        Err(e) => {
            for job in &mb.jobs {
                job.reply(Response::err(
                    job.req.id,
                    codes::OPEN_FAILED,
                    &format!("open session: {:#}", e),
                ));
                metrics::request_error(shard);
            }
            stats.errors += mb.jobs.len();
            return false;
        }
    };

    // Per-request input build: a malformed request fails alone, the
    // rest of the batch still runs.
    let mut jobs = Vec::with_capacity(mb.jobs.len());
    let mut frees: Vec<Vec<Val>> = Vec::with_capacity(mb.jobs.len());
    for job in mb.jobs {
        match corpora.input_for(&cfg, &job.req) {
            Ok(v) => {
                jobs.push(job);
                frees.push(vec![v]);
            }
            Err(e) => {
                job.reply(Response::err(job.req.id, codes::BAD_INPUT, &format!("{:#}", e)));
                metrics::request_error(shard);
                stats.errors += 1;
            }
        }
    }
    if jobs.is_empty() {
        return false;
    }

    let t0 = Instant::now();
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        // fault sites are single relaxed loads when no plan is armed
        faults::panic_on_poison(jobs.iter().map(|j| j.req.id));
        faults::forward_delay();
        // the timer scope lands in span_forward_ns via the active trace
        let _trace = metrics::trace(metrics::SpanSlot::Forward);
        let _scope = crate::util::timer::Scope::new("serve.forward");
        sess.run_batch(&frees)
    }));
    let run_ms = t0.elapsed().as_secs_f64() * 1e3;
    match result {
        Ok(Ok(outs)) => {
            let now = Instant::now();
            let n = jobs.len();
            for (job, out) in jobs.iter().zip(outs) {
                if job.expired(now) {
                    job.reply(Response::err(
                        job.req.id,
                        codes::DEADLINE_RUN,
                        "deadline expired during batched run",
                    ));
                    metrics::request_error(shard);
                    stats.errors += 1;
                    continue;
                }
                let queue_ms = popped.duration_since(job.enqueued).as_secs_f64() * 1e3;
                // recycled summary vector: filled in place here, put
                // back by the transport writer after serialization
                let mut outs = outputs_pool::take();
                summarize_into(&out, &mut outs);
                job.reply(Response::ok(job.req.id, outs, n, queue_ms, run_ms));
                metrics::request_ok(shard);
                stats.ok += 1;
            }
            false
        }
        Ok(Err(e)) => {
            for job in &jobs {
                job.reply(Response::err(
                    job.req.id,
                    codes::RUN_FAILED,
                    &format!("run: {:#}", e),
                ));
                metrics::request_error(shard);
            }
            stats.errors += jobs.len();
            false
        }
        Err(_) => {
            // The forward panicked. Supervision: recover the worker,
            // then isolate blame by re-running each request alone —
            // outputs are batch-composition-independent, so innocent
            // batch-mates answer bit-identically to a clean run.
            metrics::panic_recovered();
            crate::debug!(
                "serve: shard {} recovered a panic in a {}-request batch; re-running singly",
                shard,
                jobs.len()
            );
            if jobs.len() == 1 {
                quarantine(&jobs[0], stats, shard);
                return true;
            }
            for (job, free) in jobs.iter().zip(&frees) {
                let single_t0 = Instant::now();
                let single = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    // same fault site as the batch path: an injected
                    // poison request panics alone too, and is blamed
                    faults::panic_on_poison([job.req.id]);
                    sess.run_batch(std::slice::from_ref(free))
                }));
                let single_ms = single_t0.elapsed().as_secs_f64() * 1e3;
                match single {
                    Ok(Ok(outs)) => {
                        if job.expired(Instant::now()) {
                            job.reply(Response::err(
                                job.req.id,
                                codes::DEADLINE_RUN,
                                "deadline expired during batched run",
                            ));
                            metrics::request_error(shard);
                            stats.errors += 1;
                            continue;
                        }
                        let queue_ms =
                            popped.duration_since(job.enqueued).as_secs_f64() * 1e3;
                        let mut summary = outputs_pool::take();
                        if let Some(out) = outs.first() {
                            summarize_into(out, &mut summary);
                        }
                        job.reply(Response::ok(job.req.id, summary, 1, queue_ms, single_ms));
                        metrics::request_ok(shard);
                        stats.ok += 1;
                    }
                    Ok(Err(e)) => {
                        job.reply(Response::err(
                            job.req.id,
                            codes::RUN_FAILED,
                            &format!("run: {:#}", e),
                        ));
                        metrics::request_error(shard);
                        stats.errors += 1;
                    }
                    Err(_) => {
                        metrics::panic_recovered();
                        quarantine(job, stats, shard);
                    }
                }
            }
            true
        }
    }
}

/// The worker loop: drain the queue batch-by-batch until it is closed
/// and empty. Owns every session via `cache`; runs on the thread that
/// owns `sim`. The single-worker path — [`shard::run_sharded`] is its
/// N-worker twin.
pub fn serve_loop(
    sim: &Simulator,
    queue: &Arc<AdmissionQueue>,
    cfg: &ServeCfg,
    cache: &mut SessionCache,
) -> ServeStats {
    let batcher = Batcher::new(Arc::clone(queue), cfg.batch_window, cfg.max_batch);
    let corpora = Corpora::new();
    let mut stats = ServeStats::default();
    while let Some(mb) = batcher.next_batch() {
        if dispatch(sim, cache, &corpora, mb, &mut stats, 0) {
            // A recovered panic may have tainted cached sessions: drop
            // them all (the hit/miss counters survive) so the next
            // batch reopens cleanly from the simulator. The sharded
            // server goes further and rebuilds the simulator itself —
            // here it is borrowed, so eviction is the recovery unit.
            cache.evict_all();
        }
    }
    stats.expired = batcher.expired_count();
    stats
}

/// Spawn the drain supervisor: once the queue is draining, wait up to
/// `timeout` for admitted work to finish, flush whatever is left with
/// a `shutting_down` answer (no admitted request goes unanswered), and
/// close the queue so every worker exits its loop. Shared by the
/// `shutdown` wire verb (stdio and TCP fronts) and
/// [`transport::TcpServer::shutdown`].
pub(crate) fn spawn_drain(
    queue: Arc<AdmissionQueue>,
    timeout: Duration,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        if !queue.wait_drained(timeout) {
            for job in queue.flush_all() {
                job.reply(Response::err(
                    job.req.id,
                    codes::SHUTTING_DOWN,
                    "server drained before this request could run",
                ));
                metrics::request_error(0);
            }
        }
        queue.close();
    })
}

/// Spawn the stdin→queue reader and the queue→stdout writer shared by
/// both stdio front ends. The reader answers parse failures,
/// over-length lines and admission rejections (`queue_full` /
/// `shutting_down`, from the rejection's own reason) directly, flips
/// the queue into its draining state on a `shutdown` verb line, and
/// closes the queue at EOF. The writer exits on the internal drain
/// marker — sent by the front end *after* the worker loop finishes, so
/// every in-flight response is serialized before shutdown (the drain
/// path both fronts share). Both pumps run on the same reused-buffer
/// streaming path as the TCP transport: capped line reads (bounded
/// memory under an endless line),
/// [`protocol::parse_request_streaming`] into a scratch request,
/// [`Response::write_line`] into a reused write buffer.
fn spawn_stdio_pump(
    queue: &Arc<AdmissionQueue>,
    drain_timeout: Duration,
) -> (
    mpsc::Sender<Response>,
    std::thread::JoinHandle<()>,
    std::thread::JoinHandle<()>,
) {
    let (tx, rx) = mpsc::channel::<Response>();

    let writer = std::thread::spawn(move || {
        let stdout = std::io::stdout();
        let mut buf: Vec<u8> = Vec::with_capacity(256);
        for mut resp in rx {
            if protocol::is_drain_marker(&resp) {
                // everything sent before the marker is already written
                break;
            }
            if protocol::is_stats_marker(&resp) {
                // `stats` verb: answer with a registry snapshot line
                metrics::write_snapshot(&mut buf);
                buf.push(b'\n');
                let mut out = stdout.lock();
                let _ = out.write_all(&buf);
                let _ = out.flush();
                continue;
            }
            let t0 = Instant::now();
            resp.write_line(&mut buf);
            buf.push(b'\n');
            metrics::record_span(metrics::SpanSlot::Serialize, t0.elapsed().as_nanos() as u64);
            let mut out = stdout.lock();
            let _ = out.write_all(&buf);
            let _ = out.flush();
            outputs_pool::put(std::mem::take(&mut resp.outputs));
        }
    });

    let reader = {
        let queue = Arc::clone(queue);
        let tx = tx.clone();
        std::thread::spawn(move || {
            let stdin = std::io::stdin();
            let mut lock = stdin.lock();
            let mut line: Vec<u8> = Vec::with_capacity(256);
            let mut scratch = Request::default();
            let mut drain_started = false;
            loop {
                match transport::read_line_capped(
                    &mut lock,
                    &mut line,
                    protocol::MAX_LINE_BYTES,
                ) {
                    Ok(transport::LineRead::Eof) | Err(_) => break,
                    Ok(transport::LineRead::TooLong) => {
                        let _ = tx.send(transport::oversized_response());
                        continue;
                    }
                    Ok(transport::LineRead::Line) => {}
                }
                let bytes = transport::trim_ws(&line);
                if bytes.is_empty() {
                    continue;
                }
                if protocol::is_stats_request(bytes) {
                    let _ = tx.send(protocol::stats_marker());
                    continue;
                }
                if protocol::is_shutdown_request(bytes) {
                    // graceful drain: stop admitting, serve what was
                    // admitted (bounded by the drain timeout), close
                    queue.begin_drain();
                    let _ = tx.send(Response::err(
                        protocol::ERR_ID,
                        codes::SHUTTING_DOWN,
                        "draining: serving admitted work, then closing",
                    ));
                    if !drain_started {
                        drain_started = true;
                        let _ = spawn_drain(Arc::clone(&queue), drain_timeout);
                    }
                    continue;
                }
                match protocol::parse_request_streaming(bytes, &mut scratch) {
                    Ok(()) => {
                        let id = scratch.id;
                        if let Err(rej) = queue.try_push(Job::new(scratch.clone(), tx.clone()))
                        {
                            let _ = tx.send(Response::err(
                                id,
                                rej.reason.code(),
                                rej.reason.message(),
                            ));
                        }
                    }
                    Err(e) => {
                        // no parseable id to echo: the reserved ERR_ID
                        // cannot collide with a real request's id
                        let _ = tx.send(Response::err(
                            protocol::ERR_ID,
                            codes::BAD_REQUEST,
                            &format!("bad request: {:#}", e),
                        ));
                    }
                }
            }
            queue.close();
        })
    };

    (tx, reader, writer)
}

/// `repro serve`: the in-process server on stdin/stdout. A reader
/// thread parses request lines into the admission queue (answering
/// parse failures and queue-full rejections directly); a writer thread
/// serializes responses; the calling thread is the worker. Returns once
/// stdin reaches EOF and the queue has drained.
pub fn run_stdio(sim: &Simulator, cfg: &ServeCfg) -> Result<()> {
    let queue = AdmissionQueue::new(cfg.queue_cap);
    let (tx, reader, writer) = spawn_stdio_pump(&queue, cfg.drain_timeout);

    crate::info!(
        "serving on stdin/stdout: queue_cap={} batch_window={:?} max_batch={} \
         backend={} executor={}",
        cfg.queue_cap,
        cfg.batch_window,
        cfg.max_batch,
        backend::active().describe(),
        sim.rt.executor_name()
    );
    let mut cache = SessionCache::new();
    let stats = serve_loop(sim, &queue, cfg, &mut cache);
    // Drain handshake: every response was sent before the worker loop
    // returned, so the marker is ordered after all of them — the
    // writer serializes everything, then exits, even while a
    // `shutdown`-verb drain leaves the reader blocked on an open
    // stdin. Never exit before the writer has flushed.
    let _ = tx.send(protocol::drain_marker());
    drop(tx);
    let _ = writer.join();
    if reader.is_finished() {
        let _ = reader.join();
    }
    let (hits, misses) = cache.stats();
    crate::info!(
        "served {} requests in {} batches (ok {}, errors {}, expired-in-queue {}, \
         mean occupancy {:.2}, max {}); session cache: {} hits / {} misses",
        stats.requests,
        stats.batches,
        stats.ok,
        stats.errors,
        stats.expired,
        stats.mean_occupancy(),
        stats.max_occupancy,
        hits,
        misses
    );
    Ok(())
}

/// `repro serve --workers N` (no `--listen`): the sharded server on
/// stdin/stdout. Same pump as [`run_stdio`], but the calling thread
/// supervises an N-worker shard pool instead of serving itself.
pub fn run_stdio_sharded(spec: &SimSpec, cfg: &ServeCfg, shard_cfg: &ShardCfg) -> Result<()> {
    let queue = AdmissionQueue::new(cfg.queue_cap);
    let (tx, reader, writer) = spawn_stdio_pump(&queue, cfg.drain_timeout);

    crate::info!(
        "serving on stdin/stdout: workers={} replicate_hot={} queue_cap={} \
         batch_window={:?} max_batch={} backend={}",
        shard_cfg.workers,
        shard_cfg.replicate_hot,
        cfg.queue_cap,
        cfg.batch_window,
        cfg.max_batch,
        backend::active().describe()
    );
    // Do NOT `?` before the writer has flushed: a worker-pool error
    // must still let the final responses (including the pool's own
    // `run_failed` leftovers) reach stdout — bailing out first was
    // exactly the abortive-shutdown bug this path used to have.
    let pool_result = shard::run_sharded(spec, &queue, cfg, shard_cfg, &[]);
    let _ = tx.send(protocol::drain_marker());
    drop(tx);
    let _ = writer.join();
    if reader.is_finished() {
        let _ = reader.join();
    }
    let per_worker = pool_result?;
    let mut total = ServeStats::default();
    for w in &per_worker {
        total.absorb(&w.serve);
    }
    crate::info!(
        "served {} requests in {} batches across {} workers (ok {}, errors {}, \
         expired-in-queue {}, stolen {}, hot {})",
        total.requests,
        total.batches,
        per_worker.len(),
        total.ok,
        total.errors,
        total.expired,
        per_worker.iter().map(|w| w.stolen_batches).sum::<usize>(),
        per_worker.iter().map(|w| w.hot_batches).sum::<usize>()
    );
    Ok(())
}
