//! Deterministic fault injection for the serving plane.
//!
//! A [`FaultPlan`] arms up to three named fault sites compiled into the
//! serve stack:
//!
//! * **`worker_panic`** — a *poison request*: while a batch containing
//!   a matching request id is being executed, the worker panics just
//!   before the forward. Matching is a pure function of the request id
//!   (`id % N == seed % N`), so the same request panics every time it
//!   is tried — exactly the failure shape the supervision layer's
//!   blame isolation is built for (re-run singly, quarantine the one
//!   request that still panics).
//! * **`forward_delay`** — every Nth batched forward (phase-shifted by
//!   the seed) sleeps a configured number of milliseconds first,
//!   exercising deadline expiry and drain-timeout paths.
//! * **`conn_drop`** — every Nth request line read from a TCP
//!   connection (phase-shifted by the seed) kills that connection
//!   before the response can be written, exercising dead-connection
//!   response routing.
//!
//! The plan is **seeded and counter-based** — no wall clock, no RNG —
//! so a given (plan, traffic) pair fires the same faults on every run,
//! which is what lets `tests/serve_faults.rs` assert exact outcomes.
//! When no plan is installed every site is a single relaxed atomic
//! load: the zero-allocation hot path and the exact-count metric
//! assertions in `tests/serve.rs` are unaffected.
//!
//! Operators arm a plan with `--faults <spec>` or the
//! [`ENV_VAR`] environment variable; the spec grammar is
//! comma-separated `key=value` pairs:
//!
//! ```text
//! seed=2,panic=7,delay=3:25,drop=5
//! ```
//!
//! * `seed=N` (default 1) — the phase shift shared by every site;
//! * `panic=N` — poison requests are those with `id % N == seed % N`;
//! * `delay=N:MS` — every Nth forward sleeps `MS` milliseconds;
//! * `drop=N` — every Nth TCP request line drops its connection.
//!
//! All numbers must be integers ≥ 1; unknown keys and malformed values
//! are loud errors (mirroring the strict CLI flags).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{bail, Context, Result};

/// Environment variable consulted by [`init_from_env`]; same spec
/// grammar as the `--faults` flag (the flag wins when both are set).
pub const ENV_VAR: &str = "INTFPQSIM_FAULTS";

/// A parsed, seeded fault plan (see the module docs for the grammar
/// and the firing semantics of each site).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FaultPlan {
    /// Phase shift applied to every site's firing rule.
    pub seed: u64,
    /// `worker_panic`: poison modulus — requests with
    /// `id % n == seed % n` panic the worker serving them.
    pub panic_every: Option<u64>,
    /// `forward_delay`: delay every Nth batched forward.
    pub delay_every: Option<u64>,
    /// `forward_delay`: how long each injected delay sleeps.
    pub delay_ms: u64,
    /// `conn_drop`: drop the connection on every Nth request line.
    pub drop_every: Option<u64>,
}

impl FaultPlan {
    /// Parse a spec string (`seed=2,panic=7,delay=3:25,drop=5`).
    /// Every value must be an integer ≥ 1; unknown keys, empty pairs
    /// and malformed numbers are errors naming the offending part.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan { seed: 1, ..FaultPlan::default() };
        let mut any = false;
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                bail!("fault spec has an empty segment in {:?}", spec);
            }
            let (key, val) = part
                .split_once('=')
                .with_context(|| format!("fault spec segment {:?} is not key=value", part))?;
            match key {
                "seed" => plan.seed = fault_num(val, "seed")?,
                "panic" => plan.panic_every = Some(fault_num(val, "panic")?),
                "delay" => {
                    let (every, ms) = val.split_once(':').with_context(|| {
                        format!("delay value {:?} is not EVERY:MS (e.g. delay=3:25)", val)
                    })?;
                    plan.delay_every = Some(fault_num(every, "delay period")?);
                    plan.delay_ms = fault_num(ms, "delay ms")?;
                }
                "drop" => plan.drop_every = Some(fault_num(val, "drop")?),
                other => bail!("unknown fault site {:?} in spec {:?}", other, spec),
            }
            any = true;
        }
        if !any {
            bail!("empty fault spec");
        }
        Ok(plan)
    }

    /// Whether this plan arms at least one fault site.
    pub fn arms_anything(&self) -> bool {
        self.panic_every.is_some() || self.delay_every.is_some() || self.drop_every.is_some()
    }
}

fn fault_num(s: &str, what: &str) -> Result<u64> {
    let n: u64 = s
        .trim()
        .parse()
        .with_context(|| format!("fault {} must be an integer, got {:?}", what, s))?;
    anyhow::ensure!(n >= 1, "fault {} must be >= 1, got {}", what, n);
    Ok(n)
}

// Disarmed fast path: one relaxed load, nothing else.
static ARMED: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<FaultPlan>> = Mutex::new(None);
// Per-site traversal counters, reset on install so a test's firing
// schedule does not depend on what ran before it.
static DELAY_HITS: AtomicU64 = AtomicU64::new(0);
static DROP_HITS: AtomicU64 = AtomicU64::new(0);

/// Install `plan` process-wide and reset the site counters. Arms the
/// sites only if the plan actually configures one.
pub fn install(plan: FaultPlan) {
    DELAY_HITS.store(0, Ordering::Relaxed);
    DROP_HITS.store(0, Ordering::Relaxed);
    let armed = plan.arms_anything();
    *PLAN.lock().unwrap() = Some(plan);
    ARMED.store(armed, Ordering::Relaxed);
}

/// Disarm every site (tests call this between schedules).
pub fn clear() {
    ARMED.store(false, Ordering::Relaxed);
    *PLAN.lock().unwrap() = None;
}

/// The currently installed plan, if any.
pub fn active() -> Option<FaultPlan> {
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    *PLAN.lock().unwrap()
}

/// Install a plan from [`ENV_VAR`] if it is set; returns the installed
/// plan (an unset or empty variable installs nothing). A set-but-bad
/// spec is an error, never silently ignored.
pub fn init_from_env() -> Result<Option<FaultPlan>> {
    match std::env::var(ENV_VAR) {
        Ok(spec) if !spec.trim().is_empty() => {
            let plan = FaultPlan::parse(&spec)
                .with_context(|| format!("parse {} = {:?}", ENV_VAR, spec))?;
            install(plan);
            Ok(Some(plan))
        }
        _ => Ok(None),
    }
}

/// Seeded firing rule shared by the counter-based sites.
#[inline]
fn fires(k: u64, seed: u64, every: u64) -> bool {
    (k.wrapping_add(seed)) % every.max(1) == 0
}

/// `worker_panic` site predicate: is `id` a poison request under the
/// installed plan? Pure in the id, so a poison request panics every
/// time it is tried — including the supervised single re-run.
#[inline]
pub fn is_poison(id: u64) -> bool {
    let Some(plan) = active() else { return false };
    let Some(n) = plan.panic_every else { return false };
    id % n == plan.seed % n
}

/// `worker_panic` site: panic (caught by worker supervision) if any of
/// `ids` is a poison request. Called by the dispatcher just before the
/// batched forward.
#[inline]
pub fn panic_on_poison<I: IntoIterator<Item = u64>>(ids: I) {
    if !ARMED.load(Ordering::Relaxed) {
        return;
    }
    for id in ids {
        if is_poison(id) {
            panic!("fault injection: worker_panic on poison request {}", id);
        }
    }
}

/// `forward_delay` site: sleep before every Nth batched forward.
#[inline]
pub fn forward_delay() {
    if !ARMED.load(Ordering::Relaxed) {
        return;
    }
    let Some(plan) = active() else { return };
    let Some(every) = plan.delay_every else { return };
    let k = DELAY_HITS.fetch_add(1, Ordering::Relaxed);
    if fires(k, plan.seed, every) {
        std::thread::sleep(Duration::from_millis(plan.delay_ms));
    }
}

/// `conn_drop` site: should the transport kill this connection instead
/// of answering the request line it just read?
#[inline]
pub fn should_drop_conn() -> bool {
    if !ARMED.load(Ordering::Relaxed) {
        return false;
    }
    let Some(plan) = active() else { return false };
    let Some(every) = plan.drop_every else { return false };
    let k = DROP_HITS.fetch_add(1, Ordering::Relaxed);
    fires(k, plan.seed, every)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Lib tests share the process-global plan; serialize the ones that
    // install one.
    static SERIAL: Mutex<()> = Mutex::new(());

    fn lock() -> std::sync::MutexGuard<'static, ()> {
        SERIAL.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn parses_full_and_partial_specs() {
        let p = FaultPlan::parse("seed=2,panic=7,delay=3:25,drop=5").unwrap();
        assert_eq!(p.seed, 2);
        assert_eq!(p.panic_every, Some(7));
        assert_eq!(p.delay_every, Some(3));
        assert_eq!(p.delay_ms, 25);
        assert_eq!(p.drop_every, Some(5));
        let p = FaultPlan::parse("panic=4").unwrap();
        assert_eq!(p.seed, 1, "seed defaults to 1");
        assert!(p.arms_anything());
        assert!(!FaultPlan::parse("seed=9").unwrap().arms_anything());
    }

    #[test]
    fn rejects_zero_garbage_and_unknown_sites() {
        for bad in [
            "", "panic", "panic=0", "panic=x", "panic=-1", "panic=2.5", "seed=0", "delay=3",
            "delay=3:", "delay=0:5", "delay=3:0", "drop=", "explode=3", "panic=3,,drop=2",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "spec {:?} must be rejected", bad);
        }
    }

    #[test]
    fn poison_matching_is_pure_and_seed_shifted() {
        let _g = lock();
        install(FaultPlan::parse("seed=1,panic=4").unwrap());
        // poison iff id % 4 == 1
        assert!(is_poison(1));
        assert!(is_poison(5));
        assert!(!is_poison(2));
        assert!(is_poison(1), "pure: same id, same answer");
        install(FaultPlan::parse("seed=2,panic=4").unwrap());
        assert!(!is_poison(1), "a different seed shifts the poison set");
        assert!(is_poison(6));
        clear();
        assert!(!is_poison(6), "disarmed: nothing is poison");
    }

    #[test]
    fn drop_schedule_is_deterministic_per_install() {
        let _g = lock();
        install(FaultPlan::parse("seed=1,drop=3").unwrap());
        let a: Vec<bool> = (0..6).map(|_| should_drop_conn()).collect();
        install(FaultPlan::parse("seed=1,drop=3").unwrap());
        let b: Vec<bool> = (0..6).map(|_| should_drop_conn()).collect();
        assert_eq!(a, b, "install resets the counters: same schedule");
        assert_eq!(a.iter().filter(|&&d| d).count(), 2, "fires every 3rd line");
        clear();
        assert!(!should_drop_conn());
    }

    #[test]
    fn panic_site_panics_only_on_poison_batches() {
        let _g = lock();
        install(FaultPlan::parse("seed=1,panic=10").unwrap());
        panic_on_poison([2u64, 3, 4]); // no poison: returns normally
        let caught = std::panic::catch_unwind(|| panic_on_poison([2u64, 11, 4]));
        assert!(caught.is_err(), "id 11 (11 % 10 == 1) is poison");
        clear();
        panic_on_poison([11u64]); // disarmed: no-op
    }
}
