//! Host-side tensor math: the pieces GPTQ/SmoothQuant/RPTQ and the
//! calibrator need. The hot paths (`matmul`, `gram`, reductions) route
//! through the process-wide execution backend (`tensor::backend`):
//! scalar reference, cache-tiled, 4-lane SIMD-unrolled, row-partitioned
//! threads, or a persistent worker pool — all bit-exact for matmul/gram,
//! cross-checked in the backend parity tests, the cross-backend
//! conformance harness (`tests/backend_conformance.rs`) and against
//! naive loops here.

use super::backend;
use super::Tensor;

impl Tensor {
    /// C = A @ B for 2-D tensors (M,K) x (K,N), on the active backend.
    pub fn matmul(&self, b: &Tensor) -> Tensor {
        backend::active().matmul(self, b)
    }

    /// C = A @ B^T for 2-D tensors (M,K) x (N,K), on the active backend.
    /// Reads `b` row-major — bit-identical to
    /// `self.matmul(&b.transpose())` without materializing the
    /// transpose (the `Backend::matmul_t` contract).
    pub fn matmul_t(&self, b: &Tensor) -> Tensor {
        backend::active().matmul_t(self, b)
    }

    /// A^T @ A, the Gram/Hessian accumulator used by GPTQ (K,K from M,K),
    /// on the active backend.
    pub fn gram(&self) -> Tensor {
        backend::active().gram(self)
    }

    pub fn transpose(&self) -> Tensor {
        let (m, n) = self.dims2();
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::new(vec![n, m], out)
    }

    /// Per-column absolute max of a 2-D tensor -> (cols,).
    pub fn col_absmax(&self) -> Vec<f32> {
        let (m, n) = self.dims2();
        let mut out = vec![0.0f32; n];
        for i in 0..m {
            for (o, &v) in out.iter_mut().zip(self.row(i)) {
                let a = v.abs();
                if a > *o {
                    *o = a;
                }
            }
        }
        let _ = m;
        out
    }

    /// Per-row absolute max -> (rows,).
    pub fn row_absmax(&self) -> Vec<f32> {
        let (m, _) = self.dims2();
        (0..m)
            .map(|i| self.row(i).iter().fold(0.0f32, |a, &v| a.max(v.abs())))
            .collect()
    }

    pub fn absmax(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &v| a.max(v.abs()))
    }

    /// Elementwise multiply of each column j by s[j] (in place).
    pub fn scale_cols(&mut self, s: &[f32]) {
        let (m, n) = self.dims2();
        assert_eq!(s.len(), n);
        for i in 0..m {
            for (v, &sj) in self.row_mut(i).iter_mut().zip(s.iter()) {
                *v *= sj;
            }
        }
    }

    /// Elementwise multiply of each row i by s[i] (in place).
    pub fn scale_rows(&mut self, s: &[f32]) {
        let (m, _) = self.dims2();
        assert_eq!(s.len(), m);
        for i in 0..m {
            let si = s[i];
            for v in self.row_mut(i) {
                *v *= si;
            }
        }
    }

    /// Permute the columns: out[:, j] = self[:, perm[j]].
    pub fn permute_cols(&self, perm: &[usize]) -> Tensor {
        let (m, n) = self.dims2();
        assert_eq!(perm.len(), n);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let src = self.row(i);
            let dst = &mut out[i * n..(i + 1) * n];
            for (j, &p) in perm.iter().enumerate() {
                dst[j] = src[p];
            }
        }
        Tensor::new(vec![m, n], out)
    }

    /// Mean of squared elements (f64 reduction on the active backend).
    pub fn mean_sq(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        backend::active().sum_sq(&self.data) / self.data.len() as f64
    }

    /// Mean squared error against another tensor of the same shape.
    pub fn mse(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        if self.data.is_empty() {
            return 0.0;
        }
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum::<f64>()
            / self.data.len() as f64
    }
}

/// Cholesky decomposition (lower) of a symmetric positive-definite matrix,
/// with diagonal damping; used to invert the GPTQ Hessian.
pub fn cholesky(a: &Tensor) -> Option<Tensor> {
    let (n, n2) = a.dims2();
    assert_eq!(n, n2);
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.data[i * n + j] as f64;
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[i * n + i] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Some(Tensor::new(
        vec![n, n],
        l.into_iter().map(|v| v as f32).collect(),
    ))
}

/// Inverse of an SPD matrix via Cholesky (L L^T = A, then forward/back
/// substitution per unit column).
pub fn spd_inverse(a: &Tensor) -> Option<Tensor> {
    let (n, _) = a.dims2();
    let l = cholesky(a)?;
    // §Perf L3 iteration 1 (EXPERIMENTS.md): two structural fixes, both
    // bit-exact vs the naive solver —
    //  (a) forward solve L y = e_col: y[0..col] is exactly 0 (unit RHS,
    //      lower-triangular L), so start at i = col — halves the flops;
    //  (b) the back solve walked ld[k*n + i] at stride n; solve against a
    //      row-major transpose instead (same values, same op order).
    let ld: Vec<f64> = l.data.iter().map(|&v| v as f64).collect();
    let mut lt = vec![0.0f64; n * n]; // lt[i*n + k] = L[k, i]  (k >= i)
    for i in 0..n {
        for k in i..n {
            lt[i * n + k] = ld[k * n + i];
        }
    }
    // §Perf L3 iteration 3 (EXPERIMENTS.md): multi-RHS blocking.  The
    // solves are memory-bound (L is re-read per column), so process C=8
    // unit columns per sweep — each L row is loaded once and reused for
    // all 8 right-hand sides.  Per column the f64 operation sequence is
    // unchanged (the widened forward loop only adds exact-zero terms for
    // k < col_c), so the result is bit-identical to the one-column solver.
    const C: usize = 8;
    let mut inv = vec![0.0f64; n * n];
    let mut yb = vec![0.0f64; n * C];
    let mut xb = vec![0.0f64; n * C];
    let mut col0 = 0;
    while col0 < n {
        let cw = C.min(n - col0);
        // forward: L y_c = e_{col0+c}; y_c[i] = 0 for i < col0
        for v in yb[col0 * C..].iter_mut() {
            *v = 0.0;
        }
        let mut s = [0.0f64; C];
        for i in col0..n {
            for (c, sv) in s[..cw].iter_mut().enumerate() {
                *sv = if i == col0 + c { 1.0 } else { 0.0 };
            }
            let lrow = &ld[i * n + col0..i * n + i];
            for (k, lv) in lrow.iter().enumerate() {
                let yrow = &yb[(col0 + k) * C..(col0 + k) * C + cw];
                for (sv, yv) in s[..cw].iter_mut().zip(yrow) {
                    *sv -= lv * yv;
                }
            }
            let d = ld[i * n + i];
            for (c, sv) in s[..cw].iter().enumerate() {
                yb[i * C + c] = sv / d;
            }
        }
        // back: L^T x_c = y_c, row access through the transpose
        for i in (0..n).rev() {
            s[..cw].copy_from_slice(&yb[i * C..i * C + cw]);
            let trow = &lt[i * n + i + 1..(i + 1) * n];
            for (k, tv) in trow.iter().enumerate() {
                let xrow = &xb[(i + 1 + k) * C..(i + 1 + k) * C + cw];
                for (sv, xv) in s[..cw].iter_mut().zip(xrow) {
                    *sv -= tv * xv;
                }
            }
            let d = ld[i * n + i];
            for c in 0..cw {
                let v = s[c] / d;
                xb[i * C + c] = v;
                inv[i * n + col0 + c] = v;
            }
        }
        col0 += cw;
    }
    Some(Tensor::new(
        vec![n, n],
        inv.into_iter().map(|v| v as f32).collect(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.dims2();
        let (_, n) = b.dims2();
        let mut out = Tensor::zeros(vec![m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..k {
                    s += a.at2(i, p) * b.at2(p, j);
                }
                out.set2(i, j, s);
            }
        }
        out
    }

    #[test]
    fn matmul_matches_naive_property() {
        prop::check("matmul_vs_naive", 20, |rng| {
            let (m, k, n) = (1 + rng.below(12), 1 + rng.below(12), 1 + rng.below(12));
            let a = Tensor::new(vec![m, k], prop::heavy_vec(rng, m * k, 1.0));
            let b = Tensor::new(vec![k, n], prop::heavy_vec(rng, k * n, 1.0));
            let got = a.matmul(&b);
            let want = naive_matmul(&a, &b);
            for (g, w) in got.data.iter().zip(want.data.iter()) {
                prop_assert!(
                    (g - w).abs() <= 1e-3 * (1.0 + w.abs()),
                    "matmul mismatch {} vs {}",
                    g,
                    w
                );
            }
            Ok(())
        });
    }

    #[test]
    fn gram_equals_at_a() {
        prop::check("gram", 10, |rng| {
            let (m, k) = (1 + rng.below(10), 1 + rng.below(10));
            let a = Tensor::new(vec![m, k], prop::heavy_vec(rng, m * k, 1.0));
            let got = a.gram();
            let want = a.transpose().matmul(&a);
            for (g, w) in got.data.iter().zip(want.data.iter()) {
                prop_assert!((g - w).abs() <= 1e-3 * (1.0 + w.abs()), "gram mismatch");
            }
            Ok(())
        });
    }

    #[test]
    fn matmul_t_matches_transposed_matmul_bits() {
        prop::check("matmul_t_vs_transpose", 15, |rng| {
            let (m, k, n) = (1 + rng.below(10), 1 + rng.below(10), 1 + rng.below(10));
            let a = Tensor::new(vec![m, k], prop::heavy_vec(rng, m * k, 1.0));
            let b = Tensor::new(vec![n, k], prop::heavy_vec(rng, n * k, 1.0));
            let got = a.matmul_t(&b);
            let want = a.matmul(&b.transpose());
            prop_assert!(got.shape == want.shape, "shape");
            for (g, w) in got.data.iter().zip(want.data.iter()) {
                prop_assert!(g.to_bits() == w.to_bits(), "matmul_t {} vs {}", g, w);
            }
            Ok(())
        });
    }

    #[test]
    fn transpose_involution() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.transpose().transpose(), t);
    }

    #[test]
    fn absmax_helpers() {
        let t = Tensor::new(vec![2, 3], vec![1., -5., 3., -4., 2., 0.]);
        assert_eq!(t.col_absmax(), vec![4., 5., 3.]);
        assert_eq!(t.row_absmax(), vec![5., 4.]);
        assert_eq!(t.absmax(), 5.0);
    }

    #[test]
    fn permute_cols_roundtrip() {
        prop::check("permute_roundtrip", 10, |rng| {
            let (m, n) = (1 + rng.below(6), 2 + rng.below(8));
            let t = Tensor::new(vec![m, n], prop::heavy_vec(rng, m * n, 1.0));
            let mut perm: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut perm);
            let mut inv = vec![0usize; n];
            for (j, &p) in perm.iter().enumerate() {
                inv[p] = j;
            }
            let back = t.permute_cols(&perm).permute_cols(&inv);
            prop_assert!(back == t, "permute roundtrip failed");
            Ok(())
        });
    }

    #[test]
    fn scale_rows_cols() {
        let mut t = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        t.scale_cols(&[2.0, 0.5]);
        assert_eq!(t.data, vec![2., 1., 6., 2.]);
        t.scale_rows(&[1.0, 10.0]);
        assert_eq!(t.data, vec![2., 1., 60., 20.]);
    }

    #[test]
    fn spd_inverse_correct() {
        prop::check("spd_inverse", 10, |rng| {
            let n = 2 + rng.below(8);
            // A = B^T B + eps I is SPD
            let b = Tensor::new(vec![n + 2, n], prop::heavy_vec(rng, (n + 2) * n, 1.0));
            let mut a = b.gram();
            for i in 0..n {
                a.data[i * n + i] += 0.5;
            }
            let inv = spd_inverse(&a).expect("spd");
            let prod = a.matmul(&inv);
            for i in 0..n {
                for j in 0..n {
                    let want = if i == j { 1.0 } else { 0.0 };
                    prop_assert!(
                        (prod.at2(i, j) - want).abs() < 1e-2,
                        "A·A^-1 [{},{}] = {}",
                        i,
                        j,
                        prod.at2(i, j)
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn mse_and_mean_sq() {
        let a = Tensor::new(vec![1, 3], vec![1., 2., 3.]);
        let b = Tensor::new(vec![1, 3], vec![1., 0., 3.]);
        assert!((a.mse(&b) - 4.0 / 3.0).abs() < 1e-9);
        assert!((a.mean_sq() - 14.0 / 3.0).abs() < 1e-9);
    }
}
