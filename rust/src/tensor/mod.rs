//! Row-major f32 tensor substrate for host-side math (weight transforms,
//! calibration, metrics). Device math runs in the compiled HLO; this
//! exists for everything the coordinator computes itself.

pub mod backend;
pub mod io;
mod ops;

pub use ops::*;

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} vs data len {}",
            shape,
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![v; n] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Rows/cols of a 2-D tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.ndim(), 2, "expected 2-D, got {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    pub fn at2(&self, r: usize, c: usize) -> f32 {
        let (_, cols) = self.dims2();
        self.data[r * cols + c]
    }

    pub fn set2(&mut self, r: usize, c: usize, v: f32) {
        let cols = self.shape[1];
        self.data[r * cols + c] = v;
    }

    pub fn row(&self, r: usize) -> &[f32] {
        let (_, cols) = self.dims2();
        &self.data[r * cols..(r + 1) * cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let cols = self.shape[1];
        &mut self.data[r * cols..(r + 1) * cols]
    }

    pub fn reshape(mut self, shape: Vec<usize>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.dims2(), (2, 3));
        assert_eq!(t.at2(1, 2), 6.0);
        assert_eq!(t.row(0), &[1., 2., 3.]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0; 5]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).reshape(vec![3, 2]);
        assert_eq!(t.at2(2, 1), 6.0);
    }
}
