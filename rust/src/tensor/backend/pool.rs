//! Persistent worker-pool backend: the row-partitioned parallelism of
//! `threaded` without the per-call scoped-thread spawn.
//!
//! `threaded` pays an OS thread spawn + join per `matmul`/`gram`/
//! `par_map_f64` call, which dominates on the many-small-sites pattern
//! the calibrator produces (ROADMAP flagged exactly this). `Pool` spawns
//! its workers once, at construction; every call afterwards only pushes
//! closures onto a shared injector queue and wakes sleeping workers.
//!
//! Determinism contract — identical to `threaded`: `matmul` and `gram`
//! partition output rows and every output element is produced by one
//! worker running the shared scalar kernel, so results are bit-identical
//! to `scalar` (asserted by `tests/backend_conformance.rs`); `sum_sq`
//! combines fixed-chunk partials in ascending chunk order (deterministic,
//! <= 1e-5 relative vs scalar above the serial threshold).
//!
//! Nested fan-out (a pooled `par_map_f64` job that itself calls a pooled
//! `matmul`, as calibration -> gram does) cannot deadlock: a thread
//! waiting on its own batch *helps*, draining jobs from the injector
//! until its batch completes, so queued work always makes progress even
//! when every worker is blocked inside a nested wait.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::scalar;
use super::{Backend, PAR_MIN_LEN};
use crate::tensor::Tensor;

/// A lifetime-erased unit of work on the injector queue.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// A borrowed task in one batch (lifetime-bound to the caller's data).
type Task<'env> = Box<dyn FnOnce() + Send + 'env>;

/// The shared injector: a FIFO of jobs plus the worker wakeup signal.
struct Injector {
    queue: Mutex<InjectorState>,
    ready: Condvar,
}

struct InjectorState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

impl Injector {
    fn push(&self, job: Job) {
        self.queue.lock().unwrap().jobs.push_back(job);
        self.ready.notify_one();
    }

    fn try_pop(&self) -> Option<Job> {
        self.queue.lock().unwrap().jobs.pop_front()
    }

    /// Worker body: run jobs until shutdown is flagged *and* the queue
    /// has drained (never strands a batch someone is waiting on).
    fn worker_loop(&self) {
        loop {
            let job = {
                let mut st = self.queue.lock().unwrap();
                loop {
                    if let Some(j) = st.jobs.pop_front() {
                        break Some(j);
                    }
                    if st.shutdown {
                        break None;
                    }
                    st = self.ready.wait(st).unwrap();
                }
            };
            match job {
                Some(j) => j(),
                None => return,
            }
        }
    }
}

/// Completion tracking for one `run_batch` call.
struct BatchState {
    progress: Mutex<BatchProgress>,
    done: Condvar,
}

struct BatchProgress {
    pending: usize,
    /// First caught panic payload, re-raised to the batch owner so the
    /// original message survives (as it would under scoped threads).
    panic: Option<Box<dyn Any + Send + 'static>>,
}

/// Persistent worker pool implementing [`Backend`]. Workers are spawned
/// at construction and joined on drop (replacing the process-wide handle
/// via `configure`/`set_active` drops the old pool once idle).
pub struct Pool {
    injector: Arc<Injector>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

impl Pool {
    pub fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        let injector = Arc::new(Injector {
            queue: Mutex::new(InjectorState { jobs: VecDeque::new(), shutdown: false }),
            ready: Condvar::new(),
        });
        // A 1-thread pool runs every op on the serial path (the `t <= 1`
        // guards below), so a worker would idle forever — don't spawn one.
        let workers = if threads <= 1 {
            Vec::new()
        } else {
            (0..threads)
                .map(|i| {
                    let inj = Arc::clone(&injector);
                    std::thread::Builder::new()
                        .name(format!("intfpqsim-pool-{}", i))
                        .spawn(move || inj.worker_loop())
                        .expect("spawn pool worker")
                })
                .collect()
        };
        Pool { injector, workers, threads }
    }

    /// Run a batch of borrowing closures on the pool and block until all
    /// complete. The caller participates (helps drain the injector) while
    /// it waits — that is what makes nested batches deadlock-free.
    fn run_batch<'env>(&self, tasks: Vec<Task<'env>>) {
        let state = Arc::new(BatchState {
            progress: Mutex::new(BatchProgress { pending: tasks.len(), panic: None }),
            done: Condvar::new(),
        });
        for task in tasks {
            let st = Arc::clone(&state);
            let wrapped: Task<'env> = Box::new(move || {
                let result = catch_unwind(AssertUnwindSafe(task));
                let mut p = st.progress.lock().unwrap();
                p.pending -= 1;
                if let Err(payload) = result {
                    p.panic.get_or_insert(payload);
                }
                if p.pending == 0 {
                    st.done.notify_all();
                }
            });
            // SAFETY: `run_batch` does not return until `pending` reaches
            // zero, i.e. until every task has finished running, so no task
            // outlives the `'env` borrows it captures. Erasing the
            // lifetime only lets the job sit on the 'static injector queue
            // in the meantime (the standard scoped-pool technique).
            let wrapped = unsafe { std::mem::transmute::<Task<'env>, Job>(wrapped) };
            self.injector.push(wrapped);
        }
        loop {
            // Return as soon as OUR batch is done — before picking up any
            // foreign job, so a finished caller never rides out another
            // batch's long task.
            let mut p = state.progress.lock().unwrap();
            if p.pending == 0 {
                let panic = p.panic.take();
                drop(p);
                if let Some(payload) = panic {
                    resume_unwind(payload);
                }
                return;
            }
            drop(p);
            // Help: run queued jobs (ours or a nested batch's) instead of
            // sleeping while work is available.
            if let Some(job) = self.injector.try_pop() {
                job();
                continue;
            }
            // The timeout bounds the window of the benign race where the
            // last job completes between the try_pop miss and this wait.
            let p = state.progress.lock().unwrap();
            if p.pending > 0 {
                let (guard, _timeout) =
                    state.done.wait_timeout(p, Duration::from_micros(200)).unwrap();
                drop(guard);
            }
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut st = self.injector.queue.lock().unwrap();
            st.shutdown = true;
        }
        self.injector.ready.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Backend for Pool {
    fn name(&self) -> &'static str {
        "pool"
    }

    fn threads(&self) -> usize {
        self.threads
    }

    fn matmul(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.dims2();
        let (k2, n) = b.dims2();
        assert_eq!(k, k2, "matmul inner dim {} vs {}", k, k2);
        let mut out = vec![0.0f32; m * n];
        // Unlike `threaded` (whose fallback avoids OS thread spawns),
        // enqueueing on the pool costs microseconds, so few-row shapes
        // keep partial parallelism: clamp workers to rows rather than
        // dropping to serial. Serial only when there is nothing to split.
        let t = self.threads.min(m);
        if t <= 1 || n == 0 || k == 0 {
            scalar::matmul_rows(&a.data, &b.data, &mut out, k, n);
        } else {
            let rows_per = m.div_ceil(t);
            let (adata, bdata) = (&a.data[..], &b.data[..]);
            let mut tasks: Vec<Task<'_>> = Vec::with_capacity(t);
            for (ci, chunk) in out.chunks_mut(rows_per * n).enumerate() {
                let i0 = ci * rows_per;
                let rows = chunk.len() / n;
                let ablock = &adata[i0 * k..(i0 + rows) * k];
                tasks.push(Box::new(move || scalar::matmul_rows(ablock, bdata, chunk, k, n)));
            }
            self.run_batch(tasks);
        }
        Tensor::new(vec![m, n], out)
    }

    fn gram(&self, x: &Tensor) -> Tensor {
        let (m, k) = x.dims2();
        let mut out = vec![0.0f32; k * k];
        let t = self.threads.min(k);
        if t <= 1 || m == 0 {
            scalar::gram_rows(&x.data, m, k, 0, &mut out);
        } else {
            let rows_per = k.div_ceil(t);
            let xdata = &x.data[..];
            let mut tasks: Vec<Task<'_>> = Vec::with_capacity(t);
            for (ci, chunk) in out.chunks_mut(rows_per * k).enumerate() {
                let i0 = ci * rows_per;
                tasks.push(Box::new(move || scalar::gram_rows(xdata, m, k, i0, chunk)));
            }
            self.run_batch(tasks);
        }
        Tensor::new(vec![k, k], out)
    }

    fn axpy(&self, alpha: f32, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), y.len(), "axpy length mismatch");
        let t = self.threads;
        if t <= 1 || y.len() < PAR_MIN_LEN {
            scalar::axpy_range(alpha, x, y);
            return;
        }
        let chunk = y.len().div_ceil(t);
        let mut tasks: Vec<Task<'_>> = Vec::with_capacity(t);
        for (xc, yc) in x.chunks(chunk).zip(y.chunks_mut(chunk)) {
            tasks.push(Box::new(move || scalar::axpy_range(alpha, xc, yc)));
        }
        self.run_batch(tasks);
    }

    fn sum_sq(&self, x: &[f32]) -> f64 {
        let t = self.threads;
        if t <= 1 || x.len() < PAR_MIN_LEN {
            return scalar::sum_sq_range(x);
        }
        let chunk = x.len().div_ceil(t);
        let mut partials = vec![0.0f64; x.len().div_ceil(chunk)];
        let mut tasks: Vec<Task<'_>> = Vec::with_capacity(t);
        for (xc, p) in x.chunks(chunk).zip(partials.iter_mut()) {
            tasks.push(Box::new(move || *p = scalar::sum_sq_range(xc)));
        }
        self.run_batch(tasks);
        partials.iter().sum()
    }

    fn par_map_f64(&self, n: usize, f: &(dyn Fn(usize) -> f64 + Sync)) -> Vec<f64> {
        let t = self.threads.min(n.max(1));
        if t <= 1 {
            return (0..n).map(f).collect();
        }
        let mut out = vec![0.0f64; n];
        let chunk = n.div_ceil(t);
        let mut tasks: Vec<Task<'_>> = Vec::with_capacity(t);
        for (ci, oc) in out.chunks_mut(chunk).enumerate() {
            tasks.push(Box::new(move || {
                for (j, slot) in oc.iter_mut().enumerate() {
                    *slot = f(ci * chunk + j);
                }
            }));
        }
        self.run_batch(tasks);
        out
    }

    fn par_map_tensor(&self, n: usize, f: &(dyn Fn(usize) -> Tensor + Sync)) -> Vec<Tensor> {
        let t = self.threads.min(n.max(1));
        if t <= 1 {
            return (0..n).map(f).collect();
        }
        let mut out: Vec<Option<Tensor>> = (0..n).map(|_| None).collect();
        let chunk = n.div_ceil(t);
        let mut tasks: Vec<Task<'_>> = Vec::with_capacity(n.div_ceil(chunk));
        for (ci, oc) in out.chunks_mut(chunk).enumerate() {
            tasks.push(Box::new(move || {
                for (j, slot) in oc.iter_mut().enumerate() {
                    *slot = Some(f(ci * chunk + j));
                }
            }));
        }
        self.run_batch(tasks);
        out.into_iter().map(|t| t.expect("par_map_tensor slot filled")).collect()
    }

    fn par_chunks_f32(
        &self,
        data: &mut [f32],
        chunk: usize,
        f: &(dyn Fn(usize, &mut [f32]) + Sync),
    ) {
        let c = chunk.max(1);
        let n_chunks = data.len().div_ceil(c);
        if self.threads <= 1 || n_chunks <= 1 {
            for (ci, piece) in data.chunks_mut(c).enumerate() {
                f(ci * c, piece);
            }
            return;
        }
        // Same span grouping as `threaded`: at most `threads` queued
        // tasks, each running its chunks serially — pieces (and so
        // results) are bit-identical to the serial loop.
        let per_span = n_chunks.div_ceil(self.threads) * c;
        let mut tasks: Vec<Task<'_>> = Vec::with_capacity(data.len().div_ceil(per_span));
        for (si, span) in data.chunks_mut(per_span).enumerate() {
            tasks.push(Box::new(move || {
                for (cj, piece) in span.chunks_mut(c).enumerate() {
                    f(si * per_span + cj * c, piece);
                }
            }));
        }
        self.run_batch(tasks);
    }
}
