//! Persistent worker-pool backend: the row-partitioned parallelism of
//! `threaded` without the per-call scoped-thread spawn, on per-worker
//! **work-stealing deques**.
//!
//! `threaded` pays an OS thread spawn + join per `matmul`/`gram`/
//! `par_map_f64` call, which dominates on the many-small-sites pattern
//! the calibrator produces (ROADMAP flagged exactly this). `Pool` spawns
//! its workers once, at construction; every call afterwards only places
//! closures on the worker deques and wakes sleeping workers.
//!
//! The original design used ONE shared injector queue: every push and
//! every pop crossed the same mutex, which serializes queue traffic at
//! high core counts (the second ROADMAP contention item). Now each
//! worker owns a deque; `run_batch` sprays its tasks round-robin across
//! them, a worker pops from its **own** deque first (one uncontended
//! lock in the common case) and steals oldest-first from a sibling only
//! when it runs dry — the pop side, where workers hammer the queue,
//! no longer shares a lock. (Pushes still pass through the global
//! `sleep` mutex, but only as an empty-critical-section handshake that
//! makes the sleep/wake protocol lost-wakeup-free; they do no work
//! under it.) Task placement has no effect on results: tasks write
//! disjoint output ranges and every output element is produced by the
//! same serial kernel regardless of which worker runs it.
//!
//! Determinism contract — identical to `threaded`: `matmul`/`matmul_t`/
//! `qdq_matmul_t` and `gram` partition output rows and every output
//! element is produced by one worker running the shared simd row kernel
//! (itself bit-identical to scalar on every op), so results are
//! bit-identical to `scalar` (asserted by `tests/backend_conformance.rs`);
//! `sum_sq` combines fixed-chunk partials in ascending chunk order
//! (deterministic, <= 1e-5 relative vs scalar above the serial
//! threshold).
//!
//! Nested fan-out (a pooled `par_map_f64` job that itself calls a pooled
//! `matmul`, as calibration -> gram does) cannot deadlock: a thread
//! waiting on its own batch *helps*, draining jobs from the deques
//! until its batch completes, so queued work always makes progress even
//! when every worker is blocked inside a nested wait.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::{simd, Backend, PAR_MIN_LEN};
use crate::tensor::Tensor;

/// A lifetime-erased unit of work on a worker deque.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// A borrowed task in one batch (lifetime-bound to the caller's data).
type Task<'env> = Box<dyn FnOnce() + Send + 'env>;

/// Shared pool state: one deque per worker plus the sleep machinery.
struct Shared {
    deques: Vec<Mutex<VecDeque<Job>>>,
    /// Upper bound on the jobs queued across all deques: incremented
    /// BEFORE the job lands in a deque, decremented after a successful
    /// pop — so it can read high transiently (a pusher mid-flight) but
    /// never underflows. A worker that found every deque empty re-checks
    /// it under the `sleep` lock before blocking; a pusher passes
    /// through that same lock (empty critical section) before notifying,
    /// so the classic lost-wakeup race (push lands between a worker's
    /// last scan and its wait) cannot happen and idle workers can sleep
    /// on a plain untimed `wait`.
    queued: AtomicUsize,
    /// Guards the shutdown flag and serializes the sleep/wake handshake.
    sleep: Mutex<bool>,
    ready: Condvar,
}

impl Shared {
    fn push(&self, slot: usize, job: Job) {
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.deques[slot % self.deques.len()].lock().unwrap().push_back(job);
        // Sleep handshake: a sleeper holds `sleep` from its queued
        // re-check until `wait` releases it, so by blocking here (empty
        // critical section) we cannot notify in that gap — either the
        // sleeper saw our increment, or it is already waiting and the
        // notify lands.
        drop(self.sleep.lock().unwrap());
        self.ready.notify_one();
    }

    /// Pop a job, preferring `home`'s own deque (newest first — its
    /// operands are the hottest), then stealing oldest-first from the
    /// other deques in ring order.
    fn pop(&self, home: usize) -> Option<Job> {
        let t = self.deques.len();
        let home = home % t;
        if let Some(j) = self.deques[home].lock().unwrap().pop_back() {
            self.queued.fetch_sub(1, Ordering::SeqCst);
            return Some(j);
        }
        for off in 1..t {
            let victim = (home + off) % t;
            if let Some(j) = self.deques[victim].lock().unwrap().pop_front() {
                self.queued.fetch_sub(1, Ordering::SeqCst);
                return Some(j);
            }
        }
        None
    }

    /// Worker body: run jobs until shutdown is flagged *and* every
    /// deque has drained (never strands a batch someone is waiting on).
    fn worker_loop(&self, id: usize) {
        loop {
            if let Some(job) = self.pop(id) {
                job();
                continue;
            }
            let guard = self.sleep.lock().unwrap();
            if self.queued.load(Ordering::SeqCst) > 0 {
                continue; // work appeared (or is landing) — rescan
            }
            if *guard {
                return; // shutdown, and every deque is drained
            }
            // Untimed: safe because a pusher increments `queued` before
            // enqueueing and passes through `sleep` before notifying —
            // it cannot slip into the window between the re-check above
            // and this wait. Idle workers therefore sleep for real (no
            // periodic polling).
            let _ = self.ready.wait(guard).unwrap();
        }
    }
}

/// Completion tracking for one `run_batch` call.
struct BatchState {
    progress: Mutex<BatchProgress>,
    done: Condvar,
}

struct BatchProgress {
    pending: usize,
    /// First caught panic payload, re-raised to the batch owner so the
    /// original message survives (as it would under scoped threads).
    panic: Option<Box<dyn Any + Send + 'static>>,
}

/// Persistent worker pool implementing [`Backend`]. Workers are spawned
/// at construction and joined on drop (replacing the process-wide handle
/// via `configure`/`set_active` drops the old pool once idle).
pub struct Pool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
    /// Round-robin cursor for spraying batch tasks across the deques.
    rr: AtomicUsize,
}

impl Pool {
    pub fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            deques: (0..threads).map(|_| Mutex::new(VecDeque::new())).collect(),
            queued: AtomicUsize::new(0),
            sleep: Mutex::new(false),
            ready: Condvar::new(),
        });
        // A 1-thread pool runs every op on the serial path (the `t <= 1`
        // guards below), so a worker would idle forever — don't spawn one.
        let workers = if threads <= 1 {
            Vec::new()
        } else {
            (0..threads)
                .map(|i| {
                    let sh = Arc::clone(&shared);
                    std::thread::Builder::new()
                        .name(format!("intfpqsim-pool-{}", i))
                        .spawn(move || sh.worker_loop(i))
                        .expect("spawn pool worker")
                })
                .collect()
        };
        Pool { shared, workers, threads, rr: AtomicUsize::new(0) }
    }

    /// Run a batch of borrowing closures on the pool and block until all
    /// complete. The caller participates (helps drain the deques) while
    /// it waits — that is what makes nested batches deadlock-free.
    fn run_batch<'env>(&self, tasks: Vec<Task<'env>>) {
        let state = Arc::new(BatchState {
            progress: Mutex::new(BatchProgress { pending: tasks.len(), panic: None }),
            done: Condvar::new(),
        });
        let base = self.rr.fetch_add(tasks.len().max(1), Ordering::Relaxed);
        for (ti, task) in tasks.into_iter().enumerate() {
            let st = Arc::clone(&state);
            let wrapped: Task<'env> = Box::new(move || {
                let result = catch_unwind(AssertUnwindSafe(task));
                let mut p = st.progress.lock().unwrap();
                p.pending -= 1;
                if let Err(payload) = result {
                    p.panic.get_or_insert(payload);
                }
                if p.pending == 0 {
                    st.done.notify_all();
                }
            });
            // SAFETY: `run_batch` does not return until `pending` reaches
            // zero, i.e. until every task has finished running, so no task
            // outlives the `'env` borrows it captures. Erasing the
            // lifetime only lets the job sit on the 'static deques in the
            // meantime (the standard scoped-pool technique).
            let wrapped = unsafe { std::mem::transmute::<Task<'env>, Job>(wrapped) };
            self.shared.push(base + ti, wrapped);
        }
        loop {
            // Return as soon as OUR batch is done — before picking up any
            // foreign job, so a finished caller never rides out another
            // batch's long task.
            let mut p = state.progress.lock().unwrap();
            if p.pending == 0 {
                let panic = p.panic.take();
                drop(p);
                if let Some(payload) = panic {
                    resume_unwind(payload);
                }
                return;
            }
            drop(p);
            // Help: run queued jobs (ours or a nested batch's) instead of
            // sleeping while work is available.
            if let Some(job) = self.shared.pop(base) {
                job();
                continue;
            }
            // The timeout bounds the window of the benign race where the
            // last job completes between the pop miss and this wait.
            let p = state.progress.lock().unwrap();
            if p.pending > 0 {
                let (guard, _timeout) =
                    state.done.wait_timeout(p, Duration::from_micros(200)).unwrap();
                drop(guard);
            }
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut g = self.shared.sleep.lock().unwrap();
            *g = true;
        }
        self.shared.ready.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Backend for Pool {
    fn name(&self) -> &'static str {
        "pool"
    }

    fn threads(&self) -> usize {
        self.threads
    }

    fn qdq_panel_rows(&self) -> usize {
        self.threads
    }

    fn matmul(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.dims2();
        let (k2, n) = b.dims2();
        assert_eq!(k, k2, "matmul inner dim {} vs {}", k, k2);
        let mut out = vec![0.0f32; m * n];
        // Unlike `threaded` (whose fallback avoids OS thread spawns),
        // enqueueing on the pool costs microseconds, so few-row shapes
        // keep partial parallelism: clamp workers to rows rather than
        // dropping to serial. Serial only when there is nothing to split.
        let t = self.threads.min(m);
        if t <= 1 || n == 0 || k == 0 {
            simd::matmul_rows(&a.data, &b.data, &mut out, k, n);
        } else {
            let rows_per = m.div_ceil(t);
            let (adata, bdata) = (&a.data[..], &b.data[..]);
            let mut tasks: Vec<Task<'_>> = Vec::with_capacity(t);
            for (ci, chunk) in out.chunks_mut(rows_per * n).enumerate() {
                let i0 = ci * rows_per;
                let rows = chunk.len() / n;
                let ablock = &adata[i0 * k..(i0 + rows) * k];
                tasks.push(Box::new(move || simd::matmul_rows(ablock, bdata, chunk, k, n)));
            }
            self.run_batch(tasks);
        }
        Tensor::new(vec![m, n], out)
    }

    fn matmul_t(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.dims2();
        let (n, k2) = b.dims2();
        assert_eq!(k, k2, "matmul_t inner dim {} vs {}", k, k2);
        let mut out = vec![0.0f32; m * n];
        let t = self.threads.min(m);
        if t <= 1 || n == 0 || k == 0 {
            simd::matmul_t_rows(&a.data, &b.data, &mut out, k, n);
        } else {
            let rows_per = m.div_ceil(t);
            let (adata, bdata) = (&a.data[..], &b.data[..]);
            let mut tasks: Vec<Task<'_>> = Vec::with_capacity(t);
            for (ci, chunk) in out.chunks_mut(rows_per * n).enumerate() {
                let i0 = ci * rows_per;
                let rows = chunk.len() / n;
                let ablock = &adata[i0 * k..(i0 + rows) * k];
                tasks.push(Box::new(move || simd::matmul_t_rows(ablock, bdata, chunk, k, n)));
            }
            self.run_batch(tasks);
        }
        Tensor::new(vec![m, n], out)
    }

    fn qdq_matmul_t(&self, x: &Tensor, prep: &(dyn Fn(&mut [f32]) + Sync), w: &Tensor) -> Tensor {
        let (m, k) = x.dims2();
        let (n, k2) = w.dims2();
        assert_eq!(k, k2, "qdq_matmul_t inner dim {} vs {}", k, k2);
        let mut out = vec![0.0f32; m * n];
        let t = self.threads.min(m);
        if t <= 1 || n == 0 || k == 0 {
            simd::qdq_matmul_t_rows(&x.data, prep, &w.data, &mut out, k, n);
        } else {
            // Row partition: each worker preps its own rows (every row
            // exactly once) into its own k-panel — peak temporary
            // footprint is `t` panels, never the full (m, k) copy.
            let rows_per = m.div_ceil(t);
            let (xdata, wdata) = (&x.data[..], &w.data[..]);
            let mut tasks: Vec<Task<'_>> = Vec::with_capacity(t);
            for (ci, chunk) in out.chunks_mut(rows_per * n).enumerate() {
                let i0 = ci * rows_per;
                let rows = chunk.len() / n;
                let xblock = &xdata[i0 * k..(i0 + rows) * k];
                tasks.push(Box::new(move || {
                    simd::qdq_matmul_t_rows(xblock, prep, wdata, chunk, k, n)
                }));
            }
            self.run_batch(tasks);
        }
        Tensor::new(vec![m, n], out)
    }

    fn int_matmul_t(
        &self,
        xq: &[i8],
        x_scales: &[f32],
        wq: &super::QuantPanel,
        w_scales: &[f32],
    ) -> Tensor {
        let (n, k) = (wq.n, wq.k);
        let m = x_scales.len();
        assert_eq!(xq.len(), m * k, "int_matmul_t xq len {} vs {}x{}", xq.len(), m, k);
        assert_eq!(w_scales.len(), n, "int_matmul_t w_scales len {} vs {}", w_scales.len(), n);
        let mut out = vec![0.0f32; m * n];
        // Same row-partition-over-the-deques shape as `matmul_t`: clamp
        // workers to rows (enqueues are cheap), serial only when there
        // is nothing to split. Each task owns a disjoint C row block and
        // the matching activation-scale slice; placement cannot affect
        // the exact integer accumulation.
        let t = self.threads.min(m);
        if t <= 1 || n == 0 || k == 0 {
            simd::int_matmul_t_rows(xq, x_scales, &wq.q, w_scales, &mut out, k, n);
        } else {
            let rows_per = m.div_ceil(t);
            let wdata = &wq.q[..];
            let mut tasks: Vec<Task<'_>> = Vec::with_capacity(t);
            for (ci, chunk) in out.chunks_mut(rows_per * n).enumerate() {
                let i0 = ci * rows_per;
                let rows = chunk.len() / n;
                let xblock = &xq[i0 * k..(i0 + rows) * k];
                let sblock = &x_scales[i0..i0 + rows];
                tasks.push(Box::new(move || {
                    simd::int_matmul_t_rows(xblock, sblock, wdata, w_scales, chunk, k, n)
                }));
            }
            self.run_batch(tasks);
        }
        Tensor::new(vec![m, n], out)
    }

    fn gram(&self, x: &Tensor) -> Tensor {
        let (m, k) = x.dims2();
        let mut out = vec![0.0f32; k * k];
        let t = self.threads.min(k);
        if t <= 1 || m == 0 {
            simd::gram_rows(&x.data, m, k, 0, &mut out);
        } else {
            let rows_per = k.div_ceil(t);
            let xdata = &x.data[..];
            let mut tasks: Vec<Task<'_>> = Vec::with_capacity(t);
            for (ci, chunk) in out.chunks_mut(rows_per * k).enumerate() {
                let i0 = ci * rows_per;
                tasks.push(Box::new(move || simd::gram_rows(xdata, m, k, i0, chunk)));
            }
            self.run_batch(tasks);
        }
        Tensor::new(vec![k, k], out)
    }

    fn axpy(&self, alpha: f32, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), y.len(), "axpy length mismatch");
        let t = self.threads;
        if t <= 1 || y.len() < PAR_MIN_LEN {
            simd::axpy_lanes(alpha, x, y);
            return;
        }
        let chunk = y.len().div_ceil(t);
        let mut tasks: Vec<Task<'_>> = Vec::with_capacity(t);
        for (xc, yc) in x.chunks(chunk).zip(y.chunks_mut(chunk)) {
            tasks.push(Box::new(move || simd::axpy_lanes(alpha, xc, yc)));
        }
        self.run_batch(tasks);
    }

    fn sum_sq(&self, x: &[f32]) -> f64 {
        let t = self.threads;
        if t <= 1 || x.len() < PAR_MIN_LEN {
            return simd::sum_sq_lanes(x);
        }
        let chunk = x.len().div_ceil(t);
        let mut partials = vec![0.0f64; x.len().div_ceil(chunk)];
        let mut tasks: Vec<Task<'_>> = Vec::with_capacity(t);
        for (xc, p) in x.chunks(chunk).zip(partials.iter_mut()) {
            tasks.push(Box::new(move || *p = simd::sum_sq_lanes(xc)));
        }
        self.run_batch(tasks);
        partials.iter().sum()
    }

    fn par_map_f64(&self, n: usize, f: &(dyn Fn(usize) -> f64 + Sync)) -> Vec<f64> {
        let t = self.threads.min(n.max(1));
        if t <= 1 {
            return (0..n).map(f).collect();
        }
        let mut out = vec![0.0f64; n];
        let chunk = n.div_ceil(t);
        let mut tasks: Vec<Task<'_>> = Vec::with_capacity(t);
        for (ci, oc) in out.chunks_mut(chunk).enumerate() {
            tasks.push(Box::new(move || {
                for (j, slot) in oc.iter_mut().enumerate() {
                    *slot = f(ci * chunk + j);
                }
            }));
        }
        self.run_batch(tasks);
        out
    }

    fn par_map_tensor(&self, n: usize, f: &(dyn Fn(usize) -> Tensor + Sync)) -> Vec<Tensor> {
        let t = self.threads.min(n.max(1));
        if t <= 1 {
            return (0..n).map(f).collect();
        }
        let mut out: Vec<Option<Tensor>> = (0..n).map(|_| None).collect();
        let chunk = n.div_ceil(t);
        let mut tasks: Vec<Task<'_>> = Vec::with_capacity(n.div_ceil(chunk));
        for (ci, oc) in out.chunks_mut(chunk).enumerate() {
            tasks.push(Box::new(move || {
                for (j, slot) in oc.iter_mut().enumerate() {
                    *slot = Some(f(ci * chunk + j));
                }
            }));
        }
        self.run_batch(tasks);
        out.into_iter().map(|t| t.expect("par_map_tensor slot filled")).collect()
    }

    fn par_chunks_f32(
        &self,
        data: &mut [f32],
        chunk: usize,
        f: &(dyn Fn(usize, &mut [f32]) + Sync),
    ) {
        let c = chunk.max(1);
        let n_chunks = data.len().div_ceil(c);
        if self.threads <= 1 || n_chunks <= 1 {
            for (ci, piece) in data.chunks_mut(c).enumerate() {
                f(ci * c, piece);
            }
            return;
        }
        // Same span grouping as `threaded`: at most `threads` queued
        // tasks, each running its chunks serially — pieces (and so
        // results) are bit-identical to the serial loop.
        let per_span = n_chunks.div_ceil(self.threads) * c;
        let mut tasks: Vec<Task<'_>> = Vec::with_capacity(data.len().div_ceil(per_span));
        for (si, span) in data.chunks_mut(per_span).enumerate() {
            tasks.push(Box::new(move || {
                for (cj, piece) in span.chunks_mut(c).enumerate() {
                    f(si * per_span + cj * c, piece);
                }
            }));
        }
        self.run_batch(tasks);
    }
}
