//! Cache-tiled backend: identical arithmetic to `scalar`, reordered for
//! locality.
//!
//! Tiling only regroups *which output elements* are visited when; for any
//! single output element the sequence of fused `+= a*b` updates still
//! runs in ascending reduction order, so results are bit-identical to the
//! scalar reference (asserted by the parity property tests).

use super::scalar::{self, GRAM_RB};
use super::{simd, Backend};
use crate::tensor::Tensor;

/// Column-tile width of the C/B panels (f32 elements).
const JB: usize = 256;
/// Depth-tile height: a PB x JB panel of B is 128 KiB, L2-resident.
const PB: usize = 128;
/// B-row tile of `matmul_t`: a TBT x k panel of B (k up to a few
/// thousand f32) stays L2-resident while every A row is swept past it.
const TBT: usize = 16;
/// A-row panel height of the fused `qdq_matmul_t`: `prep` runs once per
/// row into an RBQ x k scratch, then each B row is loaded once and
/// reused across the whole panel.
const RBQ: usize = 8;

pub struct Blocked;

impl Backend for Blocked {
    fn name(&self) -> &'static str {
        "blocked"
    }

    fn matmul(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.dims2();
        let (k2, n) = b.dims2();
        assert_eq!(k, k2, "matmul inner dim {} vs {}", k, k2);
        let mut out = vec![0.0f32; m * n];
        // jt outer, pt middle, i inner: the (PB, JB) panel of B stays hot
        // across all M rows; per (i, j) the p-reduction stays ascending.
        let mut j0 = 0;
        while j0 < n {
            let jend = (j0 + JB).min(n);
            let mut p0 = 0;
            while p0 < k {
                let pend = (p0 + PB).min(k);
                for i in 0..m {
                    let arow = &a.data[i * k..(i + 1) * k];
                    let crow = &mut out[i * n + j0..i * n + jend];
                    for (p, &av) in arow[p0..pend].iter().enumerate() {
                        if av == 0.0 {
                            continue;
                        }
                        let brow = &b.data[(p0 + p) * n + j0..(p0 + p) * n + jend];
                        for (c, &bv) in crow.iter_mut().zip(brow.iter()) {
                            *c += av * bv;
                        }
                    }
                }
                p0 = pend;
            }
            j0 = jend;
        }
        Tensor::new(vec![m, n], out)
    }

    fn matmul_t(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.dims2();
        let (n, k2) = b.dims2();
        assert_eq!(k, k2, "matmul_t inner dim {} vs {}", k, k2);
        let mut out = vec![0.0f32; m * n];
        // j-tile outer, i inner: a TBT-row panel of B is reused across
        // all M output rows, and within a tile the 4-wide `dots_lanes`
        // kernel shares one A-row pass across four output dots. Each
        // output element is still one complete ascending-k dot with the
        // a == 0 skip, so bits match the transposed scalar reference.
        let mut j0 = 0;
        while j0 < n {
            let jend = (j0 + TBT).min(n);
            for i in 0..m {
                let arow = &a.data[i * k..(i + 1) * k];
                simd::dots_lanes(arow, &b.data[j0 * k..], &mut out[i * n + j0..i * n + jend], k);
            }
            j0 = jend;
        }
        Tensor::new(vec![m, n], out)
    }

    fn qdq_panel_rows(&self) -> usize {
        RBQ
    }

    fn qdq_matmul_t(&self, x: &Tensor, prep: &(dyn Fn(&mut [f32]) + Sync), w: &Tensor) -> Tensor {
        let (m, k) = x.dims2();
        let (n, k2) = w.dims2();
        assert_eq!(k, k2, "qdq_matmul_t inner dim {} vs {}", k, k2);
        let mut out = vec![0.0f32; m * n];
        if m == 0 || n == 0 || k == 0 {
            return Tensor::new(vec![m, n], out);
        }
        // A-row panels: prep each row's copy exactly once into an
        // RBQ x k scratch, then sweep B in TBT-row tiles — each tile
        // stays hot across all RBQ prepped rows, and `dots_lanes`
        // shares one prepped-row pass across four output dots.
        let mut panel = vec![0.0f32; RBQ * k];
        let mut i0 = 0;
        while i0 < m {
            let iend = (i0 + RBQ).min(m);
            let rows = iend - i0;
            let pan = &mut panel[..rows * k];
            pan.copy_from_slice(&x.data[i0 * k..iend * k]);
            for row in pan.chunks_mut(k) {
                prep(row);
            }
            let mut j0 = 0;
            while j0 < n {
                let jend = (j0 + TBT).min(n);
                for (ri, arow) in pan.chunks(k).enumerate() {
                    let orow = &mut out[(i0 + ri) * n + j0..(i0 + ri) * n + jend];
                    simd::dots_lanes(arow, &w.data[j0 * k..], orow, k);
                }
                j0 = jend;
            }
            i0 = iend;
        }
        Tensor::new(vec![m, n], out)
    }

    fn int_matmul_t(
        &self,
        xq: &[i8],
        x_scales: &[f32],
        wq: &super::QuantPanel,
        w_scales: &[f32],
    ) -> Tensor {
        let (n, k) = (wq.n, wq.k);
        let m = x_scales.len();
        assert_eq!(xq.len(), m * k, "int_matmul_t xq len {} vs {}x{}", xq.len(), m, k);
        assert_eq!(w_scales.len(), n, "int_matmul_t w_scales len {} vs {}", w_scales.len(), n);
        let mut out = vec![0.0f32; m * n];
        // Same j-tile-outer, i-inner walk as `matmul_t`: a TBT-row i8
        // panel of Wq (a quarter the bytes of the f32 panel) stays hot
        // across all M activation rows. Tiling regroups which elements
        // are visited, and the i32 accumulation is exact, so bits match
        // the scalar reference unconditionally.
        let mut j0 = 0;
        while j0 < n {
            let jend = (j0 + TBT).min(n);
            for i in 0..m {
                let arow = &xq[i * k..(i + 1) * k];
                simd::int_dots_lanes(
                    arow,
                    &wq.q[j0 * k..],
                    x_scales[i],
                    &w_scales[j0..],
                    &mut out[i * n + j0..i * n + jend],
                    k,
                );
            }
            j0 = jend;
        }
        Tensor::new(vec![m, n], out)
    }

    fn gram(&self, x: &Tensor) -> Tensor {
        let (m, k) = x.dims2();
        let mut out = vec![0.0f32; k * k];
        // Column tiles over the (k, k) output; within a tile the same
        // GRAM_RB row-blocked sweep as the scalar kernel, so per (i, j)
        // the r-order is unchanged.
        let mut j0 = 0;
        while j0 < k {
            let jend = (j0 + JB).min(k);
            let mut r0 = 0;
            while r0 < m {
                let rend = (r0 + GRAM_RB).min(m);
                for i in 0..k {
                    let orow = &mut out[i * k + j0..i * k + jend];
                    for r in r0..rend {
                        let row = &x.data[r * k..(r + 1) * k];
                        let xi = row[i];
                        if xi == 0.0 {
                            continue;
                        }
                        for (o, &xj) in orow.iter_mut().zip(row[j0..jend].iter()) {
                            *o += xi * xj;
                        }
                    }
                }
                r0 = rend;
            }
            j0 = jend;
        }
        Tensor::new(vec![k, k], out)
    }

    fn axpy(&self, alpha: f32, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), y.len(), "axpy length mismatch");
        scalar::axpy_range(alpha, x, y);
    }

    fn sum_sq(&self, x: &[f32]) -> f64 {
        scalar::sum_sq_range(x)
    }

    fn par_map_f64(&self, n: usize, f: &(dyn Fn(usize) -> f64 + Sync)) -> Vec<f64> {
        (0..n).map(f).collect()
    }
}
