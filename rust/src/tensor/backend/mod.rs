//! Pluggable execution backends for the tensor hot paths.
//!
//! Every Hessian build (GPTQ), calibration pass and eval sweep funnels
//! through `matmul`/`gram`; this module makes those paths swappable and
//! parallel. Five implementations ship today:
//!
//! * [`Scalar`] — the original single-threaded loops, the bit-exact
//!   reference;
//! * [`Blocked`] — cache-tiled, bit-identical to scalar (tiling only
//!   reorders which *elements* are visited, never the per-element
//!   reduction order);
//! * [`Simd`] — portable 4-lane-unrolled kernels, bit-identical to
//!   scalar on every op (the unroll never crosses a reduction);
//! * [`Threaded`] — output-row-partitioned scoped threads. `matmul` and
//!   `gram` are bit-identical to scalar (each element is produced by one
//!   thread running the shared simd row kernel, itself bit-identical);
//!   `sum_sq` combines fixed-chunk partials in ascending order —
//!   deterministic, documented tolerance <= 1e-5 relative. Falls back to
//!   the serial kernel (no spawns) when rows < threads or a dimension is
//!   zero;
//! * [`Pool`] — the same row partition on a persistent worker pool with
//!   per-worker work-stealing deques: no per-call thread spawn, which
//!   wins on the many-small-sites calibration pattern, and no single
//!   shared queue to contend on at high core counts.
//!
//! Besides `matmul`/`gram`, every backend implements the transpose-free
//! [`Backend::matmul_t`] (`a @ b^T` off row-major `b`) and the fused
//! [`Backend::qdq_matmul_t`] (smoothing + activation QDQ applied inside
//! the A-panel load) — both bit-identical to their unfused transposed
//! references, which is what lets the simulated-quantization forward
//! path drop every materialized transpose and activation copy without
//! moving a single output bit.
//!
//! A second **compute mode** rides the same seam:
//! [`Backend::int_matmul_t`] is a true `i8 × i8 → i32` GEMM over a
//! prepacked [`QuantPanel`] (weights quantized once per session) with a
//! per-row × per-channel rescale in the C-row store, fed by the
//! [`quantize_rows_i8`] activation front. Integer accumulation is
//! exact, so all backends are unconditionally bit-identical to the
//! scalar reference here; `model::net::set_compute_mode` /
//! `--compute int` select it for static-int sites.
//!
//! Selection is a process-wide handle, configurable at runtime:
//!
//! * env: `INTFPQSIM_BACKEND=scalar|blocked|simd|threaded|pool|auto`,
//!   `INTFPQSIM_THREADS=N` (N >= 1; unset = all cores — an explicit 0
//!   or junk is reported loudly and falls back to all cores, see
//!   [`env_threads`]);
//! * CLI: `repro ... --backend pool --threads 8` (strict: 0/non-numeric
//!   rejected);
//! * API: [`configure`] / [`set_active`] (benches compare backends by
//!   installing each in turn).
//!
//! Every backend must pass the cross-backend conformance harness in
//! `rust/tests/backend_conformance.rs` (bit-equality against `scalar`
//! over a shape grid and adversarial values); add new backends to
//! [`all_names`] and they inherit the full matrix for free. The trait is
//! also the seam for a future PJRT-offload backend (`lib.rs`).

mod blocked;
mod pool;
mod scalar;
mod simd;
mod threaded;

pub use blocked::Blocked;
pub use pool::Pool;
pub use scalar::Scalar;
pub use simd::Simd;
pub use threaded::Threaded;

/// The scalar dot-fold discipline (ascending index order, `a == 0.0`
/// skip) — re-exported crate-wide so callers that fold directly over
/// strided row views (the attention heads in `model::net`) can produce
/// `matmul_t`-contract bits without materializing block copies.
pub(crate) use scalar::dot_skip;

/// Below this many elements, the parallel backends keep reductions and
/// axpy single-threaded (and therefore bit-identical to scalar). Shared
/// by `threaded` and `pool` so the serial/parallel boundary — part of
/// the documented `sum_sq` tolerance contract — cannot drift between
/// them.
pub(crate) const PAR_MIN_LEN: usize = 1 << 15;

use std::sync::{Arc, OnceLock, RwLock};

use crate::tensor::Tensor;

/// Prepacked integer weight panel for the true low-precision compute
/// path ([`Backend::int_matmul_t`]): the site's weight matrix quantized
/// to i8 codes **once per session**, stored in natural `(dout, din)`
/// row-major layout (the same layout the QDQ path keeps, so neither
/// path ever materializes a transpose). The per-row quantization scales
/// travel separately — they are produced by the same
/// `RowQdq`/`QuantSpec::row_kernel` machinery the QDQ path uses, which
/// is what keeps the two representations of one site consistent.
pub struct QuantPanel {
    /// `n * k` i8 codes, row-major: row `j` holds output channel `j`.
    pub q: Vec<i8>,
    /// Output channels (dout) — the number of rows.
    pub n: usize,
    /// Reduction length (din) — the row width.
    pub k: usize,
}

impl QuantPanel {
    /// Quantize a natural-layout `(n, k)` weight tensor into i8 codes
    /// with the caller's per-row scales: `q = rne(w * s).clamp(±qmax)`,
    /// element-for-element the quantize half of `formats::int_qdq` —
    /// so `q / s` reproduces the QDQ path's dequantized weight exactly.
    pub fn pack(w: &Tensor, row_scales: &[f32], qmax: f32) -> QuantPanel {
        let (n, k) = w.dims2();
        assert_eq!(
            row_scales.len(),
            n,
            "QuantPanel::pack scales len {} vs rows {}",
            row_scales.len(),
            n
        );
        let mut q = vec![0i8; n * k];
        for j in 0..n {
            let s = row_scales[j];
            let row = &w.data[j * k..(j + 1) * k];
            for (c, &v) in q[j * k..(j + 1) * k].iter_mut().zip(row.iter()) {
                *c = (v * s).round_ties_even().clamp(-qmax, qmax) as i8;
            }
        }
        QuantPanel { q, n, k }
    }
}

/// Activation-quantize front of the integer path: map `rows * k` f32
/// activations to i8 codes with one per-tensor scale,
/// `q = rne(v * scale).clamp(±qmax)` — the integer codes the QDQ path's
/// `static_int_qdq` computes internally before it divides the scale
/// back out. Like the fused `qdq_matmul_t` A-panel discipline, the f32
/// activations are read in place and only the i8 panel is written; no
/// intermediate f32 copy exists (the i8 panel is 4x smaller than even
/// one fused f32 panel per row).
pub fn quantize_rows_i8(x: &[f32], scale: f32, qmax: f32, out: &mut [i8]) {
    assert_eq!(x.len(), out.len(), "quantize_rows_i8 length mismatch");
    for (q, &v) in out.iter_mut().zip(x.iter()) {
        *q = (v * scale).round_ties_even().clamp(-qmax, qmax) as i8;
    }
}

/// A tensor-math execution strategy. All implementations must be
/// deterministic for a fixed configuration; `matmul`/`gram`/`axpy` must
/// match the scalar reference bit-for-bit, reductions within 1e-5
/// relative.
pub trait Backend: Send + Sync {
    fn name(&self) -> &'static str;

    /// Worker count this backend uses (1 for serial backends).
    fn threads(&self) -> usize {
        1
    }

    /// C = A @ B for 2-D tensors (M, K) x (K, N).
    fn matmul(&self, a: &Tensor, b: &Tensor) -> Tensor;

    /// C = A @ B^T for 2-D tensors (M, K) x (N, K) — `b` is row-major
    /// and **un-transposed**; the kernel reads its rows directly, so no
    /// transposed copy is ever materialized. Contract: bit-identical to
    /// `matmul(a, b.transpose())` — every output element folds the same
    /// ascending-k `+= a*b` sequence with the same `a == 0.0` skip
    /// (conformance-enforced). This is the transpose-free hot path of
    /// attention scores (`q @ k^T`) and every head/linear projection
    /// whose weight is stored natural (dout, din).
    fn matmul_t(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.dims2();
        let (n, k2) = b.dims2();
        assert_eq!(k, k2, "matmul_t inner dim {} vs {}", k, k2);
        let mut out = vec![0.0f32; m * n];
        scalar::matmul_t_rows(&a.data, &b.data, &mut out, k, n);
        Tensor::new(vec![m, n], out)
    }

    /// Fused QDQ→matmul: C = prep(A) @ B^T where `prep` applies the
    /// caller's smoothing + activation-QDQ to ONE row in place.
    ///
    /// Contract — enforced by the conformance harness for every
    /// registered backend × thread count:
    /// * `prep` must be **row-local** (a pure function of the row it is
    ///   handed — exactly what every QDQ kernel in `formats::` is) and
    ///   is applied to a *copy* of each A row **exactly once** before
    ///   that row's dots are taken;
    /// * the result is bit-identical to the unfused reference
    ///   (clone A; prep every row; `matmul_t`), while the transformed
    ///   activation tensor is never materialized — implementations hold
    ///   at most a few k-wide row panels (one per worker) at a time.
    fn qdq_matmul_t(&self, x: &Tensor, prep: &(dyn Fn(&mut [f32]) + Sync), w: &Tensor) -> Tensor {
        let (m, k) = x.dims2();
        let (n, k2) = w.dims2();
        assert_eq!(k, k2, "qdq_matmul_t inner dim {} vs {}", k, k2);
        let mut out = vec![0.0f32; m * n];
        scalar::qdq_matmul_t_rows(&x.data, prep, &w.data, &mut out, k, n);
        Tensor::new(vec![m, n], out)
    }

    /// How many k-wide A-row panels [`Backend::qdq_matmul_t`] holds at
    /// peak: the accounting honesty hook behind the fused-vs-unfused
    /// temporary-byte numbers in the benches (`model::net::qdq_temp`).
    /// Serial kernels hold one; the blocked backend preps a fixed row
    /// block at a time; the parallel backends hold one panel per worker.
    fn qdq_panel_rows(&self) -> usize {
        1
    }

    /// True low-precision GEMM: `C = dequant(Xq @ Wq^T)` where `Xq` is
    /// `m * k` i8 activation codes (`m = x_scales.len()` rows), `wq` is
    /// the prepacked `(n, k)` i8 weight panel, and each output element
    /// accumulates in **i32** before a single rescale in the C-row
    /// store: `C[i, j] = acc / (x_scales[i] * w_scales[j])`.
    ///
    /// Contract — enforced by the conformance harness for every
    /// registered backend × thread count:
    /// * the i32 accumulation is exact (order-independent), so every
    ///   backend is **unconditionally bit-identical** to the scalar
    ///   reference for any input — tiling, lane unrolling and row
    ///   partitioning cannot change an integer sum;
    /// * every implementation applies the identical rescale expression
    ///   `(acc as f32) / (sx * sw)` (one multiply, one divide, fixed
    ///   order), so the f32 store is bit-identical too;
    /// * vs the QDQ reference the result is bit-exact **where the math
    ///   is exact** (power-of-two scales, partial sums within f32's 24
    ///   significand bits — the static-int cells the conformance tests
    ///   construct); elsewhere the two paths agree to a documented
    ///   few-ULP tolerance (`docs/architecture.md`).
    ///
    /// Callers keep `k * 127^2 < i32::MAX` (k below ~130 000 — every
    /// model dimension in the registry is orders of magnitude smaller),
    /// so the accumulator cannot overflow.
    fn int_matmul_t(
        &self,
        xq: &[i8],
        x_scales: &[f32],
        wq: &QuantPanel,
        w_scales: &[f32],
    ) -> Tensor {
        let (n, k) = (wq.n, wq.k);
        let m = x_scales.len();
        assert_eq!(xq.len(), m * k, "int_matmul_t xq len {} vs {}x{}", xq.len(), m, k);
        assert_eq!(w_scales.len(), n, "int_matmul_t w_scales len {} vs {}", w_scales.len(), n);
        let mut out = vec![0.0f32; m * n];
        scalar::int_matmul_t_rows(xq, x_scales, &wq.q, w_scales, &mut out, k, n);
        Tensor::new(vec![m, n], out)
    }

    /// A^T @ A — the Gram/Hessian accumulator used by GPTQ.
    fn gram(&self, x: &Tensor) -> Tensor;

    /// y += alpha * x (equal lengths).
    fn axpy(&self, alpha: f32, x: &[f32], y: &mut [f32]);

    /// Sum of squared elements, accumulated in f64.
    fn sum_sq(&self, x: &[f32]) -> f64;

    /// Evaluate `f(0..n)` across the backend's workers, results in index
    /// order (used to fan independent per-site calibration jobs out).
    fn par_map_f64(&self, n: usize, f: &(dyn Fn(usize) -> f64 + Sync)) -> Vec<f64>;

    /// Tensor-valued variant of [`par_map_f64`]: evaluate `f(0..n)`
    /// across the backend's workers, results in index order. Each job
    /// runs the same per-element math as the serial loop, so the result
    /// is bit-identical regardless of the worker count (enforced by the
    /// conformance harness). Used to dispatch the per-(batch, head)
    /// attention matmuls as one parallel wave.
    fn par_map_tensor(&self, n: usize, f: &(dyn Fn(usize) -> Tensor + Sync)) -> Vec<Tensor> {
        (0..n).map(f).collect()
    }

    /// Apply `f(start_elem, piece)` to consecutive disjoint `chunk`-sized
    /// pieces of `data` (the last may be short), in parallel where the
    /// backend supports it. Callers pick `chunk` aligned to their row
    /// size (≈ len / threads); since pieces are disjoint and `f` runs the
    /// same per-element math either way, results are bit-identical to the
    /// serial loop for ANY chunking — the contract the bulk-QDQ
    /// regression tests in `tests/backend_conformance.rs` enforce.
    fn par_chunks_f32(
        &self,
        data: &mut [f32],
        chunk: usize,
        f: &(dyn Fn(usize, &mut [f32]) + Sync),
    ) {
        let c = chunk.max(1);
        for (ci, piece) in data.chunks_mut(c).enumerate() {
            f(ci * c, piece);
        }
    }

    /// `"name"` or `"name(x T)"` for display.
    fn describe(&self) -> String {
        if self.threads() > 1 {
            format!("{}(x{})", self.name(), self.threads())
        } else {
            self.name().to_string()
        }
    }
}

/// Number of workers the "all cores" default (`threads = 0` at the API
/// level, an omitted `--threads` / `INTFPQSIM_THREADS` elsewhere)
/// resolves to.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Thread count resolved from `INTFPQSIM_THREADS`. Absent or empty
/// means "all cores". A value that is present but invalid — non-numeric
/// or an explicit `0` — is a configuration error: it is reported loudly
/// (level-0 log, always printed) and the all-cores default applies, so
/// a typo can never silently misconfigure the worker count. The CLI
/// `--threads` flag is stricter still and rejects such values outright
/// (`util::cli::Args::get_usize_min`).
pub fn env_threads() -> usize {
    let raw = match std::env::var("INTFPQSIM_THREADS") {
        Err(_) => return default_threads(),
        Ok(raw) if raw.is_empty() => return default_threads(),
        Ok(raw) => raw,
    };
    match raw.parse::<usize>() {
        Ok(n) if n >= 1 => n,
        _ => {
            crate::util::logging::log(
                0,
                &format!(
                    "INTFPQSIM_THREADS must be a positive integer, got {:?}; \
                     using all {} cores",
                    raw,
                    default_threads()
                ),
            );
            default_threads()
        }
    }
}

/// Every registered backend name, in the order the conformance harness
/// and benches enumerate them. Adding a backend here enrolls it in the
/// full `tests/backend_conformance.rs` matrix automatically.
pub fn all_names() -> &'static [&'static str] {
    &["scalar", "blocked", "simd", "threaded", "pool"]
}

/// Build a backend from a name + thread count (0 = all cores).
///
/// `all_names()` is the single registry: a name outside it is rejected
/// here (so a backend wired into the match below but not registered
/// fails loudly at selection), and a registered name missing a match
/// arm panics (caught by the selection tests) — drift in either
/// direction cannot silently escape the conformance matrix.
pub fn select(name: &str, threads: usize) -> Result<Arc<dyn Backend>, String> {
    let t = if threads == 0 { default_threads() } else { threads };
    if name == "auto" || name.is_empty() {
        return Ok(if t > 1 {
            Arc::new(Pool::new(t)) as Arc<dyn Backend>
        } else {
            Arc::new(Simd)
        });
    }
    if !all_names().contains(&name) {
        return Err(format!(
            "unknown backend {:?} (expected {}|auto)",
            name,
            all_names().join("|")
        ));
    }
    Ok(match name {
        "scalar" => Arc::new(Scalar),
        "blocked" => Arc::new(Blocked),
        "simd" => Arc::new(Simd),
        "threaded" => Arc::new(Threaded::new(t)),
        "pool" => Arc::new(Pool::new(t)),
        other => unreachable!("{} is in all_names() but not constructible", other),
    })
}

fn registry() -> &'static RwLock<Arc<dyn Backend>> {
    static ACTIVE: OnceLock<RwLock<Arc<dyn Backend>>> = OnceLock::new();
    ACTIVE.get_or_init(|| RwLock::new(from_env()))
}

fn from_env() -> Arc<dyn Backend> {
    let name = std::env::var("INTFPQSIM_BACKEND").unwrap_or_else(|_| "auto".to_string());
    select(&name, env_threads()).unwrap_or_else(|e| {
        crate::util::logging::log(1, &format!("{}; falling back to scalar", e));
        Arc::new(Scalar)
    })
}

/// The process-wide backend every `Tensor::matmul`/`gram` call routes
/// through. First use initializes from the environment.
pub fn active() -> Arc<dyn Backend> {
    registry().read().unwrap().clone()
}

/// Install a backend instance as the process-wide handle.
pub fn set_active(backend: Arc<dyn Backend>) {
    *registry().write().unwrap() = backend;
}

/// Parse-and-install, as the CLI flags do: `configure("threaded", 8)`.
pub fn configure(name: &str, threads: usize) -> Result<(), String> {
    set_active(select(name, threads)?);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn rand_tensor(rng: &mut crate::util::rng::Pcg64, m: usize, k: usize) -> Tensor {
        Tensor::new(vec![m, k], prop::heavy_vec(rng, m * k, 1.0))
    }

    fn alt_backends() -> Vec<Arc<dyn Backend>> {
        vec![
            Arc::new(Blocked),
            Arc::new(Simd),
            Arc::new(Threaded::new(1)),
            Arc::new(Threaded::new(3)),
            Arc::new(Threaded::new(8)),
            Arc::new(Pool::new(1)),
            Arc::new(Pool::new(3)),
            Arc::new(Pool::new(8)),
        ]
    }

    #[test]
    fn matmul_parity_exact_property() {
        // blocked must be bit-exact; threaded's row partition is too
        // (each output element is one thread's scalar-kernel work), which
        // is stronger than its documented <= 1e-5 contract.
        prop::check("backend_matmul_parity", 15, |rng| {
            let (m, k, n) = (1 + rng.below(33), 1 + rng.below(33), 1 + rng.below(33));
            let a = rand_tensor(rng, m, k);
            let b = rand_tensor(rng, k, n);
            let want = Scalar.matmul(&a, &b);
            for be in alt_backends() {
                let got = be.matmul(&a, &b);
                prop_eq_bits(&got, &want, be.describe(), "matmul")?;
            }
            Ok(())
        });
    }

    #[test]
    fn matmul_t_parity_exact_property() {
        // a @ b^T off row-major b must reproduce the transposed-operand
        // reference bit for bit on every backend.
        prop::check("backend_matmul_t_parity", 15, |rng| {
            let (m, k, n) = (1 + rng.below(33), 1 + rng.below(33), 1 + rng.below(33));
            let a = rand_tensor(rng, m, k);
            let b = rand_tensor(rng, n, k);
            let want = Scalar.matmul(&a, &b.transpose());
            prop_eq_bits(&Scalar.matmul_t(&a, &b), &want, "scalar".into(), "matmul_t")?;
            for be in alt_backends() {
                let got = be.matmul_t(&a, &b);
                prop_eq_bits(&got, &want, be.describe(), "matmul_t")?;
            }
            Ok(())
        });
    }

    #[test]
    fn qdq_matmul_t_fused_matches_unfused_property() {
        // The fused A-panel prep must equal "clone, prep every row,
        // matmul_t" exactly. The prep is deliberately non-idempotent
        // (affine, not a fixed point) so any implementation that preps a
        // row buffer twice in place fails loudly.
        prop::check("backend_qdq_matmul_t_parity", 15, |rng| {
            let (m, k, n) = (1 + rng.below(33), 1 + rng.below(33), 1 + rng.below(33));
            let a = rand_tensor(rng, m, k);
            let w = rand_tensor(rng, n, k);
            let prep = |row: &mut [f32]| {
                for (j, v) in row.iter_mut().enumerate() {
                    *v = *v * 0.5 + (j % 5) as f32;
                }
            };
            let mut xq = a.clone();
            for i in 0..m {
                prep(xq.row_mut(i));
            }
            let want = Scalar.matmul(&xq, &w.transpose());
            prop_eq_bits(
                &Scalar.qdq_matmul_t(&a, &prep, &w),
                &want,
                "scalar".into(),
                "qdq_matmul_t",
            )?;
            for be in alt_backends() {
                let got = be.qdq_matmul_t(&a, &prep, &w);
                prop_eq_bits(&got, &want, be.describe(), "qdq_matmul_t")?;
            }
            Ok(())
        });
    }

    #[test]
    fn int_matmul_t_parity_exact_property() {
        // The integer GEMM's cross-backend contract is unconditional:
        // i32 accumulation is exact and the rescale expression is
        // shared, so every backend must match scalar bit for bit on
        // ARBITRARY i8 codes and scales — no carefully-constructed
        // exact cells needed at this layer.
        prop::check("backend_int_matmul_t_parity", 15, |rng| {
            let (m, k, n) = (1 + rng.below(33), 1 + rng.below(33), 1 + rng.below(33));
            let xq: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i16 - 127) as i8).collect();
            let wq: Vec<i8> = (0..n * k).map(|_| (rng.below(255) as i16 - 127) as i8).collect();
            let x_scales: Vec<f32> =
                (0..m).map(|_| 0.25 + rng.below(1000) as f32 / 250.0).collect();
            let w_scales: Vec<f32> =
                (0..n).map(|_| 0.25 + rng.below(1000) as f32 / 250.0).collect();
            let panel = QuantPanel { q: wq, n, k };
            let want = Scalar.int_matmul_t(&xq, &x_scales, &panel, &w_scales);
            for be in alt_backends() {
                let got = be.int_matmul_t(&xq, &x_scales, &panel, &w_scales);
                prop_eq_bits(&got, &want, be.describe(), "int_matmul_t")?;
            }
            Ok(())
        });
    }

    #[test]
    fn quant_panel_pack_and_quantize_rows_match_int_qdq_codes() {
        // Packing with scale s then dequantizing q/s must reproduce the
        // QDQ kernel exactly: q = rne(v*s).clamp(±qmax) is the quantize
        // half of formats::int_qdq by construction.
        let qmax = 127.0f32;
        let w = Tensor::new(vec![2, 3], vec![0.4, -1.0, 0.26, 2.0, -2.0, 0.5]);
        let scales = [127.0f32 / 1.0, 127.0 / 2.0];
        let p = QuantPanel::pack(&w, &scales, qmax);
        assert_eq!((p.n, p.k), (2, 3));
        for j in 0..2 {
            for c in 0..3 {
                let v = w.data[j * 3 + c];
                let want = (v * scales[j]).round_ties_even().clamp(-qmax, qmax);
                assert_eq!(p.q[j * 3 + c] as f32, want, "pack code ({}, {})", j, c);
            }
        }
        let x = [0.9995f32, -0.1, 0.0, 1.5, -3.0];
        let mut codes = [0i8; 5];
        quantize_rows_i8(&x, 127.0, qmax, &mut codes);
        for (i, &v) in x.iter().enumerate() {
            let want = (v * 127.0).round_ties_even().clamp(-qmax, qmax);
            assert_eq!(codes[i] as f32, want, "activation code {}", i);
        }
    }

    #[test]
    fn gram_parity_exact_property() {
        prop::check("backend_gram_parity", 15, |rng| {
            let (m, k) = (1 + rng.below(40), 1 + rng.below(40));
            let x = rand_tensor(rng, m, k);
            let want = Scalar.gram(&x);
            for be in alt_backends() {
                let got = be.gram(&x);
                prop_eq_bits(&got, &want, be.describe(), "gram")?;
            }
            Ok(())
        });
    }

    fn prop_eq_bits(
        got: &Tensor,
        want: &Tensor,
        who: String,
        what: &str,
    ) -> Result<(), String> {
        crate::prop_assert!(got.shape == want.shape, "{} {} shape", who, what);
        for (i, (g, w)) in got.data.iter().zip(want.data.iter()).enumerate() {
            crate::prop_assert!(
                g.to_bits() == w.to_bits(),
                "{} {} idx {}: {} vs scalar {}",
                who,
                what,
                i,
                g,
                w
            );
        }
        Ok(())
    }

    #[test]
    fn parity_on_large_shapes_forces_parallel_paths() {
        // Big enough that every thread of an 8-way split owns rows and
        // axpy/sum_sq cross their parallel thresholds.
        let mut rng = crate::util::rng::Pcg64::new(17);
        let a = rand_tensor(&mut rng, 96, 80);
        let b = rand_tensor(&mut rng, 80, 64);
        let x = rand_tensor(&mut rng, 70, 130);
        let v = prop::heavy_vec(&mut rng, (1 << 15) + 777, 1.0);
        let want_mm = Scalar.matmul(&a, &b);
        let want_g = Scalar.gram(&x);
        let want_sq = Scalar.sum_sq(&v);
        for be in alt_backends() {
            assert_eq!(be.matmul(&a, &b), want_mm, "{} matmul", be.describe());
            assert_eq!(be.gram(&x), want_g, "{} gram", be.describe());
            let got = be.sum_sq(&v);
            let rel = (got - want_sq).abs() / want_sq.abs().max(1e-12);
            assert!(rel <= 1e-5, "{} sum_sq rel err {}", be.describe(), rel);
        }
    }

    #[test]
    fn axpy_parity_across_backends() {
        let mut rng = crate::util::rng::Pcg64::new(23);
        let x = prop::heavy_vec(&mut rng, (1 << 15) + 131, 1.0);
        let y0 = prop::heavy_vec(&mut rng, x.len(), 1.0);
        let mut want = y0.clone();
        Scalar.axpy(-0.75, &x, &mut want);
        for be in alt_backends() {
            let mut got = y0.clone();
            be.axpy(-0.75, &x, &mut got);
            assert_eq!(got, want, "{} axpy", be.describe());
        }
    }

    #[test]
    fn threaded_falls_back_to_scalar_on_small_or_degenerate() {
        // Regression: rows < threads used to clamp to one-row-per-thread
        // spawns; degenerate dimensions must not panic either. The
        // fallback must stay bit-identical to scalar.
        let mut rng = crate::util::rng::Pcg64::new(31);
        let be = Threaded::new(8);
        let pool = Pool::new(8);
        // fewer output rows than threads
        let a = rand_tensor(&mut rng, 3, 5);
        let b = rand_tensor(&mut rng, 5, 4);
        assert_eq!(be.matmul(&a, &b), Scalar.matmul(&a, &b));
        assert_eq!(pool.matmul(&a, &b), Scalar.matmul(&a, &b));
        let x = rand_tensor(&mut rng, 9, 4); // k=4 < 8 threads
        assert_eq!(be.gram(&x), Scalar.gram(&x));
        assert_eq!(pool.gram(&x), Scalar.gram(&x));
        // zero-sized dimensions: no panic, scalar-equal results
        for (m, k, n) in [(0, 4, 3), (4, 0, 3), (4, 3, 0), (0, 0, 0)] {
            let a = rand_tensor(&mut rng, m, k);
            let b = rand_tensor(&mut rng, k, n);
            assert_eq!(be.matmul(&a, &b), Scalar.matmul(&a, &b), "{}x{}x{}", m, k, n);
            assert_eq!(pool.matmul(&a, &b), Scalar.matmul(&a, &b), "{}x{}x{}", m, k, n);
            assert_eq!(be.gram(&a), Scalar.gram(&a), "gram {}x{}", m, k);
            assert_eq!(pool.gram(&a), Scalar.gram(&a), "gram {}x{}", m, k);
        }
    }

    #[test]
    fn par_map_preserves_index_order() {
        for be in alt_backends() {
            let got = be.par_map_f64(23, &|i| (i * i) as f64);
            let want: Vec<f64> = (0..23).map(|i| (i * i) as f64).collect();
            assert_eq!(got, want, "{}", be.describe());
        }
        assert!(Scalar.par_map_f64(0, &|_| 1.0).is_empty());
    }

    #[test]
    fn selection_and_configuration() {
        assert_eq!(select("scalar", 0).unwrap().name(), "scalar");
        assert_eq!(select("blocked", 2).unwrap().name(), "blocked");
        assert_eq!(select("simd", 2).unwrap().name(), "simd");
        let t = select("threaded", 5).unwrap();
        assert_eq!(t.name(), "threaded");
        assert_eq!(t.threads(), 5);
        assert_eq!(t.describe(), "threaded(x5)");
        let p = select("pool", 3).unwrap();
        assert_eq!(p.name(), "pool");
        assert_eq!(p.threads(), 3);
        assert_eq!(p.describe(), "pool(x3)");
        assert!(select("gpu", 1).is_err());
        // every registered name constructs, and the registry is complete
        for &name in all_names() {
            assert_eq!(select(name, 2).unwrap().name(), name);
        }
        // auto resolves to a real backend for any thread count
        assert!(["simd", "pool"].contains(&select("auto", 1).unwrap().name()));
        assert_eq!(select("auto", 4).unwrap().threads(), 4);

        // install + restore the process-wide handle
        let before = active().describe();
        configure("threaded", 2).unwrap();
        assert_eq!(active().describe(), "threaded(x2)");
        assert!(configure("nope", 1).is_err());
        assert_eq!(active().describe(), "threaded(x2)", "failed configure must not switch");
        configure(&before_name(&before), thread_of(&before)).unwrap();
    }

    fn before_name(desc: &str) -> String {
        desc.split('(').next().unwrap().to_string()
    }

    fn thread_of(desc: &str) -> usize {
        desc.split("(x")
            .nth(1)
            .and_then(|s| s.trim_end_matches(')').parse().ok())
            .unwrap_or(1)
    }
}
