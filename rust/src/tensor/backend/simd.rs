//! Portable 4-lane-unrolled backend: the scalar kernels with their inner
//! loops unrolled four wide, written so the optimizer can keep four
//! independent fused `+= a*b` streams in flight (SSE/NEON width without
//! any platform intrinsics).
//!
//! Determinism contract — *bit-identical to `scalar` on every op*:
//!
//! * `matmul`/`gram`: the unroll runs across **output columns** (four
//!   independent output elements per step), never across the reduction
//!   dimension. Per output element the `+= a*b` updates still arrive in
//!   the exact ascending order of the scalar kernel, so the reduction
//!   tree is fixed and the results match `scalar` bit for bit — including
//!   NaN propagation and the `a == 0.0` skip.
//! * `matmul_t`/`qdq_matmul_t`: the unroll runs across four independent
//!   output *dots* ([`dots_lanes`]); each dot still folds ascending-k
//!   with the `a == 0.0` skip, so bits match the transposed scalar
//!   reference.
//! * `axpy`: element-wise, so any unroll is trivially bit-identical.
//! * `sum_sq`: the four f64 squares of a lane are computed together, but
//!   they are folded into the single accumulator in ascending index
//!   order — the same left fold as `scalar`, hence bit-identical (a
//!   stronger guarantee than the 1e-5 reduction tolerance the trait
//!   requires, and what lets the conformance harness assert bits).

use super::Backend;
use crate::tensor::Tensor;

/// Unroll width (f32 lanes). Matches the narrowest ubiquitous SIMD
/// register (SSE/NEON, 128-bit).
const LANES: usize = 4;

/// C rows = A rows @ B with the inner column loop 4-lane unrolled.
/// Same signature/contract as `scalar::matmul_rows`.
pub(crate) fn matmul_rows(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize) {
    let rows = if n == 0 { 0 } else { out.len() / n };
    for i in 0..rows {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut out[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            axpy_lanes(av, brow, crow);
        }
    }
}

/// Output rows [i0, ..) of A^T A with the inner column loop unrolled.
/// Same signature/contract as `scalar::gram_rows` (including the
/// `GRAM_RB` row blocking, so the per-element r-order is unchanged).
pub(crate) fn gram_rows(x: &[f32], m: usize, k: usize, i0: usize, out_rows: &mut [f32]) {
    let ni = if k == 0 { 0 } else { out_rows.len() / k };
    let mut r0 = 0;
    while r0 < m {
        let rend = (r0 + super::scalar::GRAM_RB).min(m);
        for ii in 0..ni {
            let i = i0 + ii;
            let orow = &mut out_rows[ii * k..(ii + 1) * k];
            for r in r0..rend {
                let row = &x[r * k..(r + 1) * k];
                let xi = row[i];
                if xi == 0.0 {
                    continue;
                }
                axpy_lanes(xi, row, orow);
            }
        }
        r0 = rend;
    }
}

/// out[j] = dot_skip(a, b row j) with four output dots in flight.
/// Each accumulator folds its `+= a*b` updates in ascending-k order
/// with the same `a == 0.0` skip as `scalar::dot_skip` — the unroll
/// runs across four *independent* output elements, never across a
/// reduction — so every element is bit-identical to the scalar dot.
pub(crate) fn dots_lanes(a: &[f32], b: &[f32], out: &mut [f32], k: usize) {
    let mut jit = out.chunks_exact_mut(LANES);
    let mut j = 0;
    for c4 in &mut jit {
        let b0 = &b[j * k..(j + 1) * k];
        let b1 = &b[(j + 1) * k..(j + 2) * k];
        let b2 = &b[(j + 2) * k..(j + 3) * k];
        let b3 = &b[(j + 3) * k..(j + 4) * k];
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for (p, &av) in a.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            s0 += av * b0[p];
            s1 += av * b1[p];
            s2 += av * b2[p];
            s3 += av * b3[p];
        }
        c4[0] = s0;
        c4[1] = s1;
        c4[2] = s2;
        c4[3] = s3;
        j += LANES;
    }
    for (jj, c) in jit.into_remainder().iter_mut().enumerate() {
        *c = super::scalar::dot_skip(a, &b[(j + jj) * k..(j + jj + 1) * k]);
    }
}

/// C rows = A rows @ B^T with the output columns 4-lane unrolled.
/// Same signature/contract as `scalar::matmul_t_rows` (bit-identical to
/// the transposed scalar reference).
pub(crate) fn matmul_t_rows(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize) {
    let rows = if n == 0 { 0 } else { out.len() / n };
    for i in 0..rows {
        let arow = &a[i * k..(i + 1) * k];
        dots_lanes(arow, b, &mut out[i * n..(i + 1) * n], k);
    }
}

/// Fused `prep(A rows) @ B^T` with 4-lane-unrolled dots: one reusable
/// k-panel, `prep` applied to each row's copy exactly once. Same
/// contract as `scalar::qdq_matmul_t_rows`.
pub(crate) fn qdq_matmul_t_rows(
    a: &[f32],
    prep: &(dyn Fn(&mut [f32]) + Sync),
    b: &[f32],
    out: &mut [f32],
    k: usize,
    n: usize,
) {
    let rows = if n == 0 { 0 } else { out.len() / n };
    let mut panel = vec![0.0f32; k];
    for i in 0..rows {
        panel.copy_from_slice(&a[i * k..(i + 1) * k]);
        prep(&mut panel);
        dots_lanes(&panel, b, &mut out[i * n..(i + 1) * n], k);
    }
}

/// out[j] = dequant(int_dot(a, b row j)) with four i32 accumulators in
/// flight — the integer twin of [`dots_lanes`]. The unroll runs across
/// four independent output dots; because i32 addition is exact the
/// accumulators equal `scalar::int_dot` regardless of grouping, and the
/// rescale is the contract's verbatim `(acc as f32) / (sx * sw)` store,
/// so the f32 output is bit-identical to the scalar reference.
/// `w_scales` is indexed locally (scale `j` belongs to `b` row `j`), so
/// tiled callers pass both slices offset together.
pub(crate) fn int_dots_lanes(
    a: &[i8],
    b: &[i8],
    sx: f32,
    w_scales: &[f32],
    out: &mut [f32],
    k: usize,
) {
    let mut jit = out.chunks_exact_mut(LANES);
    let mut j = 0;
    for c4 in &mut jit {
        let b0 = &b[j * k..(j + 1) * k];
        let b1 = &b[(j + 1) * k..(j + 2) * k];
        let b2 = &b[(j + 2) * k..(j + 3) * k];
        let b3 = &b[(j + 3) * k..(j + 4) * k];
        let (mut s0, mut s1, mut s2, mut s3) = (0i32, 0i32, 0i32, 0i32);
        for (p, &av) in a.iter().enumerate() {
            let av = av as i32;
            s0 += av * b0[p] as i32;
            s1 += av * b1[p] as i32;
            s2 += av * b2[p] as i32;
            s3 += av * b3[p] as i32;
        }
        c4[0] = (s0 as f32) / (sx * w_scales[j]);
        c4[1] = (s1 as f32) / (sx * w_scales[j + 1]);
        c4[2] = (s2 as f32) / (sx * w_scales[j + 2]);
        c4[3] = (s3 as f32) / (sx * w_scales[j + 3]);
        j += LANES;
    }
    for (jj, c) in jit.into_remainder().iter_mut().enumerate() {
        let acc = super::scalar::int_dot(a, &b[(j + jj) * k..(j + jj + 1) * k]);
        *c = (acc as f32) / (sx * w_scales[j + jj]);
    }
}

/// C rows = dequant(Xq rows @ Wq^T) with the output columns 4-lane
/// unrolled. Same signature/contract as `scalar::int_matmul_t_rows`
/// (bit-identical — integer accumulation, shared rescale store).
pub(crate) fn int_matmul_t_rows(
    xq: &[i8],
    x_scales: &[f32],
    wq: &[i8],
    w_scales: &[f32],
    out: &mut [f32],
    k: usize,
    n: usize,
) {
    let rows = if n == 0 { 0 } else { out.len() / n };
    for i in 0..rows {
        let arow = &xq[i * k..(i + 1) * k];
        int_dots_lanes(arow, wq, x_scales[i], w_scales, &mut out[i * n..(i + 1) * n], k);
    }
}

/// y += alpha * x, 4-lane unrolled. The lanes are disjoint elements, so
/// this is bit-identical to `scalar::axpy_range` for any length.
pub(crate) fn axpy_lanes(alpha: f32, x: &[f32], y: &mut [f32]) {
    let mut yit = y.chunks_exact_mut(LANES);
    let mut xit = x.chunks_exact(LANES);
    for (y4, x4) in (&mut yit).zip(&mut xit) {
        y4[0] += alpha * x4[0];
        y4[1] += alpha * x4[1];
        y4[2] += alpha * x4[2];
        y4[3] += alpha * x4[3];
    }
    for (yv, &xv) in yit.into_remainder().iter_mut().zip(xit.remainder()) {
        *yv += alpha * xv;
    }
}

/// Sum of squares: lane squares computed four at a time, folded into the
/// accumulator in ascending index order — the identical left fold (and
/// therefore identical bits) as `scalar::sum_sq_range`.
pub(crate) fn sum_sq_lanes(x: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    let mut it = x.chunks_exact(LANES);
    for c in &mut it {
        let s0 = (c[0] as f64) * (c[0] as f64);
        let s1 = (c[1] as f64) * (c[1] as f64);
        let s2 = (c[2] as f64) * (c[2] as f64);
        let s3 = (c[3] as f64) * (c[3] as f64);
        acc += s0;
        acc += s1;
        acc += s2;
        acc += s3;
    }
    for &v in it.remainder() {
        acc += (v as f64) * (v as f64);
    }
    acc
}

/// Single-threaded 4-lane-unrolled backend.
pub struct Simd;

impl Backend for Simd {
    fn name(&self) -> &'static str {
        "simd"
    }

    fn matmul(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.dims2();
        let (k2, n) = b.dims2();
        assert_eq!(k, k2, "matmul inner dim {} vs {}", k, k2);
        let mut out = vec![0.0f32; m * n];
        matmul_rows(&a.data, &b.data, &mut out, k, n);
        Tensor::new(vec![m, n], out)
    }

    fn matmul_t(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.dims2();
        let (n, k2) = b.dims2();
        assert_eq!(k, k2, "matmul_t inner dim {} vs {}", k, k2);
        let mut out = vec![0.0f32; m * n];
        matmul_t_rows(&a.data, &b.data, &mut out, k, n);
        Tensor::new(vec![m, n], out)
    }

    fn qdq_matmul_t(&self, x: &Tensor, prep: &(dyn Fn(&mut [f32]) + Sync), w: &Tensor) -> Tensor {
        let (m, k) = x.dims2();
        let (n, k2) = w.dims2();
        assert_eq!(k, k2, "qdq_matmul_t inner dim {} vs {}", k, k2);
        let mut out = vec![0.0f32; m * n];
        qdq_matmul_t_rows(&x.data, prep, &w.data, &mut out, k, n);
        Tensor::new(vec![m, n], out)
    }

    fn int_matmul_t(
        &self,
        xq: &[i8],
        x_scales: &[f32],
        wq: &super::QuantPanel,
        w_scales: &[f32],
    ) -> Tensor {
        let (n, k) = (wq.n, wq.k);
        let m = x_scales.len();
        assert_eq!(xq.len(), m * k, "int_matmul_t xq len {} vs {}x{}", xq.len(), m, k);
        assert_eq!(w_scales.len(), n, "int_matmul_t w_scales len {} vs {}", w_scales.len(), n);
        let mut out = vec![0.0f32; m * n];
        int_matmul_t_rows(xq, x_scales, &wq.q, w_scales, &mut out, k, n);
        Tensor::new(vec![m, n], out)
    }

    fn gram(&self, x: &Tensor) -> Tensor {
        let (m, k) = x.dims2();
        let mut out = vec![0.0f32; k * k];
        gram_rows(&x.data, m, k, 0, &mut out);
        Tensor::new(vec![k, k], out)
    }

    fn axpy(&self, alpha: f32, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), y.len(), "axpy length mismatch");
        axpy_lanes(alpha, x, y);
    }

    fn sum_sq(&self, x: &[f32]) -> f64 {
        sum_sq_lanes(x)
    }

    fn par_map_f64(&self, n: usize, f: &(dyn Fn(usize) -> f64 + Sync)) -> Vec<f64> {
        (0..n).map(f).collect()
    }
}
