//! Row-partitioned multi-threaded backend (std scoped threads only).
//!
//! Determinism contract: `matmul`/`matmul_t`/`qdq_matmul_t` and `gram`
//! partition *output rows* across threads and each output element is
//! produced entirely by one thread running the shared **simd** row
//! kernel — which is itself bit-identical to scalar on every op (the
//! unroll never crosses a reduction), so results are bit-identical to
//! the scalar backend (stronger than the documented <= 1e-5 guarantee,
//! and asserted exactly by the parity tests). `sum_sq` reduces
//! fixed-size chunk partials in ascending chunk order — deterministic
//! for a given thread count, but a different f64 association than the
//! scalar left-fold, hence the documented 1e-5 relative tolerance.
//!
//! Fallback rule: when there are fewer output rows than threads (each
//! spawn would own ~1 row, so spawn overhead dominates) or any dimension
//! is zero, the call runs the serial kernel directly — no threads are
//! spawned. Covered by the regression tests here and by the shape grid
//! in `tests/backend_conformance.rs`.

use super::{simd, Backend, PAR_MIN_LEN};
use crate::tensor::Tensor;

pub struct Threaded {
    threads: usize,
}

impl Threaded {
    pub fn new(threads: usize) -> Threaded {
        Threaded { threads: threads.max(1) }
    }

    pub fn thread_count(&self) -> usize {
        self.threads
    }
}

impl Backend for Threaded {
    fn name(&self) -> &'static str {
        "threaded"
    }

    fn threads(&self) -> usize {
        self.threads
    }

    fn qdq_panel_rows(&self) -> usize {
        self.threads
    }

    fn matmul(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.dims2();
        let (k2, n) = b.dims2();
        assert_eq!(k, k2, "matmul inner dim {} vs {}", k, k2);
        let mut out = vec![0.0f32; m * n];
        let t = self.threads;
        if t <= 1 || n == 0 || k == 0 || m < t {
            simd::matmul_rows(&a.data, &b.data, &mut out, k, n);
        } else {
            let rows_per = m.div_ceil(t);
            let (adata, bdata) = (&a.data[..], &b.data[..]);
            std::thread::scope(|s| {
                for (ci, chunk) in out.chunks_mut(rows_per * n).enumerate() {
                    let i0 = ci * rows_per;
                    let rows = chunk.len() / n;
                    let ablock = &adata[i0 * k..(i0 + rows) * k];
                    s.spawn(move || simd::matmul_rows(ablock, bdata, chunk, k, n));
                }
            });
        }
        Tensor::new(vec![m, n], out)
    }

    fn matmul_t(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.dims2();
        let (n, k2) = b.dims2();
        assert_eq!(k, k2, "matmul_t inner dim {} vs {}", k, k2);
        let mut out = vec![0.0f32; m * n];
        let t = self.threads;
        if t <= 1 || n == 0 || k == 0 || m < t {
            simd::matmul_t_rows(&a.data, &b.data, &mut out, k, n);
        } else {
            let rows_per = m.div_ceil(t);
            let (adata, bdata) = (&a.data[..], &b.data[..]);
            std::thread::scope(|s| {
                for (ci, chunk) in out.chunks_mut(rows_per * n).enumerate() {
                    let i0 = ci * rows_per;
                    let rows = chunk.len() / n;
                    let ablock = &adata[i0 * k..(i0 + rows) * k];
                    s.spawn(move || simd::matmul_t_rows(ablock, bdata, chunk, k, n));
                }
            });
        }
        Tensor::new(vec![m, n], out)
    }

    fn qdq_matmul_t(&self, x: &Tensor, prep: &(dyn Fn(&mut [f32]) + Sync), w: &Tensor) -> Tensor {
        let (m, k) = x.dims2();
        let (n, k2) = w.dims2();
        assert_eq!(k, k2, "qdq_matmul_t inner dim {} vs {}", k, k2);
        let mut out = vec![0.0f32; m * n];
        let t = self.threads;
        if t <= 1 || n == 0 || k == 0 || m < t {
            simd::qdq_matmul_t_rows(&x.data, prep, &w.data, &mut out, k, n);
        } else {
            // Output rows are partitioned; each thread preps its own
            // rows (every row exactly once, by exactly one worker) into
            // its own k-panel, so the fused contract holds per element.
            let rows_per = m.div_ceil(t);
            let (xdata, wdata) = (&x.data[..], &w.data[..]);
            std::thread::scope(|s| {
                for (ci, chunk) in out.chunks_mut(rows_per * n).enumerate() {
                    let i0 = ci * rows_per;
                    let rows = chunk.len() / n;
                    let xblock = &xdata[i0 * k..(i0 + rows) * k];
                    s.spawn(move || simd::qdq_matmul_t_rows(xblock, prep, wdata, chunk, k, n));
                }
            });
        }
        Tensor::new(vec![m, n], out)
    }

    fn int_matmul_t(
        &self,
        xq: &[i8],
        x_scales: &[f32],
        wq: &super::QuantPanel,
        w_scales: &[f32],
    ) -> Tensor {
        let (n, k) = (wq.n, wq.k);
        let m = x_scales.len();
        assert_eq!(xq.len(), m * k, "int_matmul_t xq len {} vs {}x{}", xq.len(), m, k);
        assert_eq!(w_scales.len(), n, "int_matmul_t w_scales len {} vs {}", w_scales.len(), n);
        let mut out = vec![0.0f32; m * n];
        let t = self.threads;
        if t <= 1 || n == 0 || k == 0 || m < t {
            simd::int_matmul_t_rows(xq, x_scales, &wq.q, w_scales, &mut out, k, n);
        } else {
            // Output rows partitioned exactly like `matmul_t`; each
            // thread owns a contiguous row block plus the matching slice
            // of per-row activation scales.
            let rows_per = m.div_ceil(t);
            let wdata = &wq.q[..];
            std::thread::scope(|s| {
                for (ci, chunk) in out.chunks_mut(rows_per * n).enumerate() {
                    let i0 = ci * rows_per;
                    let rows = chunk.len() / n;
                    let xblock = &xq[i0 * k..(i0 + rows) * k];
                    let sblock = &x_scales[i0..i0 + rows];
                    s.spawn(move || {
                        simd::int_matmul_t_rows(xblock, sblock, wdata, w_scales, chunk, k, n)
                    });
                }
            });
        }
        Tensor::new(vec![m, n], out)
    }

    fn gram(&self, x: &Tensor) -> Tensor {
        let (m, k) = x.dims2();
        let mut out = vec![0.0f32; k * k];
        let t = self.threads;
        if t <= 1 || m == 0 || k < t {
            simd::gram_rows(&x.data, m, k, 0, &mut out);
        } else {
            let rows_per = k.div_ceil(t);
            let xdata = &x.data[..];
            std::thread::scope(|s| {
                for (ci, chunk) in out.chunks_mut(rows_per * k).enumerate() {
                    let i0 = ci * rows_per;
                    s.spawn(move || simd::gram_rows(xdata, m, k, i0, chunk));
                }
            });
        }
        Tensor::new(vec![k, k], out)
    }

    fn axpy(&self, alpha: f32, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), y.len(), "axpy length mismatch");
        let t = self.threads;
        if t <= 1 || y.len() < PAR_MIN_LEN {
            simd::axpy_lanes(alpha, x, y);
            return;
        }
        let chunk = y.len().div_ceil(t);
        std::thread::scope(|s| {
            for (xc, yc) in x.chunks(chunk).zip(y.chunks_mut(chunk)) {
                s.spawn(move || simd::axpy_lanes(alpha, xc, yc));
            }
        });
    }

    fn sum_sq(&self, x: &[f32]) -> f64 {
        let t = self.threads;
        if t <= 1 || x.len() < PAR_MIN_LEN {
            return simd::sum_sq_lanes(x);
        }
        let chunk = x.len().div_ceil(t);
        let mut partials = vec![0.0f64; x.len().div_ceil(chunk)];
        std::thread::scope(|s| {
            for (xc, p) in x.chunks(chunk).zip(partials.iter_mut()) {
                s.spawn(move || *p = simd::sum_sq_lanes(xc));
            }
        });
        partials.iter().sum()
    }

    fn par_map_f64(&self, n: usize, f: &(dyn Fn(usize) -> f64 + Sync)) -> Vec<f64> {
        let t = self.threads.min(n.max(1));
        if t <= 1 {
            return (0..n).map(f).collect();
        }
        let mut out = vec![0.0f64; n];
        let chunk = n.div_ceil(t);
        std::thread::scope(|s| {
            for (ci, oc) in out.chunks_mut(chunk).enumerate() {
                s.spawn(move || {
                    for (j, slot) in oc.iter_mut().enumerate() {
                        *slot = f(ci * chunk + j);
                    }
                });
            }
        });
        out
    }

    fn par_map_tensor(&self, n: usize, f: &(dyn Fn(usize) -> Tensor + Sync)) -> Vec<Tensor> {
        let t = self.threads.min(n.max(1));
        if t <= 1 {
            return (0..n).map(f).collect();
        }
        let mut out: Vec<Option<Tensor>> = (0..n).map(|_| None).collect();
        let chunk = n.div_ceil(t);
        std::thread::scope(|s| {
            for (ci, oc) in out.chunks_mut(chunk).enumerate() {
                s.spawn(move || {
                    for (j, slot) in oc.iter_mut().enumerate() {
                        *slot = Some(f(ci * chunk + j));
                    }
                });
            }
        });
        out.into_iter().map(|t| t.expect("par_map_tensor slot filled")).collect()
    }

    fn par_chunks_f32(
        &self,
        data: &mut [f32],
        chunk: usize,
        f: &(dyn Fn(usize, &mut [f32]) + Sync),
    ) {
        let c = chunk.max(1);
        let n_chunks = data.len().div_ceil(c);
        if self.threads <= 1 || n_chunks <= 1 {
            for (ci, piece) in data.chunks_mut(c).enumerate() {
                f(ci * c, piece);
            }
            return;
        }
        // Group whole chunks into at most `threads` spans (one spawn
        // each, chunks within a span processed serially): the pieces
        // handed to `f` are identical to the serial loop's, so results
        // stay bit-identical regardless of the grouping.
        let per_span = n_chunks.div_ceil(self.threads) * c;
        std::thread::scope(|s| {
            for (si, span) in data.chunks_mut(per_span).enumerate() {
                s.spawn(move || {
                    for (cj, piece) in span.chunks_mut(c).enumerate() {
                        f(si * per_span + cj * c, piece);
                    }
                });
            }
        });
    }
}
