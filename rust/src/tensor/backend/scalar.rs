//! Scalar reference backend: the original single-threaded loops, kept as
//! the bit-exact baseline every other backend is verified against.
//!
//! The row-range kernels below are shared by the `blocked` and `threaded`
//! backends — each output element is always produced by the *same*
//! instruction sequence in the same order, which is what makes the
//! cross-backend parity tests exact rather than approximate.

use super::Backend;
use crate::tensor::Tensor;

/// Row-block size of the gram accumulator (§Perf L3 iteration 4): each
/// output row is loaded once per `GRAM_RB` rank-1 updates.
pub(crate) const GRAM_RB: usize = 8;

/// C rows = A rows @ B for a contiguous block of output rows.
/// `a` holds `rows * k` elements, `out` holds `rows * n`; `b` is (K, N).
/// ikj loop order: streams B rows, accumulates into C rows.
pub(crate) fn matmul_rows(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize) {
    let rows = if n == 0 { 0 } else { out.len() / n };
    for i in 0..rows {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut out[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (c, &bv) in crow.iter_mut().zip(brow.iter()) {
                *c += av * bv;
            }
        }
    }
}

/// Output rows [i0, i0 + out_rows.len()/k) of A^T A for `x` of shape
/// (m, k). Per (i, j) element the accumulation runs in ascending-r order
/// (grouped in `GRAM_RB` row blocks), identical for every row partition.
pub(crate) fn gram_rows(x: &[f32], m: usize, k: usize, i0: usize, out_rows: &mut [f32]) {
    let ni = if k == 0 { 0 } else { out_rows.len() / k };
    let mut r0 = 0;
    while r0 < m {
        let rend = (r0 + GRAM_RB).min(m);
        for ii in 0..ni {
            let i = i0 + ii;
            let orow = &mut out_rows[ii * k..(ii + 1) * k];
            for r in r0..rend {
                let row = &x[r * k..(r + 1) * k];
                let xi = row[i];
                if xi == 0.0 {
                    continue;
                }
                for (o, &xj) in orow.iter_mut().zip(row.iter()) {
                    *o += xi * xj;
                }
            }
        }
        r0 = rend;
    }
}

/// Dot product with the kernel's `a == 0.0` skip, folded in ascending
/// index order. This is exactly the accumulation sequence one output
/// element of [`matmul_rows`] sees (the ikj loop adds `a[p] * b[p, j]`
/// into `C[i, j]` for ascending p, skipping zero A elements), so a C
/// built from these dots is bit-identical to `A @ B` — which is what
/// lets [`matmul_t_rows`] read B row-major without materializing B^T.
#[inline]
pub(crate) fn dot_skip(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (&av, &bv) in a.iter().zip(b.iter()) {
        if av == 0.0 {
            continue;
        }
        acc += av * bv;
    }
    acc
}

/// C rows = A rows @ B^T for a contiguous block of output rows.
/// `a` holds `rows * k` elements, `out` holds `rows * n`; `b` is (N, K)
/// row-major — **un-transposed**. Every output element is one complete
/// ascending-k [`dot_skip`], so the result matches
/// `matmul_rows(a, transpose(b), ..)` bit for bit with no transposed
/// copy of B ever existing.
pub(crate) fn matmul_t_rows(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize) {
    let rows = if n == 0 { 0 } else { out.len() / n };
    for i in 0..rows {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut out[i * n..(i + 1) * n];
        for (j, c) in crow.iter_mut().enumerate() {
            *c = dot_skip(arow, &b[j * k..(j + 1) * k]);
        }
    }
}

/// Fused rows of `prep(A rows) @ B^T`: each A row is copied into one
/// reusable k-panel, transformed by `prep` (the caller's smoothing +
/// activation-QDQ kernel — row-local by contract) **exactly once**, and
/// dotted against every B row. The full transformed activation tensor
/// is never materialized: peak temporary footprint is a single k-wide
/// panel per caller instead of rows × k. Because `prep` runs the same
/// per-row math as the unfused bulk path and the dots fold in the same
/// ascending-k order, results are bit-identical to
/// "clone A; prep each row; matmul_t".
pub(crate) fn qdq_matmul_t_rows(
    a: &[f32],
    prep: &(dyn Fn(&mut [f32]) + Sync),
    b: &[f32],
    out: &mut [f32],
    k: usize,
    n: usize,
) {
    let rows = if n == 0 { 0 } else { out.len() / n };
    let mut panel = vec![0.0f32; k];
    for i in 0..rows {
        panel.copy_from_slice(&a[i * k..(i + 1) * k]);
        prep(&mut panel);
        let crow = &mut out[i * n..(i + 1) * n];
        for (j, c) in crow.iter_mut().enumerate() {
            *c = dot_skip(&panel, &b[j * k..(j + 1) * k]);
        }
    }
}

/// i8 dot product accumulated in i32, ascending index order. Integer
/// addition is associative, so unlike [`dot_skip`] the fold order is
/// *not* load-bearing — every regrouping (lane unroll, tiling) produces
/// the same accumulator, which is why the integer path's cross-backend
/// contract is unconditional bit-equality rather than a fixed-order
/// discipline. No zero skip: an i8 multiply-add costs less than the
/// branch would.
#[inline]
pub(crate) fn int_dot(a: &[i8], b: &[i8]) -> i32 {
    let mut acc = 0i32;
    for (&av, &bv) in a.iter().zip(b.iter()) {
        acc += (av as i32) * (bv as i32);
    }
    acc
}

/// C rows = dequant(Xq rows @ Wq^T): the scalar reference of the true
/// low-precision path. `xq` holds `rows * k` i8 codes with one
/// activation scale per row (`x_scales`), `wq` is the `(n, k)` i8 code
/// panel with one scale per weight row (`w_scales`); each output element
/// is one complete i32 [`int_dot`] followed by THE rescale expression of
/// the contract — `(acc as f32) / (sx * sw)` — which every backend must
/// reproduce verbatim so the f32 store is bit-identical everywhere.
pub(crate) fn int_matmul_t_rows(
    xq: &[i8],
    x_scales: &[f32],
    wq: &[i8],
    w_scales: &[f32],
    out: &mut [f32],
    k: usize,
    n: usize,
) {
    let rows = if n == 0 { 0 } else { out.len() / n };
    for i in 0..rows {
        let arow = &xq[i * k..(i + 1) * k];
        let sx = x_scales[i];
        let crow = &mut out[i * n..(i + 1) * n];
        for (j, c) in crow.iter_mut().enumerate() {
            let acc = int_dot(arow, &wq[j * k..(j + 1) * k]);
            *c = (acc as f32) / (sx * w_scales[j]);
        }
    }
}

/// y += alpha * x over a contiguous range.
pub(crate) fn axpy_range(alpha: f32, x: &[f32], y: &mut [f32]) {
    for (yv, &xv) in y.iter_mut().zip(x.iter()) {
        *yv += alpha * xv;
    }
}

/// Left-to-right f64 sum of squares.
pub(crate) fn sum_sq_range(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum()
}

/// The original single-threaded implementation.
pub struct Scalar;

impl Backend for Scalar {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn matmul(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.dims2();
        let (k2, n) = b.dims2();
        assert_eq!(k, k2, "matmul inner dim {} vs {}", k, k2);
        let mut out = vec![0.0f32; m * n];
        matmul_rows(&a.data, &b.data, &mut out, k, n);
        Tensor::new(vec![m, n], out)
    }

    fn matmul_t(&self, a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.dims2();
        let (n, k2) = b.dims2();
        assert_eq!(k, k2, "matmul_t inner dim {} vs {}", k, k2);
        let mut out = vec![0.0f32; m * n];
        matmul_t_rows(&a.data, &b.data, &mut out, k, n);
        Tensor::new(vec![m, n], out)
    }

    fn qdq_matmul_t(&self, x: &Tensor, prep: &(dyn Fn(&mut [f32]) + Sync), w: &Tensor) -> Tensor {
        let (m, k) = x.dims2();
        let (n, k2) = w.dims2();
        assert_eq!(k, k2, "qdq_matmul_t inner dim {} vs {}", k, k2);
        let mut out = vec![0.0f32; m * n];
        qdq_matmul_t_rows(&x.data, prep, &w.data, &mut out, k, n);
        Tensor::new(vec![m, n], out)
    }

    fn gram(&self, x: &Tensor) -> Tensor {
        let (m, k) = x.dims2();
        let mut out = vec![0.0f32; k * k];
        gram_rows(&x.data, m, k, 0, &mut out);
        Tensor::new(vec![k, k], out)
    }

    fn axpy(&self, alpha: f32, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), y.len(), "axpy length mismatch");
        axpy_range(alpha, x, y);
    }

    fn sum_sq(&self, x: &[f32]) -> f64 {
        sum_sq_range(x)
    }

    fn par_map_f64(&self, n: usize, f: &(dyn Fn(usize) -> f64 + Sync)) -> Vec<f64> {
        (0..n).map(f).collect()
    }
}
