//! Checkpoint container: named tensors in a simple binary format.
//!
//! Layout (little-endian):
//!   magic  b"TNS1"
//!   u32    tensor count
//!   per tensor:
//!     u32          name length, then name bytes (utf-8)
//!     u32          ndim, then ndim × u32 dims
//!     f32 × numel  row-major data

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Tensor;

#[derive(Debug, Clone, Default)]
pub struct TensorStore {
    pub tensors: BTreeMap<String, Tensor>,
    /// Insertion/manifest order (BTreeMap alone would lose it).
    pub order: Vec<String>,
}

impl TensorStore {
    pub fn insert(&mut self, name: &str, t: Tensor) {
        if !self.tensors.contains_key(name) {
            self.order.push(name.to_string());
        }
        self.tensors.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.get(name)
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut Tensor> {
        self.tensors.get_mut(name)
    }

    pub fn expect(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("tensor {:?} missing from store", name))
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
            f.write_all(b"TNS1")?;
            f.write_all(&(self.order.len() as u32).to_le_bytes())?;
            for name in &self.order {
                let t = &self.tensors[name];
                f.write_all(&(name.len() as u32).to_le_bytes())?;
                f.write_all(name.as_bytes())?;
                f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
                for &d in &t.shape {
                    f.write_all(&(d as u32).to_le_bytes())?;
                }
                // bulk write of the payload
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(
                        t.data.as_ptr() as *const u8,
                        t.data.len() * 4,
                    )
                };
                f.write_all(bytes)?;
            }
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<TensorStore> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path)
                .with_context(|| format!("open checkpoint {:?}", path))?,
        );
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"TNS1" {
            bail!("bad magic in {:?}", path);
        }
        let count = read_u32(&mut f)? as usize;
        let mut store = TensorStore::default();
        for _ in 0..count {
            let nlen = read_u32(&mut f)? as usize;
            if nlen > 4096 {
                bail!("unreasonable name length {}", nlen);
            }
            let mut nbuf = vec![0u8; nlen];
            f.read_exact(&mut nbuf)?;
            let name = String::from_utf8(nbuf).context("tensor name utf8")?;
            let ndim = read_u32(&mut f)? as usize;
            if ndim > 8 {
                bail!("unreasonable ndim {}", ndim);
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u32(&mut f)? as usize);
            }
            let numel: usize = shape.iter().product();
            let mut data = vec![0f32; numel];
            let bytes: &mut [u8] = unsafe {
                std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, numel * 4)
            };
            f.read_exact(bytes)?;
            store.insert(&name, Tensor::new(shape, data));
        }
        Ok(store)
    }
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("intfpqsim_test_io");
        let path = dir.join("ckpt.tns");
        let mut s = TensorStore::default();
        s.insert("b.weight", Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]));
        s.insert("a.scalar", Tensor::scalar(7.5));
        s.insert("empty", Tensor::zeros(vec![0]));
        s.save(&path).unwrap();
        let l = TensorStore::load(&path).unwrap();
        assert_eq!(l.order, vec!["b.weight", "a.scalar", "empty"]);
        assert_eq!(l.get("b.weight").unwrap().shape, vec![2, 3]);
        assert_eq!(l.get("a.scalar").unwrap().data, vec![7.5]);
        assert_eq!(l.get("empty").unwrap().len(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_bad_magic() {
        let dir = std::env::temp_dir().join("intfpqsim_test_io2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.tns");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(TensorStore::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
