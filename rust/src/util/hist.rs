//! Lock-free fixed-bucket log2 histogram for hot-path latency metrics.
//!
//! [`Hist`] is a 64-bucket power-of-two histogram over `u64` samples:
//! bucket `i` counts samples whose value lies in `[2^i, 2^(i+1))`
//! (bucket 0 additionally holds 0 and 1). Recording is a single relaxed
//! atomic increment plus a relaxed `fetch_max` — no locks, no
//! allocation, no ordering constraints — so it is safe to call from the
//! serve wire hot path, whose zero-steady-state-allocation contract is
//! pinned by `tests/proto_alloc.rs`.
//!
//! Percentiles are reconstructed exactly from the bucket counts by rank
//! walk: `percentile(p)` returns the upper edge of the bucket containing
//! the sample of rank `ceil(p·count)`, i.e. an upper bound on the true
//! p-quantile that is exact to the bucket resolution (a factor of 2).
//! For serving latencies spanning microseconds to seconds that is the
//! resolution operators actually read dashboards at, and it is the
//! price of a histogram whose record path is two relaxed atomics.
//!
//! `Hist::new()` is `const`, so histograms can live in `static`
//! registries (see `crate::serve::metrics`) with zero init cost.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of power-of-two buckets; covers the full `u64` range.
pub const BUCKETS: usize = 64;

/// A lock-free log2 histogram (see module docs).
pub struct Hist {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Hist {
    /// An empty histogram; `const`, so usable in `static` items.
    pub const fn new() -> Hist {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Hist {
            buckets: [ZERO; BUCKETS],
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Bucket index of `v`: `floor(log2(v))`, with 0 and 1 in bucket 0.
    #[inline]
    fn bucket(v: u64) -> usize {
        if v < 2 {
            0
        } else {
            63 - v.leading_zeros() as usize
        }
    }

    /// Record one sample. Relaxed atomics only; never allocates.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Samples recorded since construction (or the last [`Hist::reset`]).
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all recorded samples (wrapping on overflow).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Largest recorded sample (0 when empty).
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Zero every bucket and counter. Not atomic as a whole — callers
    /// (tests, loadgen run boundaries) serialize around it.
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }

    /// A consistent-enough copy of the current state for reporting.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut buckets = [0u64; BUCKETS];
        for (out, b) in buckets.iter_mut().zip(&self.buckets) {
            *out = b.load(Ordering::Relaxed);
        }
        // derive count from the buckets so the rank walk always has a
        // self-consistent total even under concurrent recording
        let count = buckets.iter().sum();
        HistSnapshot {
            buckets,
            count,
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }

    /// Upper bound on the `p`-quantile (`0.0 < p <= 1.0`); see the
    /// module docs for the reconstruction contract. 0 when empty.
    pub fn percentile(&self, p: f64) -> u64 {
        self.snapshot().percentile(p)
    }
}

impl Default for Hist {
    fn default() -> Hist {
        Hist::new()
    }
}

/// A point-in-time copy of a [`Hist`]'s buckets and counters.
#[derive(Debug, Clone)]
pub struct HistSnapshot {
    /// Per-bucket sample counts (bucket `i` covers `[2^i, 2^(i+1))`).
    pub buckets: [u64; BUCKETS],
    /// Total samples across all buckets.
    pub count: u64,
    /// Sum of all samples at snapshot time.
    pub sum: u64,
    /// Largest sample at snapshot time.
    pub max: u64,
}

impl HistSnapshot {
    /// Inclusive upper edge of bucket `i` (`u64::MAX` for the last).
    fn upper_edge(i: usize) -> u64 {
        if i >= BUCKETS - 1 {
            u64::MAX
        } else {
            (1u64 << (i + 1)) - 1
        }
    }

    /// Upper bound on the `p`-quantile by exact rank walk over the
    /// bucket counts. 0 when the histogram is empty.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((p * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                // never report past the true maximum
                return Self::upper_edge(i).min(self.max);
            }
        }
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_cover_powers_of_two() {
        assert_eq!(Hist::bucket(0), 0);
        assert_eq!(Hist::bucket(1), 0);
        assert_eq!(Hist::bucket(2), 1);
        assert_eq!(Hist::bucket(3), 1);
        assert_eq!(Hist::bucket(4), 2);
        assert_eq!(Hist::bucket(1023), 9);
        assert_eq!(Hist::bucket(1024), 10);
        assert_eq!(Hist::bucket(u64::MAX), 63);
    }

    #[test]
    fn counts_sum_and_max_accumulate() {
        let h = Hist::new();
        for v in [0, 1, 2, 100, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 1103);
        assert_eq!(h.max(), 1000);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.percentile(0.99), 0, "empty after reset");
    }

    #[test]
    fn percentiles_are_bucket_upper_bounds() {
        let h = Hist::new();
        // 100 samples of 10 (bucket 3, edge 15) and 1 sample of 1000
        for _ in 0..100 {
            h.record(10);
        }
        h.record(1000);
        assert_eq!(h.percentile(0.50), 15);
        assert_eq!(h.percentile(0.95), 15);
        // rank ceil(0.999 * 101) = 101 lands in the 1000 bucket, whose
        // edge (1023) is clamped to the recorded max
        assert_eq!(h.percentile(0.999), 1000);
        assert_eq!(h.percentile(1.0), 1000);
    }

    #[test]
    fn percentile_never_exceeds_max() {
        let h = Hist::new();
        for v in [3, 5, 9, 17, 900] {
            h.record(v);
        }
        for p in [0.5, 0.9, 0.95, 0.99, 1.0] {
            assert!(h.percentile(p) <= h.max(), "p{}: {}", p, h.percentile(p));
        }
    }

    #[test]
    fn concurrent_records_are_all_counted() {
        use std::sync::Arc;
        let h = Arc::new(Hist::new());
        let mut handles = Vec::new();
        for t in 0..4 {
            let h = Arc::clone(&h);
            handles.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    h.record(t * 1000 + i);
                }
            }));
        }
        for j in handles {
            j.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
        assert_eq!(h.snapshot().count, 4000);
    }
}
