//! Micro-benchmark timing substrate (criterion is not in the vendored
//! set). Warmup + fixed-iteration sampling with mean/p50/p99 stats; used
//! by `rust/benches/*` and the §Perf profiling pass.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn report(&self, name: &str, work_per_iter: Option<(f64, &str)>) -> String {
        let base = format!(
            "{:<44} {:>10.3} ms/iter  p50 {:>9.3}  p99 {:>9.3}  ({} iters)",
            name,
            self.mean_ns / 1e6,
            self.p50_ns / 1e6,
            self.p99_ns / 1e6,
            self.iters
        );
        match work_per_iter {
            Some((units, label)) => {
                let rate = units / (self.mean_ns / 1e9);
                format!("{}  {:>12.1} {}/s", base, rate, label)
            }
            None => base,
        }
    }
}

/// Run `f` with warmup, then time `iters` iterations individually.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let pct = |p: f64| samples[((samples.len() as f64 - 1.0) * p) as usize];
    BenchStats {
        iters,
        mean_ns: mean,
        p50_ns: pct(0.5),
        p99_ns: pct(0.99),
        min_ns: samples[0],
    }
}

/// Scoped wall-clock timer for coarse phase profiling.
///
/// On drop, the elapsed time goes to whichever sink is active: inside a
/// `serve::metrics::trace` context it is recorded into that span's
/// latency histogram (how the serve dispatcher times its batched
/// forwards); otherwise it is logged at debug level, the original
/// behavior everywhere else.
pub struct Scope {
    // `&'static str` keeps construction allocation-free — the serve
    // dispatcher opens a Scope per batch on its zero-alloc hot path
    name: &'static str,
    start: Instant,
}

impl Scope {
    pub fn new(name: &'static str) -> Self {
        Scope { name, start: Instant::now() }
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

impl Drop for Scope {
    fn drop(&mut self) {
        if let Some(slot) = crate::serve::metrics::active_trace() {
            let ns = self.start.elapsed().as_nanos() as u64;
            crate::serve::metrics::record_span(slot, ns);
        } else {
            crate::util::logging::log(
                2,
                &format!("{}: {:.1} ms", self.name, self.elapsed_ms()),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_ordered_stats() {
        let s = bench(2, 50, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.min_ns <= s.p50_ns);
        assert!(s.p50_ns <= s.p99_ns);
        assert!(s.mean_ns > 0.0);
        assert_eq!(s.iters, 50);
    }

    #[test]
    fn scope_emits_into_the_active_trace_span() {
        // the Admit slot is recorded by no other test in this binary,
        // so exact count deltas are race-free here
        use crate::serve::metrics::{self, SpanSlot};
        let before = metrics::snapshot().span_admit_ns.count;
        {
            let _trace = metrics::trace(SpanSlot::Admit);
            let _scope = Scope::new("test.scope");
        }
        let after = metrics::snapshot().span_admit_ns.count;
        assert_eq!(after, before + 1, "scope drop recorded into the span hist");
        // without a trace context the drop goes to the debug log only
        drop(Scope::new("test.scope.untraced"));
        assert_eq!(metrics::snapshot().span_admit_ns.count, after);
    }
}
