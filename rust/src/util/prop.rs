//! Mini property-testing harness (proptest is not in the vendored set).
//!
//! `check(name, cases, |rng| { ... })` runs a closure over many seeded
//! RNG streams; on failure it reports the failing seed so the case can
//! be replayed exactly (`PROP_SEED=<seed> cargo test <name>`).

use super::rng::Pcg64;

pub fn check<F: Fn(&mut Pcg64) -> Result<(), String>>(name: &str, cases: u64, f: F) {
    // Replay a single seed if requested.
    if let Ok(s) = std::env::var("PROP_SEED") {
        if let Ok(seed) = s.parse::<u64>() {
            let mut rng = Pcg64::new(seed);
            if let Err(msg) = f(&mut rng) {
                panic!("property {} failed on replay seed {}: {}", name, seed, msg);
            }
            return;
        }
    }
    for case in 0..cases {
        let seed = 0x5EED_0000 + case;
        let mut rng = Pcg64::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property {} failed (seed {}, case {}/{}): {}\n  replay: PROP_SEED={} cargo test",
                name, seed, case, cases, msg, seed
            );
        }
    }
}

/// Random f32 vector with heavy tails (exercises outliers/quant edges).
pub fn heavy_vec(rng: &mut Pcg64, len: usize, scale: f32) -> Vec<f32> {
    (0..len)
        .map(|_| rng.gaussian() * scale * rng.lognormal(1.0))
        .collect()
}

#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err(format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", 20, |rng| {
            let x = rng.f32();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("out of range: {}", x))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property always_fails failed")]
    fn check_reports_failures() {
        check("always_fails", 3, |_| Err("nope".into()));
    }
}
