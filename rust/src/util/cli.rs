//! Tiny argv parser (no clap in the vendored set).
//!
//! Grammar: `repro <command> [--flag] [--key value]... [positional]...`
//! Flags and options may appear in any order after the command.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse argv[1..]. `flag_names` lists options that take no value.
    pub fn parse(argv: &[String], flag_names: &[&str]) -> Result<Args, String> {
        let mut a = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(cmd) = it.next() {
            a.command = cmd.clone();
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&name) {
                    a.flags.push(name.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        return Err(format!("option --{} needs a value", name));
                    }
                    a.options.insert(name.to_string(), it.next().unwrap().clone());
                } else {
                    return Err(format!("option --{} needs a value", name));
                }
            } else {
                a.positional.push(tok.clone());
            }
        }
        Ok(a)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.options.get(name).map(|s| s.as_str()).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.options
            .get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f32(&self, name: &str, default: f32) -> f32 {
        self.options
            .get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.options
            .get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    /// Shared body of the strict numeric getters: absent → `default`;
    /// present but non-numeric or below `min` → a clear error naming
    /// the flag — no silent fallback, no panic.
    fn get_int_min<T>(&self, name: &str, default: T, min: T) -> Result<T, String>
    where
        T: std::str::FromStr + PartialOrd + std::fmt::Display + Copy,
    {
        match self.options.get(name) {
            None => Ok(default),
            Some(raw) => match raw.parse::<T>() {
                Ok(v) if v >= min => Ok(v),
                Ok(v) => Err(format!("--{} must be >= {}, got {}", name, min, v)),
                Err(_) => Err(format!(
                    "--{} needs a positive integer, got {:?}",
                    name, raw
                )),
            },
        }
    }

    /// Strict numeric option (see [`Args::get_int_min`]). Used for flags
    /// where a typo must not misconfigure the process (`--threads`,
    /// `--batch-window`, `--max-batch`, ...); the tolerant
    /// [`Args::get_usize`] remains for knobs where the default is always
    /// safe.
    pub fn get_usize_min(
        &self,
        name: &str,
        default: usize,
        min: usize,
    ) -> Result<usize, String> {
        self.get_int_min(name, default, min)
    }

    /// `u64` twin of [`Args::get_usize_min`].
    pub fn get_u64_min(&self, name: &str, default: u64, min: u64) -> Result<u64, String> {
        self.get_int_min(name, default, min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_command_options_flags() {
        let a = Args::parse(
            &sv(&["eval", "--model", "sim-opt-125m", "--force", "--steps=30", "extra"]),
            &["force"],
        )
        .unwrap();
        assert_eq!(a.command, "eval");
        assert_eq!(a.get("model", ""), "sim-opt-125m");
        assert_eq!(a.get_usize("steps", 0), 30);
        assert!(a.flag("force"));
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&sv(&["x", "--model"]), &[]).is_err());
        assert!(Args::parse(&sv(&["x", "--model", "--other", "v"]), &[]).is_err());
    }

    #[test]
    fn backend_and_threads_options() {
        // the exact global-flag shapes main.rs feeds to backend::configure
        let a = Args::parse(
            &sv(&["eval", "--backend", "pool", "--threads", "8", "--model", "m"]),
            &[],
        )
        .unwrap();
        assert_eq!(a.get("backend", "auto"), "pool");
        assert_eq!(a.get_usize("threads", 0), 8);
        // every registered backend name round-trips through the parser
        for name in ["scalar", "blocked", "simd", "threaded", "pool", "auto"] {
            let a = Args::parse(&sv(&["eval", "--backend", name]), &[]).unwrap();
            assert_eq!(a.get("backend", "auto"), name);
        }
        // `=` form; the tolerant getter still falls back on junk (main.rs
        // routes --threads through the strict get_usize_min instead — see
        // strict_numeric_flags_reject_zero_and_garbage); a dangling
        // --backend is a parse error
        let d = Args::parse(&sv(&["eval", "--backend=blocked", "--threads=junk"]), &[])
            .unwrap();
        assert_eq!(d.get("backend", "auto"), "blocked");
        assert_eq!(d.get_usize("threads", 0), 0);
        assert!(Args::parse(&sv(&["eval", "--threads"]), &[]).is_err());
    }

    #[test]
    fn executor_option() {
        // the exact global-flag shape main.rs feeds to executor::configure
        let a = Args::parse(&sv(&["eval", "--executor", "native"]), &[]).unwrap();
        assert_eq!(a.get("executor", "auto"), "native");
        for name in ["native", "pjrt", "auto"] {
            let a = Args::parse(&sv(&["eval", "--executor", name]), &[]).unwrap();
            assert_eq!(a.get("executor", "auto"), name);
        }
        let b = Args::parse(&sv(&["eval", "--executor=pjrt"]), &[]).unwrap();
        assert_eq!(b.get("executor", "auto"), "pjrt");
        assert!(Args::parse(&sv(&["eval", "--executor"]), &[]).is_err());
    }

    #[test]
    fn compute_option_is_strict() {
        // the exact global-flag shape main.rs feeds to
        // net::configure_compute
        let a = Args::parse(&sv(&["eval", "--compute", "int"]), &[]).unwrap();
        assert_eq!(a.get("compute", "qdq"), "int");
        for name in ["qdq", "int"] {
            let a = Args::parse(&sv(&["eval", "--compute", name]), &[]).unwrap();
            assert_eq!(a.get("compute", "qdq"), name);
            assert!(crate::model::net::parse_compute_mode(name).is_ok());
        }
        let b = Args::parse(&sv(&["eval", "--compute=qdq"]), &[]).unwrap();
        assert_eq!(b.get("compute", "qdq"), "qdq");
        assert!(Args::parse(&sv(&["eval", "--compute"]), &[]).is_err());
        // Regression (ISSUE 8 satellite): unknown values must be a loud
        // configuration error downstream, never a silent QDQ fallback —
        // the same discipline --backend and --executor already enforce.
        for junk in ["", "INT", "int8", "qdq ", "fused", "auto"] {
            let e = crate::model::net::parse_compute_mode(junk).unwrap_err();
            assert!(e.contains("unknown compute mode"), "{:?}: {}", junk, e);
            assert!(e.contains("qdq|int"), "{:?}: {}", junk, e);
        }
    }

    #[test]
    fn strict_numeric_flags_reject_zero_and_garbage() {
        // Regression (ISSUE 4 satellite): --threads and the serving
        // knobs (--batch-window/--max-batch/--queue-cap) must reject 0
        // and non-numeric values with a clear error instead of
        // panicking or silently falling back to a default.
        for flag in ["threads", "batch-window", "max-batch", "queue-cap"] {
            // absent -> the caller's default, untouched
            let a = Args::parse(&sv(&["serve"]), &[]).unwrap();
            assert_eq!(a.get_usize_min(flag, 7, 1).unwrap(), 7, "--{} absent", flag);
            assert_eq!(a.get_u64_min(flag, 9, 1).unwrap(), 9, "--{} absent", flag);
            // a valid value round-trips
            let a = Args::parse(&sv(&["serve", &format!("--{}", flag), "3"]), &[]).unwrap();
            assert_eq!(a.get_usize_min(flag, 7, 1).unwrap(), 3);
            assert_eq!(a.get_u64_min(flag, 9, 1).unwrap(), 3);
            // explicit 0 is rejected with a message naming the flag
            let a = Args::parse(&sv(&["serve", &format!("--{}", flag), "0"]), &[]).unwrap();
            let e = a.get_usize_min(flag, 7, 1).unwrap_err();
            assert!(e.contains(flag) && e.contains(">= 1"), "{}", e);
            assert!(a.get_u64_min(flag, 9, 1).is_err());
            // non-numeric is rejected, not silently defaulted
            for junk in ["junk", "-3", "2.5", ""] {
                let a = Args::parse(
                    &sv(&["serve", &format!("--{}={}", flag, junk)]),
                    &[],
                )
                .unwrap();
                let e = a.get_usize_min(flag, 7, 1).unwrap_err();
                assert!(e.contains(flag), "--{}={}: {}", flag, junk, e);
                assert!(a.get_u64_min(flag, 9, 1).is_err(), "--{}={}", flag, junk);
            }
        }
    }

    #[test]
    fn failure_domain_flags_are_strict() {
        // Regression (ISSUE 10 satellite): the failure-domain knobs
        // ride the same strict getters as the other serving flags — a
        // typo must be a loud configuration error, never a silent
        // default (a server with the wrong idle timeout looks healthy
        // until it reaps a live client).
        for flag in ["idle-timeout", "drain-timeout", "max-conns"] {
            // absent -> the caller's default, untouched
            let a = Args::parse(&sv(&["serve"]), &[]).unwrap();
            assert_eq!(a.get_u64_min(flag, 11, 1).unwrap(), 11, "--{} absent", flag);
            assert_eq!(a.get_usize_min(flag, 4, 1).unwrap(), 4, "--{} absent", flag);
            // a valid value round-trips
            let a =
                Args::parse(&sv(&["serve", &format!("--{}", flag), "250"]), &[]).unwrap();
            assert_eq!(a.get_u64_min(flag, 11, 1).unwrap(), 250);
            // 0 and garbage are rejected with a message naming the flag
            for junk in ["0", "junk", "-1", "1.5", ""] {
                let a = Args::parse(&sv(&["serve", &format!("--{}={}", flag, junk)]), &[])
                    .unwrap();
                let e = a.get_u64_min(flag, 11, 1).unwrap_err();
                assert!(e.contains(flag), "--{}={}: {}", flag, junk, e);
                assert!(a.get_usize_min(flag, 4, 1).is_err(), "--{}={}", flag, junk);
            }
        }
        // --faults routes through the fault-plan grammar: a valid spec
        // parses; zeros, unknown sites and malformed values are loud.
        let a = Args::parse(&sv(&["serve", "--faults", "seed=2,panic=7"]), &[]).unwrap();
        let plan = crate::serve::faults::FaultPlan::parse(a.get("faults", "")).unwrap();
        assert_eq!(plan.seed, 2);
        assert_eq!(plan.panic_every, Some(7));
        for junk in ["panic=0", "explode=1", "delay=3", "panic=x", ""] {
            assert!(
                crate::serve::faults::FaultPlan::parse(junk).is_err(),
                "fault spec {:?} must be rejected",
                junk
            );
        }
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&sv(&["run"]), &[]).unwrap();
        assert_eq!(a.get("missing", "dflt"), "dflt");
        assert_eq!(a.get_f32("lr", 0.5), 0.5);
    }
}
