//! Deterministic PRNG + distributions (no `rand` in the vendored set).
//!
//! PCG64 (xsl-rr-128/64) core with Gaussian (Ziggurat-free polar method),
//! log-normal, Zipf, and Fisher-Yates shuffling. Everything in the
//! simulator that touches randomness (init, corpora, eval sampling) goes
//! through this, keyed by explicit seeds, so runs are exactly
//! reproducible.

#[derive(Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        // splitmix-style seeding of the 128-bit state
        let mut s = Pcg64 {
            state: 0,
            inc: ((seed as u128).wrapping_mul(0x9E3779B97F4A7C15) << 1) | 1,
        };
        s.state = (seed as u128).wrapping_mul(0x2545F4914F6CDD1D) ^ 0x853c49e6748fea9b;
        s.next_u64();
        s.state = s.state.wrapping_add(seed as u128);
        s.next_u64();
        s
    }

    /// Derive an independent stream (e.g. per-tensor init).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }

    pub fn next_u64(&mut self) -> u64 {
        const MUL: u128 = 0x2360ed051fc65da44385df649fccf645;
        self.state = self.state.wrapping_mul(MUL).wrapping_add(self.inc);
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-crypto) purposes
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via the polar (Marsaglia) method.
    pub fn gaussian(&mut self) -> f32 {
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return (u * (-2.0 * s.ln() / s).sqrt()) as f32;
            }
        }
    }

    pub fn lognormal(&mut self, sigma: f32) -> f32 {
        (self.gaussian() * sigma).exp()
    }

    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample from explicit (unnormalized) weights.
    pub fn weighted(&mut self, w: &[f32]) -> usize {
        let total: f32 = w.iter().sum();
        let mut t = self.f32() * total;
        for (i, &wi) in w.iter().enumerate() {
            t -= wi;
            if t <= 0.0 {
                return i;
            }
        }
        w.len() - 1
    }
}

/// Zipf distribution over {0..n-1} with exponent `s` (token frequencies
/// in the synthetic corpus follow this, mirroring natural language).
pub struct Zipf {
    cdf: Vec<f32>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc as f32);
        }
        let total = *cdf.last().unwrap();
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.f32();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_distinct_streams() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        let mut c = Pcg64::new(8);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Pcg64::new(1);
        let n = 20000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg64::new(2);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg64::new(3);
        let n = 50000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let g = r.gaussian() as f64;
            s1 += g;
            s2 += g * g;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.05, "var {}", var);
    }

    #[test]
    fn zipf_is_monotone_decreasing() {
        let z = Zipf::new(100, 1.1);
        let mut r = Pcg64::new(4);
        let mut counts = [0usize; 100];
        for _ in 0..50000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[60]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Pcg64::new(9);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(1);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
