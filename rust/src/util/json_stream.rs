//! Single-pass, non-recursive, bounded-depth streaming JSON reader for
//! the serve wire path.
//!
//! [`StreamParser`] walks a byte buffer and yields [`Token`]s without
//! building a tree and without allocating: strings come back as
//! [`RawStr`] borrows of the *validated but still-escaped* input bytes,
//! and the caller decides whether to compare ([`RawStr::eq_str`]),
//! decode lazily ([`RawStr::chars`]) or append into a reused `String`
//! ([`RawStr::append_to`]). Nesting uses an explicit fixed state stack
//! — a `u64` bitmask of object-vs-array frames plus a depth counter —
//! so depth is a checked constant ([`MAX_DEPTH`]), not a thread stack
//! limit: `"[[[[…"` a million deep is a clean parse error, never a
//! stack overflow.
//!
//! The grammar is strict RFC 8259: numbers like `.5`, `1.`, `01` and a
//! bare `-` are rejected; `\u` escapes take exactly four hex digits (no
//! `+` sign); surrogate halves must pair (`\ud800A` is an error, not an
//! underflow); unescaped control characters and invalid UTF-8 in
//! strings are errors. The tree parser in [`super::json`] shares the
//! number and hex scanners, and a differential test corpus
//! (`tests/protocol_stream.rs`) holds the two parsers to identical
//! accept/reject decisions.

use std::fmt;

/// Maximum container nesting depth either JSON parser accepts. One
/// `u64` bitmask frame per level — the constant is checked at compile
/// time to fit.
pub const MAX_DEPTH: usize = 64;
const _: () = assert!(MAX_DEPTH <= 64);

/// A streaming parse error: a static message plus the byte offset it
/// was detected at. Formats like [`super::json::JsonError`] so wire
/// error strings are stable across the two parsers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StreamError {
    /// What went wrong (static so the error path never allocates a
    /// message body).
    pub msg: &'static str,
    /// Byte offset into the input where the error was detected.
    pub pos: usize,
}

impl fmt::Display for StreamError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for StreamError {}

/// One parse event. `Str`/`Key` borrow the input; everything else is a
/// plain scalar or a structural marker.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Token<'a> {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// An RFC 8259 number, parsed to f64 (overflow saturates to ±inf,
    /// exactly as the tree parser does).
    Num(f64),
    /// A string value, still escaped, validated.
    Str(RawStr<'a>),
    /// An object key, still escaped, validated. Always followed by the
    /// key's value token(s).
    Key(RawStr<'a>),
    /// `{`.
    ObjStart,
    /// `}`.
    ObjEnd,
    /// `[`.
    ArrStart,
    /// `]`.
    ArrEnd,
}

/// A validated-but-still-escaped string slice of the input buffer (the
/// bytes between the quotes). Decoding is lazy and allocation-free.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RawStr<'a> {
    raw: &'a [u8],
}

impl<'a> RawStr<'a> {
    /// The raw escaped bytes between the quotes.
    pub fn raw(&self) -> &'a [u8] {
        self.raw
    }

    /// Whether any `\` escape is present (the slow-path predicate).
    pub fn has_escapes(&self) -> bool {
        self.raw.contains(&b'\\')
    }

    /// Decoded characters, resolving escapes and surrogate pairs.
    pub fn chars(&self) -> RawChars<'a> {
        RawChars { raw: self.raw, i: 0 }
    }

    /// Decoded equality against a plain string, without allocating:
    /// escape-free inputs compare bytewise, escaped ones char-by-char.
    pub fn eq_str(&self, s: &str) -> bool {
        if !self.has_escapes() {
            self.raw == s.as_bytes()
        } else {
            self.chars().eq(s.chars())
        }
    }

    /// Append the decoded string to `out` (a reused buffer), without
    /// intermediate allocation.
    pub fn append_to(&self, out: &mut String) {
        if !self.has_escapes() {
            // validated UTF-8 during the scan; the check here is cheap
            // and keeps this fully safe-code
            if let Ok(s) = std::str::from_utf8(self.raw) {
                out.push_str(s);
                return;
            }
        }
        for c in self.chars() {
            out.push(c);
        }
    }
}

/// Decoding iterator over a [`RawStr`]. The scanner already validated
/// the bytes, so the defensive arms here (lone escape at end, bad
/// codepoint) map to U+FFFD instead of panicking — they are
/// unreachable for scanner-produced slices.
pub struct RawChars<'a> {
    raw: &'a [u8],
    i: usize,
}

impl Iterator for RawChars<'_> {
    type Item = char;

    fn next(&mut self) -> Option<char> {
        let b = *self.raw.get(self.i)?;
        if b == b'\\' {
            let e = match self.raw.get(self.i + 1) {
                Some(&e) => e,
                None => {
                    self.i = self.raw.len();
                    return Some('\u{FFFD}');
                }
            };
            self.i += 2;
            return Some(match e {
                b'"' => '"',
                b'\\' => '\\',
                b'/' => '/',
                b'b' => '\u{8}',
                b'f' => '\u{c}',
                b'n' => '\n',
                b'r' => '\r',
                b't' => '\t',
                b'u' => {
                    let cp = match hex4(self.raw, self.i) {
                        Some(cp) => cp,
                        None => {
                            self.i = self.raw.len();
                            return Some('\u{FFFD}');
                        }
                    };
                    self.i += 4;
                    if (0xD800..0xDC00).contains(&cp) {
                        // validated: a `\uXXXX` low half follows
                        let lo = hex4(self.raw, self.i + 2).unwrap_or(0xDC00);
                        self.i += 6;
                        // clamp keeps the arithmetic in range even for
                        // impossible (unvalidated) inputs, so this
                        // cannot underflow under overflow-checks
                        let lo = lo.clamp(0xDC00, 0xDFFF);
                        let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                        char::from_u32(c).unwrap_or('\u{FFFD}')
                    } else {
                        char::from_u32(cp).unwrap_or('\u{FFFD}')
                    }
                }
                _ => '\u{FFFD}',
            });
        }
        if b < 0x80 {
            self.i += 1;
            return Some(b as char);
        }
        let len = match b {
            0xC2..=0xDF => 2,
            0xE0..=0xEF => 3,
            0xF0..=0xF4 => 4,
            _ => {
                self.i += 1;
                return Some('\u{FFFD}');
            }
        };
        match self
            .raw
            .get(self.i..self.i + len)
            .and_then(|s| std::str::from_utf8(s).ok())
        {
            Some(s) => {
                self.i += len;
                s.chars().next()
            }
            None => {
                self.i += 1;
                Some('\u{FFFD}')
            }
        }
    }
}

/// What the state machine will accept next.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Expect {
    /// A value is required (after `:`, or at the very start).
    Value,
    /// A value or `]` (immediately after `[`).
    ValueOrArrEnd,
    /// A key or `}` (immediately after `{`).
    KeyOrObjEnd,
    /// `,` or the matching closer (after a complete value inside a
    /// container).
    CommaOrEnd,
    /// The top-level value is complete; only whitespace may remain.
    Done,
}

/// The non-recursive streaming parser. Frames live in `obj_mask` (bit
/// per level: 1 = object, 0 = array) + `depth`; there is no call-stack
/// recursion anywhere, so adversarial nesting cannot overflow the
/// reader thread's stack.
pub struct StreamParser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
    obj_mask: u64,
    expect: Expect,
}

impl<'a> StreamParser<'a> {
    /// Parser over one complete JSON document (for the wire: one line).
    pub fn new(b: &'a [u8]) -> StreamParser<'a> {
        StreamParser { b, i: 0, depth: 0, obj_mask: 0, expect: Expect::Value }
    }

    /// Current byte offset (for error reporting by callers).
    pub fn pos(&self) -> usize {
        self.i
    }

    fn err(&self, msg: &'static str) -> StreamError {
        StreamError { msg, pos: self.i }
    }

    fn ws(&mut self) {
        while matches!(self.b.get(self.i), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn top_is_obj(&self) -> bool {
        self.depth > 0 && (self.obj_mask >> (self.depth - 1)) & 1 == 1
    }

    fn after_value(&mut self) {
        self.expect = if self.depth == 0 { Expect::Done } else { Expect::CommaOrEnd };
    }

    fn pop(&mut self) -> Token<'a> {
        let tok = if self.top_is_obj() { Token::ObjEnd } else { Token::ArrEnd };
        self.i += 1;
        self.depth -= 1;
        self.after_value();
        tok
    }

    fn lit(&mut self, s: &'static [u8], tok: Token<'a>) -> Result<Token<'a>, StreamError> {
        if self.b[self.i..].starts_with(s) {
            self.i += s.len();
            self.after_value();
            Ok(tok)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn value_token(&mut self) -> Result<Token<'a>, StreamError> {
        match *self.b.get(self.i).ok_or_else(|| self.err("unexpected end of input"))? {
            b'{' => {
                if self.depth == MAX_DEPTH {
                    return Err(self.err("nesting depth exceeds limit"));
                }
                self.obj_mask |= 1 << self.depth;
                self.depth += 1;
                self.i += 1;
                self.expect = Expect::KeyOrObjEnd;
                Ok(Token::ObjStart)
            }
            b'[' => {
                if self.depth == MAX_DEPTH {
                    return Err(self.err("nesting depth exceeds limit"));
                }
                self.obj_mask &= !(1 << self.depth);
                self.depth += 1;
                self.i += 1;
                self.expect = Expect::ValueOrArrEnd;
                Ok(Token::ArrStart)
            }
            b'"' => {
                let s = self.scan_string()?;
                self.after_value();
                Ok(Token::Str(s))
            }
            b'n' => self.lit(b"null", Token::Null),
            b't' => self.lit(b"true", Token::Bool(true)),
            b'f' => self.lit(b"false", Token::Bool(false)),
            b'-' | b'0'..=b'9' => {
                let (n, end) = scan_number(self.b, self.i).map_err(|msg| self.err(msg))?;
                self.i = end;
                self.after_value();
                Ok(Token::Num(n))
            }
            _ => Err(self.err("unexpected character")),
        }
    }

    fn key_token(&mut self) -> Result<Token<'a>, StreamError> {
        let key = self.scan_string()?;
        self.ws();
        if self.b.get(self.i) != Some(&b':') {
            return Err(self.err("expected ':' after object key"));
        }
        self.i += 1;
        self.expect = Expect::Value;
        Ok(Token::Key(key))
    }

    /// Scan and fully validate one string, returning the raw escaped
    /// slice between the quotes. `self.i` must be at the opening `"`.
    fn scan_string(&mut self) -> Result<RawStr<'a>, StreamError> {
        self.i += 1; // opening quote
        let start = self.i;
        loop {
            let c = *self.b.get(self.i).ok_or_else(|| self.err("unterminated string"))?;
            match c {
                b'"' => {
                    let raw = &self.b[start..self.i];
                    self.i += 1;
                    return Ok(RawStr { raw });
                }
                b'\\' => {
                    let e = *self
                        .b
                        .get(self.i + 1)
                        .ok_or_else(|| self.err("unterminated string"))?;
                    self.i += 2;
                    match e {
                        b'"' | b'\\' | b'/' | b'b' | b'f' | b'n' | b'r' | b't' => {}
                        b'u' => {
                            let cp =
                                hex4(self.b, self.i).ok_or_else(|| self.err("bad \\u escape"))?;
                            self.i += 4;
                            if (0xD800..0xDC00).contains(&cp) {
                                // a high half must be immediately
                                // followed by an escaped low half
                                if self.b.get(self.i) != Some(&b'\\')
                                    || self.b.get(self.i + 1) != Some(&b'u')
                                {
                                    return Err(self.err("unpaired surrogate"));
                                }
                                let lo = hex4(self.b, self.i + 2)
                                    .ok_or_else(|| self.err("bad \\u escape"))?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("unpaired surrogate"));
                                }
                                self.i += 6;
                            } else if (0xDC00..0xE000).contains(&cp) {
                                return Err(self.err("unpaired surrogate"));
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                0x00..=0x1F => {
                    return Err(self.err("unescaped control character in string"));
                }
                0x20..=0x7F => self.i += 1,
                _ => {
                    let len = match c {
                        0xC2..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF4 => 4,
                        _ => return Err(self.err("bad utf8 in string")),
                    };
                    let bytes = self
                        .b
                        .get(self.i..self.i + len)
                        .ok_or_else(|| self.err("unterminated string"))?;
                    if std::str::from_utf8(bytes).is_err() {
                        return Err(self.err("bad utf8 in string"));
                    }
                    self.i += len;
                }
            }
        }
    }

    /// The next parse event, or `Ok(None)` exactly once at the clean
    /// end of a complete document.
    pub fn next_token(&mut self) -> Result<Option<Token<'a>>, StreamError> {
        self.ws();
        match self.expect {
            Expect::Done => {
                if self.i == self.b.len() {
                    Ok(None)
                } else {
                    Err(self.err("trailing data"))
                }
            }
            Expect::Value => self.value_token().map(Some),
            Expect::ValueOrArrEnd => {
                if self.b.get(self.i) == Some(&b']') {
                    Ok(Some(self.pop()))
                } else {
                    self.value_token().map(Some)
                }
            }
            Expect::KeyOrObjEnd => match self.b.get(self.i) {
                Some(b'}') => Ok(Some(self.pop())),
                Some(b'"') => self.key_token().map(Some),
                _ => Err(self.err("expected object key or '}'")),
            },
            Expect::CommaOrEnd => match self.b.get(self.i) {
                Some(b',') => {
                    self.i += 1;
                    self.ws();
                    if self.top_is_obj() {
                        if self.b.get(self.i) != Some(&b'"') {
                            return Err(self.err("expected object key after ','"));
                        }
                        self.key_token().map(Some)
                    } else {
                        self.value_token().map(Some)
                    }
                }
                Some(b'}') if self.top_is_obj() => Ok(Some(self.pop())),
                Some(b']') if !self.top_is_obj() => Ok(Some(self.pop())),
                _ => Err(self.err("expected ',' or end of container")),
            },
        }
    }
}

/// Walk a whole document for validity (accept/reject only). Shares the
/// differential corpus with the tree parser for inputs the `&str` tree
/// API cannot even represent (invalid UTF-8 on the wire).
pub fn validate(b: &[u8]) -> Result<(), StreamError> {
    let mut p = StreamParser::new(b);
    while p.next_token()?.is_some() {}
    Ok(())
}

/// Exactly four hex digits at `b[i..i+4]` (strict: no sign, no
/// whitespace — unlike `u32::from_str_radix`, which accepts `+`).
pub(crate) fn hex4(b: &[u8], i: usize) -> Option<u32> {
    let s = b.get(i..i + 4)?;
    let mut v: u32 = 0;
    for &c in s {
        let d = match c {
            b'0'..=b'9' => (c - b'0') as u32,
            b'a'..=b'f' => (c - b'a' + 10) as u32,
            b'A'..=b'F' => (c - b'A' + 10) as u32,
            _ => return None,
        };
        v = v * 16 + d;
    }
    Some(v)
}

/// Strict RFC 8259 number scanner shared by both parsers: optional `-`,
/// integer part with no leading zero, optional fraction and exponent
/// each requiring at least one digit. Returns the value and the index
/// one past the number. Overflow parses to ±inf (matching the tree
/// parser's historical behavior for `1e999`).
pub(crate) fn scan_number(b: &[u8], start: usize) -> Result<(f64, usize), &'static str> {
    let mut i = start;
    if b.get(i) == Some(&b'-') {
        i += 1;
    }
    match b.get(i) {
        Some(b'0') => {
            i += 1;
            if matches!(b.get(i), Some(b'0'..=b'9')) {
                return Err("leading zero in number");
            }
        }
        Some(b'1'..=b'9') => {
            while matches!(b.get(i), Some(b'0'..=b'9')) {
                i += 1;
            }
        }
        _ => return Err("bad number"),
    }
    if b.get(i) == Some(&b'.') {
        i += 1;
        if !matches!(b.get(i), Some(b'0'..=b'9')) {
            return Err("bad number");
        }
        while matches!(b.get(i), Some(b'0'..=b'9')) {
            i += 1;
        }
    }
    if matches!(b.get(i), Some(b'e') | Some(b'E')) {
        i += 1;
        if matches!(b.get(i), Some(b'+') | Some(b'-')) {
            i += 1;
        }
        if !matches!(b.get(i), Some(b'0'..=b'9')) {
            return Err("bad number");
        }
        while matches!(b.get(i), Some(b'0'..=b'9')) {
            i += 1;
        }
    }
    let txt = std::str::from_utf8(&b[start..i]).map_err(|_| "bad number")?;
    txt.parse::<f64>().map(|n| (n, i)).map_err(|_| "bad number")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Result<Vec<String>, StreamError> {
        let mut p = StreamParser::new(s.as_bytes());
        let mut out = Vec::new();
        while let Some(t) = p.next_token()? {
            out.push(match t {
                Token::Null => "null".to_string(),
                Token::Bool(b) => format!("{}", b),
                Token::Num(n) => format!("{}", n),
                Token::Str(s) => {
                    let mut d = String::new();
                    s.append_to(&mut d);
                    format!("str:{}", d)
                }
                Token::Key(k) => {
                    let mut d = String::new();
                    k.append_to(&mut d);
                    format!("key:{}", d)
                }
                Token::ObjStart => "{".to_string(),
                Token::ObjEnd => "}".to_string(),
                Token::ArrStart => "[".to_string(),
                Token::ArrEnd => "]".to_string(),
            });
        }
        Ok(out)
    }

    #[test]
    fn event_sequences() {
        assert_eq!(toks("null").unwrap(), ["null"]);
        assert_eq!(toks(" 42 ").unwrap(), ["42"]);
        assert_eq!(
            toks(r#"{"a": [1, true], "b": "x"}"#).unwrap(),
            ["{", "key:a", "[", "1", "true", "]", "key:b", "str:x", "}"]
        );
        assert_eq!(toks("[]").unwrap(), ["[", "]"]);
        assert_eq!(toks("{}").unwrap(), ["{", "}"]);
        assert_eq!(toks("[[],{}]").unwrap(), ["[", "[", "]", "{", "}", "]"]);
    }

    #[test]
    fn depth_is_a_checked_constant() {
        let deep_ok = format!("{}0{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(validate(deep_ok.as_bytes()).is_ok());
        let deep_bad = format!("{}0{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        let e = validate(deep_bad.as_bytes()).unwrap_err();
        assert_eq!(e.msg, "nesting depth exceeds limit");
        // a million-deep bomb is a clean error, not a stack overflow
        let bomb = "[".repeat(1_000_000);
        assert!(validate(bomb.as_bytes()).is_err());
    }

    #[test]
    fn strict_number_grammar() {
        for bad in ["01", "-01", "00", ".5", "1.", "-", "+1", "1e", "1e+", "1.e3", "0x10"] {
            assert!(validate(bad.as_bytes()).is_err(), "{:?} must be rejected", bad);
        }
        for good in ["0", "-0", "0.5", "1E+10", "123.456e-7", "9007199254740993"] {
            assert!(validate(good.as_bytes()).is_ok(), "{:?} must parse", good);
        }
        // overflow saturates like the tree parser
        assert_eq!(toks("1e999").unwrap(), ["inf"]);
    }

    #[test]
    fn string_validation_and_surrogates() {
        assert_eq!(toks(r#""a\nb""#).unwrap(), ["str:a\nb"]);
        assert_eq!(toks(r#""😀""#).unwrap(), ["str:😀"]);
        assert_eq!(toks(r#""𐀀""#).unwrap(), ["str:\u{10000}"]);
        assert_eq!(toks(r#""􏿿""#).unwrap(), ["str:\u{10FFFF}"]);
        for bad in [
            r#""\ud800A""#,   // high half followed by a plain char
            r#""\ud800""#,    // lone high half
            r#""\udc00""#,    // lone low half
            r#""\ud800\ud800""#, // high half paired with another high
            r#""\u+123""#,    // sign inside the hex digits
            r#""abc"#,        // unterminated
            r#""\"#,          // truncated escape
            r#""\u00""#,      // truncated hex
            r#""\q""#,        // unknown escape
            "\"a\tb\"",       // raw control char
        ] {
            assert!(validate(bad.as_bytes()).is_err(), "{:?} must be rejected", bad);
        }
        // 0x7F is not a control char per RFC 8259
        assert!(validate("\"\u{7f}\"".as_bytes()).is_ok());
        // invalid UTF-8 on the wire
        assert!(validate(b"\"\xff\xfe\"").is_err());
        assert!(validate(b"\"\xe2\x82\"").is_err(), "truncated utf8 sequence");
    }

    #[test]
    fn raw_str_eq_and_append() {
        let mut p = StreamParser::new(br#""plain""#);
        let Some(Token::Str(s)) = p.next_token().unwrap() else { panic!() };
        assert!(s.eq_str("plain"));
        assert!(!s.eq_str("plain2"));
        assert!(!s.has_escapes());

        let mut p = StreamParser::new(br#""aA\n""#);
        let Some(Token::Str(s)) = p.next_token().unwrap() else { panic!() };
        assert!(s.has_escapes());
        assert!(s.eq_str("aA\n"));
        let mut out = String::from("x");
        s.append_to(&mut out);
        assert_eq!(out, "xaA\n");
    }

    #[test]
    fn structural_rejects() {
        for bad in [
            "", "  ", "1 2", "[1,]", "{", "[", r#"{"a"}"#, r#"{"a":}"#, "{1:2}",
            r#"{"a":1,}"#, "[,1]", "]", "}", "nul", "tru", "falsy",
        ] {
            assert!(validate(bad.as_bytes()).is_err(), "{:?} must be rejected", bad);
        }
    }
}
