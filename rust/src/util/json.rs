//! Minimal-but-complete JSON parser and emitter (RFC 8259: full syntax,
//! UTF-8 strings with escapes, strict number grammar, f64 numbers).
//!
//! Used for the artifact manifest, quantizer golden tables, experiment
//! configs and reports — convenience-first tree values. The serve wire
//! path uses the allocation-free streaming reader in
//! [`super::json_stream`] instead; the two share the number and `\u`
//! hex scanners and are held to identical accept/reject decisions by a
//! differential test corpus. Recursion here is bounded by the same
//! [`super::json_stream::MAX_DEPTH`] so adversarial nesting is a parse
//! error, not a stack overflow. No serde in the vendored crate set.

use std::collections::BTreeMap;
use std::fmt;

use super::json_stream::{hex4, scan_number, MAX_DEPTH};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0, depth: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors (None on type mismatch) --
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// The value as a usize — `None` unless it is a finite,
    /// non-negative integer in range (negative or fractional numbers
    /// are never silently truncated).
    pub fn as_usize(&self) -> Option<usize> {
        let n = self.as_f64()?;
        if n.is_finite() && n >= 0.0 && n.fract() == 0.0 && n <= usize::MAX as f64 {
            Some(n as usize)
        } else {
            None
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// f32 vector from a JSON array of numbers.
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|v| v as f32).collect())
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 1-space indent (matching python json.dump).
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                // non-finite f64s have no JSON literal; emit null
                // (python json.dump's behavior under allow_nan=False is
                // an error — null keeps the document parseable, which
                // matters for wire lines)
                if !n.is_finite() {
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    nl(out, indent, depth + 1);
                    e.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    nl(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    nl(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    nl(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn nl(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    // container nesting level; bounded by MAX_DEPTH so the recursion
    // here can never overflow the thread stack
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn push_depth(&mut self) -> Result<(), JsonError> {
        if self.depth == MAX_DEPTH {
            return Err(self.err("nesting depth exceeds limit"));
        }
        self.depth += 1;
        Ok(())
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        self.push_depth()?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        self.push_depth()?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or_else(|| self.err("eof in string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or_else(|| self.err("eof in escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            // strict four-hex-digit scan shared with the
                            // streaming parser (from_str_radix would
                            // accept a sign here)
                            let cp =
                                hex4(self.b, self.i).ok_or_else(|| self.err("bad \\u"))?;
                            self.i += 4;
                            // surrogate pair: a high half must pair with
                            // a validated low half — an unchecked
                            // `lo - 0xDC00` would underflow on input
                            // like "\ud800A"
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let lo = hex4(self.b, self.i + 2)
                                        .ok_or_else(|| self.err("bad surrogate"))?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(self.err("unpaired surrogate"));
                                    }
                                    self.i += 6;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                                } else {
                                    return Err(self.err("unpaired surrogate"));
                                }
                            } else if (0xDC00..0xE000).contains(&cp) {
                                return Err(self.err("unpaired surrogate"));
                            } else {
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            s.push(ch);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c if c < 0x20 => {
                    return Err(self.err("unescaped control character in string"))
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // multi-byte UTF-8: copy the full sequence
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("bad utf8")),
                    };
                    let start = self.i - 1;
                    let bytes = self
                        .b
                        .get(start..start + len)
                        .ok_or_else(|| self.err("eof in utf8"))?;
                    let st = std::str::from_utf8(bytes).map_err(|_| self.err("bad utf8"))?;
                    s.push_str(st);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        // strict RFC 8259 scanner shared with the streaming parser:
        // `.5`, `1.`, `01` and a bare `-` are grammar errors, not
        // f64::parse's problem
        match scan_number(self.b, self.i) {
            Ok((n, end)) => {
                self.i = end;
                Ok(Json::Num(n))
            }
            Err(msg) => Err(self.err(msg)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":false}],"c":"x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x");
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn parse_unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let j = Json::parse("\"héllo — ok\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo — ok");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn dump_roundtrip() {
        let src = r#"{"a":[1,2.5,null,true],"s":"x\"y","n":-7}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Num(1.5).dump(), "1.5");
    }

    #[test]
    fn strict_number_grammar_regressions() {
        // each malformed form previously leaked through to f64::parse
        assert!(Json::parse(".5").is_err(), "leading dot");
        assert!(Json::parse("1.").is_err(), "trailing dot");
        assert!(Json::parse("01").is_err(), "leading zero");
        assert!(Json::parse("-").is_err(), "bare minus");
        assert!(Json::parse("-.5").is_err());
        assert!(Json::parse("1e").is_err(), "empty exponent");
        assert!(Json::parse(r#"{"id": 01}"#).is_err(), "leading zero in context");
        // the valid forms still parse
        assert_eq!(Json::parse("0").unwrap(), Json::Num(0.0));
        assert_eq!(Json::parse("-0.5e1").unwrap(), Json::Num(-5.0));
        assert_eq!(Json::parse("1E+2").unwrap(), Json::Num(100.0));
    }

    #[test]
    fn as_usize_never_truncates() {
        assert_eq!(Json::Num(3.0).as_usize(), Some(3));
        assert_eq!(Json::Num(0.0).as_usize(), Some(0));
        // negative, fractional and non-finite values are None — a
        // protocol field like "id": -3 must not silently become 0
        assert_eq!(Json::Num(-3.0).as_usize(), None);
        assert_eq!(Json::Num(1.5).as_usize(), None);
        assert_eq!(Json::Num(f64::NAN).as_usize(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_usize(), None);
        assert_eq!(Json::Str("3".into()).as_usize(), None);
    }

    #[test]
    fn non_finite_numbers_dump_as_null_and_roundtrip() {
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).dump(), "null");
        // the document a writer emits must parse back — previously
        // "inf"/"NaN" leaked out unquoted and the parser rejected them
        let j = Json::obj(vec![("x", Json::Num(f64::INFINITY)), ("y", Json::Num(1.0))]);
        let back = Json::parse(&j.dump()).unwrap();
        assert_eq!(back.get("x"), Some(&Json::Null));
        assert_eq!(back.get("y"), Some(&Json::Num(1.0)));
        let back = Json::parse(&j.pretty()).unwrap();
        assert_eq!(back.get("x"), Some(&Json::Null));
    }

    #[test]
    fn surrogate_halves_must_pair() {
        // "\ud800A": the old decoder computed lo - 0xDC00 with lo = 'A'
        // — an underflow (panic under overflow checks)
        assert!(Json::parse(r#""\ud800A""#).is_err());
        assert!(Json::parse(r#""\ud800""#).is_err(), "lone high half");
        assert!(Json::parse(r#""\udc00""#).is_err(), "lone low half");
        assert!(Json::parse(r#""\ud800\ud800""#).is_err(), "high paired with high");
        assert!(Json::parse(r#""\u+123""#).is_err(), "sign in hex digits");
        // valid escaped pairs still decode
        assert_eq!(
            Json::parse(r#""\ud800\udc00""#).unwrap(),
            Json::Str("\u{10000}".into())
        );
        assert_eq!(
            Json::parse(r#""\udbff\udfff""#).unwrap(),
            Json::Str("\u{10FFFF}".into())
        );
        assert_eq!(
            Json::parse(r#""\ud83d\ude00""#).unwrap(),
            Json::Str("😀".into())
        );
    }

    #[test]
    fn unescaped_control_chars_are_rejected() {
        assert!(Json::parse("\"a\tb\"").is_err());
        assert!(Json::parse("\"a\nb\"").is_err());
        assert_eq!(Json::parse(r#""a\tb""#).unwrap(), Json::Str("a\tb".into()));
    }

    #[test]
    fn nesting_depth_is_bounded() {
        let ok = format!("{}1{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(Json::parse(&ok).is_ok());
        let bad = format!("{}1{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        assert!(Json::parse(&bad).is_err());
    }
}
