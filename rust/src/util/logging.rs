//! Leveled stderr logging with wall-clock offsets from the process
//! epoch (pin it early with [`init_epoch`]).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

pub static LEVEL: AtomicU8 = AtomicU8::new(1); // 0=quiet 1=info 2=debug

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn set_level(level: u8) {
    LEVEL.store(level, Ordering::Relaxed);
}

/// Pin the log epoch to "now". `main` calls this first thing: without
/// it the epoch initializes lazily on the *first log line*, so every
/// `[  12.34s]` offset would measure from whenever something first
/// logged rather than from launch — silently hiding any quiet startup
/// phase (artifact prep, checkpoint loads) from the timeline.
/// Idempotent: later calls never move an already-pinned epoch.
pub fn init_epoch() {
    let _ = START.get_or_init(Instant::now);
}

pub fn elapsed() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

pub fn log(level: u8, msg: &str) {
    if LEVEL.load(Ordering::Relaxed) >= level {
        eprintln!("[{:8.2}s] {}", elapsed(), msg);
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log(1, &format!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logging::log(2, &format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_is_pinned_once_and_elapsed_advances_from_it() {
        // regression: elapsed() used to initialize the epoch lazily on
        // the first log, so pre-log wall time never showed in offsets
        init_epoch();
        let e1 = elapsed();
        std::thread::sleep(std::time::Duration::from_millis(15));
        init_epoch(); // idempotent: must NOT re-pin the epoch
        let e2 = elapsed();
        assert!(e2 - e1 >= 0.010, "elapsed advanced {:.4}s", e2 - e1);
        assert!(e2 >= 0.010, "epoch stayed pinned across init_epoch calls");
    }
}
