//! Leveled stderr logging with wall-clock offsets.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

pub static LEVEL: AtomicU8 = AtomicU8::new(1); // 0=quiet 1=info 2=debug

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn set_level(level: u8) {
    LEVEL.store(level, Ordering::Relaxed);
}

pub fn elapsed() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

pub fn log(level: u8, msg: &str) {
    if LEVEL.load(Ordering::Relaxed) >= level {
        eprintln!("[{:8.2}s] {}", elapsed(), msg);
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log(1, &format!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logging::log(2, &format!($($arg)*)) };
}
