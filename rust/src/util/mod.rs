//! Dependency-free substrates.
//!
//! The offline build restricts crates to the vendored set (`xla`,
//! `anyhow`), so the roles usually filled by serde/clap/rand/criterion
//! are implemented here from scratch and tested in-tree.

pub mod cli;
pub mod hist;
pub mod json;
pub mod json_stream;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod timer;
