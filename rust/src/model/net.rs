//! Host-side reference network: the forward (and backward) computation
//! of the three simulated architectures, mirroring
//! `python/compile/models/{opt,bert,vit}.py` + `common.py`.
//!
//! The native executor (`runtime::native`) reconstructs each artifact's
//! computation from the manifest with these functions: embedding (with
//! the log-normal outlier gains), pre-LN blocks whose four linears are
//! quantizer-wrapped (QDQ via `formats::`, wiring from the registry
//! mirror), fp32 attention internals, and the per-task heads. Every
//! matmul routes through the caller's tensor-backend handle, so the
//! `pool`/`simd` backends accelerate evaluation end to end.
//!
//! The inference hot path is **transpose-free and fused**: site weights
//! stay in their natural (dout, din) layout and are consumed row-major
//! by `Backend::qdq_matmul_t`, which applies smoothing + activation QDQ
//! inside the matmul's A-panel load ([`qlinear`]); attention scores and
//! the task heads use `Backend::matmul_t` the same way. Both kernels
//! are bit-identical to their unfused transposed references, so this
//! moves no output bit — the [`set_qdq_fusion`] toggle exists purely so
//! benches and the conformance harness can A/B the two paths.
//!
//! Training support is a hand-rolled reverse pass over a [`Tape`] of
//! forward intermediates. QDQ sites follow the PWL straight-through
//! estimator (paper Eqn 5); with ABFP the per-vector absmax clip makes
//! the PWL mask all-ones (`quantizers.py` notes), so gradients pass
//! through the QDQ unchanged — the only wirings the train artifacts use
//! (`fp32`, `qat_*`) are exactly those.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::ModelCfg;
use crate::runtime::registry::{QuantKind, QuantSpec, QuantWiring, RowQdq};
use crate::tensor::backend::Backend;
use crate::tensor::io::TensorStore;
use crate::tensor::Tensor;

const LN_EPS: f32 = 1e-5;
const MASK_NEG: f32 = -1e30;

/// Process-wide switch for the fused QDQ→matmul inference path
/// (`Backend::qdq_matmul_t` inside [`qlinear`]). On by default; benches
/// and the conformance harness flip it to A/B the fused kernels against
/// the unfused reference. Both paths produce identical bytes (the fused
/// kernel contract), so the toggle can never change results — only
/// allocation and throughput.
static QDQ_FUSION: AtomicBool = AtomicBool::new(true);

/// Enable/disable the fused inference path; returns the previous value.
pub fn set_qdq_fusion(on: bool) -> bool {
    QDQ_FUSION.swap(on, Ordering::Relaxed)
}

/// Whether [`qlinear`] takes the fused `qdq_matmul_t` path (inference
/// only — the training tape always materializes `x_q`).
pub fn qdq_fusion() -> bool {
    QDQ_FUSION.load(Ordering::Relaxed)
}

/// Which execution engine [`qlinear`] uses for quantized sites.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComputeMode {
    /// Simulated quantization (the default): dequantize back to f32 and
    /// run the f32 matmul — the fused `qdq_matmul_t` hot path.
    Qdq,
    /// True low-precision compute: static-int sites run the i8×i8→i32
    /// GEMM (`Backend::int_matmul_t`) over a prepacked [`IntSite`].
    /// Sites with no int prepack (ABFP / float formats / per-channel
    /// activation scales / smoothing) keep the QDQ path per-site, so
    /// the mode is a per-site dispatch, not an all-or-nothing switch.
    IntKernel,
}

/// Process-wide compute-mode cell, seeded once from `INTFPQSIM_COMPUTE`
/// (unset/empty → QDQ; unknown values log loudly and fall back, the
/// same forgiving-env / strict-flag split the backend selector uses).
fn compute_cell() -> &'static AtomicBool {
    use std::sync::OnceLock;
    static CELL: OnceLock<AtomicBool> = OnceLock::new();
    CELL.get_or_init(|| {
        let name = std::env::var("INTFPQSIM_COMPUTE").unwrap_or_default();
        let mode = if name.is_empty() {
            ComputeMode::Qdq
        } else {
            parse_compute_mode(&name).unwrap_or_else(|e| {
                crate::util::logging::log(1, &format!("{}; falling back to qdq", e));
                ComputeMode::Qdq
            })
        };
        AtomicBool::new(mode == ComputeMode::IntKernel)
    })
}

/// Parse a `--compute`/`INTFPQSIM_COMPUTE` value. Unknown names are a
/// loud error, mirroring the `--backend`/`--executor` strictness.
pub fn parse_compute_mode(name: &str) -> Result<ComputeMode, String> {
    match name {
        "qdq" => Ok(ComputeMode::Qdq),
        "int" => Ok(ComputeMode::IntKernel),
        other => Err(format!("unknown compute mode {:?} (expected qdq|int)", other)),
    }
}

/// Set the process-wide compute mode; returns the previous value.
pub fn set_compute_mode(m: ComputeMode) -> ComputeMode {
    let was = compute_cell().swap(m == ComputeMode::IntKernel, Ordering::Relaxed);
    if was {
        ComputeMode::IntKernel
    } else {
        ComputeMode::Qdq
    }
}

/// The compute mode [`qlinear`] dispatches on.
pub fn compute_mode() -> ComputeMode {
    if compute_cell().load(Ordering::Relaxed) {
        ComputeMode::IntKernel
    } else {
        ComputeMode::Qdq
    }
}

/// CLI entry: `--compute qdq|int`. Strict — unknown names error out.
pub fn configure_compute(name: &str) -> Result<(), String> {
    set_compute_mode(parse_compute_mode(name)?);
    Ok(())
}

/// Activation-temporary accounting for the fused-vs-unfused A/B benches:
/// cumulative bytes of quantized-activation temporaries requested by
/// [`qlinear`] since the last reset. The unfused path materializes the
/// full (N, din) copy per site; the fused path counts the backend's
/// actual peak panel footprint (`Backend::qdq_panel_rows` × din).
pub mod qdq_temp {
    use std::sync::atomic::{AtomicU64, Ordering};

    static BYTES: AtomicU64 = AtomicU64::new(0);

    pub fn reset() {
        BYTES.store(0, Ordering::Relaxed);
    }

    pub fn bytes() -> u64 {
        BYTES.load(Ordering::Relaxed)
    }

    pub(crate) fn add(b: u64) {
        BYTES.fetch_add(b, Ordering::Relaxed);
    }
}

/// Per-site compute-dispatch accounting: how many [`qlinear`] site
/// executions took the true int8 GEMM vs the simulated QDQ path (fused,
/// unfused or taped) since process start. `--compute int` eligibility
/// is per-site and otherwise silent; these counters make it observable
/// — the serve metrics plane (`serve::metrics`) surfaces them via the
/// `stats` wire verb, and the int share tells an operator how much of
/// the traffic actually ran low-precision. Relaxed atomics only, so
/// recording adds two instructions to a path that runs a matmul.
pub mod site_dispatch {
    use std::sync::atomic::{AtomicU64, Ordering};

    static INT: AtomicU64 = AtomicU64::new(0);
    static QDQ: AtomicU64 = AtomicU64::new(0);

    /// Zero both counters (test/bench boundaries).
    pub fn reset() {
        INT.store(0, Ordering::Relaxed);
        QDQ.store(0, Ordering::Relaxed);
    }

    /// `(int, qdq)` cumulative site dispatches. Monotone between
    /// resets; compare deltas, not absolutes.
    pub fn counts() -> (u64, u64) {
        (INT.load(Ordering::Relaxed), QDQ.load(Ordering::Relaxed))
    }

    pub(crate) fn note_int() {
        INT.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn note_qdq() {
        QDQ.fetch_add(1, Ordering::Relaxed);
    }
}

/// One quantized site, prepared for execution: the weight QDQ is
/// pre-applied and the weight kept in its natural (dout, din) row-major
/// layout — the hot loop reads its rows directly via
/// `Backend::qdq_matmul_t`/`matmul_t`, so no transposed copy is ever
/// built (neither at session prep nor per forward).
pub struct SiteCtx {
    pub wq: Tensor,
    pub bias: Vec<f32>,
    pub aq: QuantSpec,
    /// `aq` resolved against the site width once at build time
    /// (validation + static-scale precomputation out of the per-forward
    /// path) — the fused `qdq_matmul_t` A-panel prep kernel.
    pub row_aq: RowQdq,
    pub oq: QuantSpec,
    pub smooth: Option<Vec<f32>>,
    pub alpha: Option<Vec<f32>>,
    /// True low-precision prepack ([`ComputeMode::IntKernel`]): present
    /// only for sites whose wiring the int GEMM can execute (per-tensor
    /// static-int activations × per-channel-max int weights, no
    /// smoothing). Both representations are always built, so switching
    /// the compute mode mid-session needs no re-prep.
    pub int: Option<IntSite>,
}

/// One site's integer-GEMM state, built once at session prep from the
/// **raw** (pre-QDQ) weights: the i8 weight codes in natural (dout, din)
/// layout plus the quantization scales of both operands. The scales use
/// exactly the arithmetic of the QDQ kernels (`qmax / absmax` per weight
/// row, `qmax / alpha` per tensor for activations), so `codes / scale`
/// reproduces the QDQ path's dequantized values bit-for-bit and the
/// i32 GEMM's rescale `(acc as f32) / (sx * sw)` lands on the QDQ
/// result exactly wherever that f32 arithmetic is exact.
pub struct IntSite {
    /// Prepacked i8 weight codes, (dout, din) row-major.
    pub panel: crate::tensor::backend::QuantPanel,
    /// Per-output-channel weight scales (`qmax_w / row absmax`).
    pub w_scales: Vec<f32>,
    /// Per-tensor activation scale (`qmax_a / alpha`).
    pub x_scale: f32,
    /// Activation clamp bound (`IntFmt::qmax`, e.g. 127 for INT8).
    pub x_qmax: f32,
}

/// Layer index of a `l{i}.{kind}` site name.
fn site_layer(site: &str) -> Result<usize> {
    site.strip_prefix('l')
        .and_then(|rest| rest.split_once('.'))
        .and_then(|(li, _)| li.parse().ok())
        .with_context(|| format!("bad site name {:?}", site))
}

/// Build every site's execution context: effective per-layer wiring
/// (mixed-precision overrides), QDQ-transformed weights, smoothing and
/// clip-range runtime inputs.
pub fn build_sites(
    cfg: &ModelCfg,
    wiring: &QuantWiring,
    params: &TensorStore,
    smooth: &BTreeMap<String, Vec<f32>>,
    alpha: &BTreeMap<String, Vec<f32>>,
    be: &dyn Backend,
) -> Result<BTreeMap<String, SiteCtx>> {
    let mut out = BTreeMap::new();
    for site in &cfg.sites {
        let lw = wiring.for_layer(site_layer(&site.name)?, cfg.layers);
        let wname = crate::methods::site_weight_param(&site.name)?;
        let bname = crate::methods::site_bias_param(&site.name)?;
        let mut wq = params.expect(&wname)?.clone();
        let (_, din) = wq.dims2();
        anyhow::ensure!(
            din == site.dim,
            "site {} dim {} vs weight din {}",
            site.name,
            site.dim,
            din
        );
        let alpha_v = alpha.get(&site.name).cloned();
        let smooth_v = smooth.get(&site.name).cloned();
        // The int prepack quantizes the RAW weights — it must run
        // before the in-place weight QDQ below, with the same per-row
        // scale arithmetic, so its codes dequantize to exactly the
        // bytes the QDQ leaves behind.
        let int = int_site_for(&lw, &wq, din, alpha_v.as_deref(), smooth_v.is_some());
        lw.wq.apply_with(&mut wq.data, din, None, be)?;
        // Resolve the activation row kernel once per site: validation
        // and static-scale precomputation leave the per-forward path
        // entirely (errors surface here — still the first `run`, with
        // the same message the bulk path produced).
        let row_aq = lw
            .aq
            .row_kernel(din, alpha_v.as_deref())
            .with_context(|| format!("site {} activation quantizer", site.name))?;
        out.insert(
            site.name.clone(),
            SiteCtx {
                wq,
                bias: params.expect(&bname)?.data.clone(),
                aq: lw.aq,
                row_aq,
                oq: lw.oq,
                smooth: smooth_v,
                alpha: alpha_v,
                int,
            },
        );
    }
    Ok(out)
}

/// Build the [`IntSite`] prepack for one site, if (and only if) the
/// int GEMM can execute its wiring: per-tensor static-int activations
/// (`StaticInt` with an integer format and a scalar clip range),
/// per-channel-max integer weights (`WPcmaxInt`), and no smoothing
/// vector (the int activation front is one multiply per element;
/// folding a per-channel smooth multiply in would change the rounding,
/// so smoothed sites stay on the QDQ path). Everything else — ABFP,
/// float formats, per-channel activation scales — returns `None` and
/// keeps simulating.
fn int_site_for(
    lw: &QuantWiring,
    w_raw: &Tensor,
    din: usize,
    alpha: Option<&[f32]>,
    smoothed: bool,
) -> Option<IntSite> {
    use crate::formats::Format;
    if smoothed || lw.aq.kind != QuantKind::StaticInt || lw.wq.kind != QuantKind::WPcmaxInt {
        return None;
    }
    let (a_fmt, w_fmt) = match (lw.aq.fmt, lw.wq.fmt) {
        (Some(Format::Int(a)), Some(Format::Int(w))) => (a, w),
        _ => return None,
    };
    let a = alpha?;
    if a.len() != 1 {
        return None;
    }
    let x_qmax = a_fmt.qmax();
    let w_qmax = w_fmt.qmax();
    // i32 accumulator headroom: |acc| <= din * qmax_a * qmax_w. Sites
    // wide enough to overflow (din ≳ 133k at 8 bits) keep the QDQ path.
    if (din as f64) * (x_qmax as f64) * (w_qmax as f64) >= i32::MAX as f64 {
        return None;
    }
    // Per-tensor activation scale and per-row weight scales use exactly
    // the arithmetic of `formats::static_int_qdq_with` /
    // `pcmax_weight_qdq_with`, so codes / scale == the QDQ'd values.
    let clip = if a[0] > 0.0 { a[0] } else { 1.0 };
    let x_scale = x_qmax / clip;
    let (dout, k) = w_raw.dims2();
    debug_assert_eq!(k, din);
    let mut w_scales = Vec::with_capacity(dout);
    for r in 0..dout {
        let row = &w_raw.data[r * k..(r + 1) * k];
        let m = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let m = if m > 0.0 { m } else { 1.0 };
        w_scales.push(w_qmax / m);
    }
    let panel = crate::tensor::backend::QuantPanel::pack(w_raw, &w_scales, w_qmax);
    Some(IntSite { panel, w_scales, x_scale, x_qmax })
}

/// The data tensor feeding one forward pass.
pub enum NetInput<'a> {
    /// (B, S) token ids (opt/bert).
    Tokens(&'a [i32]),
    /// (B, H, W, C) pixels (vit).
    Images(&'a [f32]),
}

/// (batch, rows-per-batch-item) of the encoded sequence.
pub fn seq_rows(cfg: &ModelCfg) -> (usize, usize) {
    if cfg.arch == "vit" {
        let np = (cfg.image / cfg.patch.max(1)) * (cfg.image / cfg.patch.max(1));
        (cfg.batch, np + 1)
    } else {
        (cfg.batch, cfg.seq)
    }
}

// --- small dense helpers ---------------------------------------------------

fn col_sum(x: &Tensor) -> Vec<f32> {
    let (m, n) = x.dims2();
    let mut out = vec![0.0f32; n];
    for r in 0..m {
        for (o, &v) in out.iter_mut().zip(x.row(r)) {
            *o += v;
        }
    }
    out
}

fn add_assign(dst: &mut Tensor, src: &Tensor) {
    debug_assert_eq!(dst.shape, src.shape);
    for (d, &s) in dst.data.iter_mut().zip(src.data.iter()) {
        *d += s;
    }
}

fn add_slice(dst: &mut [f32], src: &[f32]) {
    for (d, &s) in dst.iter_mut().zip(src.iter()) {
        *d += s;
    }
}

/// Copy rows r0..r0+rows, cols c0..c0+cols out of a (_, stride) tensor.
fn take_block(x: &Tensor, r0: usize, rows: usize, c0: usize, cols: usize) -> Tensor {
    let (_, stride) = x.dims2();
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let src = &x.data[(r0 + r) * stride + c0..(r0 + r) * stride + c0 + cols];
        out[r * cols..(r + 1) * cols].copy_from_slice(src);
    }
    Tensor::new(vec![rows, cols], out)
}

/// dst[r0+r, c0..c0+cols] += block[r, :] into a (_, stride) tensor.
fn add_block(dst: &mut Tensor, block: &Tensor, r0: usize, c0: usize) {
    let (rows, cols) = block.dims2();
    let stride = dst.shape[1];
    for r in 0..rows {
        let d = &mut dst.data[(r0 + r) * stride + c0..(r0 + r) * stride + c0 + cols];
        add_slice(d, block.row(r));
    }
}

// --- layer norm ------------------------------------------------------------

pub struct LnTape {
    xhat: Tensor,
    inv_std: Vec<f32>,
}

/// Pre-LN layer norm (`common.py layer_norm`), population variance.
fn layer_norm(
    x: &Tensor,
    g: &[f32],
    b: &[f32],
    want_tape: bool,
) -> (Tensor, Option<LnTape>) {
    let (m, d) = x.dims2();
    let mut out = vec![0.0f32; m * d];
    let mut xhat = vec![0.0f32; if want_tape { m * d } else { 0 }];
    let mut inv_std = vec![0.0f32; if want_tape { m } else { 0 }];
    for r in 0..m {
        let row = x.row(r);
        let mut mu = 0.0f64;
        for &v in row {
            mu += v as f64;
        }
        let mu = (mu / d as f64) as f32;
        let mut var = 0.0f64;
        for &v in row {
            let c = (v - mu) as f64;
            var += c * c;
        }
        let var = (var / d as f64) as f32;
        let istd = 1.0 / (var + LN_EPS).sqrt();
        let dst = &mut out[r * d..(r + 1) * d];
        for j in 0..d {
            let xh = (row[j] - mu) * istd;
            dst[j] = xh * g[j] + b[j];
            if want_tape {
                xhat[r * d + j] = xh;
            }
        }
        if want_tape {
            inv_std[r] = istd;
        }
    }
    let tape = want_tape.then(|| LnTape {
        xhat: Tensor::new(vec![m, d], xhat),
        inv_std,
    });
    (Tensor::new(vec![m, d], out), tape)
}

/// dL/dx, dL/dg, dL/db of [`layer_norm`].
fn layer_norm_bwd(dy: &Tensor, lt: &LnTape, g: &[f32]) -> (Tensor, Vec<f32>, Vec<f32>) {
    let (m, d) = dy.dims2();
    let mut dx = vec![0.0f32; m * d];
    let mut dg = vec![0.0f32; d];
    let mut db = vec![0.0f32; d];
    for r in 0..m {
        let dyr = dy.row(r);
        let xh = lt.xhat.row(r);
        let istd = lt.inv_std[r];
        let mut m1 = 0.0f64; // mean(dxhat)
        let mut m2 = 0.0f64; // mean(dxhat * xhat)
        for j in 0..d {
            let dxh = dyr[j] * g[j];
            m1 += dxh as f64;
            m2 += (dxh * xh[j]) as f64;
            dg[j] += dyr[j] * xh[j];
            db[j] += dyr[j];
        }
        let m1 = (m1 / d as f64) as f32;
        let m2 = (m2 / d as f64) as f32;
        let dst = &mut dx[r * d..(r + 1) * d];
        for j in 0..d {
            let dxh = dyr[j] * g[j];
            dst[j] = istd * (dxh - m1 - xh[j] * m2);
        }
    }
    (Tensor::new(vec![m, d], dx), dg, db)
}

// --- quantizer-wrapped linear ----------------------------------------------

pub struct LinTape {
    /// (N, din) post-smooth, post-QDQ input — the matmul operand.
    xq: Tensor,
}

/// `common.py qlinear`: y = f_q^x(x · smooth) @ f_q^w(W)^T + b, with the
/// optional output quantizer f_q^y. `capture` collects the raw (pre-
/// smoothing, pre-quantizer) activations for the calibration engine.
///
/// Inference (no tape, [`qdq_fusion`] on — the default) runs the fused
/// hot path: smoothing + activation QDQ are applied to each row exactly
/// once inside the matmul's A-panel load (`Backend::qdq_matmul_t`), so
/// the full quantized (N, din) activation tensor is never materialized
/// and the weight is consumed row-major with no transpose. The training
/// tape needs the materialized `x_q`, so the taped path keeps the
/// unfused reference — both produce identical bytes (the fused kernel
/// contract, conformance-enforced per backend × thread count).
///
/// Under [`ComputeMode::IntKernel`], sites carrying an [`IntSite`]
/// prepack (static-int W8A8-style wirings) skip simulation entirely:
/// activations are quantized to i8 codes and `Backend::int_matmul_t`
/// accumulates in i32 — bit-identical to the QDQ reference wherever the
/// latter's f32 arithmetic is exact (power-of-two scales, sums inside
/// 2^24), within a few ULP elsewhere. Sites without a prepack keep the
/// QDQ path regardless of the mode.
fn qlinear(
    x: &Tensor,
    site: &SiteCtx,
    be: &dyn Backend,
    want_tape: bool,
    capture: Option<(&mut Vec<(String, Tensor)>, String)>,
) -> Result<(Tensor, Option<LinTape>)> {
    if let Some((cap, name)) = capture {
        cap.push((name, x.clone()));
    }
    let (n, din) = x.dims2();
    let (dout, w_din) = site.wq.dims2();
    anyhow::ensure!(w_din == din, "site weight din {} vs input width {}", w_din, din);
    anyhow::ensure!(site.bias.len() == dout, "bias len {} vs dout {}", site.bias.len(), dout);
    if let Some(sm) = &site.smooth {
        anyhow::ensure!(sm.len() == din, "smooth len {} vs din {}", sm.len(), din);
    }
    let (mut y, tape) = if !want_tape
        && compute_mode() == ComputeMode::IntKernel
        && site.int.is_some()
    {
        // True low-precision path: quantize the activation rows to i8
        // codes once (the only per-forward temporary — n*din bytes, a
        // quarter of even one f32 row panel per element) and run the
        // i8×i8→i32 GEMM over the session-prepacked weight codes. The
        // per-row × per-channel rescale happens in the C-row store.
        let is = site.int.as_ref().expect("int site checked above");
        site_dispatch::note_int();
        let mut codes = vec![0i8; n * din];
        crate::tensor::backend::quantize_rows_i8(&x.data, is.x_scale, is.x_qmax, &mut codes);
        let x_scales = vec![is.x_scale; n];
        qdq_temp::add((n * din + n * 4) as u64);
        (be.int_matmul_t(&codes, &x_scales, &is.panel, &is.w_scales), None)
    } else if !want_tape && qdq_fusion() {
        site_dispatch::note_qdq();
        let y = if site.smooth.is_none() && site.aq.kind == QuantKind::None {
            // nothing to prep: skip the panel copies entirely
            be.matmul_t(x, &site.wq)
        } else {
            // `row_aq` was resolved at build_sites time, so the prep
            // closure does zero validation/allocation per forward.
            let kern = &site.row_aq;
            let smooth = site.smooth.as_deref();
            qdq_temp::add((be.qdq_panel_rows().min(n.max(1)) * din * 4) as u64);
            let prep = move |row: &mut [f32]| {
                if let Some(sm) = smooth {
                    for (v, &s) in row.iter_mut().zip(sm.iter()) {
                        *v *= s;
                    }
                }
                kern.apply(row);
            };
            be.qdq_matmul_t(x, &prep, &site.wq)
        };
        (y, None)
    } else {
        // Unfused reference: materialize x_q (the tape operand).
        site_dispatch::note_qdq();
        let mut xq = x.clone();
        if let Some(sm) = &site.smooth {
            xq.scale_cols(sm);
        }
        site.aq.apply_with(&mut xq.data, din, site.alpha.as_deref(), be)?;
        qdq_temp::add((xq.len() * 4) as u64);
        let y = be.matmul_t(&xq, &site.wq);
        (y, want_tape.then(|| LinTape { xq }))
    };
    for r in 0..n {
        add_slice(y.row_mut(r), &site.bias);
    }
    if site.oq.kind != QuantKind::None {
        site.oq.apply_with(&mut y.data, dout, None, be)?;
    }
    Ok((y, tape))
}

/// Gradients of [`qlinear`] under the PWL straight-through estimator
/// with an all-ones mask (ABFP / no-quant wirings — the train configs).
fn qlinear_bwd(
    dy: &Tensor,
    lt: &LinTape,
    site: &SiteCtx,
    be: &dyn Backend,
) -> (Tensor, Tensor, Vec<f32>) {
    let db = col_sum(dy);
    // dW (dout, din) = dy^T @ x_q
    let dw = be.matmul(&dy.transpose(), &lt.xq);
    // dx (N, din) = dy @ W_q, then back through the smoothing multiply.
    // W_q is stored natural (dout, din), so this is one plain matmul —
    // the old `wq_t.transpose()` round-trip (materializing the weight a
    // second time every backward step) is gone; same bytes, zero copies.
    let mut dx = be.matmul(dy, &site.wq);
    if let Some(sm) = &site.smooth {
        dx.scale_cols(sm);
    }
    (dx, dw, db)
}

// --- attention --------------------------------------------------------------

pub struct AttnTape {
    q: Tensor,
    k: Tensor,
    v: Tensor,
    /// Softmax probabilities per (batch, head), each (S, S).
    probs: Vec<Tensor>,
}

/// One (batch item, head) attention: scores → causal mask → row softmax
/// → context. Returns the (S, hd) context block and the (S, S) softmax
/// probabilities (the tape record). This is the shared serial kernel of
/// both the sequential and the batched dispatch below, so the two paths
/// are bit-identical by construction.
///
/// The per-head Q/K/V rows are **contiguous hd-wide slices** of the
/// packed (N, 3d) qkv rows, so the kernel folds directly over those
/// views — the three per-(b, h) `take_block` copies the old hot path
/// materialized are gone. Scores fold the ascending-k `a == 0.0`-skip
/// dot of the `matmul_t` contract and the context accumulates in the
/// ikj order of the `matmul` contract, so every output bit matches the
/// old take_block + backend-matmul formulation on every backend.
fn attn_head(
    qkv: &Tensor,
    bi: usize,
    h: usize,
    s: usize,
    d: usize,
    hd: usize,
    causal: bool,
) -> (Tensor, Tensor) {
    use crate::tensor::backend::dot_skip;
    let scale = 1.0 / (hd as f32).sqrt();
    let stride = 3 * d;
    let (qo, ko, vo) = (h * hd, d + h * hd, 2 * d + h * hd);
    let row = |r: usize, off: usize| {
        let base = (bi * s + r) * stride + off;
        &qkv.data[base..base + hd]
    };
    // scores = scale * (q @ k^T); masked entries never feed a dot.
    let mut scores = Tensor::zeros(vec![s, s]);
    for i in 0..s {
        let q = row(i, qo);
        let jmax = if causal { i + 1 } else { s };
        let srow = scores.row_mut(i);
        for (j, slot) in srow.iter_mut().take(jmax).enumerate() {
            *slot = dot_skip(q, row(j, ko)) * scale;
        }
        for slot in srow.iter_mut().skip(jmax) {
            *slot = MASK_NEG;
        }
    }
    // row softmax with max-shift
    for i in 0..s {
        let row = scores.row_mut(i);
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
    // context = P @ V, accumulated over the strided V row views.
    let mut oh = Tensor::zeros(vec![s, hd]);
    for i in 0..s {
        let pr = &scores.data[i * s..(i + 1) * s];
        let crow = &mut oh.data[i * hd..(i + 1) * hd];
        for (p, &av) in pr.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            for (c, &bv) in crow.iter_mut().zip(row(p, vo).iter()) {
                *c += av * bv;
            }
        }
    }
    (oh, scores)
}

/// Multi-head attention over packed (N, 3d) qkv projections, fp32
/// internals (`common.py attention`).
///
/// The inference path (no tape) dispatches every (batch item, head)
/// block as one parallel wave through [`Backend::par_map_tensor`] —
/// batching the per-(b, h) matmuls instead of running B·H sequential
/// backend calls. Each wave job runs [`attn_head`], the same serial
/// kernel the taped path uses, so results are bit-identical to the
/// sequential loop on every backend (conformance-tested end to end by
/// the `run_batch` parity suite).
fn attention(
    qkv: &Tensor,
    b: usize,
    s: usize,
    heads: usize,
    causal: bool,
    be: &dyn Backend,
    want_tape: bool,
) -> (Tensor, Option<AttnTape>) {
    let d = qkv.shape[1] / 3;
    let hd = d / heads;
    let mut out = Tensor::zeros(vec![b * s, d]);
    if !want_tape && b * heads > 1 {
        let outs = be.par_map_tensor(b * heads, &|i| {
            attn_head(qkv, i / heads, i % heads, s, d, hd, causal).0
        });
        for (i, oh) in outs.iter().enumerate() {
            add_block(&mut out, oh, (i / heads) * s, (i % heads) * hd);
        }
        return (out, None);
    }
    let mut probs = Vec::with_capacity(if want_tape { b * heads } else { 0 });
    for bi in 0..b {
        for h in 0..heads {
            let (oh, scores) = attn_head(qkv, bi, h, s, d, hd, causal);
            add_block(&mut out, &oh, bi * s, h * hd);
            if want_tape {
                probs.push(scores);
            }
        }
    }
    let tape = want_tape.then(|| AttnTape {
        q: take_block(qkv, 0, b * s, 0, d),
        k: take_block(qkv, 0, b * s, d, d),
        v: take_block(qkv, 0, b * s, 2 * d, d),
        probs,
    });
    (out, tape)
}

/// d qkv (N, 3d) given d out (N, d).
fn attention_bwd(
    dout: &Tensor,
    at: &AttnTape,
    b: usize,
    s: usize,
    heads: usize,
    be: &dyn Backend,
) -> Tensor {
    let d = dout.shape[1];
    let hd = d / heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut dqkv = Tensor::zeros(vec![b * s, 3 * d]);
    for bi in 0..b {
        for h in 0..heads {
            let r0 = bi * s;
            let c = h * hd;
            let doh = take_block(dout, r0, s, c, hd);
            let ph = &at.probs[bi * heads + h];
            let kh = take_block(&at.k, r0, s, c, hd);
            let qh = take_block(&at.q, r0, s, c, hd);
            let vh = take_block(&at.v, r0, s, c, hd);
            // dV = P^T dO ; dP = dO V^T (transpose-free off row-major V)
            let dvh = be.matmul(&ph.transpose(), &doh);
            let dp = be.matmul_t(&doh, &vh);
            // softmax backward: dS = P ∘ (dP − rowsum(dP ∘ P))
            let mut ds = Tensor::zeros(vec![s, s]);
            for i in 0..s {
                let pr = ph.row(i);
                let dpr = dp.row(i);
                let mut dot = 0.0f64;
                for j in 0..s {
                    dot += (dpr[j] * pr[j]) as f64;
                }
                let dot = dot as f32;
                let dst = ds.row_mut(i);
                for j in 0..s {
                    dst[j] = pr[j] * (dpr[j] - dot);
                }
            }
            // masked positions have P == 0, so dS is already 0 there.
            let mut dqh = be.matmul(&ds, &kh);
            let mut dkh = be.matmul(&ds.transpose(), &qh);
            for v in dqh.data.iter_mut() {
                *v *= scale;
            }
            for v in dkh.data.iter_mut() {
                *v *= scale;
            }
            add_block(&mut dqkv, &dqh, r0, c);
            add_block(&mut dqkv, &dkh, r0, d + c);
            add_block(&mut dqkv, &dvh, r0, 2 * d + c);
        }
    }
    dqkv
}

// --- transformer block ------------------------------------------------------

pub struct BlockTape {
    ln1: LnTape,
    qkv: LinTape,
    attn: AttnTape,
    wo: LinTape,
    ln2: LnTape,
    fc1: LinTape,
    /// fc1 pre-activation (N, d_ff) for the ReLU mask.
    relu_in: Tensor,
    fc2: LinTape,
}

struct BlockSites<'a> {
    qkv: &'a SiteCtx,
    attn_out: &'a SiteCtx,
    fc1: &'a SiteCtx,
    fc2: &'a SiteCtx,
}

fn block_sites<'a>(
    sites: &'a BTreeMap<String, SiteCtx>,
    li: usize,
) -> Result<BlockSites<'a>> {
    let get = |kind: &str| {
        sites
            .get(&format!("l{}.{}", li, kind))
            .with_context(|| format!("site l{}.{} missing", li, kind))
    };
    Ok(BlockSites {
        qkv: get("qkv")?,
        attn_out: get("attn_out")?,
        fc1: get("fc1")?,
        fc2: get("fc2")?,
    })
}

/// Pre-LN transformer block (`common.py block`).
#[allow(clippy::too_many_arguments)]
fn block_fwd(
    x: Tensor,
    li: usize,
    cfg: &ModelCfg,
    params: &TensorStore,
    sites: &BTreeMap<String, SiteCtx>,
    causal: bool,
    be: &dyn Backend,
    want_tape: bool,
    capture: Option<&mut Vec<(String, Tensor)>>,
) -> Result<(Tensor, Option<BlockTape>)> {
    let (b, s) = seq_rows(cfg);
    let bs = block_sites(sites, li)?;
    let p = |n: &str| params.expect(&format!("l{}.{}", li, n));
    let mut cap = capture;

    let (h, t_ln1) = layer_norm(&x, &p("ln1_g")?.data, &p("ln1_b")?.data, want_tape);
    let (qkv, t_qkv) =
        qlinear(&h, bs.qkv, be, want_tape, cap_arg(&mut cap, format!("l{}.qkv", li)))?;
    let (a, t_attn) = attention(&qkv, b, s, cfg.heads, causal, be, want_tape);
    let (a2, t_wo) = qlinear(
        &a,
        bs.attn_out,
        be,
        want_tape,
        cap_arg(&mut cap, format!("l{}.attn_out", li)),
    )?;
    let mut x_mid = x;
    add_assign(&mut x_mid, &a2);

    let (h2, t_ln2) = layer_norm(&x_mid, &p("ln2_g")?.data, &p("ln2_b")?.data, want_tape);
    let (f1, t_fc1) =
        qlinear(&h2, bs.fc1, be, want_tape, cap_arg(&mut cap, format!("l{}.fc1", li)))?;
    let mut r = f1.clone();
    for v in r.data.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
    let (f2, t_fc2) =
        qlinear(&r, bs.fc2, be, want_tape, cap_arg(&mut cap, format!("l{}.fc2", li)))?;
    let mut x_out = x_mid;
    add_assign(&mut x_out, &f2);

    let tape = if want_tape {
        Some(BlockTape {
            ln1: t_ln1.unwrap(),
            qkv: t_qkv.unwrap(),
            attn: t_attn.unwrap(),
            wo: t_wo.unwrap(),
            ln2: t_ln2.unwrap(),
            fc1: t_fc1.unwrap(),
            relu_in: f1,
            fc2: t_fc2.unwrap(),
        })
    } else {
        None
    };
    Ok((x_out, tape))
}

/// Reborrow the optional capture sink for one `qlinear` call.
fn cap_arg<'x>(
    cap: &'x mut Option<&mut Vec<(String, Tensor)>>,
    name: String,
) -> Option<(&'x mut Vec<(String, Tensor)>, String)> {
    cap.as_mut().map(|c| (&mut **c, name))
}

#[allow(clippy::too_many_arguments)]
fn block_bwd(
    dx_out: Tensor,
    bt: &BlockTape,
    li: usize,
    cfg: &ModelCfg,
    params: &TensorStore,
    sites: &BTreeMap<String, SiteCtx>,
    grads: &mut TensorStore,
    be: &dyn Backend,
) -> Result<Tensor> {
    let (b, s) = seq_rows(cfg);
    let bs = block_sites(sites, li)?;
    let add_grad = |grads: &mut TensorStore, name: String, dw: Tensor| {
        add_assign(grads.get_mut(&name).unwrap(), &dw);
    };
    let add_vec = |grads: &mut TensorStore, name: String, dv: &[f32]| {
        add_slice(&mut grads.get_mut(&name).unwrap().data, dv);
    };

    // x_out = x_mid + fc2(relu(fc1(ln2(x_mid))))
    let (dr, dw_fc2, db_fc2) = qlinear_bwd(&dx_out, &bt.fc2, bs.fc2, be);
    add_grad(grads, format!("l{}.wfc2", li), dw_fc2);
    add_vec(grads, format!("l{}.bfc2", li), &db_fc2);
    let mut df1 = dr;
    for (g, &pre) in df1.data.iter_mut().zip(bt.relu_in.data.iter()) {
        if pre <= 0.0 {
            *g = 0.0;
        }
    }
    let (dh2, dw_fc1, db_fc1) = qlinear_bwd(&df1, &bt.fc1, bs.fc1, be);
    add_grad(grads, format!("l{}.wfc1", li), dw_fc1);
    add_vec(grads, format!("l{}.bfc1", li), &db_fc1);
    let g2 = &params.expect(&format!("l{}.ln2_g", li))?.data;
    let (dx_ln2, dg2, db2) = layer_norm_bwd(&dh2, &bt.ln2, g2);
    add_vec(grads, format!("l{}.ln2_g", li), &dg2);
    add_vec(grads, format!("l{}.ln2_b", li), &db2);
    let mut dx_mid = dx_out;
    add_assign(&mut dx_mid, &dx_ln2);

    // x_mid = x_in + wo(attention(qkv(ln1(x_in))))
    let (da, dw_wo, db_wo) = qlinear_bwd(&dx_mid, &bt.wo, bs.attn_out, be);
    add_grad(grads, format!("l{}.wo", li), dw_wo);
    add_vec(grads, format!("l{}.bo", li), &db_wo);
    let dqkv = attention_bwd(&da, &bt.attn, b, s, cfg.heads, be);
    let (dh, dw_qkv, db_qkv) = qlinear_bwd(&dqkv, &bt.qkv, bs.qkv, be);
    add_grad(grads, format!("l{}.wqkv", li), dw_qkv);
    add_vec(grads, format!("l{}.bqkv", li), &db_qkv);
    let g1 = &params.expect(&format!("l{}.ln1_g", li))?.data;
    let (dx_ln1, dg1, db1) = layer_norm_bwd(&dh, &bt.ln1, g1);
    add_vec(grads, format!("l{}.ln1_g", li), &dg1);
    add_vec(grads, format!("l{}.ln1_b", li), &db1);
    let mut dx_in = dx_mid;
    add_assign(&mut dx_in, &dx_ln1);
    Ok(dx_in)
}

// --- embeddings & heads -----------------------------------------------------

fn embed_tokens(cfg: &ModelCfg, params: &TensorStore, tokens: &[i32]) -> Result<Tensor> {
    let (b, s) = (cfg.batch, cfg.seq);
    anyhow::ensure!(tokens.len() == b * s, "tokens len {} vs {}x{}", tokens.len(), b, s);
    let d = cfg.d;
    let tok = params.expect("tok_emb")?;
    let pos = params.expect("pos_emb")?;
    let gain = &params.expect("emb_gain")?.data;
    let mut x = vec![0.0f32; b * s * d];
    for bi in 0..b {
        for si in 0..s {
            let t = tokens[bi * s + si];
            anyhow::ensure!(
                (0..cfg.vocab as i32).contains(&t),
                "token {} out of vocab {}",
                t,
                cfg.vocab
            );
            let e = &tok.data[t as usize * d..(t as usize + 1) * d];
            let pr = &pos.data[si * d..(si + 1) * d];
            let dst = &mut x[(bi * s + si) * d..(bi * s + si + 1) * d];
            for j in 0..d {
                dst[j] = e[j] * gain[j] + pr[j];
            }
        }
    }
    Ok(Tensor::new(vec![b * s, d], x))
}

/// `vit.py patchify`: (B, H, W, C) → (B·P, patch·patch·C).
fn patchify(cfg: &ModelCfg, images: &[f32]) -> Tensor {
    let (b, img, ch, p) = (cfg.batch, cfg.image, cfg.channels, cfg.patch);
    let per_side = img / p;
    let pdim = p * p * ch;
    let np = per_side * per_side;
    let mut out = vec![0.0f32; b * np * pdim];
    for bi in 0..b {
        for ph in 0..per_side {
            for pw in 0..per_side {
                let pi = ph * per_side + pw;
                let dst0 = (bi * np + pi) * pdim;
                for dy in 0..p {
                    for dx in 0..p {
                        let src0 = ((bi * img + ph * p + dy) * img + pw * p + dx) * ch;
                        let d0 = dst0 + (dy * p + dx) * ch;
                        out[d0..d0 + ch].copy_from_slice(&images[src0..src0 + ch]);
                    }
                }
            }
        }
    }
    Tensor::new(vec![b * np, pdim], out)
}

fn embed_images(
    cfg: &ModelCfg,
    params: &TensorStore,
    images: &[f32],
    be: &dyn Backend,
) -> Result<(Tensor, Tensor)> {
    let d = cfg.d;
    let (b, srows) = seq_rows(cfg);
    let np = srows - 1;
    anyhow::ensure!(
        images.len() == b * cfg.image * cfg.image * cfg.channels,
        "images len {} vs expected",
        images.len()
    );
    let patches = patchify(cfg, images);
    let patch_w = params.expect("patch_w")?; // (d, pdim)
    let patch_b = &params.expect("patch_b")?.data;
    let cls = &params.expect("cls_tok")?.data;
    let pos = params.expect("pos_emb")?; // (np + 1, d)
    let gain = &params.expect("emb_gain")?.data;
    let xe = be.matmul_t(&patches, patch_w);
    let mut x = vec![0.0f32; b * srows * d];
    for bi in 0..b {
        for r in 0..srows {
            let dst = &mut x[(bi * srows + r) * d..(bi * srows + r + 1) * d];
            if r == 0 {
                dst.copy_from_slice(cls);
            } else {
                let src = xe.row(bi * np + (r - 1));
                for j in 0..d {
                    dst[j] = src[j] + patch_b[j];
                }
            }
            let pr = &pos.data[r * d..(r + 1) * d];
            for j in 0..d {
                dst[j] = (dst[j] + pr[j]) * gain[j];
            }
        }
    }
    Ok((Tensor::new(vec![b * srows, d], x), patches))
}

// --- full forward -----------------------------------------------------------

pub struct Tape {
    blocks: Vec<BlockTape>,
    lnf: LnTape,
    /// Final layer-norm output (N, d) — the head input.
    pub xf: Tensor,
    /// vit only: (B·P, pdim) patch matrix for the patch-embed backward.
    patches: Option<Tensor>,
}

pub struct FwdOut {
    /// Task-head output: opt → logits (N, vocab); bert → span (N, 2);
    /// vit → class logits (B, classes).
    pub head: Tensor,
    pub tape: Option<Tape>,
    /// Raw per-site input activations in model order (capture purpose).
    pub capture: Vec<(String, Tensor)>,
}

pub fn forward(
    cfg: &ModelCfg,
    params: &TensorStore,
    sites: &BTreeMap<String, SiteCtx>,
    input: &NetInput,
    be: &dyn Backend,
    want_tape: bool,
    want_capture: bool,
) -> Result<FwdOut> {
    let causal = cfg.arch == "opt";
    let mut capture: Vec<(String, Tensor)> = Vec::new();
    let (mut x, patches) = match (cfg.arch.as_str(), input) {
        ("vit", NetInput::Images(img)) => {
            let (x, patches) = embed_images(cfg, params, img, be)?;
            (x, Some(patches))
        }
        ("vit", _) => bail!("vit model needs image input"),
        (_, NetInput::Tokens(toks)) => (embed_tokens(cfg, params, toks)?, None),
        (_, _) => bail!("{} model needs token input", cfg.arch),
    };
    let mut blocks = Vec::with_capacity(if want_tape { cfg.layers } else { 0 });
    for li in 0..cfg.layers {
        let cap = if want_capture { Some(&mut capture) } else { None };
        let (x2, bt) = block_fwd(x, li, cfg, params, sites, causal, be, want_tape, cap)?;
        x = x2;
        if let Some(bt) = bt {
            blocks.push(bt);
        }
    }
    let (xf, t_lnf) = layer_norm(
        &x,
        &params.expect("lnf_g")?.data,
        &params.expect("lnf_b")?.data,
        want_tape,
    );

    // Task heads read their (rows, d) weights row-major through
    // matmul_t: the per-forward transposed copies (a fresh (d, vocab)
    // tensor for the LM head on EVERY call) are gone — bit-identical by
    // the matmul_t contract.
    let head = match cfg.arch.as_str() {
        "opt" => {
            // tied LM head, unquantized: logits = xf @ tok_emb^T
            be.matmul_t(&xf, params.expect("tok_emb")?)
        }
        "bert" => {
            let mut span = be.matmul_t(&xf, params.expect("span_w")?);
            let sb = &params.expect("span_b")?.data;
            let n = span.shape[0];
            for r in 0..n {
                add_slice(span.row_mut(r), sb);
            }
            span
        }
        "vit" => {
            let (b, srows) = seq_rows(cfg);
            let xc = gather_cls(&xf, b, srows);
            let mut logits = be.matmul_t(&xc, params.expect("head_w")?);
            let hb = &params.expect("head_b")?.data;
            for r in 0..b {
                add_slice(logits.row_mut(r), hb);
            }
            logits
        }
        other => bail!("unknown arch {}", other),
    };

    let tape = want_tape.then(|| Tape {
        blocks,
        lnf: t_lnf.unwrap(),
        xf,
        patches,
    });
    Ok(FwdOut { head, tape, capture })
}

fn gather_cls(xf: &Tensor, b: usize, srows: usize) -> Tensor {
    let d = xf.shape[1];
    let mut out = vec![0.0f32; b * d];
    for bi in 0..b {
        out[bi * d..(bi + 1) * d].copy_from_slice(xf.row(bi * srows));
    }
    Tensor::new(vec![b, d], out)
}

// --- full backward ----------------------------------------------------------

/// Reverse pass: gradients of every parameter given `dhead` (the loss
/// gradient at the head output, same shape as `FwdOut::head`). Returns a
/// full-parameter-layout store (zeros where nothing flows).
pub fn backward(
    cfg: &ModelCfg,
    params: &TensorStore,
    sites: &BTreeMap<String, SiteCtx>,
    input: &NetInput,
    tape: &Tape,
    dhead: &Tensor,
    be: &dyn Backend,
) -> Result<TensorStore> {
    let mut grads = crate::model::zero_like_params(cfg);
    let (b, srows) = seq_rows(cfg);
    let n = b * srows;
    let d = cfg.d;

    // head backward → dxf
    let mut dx = match cfg.arch.as_str() {
        "opt" => {
            let tok = params.expect("tok_emb")?;
            let dxf = be.matmul(dhead, tok);
            let dtok = be.matmul(&dhead.transpose(), &tape.xf);
            add_assign(grads.get_mut("tok_emb").unwrap(), &dtok);
            dxf
        }
        "bert" => {
            let sw = params.expect("span_w")?;
            let dxf = be.matmul(dhead, sw);
            let dsw = be.matmul(&dhead.transpose(), &tape.xf);
            add_assign(grads.get_mut("span_w").unwrap(), &dsw);
            add_slice(&mut grads.get_mut("span_b").unwrap().data, &col_sum(dhead));
            dxf
        }
        "vit" => {
            let hw = params.expect("head_w")?;
            let xc = gather_cls(&tape.xf, b, srows);
            let dxc = be.matmul(dhead, hw); // (B, d)
            let dhw = be.matmul(&dhead.transpose(), &xc);
            add_assign(grads.get_mut("head_w").unwrap(), &dhw);
            add_slice(&mut grads.get_mut("head_b").unwrap().data, &col_sum(dhead));
            let mut dxf = Tensor::zeros(vec![n, d]);
            for bi in 0..b {
                dxf.row_mut(bi * srows).copy_from_slice(dxc.row(bi));
            }
            dxf
        }
        other => bail!("unknown arch {}", other),
    };

    // final LN
    let (dx2, dgf, dbf) = layer_norm_bwd(&dx, &tape.lnf, &params.expect("lnf_g")?.data);
    add_slice(&mut grads.get_mut("lnf_g").unwrap().data, &dgf);
    add_slice(&mut grads.get_mut("lnf_b").unwrap().data, &dbf);
    dx = dx2;

    // blocks, in reverse
    anyhow::ensure!(tape.blocks.len() == cfg.layers, "tape missing block records");
    for li in (0..cfg.layers).rev() {
        dx = block_bwd(dx, &tape.blocks[li], li, cfg, params, sites, &mut grads, be)?;
    }

    // embedding backward
    match (cfg.arch.as_str(), input) {
        ("vit", NetInput::Images(_)) => {
            let gain = params.expect("emb_gain")?.data.clone();
            let np = srows - 1;
            // x = (concat(cls, patch_embed) + pos) * gain
            let mut dpre = dx;
            for r in 0..n {
                let row = dpre.row_mut(r);
                for j in 0..d {
                    row[j] *= gain[j];
                }
            }
            {
                let dpos = grads.get_mut("pos_emb").unwrap();
                for bi in 0..b {
                    for r in 0..srows {
                        let src = dpre.row(bi * srows + r);
                        add_slice(&mut dpos.data[r * d..(r + 1) * d], src);
                    }
                }
            }
            {
                let dcls = grads.get_mut("cls_tok").unwrap();
                for bi in 0..b {
                    add_slice(&mut dcls.data, dpre.row(bi * srows));
                }
            }
            // patch rows: xe = patches @ patch_w^T + patch_b
            let mut dxe = vec![0.0f32; b * np * d];
            for bi in 0..b {
                for r in 0..np {
                    dxe[(bi * np + r) * d..(bi * np + r + 1) * d]
                        .copy_from_slice(dpre.row(bi * srows + r + 1));
                }
            }
            let dxe = Tensor::new(vec![b * np, d], dxe);
            let patches = tape.patches.as_ref().context("vit tape missing patches")?;
            let dpw = be.matmul(&dxe.transpose(), patches);
            add_assign(grads.get_mut("patch_w").unwrap(), &dpw);
            add_slice(&mut grads.get_mut("patch_b").unwrap().data, &col_sum(&dxe));
        }
        (_, NetInput::Tokens(tokens)) => {
            let gain = params.expect("emb_gain")?.data.clone();
            let (bsz, s) = (cfg.batch, cfg.seq);
            {
                let dtok = grads.get_mut("tok_emb").unwrap();
                for r in 0..bsz * s {
                    let t = tokens[r] as usize;
                    let src = dx.row(r);
                    let dst = &mut dtok.data[t * d..(t + 1) * d];
                    for j in 0..d {
                        dst[j] += src[j] * gain[j];
                    }
                }
            }
            {
                let dpos = grads.get_mut("pos_emb").unwrap();
                for bi in 0..bsz {
                    for si in 0..s {
                        add_slice(
                            &mut dpos.data[si * d..(si + 1) * d],
                            dx.row(bi * s + si),
                        );
                    }
                }
            }
        }
        _ => bail!("input kind does not match arch {}", cfg.arch),
    }

    Ok(grads)
}

// --- losses ------------------------------------------------------------------

/// Sum of next-token NLLs (`opt.py nll_sum`): positions 0..S-2 predict
/// tokens 1..S-1. Optionally also the gradient w.r.t. the (N, V) logits
/// (softmax − onehot at predicting positions, zero at the last one).
pub fn nll_sum_and_grad(
    logits: &Tensor,
    tokens: &[i32],
    b: usize,
    s: usize,
    want_grad: bool,
) -> (f64, Option<Tensor>) {
    let v = logits.shape[1];
    let mut total = 0.0f64;
    let mut grad = want_grad.then(|| Tensor::zeros(vec![b * s, v]));
    for bi in 0..b {
        for si in 0..s - 1 {
            let r = bi * s + si;
            let row = logits.row(r);
            let tgt = tokens[bi * s + si + 1] as usize;
            let mx = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
            let mut sum = 0.0f64;
            for &z in row {
                sum += ((z - mx) as f64).exp();
            }
            let lse = sum.ln();
            total += lse - ((row[tgt] - mx) as f64);
            if let Some(g) = grad.as_mut() {
                let gr = g.row_mut(r);
                for (j, &z) in row.iter().enumerate() {
                    gr[j] = (((z - mx) as f64).exp() / sum) as f32;
                }
                gr[tgt] -= 1.0;
            }
        }
    }
    (total, grad)
}

/// Mean softmax cross-entropy over rows of (R, C) logits, plus the
/// gradient (softmax − onehot) / R.
pub fn softmax_ce_mean(
    logits: &Tensor,
    targets: &[i32],
    want_grad: bool,
) -> (f64, Option<Tensor>) {
    let (rows, c) = logits.dims2();
    let mut total = 0.0f64;
    let mut grad = want_grad.then(|| Tensor::zeros(vec![rows, c]));
    for r in 0..rows {
        let row = logits.row(r);
        let tgt = targets[r] as usize;
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
        let mut sum = 0.0f64;
        for &z in row {
            sum += ((z - mx) as f64).exp();
        }
        let lse = sum.ln();
        total += lse - ((row[tgt] - mx) as f64);
        if let Some(g) = grad.as_mut() {
            let gr = g.row_mut(r);
            for (j, &z) in row.iter().enumerate() {
                gr[j] = ((((z - mx) as f64).exp() / sum) / rows as f64) as f32;
            }
            gr[tgt] -= 1.0 / rows as f32;
        }
    }
    (total / rows as f64, grad)
}

/// LM training loss (`aot.py lm_loss`): nll_sum / (B·(S−1)), with the
/// logits gradient scaled the same way.
pub fn lm_loss_and_grad(
    logits: &Tensor,
    tokens: &[i32],
    b: usize,
    s: usize,
    want_grad: bool,
) -> (f64, Option<Tensor>) {
    let denom = (b * (s - 1)) as f64;
    let (nll, mut grad) = nll_sum_and_grad(logits, tokens, b, s, want_grad);
    if let Some(g) = grad.as_mut() {
        let inv = (1.0 / denom) as f32;
        for v in g.data.iter_mut() {
            *v *= inv;
        }
    }
    (nll / denom, grad)
}

/// Span-QA training loss (`bert.py span_loss`): the mean of the start-
/// and end-position cross-entropies over a (N, 2) span-logit head.
pub fn bert_span_loss_and_grad(
    span: &Tensor,
    b: usize,
    s: usize,
    starts: &[i32],
    ends: &[i32],
    want_grad: bool,
) -> (f64, Option<Tensor>) {
    // Column c of `span` is a (B, S) logit matrix over positions.
    let unpack = |c: usize| {
        let mut m = vec![0.0f32; b * s];
        for (r, slot) in m.iter_mut().enumerate() {
            *slot = span.data[r * 2 + c];
        }
        Tensor::new(vec![b, s], m)
    };
    let (ls, gs) = softmax_ce_mean(&unpack(0), starts, want_grad);
    let (le, ge) = softmax_ce_mean(&unpack(1), ends, want_grad);
    let loss = 0.5 * (ls + le);
    let grad = want_grad.then(|| {
        let (gs, ge) = (gs.unwrap(), ge.unwrap());
        let mut g = Tensor::zeros(vec![b * s, 2]);
        for (r, pair) in g.data.chunks_mut(2).enumerate() {
            pair[0] = 0.5 * gs.data[r];
            pair[1] = 0.5 * ge.data[r];
        }
        g
    });
    (loss, grad)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init_params;
    use crate::runtime::registry::{quant_config, ModelDef};
    use crate::util::rng::Pcg64;

    fn tiny(arch: &'static str) -> ModelCfg {
        let (task, vocab, image, patch, channels, classes) = match arch {
            "opt" => ("lm", 12, 0, 0, 0, 0),
            "bert" => ("span_qa", 12, 0, 0, 0, 0),
            _ => ("image_cls", 0, 8, 4, 3, 5),
        };
        ModelDef {
            name: "tiny",
            arch,
            task,
            stands_for: "",
            vocab,
            d: 8,
            l: 2,
            heads: 2,
            seq: if arch == "vit" { 0 } else { 6 },
            batch: 2,
            image,
            patch,
            channels,
            classes,
        }
        .to_model_cfg()
    }

    fn fp32_sites(
        cfg: &ModelCfg,
        params: &TensorStore,
    ) -> BTreeMap<String, SiteCtx> {
        let be = crate::tensor::backend::active();
        build_sites(
            cfg,
            &quant_config("fp32").unwrap(),
            params,
            &BTreeMap::new(),
            &BTreeMap::new(),
            be.as_ref(),
        )
        .unwrap()
    }

    fn rand_tokens(cfg: &ModelCfg, seed: u64) -> Vec<i32> {
        let mut rng = Pcg64::new(seed);
        (0..cfg.batch * cfg.seq).map(|_| rng.below(cfg.vocab) as i32).collect()
    }

    /// Forward + task loss for one arch, used by the finite-difference
    /// checks (always fresh sites so weight perturbations take effect).
    fn loss_of(cfg: &ModelCfg, params: &TensorStore, input: &NetInput, aux: &[i32]) -> f64 {
        let be = crate::tensor::backend::active();
        let sites = fp32_sites(cfg, params);
        let fwd = forward(cfg, params, &sites, input, be.as_ref(), false, false).unwrap();
        match cfg.arch.as_str() {
            "opt" => match input {
                NetInput::Tokens(t) => {
                    lm_loss_and_grad(&fwd.head, t, cfg.batch, cfg.seq, false).0
                }
                _ => unreachable!(),
            },
            "bert" => {
                let (starts, ends) = aux.split_at(cfg.batch);
                bert_span_loss_and_grad(&fwd.head, cfg.batch, cfg.seq, starts, ends, false).0
            }
            _ => softmax_ce_mean(&fwd.head, aux, false).0,
        }
    }

    fn check_grads(cfg: &ModelCfg, input: &NetInput, aux: &[i32], probe: &[&str]) {
        let be = crate::tensor::backend::active();
        let params = init_params(cfg, 3);
        let sites = fp32_sites(cfg, &params);
        let fwd = forward(cfg, &params, &sites, input, be.as_ref(), true, false).unwrap();
        let (_, dhead) = match cfg.arch.as_str() {
            "opt" => match input {
                NetInput::Tokens(t) => lm_loss_and_grad(&fwd.head, t, cfg.batch, cfg.seq, true),
                _ => unreachable!(),
            },
            "bert" => {
                let (starts, ends) = aux.split_at(cfg.batch);
                bert_span_loss_and_grad(&fwd.head, cfg.batch, cfg.seq, starts, ends, true)
            }
            _ => softmax_ce_mean(&fwd.head, aux, true),
        };
        let grads = backward(
            cfg,
            &params,
            &sites,
            input,
            fwd.tape.as_ref().unwrap(),
            &dhead.unwrap(),
            be.as_ref(),
        )
        .unwrap();

        let mut rng = Pcg64::new(17);
        let mut checked = 0usize;
        for &pname in probe {
            let len = params.get(pname).unwrap().data.len();
            for _ in 0..3 {
                let idx = rng.below(len);
                let eps = 1e-2f32;
                let mut pp = params.clone();
                pp.get_mut(pname).unwrap().data[idx] += eps;
                let lp = loss_of(cfg, &pp, input, aux);
                let mut pm = params.clone();
                pm.get_mut(pname).unwrap().data[idx] -= eps;
                let lm = loss_of(cfg, &pm, input, aux);
                let num = (lp - lm) / (2.0 * eps as f64);
                let ana = grads.get(pname).unwrap().data[idx] as f64;
                let tol = 0.12 * num.abs().max(ana.abs()) + 3e-3;
                assert!(
                    (num - ana).abs() <= tol,
                    "{}[{}]: numeric {} vs analytic {}",
                    pname,
                    idx,
                    num,
                    ana
                );
                checked += 1;
            }
        }
        assert!(checked >= 3 * probe.len());
    }

    #[test]
    fn opt_gradients_match_finite_difference() {
        let cfg = tiny("opt");
        let tokens = rand_tokens(&cfg, 5);
        check_grads(
            &cfg,
            &NetInput::Tokens(&tokens),
            &[],
            &[
                "tok_emb", "pos_emb", "l0.wqkv", "l0.bqkv", "l0.wo", "l1.wfc1",
                "l1.wfc2", "l1.bfc2", "l0.ln1_b", "lnf_g", "lnf_b",
            ],
        );
    }

    #[test]
    fn bert_gradients_match_finite_difference() {
        let cfg = tiny("bert");
        let tokens = rand_tokens(&cfg, 6);
        let mut rng = Pcg64::new(7);
        let mut aux: Vec<i32> =
            (0..cfg.batch).map(|_| rng.below(cfg.seq) as i32).collect();
        aux.extend((0..cfg.batch).map(|_| rng.below(cfg.seq) as i32));
        check_grads(
            &cfg,
            &NetInput::Tokens(&tokens),
            &aux,
            &["span_w", "span_b", "l0.wqkv", "l1.wo", "l0.wfc1", "tok_emb"],
        );
    }

    #[test]
    fn vit_gradients_match_finite_difference() {
        let cfg = tiny("vit");
        let mut rng = Pcg64::new(8);
        let images: Vec<f32> = (0..cfg.batch * cfg.image * cfg.image * cfg.channels)
            .map(|_| rng.gaussian())
            .collect();
        let labels: Vec<i32> =
            (0..cfg.batch).map(|_| rng.below(cfg.classes) as i32).collect();
        check_grads(
            &cfg,
            &NetInput::Images(&images),
            &labels,
            &["head_w", "head_b", "patch_w", "patch_b", "cls_tok", "pos_emb", "l0.wqkv"],
        );
    }

    /// Bit-equality helper for the parity regressions below.
    fn assert_bits(got: &[f32], want: &[f32], what: &str) {
        assert_eq!(got.len(), want.len(), "{} length", what);
        for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            assert!(
                g.to_bits() == w.to_bits() || (g.is_nan() && w.is_nan()),
                "{} idx {}: {} vs {}",
                what,
                i,
                g,
                w
            );
        }
    }

    #[test]
    fn qlinear_bwd_matches_double_transpose_reference_bits() {
        // Satellite regression (ISSUE 5): the backward used to rebuild
        // the weight via `wq_t.transpose()` every step. The natural
        // (dout, din) layout must reproduce those gradients bit for bit.
        use crate::runtime::registry::Q_NONE;
        use crate::util::prop;
        let be = crate::tensor::backend::active();
        let mut rng = Pcg64::new(41);
        let (n, din, dout) = (7usize, 12usize, 9usize);
        let wq = Tensor::new(vec![dout, din], prop::heavy_vec(&mut rng, dout * din, 1.0));
        let smooth: Vec<f32> = (0..din).map(|j| 0.5 + 0.125 * (j % 4) as f32).collect();
        let site = SiteCtx {
            wq: wq.clone(),
            bias: vec![0.0; dout],
            aq: Q_NONE,
            row_aq: RowQdq::None,
            oq: Q_NONE,
            smooth: Some(smooth.clone()),
            alpha: None,
            int: None,
        };
        let x = Tensor::new(vec![n, din], prop::heavy_vec(&mut rng, n * din, 1.0));
        let (_, tape) = qlinear(&x, &site, be.as_ref(), true, None).unwrap();
        let lt = tape.unwrap();
        let dy = Tensor::new(vec![n, dout], prop::heavy_vec(&mut rng, n * dout, 1.0));
        let (dx, dw, db) = qlinear_bwd(&dy, &lt, &site, be.as_ref());
        // the pre-refactor formulas, double transpose and all
        let wq_t = wq.transpose();
        let mut dx_ref = be.matmul(&dy, &wq_t.transpose());
        dx_ref.scale_cols(&smooth);
        let dw_ref = be.matmul(&dy.transpose(), &lt.xq);
        assert_bits(&dx.data, &dx_ref.data, "qlinear_bwd dx");
        assert_bits(&dw.data, &dw_ref.data, "qlinear_bwd dw");
        assert_bits(&db, &col_sum(&dy), "qlinear_bwd db");
    }

    #[test]
    fn fused_forward_bit_identical_to_unfused() {
        // The fused qdq_matmul_t inference path vs the unfused reference
        // (materialized x_q), end to end through `forward`, for wirings
        // covering smoothing + ABFP, static-int clip ranges, and output
        // quantization. Identical bytes is the tentpole contract.
        use crate::formats::{Format, INT4, INT8};
        struct RestoreFusion(bool);
        impl Drop for RestoreFusion {
            fn drop(&mut self) {
                set_qdq_fusion(self.0);
            }
        }
        let _restore = RestoreFusion(set_qdq_fusion(true));

        let cfg = tiny("opt");
        let params = init_params(&cfg, 12);
        let tokens = rand_tokens(&cfg, 13);
        let be = crate::tensor::backend::active();
        let abfp4 = QuantSpec { kind: QuantKind::Abfp, fmt: Some(Format::Int(INT4)), n: 4 };
        let abfp8 = QuantSpec { kind: QuantKind::Abfp, fmt: Some(Format::Int(INT8)), n: 4 };
        let stat8 =
            QuantSpec { kind: QuantKind::StaticInt, fmt: Some(Format::Int(INT8)), n: 4 };
        let wirings = vec![
            QuantWiring { wq: abfp4, aq: abfp4, smooth: true, ..QuantWiring::fp32() },
            QuantWiring { wq: abfp4, aq: stat8, ..QuantWiring::fp32() },
            QuantWiring { wq: abfp4, aq: abfp8, oq: abfp8, smooth: true, ..QuantWiring::fp32() },
            QuantWiring::fp32(),
        ];
        for (wi, wiring) in wirings.into_iter().enumerate() {
            let mut smooth = BTreeMap::new();
            let mut alpha = BTreeMap::new();
            for site in &cfg.sites {
                if wiring.smooth {
                    let sm: Vec<f32> =
                        (0..site.dim).map(|j| 0.5 + 0.25 * (j % 3) as f32).collect();
                    smooth.insert(site.name.clone(), sm);
                }
                if wiring.aq.kind == QuantKind::StaticInt {
                    alpha.insert(site.name.clone(), vec![1.5]);
                }
            }
            let sites =
                build_sites(&cfg, &wiring, &params, &smooth, &alpha, be.as_ref()).unwrap();
            let input = NetInput::Tokens(&tokens);
            set_qdq_fusion(true);
            let fused =
                forward(&cfg, &params, &sites, &input, be.as_ref(), false, false).unwrap();
            set_qdq_fusion(false);
            let unfused =
                forward(&cfg, &params, &sites, &input, be.as_ref(), false, false).unwrap();
            set_qdq_fusion(true);
            assert_eq!(fused.head.shape, unfused.head.shape, "wiring {}", wi);
            assert_bits(
                &fused.head.data,
                &unfused.head.data,
                &format!("fused-vs-unfused head, wiring {}", wi),
            );
        }
    }

    #[test]
    fn compute_mode_parsing_is_strict() {
        assert_eq!(parse_compute_mode("qdq").unwrap(), ComputeMode::Qdq);
        assert_eq!(parse_compute_mode("int").unwrap(), ComputeMode::IntKernel);
        for bad in ["", "INT", "int8", "qdq ", "fused"] {
            let err = parse_compute_mode(bad).unwrap_err();
            assert!(err.contains("unknown compute mode"), "{}: {}", bad, err);
            assert!(err.contains("expected qdq|int"), "{}: {}", bad, err);
            assert!(configure_compute(bad).is_err(), "{}", bad);
        }
    }

    #[test]
    fn int_prepack_dequantizes_to_qdq_weights_bits() {
        // The IntSite codes must be the exact integer codes the weight
        // QDQ rounds to: code / w_scale == the QDQ'd weight, bit for
        // bit, for every element — the invariant that makes the int
        // GEMM's rescale land on the QDQ result wherever f32 is exact.
        use crate::formats::{Format, INT8};
        let cfg = tiny("opt");
        let params = init_params(&cfg, 21);
        let be = crate::tensor::backend::active();
        let wiring = quant_config("mse_w8a8").unwrap();
        let mut alpha = BTreeMap::new();
        for site in &cfg.sites {
            alpha.insert(site.name.clone(), vec![1.5f32]);
        }
        let sites = build_sites(
            &cfg,
            &wiring,
            &params,
            &BTreeMap::new(),
            &alpha,
            be.as_ref(),
        )
        .unwrap();
        assert_eq!(sites.len(), cfg.sites.len());
        for (name, site) in &sites {
            let is = site.int.as_ref().unwrap_or_else(|| panic!("{} has no IntSite", name));
            let (dout, din) = site.wq.dims2();
            assert_eq!((is.panel.n, is.panel.k), (dout, din), "{}", name);
            assert_eq!(is.w_scales.len(), dout, "{}", name);
            assert_eq!(is.x_qmax, 127.0, "{}", name);
            // x_scale matches the RowQdq the fused QDQ path resolved
            match &site.row_aq {
                RowQdq::StaticInt { scales, qmax } => {
                    assert_eq!(scales.len(), 1, "{}", name);
                    assert_eq!(is.x_scale.to_bits(), scales[0].to_bits(), "{}", name);
                    assert_eq!(*qmax, 127.0, "{}", name);
                }
                other => panic!("{}: unexpected row kernel {:?}", name, other),
            }
            for r in 0..dout {
                let s = is.w_scales[r];
                for j in 0..din {
                    let deq = (is.panel.q[r * din + j] as f32) / s;
                    let want = site.wq.data[r * din + j];
                    assert_eq!(
                        deq.to_bits(),
                        want.to_bits(),
                        "{} [{},{}]: {} vs {}",
                        name,
                        r,
                        j,
                        deq,
                        want
                    );
                }
            }
        }
        // Ineligible wirings build no prepack: ABFP weights, smoothing,
        // per-channel clip ranges all stay QDQ-only.
        let abfp = quant_config("abfp_w4a8_n64").unwrap();
        let mut smooth = BTreeMap::new();
        for site in &cfg.sites {
            smooth.insert(site.name.clone(), vec![1.0f32; site.dim]);
        }
        let s2 = build_sites(&cfg, &abfp, &params, &smooth, &BTreeMap::new(), be.as_ref())
            .unwrap();
        assert!(s2.values().all(|s| s.int.is_none()));
        let w8 = QuantSpec {
            kind: QuantKind::WPcmaxInt,
            fmt: Some(Format::Int(INT8)),
            n: 4,
        };
        let a8 = QuantSpec {
            kind: QuantKind::StaticInt,
            fmt: Some(Format::Int(INT8)),
            n: 4,
        };
        let lw = QuantWiring { wq: w8, aq: a8, ..QuantWiring::fp32() };
        let raw = Tensor::new(vec![2, 4], vec![1.0; 8]);
        assert!(int_site_for(&lw, &raw, 4, Some(&[1.5]), true).is_none(), "smoothed");
        assert!(int_site_for(&lw, &raw, 4, Some(&[1.5, 2.0]), false).is_none(), "per-channel");
        assert!(int_site_for(&lw, &raw, 4, None, false).is_none(), "no alpha");
        assert!(int_site_for(&lw, &raw, 4, Some(&[1.5]), false).is_some());
    }

    #[test]
    fn int_qlinear_bit_exact_on_power_of_two_cell() {
        // A static-int W8A8 cell constructed so every rounding in the
        // QDQ reference is exact (scales exactly 1.0, integer operands,
        // partial sums far inside 2^24): the int GEMM must reproduce
        // the QDQ path bit for bit. This is the site-level version of
        // the conformance-suite contract; the global ComputeMode switch
        // itself is exercised end to end by the runtime_smoke / serve
        // integration cases (lib tests never flip process-wide state).
        use crate::formats::{Format, INT8};
        use crate::runtime::registry::Q_NONE;
        let be = crate::tensor::backend::active();
        let (n, din, dout) = (5usize, 8usize, 4usize);
        let mut rng = Pcg64::new(77);
        // integer weights, each row's absmax exactly 127
        let mut wraw = vec![0.0f32; dout * din];
        for r in 0..dout {
            for j in 0..din {
                wraw[r * din + j] = (rng.below(201) as f32) - 100.0;
            }
            wraw[r * din + r % din] = if r % 2 == 0 { 127.0 } else { -127.0 };
        }
        let raw = Tensor::new(vec![dout, din], wraw);
        let w8 = QuantSpec {
            kind: QuantKind::WPcmaxInt,
            fmt: Some(Format::Int(INT8)),
            n: 4,
        };
        let a8 = QuantSpec {
            kind: QuantKind::StaticInt,
            fmt: Some(Format::Int(INT8)),
            n: 4,
        };
        let lw = QuantWiring { wq: w8, aq: a8, ..QuantWiring::fp32() };
        let alpha = vec![127.0f32]; // s_x = 127/127 = 1.0 exactly
        let int = int_site_for(&lw, &raw, din, Some(&alpha), false);
        let mut wq = raw.clone();
        lw.wq.apply_with(&mut wq.data, din, None, be.as_ref()).unwrap();
        // with s_w = 1.0 the weight QDQ is the identity on these values
        assert_bits(&wq.data, &raw.data, "exact-cell weight qdq");
        let site = SiteCtx {
            wq,
            bias: (0..dout).map(|r| 0.25 + r as f32).collect(),
            aq: lw.aq,
            row_aq: lw.aq.row_kernel(din, Some(&alpha)).unwrap(),
            oq: Q_NONE,
            smooth: None,
            alpha: Some(alpha),
            int,
        };
        let is = site.int.as_ref().expect("exact cell is int-eligible");
        assert_eq!(is.x_scale.to_bits(), 1.0f32.to_bits());
        assert!(is.w_scales.iter().all(|s| s.to_bits() == 1.0f32.to_bits()));
        // integer activations in clip range
        let xv: Vec<f32> = (0..n * din).map(|_| (rng.below(41) as f32) - 20.0).collect();
        let x = Tensor::new(vec![n, din], xv);
        let (y_qdq, _) = qlinear(&x, &site, be.as_ref(), false, None).unwrap();
        // the int branch, step for step
        let mut codes = vec![0i8; n * din];
        crate::tensor::backend::quantize_rows_i8(&x.data, is.x_scale, is.x_qmax, &mut codes);
        let x_scales = vec![is.x_scale; n];
        let mut y_int = be.int_matmul_t(&codes, &x_scales, &is.panel, &is.w_scales);
        for r in 0..n {
            add_slice(y_int.row_mut(r), &site.bias);
        }
        assert_eq!(y_int.shape, y_qdq.shape);
        assert_bits(&y_int.data, &y_qdq.data, "int vs qdq exact cell");
    }

    #[test]
    fn attn_head_slices_match_take_block_reference_bits() {
        // Satellite regression: attn_head now folds over contiguous row
        // slices of the packed (N, 3d) qkv instead of materializing
        // per-head Q/K/V copies. The old take_block + backend-matmul
        // formulation must be reproduced bit for bit, causal and not.
        use crate::util::prop;
        let be = crate::tensor::backend::active();
        let (b, s, heads, d) = (2usize, 5usize, 2usize, 8usize);
        let hd = d / heads;
        let mut rng = Pcg64::new(31);
        let qkv = Tensor::new(vec![b * s, 3 * d], prop::heavy_vec(&mut rng, b * s * 3 * d, 1.0));
        let scale = 1.0 / (hd as f32).sqrt();
        for causal in [false, true] {
            for bi in 0..b {
                for h in 0..heads {
                    let (oh, probs) = attn_head(&qkv, bi, h, s, d, hd, causal);
                    // the pre-refactor formulation, copies and all
                    let r0 = bi * s;
                    let c = h * hd;
                    let qh = take_block(&qkv, r0, s, c, hd);
                    let kh = take_block(&qkv, r0, s, d + c, hd);
                    let vh = take_block(&qkv, r0, s, 2 * d + c, hd);
                    let mut sc = be.matmul_t(&qh, &kh);
                    for v in sc.data.iter_mut() {
                        *v *= scale;
                    }
                    if causal {
                        for i in 0..s {
                            for j in (i + 1)..s {
                                sc.data[i * s + j] = MASK_NEG;
                            }
                        }
                    }
                    for i in 0..s {
                        let row = sc.row_mut(i);
                        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
                        let mut sum = 0.0f32;
                        for v in row.iter_mut() {
                            *v = (*v - mx).exp();
                            sum += *v;
                        }
                        for v in row.iter_mut() {
                            *v /= sum;
                        }
                    }
                    let oh_ref = be.matmul(&sc, &vh);
                    let what = format!("attn bi={} h={} causal={}", bi, h, causal);
                    assert_bits(&probs.data, &sc.data, &format!("{} probs", what));
                    assert_bits(&oh.data, &oh_ref.data, &format!("{} context", what));
                }
            }
        }
    }

    #[test]
    fn capture_collects_sites_in_model_order() {
        let cfg = tiny("opt");
        let params = init_params(&cfg, 1);
        let sites = fp32_sites(&cfg, &params);
        let tokens = rand_tokens(&cfg, 2);
        let be = crate::tensor::backend::active();
        let fwd = forward(
            &cfg,
            &params,
            &sites,
            &NetInput::Tokens(&tokens),
            be.as_ref(),
            false,
            true,
        )
        .unwrap();
        let names: Vec<&str> = fwd.capture.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "l0.qkv", "l0.attn_out", "l0.fc1", "l0.fc2", "l1.qkv",
                "l1.attn_out", "l1.fc1", "l1.fc2"
            ]
        );
        for (name, t) in &fwd.capture {
            let dim = if name.ends_with("fc2") { 4 * cfg.d } else { cfg.d };
            assert_eq!(t.shape, vec![cfg.batch * cfg.seq, dim], "{}", name);
        }
    }

    #[test]
    fn random_init_lm_nll_is_near_uniform() {
        let cfg = tiny("opt");
        let params = init_params(&cfg, 4);
        let sites = fp32_sites(&cfg, &params);
        let tokens = rand_tokens(&cfg, 3);
        let be = crate::tensor::backend::active();
        let fwd = forward(
            &cfg,
            &params,
            &sites,
            &NetInput::Tokens(&tokens),
            be.as_ref(),
            false,
            false,
        )
        .unwrap();
        let (nll, _) = nll_sum_and_grad(&fwd.head, &tokens, cfg.batch, cfg.seq, false);
        let per_tok = nll / (cfg.batch * (cfg.seq - 1)) as f64;
        let uniform = (cfg.vocab as f64).ln();
        assert!(
            (per_tok - uniform).abs() < 0.8,
            "per-token NLL {} vs uniform {}",
            per_tok,
            uniform
        );
    }

    #[test]
    fn causal_mask_blocks_future_tokens() {
        // Changing a future token must not change earlier positions'
        // logits (opt is causal); for bert (bidirectional) it must.
        let cfg = tiny("opt");
        let params = init_params(&cfg, 9);
        let sites = fp32_sites(&cfg, &params);
        let be = crate::tensor::backend::active();
        let t1 = rand_tokens(&cfg, 11);
        let mut t2 = t1.clone();
        let s = cfg.seq;
        t2[s - 1] = (t2[s - 1] + 1) % cfg.vocab as i32; // last token, batch row 0
        let f1 = forward(&cfg, &params, &sites, &NetInput::Tokens(&t1), be.as_ref(), false, false)
            .unwrap();
        let f2 = forward(&cfg, &params, &sites, &NetInput::Tokens(&t2), be.as_ref(), false, false)
            .unwrap();
        let v = cfg.vocab;
        // positions 0..S-2 of row 0 identical
        assert_eq!(
            f1.head.data[..(s - 1) * v],
            f2.head.data[..(s - 1) * v],
            "causal leak"
        );
        // the changed position itself differs
        assert_ne!(
            f1.head.data[(s - 1) * v..s * v],
            f2.head.data[(s - 1) * v..s * v]
        );
    }
}
