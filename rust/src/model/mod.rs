//! Parameter initialization and checkpoint management.
//!
//! The manifest's per-model param list (name, shape, init kind) is the
//! layout contract with L2; initialization reproduces the same scheme the
//! Python tests use (normal 0.02, residual-scaled projections, ones/zeros
//! for norms, log-normal embedding gain — the outlier-channel injector,
//! DESIGN.md §1).

pub mod net;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::ModelCfg;
use crate::runtime::Val;
use crate::tensor::io::TensorStore;
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// Standard deviation of the log-normal embedding gain: controls how
/// spread per-channel activation magnitudes are (the LLM-outlier
/// simulation knob).
pub const EMB_GAIN_SIGMA: f32 = 2.0;

/// Log-normal spread of the LayerNorm gain init: puts LLM-style outlier
/// channels directly at the quantized sites (qkv/fc1 inputs are LN
/// outputs scaled by these gains).
pub const LN_GAIN_SIGMA: f32 = 1.2;

pub fn init_params(cfg: &ModelCfg, seed: u64) -> TensorStore {
    let mut base = Pcg64::new(seed ^ 0x1217_BEEF);
    let mut store = TensorStore::default();
    let depth_scale = 0.02 / (2.0 * cfg.layers as f32).sqrt();
    // Outlier channels are an *LLM* phenomenon (the paper's own finding:
    // vision & smaller-task models are "inherently easier to quantize").
    // Inject them only for the OPT/Wikitext2 stand-ins; the other
    // families get unit gains — for span-QA the log-normal token gains
    // would also drown the positional signal the task depends on.
    let outliers = cfg.task == "lm";
    for p in &cfg.params {
        let mut rng = base.fork(fnv(&p.name));
        let n: usize = p.shape.iter().product();
        let data: Vec<f32> = match p.init.as_str() {
            "zeros" => vec![0.0; n],
            "ones" => vec![1.0; n],
            "lognormal" if outliers => {
                (0..n).map(|_| rng.lognormal(EMB_GAIN_SIGMA)).collect()
            }
            "lngain" if outliers => {
                (0..n).map(|_| rng.lognormal(LN_GAIN_SIGMA)).collect()
            }
            "lognormal" | "lngain" => vec![1.0; n],
            "residual" => (0..n).map(|_| rng.gaussian() * depth_scale).collect(),
            _ => (0..n).map(|_| rng.gaussian() * 0.02).collect(),
        };
        store.insert(&p.name, Tensor::new(p.shape.clone(), data));
    }
    store
}

fn fnv(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Validate a checkpoint against the manifest layout.
pub fn check_params(cfg: &ModelCfg, store: &TensorStore) -> Result<()> {
    for p in &cfg.params {
        let t = store
            .get(&p.name)
            .with_context(|| format!("checkpoint missing param {}", p.name))?;
        if t.shape != p.shape {
            bail!(
                "param {}: checkpoint shape {:?} != manifest {:?}",
                p.name,
                t.shape,
                p.shape
            );
        }
    }
    Ok(())
}

/// Sticky-input map (`name -> Val`) for the param inputs of an artifact.
pub fn param_vals(cfg: &ModelCfg, store: &TensorStore) -> Result<BTreeMap<String, Val>> {
    check_params(cfg, store)?;
    let mut m = BTreeMap::new();
    for p in &cfg.params {
        m.insert(p.name.clone(), Val::from_tensor(store.get(&p.name).unwrap()));
    }
    Ok(m)
}

pub struct CkptDir {
    pub dir: PathBuf,
}

impl CkptDir {
    pub fn new(dir: &str) -> CkptDir {
        CkptDir { dir: PathBuf::from(dir) }
    }

    pub fn path(&self, model: &str, tag: &str) -> PathBuf {
        self.dir.join(format!("{}.{}.tns", model, tag))
    }

    pub fn exists(&self, model: &str, tag: &str) -> bool {
        self.path(model, tag).exists()
    }

    pub fn save(&self, model: &str, tag: &str, store: &TensorStore) -> Result<()> {
        store.save(&self.path(model, tag))
    }

    pub fn load(&self, model: &str, tag: &str) -> Result<TensorStore> {
        TensorStore::load(&self.path(model, tag))
    }

    pub fn load_or_init(
        &self,
        cfg: &ModelCfg,
        tag: &str,
        seed: u64,
    ) -> Result<(TensorStore, bool)> {
        if self.exists(&cfg.name, tag) {
            let s = self.load(&cfg.name, tag)?;
            check_params(cfg, &s)?;
            Ok((s, true))
        } else {
            Ok((init_params(cfg, seed), false))
        }
    }
}

/// Flat adam state (m or v) initialised to zeros, matching param layout.
pub fn zero_like_params(cfg: &ModelCfg) -> TensorStore {
    let mut store = TensorStore::default();
    for p in &cfg.params {
        store.insert(&p.name, Tensor::zeros(p.shape.clone()));
    }
    store
}

pub fn ckpt_default_dir() -> &'static Path {
    Path::new("checkpoints")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamSpec;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg {
            name: "t".into(),
            arch: "opt".into(),
            task: "lm".into(),
            stands_for: String::new(),
            vocab: 8,
            d: 4,
            layers: 1,
            heads: 1,
            d_ff: 16,
            seq: 4,
            batch: 1,
            image: 0,
            patch: 0,
            channels: 0,
            classes: 0,
            params: vec![
                ParamSpec { name: "w".into(), shape: vec![2, 3], init: "normal".into() },
                ParamSpec { name: "g".into(), shape: vec![3], init: "ones".into() },
                ParamSpec { name: "e".into(), shape: vec![3], init: "lognormal".into() },
            ],
            sites: vec![],
        }
    }

    #[test]
    fn init_respects_kinds_and_is_deterministic() {
        let cfg = tiny_cfg();
        let a = init_params(&cfg, 42);
        let b = init_params(&cfg, 42);
        let c = init_params(&cfg, 43);
        assert_eq!(a.get("w").unwrap().data, b.get("w").unwrap().data);
        assert_ne!(a.get("w").unwrap().data, c.get("w").unwrap().data);
        assert_eq!(a.get("g").unwrap().data, vec![1.0, 1.0, 1.0]);
        assert!(a.get("e").unwrap().data.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn check_params_catches_mismatch() {
        let cfg = tiny_cfg();
        let mut s = init_params(&cfg, 1);
        assert!(check_params(&cfg, &s).is_ok());
        s.insert("w", Tensor::zeros(vec![3, 3]));
        assert!(check_params(&cfg, &s).is_err());
    }

    #[test]
    fn ckpt_roundtrip() {
        let cfg = tiny_cfg();
        let dir = std::env::temp_dir().join("intfpqsim_ckpt_test");
        let ck = CkptDir::new(dir.to_str().unwrap());
        let (s, existed) = ck.load_or_init(&cfg, "fp32", 7).unwrap();
        assert!(!existed);
        ck.save("t", "fp32", &s).unwrap();
        let (s2, existed2) = ck.load_or_init(&cfg, "fp32", 8).unwrap();
        assert!(existed2);
        assert_eq!(s.get("w").unwrap().data, s2.get("w").unwrap().data);
        std::fs::remove_dir_all(&dir).ok();
    }
}
