//! Expression-grammar code corpus + exact interpreter (HumanEval stand-in).
//!
//! Programs are arithmetic statements over single digits:
//!
//!   `( a OP b ) = <digits of result> ;`
//!
//! with OP ∈ {+, *}. (Single operation: a ~5M-parameter stand-in trained
//! for a few hundred steps can master the 200-fact table, giving a
//! meaningful Pass@1 headroom for quantization to damage — two chained
//! ops left the FP32 baseline near zero, making the metric useless.)  Training streams pack statements back-to-back into
//! fixed-length sequences.  Pass@1 (the paper's Codegen metric): prompt
//! the model with everything up to `=`, greedy-decode, and check the
//! generated digits against the interpreter's exact value — the same
//! generate→execute→check loop HumanEval uses.

use crate::util::rng::Pcg64;

use super::TokenBatch;

pub const CODE_VOCAB: usize = 64;

// token ids
pub const T_PLUS: i32 = 10;
pub const T_STAR: i32 = 11;
pub const T_LPAR: i32 = 12;
pub const T_RPAR: i32 = 13;
pub const T_EQ: i32 = 14;
pub const T_SEMI: i32 = 15;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    Add,
    Mul,
}

impl Op {
    fn token(self) -> i32 {
        match self {
            Op::Add => T_PLUS,
            Op::Mul => T_STAR,
        }
    }
}

/// One synthetic "program": (a op1 b).
#[derive(Debug, Clone, Copy)]
pub struct Program {
    pub a: i32,
    pub b: i32,
    pub op1: Op,
}

impl Program {
    pub fn sample(rng: &mut Pcg64) -> Program {
        let op = |r: &mut Pcg64| if r.f32() < 0.5 { Op::Add } else { Op::Mul };
        Program {
            a: rng.below(10) as i32,
            b: rng.below(10) as i32,
            op1: op(rng),
        }
    }

    /// Exact evaluation — the "test harness" of the Pass@1 metric.
    pub fn value(&self) -> i32 {
        match self.op1 {
            Op::Add => self.a + self.b,
            Op::Mul => self.a * self.b,
        }
    }

    /// Prompt tokens: `( a op b ) =`.
    pub fn prompt(&self) -> Vec<i32> {
        vec![T_LPAR, self.a, self.op1.token(), self.b, T_RPAR, T_EQ]
    }

    /// Expected completion: result digits then `;`.
    pub fn completion(&self) -> Vec<i32> {
        let mut out = digits(self.value());
        out.push(T_SEMI);
        out
    }

    pub fn statement(&self) -> Vec<i32> {
        let mut s = self.prompt();
        s.extend(self.completion());
        s
    }
}

pub fn digits(v: i32) -> Vec<i32> {
    assert!(v >= 0);
    if v == 0 {
        return vec![0];
    }
    let mut ds = Vec::new();
    let mut v = v;
    while v > 0 {
        ds.push(v % 10);
        v /= 10;
    }
    ds.reverse();
    ds
}

pub struct CodeCorpus {
    seed: u64,
}

impl CodeCorpus {
    pub fn new(seed: u64) -> CodeCorpus {
        CodeCorpus { seed }
    }

    fn rng(&self, split: u64, index: u64) -> Pcg64 {
        Pcg64::new(
            self.seed
                ^ split.wrapping_mul(0xD6E8_FEB8_6659_FD93)
                ^ index.wrapping_mul(0xA24B_AED4_963E_E407),
        )
    }

    /// Training batch: statements packed back-to-back.
    pub fn train_batch(&self, index: u64, batch: usize, seq: usize) -> TokenBatch {
        let mut out = TokenBatch::new(batch, seq);
        for b in 0..batch {
            let mut rng = self.rng(0xC0DE, index * 4096 + b as u64);
            let row = out.row_mut(b);
            let mut pos = 0;
            while pos < row.len() {
                let stmt = Program::sample(&mut rng).statement();
                for t in stmt {
                    if pos >= row.len() {
                        break;
                    }
                    row[pos] = t;
                    pos += 1;
                }
            }
        }
        out
    }

    /// Held-out evaluation programs for Pass@1.
    pub fn eval_programs(&self, count: usize) -> Vec<Program> {
        let mut rng = self.rng(EVAL_SPLIT, 0);
        (0..count).map(|_| Program::sample(&mut rng)).collect()
    }
}

const EVAL_SPLIT: u64 = 0xE7A1;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interpreter_exact() {
        let p = Program { a: 3, b: 4, op1: Op::Add };
        assert_eq!(p.value(), 7);
        let p = Program { a: 9, b: 9, op1: Op::Mul };
        assert_eq!(p.value(), 81);
    }

    #[test]
    fn digits_roundtrip() {
        assert_eq!(digits(0), vec![0]);
        assert_eq!(digits(7), vec![7]);
        assert_eq!(digits(81), vec![8, 1]);
    }

    #[test]
    fn statement_layout() {
        let p = Program { a: 1, b: 2, op1: Op::Add };
        // (1+2) = 3;
        assert_eq!(
            p.statement(),
            vec![T_LPAR, 1, T_PLUS, 2, T_RPAR, T_EQ, 3, T_SEMI]
        );
    }

    #[test]
    fn tokens_in_vocab() {
        let c = CodeCorpus::new(3);
        let b = c.train_batch(0, 4, 64);
        assert!(b.tokens.iter().all(|&t| (0..CODE_VOCAB as i32).contains(&t)));
    }

    #[test]
    fn batches_deterministic() {
        let c = CodeCorpus::new(3);
        assert_eq!(c.train_batch(1, 2, 32).tokens, c.train_batch(1, 2, 32).tokens);
        assert_ne!(c.train_batch(1, 2, 32).tokens, c.train_batch(2, 2, 32).tokens);
    }

    #[test]
    fn eval_programs_deterministic() {
        let c = CodeCorpus::new(3);
        let a = c.eval_programs(10);
        let b = c.eval_programs(10);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.statement(), y.statement());
        }
    }
}
