//! Zipf–Markov language corpus (the Wikitext2 stand-in).
//!
//! A second-order Markov chain over a 512-token vocabulary: each token has
//! a sparse successor set (~12 candidates drawn Zipf-weighted) plus a
//! small uniform smoothing mass.  Token marginals come out Zipfian and
//! transitions are learnable by a small transformer, so perplexity
//! improvements/regressions behave qualitatively like natural text.

use crate::util::rng::{Pcg64, Zipf};

use super::TokenBatch;

pub const TEXT_VOCAB: usize = 512;
const SUCCESSORS: usize = 24;
const SMOOTH: f32 = 0.08; // probability mass of uniform "noise" tokens

pub struct TextCorpus {
    vocab: usize,
    succ: Vec<[u16; SUCCESSORS]>,
    weights: [f32; SUCCESSORS],
    seed: u64,
}

impl TextCorpus {
    pub fn new(seed: u64) -> TextCorpus {
        Self::with_vocab(TEXT_VOCAB, seed)
    }

    pub fn with_vocab(vocab: usize, seed: u64) -> TextCorpus {
        let mut rng = Pcg64::new(seed ^ 0x7E87_C0DE);
        let zipf = Zipf::new(vocab, 1.05);
        let mut succ = Vec::with_capacity(vocab);
        for _ in 0..vocab {
            let mut cands = [0u16; SUCCESSORS];
            for c in cands.iter_mut() {
                *c = zipf.sample(&mut rng) as u16;
            }
            succ.push(cands);
        }
        // Zipf-shaped weights over the successor slots.
        let mut weights = [0f32; SUCCESSORS];
        for (i, w) in weights.iter_mut().enumerate() {
            *w = 1.0 / (i as f32 + 1.0).powf(0.8);
        }
        TextCorpus { vocab, succ, weights, seed }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    fn stream_rng(&self, split: u64, index: u64) -> Pcg64 {
        Pcg64::new(
            self.seed
                ^ split.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ index.wrapping_mul(0xC2B2_AE3D_27D4_EB4F),
        )
    }

    fn fill_row(&self, rng: &mut Pcg64, row: &mut [i32]) {
        let mut prev = rng.below(self.vocab);
        let mut cur = rng.below(self.vocab);
        for slot in row.iter_mut() {
            *slot = cur as i32;
            let next = if rng.f32() < SMOOTH {
                rng.below(self.vocab)
            } else {
                // second-order structure: the previous token's parity
                // flips the successor preference order, so the chain is
                // NOT learnable from bigram statistics alone — the
                // transformer blocks (the quantized components) must do
                // real work, which is what makes quantization damage
                // visible in PPL.
                let k = rng.weighted(&self.weights);
                let k = if prev % 2 == 1 { SUCCESSORS - 1 - k } else { k };
                self.succ[cur][k] as usize
            };
            prev = cur;
            cur = next;
        }
    }

    /// Deterministic train batch `index` (split 0) of shape (batch, seq).
    pub fn train_batch(&self, index: u64, batch: usize, seq: usize) -> TokenBatch {
        self.batch_for_split(0xA11CE, index, batch, seq)
    }

    /// Deterministic eval batch `index` (disjoint stream from training).
    pub fn eval_batch(&self, index: u64, batch: usize, seq: usize) -> TokenBatch {
        self.batch_for_split(0xB0B, index, batch, seq)
    }

    fn batch_for_split(
        &self,
        split: u64,
        index: u64,
        batch: usize,
        seq: usize,
    ) -> TokenBatch {
        let mut out = TokenBatch::new(batch, seq);
        for b in 0..batch {
            let mut rng = self.stream_rng(split, index * 4096 + b as u64);
            self.fill_row(&mut rng, out.row_mut(b));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_batches() {
        let c = TextCorpus::new(7);
        let a = c.train_batch(3, 4, 64);
        let b = c.train_batch(3, 4, 64);
        assert_eq!(a.tokens, b.tokens);
        let d = c.train_batch(4, 4, 64);
        assert_ne!(a.tokens, d.tokens);
    }

    #[test]
    fn train_eval_disjoint_streams() {
        let c = TextCorpus::new(7);
        let a = c.train_batch(0, 2, 32);
        let b = c.eval_batch(0, 2, 32);
        assert_ne!(a.tokens, b.tokens);
    }

    #[test]
    fn tokens_in_range_and_zipfy() {
        let c = TextCorpus::new(1);
        let mut counts = vec![0usize; TEXT_VOCAB];
        for i in 0..20 {
            let b = c.train_batch(i, 8, 64);
            for &t in &b.tokens {
                assert!((0..TEXT_VOCAB as i32).contains(&t));
                counts[t as usize] += 1;
            }
        }
        // head of the distribution should be much heavier than the tail
        let head: usize = counts[..32].iter().sum();
        let tail: usize = counts[TEXT_VOCAB - 128..].iter().sum();
        assert!(head > tail, "head {} tail {}", head, tail);
    }

    #[test]
    fn chain_is_predictable() {
        // A bigram model trained on the stream should beat uniform:
        // check that successor entropy is far below log2(vocab).
        let c = TextCorpus::new(2);
        let b = c.train_batch(0, 8, 512);
        let mut pair_counts = std::collections::HashMap::new();
        let mut uni = std::collections::HashMap::new();
        for r in 0..8 {
            let row = b.row(r);
            for w in row.windows(2) {
                *pair_counts.entry((w[0], w[1])).or_insert(0usize) += 1;
                *uni.entry(w[0]).or_insert(0usize) += 1;
            }
        }
        // average distinct successors per observed token must be small
        let mut succ_sets: std::collections::HashMap<i32, std::collections::HashSet<i32>> =
            Default::default();
        for (a, b2) in pair_counts.keys() {
            succ_sets.entry(*a).or_default().insert(*b2);
        }
        let avg: f64 = succ_sets.values().map(|s| s.len() as f64).sum::<f64>()
            / succ_sets.len() as f64;
        assert!(avg < 80.0, "avg successors {}", avg);
    }
}
