//! Gaussian-blob image classes (ImageNet stand-in for the ViT models).
//!
//! Each of the 16 classes is a fixed prototype: a mixture of 3 colored
//! Gaussian blobs at class-specific positions/colors on a 32×32 canvas.
//! Samples add per-image jitter (blob positions wobble, global noise),
//! so the task needs real spatial feature extraction but is learnable by
//! a small ViT in a few hundred steps.

use crate::util::rng::Pcg64;

pub const IMG: usize = 32;
pub const CHANNELS: usize = 3;
pub const CLASSES: usize = 16;
const BLOBS: usize = 3;

#[derive(Clone, Copy)]
struct Blob {
    cx: f32,
    cy: f32,
    sigma: f32,
    color: [f32; CHANNELS],
}

pub struct ImageCorpus {
    prototypes: Vec<[Blob; BLOBS]>,
    seed: u64,
}

#[derive(Debug, Clone)]
pub struct ImageBatch {
    pub batch: usize,
    /// (B, 32, 32, 3) row-major f32
    pub pixels: Vec<f32>,
    pub labels: Vec<i32>,
}

impl ImageCorpus {
    pub fn new(seed: u64) -> ImageCorpus {
        let mut rng = Pcg64::new(seed ^ 0x1CACE);
        let mut prototypes = Vec::with_capacity(CLASSES);
        for _ in 0..CLASSES {
            let mut blobs = [Blob { cx: 0.0, cy: 0.0, sigma: 1.0, color: [0.0; 3] }; BLOBS];
            for b in blobs.iter_mut() {
                *b = Blob {
                    cx: 4.0 + rng.f32() * (IMG as f32 - 8.0),
                    cy: 4.0 + rng.f32() * (IMG as f32 - 8.0),
                    sigma: 2.0 + rng.f32() * 3.0,
                    color: [rng.f32(), rng.f32(), rng.f32()],
                };
            }
            prototypes.push(blobs);
        }
        ImageCorpus { prototypes, seed }
    }

    fn render(&self, class: usize, rng: &mut Pcg64, out: &mut [f32]) {
        debug_assert_eq!(out.len(), IMG * IMG * CHANNELS);
        out.fill(0.0);
        for proto in &self.prototypes[class] {
            // per-sample jitter
            let cx = proto.cx + rng.gaussian() * 1.0;
            let cy = proto.cy + rng.gaussian() * 1.0;
            let inv2s = 1.0 / (2.0 * proto.sigma * proto.sigma);
            for y in 0..IMG {
                for x in 0..IMG {
                    let d2 = (x as f32 - cx).powi(2) + (y as f32 - cy).powi(2);
                    let w = (-d2 * inv2s).exp();
                    if w < 1e-3 {
                        continue;
                    }
                    let base = (y * IMG + x) * CHANNELS;
                    for c in 0..CHANNELS {
                        out[base + c] += w * proto.color[c];
                    }
                }
            }
        }
        // global pixel noise
        for v in out.iter_mut() {
            *v += rng.gaussian() * 0.05;
        }
    }

    pub fn batch(&self, split: u64, index: u64, batch: usize) -> ImageBatch {
        let mut pixels = vec![0.0f32; batch * IMG * IMG * CHANNELS];
        let mut labels = Vec::with_capacity(batch);
        for b in 0..batch {
            let mut rng = Pcg64::new(
                self.seed
                    ^ split.wrapping_mul(0xFF51_AFD7_ED55_8CCD)
                    ^ (index * 4096 + b as u64).wrapping_mul(0xC4CE_B9FE_1A85_EC53),
            );
            let class = rng.below(CLASSES);
            labels.push(class as i32);
            let sl = &mut pixels
                [b * IMG * IMG * CHANNELS..(b + 1) * IMG * IMG * CHANNELS];
            self.render(class, &mut rng, sl);
        }
        ImageBatch { batch, pixels, labels }
    }

    pub fn train_batch(&self, index: u64, batch: usize) -> ImageBatch {
        self.batch(0x17A1, index, batch)
    }

    pub fn eval_batch(&self, index: u64, batch: usize) -> ImageBatch {
        self.batch(0xE0A1, index, batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes_and_labels() {
        let c = ImageCorpus::new(11);
        let b = c.train_batch(0, 4);
        assert_eq!(b.pixels.len(), 4 * IMG * IMG * CHANNELS);
        assert!(b.labels.iter().all(|&l| (0..CLASSES as i32).contains(&l)));
    }

    #[test]
    fn deterministic_and_split_disjoint() {
        let c = ImageCorpus::new(11);
        assert_eq!(c.train_batch(2, 2).pixels, c.train_batch(2, 2).pixels);
        assert_ne!(c.train_batch(2, 2).pixels, c.eval_batch(2, 2).pixels);
    }

    #[test]
    fn classes_are_separable() {
        // mean image of class k must be closer to another sample of class
        // k than to samples of other classes (prototype structure).
        let c = ImageCorpus::new(11);
        let mut by_class: Vec<Vec<Vec<f32>>> = vec![Vec::new(); CLASSES];
        for i in 0..40 {
            let b = c.train_batch(i, 4);
            for j in 0..4 {
                let px = b.pixels[j * IMG * IMG * 3..(j + 1) * IMG * IMG * 3].to_vec();
                by_class[b.labels[j] as usize].push(px);
            }
        }
        let dist = |a: &[f32], b: &[f32]| -> f64 {
            a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum()
        };
        let mut checked = 0;
        for k in 0..CLASSES {
            if by_class[k].len() < 2 {
                continue;
            }
            let intra = dist(&by_class[k][0], &by_class[k][1]);
            for other in 0..CLASSES {
                if other != k && !by_class[other].is_empty() {
                    let inter = dist(&by_class[k][0], &by_class[other][0]);
                    assert!(intra < inter, "class {} vs {}", k, other);
                    checked += 1;
                    break;
                }
            }
        }
        assert!(checked >= 8);
    }
}
