//! Delimiter-span QA corpus (SQuAD v1.1 stand-in).
//!
//! Layout of each example (seq = 64):
//!   [CLS] [Q] [SEP] passage…
//! The passage contains one answer span delimited by OPEN/CLOSE marker
//! tokens; the gold span is (open_pos, close_pos) inclusive and the
//! model predicts start/end positions — the same extractive-span head +
//! token-overlap F1 as SQuAD.
//!
//! (Design note: an earlier variant queried one of four marker *types*;
//! query-conditioned matching turned out not to be learnable by these
//! 2-layer stand-ins — the loss plateaus at the marker-position entropy —
//! so the task was reduced to delimiter extraction, which trains to high
//! F1 and leaves quantization damage visible as span mislocations.)

use crate::util::rng::{Pcg64, Zipf};

use super::TokenBatch;

pub const QA_VOCAB: usize = 512;
pub const ORDINARY: usize = 480; // ids [0, 480) are ordinary tokens
pub const T_CLS: i32 = 480;
pub const T_SEP: i32 = 481;
pub const T_OPEN: i32 = 482;
pub const T_CLOSE: i32 = 483;
pub const T_Q: i32 = 484;

pub const SPAN_LEN: usize = 3; // tokens strictly inside OPEN..CLOSE

#[derive(Debug, Clone)]
pub struct QaBatch {
    pub tokens: TokenBatch,
    pub starts: Vec<i32>,
    pub ends: Vec<i32>,
}

pub struct QaCorpus {
    seed: u64,
    zipf: Zipf,
}

impl QaCorpus {
    pub fn new(seed: u64) -> QaCorpus {
        QaCorpus { seed, zipf: Zipf::new(ORDINARY, 1.05) }
    }

    fn rng(&self, split: u64, index: u64) -> Pcg64 {
        Pcg64::new(
            self.seed
                ^ split.wrapping_mul(0x94D0_49BB_1331_11EB)
                ^ index.wrapping_mul(0xBF58_476D_1CE4_E5B9),
        )
    }

    fn example(&self, rng: &mut Pcg64, seq: usize) -> (Vec<i32>, i32, i32) {
        assert!(
            seq >= 16,
            "QA examples need seq >= 16 for a delimited span (got {})",
            seq
        );
        let mut row = vec![0i32; seq];
        row[0] = T_CLS;
        row[1] = T_Q;
        row[2] = T_SEP;
        for slot in row.iter_mut().skip(3) {
            *slot = self.zipf.sample(rng) as i32;
        }
        let body = 3..seq - SPAN_LEN - 2;
        let open = body.start + rng.below(body.end - body.start);
        let close = open + SPAN_LEN + 1;
        row[open] = T_OPEN;
        row[close] = T_CLOSE;
        (row, open as i32, close as i32)
    }

    pub fn batch(&self, split: u64, index: u64, batch: usize, seq: usize) -> QaBatch {
        let mut tokens = TokenBatch::new(batch, seq);
        let mut starts = Vec::with_capacity(batch);
        let mut ends = Vec::with_capacity(batch);
        for b in 0..batch {
            let mut rng = self.rng(split, index * 4096 + b as u64);
            let (row, s, e) = self.example(&mut rng, seq);
            tokens.row_mut(b).copy_from_slice(&row);
            starts.push(s);
            ends.push(e);
        }
        QaBatch { tokens, starts, ends }
    }

    pub fn train_batch(&self, index: u64, batch: usize, seq: usize) -> QaBatch {
        self.batch(0x77AA, index, batch, seq)
    }

    pub fn eval_batch(&self, index: u64, batch: usize, seq: usize) -> QaBatch {
        self.batch(0x88BB, index, batch, seq)
    }
}

/// Token-overlap span F1 (SQuAD definition) for predicted vs gold spans.
pub fn span_f1(pred: (i32, i32), gold: (i32, i32)) -> f64 {
    let (ps, pe) = (pred.0.min(pred.1), pred.0.max(pred.1));
    let (gs, ge) = gold;
    let inter = (pe.min(ge) - ps.max(gs) + 1).max(0) as f64;
    if inter == 0.0 {
        return 0.0;
    }
    let plen = (pe - ps + 1) as f64;
    let glen = (ge - gs + 1) as f64;
    let prec = inter / plen;
    let rec = inter / glen;
    2.0 * prec * rec / (prec + rec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn examples_well_formed() {
        let c = QaCorpus::new(5);
        let b = c.train_batch(0, 8, 64);
        for r in 0..8 {
            let row = b.tokens.row(r);
            assert_eq!(row[0], T_CLS);
            assert_eq!(row[1], T_Q);
            assert_eq!(row[2], T_SEP);
            let (s, e) = (b.starts[r] as usize, b.ends[r] as usize);
            assert!(s > 2 && e < 64 && e == s + SPAN_LEN + 1);
            assert_eq!(row[s], T_OPEN);
            assert_eq!(row[e], T_CLOSE);
            // inner span is ordinary tokens
            for &t in &row[s + 1..e] {
                assert!((0..ORDINARY as i32).contains(&t));
            }
        }
    }

    #[test]
    fn f1_values() {
        assert_eq!(span_f1((5, 7), (5, 7)), 1.0);
        assert_eq!(span_f1((0, 2), (10, 12)), 0.0);
        let f = span_f1((5, 7), (6, 8)); // overlap 2 of 3
        assert!((f - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn deterministic() {
        let c = QaCorpus::new(5);
        let a = c.eval_batch(1, 4, 64);
        let b = c.eval_batch(1, 4, 64);
        assert_eq!(a.tokens.tokens, b.tokens.tokens);
        assert_eq!(a.starts, b.starts);
        let tr = c.train_batch(1, 4, 64);
        assert_ne!(a.tokens.tokens, tr.tokens.tokens);
    }
}
