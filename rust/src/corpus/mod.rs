//! Synthetic workloads standing in for the paper's datasets (DESIGN.md §1):
//! Wikitext2 → Zipf–Markov text, HumanEval → expression grammar with an
//! exact interpreter, SQuAD → marker-span QA, ImageNet → Gaussian-blob
//! classes.  Everything is deterministic from a seed; train/eval streams
//! are disjoint.

mod code;
mod image;
mod qa;
mod text;

pub use code::{digits, CodeCorpus, Program, CODE_VOCAB};
pub use qa::span_f1;

/// The code corpus statement terminator (used by the Pass@1 decoder).
pub fn code_semi() -> i32 {
    code::T_SEMI
}

/// Family-level corpus seeds. One corpus per model family (like the
/// paper's shared Wikitext2/HumanEval/SQuAD/ImageNet): training, QAT,
/// calibration and evaluation MUST all see the same generative process,
/// so these are constants — only the stream/batch indices vary.
pub const TEXT_SEED: u64 = 0x7E87_0001;
pub const CODE_SEED: u64 = 0x7E87_0002;
pub const QA_SEED: u64 = 0x7E87_0003;
pub const IMG_SEED: u64 = 0x7E87_0004;
pub use image::ImageCorpus;
pub use qa::{QaBatch, QaCorpus, QA_VOCAB};
pub use text::{TextCorpus, TEXT_VOCAB};

/// A (B, S) batch of token ids.
#[derive(Debug, Clone)]
pub struct TokenBatch {
    pub batch: usize,
    pub seq: usize,
    pub tokens: Vec<i32>,
}

impl TokenBatch {
    pub fn new(batch: usize, seq: usize) -> TokenBatch {
        TokenBatch { batch, seq, tokens: vec![0; batch * seq] }
    }

    pub fn row(&self, b: usize) -> &[i32] {
        &self.tokens[b * self.seq..(b + 1) * self.seq]
    }

    pub fn row_mut(&mut self, b: usize) -> &mut [i32] {
        &mut self.tokens[b * self.seq..(b + 1) * self.seq]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_batch_row_views() {
        let mut tb = TokenBatch::new(3, 4);
        tb.row_mut(1).copy_from_slice(&[9, 8, 7, 6]);
        assert_eq!(tb.row(0), &[0, 0, 0, 0]);
        assert_eq!(tb.row(1), &[9, 8, 7, 6]);
        assert_eq!(tb.tokens.len(), 12);
    }

    #[test]
    fn corpora_deterministic_from_seed() {
        // Same seed + same stream index => identical batch; different
        // stream index => different batch (the property every eval
        // comparison in EXPERIMENTS.md relies on).
        let (a, b) = (TextCorpus::new(TEXT_SEED), TextCorpus::new(TEXT_SEED));
        assert_eq!(a.eval_batch(3, 4, 16).tokens, b.eval_batch(3, 4, 16).tokens);
        assert_ne!(a.eval_batch(3, 4, 16).tokens, a.eval_batch(4, 4, 16).tokens);
        let (c, d) = (CodeCorpus::new(CODE_SEED), CodeCorpus::new(CODE_SEED));
        let (pc, pd) = (c.eval_programs(8), d.eval_programs(8));
        for (x, y) in pc.iter().zip(pd.iter()) {
            assert_eq!(x.prompt(), y.prompt());
            assert_eq!(x.completion(), y.completion());
        }
    }

    #[test]
    fn train_and_eval_streams_disjoint() {
        let t = TextCorpus::new(TEXT_SEED);
        // eval batch i must differ from train batch i (disjoint streams)
        let e = t.eval_batch(0, 4, 32).tokens;
        let tr = t.train_batch(0, 4, 32).tokens;
        assert_ne!(e, tr);
    }
}
